//! `lisa-lint` — invariant-enforcing static analysis over `rust/src`
//! (DESIGN.md §14).
//!
//! The repo's correctness rests on cross-cutting contracts that no type
//! checker sees: the serving path must never panic (DESIGN.md §13), the
//! `Operand` device/host decision lives in one funnel (§8), strategies
//! that write weights must report `Touched` (§8), the model thread never
//! blocks on a bounded channel (§11), `unsafe` carries a justification,
//! and completions are a function of `(prompt, spec, seed)` alone (§10).
//! Each contract is a [`Pass`] here, enforced at CI time on every path —
//! not just the ones integration tests happen to execute.
//!
//! The scanner is lexical, not `syn`-based (this build image has no
//! registry access, and the tool must stay dependency-free): source is
//! scrubbed of comments and string/char literals with a line-preserving
//! lexer, `#[cfg(test)]`/`#[test]` regions are tracked by brace
//! matching, and enclosing-`fn` names/return types are recovered from
//! the token stream. That is enough to make every pass precise on this
//! tree; the residual blind spots of each heuristic are documented on
//! the pass and in DESIGN.md §14.
//!
//! Suppression is explicit and audited: only
//! `// lisa-lint: allow(<pass>): <reason>` on the violating line or the
//! line above is honored, and the reason is mandatory — an allow without
//! one is itself a violation.

use std::fmt;
use std::path::Path;

/// Every pass, in reporting order.
pub const PASSES: &[&str] = &[
    "serve_panic",
    "operand_builder",
    "touched_contract",
    "blocking_send",
    "safety_comment",
    "determinism",
    "int_cast",
];

/// One violation, addressed `file:line`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub pass: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.pass, self.msg)
    }
}

// --------------------------------------------------------------- lexer

/// Comment- and literal-scrubbed source: `code` keeps the lexical
/// skeleton (string contents blanked, quotes kept), `comments` keeps
/// only comment text. Both preserve byte-for-byte line structure.
pub struct Scrubbed {
    pub code: String,
    pub comments: String,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Scrub comments and string/char literals out of Rust source while
/// preserving line structure. Handles nested block comments, raw
/// strings (`r#".."#`), byte strings, escapes, and the char-literal vs
/// lifetime ambiguity (`'a'` vs `'a`).
pub fn scrub(src: &str) -> Scrubbed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut code = String::with_capacity(src.len());
    let mut com = String::with_capacity(src.len());
    let blank = |s: &mut String, c: char| s.push(if c == '\n' { '\n' } else { ' ' });
    let mut i = 0;
    let mut prev_code = '\0'; // last char emitted to `code` (ident guard)
    while i < n {
        let c = b[i];
        // line comment
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                blank(&mut code, b[i]);
                com.push(b[i]);
                i += 1;
            }
            continue;
        }
        // block comment (nested)
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 0;
            while i < n {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    com.push('/');
                    com.push('*');
                    blank(&mut code, b[i]);
                    blank(&mut code, b[i + 1]);
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    com.push('*');
                    com.push('/');
                    blank(&mut code, b[i]);
                    blank(&mut code, b[i + 1]);
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    com.push(b[i]);
                    blank(&mut code, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // raw (byte) string: r"..", r#".."#, br#".."# — only when the
        // `r`/`b` does not continue an identifier
        if (c == 'r' || c == 'b') && !is_ident(prev_code) {
            let mut j = i + 1;
            if c == 'b' && j < n && b[j] == 'r' {
                j += 1;
            }
            let mut hashes = 0;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' && (b[i] == 'r' || (b[i] == 'b' && b[i + 1] == 'r') || hashes == 0 && c == 'r') {
                // emit the prefix + opening quote, blank the contents
                for k in i..=j {
                    code.push(b[k]);
                    blank(&mut com, b[k]);
                }
                i = j + 1;
                while i < n {
                    if b[i] == '"' {
                        let mut m = 0;
                        while m < hashes && i + 1 + m < n && b[i + 1 + m] == '#' {
                            m += 1;
                        }
                        if m == hashes {
                            for k in i..=(i + hashes) {
                                code.push(b[k]);
                                blank(&mut com, b[k]);
                            }
                            i += hashes + 1;
                            break;
                        }
                    }
                    blank(&mut code, b[i]);
                    blank(&mut com, b[i]);
                    i += 1;
                }
                prev_code = '"';
                continue;
            }
        }
        // plain (byte) string
        if c == '"' {
            code.push('"');
            blank(&mut com, '"');
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    blank(&mut code, b[i]);
                    blank(&mut code, b[i + 1]);
                    blank(&mut com, b[i]);
                    blank(&mut com, b[i + 1]);
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    code.push('"');
                    blank(&mut com, '"');
                    i += 1;
                    break;
                }
                blank(&mut code, b[i]);
                blank(&mut com, b[i]);
                i += 1;
            }
            prev_code = '"';
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            let next = b.get(i + 1).copied().unwrap_or('\0');
            let is_char = next == '\\'
                || (next != '\0' && b.get(i + 2).copied() == Some('\''))
                || !(next.is_ascii_alphabetic() || next == '_');
            if is_char && next != '\0' {
                code.push('\'');
                blank(&mut com, '\'');
                i += 1;
                while i < n {
                    if b[i] == '\\' && i + 1 < n {
                        blank(&mut code, b[i]);
                        blank(&mut code, b[i + 1]);
                        blank(&mut com, b[i]);
                        blank(&mut com, b[i + 1]);
                        i += 2;
                        continue;
                    }
                    if b[i] == '\'' {
                        code.push('\'');
                        blank(&mut com, '\'');
                        i += 1;
                        break;
                    }
                    blank(&mut code, b[i]);
                    blank(&mut com, b[i]);
                    i += 1;
                }
                prev_code = '\'';
                continue;
            }
            // lifetime: emit as-is
        }
        code.push(c);
        blank(&mut com, c);
        if !c.is_whitespace() {
            prev_code = c;
        }
        i += 1;
    }
    Scrubbed { code, comments: com }
}

// ------------------------------------------------- structural analysis

/// A function item recovered from the token stream.
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    /// Raw text between the argument list and the body (return type +
    /// where clause).
    pub ret: String,
    /// Byte range of the body (inclusive of both braces) in the
    /// scrubbed code.
    pub body: std::ops::Range<usize>,
}

/// Per-file analysis every pass consumes.
pub struct Analysis {
    /// Path with `/` separators, relative to the lint root.
    pub rel: String,
    /// Scrubbed code, joined.
    pub code: String,
    /// Scrubbed code, split into lines.
    pub code_lines: Vec<String>,
    /// Comment text per line.
    pub comment_lines: Vec<String>,
    /// Line (0-based) → inside a `#[cfg(test)]` / `#[test]` region.
    pub in_test: Vec<bool>,
    pub fns: Vec<FnSpan>,
    /// Byte offset of each line start in `code`.
    line_starts: Vec<usize>,
}

impl Analysis {
    pub fn new(rel: &str, src: &str) -> Analysis {
        let Scrubbed { code, comments } = scrub(src);
        let code_lines: Vec<String> = code.split('\n').map(str::to_string).collect();
        let comment_lines: Vec<String> = comments.split('\n').map(str::to_string).collect();
        let mut line_starts = vec![0usize];
        for (off, ch) in code.char_indices() {
            if ch == '\n' {
                line_starts.push(off + 1);
            }
        }
        let in_test = mark_test_regions(&code, &line_starts);
        let fns = find_fns(&code);
        Analysis {
            rel: rel.replace('\\', "/"),
            code,
            code_lines,
            comment_lines,
            in_test,
            fns,
            line_starts,
        }
    }

    /// 0-based line of a byte offset into `code`.
    pub fn line_of(&self, off: usize) -> usize {
        match self.line_starts.binary_search(&off) {
            Ok(l) => l,
            Err(l) => l - 1,
        }
    }

    /// Innermost function whose body contains `off`.
    pub fn enclosing_fn(&self, off: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.body.contains(&off))
            .min_by_key(|f| f.body.end - f.body.start)
    }

    fn is_test_line(&self, line: usize) -> bool {
        self.in_test.get(line).copied().unwrap_or(false)
    }
}

/// Mark lines inside `#[cfg(test)]` / `#[test]` items by brace
/// matching. An attribute whose item ends in `;` before any `{` (e.g.
/// `#[cfg(test)] use ...;`) opens no region.
fn mark_test_regions(code: &str, line_starts: &[usize]) -> Vec<bool> {
    let b: Vec<char> = code.chars().collect();
    let n = b.len();
    let nlines = line_starts.len();
    let mut in_test = vec![false; nlines];
    let mut depth: i64 = 0;
    let mut bracket: i64 = 0; // () + [] nesting, for the `;` cancel rule
    let mut test_stack: Vec<i64> = Vec::new();
    let mut pending = false;
    let mut line = 0usize;
    let mut i = 0;
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if !test_stack.is_empty() {
            in_test[line] = true;
        }
        match c {
            '#' if i + 1 < n && b[i + 1] == '[' => {
                // read the attribute to its matching ]
                let mut j = i + 2;
                let mut d = 1;
                let mut attr = String::new();
                while j < n && d > 0 {
                    match b[j] {
                        '[' => d += 1,
                        ']' => d -= 1,
                        '\n' => line += 1,
                        _ => {}
                    }
                    if d > 0 && !b[j].is_whitespace() {
                        attr.push(b[j]);
                    }
                    j += 1;
                }
                if attr == "test"
                    || (attr.starts_with("cfg(")
                        && attr.contains("test")
                        && !attr.contains("not(test"))
                {
                    pending = true;
                }
                i = j;
                continue;
            }
            '(' | '[' => bracket += 1,
            ')' | ']' => bracket -= 1,
            ';' if pending && bracket == 0 => pending = false,
            '{' => {
                if pending {
                    test_stack.push(depth);
                    pending = false;
                    in_test[line] = true;
                }
                depth += 1;
            }
            '}' => {
                depth -= 1;
                if test_stack.last() == Some(&depth) {
                    in_test[line] = true; // the closing brace line too
                    test_stack.pop();
                }
            }
            _ => {}
        }
        i += 1;
    }
    in_test
}

/// Recover `fn` items (name, return-type text, body range) from the
/// scrubbed token stream. Fn-pointer types (`fn(i32)`) carry no name
/// and are skipped; trait-method declarations without a body likewise.
fn find_fns(code: &str) -> Vec<FnSpan> {
    let b: Vec<char> = code.chars().collect();
    let n = b.len();
    let mut out = Vec::new();
    let mut i = 0;
    while i < n {
        // find the keyword `fn` at an identifier boundary
        if b[i] == 'f'
            && i + 1 < n
            && b[i + 1] == 'n'
            && (i == 0 || !is_ident(b[i - 1]))
            && (i + 2 >= n || !is_ident(b[i + 2]))
        {
            let mut j = i + 2;
            while j < n && b[j].is_whitespace() {
                j += 1;
            }
            // need an identifier: `fn(` is a type, not an item
            if j >= n || !(b[j].is_ascii_alphabetic() || b[j] == '_') {
                i += 2;
                continue;
            }
            let mut name = String::new();
            while j < n && is_ident(b[j]) {
                name.push(b[j]);
                j += 1;
            }
            while j < n && b[j].is_whitespace() {
                j += 1;
            }
            // skip generics, ignoring `->`'s `>`
            if j < n && b[j] == '<' {
                let mut d = 0i64;
                while j < n {
                    match b[j] {
                        '<' => d += 1,
                        '>' if j > 0 && b[j - 1] != '-' => d -= 1,
                        _ => {}
                    }
                    j += 1;
                    if d == 0 {
                        break;
                    }
                }
                while j < n && b[j].is_whitespace() {
                    j += 1;
                }
            }
            // argument list
            if j >= n || b[j] != '(' {
                i = j;
                continue;
            }
            let mut d = 0i64;
            while j < n {
                match b[j] {
                    '(' => d += 1,
                    ')' => d -= 1,
                    _ => {}
                }
                j += 1;
                if d == 0 {
                    break;
                }
            }
            // return type + where clause: up to `{` (body) or `;` (decl)
            let ret_start = j;
            while j < n && b[j] != '{' && b[j] != ';' {
                j += 1;
            }
            let ret: String = b[ret_start..j.min(n)].iter().collect();
            if j >= n || b[j] == ';' {
                i = j;
                continue;
            }
            // body: match braces
            let body_start = j;
            let mut d = 0i64;
            while j < n {
                match b[j] {
                    '{' => d += 1,
                    '}' => d -= 1,
                    _ => {}
                }
                j += 1;
                if d == 0 {
                    break;
                }
            }
            out.push(FnSpan { name, ret: ret.trim().to_string(), body: body_start..j });
            // continue scanning *inside* the body for nested fns
            i = body_start + 1;
            continue;
        }
        i += 1;
    }
    out
}

// -------------------------------------------------------------- passes

fn in_serve_scope(rel: &str) -> bool {
    rel.contains("engine/serve/")
        || rel.contains("serve_http/")
        || rel.ends_with("engine/decode.rs")
        || rel.ends_with("runtime/fault.rs")
}

fn in_determinism_scope(rel: &str) -> bool {
    rel.contains("engine/serve/") || rel.ends_with("eval/generate.rs")
}

/// Positions of `needle` in `hay` at identifier boundaries on both
/// sides (so `Instant` does not match `Instantiate`).
fn word_positions(hay: &str, needle: &str) -> Vec<usize> {
    let hb: Vec<char> = hay.chars().collect();
    let mut out = Vec::new();
    let mut start = 0;
    while let Some(pos) = hay[start..].find(needle) {
        let at = start + pos;
        let before_ok = at == 0 || {
            let c = hb[hay[..at].chars().count() - 1];
            !is_ident(c)
        };
        let after = at + needle.len();
        let after_ok = after >= hay.len() || {
            let c = hay[after..].chars().next().unwrap();
            !is_ident(c)
        };
        if before_ok && after_ok {
            out.push(at);
        }
        start = at + needle.len();
    }
    out
}

/// Pass 1 — panic-freedom on the serving path (DESIGN.md §13): no
/// `unwrap()`/`expect()`/panic-family macros/indexing-of-temporaries in
/// non-test code under `engine/serve/`, `serve_http/`,
/// `engine/decode.rs`, `runtime/fault.rs`. `assert!` is allowed: an
/// invariant check with a message is a contract, a stray unwrap is not.
fn pass_serve_panic(a: &Analysis, out: &mut Vec<Diagnostic>) {
    if !in_serve_scope(&a.rel) {
        return;
    }
    const CALLS: &[(&str, &str)] = &[
        (".unwrap()", "`.unwrap()` can kill the model thread"),
        (".expect(", "`.expect()` can kill the model thread"),
        (".get_unchecked(", "unchecked indexing on the serving path"),
        (".get_unchecked_mut(", "unchecked indexing on the serving path"),
        (")[", "indexing a temporary cannot be bounds-checked first"),
    ];
    const MACROS: &[&str] = &["panic!", "todo!", "unimplemented!", "unreachable!"];
    for (ln, line) in a.code_lines.iter().enumerate() {
        if a.is_test_line(ln) {
            continue;
        }
        for (pat, why) in CALLS {
            if line.contains(pat) {
                out.push(Diagnostic {
                    pass: "serve_panic",
                    file: a.rel.clone(),
                    line: ln + 1,
                    msg: format!(
                        "{why}; return a typed error through the FailClass ladder \
                         (DESIGN.md §13) instead"
                    ),
                });
            }
        }
        for mac in MACROS {
            for at in word_positions(line, &mac[..mac.len() - 1]) {
                if line[at..].starts_with(mac) {
                    out.push(Diagnostic {
                        pass: "serve_panic",
                        file: a.rel.clone(),
                        line: ln + 1,
                        msg: format!(
                            "`{mac}` aborts the model thread; drain the row with \
                             StopReason::Error instead (DESIGN.md §13)"
                        ),
                    });
                }
            }
        }
    }
}

/// The only places allowed to construct `Operand::F32` / `Operand::Buf`
/// (the device/host decision funnel, DESIGN.md §8).
const OPERAND_FUNNEL_FILE: &str = "engine/trainer.rs";
const OPERAND_FUNNEL_FNS: &[&str] =
    &["operand", "embed_ops", "block_ops", "head_ops", "adapter_ops"];

/// Pass 2 — operand-builder discipline: `Operand::Buf(..)` /
/// `Operand::F32(..)` may be *constructed* only inside the Engine
/// operand-builder funnel in `engine/trainer.rs`. Match patterns
/// (`Operand::F32(t) => ...`, `| Operand::Buf(b)`) consume, not
/// construct, and are exempt.
fn pass_operand_builder(a: &Analysis, out: &mut Vec<Diagnostic>) {
    for variant in ["Operand::F32(", "Operand::Buf("] {
        let mut start = 0;
        while let Some(pos) = a.code[start..].find(variant) {
            let at = start + pos;
            start = at + variant.len();
            // identifier boundary on the left (reject e.g. `MyOperand::F32`)
            if a.code[..at].chars().next_back().map(is_ident).unwrap_or(false) {
                continue;
            }
            let ln = a.line_of(at);
            if a.is_test_line(ln) {
                continue;
            }
            // preceded by `|` → or-pattern
            let before = a.code[..at].trim_end();
            if before.ends_with('|') {
                continue;
            }
            // followed (after the matching paren) by `=>`, `|`, or `if`
            // → match pattern
            let open = at + variant.len() - 1;
            let mut d = 0i64;
            let mut close = None;
            for (off, ch) in a.code[open..].char_indices() {
                match ch {
                    '(' => d += 1,
                    ')' => {
                        d -= 1;
                        if d == 0 {
                            close = Some(open + off);
                            break;
                        }
                    }
                    _ => {}
                }
            }
            if let Some(close) = close {
                let after = a.code[close + 1..].trim_start();
                if after.starts_with("=>") || after.starts_with('|') || after.starts_with("if ") {
                    continue;
                }
            }
            // construction: allowed only in the funnel
            let blessed = a.rel.ends_with(OPERAND_FUNNEL_FILE)
                && a
                    .enclosing_fn(at)
                    .map(|f| OPERAND_FUNNEL_FNS.contains(&f.name.as_str()))
                    .unwrap_or(false);
            if !blessed {
                out.push(Diagnostic {
                    pass: "operand_builder",
                    file: a.rel.clone(),
                    line: ln + 1,
                    msg: format!(
                        "`{}..)` constructed outside the Engine operand-builder funnel \
                         ({OPERAND_FUNNEL_FILE}: {}); route device/host operand \
                         decisions through it (DESIGN.md §8)",
                        variant,
                        OPERAND_FUNNEL_FNS.join("/")
                    ),
                });
            }
        }
    }
}

/// Pass 3 — `Touched` contract heuristic: in `strategy/`, an assignment
/// whose left-hand side writes through `params.` / `lora.` must sit in
/// a function whose signature returns `Touched` (the invalidation
/// contract, DESIGN.md §8). Catches direct-field-write escapes that
/// would let the device cache serve stale bytes.
fn pass_touched_contract(a: &Analysis, out: &mut Vec<Diagnostic>) {
    if !a.rel.contains("strategy/") {
        return;
    }
    for (ln, line) in a.code_lines.iter().enumerate() {
        if a.is_test_line(ln) {
            continue;
        }
        let chars: Vec<char> = line.chars().collect();
        for (i, &c) in chars.iter().enumerate() {
            if c != '=' {
                continue;
            }
            let next = chars.get(i + 1).copied().unwrap_or('\0');
            let prev = if i > 0 { chars[i - 1] } else { '\0' };
            if next == '=' || next == '>' || matches!(prev, '=' | '!' | '<' | '>') {
                continue; // ==, =>, !=, <=, >=
            }
            // LHS: this statement's text before the operator
            let lhs_full: String = chars[..i].iter().collect();
            let lhs = lhs_full.rsplit(';').next().unwrap_or("");
            let writes_params = word_positions(lhs, "params")
                .into_iter()
                .any(|p| lhs[p..].starts_with("params."))
                || word_positions(lhs, "lora")
                    .into_iter()
                    .any(|p| lhs[p..].starts_with("lora."));
            if !writes_params {
                continue;
            }
            let off = a.line_starts[ln] + i;
            let ret = a.enclosing_fn(off).map(|f| f.ret.clone()).unwrap_or_default();
            if !ret.contains("Touched") {
                out.push(Diagnostic {
                    pass: "touched_contract",
                    file: a.rel.clone(),
                    line: ln + 1,
                    msg: "direct write to model/LoRA parameters in a function that does \
                          not return `Touched`; the device cache will serve stale bytes \
                          unless the write is reported (DESIGN.md §8)"
                        .to_string(),
                });
                break; // one diagnostic per line is enough
            }
        }
    }
}

/// Pass 4 — blocking-send discipline: code reachable from the model
/// thread (`engine/serve/`, `serve_http/`, `engine/decode.rs`) must
/// never call a blocking `.send(..)`; bounded channels are
/// try_send-or-shed (DESIGN.md §11/§13).
fn pass_blocking_send(a: &Analysis, out: &mut Vec<Diagnostic>) {
    if !(a.rel.contains("engine/serve/")
        || a.rel.contains("serve_http/")
        || a.rel.ends_with("engine/decode.rs"))
    {
        return;
    }
    for (ln, line) in a.code_lines.iter().enumerate() {
        if a.is_test_line(ln) {
            continue;
        }
        if line.contains(".send(") {
            out.push(Diagnostic {
                pass: "blocking_send",
                file: a.rel.clone(),
                line: ln + 1,
                msg: "blocking `.send()` on the model-thread path; a stalled consumer \
                      would wedge the serve loop — use `try_send` and shed \
                      (DESIGN.md §11)"
                    .to_string(),
            });
        }
    }
}

/// Pass 5 — SAFETY-comment coverage: every `unsafe` keyword (blocks,
/// `unsafe impl`, `unsafe fn`) must have a `// SAFETY:` justification
/// on the same line or in the comment block directly above. Applies to
/// test code too — unsafety does not care where it runs.
fn pass_safety_comment(a: &Analysis, out: &mut Vec<Diagnostic>) {
    for (ln, line) in a.code_lines.iter().enumerate() {
        if word_positions(line, "unsafe").is_empty() {
            continue;
        }
        let mut justified = a.comment_lines[ln].contains("SAFETY:");
        // scan upward through comment-only / attribute-only / blank lines
        let mut k = ln;
        while !justified && k > 0 {
            k -= 1;
            if a.comment_lines[k].contains("SAFETY:") {
                justified = true;
                break;
            }
            let code = a.code_lines[k].trim();
            let pure_comment_or_attr =
                code.is_empty() || (code.starts_with("#[") && code.ends_with(']'));
            if !pure_comment_or_attr {
                break;
            }
        }
        if !justified {
            out.push(Diagnostic {
                pass: "safety_comment",
                file: a.rel.clone(),
                line: ln + 1,
                msg: "`unsafe` without a `// SAFETY:` justification on the same line \
                      or directly above"
                    .to_string(),
            });
        }
    }
}

/// Pass 6 — determinism discipline: nothing in `engine/serve/` or
/// `eval/generate.rs` may derive values from wall/monotonic clocks or
/// unordered-map iteration — completions must stay a function of
/// `(prompt, spec, seed)` (DESIGN.md §10). Use `BTreeMap` and counters
/// instead.
fn pass_determinism(a: &Analysis, out: &mut Vec<Diagnostic>) {
    if !in_determinism_scope(&a.rel) {
        return;
    }
    const BANNED: &[(&str, &str)] = &[
        ("SystemTime", "wall-clock time feeding serve-path state"),
        ("Instant", "monotonic-clock time feeding serve-path state"),
        ("HashMap", "iteration order is seeded per process"),
        ("HashSet", "iteration order is seeded per process"),
        ("thread_rng", "unseeded randomness"),
    ];
    for (ln, line) in a.code_lines.iter().enumerate() {
        if a.is_test_line(ln) {
            continue;
        }
        for (word, why) in BANNED {
            if !word_positions(line, word).is_empty() {
                out.push(Diagnostic {
                    pass: "determinism",
                    file: a.rel.clone(),
                    line: ln + 1,
                    msg: format!(
                        "`{word}` on a determinism-scoped path ({why}); completions \
                         must be a function of (prompt, spec, seed) alone \
                         (DESIGN.md §10)"
                    ),
                });
            }
        }
    }
}

/// Files where integer-narrowing `as` casts are load-bearing: page-table
/// and row-cursor arithmetic feeding the segment ABI, and the int8
/// quantizer. `util/cast.rs` is the audited funnel and is deliberately
/// outside the scope.
fn in_int_cast_scope(rel: &str) -> bool {
    rel.ends_with("engine/decode.rs")
        || rel.ends_with("engine/serve/session.rs")
        || rel.contains("opt/quant")
        || rel.contains("runtime/")
}

/// Pass 7 — audited narrowing: in page/quant arithmetic, a bare
/// `as i8|u8|i16|u16|i32|u32` silently truncates on overflow. Non-test
/// code in the scoped files must route through the saturating helpers
/// in `util/cast.rs` (`idx_i32` / `idx_u32` / `sat_i8`), which pin the
/// overflow behavior in one reviewable place (DESIGN.md §14). Widening
/// casts (`as usize`, `as u64`, `as f32`) are exempt.
fn pass_int_cast(a: &Analysis, out: &mut Vec<Diagnostic>) {
    if !in_int_cast_scope(&a.rel) {
        return;
    }
    const NARROW: &[&str] = &["i8", "u8", "i16", "u16", "i32", "u32"];
    for (ln, line) in a.code_lines.iter().enumerate() {
        if a.is_test_line(ln) {
            continue;
        }
        for at in word_positions(line, "as") {
            let after = line[at + 2..].trim_start();
            for ty in NARROW {
                let boundary_ok = after.len() == ty.len()
                    || after
                        .chars()
                        .nth(ty.len())
                        .map(|c| !is_ident(c))
                        .unwrap_or(true);
                if after.starts_with(ty) && boundary_ok {
                    out.push(Diagnostic {
                        pass: "int_cast",
                        file: a.rel.clone(),
                        line: ln + 1,
                        msg: format!(
                            "unchecked `as {ty}` narrowing in page/quant arithmetic; \
                             route through the audited util/cast.rs helpers \
                             (idx_i32/idx_u32/sat_i8) so overflow saturates instead \
                             of wrapping (DESIGN.md §14)"
                        ),
                    });
                    break;
                }
            }
        }
    }
}

// ---------------------------------------------------- allow + driving

/// Parsed `// lisa-lint: allow(<pass>): <reason>` comment.
struct Allow {
    pass: String,
    has_reason: bool,
}

fn allows_on_line(comment: &str) -> Vec<Allow> {
    const NEEDLE: &str = "lisa-lint: allow(";
    let mut out = Vec::new();
    let mut start = 0;
    while let Some(pos) = comment[start..].find(NEEDLE) {
        let at = start + pos + NEEDLE.len();
        let rest = &comment[at..];
        let Some(close) = rest.find(')') else {
            break;
        };
        let pass = rest[..close].trim().to_string();
        let after = &rest[close + 1..];
        let has_reason = after
            .strip_prefix(':')
            .map(|r| {
                let r = r.trim();
                !r.is_empty() && r.chars().any(|c| c.is_alphanumeric())
            })
            .unwrap_or(false);
        out.push(Allow { pass, has_reason });
        start = at + close;
    }
    out
}

/// Run `passes` over one file and apply the allow-comment rules.
pub fn lint_file(rel: &str, src: &str, passes: &[&str]) -> Vec<Diagnostic> {
    let a = Analysis::new(rel, src);
    let mut raw = Vec::new();
    if passes.contains(&"serve_panic") {
        pass_serve_panic(&a, &mut raw);
    }
    if passes.contains(&"operand_builder") {
        pass_operand_builder(&a, &mut raw);
    }
    if passes.contains(&"touched_contract") {
        pass_touched_contract(&a, &mut raw);
    }
    if passes.contains(&"blocking_send") {
        pass_blocking_send(&a, &mut raw);
    }
    if passes.contains(&"safety_comment") {
        pass_safety_comment(&a, &mut raw);
    }
    if passes.contains(&"determinism") {
        pass_determinism(&a, &mut raw);
    }
    if passes.contains(&"int_cast") {
        pass_int_cast(&a, &mut raw);
    }

    // collect allows: line → (pass, ok)
    let mut out = Vec::new();
    for d in raw {
        // an allow on the diagnostic's line or the line above suppresses it
        let lines = [d.line.checked_sub(1), d.line.checked_sub(2)];
        let mut suppressed = false;
        for l in lines.into_iter().flatten() {
            for al in allows_on_line(a.comment_lines.get(l).map(String::as_str).unwrap_or("")) {
                if al.pass == d.pass && al.has_reason {
                    suppressed = true;
                }
            }
        }
        if !suppressed {
            out.push(d);
        }
    }
    // malformed allow comments are violations themselves: a reason is
    // the audit trail that makes the escape hatch reviewable
    for (ln, comment) in a.comment_lines.iter().enumerate() {
        for al in allows_on_line(comment) {
            let known = PASSES.contains(&al.pass.as_str());
            if !known {
                out.push(Diagnostic {
                    pass: "serve_panic", // unknown pass: attribute to pass 1 arbitrarily
                    file: a.rel.clone(),
                    line: ln + 1,
                    msg: format!(
                        "allow comment names unknown pass `{}` (known: {})",
                        al.pass,
                        PASSES.join(", ")
                    ),
                });
            } else if !al.has_reason && passes.contains(&al.pass.as_str()) {
                out.push(Diagnostic {
                    pass: PASSES[PASSES.iter().position(|p| *p == al.pass).unwrap()],
                    file: a.rel.clone(),
                    line: ln + 1,
                    msg: format!(
                        "`lisa-lint: allow({})` requires a reason: \
                         `// lisa-lint: allow({}): <why this is sound>`",
                        al.pass, al.pass
                    ),
                });
            }
        }
    }
    out.sort_by(|x, y| (x.line, x.pass).cmp(&(y.line, y.pass)));
    out
}

/// Recursively lint every `.rs` file under `root` (or `root` itself if
/// it is a file). Paths in diagnostics are relative to `root`.
pub fn lint_tree(root: &Path, passes: &[&str]) -> std::io::Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in files {
        let rel = f
            .strip_prefix(root)
            .map(|p| p.to_string_lossy().replace('\\', "/"))
            .unwrap_or_else(|_| f.to_string_lossy().replace('\\', "/"));
        let src = std::fs::read_to_string(&f)?;
        out.extend(lint_file(&rel, &src, passes));
    }
    Ok(out)
}

fn collect_rs(path: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    if path.is_file() {
        if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<_> =
        std::fs::read_dir(path)?.collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            // never descend into build output
            if p.file_name().map(|n| n == "target").unwrap_or(false) {
                continue;
            }
            collect_rs(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrub_blanks_comments_and_strings_but_keeps_structure() {
        let src = r##"let x = "a { b"; // unwrap() in comment
let r = r#"raw " str"#; /* block
   .expect( */ let c = 'x'; let lt: &'static str = "s";"##;
        let s = scrub(src);
        assert!(!s.code.contains("unwrap"));
        assert!(!s.code.contains("a { b"));
        assert!(!s.code.contains("raw"));
        assert!(!s.code.contains(".expect("));
        assert!(s.code.contains("let c ="));
        assert!(s.code.contains("'static"));
        assert!(s.comments.contains("unwrap() in comment"));
        assert_eq!(s.code.lines().count(), src.lines().count());
    }

    #[test]
    fn test_regions_cover_cfg_test_modules_not_cfg_test_uses() {
        let src = "fn live() {}\n#[cfg(test)]\nuse foo::bar;\nfn live2() {}\n\
                   #[cfg(test)]\nmod tests {\n    fn inner() {}\n}\nfn live3() {}\n";
        let a = Analysis::new("x.rs", src);
        assert!(!a.in_test[0] && !a.in_test[2] && !a.in_test[3]);
        assert!(a.in_test[5] && a.in_test[6] && a.in_test[7]);
        assert!(!a.in_test[8]);
    }

    #[test]
    fn fn_spans_capture_name_and_return_type() {
        let src = "impl X {\n    fn apply(&mut self) -> Result<Touched> {\n        body();\n    }\n}\nfn plain() {}\n";
        let a = Analysis::new("x.rs", src);
        let names: Vec<&str> = a.fns.iter().map(|f| f.name.as_str()).collect();
        assert!(names.contains(&"apply") && names.contains(&"plain"));
        let apply = a.fns.iter().find(|f| f.name == "apply").unwrap();
        assert!(apply.ret.contains("Touched"));
        let off = a.code.find("body").unwrap();
        assert_eq!(a.enclosing_fn(off).unwrap().name, "apply");
    }

    #[test]
    fn serve_panic_flags_unwrap_only_outside_tests_and_scope() {
        let src = "fn f() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn g() { y.unwrap(); }\n}\n";
        let d = lint_file("engine/serve/session.rs", src, PASSES);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 1);
        assert!(lint_file("lisa/mod.rs", src, PASSES).is_empty());
    }

    #[test]
    fn operand_patterns_are_not_construction() {
        let src = "fn f(op: &Operand) -> u32 {\n    match op {\n        Operand::F32(t) => 1,\n        Operand::Buf(b) if b.big() => 2,\n        Operand::F32(_) | Operand::Buf(_) => 3,\n    }\n}\n";
        assert!(lint_file("runtime/client.rs", src, PASSES).is_empty());
        let bad = "fn f() { run(&[Operand::F32(&t)]); }\n";
        let d = lint_file("engine/memory.rs", bad, PASSES);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].pass, "operand_builder");
    }

    #[test]
    fn operand_construction_allowed_in_the_funnel() {
        let src = "impl Act {\n    fn operand(&self) -> Operand<'_> {\n        Operand::F32(&self.t)\n    }\n}\n";
        assert!(lint_file("engine/trainer.rs", src, PASSES).is_empty());
        // same code outside the funnel file is a violation
        assert_eq!(lint_file("engine/serve/mod.rs", src, PASSES).len(), 1);
    }

    #[test]
    fn touched_contract_requires_touched_return() {
        let bad = "fn apply(params: &mut P) {\n    params.blocks[0].w = 1.0;\n}\n";
        let d = lint_file("strategy/lomo.rs", bad, PASSES);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].pass, "touched_contract");
        let ok = "fn apply(params: &mut P) -> Touched {\n    params.blocks[0].w = 1.0;\n    Touched::All\n}\n";
        assert!(lint_file("strategy/lomo.rs", ok, PASSES).is_empty());
        // comparisons are not writes
        let cmp = "fn check(params: &P) -> bool {\n    params.lr == 0.1\n}\n";
        assert!(lint_file("strategy/lomo.rs", cmp, PASSES).is_empty());
    }

    #[test]
    fn allow_comment_with_reason_suppresses_without_reason_errors() {
        let src = "fn f() {\n    // lisa-lint: allow(serve_panic): constructor asserts non-empty\n    x.unwrap();\n}\n";
        assert!(lint_file("engine/serve/session.rs", src, PASSES).is_empty());
        let bare = "fn f() {\n    // lisa-lint: allow(serve_panic)\n    x.unwrap();\n}\n";
        let d = lint_file("engine/serve/session.rs", bare, PASSES);
        assert_eq!(d.len(), 2, "{d:?}"); // the unwrap AND the reasonless allow
    }

    #[test]
    fn safety_comments_are_required_adjacent() {
        let ok = "// SAFETY: the slice outlives the call\nlet b = unsafe { cast(x) };\n";
        assert!(lint_file("model/checkpoint.rs", ok, PASSES).is_empty());
        let far = "// SAFETY: stale\nfn g() {}\nlet b = unsafe { cast(x) };\n";
        assert_eq!(lint_file("model/checkpoint.rs", far, PASSES).len(), 1);
    }

    #[test]
    fn determinism_scope_bans_clocks_and_hash_iteration() {
        let bad = "fn pick() { let t = Instant::now(); let m = HashMap::new(); }\n";
        let d = lint_file("engine/serve/sampler.rs", bad, PASSES);
        assert_eq!(d.len(), 2, "{d:?}");
        // Instant is fine outside the determinism scope (metrics want it)
        assert!(lint_file("serve_http/metrics.rs", bad, PASSES)
            .iter()
            .all(|d| d.pass != "determinism"));
        // the word inside an identifier does not match
        let ok = "/// Instantiate the sampler.\nfn build() {}\n";
        assert!(lint_file("engine/serve/sampler.rs", ok, PASSES).is_empty());
    }

    #[test]
    fn int_cast_flags_bare_narrowing_only_in_scope() {
        let bad = "fn f(n: usize) -> i32 { n as i32 }\n";
        let d = lint_file("engine/decode.rs", bad, PASSES);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].pass, "int_cast");
        // in scope for runtime/ and the quantizer too
        assert_eq!(lint_file("runtime/device_cache.rs", bad, PASSES).len(), 1);
        assert_eq!(lint_file("opt/quant.rs", bad, PASSES).len(), 1);
        // widening casts are exempt; so are out-of-scope files (including
        // the audited funnel itself)
        let wide = "fn f(n: u32) -> usize { n as usize }\n";
        assert!(lint_file("engine/decode.rs", wide, PASSES).is_empty());
        assert!(lint_file("model/checkpoint.rs", bad, PASSES).is_empty());
        assert!(lint_file("util/cast.rs", bad, PASSES).is_empty());
        // test code is exempt, and `as` inside an identifier is not a cast
        let test = "#[cfg(test)]\nmod tests {\n    fn g(n: usize) -> i32 { n as i32 }\n}\n";
        assert!(lint_file("engine/decode.rs", test, PASSES).is_empty());
        let ident = "fn f(x: &T) -> V { x.astype(i32_kind) }\n";
        assert!(lint_file("engine/decode.rs", ident, PASSES).is_empty());
    }

    #[test]
    fn blocking_send_flags_send_not_try_send() {
        let bad = "fn f(tx: &SyncSender<u8>) { tx.send(1).ok(); }\n";
        let d = lint_file("serve_http/server.rs", bad, PASSES);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].pass, "blocking_send");
        let ok = "fn f(tx: &SyncSender<u8>) { tx.try_send(1).ok(); }\n";
        assert!(lint_file("serve_http/server.rs", ok, PASSES).is_empty());
    }
}
