//! CLI for `lisa-lint` (DESIGN.md §14).
//!
//! ```text
//! lisa-lint [--pass <name>]... [--list-passes] [paths...]
//! ```
//!
//! Default path is `rust/src` (run from the repo root; CI does).
//! Exit codes: 0 clean, 1 violations found, 2 usage / I/O error.
//! Diagnostics go to stdout as `file:line: [pass] message`; the summary
//! goes to stderr so tooling can consume stdout alone.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut passes: Vec<&'static str> = Vec::new();
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--pass" => {
                let Some(name) = args.next() else {
                    eprintln!("lisa-lint: --pass requires a pass name");
                    return ExitCode::from(2);
                };
                match lisa_lint::PASSES.iter().find(|p| **p == name) {
                    Some(p) => passes.push(p),
                    None => {
                        eprintln!(
                            "lisa-lint: unknown pass `{name}` (known: {})",
                            lisa_lint::PASSES.join(", ")
                        );
                        return ExitCode::from(2);
                    }
                }
            }
            "--list-passes" => {
                for p in lisa_lint::PASSES {
                    println!("{p}");
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                eprintln!(
                    "usage: lisa-lint [--pass <name>]... [--list-passes] [paths...]\n\
                     default path: rust/src    passes: {}",
                    lisa_lint::PASSES.join(", ")
                );
                return ExitCode::SUCCESS;
            }
            flag if flag.starts_with("--") => {
                eprintln!("lisa-lint: unknown flag `{flag}` (try --help)");
                return ExitCode::from(2);
            }
            path => paths.push(PathBuf::from(path)),
        }
    }
    if passes.is_empty() {
        passes = lisa_lint::PASSES.to_vec();
    }
    if paths.is_empty() {
        paths.push(PathBuf::from("rust/src"));
    }

    let mut diags = Vec::new();
    let mut files_seen = false;
    for root in &paths {
        if !root.exists() {
            eprintln!("lisa-lint: no such path: {}", root.display());
            return ExitCode::from(2);
        }
        files_seen = true;
        match lisa_lint::lint_tree(root, &passes) {
            Ok(mut d) => {
                // prefix diagnostics with the root so multi-root runs
                // stay unambiguous (single-root runs keep bare rels)
                if paths.len() > 1 {
                    let tag = root.display().to_string();
                    for diag in &mut d {
                        diag.file = format!("{tag}/{}", diag.file);
                    }
                }
                diags.extend(d);
            }
            Err(e) => {
                eprintln!("lisa-lint: error reading {}: {e}", root.display());
                return ExitCode::from(2);
            }
        }
    }
    let _ = files_seen;

    for d in &diags {
        println!("{d}");
    }
    if diags.is_empty() {
        eprintln!("lisa-lint: clean ({} passes)", passes.len());
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "lisa-lint: {} violation(s) across {} pass(es)",
            diags.len(),
            passes.len()
        );
        ExitCode::from(1)
    }
}
