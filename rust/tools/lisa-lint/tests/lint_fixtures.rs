//! Self-test: run the built `lisa-lint` binary over each pass's fixture
//! pair. Every pass must flag its `bad/` tree (exit 1, diagnostics on
//! stdout) and pass its `ok/` tree (exit 0) under a `--pass` filter, so
//! a regression in any one pass fails exactly its own case.

use std::path::PathBuf;
use std::process::{Command, Output};

fn fixture(pass: &str, kind: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(pass)
        .join(kind)
}

fn run_lint(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_lisa-lint"))
        .args(args)
        .output()
        .expect("spawn lisa-lint")
}

fn run_pass(pass: &str, kind: &str) -> Output {
    let root = fixture(pass, kind);
    assert!(root.is_dir(), "missing fixture tree {}", root.display());
    run_lint(&["--pass", pass, root.to_str().expect("utf-8 path")])
}

fn check_pair(pass: &str, expect_bad: usize) {
    let bad = run_pass(pass, "bad");
    let stdout = String::from_utf8_lossy(&bad.stdout);
    assert_eq!(
        bad.status.code(),
        Some(1),
        "[{pass}] bad fixture must exit 1; stdout:\n{stdout}"
    );
    let flagged = stdout.lines().filter(|l| l.contains(&format!("[{pass}]"))).count();
    assert_eq!(
        flagged, expect_bad,
        "[{pass}] bad fixture diagnostic count; stdout:\n{stdout}"
    );
    // diagnostics carry file:line anchors relative to the lint root
    assert!(
        stdout.lines().all(|l| l.is_empty() || l.contains(".rs:")),
        "[{pass}] diagnostics must be file:line addressed; stdout:\n{stdout}"
    );

    let ok = run_pass(pass, "ok");
    assert_eq!(
        ok.status.code(),
        Some(0),
        "[{pass}] ok fixture must exit 0; stdout:\n{}",
        String::from_utf8_lossy(&ok.stdout)
    );
}

#[test]
fn serve_panic_fixtures() {
    check_pair("serve_panic", 8);
}

#[test]
fn operand_builder_fixtures() {
    check_pair("operand_builder", 2);
}

#[test]
fn touched_contract_fixtures() {
    check_pair("touched_contract", 2);
}

#[test]
fn blocking_send_fixtures() {
    check_pair("blocking_send", 1);
}

#[test]
fn safety_comment_fixtures() {
    check_pair("safety_comment", 2);
}

#[test]
fn determinism_fixtures() {
    check_pair("determinism", 6);
}

#[test]
fn int_cast_fixtures() {
    check_pair("int_cast", 3);
}

#[test]
fn allow_comment_with_reason_suppresses() {
    let out = run_pass("serve_panic", "../allow/ok");
    assert_eq!(
        out.status.code(),
        Some(0),
        "justified allows must suppress; stdout:\n{}",
        String::from_utf8_lossy(&out.stdout)
    );
}

#[test]
fn allow_comment_without_reason_is_a_violation() {
    let out = run_pass("serve_panic", "../allow/bad");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(1), "stdout:\n{stdout}");
    assert!(
        stdout.contains("requires a reason"),
        "the reasonless allow itself must be flagged; stdout:\n{stdout}"
    );
    // and it suppresses nothing: the underlying violation still fires
    assert!(
        stdout.lines().filter(|l| l.contains("[serve_panic]")).count() >= 2,
        "stdout:\n{stdout}"
    );
}

#[test]
fn unknown_pass_name_is_a_usage_error() {
    let out = run_lint(&["--pass", "no_such_pass", "."]);
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn list_passes_names_all_seven() {
    let out = run_lint(&["--list-passes"]);
    assert_eq!(out.status.code(), Some(0));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for p in [
        "serve_panic",
        "operand_builder",
        "touched_contract",
        "blocking_send",
        "safety_comment",
        "determinism",
        "int_cast",
    ] {
        assert!(stdout.contains(p), "missing pass {p} in --list-passes");
    }
}

/// The whole suite at once over every `bad/` tree: all passes fire
/// together and the summary goes to stderr, diagnostics to stdout.
#[test]
fn full_run_over_all_bad_fixtures_reports_everything() {
    let base = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let mut args: Vec<String> = Vec::new();
    for p in [
        "serve_panic",
        "operand_builder",
        "touched_contract",
        "blocking_send",
        "safety_comment",
        "determinism",
        "int_cast",
    ] {
        args.push(base.join(p).join("bad").to_string_lossy().into_owned());
    }
    let arg_refs: Vec<&str> = args.iter().map(String::as_str).collect();
    let out = run_lint(&arg_refs);
    assert_eq!(out.status.code(), Some(1));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("violation"), "summary on stderr: {stderr}");
}
