//! Positive fixture: writes report `Touched`; reads and comparisons are
//! free.

fn apply(params: &mut ModelParams, lora: &mut LoraState) -> Touched {
    params.blocks[0].data[0] = 1.0;
    lora.a.data[3] += 0.5;
    Touched::Blocks(vec![0])
}

fn inspect(params: &ModelParams) -> bool {
    let lr = params.lr;
    params.step == 0 && lr >= 0.0
}
