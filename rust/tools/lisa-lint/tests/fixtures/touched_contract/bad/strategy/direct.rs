//! Negative fixture: parameter writes in a function not returning
//! `Touched`.

fn clobber(params: &mut ModelParams, lora: &mut LoraState) {
    params.blocks[0].data[0] = 1.0;
    lora.a.data[3] += 0.5;
}
