//! Negative fixture: Operand constructed outside the blessed funnel.

fn run_direct(t: &HostTensor, b: &DeviceTensor) {
    let ops = [Operand::F32(t), Operand::Buf(b)];
    execute(&ops);
}

fn execute(_ops: &[Operand]) {}
