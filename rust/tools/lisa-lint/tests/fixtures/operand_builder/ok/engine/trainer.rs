//! Positive fixture: construction inside the funnel, matches elsewhere.

impl Act {
    fn operand(&self) -> Operand<'_> {
        match self {
            Act::Host(t) => Operand::F32(t),
            Act::Dev(b) => Operand::Buf(b),
        }
    }
}

fn classify(op: &Operand) -> &'static str {
    // consuming a variant in a match pattern is not construction
    match op {
        Operand::F32(_) | Operand::Buf(_) => "tensor",
        Operand::Buf(b) if b.big() => "big",
        _ => "other",
    }
}
