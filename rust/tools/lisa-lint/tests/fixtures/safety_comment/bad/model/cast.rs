//! Negative fixture: unsafe without an adjacent SAFETY justification.

fn as_bytes(data: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

struct Ptr(*mut u8);
unsafe impl Send for Ptr {}
