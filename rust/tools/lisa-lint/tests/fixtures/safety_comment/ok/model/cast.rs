//! Positive fixture: every unsafe carries a SAFETY justification.

fn as_bytes(data: &[f32]) -> &[u8] {
    // SAFETY: an f32 slice viewed as its own bytes — same allocation,
    // same length, stricter source alignment.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

struct Ptr(*mut u8);
// SAFETY: the pointer is only dereferenced by the one thread that owns
// the slot it points to.
#[allow(dead_code)]
unsafe impl Send for Ptr {}

fn same_line(x: &[u8]) -> u8 {
    unsafe { *x.as_ptr() } // SAFETY: caller guarantees non-empty
}
