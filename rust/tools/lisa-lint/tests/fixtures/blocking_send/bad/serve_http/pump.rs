//! Negative fixture: blocking send on a model-thread-reachable path.

fn forward(tx: &std::sync::mpsc::SyncSender<i32>, tok: i32) {
    tx.send(tok).ok();
}
