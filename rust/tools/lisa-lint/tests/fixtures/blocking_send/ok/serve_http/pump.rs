//! Positive fixture: try_send-or-shed, never a blocking send.

fn forward(tx: &std::sync::mpsc::SyncSender<i32>, tok: i32) -> bool {
    tx.try_send(tok).is_ok()
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_block() {
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        tx.send(7).unwrap();
        assert_eq!(rx.recv().unwrap(), 7);
    }
}
