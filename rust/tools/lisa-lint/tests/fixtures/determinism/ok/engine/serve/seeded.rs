//! Positive fixture: deterministic selection — seeded streams, ordered
//! maps, counters instead of clocks.
//!
//! Doc text may Instantiate words that embed banned stems; the scrubber
//! must not flag them.

use std::collections::BTreeMap;

fn pick(logits: &[f32], seed: u64) -> usize {
    let mut ranked: BTreeMap<usize, u32> = BTreeMap::new();
    for (i, &l) in logits.iter().enumerate() {
        ranked.insert(i, l.to_bits());
    }
    let step = (seed as usize).wrapping_mul(31);
    ranked.keys().next().copied().unwrap_or(step % logits.len().max(1))
}

#[cfg(test)]
mod tests {
    use std::time::Instant;

    #[test]
    fn tests_may_time_things() {
        let t0 = Instant::now();
        assert!(super::pick(&[0.5, 0.25], 7) < 2);
        assert!(t0.elapsed().as_secs() < 60);
    }
}
