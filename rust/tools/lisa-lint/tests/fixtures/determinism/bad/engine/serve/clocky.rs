//! Negative fixture: clocks and unordered maps feeding token selection.

use std::collections::HashMap;
use std::time::{Instant, SystemTime};

fn pick(logits: &[f32]) -> usize {
    let t = Instant::now().elapsed().subsec_nanos() as usize;
    let s = SystemTime::now();
    let mut seen: HashMap<usize, f32> = HashMap::new();
    for (i, &l) in logits.iter().enumerate() {
        seen.insert(i, l);
    }
    let _ = s;
    seen.keys().next().copied().unwrap_or(t % logits.len())
}
