//! Positive fixture: narrowing routes through the audited helpers;
//! widening casts and test code stay exempt.

fn page_id(n_pages: usize) -> u32 {
    crate::util::cast::idx_u32(n_pages)
}

fn widen(x: u32) -> usize {
    x as usize
}

fn to_float(x: i32) -> f32 {
    x as f32
}

fn justified(v: usize) -> i32 {
    // lisa-lint: allow(int_cast): v is a loop index bounded by batch size
    v as i32
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_narrow() {
        let n = 5usize;
        assert_eq!(n as i32, 5);
    }
}
