//! Negative fixture: bare narrowing `as` casts in page/quant arithmetic.

fn page_id(n_pages: usize) -> u32 {
    n_pages as u32
}

fn row_cursor(fed: usize) -> i32 {
    fed as i32
}

fn quantize_one(v: f32) -> i8 {
    v as i8
}
