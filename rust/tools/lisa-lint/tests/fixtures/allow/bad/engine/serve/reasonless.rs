//! Allow-comment fixture: a reasonless allow is itself a violation and
//! suppresses nothing.

fn first(xs: &[i32]) -> i32 {
    // lisa-lint: allow(serve_panic)
    *xs.first().expect("non-empty")
}
