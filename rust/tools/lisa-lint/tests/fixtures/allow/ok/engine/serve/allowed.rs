//! Allow-comment fixture: a justified suppression silences the pass.

fn first(xs: &[i32]) -> i32 {
    // lisa-lint: allow(serve_panic): the caller asserts non-empty at admission
    *xs.first().expect("non-empty")
}

fn same_line(xs: &[i32]) -> i32 {
    xs.iter().copied().next().unwrap() // lisa-lint: allow(serve_panic): iterator is never empty here
}
