//! Positive fixture: the same logic written panic-free, plus test code
//! where panics are allowed.

fn drive(xs: &[i32], opt: Option<i32>) -> Result<i32, String> {
    let a = opt.ok_or_else(|| "missing operand".to_string())?;
    let c = xs.first().copied().unwrap_or(0);
    assert!(a >= 0, "invariant checks are contracts, not strays");
    let d = xs.get(0).copied().unwrap_or_default();
    Ok(a + c + d)
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_panic() {
        let v: Option<i32> = Some(3);
        assert_eq!(v.unwrap(), 3);
        v.expect("tests panic by design");
    }
}
