//! Negative fixture: every serve_panic trigger in non-test code.

fn drive(xs: &[i32], opt: Option<i32>) -> i32 {
    let a = opt.unwrap();
    let b = opt.expect("present");
    let c = xs.first().copied().unwrap_or(0); // fine: unwrap_or is total
    if xs.is_empty() {
        panic!("empty batch");
    }
    match a {
        0 => todo!(),
        1 => unimplemented!(),
        2 => unreachable!("impossible"),
        _ => {}
    }
    let d = head(xs)[0];
    a + b + c + d
}

fn head(xs: &[i32]) -> &[i32] {
    xs
}

unsafe fn raw(xs: &[i32]) -> i32 {
    // SAFETY: fixture only; index checked by the caller.
    *xs.get_unchecked(0)
}
