//! Vendored offline shim of the `log` facade (rust/vendor/README.md).
//! Implements the subset this workspace uses: the five leveled macros,
//! the [`Log`] trait, [`set_logger`]/[`set_max_level`], and the
//! `Level`-vs-`LevelFilter` comparisons `util::logger` performs.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single log record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        })
    }
}

/// Maximum-verbosity filter installed via [`set_max_level`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata for a record: level + target module path.
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record as handed to [`Log::log`].
#[derive(Debug, Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// The backend trait; `util::logger::StderrLogger` is the one impl.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum verbosity; records above it are skipped
/// before formatting.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The currently installed maximum verbosity.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: build a record and hand it to the installed logger.
/// Public because the exported macros expand to it; not a stable API.
#[doc(hidden)]
pub fn __log_impl(level: Level, target: &str, args: fmt::Arguments) {
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__log_impl(lvl, module_path!(), format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Error, $($arg)+));
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Warn, $($arg)+));
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Info, $($arg)+));
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Debug, $($arg)+));
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Trace, $($arg)+));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_compares_against_filter() {
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(!(Level::Error <= LevelFilter::Off));
    }

    #[test]
    fn max_level_round_trips() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
