//! Vendored offline shim of `anyhow` (rust/vendor/README.md).
//!
//! The workspace depends on a specific subset of anyhow's semantics,
//! all kept here:
//!
//! * [`Error`] wraps a typed root error (`dyn std::error::Error`) under
//!   a stack of string context frames;
//! * [`Error::downcast_ref`] reaches the root **through** any number of
//!   `.context(...)` frames — the serve loop classifies
//!   `FaultError` this way (DESIGN.md §13);
//! * `Display` shows the outermost message, `{:#}` the whole chain
//!   (`outer: inner: root`), matching what the error-path tests assert;
//! * [`Context`] is implemented for `Result` (any std error *or*
//!   already-`anyhow` error) and `Option`;
//! * `anyhow!` / `bail!` / `ensure!` with format args, plus the
//!   autoref-specialized single-expression `anyhow!(err)` form that
//!   preserves the error type for downcasting.

use std::error::Error as StdError;
use std::fmt::{self, Debug, Display};

/// `Result` defaulted to [`Error`], as in real anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error: a typed root plus context frames (outermost first).
pub struct Error {
    context: Vec<String>,
    root: Box<dyn StdError + Send + Sync + 'static>,
}

/// Root for message-only errors (`anyhow!("...")`).
struct Message(String);

impl Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl Debug for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for Message {}

impl Error {
    /// Wrap a typed error; it stays downcastable at the root.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { context: Vec::new(), root: Box::new(error) }
    }

    /// A message-only error.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { context: Vec::new(), root: Box::new(Message(message.to_string())) }
    }

    /// Push a context frame; the typed root is untouched, so
    /// `downcast_ref` keeps working.
    pub fn context<C: Display + Send + Sync + 'static>(mut self, context: C) -> Error {
        self.context.insert(0, context.to_string());
        self
    }

    /// Downcast to the typed root error, looking through every context
    /// frame (the property `runtime::fault` pins in its tests).
    pub fn downcast_ref<T: StdError + 'static>(&self) -> Option<&T> {
        let root: &(dyn StdError + Send + Sync + 'static) = self.root.as_ref();
        root.downcast_ref::<T>()
    }

    /// The innermost (root) error.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        self.root.as_ref()
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, outermost context first
            for c in &self.context {
                write!(f, "{c}: ")?;
            }
            write!(f, "{}", self.root)
        } else {
            match self.context.first() {
                Some(c) => f.write_str(c),
                None => write!(f, "{}", self.root),
            }
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:#}")?;
        if !self.context.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in self.context.iter().skip(1) {
                write!(f, "\n    {c}")?;
            }
            write!(f, "\n    {}", self.root)?;
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`: that is
// what makes this blanket conversion coherent (same shape as real
// anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

mod ext {
    use super::*;

    /// Sealed dispatch for [`Context`]: either a std error (wrap it)
    /// or an [`Error`] (push a frame). Mirrors anyhow's `ext::StdError`.
    pub trait ExtContext {
        fn ext_context<C: Display + Send + Sync + 'static>(self, context: C) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> ExtContext for E {
        fn ext_context<C: Display + Send + Sync + 'static>(self, context: C) -> Error {
            Error::new(self).context(context)
        }
    }

    impl ExtContext for Error {
        fn ext_context<C: Display + Send + Sync + 'static>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// `.context(...)` / `.with_context(...)` on `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::ExtContext> Context<T, E> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Autoref-specialization plumbing for the `anyhow!($expr)` form:
/// `(&e).anyhow_kind()` picks `Trait` when the expression converts
/// into [`Error`] (typed errors — preserved for downcast) and `Adhoc`
/// when it is merely `Display` (becomes a message). Not a stable API.
#[doc(hidden)]
pub mod kind {
    use super::*;

    pub struct Adhoc;

    pub trait AdhocKind: Sized {
        fn anyhow_kind(&self) -> Adhoc {
            Adhoc
        }
    }

    impl<T: ?Sized + Display> AdhocKind for &T {}

    impl Adhoc {
        pub fn new<M: Display>(self, message: M) -> Error {
            Error::msg(message)
        }
    }

    pub struct Trait;

    pub trait TraitKind: Sized {
        fn anyhow_kind(&self) -> Trait {
            Trait
        }
    }

    impl<E: Into<Error>> TraitKind for E {}

    impl Trait {
        pub fn new<E: Into<Error>>(self, error: E) -> Error {
            error.into()
        }
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {{
        use $crate::kind::*;
        let error = $err;
        (&error).anyhow_kind().new(error)
    }};
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Typed(u32);

    impl Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error #{}", self.0)
        }
    }

    impl StdError for Typed {}

    #[test]
    fn downcast_survives_context_frames() {
        let err: Error = Typed(7).into();
        let err = err.context("outer").context("outermost");
        assert_eq!(err.downcast_ref::<Typed>().unwrap().0, 7);
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let err = Error::new(Typed(1)).context("reading header");
        assert_eq!(format!("{err}"), "reading header");
        assert_eq!(format!("{err:#}"), "reading header: typed error #1");
    }

    #[test]
    fn result_and_option_context() {
        fn fails() -> Result<(), Typed> {
            Err(Typed(2))
        }
        let e = fails().context("step").unwrap_err();
        assert_eq!(format!("{e:#}"), "step: typed error #2");
        assert!(e.downcast_ref::<Typed>().is_some());

        let none: Option<u8> = None;
        let e = none.with_context(|| "missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn macros_build_messages_and_preserve_typed_errors() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let n = 3;
        let e = anyhow!("got {n} and {}", 4);
        assert_eq!(format!("{e}"), "got 3 and 4");
        let e = anyhow!(Typed(9));
        assert!(e.downcast_ref::<Typed>().is_some());

        fn bails() -> Result<()> {
            bail!("bad {}", "news");
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "bad news");

        fn ensures(x: u32) -> Result<()> {
            ensure!(x > 2, "x was {x}");
            Ok(())
        }
        assert!(ensures(3).is_ok());
        assert_eq!(format!("{}", ensures(1).unwrap_err()), "x was 1");
    }
}
