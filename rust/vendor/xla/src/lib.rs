//! Vendored **stub** of the PJRT/XLA wrapper crate
//! (rust/vendor/README.md).
//!
//! Signature-compatible with the subset `runtime/` + `engine/` call,
//! but carries no native runtime: every entry point that would touch a
//! device returns [`Error`] at runtime. The artifact-gated test tiers
//! check for `artifacts/*/manifest.json` before constructing a
//! [`PjRtClient`], so the always-on tiers never reach these stubs; a
//! machine with the real XLA toolchain swaps this path dependency for
//! the real crate with no source changes.

use std::fmt;
use std::path::Path;

/// The stub's uniform failure: the PJRT runtime is not in this build.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: PJRT runtime unavailable in this build \
                 (stub xla crate; see rust/vendor/README.md)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the workspace uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    S8,
}

/// Host-native scalar types admissible in buffers/literals.
pub trait NativeType: Copy {
    const DTYPE: ElementType;
}

impl NativeType for f32 {
    const DTYPE: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const DTYPE: ElementType = ElementType::S32;
}

impl NativeType for i8 {
    const DTYPE: ElementType = ElementType::S8;
}

/// A host-side literal value. Never constructible through the stub
/// (every constructor errors first), so the methods are unreachable in
/// practice; they still return `Err` rather than panic.
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(Error::unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// A device placement handle (opaque in the stub).
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice {
    _private: (),
}

/// A device-resident buffer (opaque in the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// The PJRT client handle.
#[derive(Debug, Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

/// A compiled executable handle (opaque in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Parsed HLO module text (opaque in the stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let _ = path.as_ref();
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation handle (opaque in the stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_a_typed_unavailable_error_not_a_panic() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT runtime unavailable"));
        let err = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[0; 16])
            .unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }
}
