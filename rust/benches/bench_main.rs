//! `cargo bench` — the performance harness (criterion is unavailable in
//! this image; `lisa::util::bench` provides warmup + median/p95 timing).
//!
//! Groups map to the paper artifacts they feed:
//! * `step/*`        — Fig 4 (single-iteration time per method)
//! * `segment/*`     — per-executable latency, pallas vs jnp (L1 ablation)
//! * `adamw/*`       — Rust optimizer vs fused-kernel artifact (§Perf)
//! * `galore/*`      — projection cost (baseline overhead)
//! * `host/*`        — L3 substrate hot paths (tensor bridge, dataloader,
//!                     tokenizer, sampler)
//! * `decode/*`      — serving: legacy full-forward vs KV-cached decode
//!                     (`decode/paged-tiny` adds the ABI v2 paged layout)
//! * `serve/*`       — serving: static vs continuous batching (tokens/sec),
//!                     the shared-prefix page-reuse arm
//!                     (`serve/paged-prefix-tiny`: prefill work saved by
//!                     prefix-cache adoption), plus the same queue through
//!                     the `lisa serve` HTTP front end (`serve/http-tiny`:
//!                     loopback tokens/sec with TTFT p50/p99 from the
//!                     /metrics histograms)
//!
//! Set `LISA_BENCH_QUICK=1` for a fast smoke pass.
//!
//! Every run writes the machine-readable `BENCH_step.json` at the repo
//! root (schema `lisa-bench-v1`); the `step/*-hostpath` arms rerun the
//! training step with the device-resident flow disabled, so the file
//! always carries the before/after pair for the runtime's data-movement
//! optimization.

use std::path::Path;

use lisa::data::tokenizer::{EOS, PAD};
use lisa::data::{corpus, encode_sft, DataLoader, Tokenizer};
use lisa::engine::{DecodeSession, Engine, KvMode, Request, ServeSession};
use lisa::eval::generate;
use lisa::lisa::{LisaConfig, LisaScheduler};
use lisa::model::{ModelParams, ParamKey};
use lisa::opt::{adamw::AdamHp, AdamW, Galore, GaloreHp, StatePolicy};
use lisa::runtime::{HostTensor, Operand, Runtime};
use lisa::strategy::StrategySpec;
use lisa::train::{TrainConfig, TrainSession};
use lisa::util::bench::{black_box, Bench};
use lisa::util::rng::Rng;

fn bench() -> Bench {
    if std::env::var("LISA_BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench {
            warmup: std::time::Duration::from_millis(200),
            target_time: std::time::Duration::from_secs(3),
            min_iters: 5,
            max_iters: 50_000,
        }
    }
}

fn main() -> anyhow::Result<()> {
    lisa::util::logger::init();
    let b = bench();
    let mut results = Vec::new();

    // ---------------- host substrates (always run) ----------------
    {
        let mut rng = Rng::new(1);
        let n = 1 << 20;
        let mut p = vec![0f32; n];
        rng.fill_normal(&mut p, 1.0);
        let mut g = vec![0f32; n];
        rng.fill_normal(&mut g, 0.1);
        let hp = AdamHp::default();
        let mut opt = AdamW::new(hp, StatePolicy::Keep);
        results.push(b.run_with_elements("adamw/rust-1M-params", n as u64, || {
            opt.step(ParamKey::Emb, true, &mut p, &g);
        }));

        let mut gal = Galore::new(GaloreHp { rank: 32, update_proj_gap: 1_000_000, ..Default::default() }, 2);
        let (rows, cols) = (512, 2048);
        let mut w = vec![0f32; rows * cols];
        let gw = vec![0.01f32; rows * cols];
        gal.step_matrix(ParamKey::Block(0, 1), true, &mut w, &gw, rows, cols); // build proj
        results.push(b.run_with_elements("galore/project-512x2048-r32", (rows * cols) as u64, || {
            gal.step_matrix(ParamKey::Block(0, 1), true, &mut w, &gw, rows, cols);
        }));

        let t = HostTensor::from_vec(&[64, 64, 64], vec![0.5; 64 * 64 * 64]);
        results.push(b.run_with_elements("host/tensor-to-literal-1M", t.numel() as u64, || {
            black_box(t.to_literal().unwrap());
        }));

        let samples = corpus::gen_instruction_corpus(512, 3);
        let texts = corpus::sample_texts(&samples);
        results.push(b.run("host/tokenizer-build-512-samples", || {
            black_box(Tokenizer::build(&texts, 2048));
        }));
        let tok = Tokenizer::build(&texts, 2048);
        let enc: Vec<_> = samples.iter().map(|s| encode_sft(&tok, s, 128)).collect();
        let mut dl = DataLoader::new(enc, 4, 128, 1);
        results.push(b.run("host/dataloader-next-batch", || {
            black_box(dl.next_batch());
        }));

        let mut sched = LisaScheduler::new(LisaConfig::paper(2, 1), 32, 5);
        let mut step = 0usize;
        results.push(b.run("host/lisa-sampler-resample", || {
            step += 1;
            black_box(sched.mask_for_step(step));
        }));
    }

    // ---------------- runtime-backed benches ----------------
    let art = Path::new("artifacts");
    if art.join("tiny/manifest.json").exists() {
        for backend in ["pallas", "jnp"] {
            let rt = Runtime::load(&art.join("tiny"), backend)?;
            let m = rt.manifest.clone();
            let mut rng = Rng::new(7);
            let params = ModelParams::init(&m, &mut rng);
            let mut h = HostTensor::zeros(&[m.batch, m.seq, m.d_model]);
            rng.fill_normal(&mut h.data, 1.0);
            rt.warmup(&["block_fwd", "block_bwd_full", "block_bwd_x"])?;
            let mut ops: Vec<Operand> = vec![Operand::F32(&h)];
            ops.extend(params.blocks[0].iter().map(Operand::F32));
            results.push(b.run(&format!("segment/block_fwd-{backend}"), || {
                black_box(rt.run("block_fwd", &ops).unwrap());
            }));
            let mut bops: Vec<Operand> = vec![Operand::F32(&h), Operand::F32(&h)];
            bops.extend(params.blocks[0].iter().map(Operand::F32));
            results.push(b.run(&format!("segment/block_bwd_full-{backend}"), || {
                black_box(rt.run("block_bwd_full", &bops).unwrap());
            }));
            results.push(b.run(&format!("segment/block_bwd_x-{backend}"), || {
                black_box(rt.run("block_bwd_x", &bops).unwrap());
            }));
        }

        // adamw artifact vs rust optimizer at the artifact's size
        let rt = Runtime::load(&art.join("tiny"), "pallas")?;
        let seg = rt.manifest.segment("adamw_update", "pallas")?.clone();
        let n = seg.operands[0].numel();
        let mut rng = Rng::new(9);
        let mut mk = |rng: &mut Rng| {
            let mut t = HostTensor::zeros(&[n]);
            rng.fill_normal(&mut t.data, 0.1);
            t
        };
        let (p, g, mm, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let hyper = HostTensor::from_vec(&[8], vec![1e-3, 0.9, 0.999, 1e-8, 0.01, 0.1, 0.001, 0.0]);
        rt.warmup(&["adamw_update"])?;
        results.push(b.run_with_elements(&format!("adamw/pallas-artifact-{n}"), n as u64, || {
            black_box(
                rt.run(
                    "adamw_update",
                    &[Operand::F32(&p), Operand::F32(&g), Operand::F32(&mm), Operand::F32(&v), Operand::F32(&hyper)],
                )
                .unwrap(),
            );
        }));
        let mut pr = p.data.clone();
        let mut opt = AdamW::new(AdamHp::default(), StatePolicy::Keep);
        results.push(b.run_with_elements(&format!("adamw/rust-same-size-{n}"), n as u64, || {
            opt.step(ParamKey::Emb, true, &mut pr, &g.data);
        }));
    }

    // ---------------- end-to-end step benches (Fig 4) ----------------
    let cfg_name = if art.join("small/manifest.json").exists() { "small" } else { "tiny" };
    if art.join(cfg_name).join("manifest.json").exists() {
        let rt = Runtime::load(&art.join(cfg_name), "pallas")?;
        let m = rt.manifest.clone();
        let samples = corpus::gen_instruction_corpus(128, 3);
        let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);
        let enc: Vec<_> = samples.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
        // (spec, name suffix, device-resident flow on/off). The
        // `-hostpath` arms disable the device cache + buffer chaining —
        // the seed's upload-everything schedule — so BENCH_step.json
        // carries the before/after pair for the same binary.
        let arms: Vec<(StrategySpec, &str, bool)> = vec![
            (StrategySpec::ft(), "", true),
            (StrategySpec::ft(), "-hostpath", false),
            (StrategySpec::lisa(2, 5), "", true),
            (StrategySpec::lisa(2, 5), "-hostpath", false),
            (StrategySpec::lora(), "", true),
        ];
        for (spec, suffix, device_flow) in arms {
            let mut dl = DataLoader::new(enc.clone(), m.batch, m.seq, 1);
            let cfg = TrainConfig { steps: 0, lr: 1e-3, log_every: 0, ..Default::default() };
            let mut sess = TrainSession::new(&rt, &spec, cfg)?;
            sess.engine.device_flow = device_flow;
            let label = sess.label().to_string();
            // warm executables
            sess.step(0, &mut dl)?;
            let mut step = 1usize;
            let quick = Bench {
                target_time: std::time::Duration::from_secs(
                    if std::env::var("LISA_BENCH_QUICK").is_ok() { 2 } else { 8 },
                ),
                min_iters: 3,
                ..Bench::quick()
            };
            results.push(quick.run_with_elements(
                &format!("step/{label}{suffix}-{cfg_name}"),
                (m.batch * m.seq) as u64,
                || {
                    step += 1;
                    black_box(sess.step(step, &mut dl).unwrap());
                },
            ));
        }

        // upload traffic: with the cache warm, weight uploads must scale
        // with the trainable subset only (γ blocks + embed/head for LISA)
        {
            let mut dl = DataLoader::new(enc.clone(), m.batch, m.seq, 1);
            let cfg = TrainConfig { steps: 0, lr: 1e-3, log_every: 0, ..Default::default() };
            let mut sess = TrainSession::new(&rt, &StrategySpec::lisa(2, 5), cfg)?;
            sess.step(0, &mut dl)?;
            rt.reset_stats();
            for s in 1..=3 {
                sess.step(s, &mut dl)?;
            }
            println!("\nper-segment upload traffic (lisa γ=2, 3 warm steps):");
            for (name, s) in rt.stats() {
                println!(
                    "  {:<18} calls {:>4}  uploads {:>5} ({:>10} B)  device-served {:>5}",
                    name, s.calls, s.uploads, s.upload_bytes, s.buf_hits
                );
            }
            let cs = sess.engine.device_cache_stats();
            println!(
                "  device cache: {} entries, {} B resident, {} hits / {} misses / {} invalidations",
                cs.entries, cs.resident_bytes, cs.hits, cs.misses, cs.invalidations
            );
        }

        // engine overhead: step time minus PJRT execute time
        rt.reset_stats();
        let mut dl = DataLoader::new(enc.clone(), m.batch, m.seq, 1);
        let cfg = TrainConfig { steps: 0, lr: 1e-3, log_every: 0, ..Default::default() };
        let mut sess = TrainSession::new(&rt, &StrategySpec::ft(), cfg)?;
        sess.step(0, &mut dl)?;
        rt.reset_stats();
        let t0 = std::time::Instant::now();
        let n_steps = 5;
        for s in 1..=n_steps {
            sess.step(s, &mut dl)?;
        }
        let wall = t0.elapsed().as_nanos() as f64;
        let exec: u128 = rt.stats().values().map(|s| s.total_ns).sum();
        let overhead = (wall - exec as f64) / wall * 100.0;
        println!(
            "engine/overhead-{cfg_name}: {overhead:.1}% of step time outside PJRT execute ({n_steps} steps)"
        );
    }

    // ---------------- serving: decode throughput (tokens/sec) -------------
    // legacy-vs-cached before/after pair: `decode/legacy-*` re-runs a full
    // L-block forward per emitted token, `decode/cached-*` pays one
    // decode_step per token over the device-resident KV state.
    if art.join("tiny/manifest.json").exists() {
        let rt = Runtime::load(&art.join("tiny"), "pallas")?;
        let m = rt.manifest.clone();
        let samples = corpus::gen_instruction_corpus(64, 3);
        let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);
        let params = ModelParams::init(&m, &mut Rng::new(7));
        let prompts: Vec<String> = samples.iter().take(4).map(|s| s.prompt.clone()).collect();
        let refs: Vec<&str> = prompts.iter().map(|s| s.as_str()).collect();
        let max_new = 8;

        let mut eng = Engine::new(&rt);
        // token count for the throughput annotation (greedy = deterministic)
        let legacy_tokens: usize = refs
            .iter()
            .map(|p| {
                generate::greedy_complete_legacy(&mut eng, &params, &tok, p, max_new)
                    .unwrap()
                    .tokens
                    .len()
            })
            .sum();
        results.push(b.run_with_elements(
            "decode/legacy-tiny",
            legacy_tokens.max(1) as u64,
            || {
                for p in &refs {
                    black_box(
                        generate::greedy_complete_legacy(&mut eng, &params, &tok, p, max_new)
                            .unwrap(),
                    );
                }
            },
        ));

        if m.supports_decode("pallas") {
            let enc: Vec<Vec<i32>> =
                refs.iter().map(|p| generate::encode_prompt(&tok, p)).collect();
            // pinned per-layout so the arm names keep meaning on v2
            // artifact dirs (where `new` would auto-select paged)
            let mut eng = Engine::new(&rt);
            let cached_tokens: usize = {
                let mut sess = DecodeSession::with_mode(&mut eng, &params, KvMode::Packed)?;
                sess.greedy(&enc, max_new, EOS, PAD)?
                    .iter()
                    .map(|c| c.tokens.len())
                    .sum()
            };
            results.push(b.run_with_elements(
                "decode/cached-tiny",
                cached_tokens.max(1) as u64,
                || {
                    let mut sess =
                        DecodeSession::with_mode(&mut eng, &params, KvMode::Packed).unwrap();
                    black_box(sess.greedy(&enc, max_new, EOS, PAD).unwrap());
                },
            ));

            if m.supports_paged("pallas") {
                let mut eng = Engine::new(&rt);
                let paged_tokens: usize = {
                    let mut sess = DecodeSession::with_mode(&mut eng, &params, KvMode::Paged)?;
                    sess.greedy(&enc, max_new, EOS, PAD)?
                        .iter()
                        .map(|c| c.tokens.len())
                        .sum()
                };
                results.push(b.run_with_elements(
                    "decode/paged-tiny",
                    paged_tokens.max(1) as u64,
                    || {
                        let mut sess =
                            DecodeSession::with_mode(&mut eng, &params, KvMode::Paged).unwrap();
                        black_box(sess.greedy(&enc, max_new, EOS, PAD).unwrap());
                    },
                ));
            }
        } else {
            println!(
                "decode/cached-tiny skipped: artifacts lack the decode ABI — \
                 re-export with python/compile/aot.py"
            );
        }

        // serving: static vs continuous batching over one mixed-length
        // queue (tokens/sec). The continuous arm admits queued prompts
        // into rows freed mid-decode, so long rows no longer gate short
        // ones — the ISSUE 5 before/after pair.
        if m.supports_decode("pallas") {
            let eos_off = -1; // unreachable: every row runs its exact budget
            let queue: Vec<Request> = samples
                .iter()
                .take(2 * m.batch)
                .enumerate()
                .map(|(i, s)| {
                    // one long row per static chunk, the rest short
                    let budget = if i % m.batch == 0 { 16.min(m.seq / 4) } else { 2 };
                    Request::greedy(generate::encode_prompt(&tok, &s.prompt), budget)
                })
                .collect();
            let toks = |outs: &[lisa::engine::Completion]| {
                outs.iter().map(|c| c.tokens.len()).sum::<usize>().max(1) as u64
            };

            let mut eng = Engine::new(&rt);
            let n = {
                let mut sess = ServeSession::with_mode(&mut eng, &params, KvMode::Packed)?;
                toks(&sess.run_static(&queue, eos_off, PAD)?)
            };
            results.push(b.run_with_elements("serve/static-tiny", n, || {
                let mut sess =
                    ServeSession::with_mode(&mut eng, &params, KvMode::Packed).unwrap();
                black_box(sess.run_static(&queue, eos_off, PAD).unwrap());
            }));

            let mut eng = Engine::new(&rt);
            let n = {
                let mut sess = ServeSession::with_mode(&mut eng, &params, KvMode::Packed)?;
                toks(&sess.run(&queue, eos_off, PAD)?)
            };
            results.push(b.run_with_elements("serve/continuous-tiny", n, || {
                let mut sess =
                    ServeSession::with_mode(&mut eng, &params, KvMode::Packed).unwrap();
                black_box(sess.run(&queue, eos_off, PAD).unwrap());
            }));

            // prefix reuse (ABI v2): one session keeps its page pool and
            // prefix cache across runs, so after the cold warm-up every
            // timed run adopts the cached prompt pages — prefill FLOPs
            // saved is the bench; the ExecStats line below is the proof
            if m.supports_paged("pallas") {
                let budget = 8usize;
                let plen = 2 * m.page_t + m.page_t / 2; // 2 full pages + tail
                let prompt: Vec<i32> =
                    (0..plen as i32).map(|i| 3 + (i * 5) % (m.vocab as i32 - 4)).collect();
                let req = Request::greedy(prompt, budget);
                let mut eng = Engine::new(&rt);
                let mut sess = ServeSession::with_mode(&mut eng, &params, KvMode::Paged)?;
                sess.run(std::slice::from_ref(&req), eos_off, PAD)?; // cold: registers
                rt.reset_stats();
                results.push(b.run_with_elements(
                    "serve/paged-prefix-tiny",
                    budget as u64,
                    || {
                        black_box(sess.run(std::slice::from_ref(&req), eos_off, PAD).unwrap());
                    },
                ));
                let stats = rt.stats();
                let pk = stats.get("prefill_kv").map_or(0, |s| s.calls);
                let steps = stats.get("paged_step").map_or(0, |s| s.calls);
                println!(
                    "serve/paged-prefix-tiny: {pk} prefill_kv executions with a warm \
                     prefix cache (reuse target 0), {steps} paged_step executions"
                );
            }
        }

        // serving over HTTP: the same mixed queue through the full front
        // end — loopback sockets, JSON/SSE framing, bounded admission —
        // so the serve/continuous-vs-http delta prices the transport
        // (DESIGN.md §11). TTFT percentiles come from the live /metrics
        // histograms after the timed burst.
        if m.supports_decode("pallas") {
            use lisa::serve_http::{proto::client, HttpFrontend, ServeConfig};
            let front = HttpFrontend::bind(
                ServeConfig { addr: "127.0.0.1:0".into(), max_queue: 64, ..Default::default() },
                Tokenizer::build(&corpus::sample_texts(&samples), m.vocab),
            )?;
            let addr = front.local_addr()?.to_string();
            let state = front.state();
            let art_dir = art.join("tiny");
            let server = std::thread::spawn(move || {
                // the engine is thread-bound: the server thread owns its
                // own runtime over the same artifacts and parameter seed
                let rt = Runtime::load(&art_dir, "pallas").unwrap();
                let params = ModelParams::init(&rt.manifest, &mut Rng::new(7));
                let mut eng = Engine::new(&rt);
                let mut sess = ServeSession::new(&mut eng, &params).unwrap();
                front.run(|src| sess.run_loop(src, -1, PAD)).unwrap();
            });
            let mut n_tokens = 0u64;
            let bodies: Vec<String> = samples
                .iter()
                .take(2 * m.batch)
                .enumerate()
                .map(|(i, s)| {
                    let budget = if i % m.batch == 0 { 16.min(m.seq / 4) } else { 2 };
                    n_tokens += budget as u64; // eos is unreachable: exact
                    let prompt = generate::encode_prompt(&tok, &s.prompt);
                    format!(
                        r#"{{"tokens": {prompt:?}, "max_new": {budget}, "sample": "greedy"}}"#
                    )
                })
                .collect();
            results.push(b.run_with_elements("serve/http-tiny", n_tokens, || {
                for body in &bodies {
                    let resp = client::post(&addr, "/v1/completions", body).unwrap();
                    assert_eq!(resp.status, 200, "{}", resp.body);
                    black_box(resp.body.len());
                }
            }));
            println!(
                "serve/http-tiny TTFT: p50 {:.1} ms, p99 {:.1} ms over {} requests",
                state.metrics.ttft.quantile(0.5) * 1e3,
                state.metrics.ttft.quantile(0.99) * 1e3,
                state.metrics.ttft.count()
            );
            state.request_shutdown();
            server.join().unwrap();
        }
    }

    // ---------------- quantized frozen-base residency (ISSUE 10) ----------
    // f32-twin / int8 pairs at each tier: the timed arm gives tokens/sec
    // (or step/sec), the printed lines give the upload-byte and
    // device-resident-byte deltas the quantization exists to shrink.
    if art.join("tiny/manifest.json").exists() {
        use lisa::engine::QuantMode;
        let rt = Runtime::load(&art.join("tiny"), "pallas")?;
        let m = rt.manifest.clone();
        let samples = corpus::gen_instruction_corpus(64, 3);
        let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);

        if m.supports_quant("pallas") {
            let enc: Vec<_> = samples.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
            for (mode, name) in
                [(QuantMode::Off, "step/quant-f32-twin-tiny"), (QuantMode::Int8, "step/quant-tiny")]
            {
                let mut dl = DataLoader::new(enc.clone(), m.batch, m.seq, 1);
                let cfg = TrainConfig { steps: 0, lr: 1e-3, log_every: 0, ..Default::default() };
                let mut sess = TrainSession::new(&rt, &StrategySpec::lisa(2, 5), cfg)?;
                sess.engine.set_quant(mode);
                sess.step(0, &mut dl)?; // warm executables + device cache
                let mut step = 1usize;
                results.push(b.run_with_elements(name, (m.batch * m.seq) as u64, || {
                    step += 1;
                    black_box(sess.step(step, &mut dl).unwrap());
                }));
                // cold re-upload traffic: how many bytes a full weight
                // refresh moves under each residency format
                sess.engine.invalidate_all();
                rt.reset_stats();
                step += 1;
                sess.step(step, &mut dl)?;
                let up: u64 = rt.stats().values().map(|s| s.upload_bytes).sum();
                let cs = sess.engine.device_cache_stats();
                println!(
                    "{name}: cold re-upload {up} B; device-resident {} B \
                     (f32 {} B, i8 {} B)",
                    cs.resident_bytes, cs.resident_f32_bytes, cs.resident_i8_bytes
                );
            }
        } else {
            println!("step/quant-tiny skipped: artifacts carry no q8 segment twins");
        }

        if m.supports_quant("pallas") && m.supports_quant_decode("pallas") {
            let params = ModelParams::init(&m, &mut Rng::new(7));
            let prompts: Vec<String> = samples.iter().take(4).map(|s| s.prompt.clone()).collect();
            let enc: Vec<Vec<i32>> =
                prompts.iter().map(|p| generate::encode_prompt(&tok, p)).collect();
            let max_new = 8;
            for (mode, name) in [
                (QuantMode::Off, "decode/quant-f32-twin-tiny"),
                (QuantMode::Int8, "decode/quant-tiny"),
            ] {
                let mut eng = Engine::new(&rt);
                eng.set_quant(mode);
                rt.reset_stats();
                let n_tokens: usize = {
                    let mut sess = DecodeSession::with_mode(&mut eng, &params, KvMode::Packed)?;
                    sess.greedy(&enc, max_new, EOS, PAD)?.iter().map(|c| c.tokens.len()).sum()
                };
                let cold_up: u64 = rt.stats().values().map(|s| s.upload_bytes).sum();
                results.push(b.run_with_elements(name, n_tokens.max(1) as u64, || {
                    let mut sess =
                        DecodeSession::with_mode(&mut eng, &params, KvMode::Packed).unwrap();
                    black_box(sess.greedy(&enc, max_new, EOS, PAD).unwrap());
                }));
                let cs = eng.device_cache_stats();
                println!(
                    "{name}: cold weight upload {cold_up} B; device-resident {} B \
                     (f32 {} B, i8 {} B)",
                    cs.resident_bytes, cs.resident_f32_bytes, cs.resident_i8_bytes
                );
            }

            let eos_off = -1;
            let queue: Vec<Request> = samples
                .iter()
                .take(2 * m.batch)
                .enumerate()
                .map(|(i, s)| {
                    let budget = if i % m.batch == 0 { 16.min(m.seq / 4) } else { 2 };
                    Request::greedy(generate::encode_prompt(&tok, &s.prompt), budget)
                })
                .collect();
            for (mode, name) in [
                (QuantMode::Off, "serve/quant-f32-twin-tiny"),
                (QuantMode::Int8, "serve/quant-tiny"),
            ] {
                let mut eng = Engine::new(&rt);
                eng.set_quant(mode);
                rt.reset_stats();
                let n_tokens = {
                    let mut sess = ServeSession::with_mode(&mut eng, &params, KvMode::Packed)?;
                    sess.run(&queue, eos_off, PAD)?
                        .iter()
                        .map(|c| c.tokens.len())
                        .sum::<usize>()
                        .max(1) as u64
                };
                let cold_up: u64 = rt.stats().values().map(|s| s.upload_bytes).sum();
                results.push(b.run_with_elements(name, n_tokens, || {
                    let mut sess =
                        ServeSession::with_mode(&mut eng, &params, KvMode::Packed).unwrap();
                    black_box(sess.run(&queue, eos_off, PAD).unwrap());
                }));
                let cs = eng.device_cache_stats();
                println!(
                    "{name}: cold weight upload {cold_up} B; device-resident {} B \
                     (f32 {} B, i8 {} B)",
                    cs.resident_bytes, cs.resident_f32_bytes, cs.resident_i8_bytes
                );
            }
        } else if m.supports_quant("pallas") {
            println!("decode/quant-tiny skipped: no q8 decode-ABI twins in the artifacts");
        }
    }

    println!("\n=== bench results ===");
    for r in &results {
        println!("{}", r.report());
    }

    // Machine-readable trajectory: BENCH_step.json at the repo root
    // (cargo bench runs with cwd = rust/). Falls back to the crate dir
    // when the parent is not writable.
    let quick = std::env::var("LISA_BENCH_QUICK").is_ok();
    let note = "generated by `cargo bench` (LISA_BENCH_QUICK=1 for the smoke pass); \
                step/*-hostpath arms run the pre-device-cache host-roundtrip schedule; \
                decode/{legacy,cached}-* are the KV-cache before/after pair \
                (decode/paged-tiny adds the ABI v2 paged layout on v2 artifacts), \
                serve/{static,continuous}-* the continuous-batching pair (tokens/sec), \
                serve/paged-prefix-tiny the shared-prefix page-reuse arm (tokens/sec with \
                prefill_kv executions printed; reuse target 0) and \
                serve/http-tiny the same queue through the `lisa serve` HTTP front end \
                (loopback tokens/sec; TTFT p50/p99 printed from /metrics); \
                {step,decode,serve}/quant-tiny vs their -f32-twin arms are the int8 \
                frozen-base residency pair (upload-byte and device-resident-byte deltas \
                printed per arm)";
    let target = Path::new("../BENCH_step.json");
    let path = if lisa::util::bench::write_json(target, &results, quick, note).is_ok() {
        target
    } else {
        let fallback = Path::new("BENCH_step.json");
        lisa::util::bench::write_json(fallback, &results, quick, note)?;
        fallback
    };
    println!("\nwrote {} ({} groups)", path.display(), results.len());

    // Append-per-run history next to the snapshot: the snapshot answers
    // "how fast is HEAD", the trajectory answers "how has it moved".
    let traj = if path.starts_with("..") {
        Path::new("../BENCH_trajectory.jsonl")
    } else {
        Path::new("BENCH_trajectory.jsonl")
    };
    lisa::util::bench::append_trajectory(traj, &results, quick, note)?;
    println!("appended run to {}", traj.display());
    Ok(())
}
