//! Property-based corruption robustness for the checkpoint formats (via
//! the hand-rolled `util::prop` framework, like `prop_coordinator.rs`):
//! truncating or bit-flipping a valid checkpoint at *any* offset must
//! yield `Err` — never a panic, an abort-sized allocation, or a silent
//! partial load.

use std::path::PathBuf;

use lisa::model::checkpoint::{load_sections, load_tensors, save_sections, save_tensors, Section};
use lisa::prop_assert;
use lisa::runtime::HostTensor;
use lisa::util::prop::prop_check;
use lisa::util::rng::Rng;

fn tdir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lisa_prop_ckpt2_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A random but valid v2 checkpoint: 1–3 sections mixing every dtype.
fn random_sections(rng: &mut Rng) -> Vec<Section<'static>> {
    let n_sections = 1 + rng.below(3);
    (0..n_sections)
        .map(|s| {
            let mut sec = Section::new(&format!("sec{s}"));
            for e in 0..1 + rng.below(4) {
                match rng.below(4) {
                    0 => {
                        let rank = 1 + rng.below(3);
                        let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(6)).collect();
                        let mut t = HostTensor::zeros(&shape);
                        rng.fill_normal(&mut t.data, 1.0);
                        sec.put_tensor_owned(&format!("t{e}"), t);
                    }
                    1 => {
                        let n = 1 + rng.below(8);
                        sec.put_u64s(&format!("u{e}"), (0..n).map(|_| rng.next_u64()).collect());
                    }
                    2 => sec.put_str(&format!("s{e}"), "some-label"),
                    _ => sec.put_f64s(&format!("f{e}"), &[rng.f64(), rng.f64()]),
                }
            }
            sec
        })
        .collect()
}

#[test]
fn prop_v2_roundtrip_is_exact() {
    let dir = tdir();
    prop_check("v2 roundtrip", 40, |rng| {
        let path = dir.join(format!("rt{}.state", rng.next_u64()));
        let sections = random_sections(rng);
        save_sections(&path, &sections).map_err(|e| e.to_string())?;
        let loaded = load_sections(&path).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        prop_assert!(loaded == sections, "roundtrip not exact");
        Ok(())
    });
}

#[test]
fn prop_v2_truncation_at_any_offset_errs() {
    let dir = tdir();
    prop_check("v2 truncation", 60, |rng| {
        let path = dir.join(format!("tr{}.state", rng.next_u64()));
        save_sections(&path, &random_sections(rng)).map_err(|e| e.to_string())?;
        let bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
        let cut = rng.below(bytes.len()); // keep 0..len-1 bytes
        std::fs::write(&path, &bytes[..cut]).map_err(|e| e.to_string())?;
        let res = load_sections(&path);
        std::fs::remove_file(&path).ok();
        prop_assert!(
            res.is_err(),
            "truncation to {cut}/{} bytes loaded successfully",
            bytes.len()
        );
        Ok(())
    });
}

#[test]
fn prop_v2_bit_flip_at_any_offset_errs() {
    let dir = tdir();
    prop_check("v2 bit flip", 120, |rng| {
        let path = dir.join(format!("bf{}.state", rng.next_u64()));
        save_sections(&path, &random_sections(rng)).map_err(|e| e.to_string())?;
        let mut bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
        let byte = rng.below(bytes.len());
        let bit = rng.below(8);
        bytes[byte] ^= 1 << bit;
        std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
        let res = load_sections(&path);
        std::fs::remove_file(&path).ok();
        prop_assert!(
            res.is_err(),
            "bit flip at {byte}:{bit} of {} bytes loaded successfully",
            bytes.len()
        );
        Ok(())
    });
}

#[test]
fn prop_v1_truncation_at_any_offset_errs() {
    let dir = tdir();
    prop_check("v1 truncation", 60, |rng| {
        let path = dir.join(format!("v1tr{}.ckpt", rng.next_u64()));
        let n_tensors = 1 + rng.below(4);
        let tensors: Vec<(String, HostTensor)> = (0..n_tensors)
            .map(|i| {
                let shape = vec![1 + rng.below(5), 1 + rng.below(5)];
                let mut t = HostTensor::zeros(&shape);
                rng.fill_normal(&mut t.data, 1.0);
                (format!("t{i}"), t)
            })
            .collect();
        let refs: Vec<(String, &HostTensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        save_tensors(&path, &refs).map_err(|e| e.to_string())?;
        let bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
        let cut = rng.below(bytes.len());
        std::fs::write(&path, &bytes[..cut]).map_err(|e| e.to_string())?;
        let res = load_tensors(&path);
        std::fs::remove_file(&path).ok();
        prop_assert!(
            res.is_err(),
            "v1 truncation to {cut}/{} bytes loaded successfully",
            bytes.len()
        );
        Ok(())
    });
}
