//! Strategy-conformance suite: every registered strategy must honour the
//! driver protocol — correct mask arity, the γ invariant for LISA
//! variants, deterministic replay per seed, and a faithful
//! `eval_params` round-trip. Runs against a synthetic manifest so it needs
//! no AOT artifacts.

use std::collections::BTreeMap;
use std::path::PathBuf;

use lisa::model::ModelParams;
use lisa::runtime::Manifest;
use lisa::strategy::{self, StrategySpec};
use lisa::train::TrainConfig;
use lisa::util::rng::Rng;

const N_LAYERS: usize = 8;

/// A manifest with everything strategy construction needs (no segments —
/// those only matter once an Engine executes).
fn synth_manifest() -> Manifest {
    let d = 8usize;
    let h = 4 * d;
    let r = 2usize;
    let block_params: Vec<(String, Vec<usize>)> = vec![
        ("g1".into(), vec![d]),
        ("wq".into(), vec![d, d]),
        ("wk".into(), vec![d, d]),
        ("wv".into(), vec![d, d]),
        ("wo".into(), vec![d, d]),
        ("g2".into(), vec![d]),
        ("w1".into(), vec![d, h]),
        ("w2".into(), vec![h, d]),
    ];
    let lora_params: Vec<(String, Vec<usize>)> = vec![
        ("aq".into(), vec![d, r]),
        ("bq".into(), vec![r, d]),
        ("ak".into(), vec![d, r]),
        ("bk".into(), vec![r, d]),
        ("av".into(), vec![d, r]),
        ("bv".into(), vec![r, d]),
        ("ao".into(), vec![d, r]),
        ("bo".into(), vec![r, d]),
        ("a1".into(), vec![d, r]),
        ("b1".into(), vec![r, h]),
        ("a2".into(), vec![h, r]),
        ("b2".into(), vec![r, d]),
    ];
    Manifest {
        dir: PathBuf::new(),
        name: "synthetic".into(),
        d_model: d,
        n_layers: N_LAYERS,
        n_heads: 2,
        vocab: 32,
        seq: 4,
        batch: 2,
        mlp_ratio: 4,
        lora_rank: r,
        lora_alpha: 4.0,
        n_params: 0,
        block_params,
        lora_params,
        decode_abi: 0,
        segments: BTreeMap::new(),
    }
}

fn cfg(seed: u64) -> TrainConfig {
    TrainConfig { seed, ..Default::default() }
}

/// Specs with explicit sampling options so the γ invariant is checkable.
fn all_specs() -> Vec<StrategySpec> {
    strategy::registry()
        .iter()
        .map(|r| StrategySpec::new(r.name).with("gamma", 3usize).with("period", 4usize))
        .collect()
}

#[test]
fn every_registered_strategy_builds() {
    let m = synth_manifest();
    for spec in all_specs() {
        let s = spec.build(&m, &cfg(42));
        assert!(s.is_ok(), "'{}' failed to build: {:?}", spec.name, s.err());
        let s = s.unwrap();
        assert!(!s.label().is_empty());
        assert_eq!(s.state_bytes(), 0, "'{}' holds state before any step", spec.name);
    }
}

#[test]
fn mask_arity_matches_n_layers_for_every_strategy() {
    let m = synth_manifest();
    for spec in all_specs() {
        let mut s = spec.build(&m, &cfg(42)).unwrap();
        for step in 0..25 {
            let mask = s.mask_for_step(step);
            assert_eq!(
                mask.blocks.len(),
                N_LAYERS,
                "'{}' mask arity at step {step}",
                spec.name
            );
        }
    }
}

#[test]
fn masks_replay_deterministically_per_seed() {
    let m = synth_manifest();
    for spec in all_specs() {
        let mut a = spec.build(&m, &cfg(7)).unwrap();
        let mut b = spec.build(&m, &cfg(7)).unwrap();
        for step in 0..25 {
            assert_eq!(
                a.mask_for_step(step),
                b.mask_for_step(step),
                "'{}' diverged at step {step} under the same seed",
                spec.name
            );
        }
    }
}

#[test]
fn lisa_variants_hold_the_gamma_invariant() {
    let m = synth_manifest();
    for name in ["lisa", "lisa-fix", "lisa-grad"] {
        let spec = StrategySpec::new(name).with("gamma", 3usize).with("period", 4usize);
        let mut s = spec.build(&m, &cfg(42)).unwrap();
        for step in 0..40 {
            let mask = s.mask_for_step(step);
            assert_eq!(
                mask.n_trainable_blocks(),
                3,
                "'{name}' γ invariant at step {step}"
            );
            assert!(mask.embed && mask.head, "'{name}' must train embed+head");
        }
    }
}

#[test]
fn lisa_seeds_diverge() {
    let m = synth_manifest();
    for name in ["lisa", "lisa-grad"] {
        let spec = StrategySpec::new(name).with("gamma", 2usize).with("period", 1usize);
        let seq = |seed: u64| -> Vec<Vec<bool>> {
            let mut s = spec.clone().build(&m, &cfg(seed)).unwrap();
            (0..20).map(|i| s.mask_for_step(i).blocks).collect()
        };
        assert_eq!(seq(1), seq(1), "'{name}' same-seed replay");
        assert_ne!(seq(1), seq(2), "'{name}' different seeds must diverge");
    }
}

#[test]
fn dense_strategies_train_everything_lora_trains_nothing_in_base() {
    let m = synth_manifest();
    let mut ft = StrategySpec::ft().build(&m, &cfg(42)).unwrap();
    let mask = ft.mask_for_step(0);
    assert!(mask.embed && mask.head);
    assert_eq!(mask.n_trainable_blocks(), N_LAYERS);

    let mut lora = StrategySpec::lora().build(&m, &cfg(42)).unwrap();
    let mask = lora.mask_for_step(0);
    assert!(!mask.embed && !mask.head);
    assert_eq!(mask.n_trainable_blocks(), 0);

    let mut vanilla = StrategySpec::vanilla().build(&m, &cfg(42)).unwrap();
    assert!(vanilla.is_noop());
    assert_eq!(vanilla.mask_for_step(0).n_trainable_blocks(), 0);
}

#[test]
fn lora_eval_params_roundtrip_at_init() {
    // B = 0 at init, so merging adapters must reproduce the base model
    // bit-for-bit (the eval_params round-trip of the LoRA merge).
    let m = synth_manifest();
    let base = ModelParams::init(&m, &mut Rng::new(9));
    let lora = StrategySpec::lora().build(&m, &cfg(42)).unwrap();
    let merged = lora.eval_params(&base);
    assert_eq!(merged.emb.data, base.emb.data);
    for l in 0..N_LAYERS {
        for t in 0..base.blocks[l].len() {
            assert_eq!(
                merged.blocks[l][t].data, base.blocks[l][t].data,
                "layer {l} tensor {t} changed by zero-delta merge"
            );
        }
    }
    // effective norms agree with the base at init, for every strategy
    for spec in all_specs() {
        let s = spec.build(&m, &cfg(42)).unwrap();
        let norms = s.effective_weight_norms(&base);
        assert_eq!(norms.len(), N_LAYERS + 2, "'{}' norm arity", spec.name);
    }
}

#[test]
fn labels_are_stable() {
    let m = synth_manifest();
    let expect = [
        ("vanilla", "vanilla"),
        ("ft", "ft"),
        ("lisa", "lisa"),
        ("lisa-fix", "lisa-fix"),
        ("lisa-grad", "lisa-grad"),
        ("lora", "lora"),
        ("galore", "galore"),
    ];
    for (name, label) in expect {
        let s = StrategySpec::new(name)
            .with("gamma", 2usize)
            .with("period", 4usize)
            .build(&m, &cfg(42))
            .unwrap();
        assert_eq!(s.label(), label);
    }
    // the fixed flag relabels plain lisa
    let s = StrategySpec::lisa(2, 4).with("fixed", true).build(&m, &cfg(42)).unwrap();
    assert_eq!(s.label(), "lisa-fix");
}

#[test]
fn weighted_spec_rejects_wrong_arity() {
    let m = synth_manifest();
    let bad = StrategySpec::lisa_weighted(2, 4, &[1.0, 2.0]); // 2 != 8 layers
    assert!(bad.build(&m, &cfg(42)).is_err());
    let good = StrategySpec::lisa_weighted(2, 4, &[1.0; N_LAYERS]);
    assert!(good.build(&m, &cfg(42)).is_ok());
}

#[test]
fn unknown_strategy_is_a_clean_error() {
    let m = synth_manifest();
    let err = StrategySpec::new("does-not-exist").build(&m, &cfg(42));
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("unknown strategy"), "got: {msg}");
    assert!(msg.contains("lisa-grad"), "error should list registered names: {msg}");
}
