//! Additional coverage: failure injection on the runtime (bad operands,
//! missing artifacts), checkpoint round-trip through a full model, LoRA
//! merge consistency at the runtime level, and grad-accumulation semantics.

use std::path::{Path, PathBuf};

use lisa::data::{corpus, encode_sft, DataLoader, Tokenizer};
use lisa::engine::{Batch, Engine, TrainMask};
use lisa::model::{checkpoint, ModelParams};
use lisa::runtime::{HostTensor, HostTensorI32, Operand, Runtime};
use lisa::strategy::StrategySpec;
use lisa::train::{TrainConfig, TrainSession};
use lisa::util::rng::Rng;
use lisa::util::stats::allclose;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

fn have() -> bool {
    artifacts().join("manifest.json").exists()
}

#[test]
fn runtime_rejects_wrong_operand_shapes_and_counts() {
    if !have() { return; }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let m = rt.manifest.clone();
    let good_tokens = HostTensorI32::zeros(&[m.batch, m.seq]);
    let emb = HostTensor::zeros(&[m.vocab, m.d_model]);
    let pos = HostTensor::zeros(&[m.seq, m.d_model]);

    // wrong count
    let err = rt.run("embed_fwd", &[Operand::I32(&good_tokens)]);
    assert!(err.is_err());
    // wrong shape
    let bad = HostTensor::zeros(&[m.vocab, m.d_model + 1]);
    let err = rt.run(
        "embed_fwd",
        &[Operand::I32(&good_tokens), Operand::F32(&bad), Operand::F32(&pos)],
    );
    match err {
        Err(e) => assert!(e.to_string().contains("mismatch")),
        Ok(_) => panic!("wrong shape must be rejected"),
    }
    // wrong dtype position
    let err = rt.run(
        "embed_fwd",
        &[Operand::F32(&emb), Operand::F32(&emb), Operand::F32(&pos)],
    );
    assert!(err.is_err());
    // unknown segment
    assert!(rt.run("nonexistent", &[]).is_err());
}

#[test]
fn runtime_missing_artifacts_dir_errors_cleanly() {
    let err = Runtime::load(Path::new("/nonexistent/lisa/artifacts"), "pallas");
    assert!(err.is_err());
}

#[test]
fn full_model_checkpoint_roundtrip_preserves_loss() {
    if !have() { return; }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let m = rt.manifest.clone();
    let mut rng = Rng::new(21);
    let params = ModelParams::init(&m, &mut rng);
    let batch = Batch {
        tokens: HostTensorI32::from_vec(
            &[m.batch, m.seq],
            (0..m.batch * m.seq).map(|i| (i % m.vocab) as i32).collect(),
        ),
        targets: HostTensorI32::from_vec(
            &[m.batch, m.seq],
            (0..m.batch * m.seq).map(|i| ((i + 1) % m.vocab) as i32).collect(),
        ),
    };
    let mut eng = Engine::new(&rt);
    let loss_before = eng.forward_loss(&params, &batch).unwrap();

    let path = std::env::temp_dir().join("lisa_full_model.ckpt");
    checkpoint::save_model(&path, &params).unwrap();
    let mut restored = ModelParams::init(&m, &mut Rng::new(99)); // different init
    checkpoint::load_model(&path, &mut restored).unwrap();
    let loss_after = eng.forward_loss(&restored, &batch).unwrap();
    assert_eq!(loss_before, loss_after, "checkpoint must restore exactly");
}

#[test]
fn grad_accumulation_equals_mean_of_microbatch_grads() {
    if !have() { return; }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(31));
    let mut eng = Engine::new(&rt);
    let mask = TrainMask::all(m.n_layers);

    let mk_batch = |seed: u64| {
        let mut r = Rng::new(seed);
        Batch {
            tokens: HostTensorI32::from_vec(
                &[m.batch, m.seq],
                (0..m.batch * m.seq).map(|_| r.below(m.vocab) as i32).collect(),
            ),
            targets: HostTensorI32::from_vec(
                &[m.batch, m.seq],
                (0..m.batch * m.seq).map(|_| r.below(m.vocab) as i32).collect(),
            ),
        }
    };
    let b1 = mk_batch(1);
    let b2 = mk_batch(2);
    let g1 = eng.forward_backward(&params, &b1, &mask).unwrap().grads;
    let g2 = eng.forward_backward(&params, &b2, &mask).unwrap().grads;
    let mut acc = g1.clone();
    acc.add_assign(&g2);
    acc.scale(0.5);

    // manual mean per tensor
    let a = acc.blocks[0].as_ref().unwrap();
    let x1 = g1.blocks[0].as_ref().unwrap();
    let x2 = g2.blocks[0].as_ref().unwrap();
    for ((am, (m1, m2)), _) in a.iter().zip(x1.iter().zip(x2)).zip(0..) {
        let manual: Vec<f32> = m1.data.iter().zip(&m2.data).map(|(p, q)| (p + q) / 2.0).collect();
        assert!(allclose(&am.data, &manual, 1e-6, 1e-7));
    }
    // global norm is finite and positive
    assert!(acc.global_norm() > 0.0);
}

#[test]
fn lisa_state_drop_vs_keep_changes_memory_not_correctness() {
    if !have() { return; }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let m = rt.manifest.clone();
    let samples = corpus::gen_instruction_corpus(64, 17);
    let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);
    let enc: Vec<_> = samples.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();

    let run = |policy| {
        let mut dl = DataLoader::new(enc.clone(), m.batch, m.seq, 3);
        let cfg = TrainConfig {
            steps: 12,
            lr: 3e-3,
            seed: 5,
            state_policy: policy,
            log_every: 0,
            ..Default::default()
        };
        let mut sess = TrainSession::new(&rt, &StrategySpec::lisa(1, 3), cfg).unwrap();
        let res = sess.run(&mut dl).unwrap();
        (res.final_train_loss, res.peak_mem)
    };
    let (loss_keep, _mem_keep) = run(lisa::opt::StatePolicy::Keep);
    let (loss_drop, _mem_drop) = run(lisa::opt::StatePolicy::Drop);
    // both must learn; exact losses differ (bias-correction restart)
    assert!(loss_keep.is_finite() && loss_drop.is_finite());
    assert!(loss_keep < 7.0 && loss_drop < 7.0);
}

#[test]
fn backend_gradients_agree_end_to_end() {
    if !have() { return; }
    let rt_p = Runtime::load(&artifacts(), "pallas").unwrap();
    let rt_j = Runtime::load(&artifacts(), "jnp").unwrap();
    let m = rt_p.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(41));
    let batch = Batch {
        tokens: HostTensorI32::from_vec(
            &[m.batch, m.seq],
            (0..m.batch * m.seq).map(|i| ((i * 7) % m.vocab) as i32).collect(),
        ),
        targets: HostTensorI32::from_vec(
            &[m.batch, m.seq],
            (0..m.batch * m.seq).map(|i| ((i * 3) % m.vocab) as i32).collect(),
        ),
    };
    let mask = TrainMask::all(m.n_layers);
    let gp = Engine::new(&rt_p).forward_backward(&params, &batch, &mask).unwrap();
    let gj = Engine::new(&rt_j).forward_backward(&params, &batch, &mask).unwrap();
    assert!((gp.loss - gj.loss).abs() < 1e-4);
    let a = gp.grads.emb.as_ref().unwrap();
    let b = gj.grads.emb.as_ref().unwrap();
    assert!(allclose(&a.data, &b.data, 1e-3, 1e-4), "embed grads diverge across backends");
}
