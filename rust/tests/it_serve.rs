//! Serving conformance suite (artifact-gated like `it_decode.rs`, plus a
//! pure sampler/scheduler tier that always runs):
//!
//! * **continuous-batching parity** — every completion served from a
//!   mixed queue (greedy and sampled rows interleaved) must be
//!   token-identical to a solo static-batch decode of the same request at
//!   the same seed: per-request sampler streams make a completion a
//!   function of `(prompt, spec, seed)` alone, never of batch placement;
//! * **admission saves work** — for a mixed-length queue, total
//!   `decode_step` executions must be *strictly fewer* than the
//!   static-batch-rounds schedule (asserted against `ExecStats` and
//!   against an actual `run_static` of the same queue), and only one
//!   batch prefill is paid where the static schedule pays one per chunk;
//! * **sampler determinism** — seeded runs are bit-reproducible
//!   end-to-end; `temperature -> 0` and `top_k == 1` reproduce the greedy
//!   decode token for token; the legacy full-forward path agrees with the
//!   served path under every sampling policy.
//!
//! The sampler unit properties (top-p mass cutoff, top-k membership,
//! argmax degeneracies on synthetic logits) live with the sampler
//! (`engine::serve::sampler`); this file covers the end-to-end surfaces.

use std::path::{Path, PathBuf};

use lisa::data::tokenizer::{EOS, PAD};
use lisa::data::{corpus, Tokenizer};
use lisa::engine::serve::request_seed;
use lisa::engine::{
    Completion, Engine, Feed, KvMode, LoopStats, Request, RequestSink, RequestSource,
    SamplerSpec, ServeSession, StopReason,
};
use lisa::eval::generate;
use lisa::model::ModelParams;
use lisa::runtime::Runtime;
use lisa::util::rng::Rng;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

/// Artifacts present *and* exported with the decode ABI.
fn have_decode() -> Option<Runtime> {
    if !artifacts().join("manifest.json").exists() {
        return None;
    }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    rt.manifest.supports_decode("pallas").then_some(rt)
}

fn make_tok(rt: &Runtime) -> Tokenizer {
    let samples = corpus::gen_instruction_corpus(64, 11);
    Tokenizer::build(&corpus::sample_texts(&samples), rt.manifest.vocab)
}

/// A queue longer than the batch with mixed prompt lengths, budgets and
/// sampling policies — the shape continuous batching exists for.
fn mixed_requests(tok: &Tokenizer, gen_seed: u64) -> Vec<Request> {
    let texts = [
        "what is 12 plus 10 ?",
        "name the capital of france .",
        "what is 3 times 4 ?",
        "who built the eiffel tower ?",
        "what is 9 minus 2 ?",
        "in what year was the eiffel tower built ?",
        "what is 7 times 8 ?",
        "name the capital of japan .",
    ];
    let specs = [
        SamplerSpec::Greedy,
        SamplerSpec::Temperature { temperature: 0.8 },
        SamplerSpec::TopK { k: 5, temperature: 1.0 },
        SamplerSpec::TopP { p: 0.9, temperature: 1.0 },
    ];
    texts
        .iter()
        .enumerate()
        .map(|(i, t)| {
            // greedy rows run longer (they tolerate streamed-prefill float
            // noise via argmax margins); sampled rows keep short budgets so
            // the multinomial boundary-noise caveat stays negligible
            let greedy = i % specs.len() == 0;
            Request::sampled(
                generate::encode_prompt(tok, t),
                if greedy { 3 + i } else { 2 + (i % 2) },
                specs[i % specs.len()].clone(),
                request_seed(gen_seed, i),
            )
        })
        .collect()
}

fn run_serve(rt: &Runtime, params: &ModelParams, reqs: &[Request], eos: i32) -> Vec<Completion> {
    let mut eng = Engine::new(rt);
    let mut sess = ServeSession::new(&mut eng, params).unwrap();
    sess.run(reqs, eos, PAD).unwrap()
}

// Parity caveat (same class as it_decode.rs): a mid-decode-admitted row's
// prompt K/V comes through decode_step's masked-softmax attention while a
// solo decode prefills it through the flash kernel — equal to float
// tolerance (~2e-4, pinned by python/tests/test_decode.py), not
// bit-for-bit. Token identity relies on argmax margins / multinomial
// draws landing away from probability boundaries; sampled rows keep
// 2-3-token budgets above precisely to keep the per-draw boundary
// exposure negligible.
#[test]
fn every_continuous_completion_matches_a_solo_decode() {
    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(3));
    let tok = make_tok(&rt);
    let reqs = mixed_requests(&tok, 42);
    assert!(reqs.len() > m.batch, "queue must force admission");

    let served = run_serve(&rt, &params, &reqs, EOS);
    assert_eq!(served.len(), reqs.len());
    for (i, r) in reqs.iter().enumerate() {
        let solo = run_serve(&rt, &params, std::slice::from_ref(r), EOS);
        assert_eq!(served[i].tokens, solo[0].tokens, "request {i} diverged from solo");
        assert_eq!(served[i].stop, solo[0].stop, "request {i} stop reason");
        assert_eq!(served[i].prompt_truncated, solo[0].prompt_truncated);
    }
}

#[test]
fn seeded_sampled_serving_is_bit_reproducible() {
    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(5));
    let tok = make_tok(&rt);

    let a = run_serve(&rt, &params, &mixed_requests(&tok, 42), EOS);
    let b = run_serve(&rt, &params, &mixed_requests(&tok, 42), EOS);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.tokens, y.tokens, "request {i} not reproducible");
        assert_eq!(x.stop, y.stop);
    }
}

#[test]
fn degenerate_samplers_reproduce_greedy_end_to_end() {
    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(7));
    let tok = make_tok(&rt);
    let prompt = generate::encode_prompt(&tok, "who built the eiffel tower ?");

    let greedy = run_serve(&rt, &params, &[Request::greedy(prompt.clone(), 8)], EOS);
    for spec in [
        SamplerSpec::Temperature { temperature: 0.0 },
        SamplerSpec::TopK { k: 1, temperature: 1.0 },
    ] {
        let got = run_serve(
            &rt,
            &params,
            &[Request::sampled(prompt.clone(), 8, spec.clone(), 999)],
            EOS,
        );
        assert_eq!(got[0].tokens, greedy[0].tokens, "{spec:?} must equal greedy");
        assert_eq!(got[0].stop, greedy[0].stop);
    }
}

#[test]
fn legacy_full_forward_agrees_with_served_sampling() {
    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(9));
    let tok = make_tok(&rt);
    let text = "name the capital of france .";

    for (spec, seed) in [
        (SamplerSpec::Greedy, 0u64),
        (SamplerSpec::Temperature { temperature: 0.7 }, 17),
        (SamplerSpec::TopK { k: 4, temperature: 1.0 }, 23),
        (SamplerSpec::TopP { p: 0.85, temperature: 1.0 }, 31),
    ] {
        // short budgets: cached-vs-legacy logits agree to ~2e-4 (the §9
        // parity caveat), so sampled draws get few boundary exposures
        let budget = if spec == SamplerSpec::Greedy { 8 } else { 3 };
        let mut eng = Engine::new(&rt);
        let legacy = generate::complete_legacy(
            &mut eng,
            &params,
            &tok,
            text,
            budget,
            spec.clone(),
            seed,
        )
        .unwrap();
        let served = run_serve(
            &rt,
            &params,
            &[Request::sampled(
                generate::encode_prompt(&tok, text),
                budget,
                spec.clone(),
                seed,
            )],
            EOS,
        );
        assert_eq!(served[0].tokens, legacy.tokens, "{spec:?} legacy/served diverged");
        assert_eq!(served[0].stop, legacy.stop);
    }
}

/// `decode_step` executions the static-rounds schedule needs for these
/// completions: per chunk, the slowest row (first token comes from
/// prefill; an `<eos>`-stopped row pays one extra surfacing step).
fn static_schedule_steps(completions: &[Completion], batch: usize) -> u64 {
    completions
        .chunks(batch)
        .map(|chunk| {
            chunk
                .iter()
                .map(|c| {
                    let k = c.tokens.len() as u64;
                    match c.stop {
                        StopReason::Eos => k,
                        _ => k.saturating_sub(1),
                    }
                })
                .max()
                .unwrap_or(0)
        })
        .sum()
}

// The ISSUE 5 acceptance gate: a mixed-length queue must finish in
// strictly fewer decode_step executions than the static-batch-rounds
// schedule, because freed rows take queued work mid-decode. `eos` is set
// to an id greedy decode can never emit, so every row runs its exact
// budget and the schedule comparison is deterministic.
#[test]
fn continuous_batching_admits_mid_decode_and_saves_steps() {
    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let bsz = m.batch;
    let params = ModelParams::init(&m, &mut Rng::new(11));
    let tok = make_tok(&rt);
    let eos = -1; // unreachable: lengths are exactly the budgets

    // chunk 1 of the static schedule: one long row + minimal-budget rows
    // that free immediately; then a tail of short-prompt requests that
    // fit entirely inside the long row's decode
    let long = generate::encode_prompt(&tok, "who built the eiffel tower ?");
    let long_budget = (m.seq - long.len() - 1).min(16);
    let tail = generate::encode_prompt(&tok, "paris .");
    // the two tail admissions stream sequentially through one row:
    // each costs tail.len() prompt columns + 1 decode step, and both
    // must finish inside the long row's long_budget - 1 steps
    assert!(
        2 * (tail.len() + 1) <= long_budget - 1,
        "tail admissions must finish inside the long row's decode"
    );
    let mut reqs = vec![Request::greedy(long.clone(), long_budget)];
    for _ in 1..bsz {
        reqs.push(Request::greedy(tail.clone(), 1));
    }
    for _ in 0..bsz {
        reqs.push(Request::greedy(tail.clone(), 2));
    }

    // ---- continuous — pinned to the packed v1 layout: the decode_step
    // ExecStats arithmetic below is the v1 contract (the paged path runs
    // paged_step and has its own accounting suite, it_paged.rs)
    rt.reset_stats();
    let mut eng = Engine::new(&rt);
    let (served, steps, streamed, prefills) = {
        let mut sess = ServeSession::with_mode(&mut eng, &params, KvMode::Packed).unwrap();
        let served = sess.run(&reqs, eos, PAD).unwrap();
        (served, sess.decode_steps, sess.streamed_prompt_tokens, sess.batch_prefills)
    };
    assert_eq!(served[0].tokens.len(), long_budget, "eos must be unreachable");
    let stats = rt.stats();
    assert_eq!(stats.get("decode_step").expect("ran").calls, steps, "ExecStats vs counter");

    // admission really streamed queued prompts into freed rows
    assert!(streamed > 0, "no prompt was streamed mid-decode");
    assert_eq!(prefills, 1, "continuous mode pays one batch prefill here");

    // acceptance: strictly fewer decode_step executions than the
    // static-rounds schedule of the same completions
    let static_steps = static_schedule_steps(&served, bsz);
    assert!(
        steps < static_steps,
        "continuous ({steps}) must beat the static schedule ({static_steps})"
    );

    // ---- and the static path really pays that schedule, with identical
    // tokens per request and one prefill per chunk
    rt.reset_stats();
    let mut eng2 = Engine::new(&rt);
    let (static_served, static_ran, static_prefills) = {
        let mut sess = ServeSession::with_mode(&mut eng2, &params, KvMode::Packed).unwrap();
        let out = sess.run_static(&reqs, eos, PAD).unwrap();
        (out, sess.decode_steps, sess.batch_prefills)
    };
    assert_eq!(static_ran, static_steps, "run_static must pay the static schedule");
    assert_eq!(static_prefills as usize, reqs.len().div_ceil(bsz));
    for (i, (a, b)) in served.iter().zip(&static_served).enumerate() {
        assert_eq!(a.tokens, b.tokens, "request {i}: continuous vs static tokens");
        assert_eq!(a.stop, b.stop);
    }
    // the avoided second prefill is visible in the segment stats too
    let bf = rt.stats().get("block_fwd").expect("prefill ran").calls;
    assert_eq!(bf, m.n_layers as u64 * static_prefills);
}

#[test]
fn zero_budget_queue_runs_no_segments_at_all() {
    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(13));
    let tok = make_tok(&rt);
    let reqs: Vec<Request> = (0..m.batch + 1)
        .map(|_| Request::greedy(generate::encode_prompt(&tok, "what is 3 times 4 ?"), 0))
        .collect();
    rt.reset_stats();
    let served = run_serve(&rt, &params, &reqs, EOS);
    assert!(served.iter().all(|c| c.tokens.is_empty()));
    assert!(served.iter().all(|c| c.stop == StopReason::MaxNew));
    assert!(
        rt.stats().is_empty(),
        "zero-budget requests must not execute any segment"
    );
}

// The ISSUE 7 fairness gate: the admission queue is FIFO — a request
// that arrived earlier must never start decoding after one that arrived
// later, no matter which row frees first. The recording source below
// logs (arrival index, decode-step at admission) for every poll the loop
// takes; completions carry distinct per-index budgets so any cross-wired
// sink association would surface as a wrong length.
#[test]
fn admission_queue_is_fifo_in_arrival_order() {
    use std::cell::RefCell;
    use std::rc::Rc;

    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(17));
    let tok = make_tok(&rt);
    let eos = -1; // unreachable: every row runs its exact budget
    let n = 2 * m.batch + 3; // forces several mid-decode admissions
    let texts = ["what is 3 times 4 ?", "paris .", "name the capital of japan ."];
    let reqs: Vec<Request> = (0..n)
        .map(|i| Request::greedy(generate::encode_prompt(&tok, texts[i % 3]), 1 + (i % 3)))
        .collect();

    struct Collect {
        idx: usize,
        done: Rc<RefCell<Vec<Option<Completion>>>>,
    }
    impl RequestSink for Collect {
        fn on_token(&mut self, _tok: i32) {}
        fn on_done(&mut self, c: &Completion) {
            self.done.borrow_mut()[self.idx] = Some(c.clone());
        }
    }

    struct RecSrc {
        reqs: Vec<Request>,
        next: usize,
        /// `(arrival index, decode-step count at admission)` per poll.
        log: Vec<(usize, u64)>,
        steps: u64,
        admitted: u64,
        done: Rc<RefCell<Vec<Option<Completion>>>>,
    }
    impl RequestSource for RecSrc {
        fn poll(&mut self, _idle: bool) -> Feed {
            if self.next >= self.reqs.len() {
                return Feed::Closed;
            }
            let idx = self.next;
            self.next += 1;
            self.log.push((idx, self.steps));
            Feed::Admit(
                self.reqs[idx].clone(),
                Box::new(Collect { idx, done: self.done.clone() }),
            )
        }
        fn observe(&mut self, _eng: &Engine, s: LoopStats) {
            self.steps = s.decode_steps;
            self.admitted = s.admitted;
        }
    }

    let done = Rc::new(RefCell::new(vec![None; n]));
    let mut src = RecSrc {
        reqs: reqs.clone(),
        next: 0,
        log: Vec::new(),
        steps: 0,
        admitted: 0,
        done: done.clone(),
    };
    let mut eng = Engine::new(&rt);
    let mut sess = ServeSession::new(&mut eng, &params).unwrap();
    sess.run_loop(&mut src, eos, PAD).unwrap();

    // every request the source handed out was admitted — the loop never
    // buffered, dropped or re-queued one (that is what could reorder)
    assert_eq!(src.admitted, n as u64, "polls vs admissions");
    let order: Vec<usize> = src.log.iter().map(|&(i, _)| i).collect();
    assert_eq!(order, (0..n).collect::<Vec<_>>(), "admission order vs arrival order");
    // earlier arrivals are admitted at earlier-or-equal decode steps
    for w in src.log.windows(2) {
        assert!(
            w[0].1 <= w[1].1,
            "request {} admitted at step {} after request {} at step {}",
            w[0].0, w[0].1, w[1].0, w[1].1
        );
    }
    // sink association survived out-of-order row frees: each completion
    // has its own request's budget
    let done = done.borrow();
    for (i, c) in done.iter().enumerate() {
        let c = c.as_ref().unwrap_or_else(|| panic!("request {i} never completed"));
        assert_eq!(c.tokens.len(), 1 + (i % 3), "request {i} got another row's budget");
        assert_eq!(c.stop, StopReason::MaxNew);
    }
}

// The ISSUE 7 stop-holdback gate, end to end: a stop sequence whose
// prefix keeps matching the live tail holds tokens back from the
// streamed sink — when the row then drains for a *non*-StopSeq reason
// (here WindowFull), the held-back tail must flush, not vanish. The
// baseline pass learns the greedy trajectory; the streamed pass stops on
// `[last_token, -7]`, a sequence that partially matches every time the
// final token recurs but can never complete (-7 is not emittable).
#[test]
fn streamed_sink_receives_the_held_back_tail_on_window_full_drain() {
    use std::cell::RefCell;
    use std::rc::Rc;

    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(19));
    let tok = make_tok(&rt);
    let eos = -1;
    let prompt = generate::encode_prompt(&tok, "what is 9 minus 2 ?");
    let budget = m.seq; // clipped by the window: the row drains WindowFull

    let mut eng = Engine::new(&rt);
    let baseline = {
        let mut sess = ServeSession::new(&mut eng, &params).unwrap();
        sess.run(&[Request::greedy(prompt.clone(), budget)], eos, PAD).unwrap().remove(0)
    };
    assert_eq!(baseline.stop, StopReason::WindowFull, "budget must exceed the window");
    let last = *baseline.tokens.last().unwrap();

    struct Stream {
        events: Rc<RefCell<(Vec<i32>, Option<Completion>)>>,
    }
    impl RequestSink for Stream {
        fn on_token(&mut self, tok: i32) {
            self.events.borrow_mut().0.push(tok);
        }
        fn on_done(&mut self, c: &Completion) {
            self.events.borrow_mut().1 = Some(c.clone());
        }
    }
    struct OneShot {
        req: Option<Request>,
        events: Rc<RefCell<(Vec<i32>, Option<Completion>)>>,
    }
    impl RequestSource for OneShot {
        fn poll(&mut self, _idle: bool) -> Feed {
            match self.req.take() {
                Some(r) => Feed::Admit(r, Box::new(Stream { events: self.events.clone() })),
                None => Feed::Closed,
            }
        }
    }

    let events = Rc::new(RefCell::new((Vec::new(), None)));
    let req = Request::greedy(prompt, budget).with_stop(vec![vec![last, -7]]);
    let mut src = OneShot { req: Some(req), events: events.clone() };
    let mut sess = ServeSession::new(&mut eng, &params).unwrap();
    sess.run_loop(&mut src, eos, PAD).unwrap();

    let (streamed, done) = Rc::try_unwrap(events).unwrap().into_inner();
    let done = done.expect("row drained");
    assert_eq!(done.stop, StopReason::WindowFull, "the stop sequence must never complete");
    assert_eq!(done.tokens, baseline.tokens, "an uncompletable stop changed the decode");
    // the acceptance bit: the streamed events cover every token — the
    // tail held back behind the partial match flushed on drain
    assert_eq!(streamed, done.tokens, "held-back tail was swallowed on WindowFull drain");
}

// ---- pure tier (no artifacts): the public sampling surface ------------

#[test]
fn request_seed_streams_are_stable_and_distinct() {
    let s: Vec<u64> = (0..16).map(|i| request_seed(42, i)).collect();
    let t: Vec<u64> = (0..16).map(|i| request_seed(42, i)).collect();
    assert_eq!(s, t);
    for i in 0..s.len() {
        for j in 0..i {
            assert_ne!(s[i], s[j], "seeds {i}/{j} collide");
        }
    }
}

#[test]
fn greedy_degenerate_specs_report_themselves() {
    assert!(SamplerSpec::Greedy.is_greedy());
    assert!(SamplerSpec::Temperature { temperature: 0.0 }.is_greedy());
    assert!(SamplerSpec::TopK { k: 1, temperature: 0.9 }.is_greedy());
    assert!(!SamplerSpec::Temperature { temperature: 0.5 }.is_greedy());
    assert!(!SamplerSpec::TopK { k: 2, temperature: 0.5 }.is_greedy());
    assert!(!SamplerSpec::TopP { p: 0.9, temperature: 1.0 }.is_greedy());
}
