//! Fault-isolation chaos suite (DESIGN.md §13) — a pure unit tier that
//! always runs (injector grammar through `anyhow` chains, typed page-pool
//! exhaustion) plus an artifact-gated tier that drives the serve loop
//! through deterministic `FaultPlan`s and asserts the containment
//! contract:
//!
//! * **transient faults retry in place** — a `seg:*:transient` hit is
//!   absorbed by restore-and-retry and the completions are token-identical
//!   to the fault-free run (XLA executions are functional: a failed step
//!   never mutated the pre-step state, and it consumed no sampler picks);
//! * **persistent faults quarantine, neighbors survive** — rows rebuild
//!   their K/V by re-prefill (teacher-forcing the full host-side
//!   sequence), again token-identical; a fault that never clears drains
//!   its rows with [`StopReason::Error`] while the loop itself survives
//!   to serve the rest of the queue;
//! * **pool pressure degrades, never crashes** — an injected allocation
//!   failure mid-decode parks the row (pages released) and the row
//!   completes identically after unparking; allocation failures at
//!   admission surface as typed overload rejections;
//! * **cancellation is prompt and leak-free** — a [`CancelToken`] flipped
//!   mid-decode drains exactly that row with [`FailClass::Cancelled`],
//!   neighbors finish token-identical, and the allocator ends with zero
//!   outstanding pages (the ISSUE 7 leak gate, now under faults).
//!
//! Token-identity caveats are the same float-tolerance class as
//! `it_serve.rs` / `it_paged.rs`: re-prefilled K/V comes through the
//! prefill kernels while the original came through step columns, so
//! identity relies on argmax margins over short greedy budgets.

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use lisa::data::tokenizer::PAD;
use lisa::data::{corpus, Tokenizer};
use lisa::engine::{
    CancelToken, Completion, Engine, FailClass, Feed, KvMode, PageAllocator, Request, RequestSink,
    RequestSource, ServeFail, ServeSession, StopReason,
};
use lisa::eval::generate;
use lisa::model::ModelParams;
use lisa::runtime::{FaultError, FaultInjector, FaultKind, Runtime};
use lisa::util::rng::Rng;

// ------------------------------------------------------------ unit tier

fn injector(spec: &str) -> Rc<RefCell<FaultInjector>> {
    Rc::new(RefCell::new(FaultInjector::parse(spec).unwrap()))
}

#[test]
fn fault_error_survives_anyhow_context_chains() {
    let mut inj = FaultInjector::parse("seg:decode_step:nth=2:persistent").unwrap();
    assert!(inj.on_segment("decode_step").is_none());
    let f = inj.on_segment("decode_step").expect("nth=2 fires on the second execution");
    let err = anyhow::Error::from(f).context("running segment").context("decode step");
    let back = err.downcast_ref::<FaultError>().expect("typed fault survives context");
    assert_eq!(back.kind, FaultKind::Persistent);
    assert_eq!(back.site, "decode_step");
    assert_eq!(back.hit, 2);
    assert!(format!("{back}").contains("injected persistent fault at decode_step"));
}

#[test]
fn injected_pool_fault_is_typed_and_spends_its_plan() {
    let mut alloc = PageAllocator::new(8, 4);
    alloc.set_fault_injector(injector("pool:nth=2"));
    let a = alloc.alloc().unwrap();
    let err = alloc.alloc().expect_err("the second allocation is the injected one");
    let f = err.downcast_ref::<FaultError>().expect("pool faults are typed");
    assert_eq!(f.kind, FaultKind::PoolExhausted);
    assert_eq!(f.site, "page_pool");
    assert_eq!(f.hit, 2);
    // the plan fired once: the pool is healthy again
    let b = alloc.alloc().unwrap();
    alloc.release(a);
    alloc.release(b);
    assert_eq!(alloc.outstanding(), 0);
}

#[test]
fn real_exhaustion_carries_the_same_class_as_an_injected_one() {
    let mut alloc = PageAllocator::new(4, 4); // page 0 is pinned scratch
    let mut held = Vec::new();
    while alloc.n_free() > 0 {
        held.push(alloc.alloc().unwrap());
    }
    let err = alloc.alloc().expect_err("an empty pool must refuse");
    let f = err.downcast_ref::<FaultError>().expect("exhaustion is typed");
    assert_eq!(f.kind, FaultKind::PoolExhausted);
    assert_eq!(f.hit, 0, "a real (non-injected) failure reports hit 0");
    for p in held {
        alloc.release(p);
    }
    assert_eq!(alloc.outstanding(), 0);
}

#[test]
fn transient_plans_rewind_so_the_retry_goes_through() {
    let mut inj = FaultInjector::parse("seg:step:nth=2:transient").unwrap();
    assert!(inj.on_segment("step").is_none()); // execution 1
    assert!(inj.on_segment("step").is_some()); // execution 2 fails...
    assert!(inj.on_segment("step").is_none()); // ...its retry replays index 2
    assert!(inj.on_segment("step").is_none());
    assert_eq!(inj.injected, 1);
}

#[test]
fn armed_environment_never_panics_the_parser() {
    // the CI fault-matrix smoke step runs this suite under LISA_FAULT
    // (including deliberately malformed specs): from_env must always
    // yield a usable injector
    let mut inj = FaultInjector::from_env();
    let _ = inj.on_segment("decode_step");
    let _ = inj.on_alloc();
}

// -------------------------------------------------------- artifact tier

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

/// Artifacts present *and* exported with the decode ABI.
fn have_decode() -> Option<Runtime> {
    if !artifacts().join("manifest.json").exists() {
        return None;
    }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    rt.manifest.supports_decode("pallas").then_some(rt)
}

/// Artifacts additionally exported with the paged decode ABI (v2).
fn have_paged() -> Option<Runtime> {
    have_decode().filter(|rt| rt.manifest.supports_paged("pallas"))
}

fn make_tok(rt: &Runtime) -> Tokenizer {
    let samples = corpus::gen_instruction_corpus(64, 11);
    Tokenizer::build(&corpus::sample_texts(&samples), rt.manifest.vocab)
}

/// Greedy-only mixed-length queue: short argmax budgets keep the
/// re-prefill float-tolerance caveat negligible (same policy as the
/// parity suites).
fn greedy_queue(tok: &Tokenizer) -> Vec<Request> {
    [
        "what is 12 plus 10 ?",
        "name the capital of france .",
        "what is 3 times 4 ?",
        "who built the eiffel tower ?",
        "what is 9 minus 2 ?",
        "name the capital of japan .",
    ]
    .iter()
    .enumerate()
    .map(|(i, t)| Request::greedy(generate::encode_prompt(tok, t), 3 + i % 3))
    .collect()
}

/// Plain token ids below `vocab`, long enough to span pages.
fn long_prompt(vocab: usize, len: usize, salt: i32) -> Vec<i32> {
    (0..len as i32).map(|i| 3 + (salt + i * 7) % (vocab as i32 - 4)).collect()
}

/// Post-run counters + the allocator leak gate, snapshotted before the
/// session drops.
#[derive(Debug)]
struct RunOut {
    done: Vec<Completion>,
    retries: u64,
    reprefills: u64,
    error_drains: u64,
    preemptions: u64,
    cancelled: u64,
    rejected: u64,
    injected: u64,
    /// `(outstanding, free + cached)` — paged sessions only.
    pool: Option<(usize, usize)>,
}

/// Arm `plan` on the runtime and serve `reqs` in a fresh session.
/// `eos = -1` is unreachable: budgets run exactly.
fn serve_with_plan(
    rt: &Runtime,
    params: &ModelParams,
    reqs: &[Request],
    mode: KvMode,
    plan: &str,
) -> RunOut {
    rt.set_fault_plan(plan).unwrap();
    let mut eng = Engine::new(rt);
    let mut sess = ServeSession::with_mode(&mut eng, params, mode).unwrap();
    sess.set_recovery(2, 0, 2); // zero backoff: tests never sleep
    let done = sess.run(reqs, -1, PAD).unwrap();
    RunOut {
        done,
        retries: sess.retries,
        reprefills: sess.reprefills,
        error_drains: sess.error_drains,
        preemptions: sess.preemptions,
        cancelled: sess.cancelled,
        rejected: sess.rejected,
        injected: rt.fault_handle().borrow().injected,
        pool: sess.page_allocator().map(|a| (a.outstanding(), a.n_free() + a.n_cached())),
    }
}

fn assert_token_identical(faulted: &RunOut, baseline: &RunOut, what: &str) {
    assert_eq!(faulted.done.len(), baseline.done.len());
    for (i, (a, b)) in faulted.done.iter().zip(&baseline.done).enumerate() {
        assert_eq!(a.tokens, b.tokens, "{what}: request {i} diverged under faults");
        assert_eq!(a.stop, b.stop, "{what}: request {i} stop reason");
    }
}

fn assert_no_leak(out: &RunOut, page_n: usize) {
    if let Some((outstanding, free_cached)) = out.pool {
        assert_eq!(outstanding, 0, "pages leaked across the faulted drain");
        assert_eq!(free_cached, page_n - 1, "free + cached must account for every page");
    }
}

#[test]
fn transient_decode_fault_retries_in_place_token_identical() {
    let Some(rt) = have_decode() else { return };
    let params = ModelParams::init(&rt.manifest, &mut Rng::new(3));
    let reqs = greedy_queue(&make_tok(&rt));
    let base = serve_with_plan(&rt, &params, &reqs, KvMode::Packed, "");
    assert!(base.done.iter().all(|c| c.stop == StopReason::MaxNew));

    let out =
        serve_with_plan(&rt, &params, &reqs, KvMode::Packed, "seg:decode_step:nth=3:transient");
    assert_eq!(out.injected, 1, "the plan must actually fire");
    assert!(out.retries >= 1, "a transient fault is absorbed by retry, not quarantine");
    assert_eq!(out.reprefills, 0);
    assert_eq!(out.error_drains, 0);
    assert_token_identical(&out, &base, "transient retry");
}

#[test]
fn persistent_fault_quarantines_and_reprefills_token_identical() {
    let Some(rt) = have_decode() else { return };
    let params = ModelParams::init(&rt.manifest, &mut Rng::new(3));
    let reqs = greedy_queue(&make_tok(&rt));
    let base = serve_with_plan(&rt, &params, &reqs, KvMode::Packed, "");

    let out =
        serve_with_plan(&rt, &params, &reqs, KvMode::Packed, "seg:decode_step:nth=3:persistent");
    assert_eq!(out.injected, 1);
    assert!(out.reprefills >= 1, "a persistent fault rebuilds rows by re-prefill");
    assert_eq!(out.error_drains, 0, "one recoverable fault must not drain anybody");
    assert_token_identical(&out, &base, "quarantine + re-prefill");
}

#[test]
fn unrecoverable_fault_drains_rows_but_the_loop_survives() {
    let Some(rt) = have_decode() else { return };
    let params = ModelParams::init(&rt.manifest, &mut Rng::new(3));
    let reqs = greedy_queue(&make_tok(&rt));

    // every decode step fails, forever: rows burn their fault budget and
    // drain with a typed error — but run() itself must return Ok with one
    // completion per request
    let out = serve_with_plan(
        &rt,
        &params,
        &reqs,
        KvMode::Packed,
        "seg:decode_step:nth=1:every=1:count=*:persistent",
    );
    assert_eq!(out.done.len(), reqs.len(), "the loop must survive to serve the whole queue");
    // each re-prefill round still commits one token off the prefill
    // logits, so the shortest budgets can finish legitimately before
    // their fault budget runs out — everything else drains typed
    assert!(
        out.done.iter().all(|c| matches!(c.stop, StopReason::Error | StopReason::MaxNew)),
        "{:?}",
        out.done
    );
    let errs = out.done.iter().filter(|c| c.stop == StopReason::Error).count();
    assert!(errs >= 1, "some rows must exhaust the fault budget");
    assert_eq!(out.error_drains as usize, errs);
    assert!(out.reprefills >= 1, "rows got their re-prefill chances before draining");
}

#[test]
fn paged_transient_fault_retries_with_the_leak_gate_held() {
    let Some(rt) = have_paged() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(5));
    let reqs = greedy_queue(&make_tok(&rt));
    let base = serve_with_plan(&rt, &params, &reqs, KvMode::Paged, "");

    let out = serve_with_plan(&rt, &params, &reqs, KvMode::Paged, "seg:paged_step:nth=4:transient");
    assert_eq!(out.injected, 1);
    assert!(out.retries >= 1);
    assert_token_identical(&out, &base, "paged transient retry");
    assert_no_leak(&out, m.page_n);
}

#[test]
fn failed_prefill_scatter_restores_the_pool_and_recovers() {
    let Some(rt) = have_paged() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(5));
    let reqs = greedy_queue(&make_tok(&rt));
    let base = serve_with_plan(&rt, &params, &reqs, KvMode::Paged, "");

    // the very first batch prefill's scatter fails persistently once:
    // the pool state is restored, the batch quarantines and the retry
    // prefill succeeds — completions unchanged, nothing leaked
    let out =
        serve_with_plan(&rt, &params, &reqs, KvMode::Paged, "seg:paged_scatter:nth=1:persistent");
    assert_eq!(out.injected, 1);
    assert!(out.reprefills >= 1);
    assert_eq!(out.error_drains, 0);
    assert_token_identical(&out, &base, "scatter restore");
    assert_no_leak(&out, m.page_n);
}

#[test]
fn pool_fault_mid_decode_parks_the_row_and_completes_identically() {
    let Some(rt) = have_paged() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(7));
    // prompt two short of a page boundary, budget across it: allocation
    // #1 is admission, #2 is the mid-decode page growth — the injected
    // failure point
    let reqs = vec![Request::greedy(long_prompt(m.vocab, m.page_t - 2, 1), 6)];
    let base = serve_with_plan(&rt, &params, &reqs, KvMode::Paged, "");
    assert_eq!(base.done[0].tokens.len(), 6);

    let out = serve_with_plan(&rt, &params, &reqs, KvMode::Paged, "pool:nth=2");
    assert_eq!(out.injected, 1);
    assert_eq!(out.preemptions, 1, "the row parks instead of failing");
    assert_eq!(out.error_drains, 0);
    assert_eq!(out.rejected, 0);
    assert_token_identical(&out, &base, "park + unpark");
    assert_no_leak(&out, m.page_n);
}

#[test]
fn admission_under_a_dead_pool_rejects_with_overload_and_survives() {
    let Some(rt) = have_paged() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(7));
    let tok = make_tok(&rt);
    let reqs = vec![
        Request::greedy(generate::encode_prompt(&tok, "what is 3 times 4 ?"), 3),
        Request::greedy(generate::encode_prompt(&tok, "name the capital of france ."), 3),
    ];

    // every allocation fails: page-budget reservation passes (free pages
    // exist on paper) but attach fails — both requests drain as typed
    // overload rejections, the loop exits cleanly, nothing leaks
    let out = serve_with_plan(&rt, &params, &reqs, KvMode::Paged, "pool:nth=1:every=1:count=*");
    assert_eq!(out.done.len(), reqs.len());
    assert!(out.done.iter().all(|c| c.stop == StopReason::Error));
    assert!(out.done.iter().all(|c| c.tokens.is_empty()));
    assert_eq!(out.rejected as usize, reqs.len());
    assert_eq!(out.error_drains, 0);
    assert_no_leak(&out, m.page_n);
}

// ------------------------------------------------- cancellation harness

#[derive(Default)]
struct Observed {
    done: Option<Completion>,
    fail: Option<ServeFail>,
}

/// Sink that records the terminal event and optionally flips a
/// [`CancelToken`] after `cancel_after` delivered tokens — cancellation
/// originating mid-decode, exactly like a disconnecting HTTP client.
struct ChaosSink {
    obs: Rc<RefCell<Observed>>,
    cancel_after: Option<(CancelToken, usize)>,
    n: usize,
}

impl RequestSink for ChaosSink {
    fn on_token(&mut self, _tok: i32) {
        self.n += 1;
        if let Some((c, after)) = &self.cancel_after {
            if self.n >= *after {
                c.cancel();
            }
        }
    }
    fn on_done(&mut self, c: &Completion) {
        self.obs.borrow_mut().done = Some(c.clone());
    }
    fn on_fail(&mut self, f: &ServeFail) {
        self.obs.borrow_mut().fail = Some(f.clone());
    }
}

struct VecSrc {
    feeds: Vec<(Request, ChaosSink)>,
}

impl RequestSource for VecSrc {
    fn poll(&mut self, _idle: bool) -> Feed {
        match self.feeds.pop() {
            Some((req, sink)) => Feed::Admit(req, Box::new(sink)),
            None => Feed::Closed,
        }
    }
}

#[test]
fn mid_decode_cancellation_drains_one_row_and_spares_the_rest() {
    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    if m.batch < 2 {
        return; // the test needs a concurrent neighbor
    }
    let params = ModelParams::init(&m, &mut Rng::new(9));
    let tok = make_tok(&rt);
    let victim_req = Request::greedy(generate::encode_prompt(&tok, "what is 12 plus 10 ?"), 8);
    let neighbor_req =
        Request::greedy(generate::encode_prompt(&tok, "name the capital of japan ."), 5);

    // solo fault-free baselines for both prompts
    rt.set_fault_plan("").unwrap();
    let base_victim =
        serve_with_plan(&rt, &params, std::slice::from_ref(&victim_req), KvMode::Packed, "");
    let base_neighbor =
        serve_with_plan(&rt, &params, std::slice::from_ref(&neighbor_req), KvMode::Packed, "");

    let token = CancelToken::new();
    let mut victim = victim_req.clone();
    victim.cancel = Some(token.clone());
    let mut pre_cancelled = neighbor_req.clone();
    let dead = CancelToken::new();
    dead.cancel();
    pre_cancelled.cancel = Some(dead);

    let obs_victim = Rc::new(RefCell::new(Observed::default()));
    let obs_neighbor = Rc::new(RefCell::new(Observed::default()));
    let obs_pre = Rc::new(RefCell::new(Observed::default()));
    // popped back-to-front: victim admits first, then the neighbor, then
    // the request that was cancelled before it ever reached a row
    let mut src = VecSrc {
        feeds: vec![
            (pre_cancelled, ChaosSink { obs: obs_pre.clone(), cancel_after: None, n: 0 }),
            (neighbor_req, ChaosSink { obs: obs_neighbor.clone(), cancel_after: None, n: 0 }),
            (
                victim,
                ChaosSink { obs: obs_victim.clone(), cancel_after: Some((token.clone(), 2)), n: 0 },
            ),
        ],
    };

    let mut eng = Engine::new(&rt);
    let mut sess = ServeSession::with_mode(&mut eng, &params, KvMode::Packed).unwrap();
    sess.run_loop(&mut src, -1, PAD).unwrap();
    assert_eq!(sess.cancelled, 2, "the mid-decode victim and the pre-cancelled request");

    let v = obs_victim.borrow();
    let fail = v.fail.as_ref().expect("the victim fails, it does not complete");
    assert!(v.done.is_none());
    assert_eq!(fail.class, FailClass::Cancelled);
    assert_eq!(fail.stop_reason(), StopReason::Cancelled);
    assert!(
        fail.tokens.len() >= 2 && fail.tokens.len() < 8,
        "cancellation lands between steps: {} tokens",
        fail.tokens.len()
    );
    // everything delivered before the cancel is the greedy prefix
    assert_eq!(&fail.tokens[..], &base_victim.done[0].tokens[..fail.tokens.len()]);

    let p = obs_pre.borrow();
    let pre_fail = p.fail.as_ref().expect("pre-cancelled requests fail at admission");
    assert_eq!(pre_fail.class, FailClass::Cancelled);
    assert!(pre_fail.tokens.is_empty());

    let n = obs_neighbor.borrow();
    let done = n.done.as_ref().expect("the neighbor must be untouched");
    assert!(n.fail.is_none());
    assert_eq!(done.tokens, base_neighbor.done[0].tokens, "neighbor diverged after the cancel");
    assert_eq!(done.stop, StopReason::MaxNew);
}
