//! Resume-conformance suite: for **every registered strategy**,
//! interrupted-and-resumed training must reproduce the uninterrupted run
//! exactly — same loss curve, same weight norms, same final parameters,
//! bit for bit. Two tiers:
//!
//! * engine-free: the sampler/optimizer state protocol replays mask
//!   streams identically after a save/load round-trip (synthetic
//!   manifest, no artifacts needed — always runs);
//! * engine-backed: full differential training runs on the tiny config
//!   (skipped gracefully when `artifacts/tiny/manifest.json` is absent,
//!   like `it_train.rs`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use lisa::data::{corpus, encode_sft, DataLoader, Tokenizer};
use lisa::engine::QuantMode;
use lisa::model::checkpoint::Section;
use lisa::model::ModelParams;
use lisa::runtime::{Manifest, Runtime};
use lisa::strategy::{self, StrategySpec};
use lisa::train::{TrainConfig, TrainSession};

// ---------------------------------------------------------------------------
// Shared fixtures
// ---------------------------------------------------------------------------

const N_LAYERS: usize = 8;

/// Synthetic manifest (same shape as `it_strategy.rs`): everything
/// strategy construction needs, no artifacts.
fn synth_manifest() -> Manifest {
    let d = 8usize;
    let h = 4 * d;
    let r = 2usize;
    let block_params: Vec<(String, Vec<usize>)> = vec![
        ("g1".into(), vec![d]),
        ("wq".into(), vec![d, d]),
        ("wk".into(), vec![d, d]),
        ("wv".into(), vec![d, d]),
        ("wo".into(), vec![d, d]),
        ("g2".into(), vec![d]),
        ("w1".into(), vec![d, h]),
        ("w2".into(), vec![h, d]),
    ];
    let lora_params: Vec<(String, Vec<usize>)> = vec![
        ("aq".into(), vec![d, r]),
        ("bq".into(), vec![r, d]),
        ("ak".into(), vec![d, r]),
        ("bk".into(), vec![r, d]),
        ("av".into(), vec![d, r]),
        ("bv".into(), vec![r, d]),
        ("ao".into(), vec![d, r]),
        ("bo".into(), vec![r, d]),
        ("a1".into(), vec![d, r]),
        ("b1".into(), vec![r, h]),
        ("a2".into(), vec![h, r]),
        ("b2".into(), vec![r, d]),
    ];
    Manifest {
        dir: PathBuf::new(),
        name: "synthetic".into(),
        d_model: d,
        n_layers: N_LAYERS,
        n_heads: 2,
        vocab: 32,
        seq: 4,
        batch: 2,
        mlp_ratio: 4,
        lora_rank: r,
        lora_alpha: 4.0,
        n_params: 0,
        block_params,
        lora_params,
        decode_abi: 0,
        segments: BTreeMap::new(),
    }
}

/// Every registered strategy with explicit sampler options.
fn all_specs() -> Vec<StrategySpec> {
    strategy::registry()
        .iter()
        .map(|r| {
            StrategySpec::new(r.name)
                .with("gamma", 3usize)
                .with("period", 4usize)
                .with("rank", 4usize)
                .with("update-proj-gap", 4usize)
        })
        .collect()
}

fn tdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lisa_resume_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ---------------------------------------------------------------------------
// Tier 1: engine-free mask-stream conformance (always runs)
// ---------------------------------------------------------------------------

#[test]
fn every_strategy_mask_stream_survives_state_roundtrip() {
    let m = synth_manifest();
    let cfg = TrainConfig { seed: 17, ..Default::default() };
    let params = ModelParams::init(&m, &mut lisa::util::rng::Rng::new(1));
    for spec in all_specs() {
        let mut full = spec.build(&m, &cfg).unwrap();
        let mut part1 = spec.build(&m, &cfg).unwrap();
        // interrupt at a non-boundary step so the live layer set matters
        let k = 13usize;
        for step in 0..k {
            assert_eq!(
                full.mask_for_step(step),
                part1.mask_for_step(step),
                "'{}' twins diverged before the interrupt",
                spec.name
            );
        }
        let mut sec = Section::new("strategy");
        part1.save_state(&mut sec).unwrap();
        let mut part2 = spec.build(&m, &cfg).unwrap();
        part2.load_state(&mut sec, &params).unwrap();
        assert!(
            sec.is_empty(),
            "'{}' left {} unconsumed state entries: {:?}",
            spec.name,
            sec.len(),
            sec.keys()
        );
        for step in k..45 {
            assert_eq!(
                full.mask_for_step(step),
                part2.mask_for_step(step),
                "'{}' resumed mask diverged at step {step}",
                spec.name
            );
        }
    }
}

#[test]
fn state_roundtrip_through_a_real_file() {
    // Same conformance but through save_sections/load_sections, so the
    // serialization layer (CRC, dtypes, atomic write) is in the loop.
    let m = synth_manifest();
    let cfg = TrainConfig { seed: 23, ..Default::default() };
    let params = ModelParams::init(&m, &mut lisa::util::rng::Rng::new(2));
    let dir = tdir("file");
    for spec in all_specs() {
        let path = dir.join(format!("{}.state", spec.name));
        let mut full = spec.build(&m, &cfg).unwrap();
        let mut part1 = spec.build(&m, &cfg).unwrap();
        for step in 0..9 {
            full.mask_for_step(step);
            part1.mask_for_step(step);
        }
        let mut sec = Section::new("strategy");
        part1.save_state(&mut sec).unwrap();
        lisa::model::checkpoint::save_sections(&path, &[sec]).unwrap();

        let mut sections = lisa::model::checkpoint::load_sections(&path).unwrap();
        let mut sec = lisa::model::checkpoint::take_section(&mut sections, "strategy").unwrap();
        let mut part2 = spec.build(&m, &cfg).unwrap();
        part2.load_state(&mut sec, &params).unwrap();
        assert!(sec.is_empty(), "'{}' leftovers after file roundtrip", spec.name);
        for step in 9..40 {
            assert_eq!(
                full.mask_for_step(step),
                part2.mask_for_step(step),
                "'{}' file-roundtrip mask diverged at step {step}",
                spec.name
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Tier 2: engine-backed differential runs (need AOT artifacts)
// ---------------------------------------------------------------------------

const STEPS: usize = 12;
const K: usize = 5; // interrupt after 5 optimizer steps (mid-period for K=3)

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

fn have() -> bool {
    artifacts().join("manifest.json").exists()
}

/// Specs for the engine runs: tiny has few layers, so γ=2, K=3; GaLore
/// gets a refresh gap the continuation crosses.
fn engine_specs() -> Vec<StrategySpec> {
    vec![
        StrategySpec::vanilla(),
        StrategySpec::ft(),
        StrategySpec::lisa(2, 3),
        StrategySpec::lisa_fixed(2, 3),
        StrategySpec::lisa_grad(2, 3),
        StrategySpec::lora(),
        StrategySpec::galore(4).with("update-proj-gap", 4),
    ]
}

fn make_loader(rt: &Runtime) -> DataLoader {
    let m = &rt.manifest;
    let samples = corpus::gen_instruction_corpus(96, 11);
    let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);
    let enc: Vec<_> = samples.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    DataLoader::new(enc, m.batch, m.seq, 5)
}

fn cfg() -> TrainConfig {
    TrainConfig {
        steps: STEPS,
        lr: 3e-3,
        warmup: 3,
        log_every: 0,
        ..Default::default()
    }
}

struct RunOut {
    losses: Vec<f32>,
    params: Vec<(String, Vec<f32>)>,
    eval_params: Vec<(String, Vec<f32>)>,
    norms: Vec<f64>,
    /// Whole-run engine observables (peak bytes, bwd full/x/skipped) —
    /// checkpointed, so a resumed run must report the same totals.
    peak_mem: u64,
    bwd: (u64, u64, u64),
}

fn snapshot(p: &ModelParams) -> Vec<(String, Vec<f32>)> {
    p.iter().map(|(k, t)| (k.name(), t.data.clone())).collect()
}

fn finish(sess: &TrainSession, losses: Vec<f32>) -> RunOut {
    RunOut {
        losses,
        params: snapshot(&sess.params),
        eval_params: snapshot(&sess.eval_params()),
        norms: sess.effective_weight_norms(),
        peak_mem: sess.engine.meter.peak(),
        bwd: (
            sess.engine.bwd_full_calls,
            sess.engine.bwd_x_calls,
            sess.engine.bwd_skipped,
        ),
    }
}

fn run_uninterrupted(spec: &StrategySpec) -> RunOut {
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let mut dl = make_loader(&rt);
    let mut sess = TrainSession::new(&rt, spec, cfg()).unwrap();
    let res = sess.run(&mut dl).unwrap();
    let losses = res.loss_curve.iter().map(|&(_, l)| l).collect();
    finish(&sess, losses)
}

/// Train K steps, save the full training state, tear everything down,
/// rebuild from scratch, resume, train the remaining steps.
fn run_interrupted(spec: &StrategySpec, path: &Path) -> RunOut {
    let mut losses = Vec::new();
    {
        let rt = Runtime::load(&artifacts(), "pallas").unwrap();
        let mut dl = make_loader(&rt);
        let mut sess = TrainSession::new(&rt, spec, cfg()).unwrap();
        for step in 0..K {
            losses.push(sess.step(step, &mut dl).unwrap());
        }
        sess.save_checkpoint(path, K, &dl).unwrap();
    } // the "crash": runtime, session and loader all dropped

    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let mut dl = make_loader(&rt);
    let mut sess = TrainSession::new(&rt, spec, cfg()).unwrap();
    let res = sess.run_resumable(&mut dl, None, Some(path)).unwrap();
    assert_eq!(res.loss_curve.first().map(|&(s, _)| s), Some(K), "resume step offset");
    losses.extend(res.loss_curve.iter().map(|&(_, l)| l));
    finish(&sess, losses)
}

fn assert_params_eq(a: &[(String, Vec<f32>)], b: &[(String, Vec<f32>)], what: &str, arm: &str) {
    assert_eq!(a.len(), b.len(), "[{arm}] {what}: tensor count");
    for ((na, da), (nb, db)) in a.iter().zip(b) {
        assert_eq!(na, nb, "[{arm}] {what}: tensor order");
        assert_eq!(da.len(), db.len(), "[{arm}] {what}: '{na}' length");
        let identical = da
            .iter()
            .zip(db)
            .all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(
            identical,
            "[{arm}] {what}: tensor '{na}' differs after resume (bit-for-bit required)"
        );
    }
}

#[test]
fn resume_equals_uninterrupted_for_every_strategy() {
    if !have() {
        return;
    }
    let dir = tdir("diff");
    for spec in engine_specs() {
        let arm = spec.name.clone();
        let path = dir.join(format!("{arm}.state"));
        let full = run_uninterrupted(&spec);
        let resumed = run_interrupted(&spec, &path);
        assert_eq!(
            full.losses.len(),
            resumed.losses.len(),
            "[{arm}] loss curve length"
        );
        for (i, (a, b)) in full.losses.iter().zip(&resumed.losses).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "[{arm}] loss diverged at step {i}: {a} vs {b}"
            );
        }
        assert_params_eq(&full.params, &resumed.params, "base params", &arm);
        assert_params_eq(&full.eval_params, &resumed.eval_params, "eval params", &arm);
        assert_eq!(full.norms, resumed.norms, "[{arm}] weight norms");
        assert_eq!(full.peak_mem, resumed.peak_mem, "[{arm}] peak memory");
        assert_eq!(full.bwd, resumed.bwd, "[{arm}] backward-call counters");
    }
}

#[test]
fn resume_rejects_method_and_seed_mismatch() {
    if !have() {
        return;
    }
    let dir = tdir("mismatch");
    let path = dir.join("lisa.state");
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let mut dl = make_loader(&rt);
    let spec = StrategySpec::lisa(2, 3);
    let mut sess = TrainSession::new(&rt, &spec, cfg()).unwrap();
    for step in 0..2 {
        sess.step(step, &mut dl).unwrap();
    }
    sess.save_checkpoint(&path, 2, &dl).unwrap();

    // different method
    let mut other = TrainSession::new(&rt, &StrategySpec::ft(), cfg()).unwrap();
    let err = other.resume_checkpoint(&path, &mut dl).unwrap_err();
    assert!(format!("{err:#}").contains("method"), "got: {err:#}");

    // different seed
    let mut wrong_seed =
        TrainSession::new(&rt, &spec, TrainConfig { seed: 99, ..cfg() }).unwrap();
    let err = wrong_seed.resume_checkpoint(&path, &mut dl).unwrap_err();
    assert!(format!("{err:#}").contains("seed"), "got: {err:#}");
}

#[test]
fn kill_during_save_preserves_resumable_checkpoint() {
    if !have() {
        return;
    }
    let dir = tdir("kill");
    let path = dir.join("train.state");
    let spec = StrategySpec::lisa(2, 3);
    let full = run_uninterrupted(&spec);

    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let mut dl = make_loader(&rt);
    let mut sess = TrainSession::new(&rt, &spec, cfg()).unwrap();
    let mut losses = Vec::new();
    for step in 0..K {
        losses.push(sess.step(step, &mut dl).unwrap());
    }
    sess.save_checkpoint(&path, K, &dl).unwrap();

    // a later save is killed mid-write: a directory squatting on the tmp
    // path makes the write fail exactly like a dead writer would
    sess.step(K, &mut dl).unwrap();
    let tmp = path.with_file_name("train.state.tmp");
    std::fs::create_dir_all(&tmp).unwrap();
    assert!(sess.save_checkpoint(&path, K + 1, &dl).is_err());
    std::fs::remove_dir_all(&tmp).unwrap();

    // the previous checkpoint is untouched and resumes to the exact
    // uninterrupted trajectory
    let rt2 = Runtime::load(&artifacts(), "pallas").unwrap();
    let mut dl2 = make_loader(&rt2);
    let mut sess2 = TrainSession::new(&rt2, &spec, cfg()).unwrap();
    let res = sess2.run_resumable(&mut dl2, None, Some(&path)).unwrap();
    let mut resumed_losses = losses;
    resumed_losses.truncate(K);
    resumed_losses.extend(res.loss_curve.iter().map(|&(_, l)| l));
    assert_eq!(
        full.losses.len(),
        resumed_losses.len(),
        "loss curve length after interrupted save"
    );
    for (i, (a, b)) in full.losses.iter().zip(&resumed_losses).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "loss diverged at step {i}");
    }
    assert_params_eq(&full.params, &snapshot(&sess2.params), "base params", "lisa-kill");
}

// ---------------------------------------------------------------------------
// Quantized-base runs (ISSUE 10): checkpoints are ALWAYS f32
// ---------------------------------------------------------------------------

/// Artifacts present *and* stamped with the q8 segment set.
fn have_quant() -> bool {
    have()
        && Runtime::load(&artifacts(), "pallas")
            .map(|rt| rt.manifest.supports_quant("pallas"))
            .unwrap_or(false)
}

/// `run_uninterrupted` with `--quant int8` switched on for the session.
fn run_uninterrupted_q8(spec: &StrategySpec) -> RunOut {
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let mut dl = make_loader(&rt);
    let mut sess = TrainSession::new(&rt, spec, cfg()).unwrap();
    sess.engine.set_quant(QuantMode::Int8);
    let res = sess.run(&mut dl).unwrap();
    let losses = res.loss_curve.iter().map(|&(_, l)| l).collect();
    finish(&sess, losses)
}

// Quantization is a device-residency format, not a storage format
// (DESIGN.md §15): a `--quant int8` run trains on f32 masters, so an
// interrupted q8 run must resume bit-identical to the uninterrupted q8
// run — the checkpoint round-trip crosses the qhost/device-cache
// teardown and must not leak quantized state into it.
#[test]
fn quantized_run_resumes_bit_identical() {
    if !have_quant() {
        return;
    }
    let dir = tdir("quant-diff");
    let spec = StrategySpec::lisa(2, 3);
    let path = dir.join("lisa-q8.state");

    let full = run_uninterrupted_q8(&spec);

    // interrupted twin: K q8 steps, save, tear down, rebuild, resume q8
    let mut losses = Vec::new();
    {
        let rt = Runtime::load(&artifacts(), "pallas").unwrap();
        let mut dl = make_loader(&rt);
        let mut sess = TrainSession::new(&rt, &spec, cfg()).unwrap();
        sess.engine.set_quant(QuantMode::Int8);
        for step in 0..K {
            losses.push(sess.step(step, &mut dl).unwrap());
        }
        sess.save_checkpoint(&path, K, &dl).unwrap();
    }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let mut dl = make_loader(&rt);
    let mut sess = TrainSession::new(&rt, &spec, cfg()).unwrap();
    sess.engine.set_quant(QuantMode::Int8);
    let res = sess.run_resumable(&mut dl, None, Some(&path)).unwrap();
    assert_eq!(res.loss_curve.first().map(|&(s, _)| s), Some(K), "resume step offset");
    losses.extend(res.loss_curve.iter().map(|&(_, l)| l));
    let resumed = finish(&sess, losses);

    assert_eq!(full.losses.len(), resumed.losses.len(), "[q8] loss curve length");
    for (i, (a, b)) in full.losses.iter().zip(&resumed.losses).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "[q8] loss diverged at step {i}: {a} vs {b}");
    }
    assert_params_eq(&full.params, &resumed.params, "base params", "lisa-q8");
    assert_params_eq(&full.eval_params, &resumed.eval_params, "eval params", "lisa-q8");
    assert_eq!(full.bwd, resumed.bwd, "[q8] backward-call counters");
}

// The storage-format half of the rule: a checkpoint written by a
// `--quant int8` session contains exactly the f32 masters — an
// *unquantized* session resumes it cleanly and holds bit-identical
// parameters to what the quantized session held at save time.
#[test]
fn quantized_checkpoint_is_f32_and_loads_into_unquantized_session() {
    if !have_quant() {
        return;
    }
    let dir = tdir("quant-f32");
    let spec = StrategySpec::lisa(2, 3);
    let path = dir.join("lisa-q8-to-f32.state");

    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let mut dl = make_loader(&rt);
    let saved_at = {
        let mut sess = TrainSession::new(&rt, &spec, cfg()).unwrap();
        sess.engine.set_quant(QuantMode::Int8);
        for step in 0..K {
            sess.step(step, &mut dl).unwrap();
        }
        sess.save_checkpoint(&path, K, &dl).unwrap();
        snapshot(&sess.params)
    };

    // a pure-f32 session resumes the quantized run's checkpoint
    let mut dl2 = make_loader(&rt);
    let mut f32_sess = TrainSession::new(&rt, &spec, cfg()).unwrap();
    assert_eq!(f32_sess.engine.quant(), QuantMode::Off);
    f32_sess.resume_checkpoint(&path, &mut dl2).unwrap();
    assert_params_eq(
        &saved_at,
        &snapshot(&f32_sess.params),
        "q8-written checkpoint into f32 session",
        "lisa-q8-f32",
    );
}
