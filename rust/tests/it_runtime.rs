//! Integration: load tiny artifacts, execute segments, cross-check the
//! pallas and jnp backends against each other (the two lowering paths must
//! agree bit-for-bit-ish on CPU f32).

use std::path::Path;

use lisa::runtime::{HostTensor, HostTensorI32, Operand, Runtime};
use lisa::util::rng::Rng;
use lisa::util::stats::allclose;

fn artifacts() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").leak()
}

trait Leak {
    fn leak(self) -> &'static Path;
}
impl Leak for std::path::PathBuf {
    fn leak(self) -> &'static Path {
        Box::leak(self.into_boxed_path())
    }
}

fn have_tiny() -> bool {
    artifacts().join("tiny/manifest.json").exists()
}

#[test]
fn block_fwd_backends_agree() {
    if !have_tiny() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt_p = Runtime::load(&artifacts().join("tiny"), "pallas").unwrap();
    let rt_j = Runtime::load(&artifacts().join("tiny"), "jnp").unwrap();
    let m = &rt_p.manifest;
    let mut rng = Rng::new(7);

    let mut h = HostTensor::zeros(&[m.batch, m.seq, m.d_model]);
    rng.fill_normal(&mut h.data, 1.0);
    let mut params = Vec::new();
    for (_, shape) in &m.block_params {
        let mut t = HostTensor::zeros(shape);
        rng.fill_normal(&mut t.data, 0.05);
        params.push(t);
    }
    let mut ops = vec![Operand::F32(&h)];
    ops.extend(params.iter().map(Operand::F32));

    let out_p = rt_p.run("block_fwd", &ops).unwrap();
    let out_j = rt_j.run("block_fwd", &ops).unwrap();
    let a = HostTensor::from_literal(&out_p[0], &[m.batch, m.seq, m.d_model]).unwrap();
    let b = HostTensor::from_literal(&out_j[0], &[m.batch, m.seq, m.d_model]).unwrap();
    assert!(
        allclose(&a.data, &b.data, 1e-4, 1e-5),
        "pallas vs jnp block_fwd diverge"
    );
    assert!(a.data.iter().all(|x| x.is_finite()));
}

#[test]
fn full_forward_loss_is_finite_and_backends_agree() {
    if !have_tiny() {
        return;
    }
    let rt_p = Runtime::load(&artifacts().join("tiny"), "pallas").unwrap();
    let rt_j = Runtime::load(&artifacts().join("tiny"), "jnp").unwrap();
    let m = rt_p.manifest.clone();
    let mut rng = Rng::new(3);

    let tokens = HostTensorI32::from_vec(
        &[m.batch, m.seq],
        (0..m.batch * m.seq).map(|_| rng.below(m.vocab) as i32).collect(),
    );
    let mut emb = HostTensor::zeros(&[m.vocab, m.d_model]);
    let mut pos = HostTensor::zeros(&[m.seq, m.d_model]);
    rng.fill_normal(&mut emb.data, 0.02);
    rng.fill_normal(&mut pos.data, 0.02);

    let losses: Vec<f32> = [&rt_p, &rt_j]
        .iter()
        .map(|rt| {
            let outs = rt
                .run("embed_fwd", &[Operand::I32(&tokens), Operand::F32(&emb), Operand::F32(&pos)])
                .unwrap();
            let h = HostTensor::from_literal(&outs[0], &[m.batch, m.seq, m.d_model]).unwrap();
            let mut gf = HostTensor::zeros(&[m.d_model]);
            gf.fill(1.0);
            let mut wh = HostTensor::zeros(&[m.d_model, m.vocab]);
            let mut r2 = Rng::new(5);
            r2.fill_normal(&mut wh.data, 0.02);
            let outs = rt
                .run(
                    "head_loss",
                    &[Operand::F32(&h), Operand::F32(&gf), Operand::F32(&wh), Operand::I32(&tokens)],
                )
                .unwrap();
            HostTensor::scalar_from_literal(&outs[0]).unwrap()
        })
        .collect();

    assert!(losses[0].is_finite());
    // random init ⇒ loss ≈ ln(vocab)
    let expect = (m.vocab as f32).ln();
    assert!(
        (losses[0] - expect).abs() < 1.0,
        "loss {} far from ln(V)={}",
        losses[0],
        expect
    );
    assert!((losses[0] - losses[1]).abs() < 1e-4);
}
