//! HTTP serving conformance (DESIGN.md §11), in two tiers:
//!
//! * a **stub tier** that always runs: the real `HttpFrontend` (sockets,
//!   workers, bounded admission, SSE streaming, metrics, shutdown) over
//!   a scripted model loop, so threading and protocol behaviour are
//!   exercised with no artifacts and controllable timing — including a
//!   deterministic 429 overflow;
//! * an **artifact tier** (gated like `it_serve.rs`): the full stack —
//!   HTTP → `ChannelSource` → `ServeSession::run_loop` → KV-cached
//!   decode — asserting that served completions are token-identical to
//!   solo `ServeSession` runs at the same `(prompt, spec, seed)`,
//!   streamed == non-streamed, stop sequences and logit bias apply end
//!   to end, queue overflow answers 429 without disturbing in-flight
//!   rows, and the burst leaves non-zero TTFT / throughput histograms
//!   and `ExecStats` in `/metrics`.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use lisa::data::tokenizer::{EOS, PAD};
use lisa::data::{corpus, Tokenizer};
use lisa::engine::{
    Completion, Engine, FailClass, Feed, Request, RequestSource, SamplerSpec, ServeFail,
    ServeSession, StopReason,
};
use lisa::eval::generate;
use lisa::model::ModelParams;
use lisa::runtime::Runtime;
use lisa::serve_http::proto::{self, client};
use lisa::serve_http::{ChannelSource, HttpFrontend, ServeConfig, ServerState};
use lisa::util::json::Json;
use lisa::util::rng::Rng;

fn make_tok(vocab: usize) -> Tokenizer {
    let samples = corpus::gen_instruction_corpus(64, 11);
    Tokenizer::build(&corpus::sample_texts(&samples), vocab)
}

// ---------------------------------------------------------------- stub tier

/// Scripted model loop: serves one admission at a time, synchronously.
/// Tokens are a pure function of the prompt (`5 + (sum + i) % 13`), and
/// `req.seed` doubles as a per-token delay in ms so tests can hold the
/// loop busy for a known window. Mirrors the real serve loop's
/// cancellation contract: `req.cancel` is observed between tokens and a
/// flipped token drains the request through `on_fail`. Ends on
/// `Feed::Closed` (shutdown).
fn stub_loop(src: &mut ChannelSource) {
    loop {
        match src.poll(true) {
            Feed::Admit(req, mut sink) => {
                let delay = Duration::from_millis(req.seed.min(60));
                let base: i64 = req.prompt.iter().map(|&t| t as i64).sum();
                let mut tokens = Vec::with_capacity(req.max_new);
                let mut cancelled = false;
                for i in 0..req.max_new {
                    if req.cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
                        cancelled = true;
                        break;
                    }
                    thread::sleep(delay);
                    let t = 5 + ((base as usize + i) % 13) as i32;
                    sink.on_token(t);
                    tokens.push(t);
                }
                if cancelled {
                    sink.on_fail(&ServeFail {
                        tokens,
                        ..ServeFail::new(FailClass::Cancelled, "request cancelled")
                    });
                } else {
                    sink.on_done(&Completion {
                        tokens,
                        prompt_truncated: false,
                        stop: StopReason::MaxNew,
                    });
                }
            }
            Feed::Pending => {}
            Feed::Closed => return,
        }
    }
}

/// Bind on an ephemeral port, run `stub_loop` on a server thread, hand
/// the test `(addr, state, join-handle)`.
fn start_stub(
    cfg: ServeConfig,
) -> (String, Arc<ServerState>, thread::JoinHandle<()>) {
    let tok = make_tok(64);
    let front = HttpFrontend::bind(ServeConfig { addr: "127.0.0.1:0".into(), ..cfg }, tok)
        .expect("bind ephemeral");
    let addr = front.local_addr().unwrap().to_string();
    let state = front.state();
    let h = thread::spawn(move || front.run(stub_loop));
    (addr, state, h)
}

fn post_tokens(addr: &str, body: &str) -> (u16, Vec<i32>) {
    let resp = client::post(addr, "/v1/completions", body).unwrap();
    if resp.status != 200 {
        return (resp.status, Vec::new());
    }
    let toks = resp
        .json()
        .unwrap()
        .get("tokens")
        .and_then(|t| t.as_arr().map(|a| a.iter().map(|x| x.as_f64().unwrap() as i32).collect()))
        .unwrap();
    (200, toks)
}

/// Streamed request: per-token SSE frames plus the final done frame.
fn post_stream_tokens(addr: &str, body: &str) -> (Vec<i32>, Vec<i32>, String) {
    let resp = client::post(addr, "/v1/completions", body).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(resp.header("Content-Type"), Some("text/event-stream"));
    let frames = resp.sse_frames().unwrap();
    let (done, toks): (Vec<&Json>, Vec<&Json>) =
        frames.iter().partition(|f| f.get("done").is_some());
    assert_eq!(done.len(), 1, "exactly one done frame");
    let streamed = toks
        .iter()
        .map(|f| f.get("token").unwrap().as_f64().unwrap() as i32)
        .collect();
    let final_tokens = done[0]
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as i32)
        .collect();
    let reason = done[0].get("finish_reason").unwrap().as_str().unwrap().to_string();
    (streamed, final_tokens, reason)
}

#[test]
fn stream_and_nonstream_agree_over_real_sockets() {
    let (addr, state, h) = start_stub(ServeConfig::default());
    let body = r#"{"tokens": [2, 4, 6], "max_new": 5, "seed": 0}"#;
    let (code, plain) = post_tokens(&addr, body);
    assert_eq!(code, 200);
    assert_eq!(plain.len(), 5);

    let body = r#"{"tokens": [2, 4, 6], "max_new": 5, "seed": 0, "stream": true}"#;
    let (streamed, final_tokens, reason) = post_stream_tokens(&addr, body);
    assert_eq!(streamed, plain, "SSE token frames vs JSON body");
    assert_eq!(final_tokens, plain, "done-frame tokens vs JSON body");
    assert_eq!(reason, "max_new");

    state.request_shutdown();
    h.join().unwrap();
}

#[test]
fn text_prompts_resolve_through_the_server_tokenizer() {
    let (addr, state, h) = start_stub(ServeConfig::default());
    // same text must map to the same token trajectory on repeat
    let body = r#"{"prompt": "what is 3 times 4 ?", "max_new": 4, "seed": 0}"#;
    let (c1, t1) = post_tokens(&addr, body);
    let (c2, t2) = post_tokens(&addr, body);
    assert_eq!((c1, c2), (200, 200));
    assert_eq!(t1, t2);
    assert_eq!(t1.len(), 4);
    state.request_shutdown();
    h.join().unwrap();
}

#[test]
fn queue_overflow_answers_429_and_spares_in_flight_requests() {
    let (addr, state, h) = start_stub(ServeConfig {
        max_queue: 1,
        workers: 4,
        ..ServeConfig::default()
    });
    // hold the loop busy ~400 ms: 8 tokens at 50 ms each, streamed
    let slow = addr.clone();
    let slow_h = thread::spawn(move || {
        post_stream_tokens(
            &slow,
            r#"{"tokens": [1, 2], "max_new": 8, "seed": 50, "stream": true}"#,
        )
    });
    thread::sleep(Duration::from_millis(120)); // slow request is admitted

    // burst of 4 fast requests against a busy loop and a 1-deep queue:
    // one queues, the rest must bounce with 429 + Retry-After
    let mut joins = Vec::new();
    for _ in 0..4 {
        let a = addr.clone();
        joins.push(thread::spawn(move || {
            client::post(&a, "/v1/completions", r#"{"tokens": [9], "max_new": 2, "seed": 0}"#)
                .unwrap()
        }));
    }
    let responses: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let rejected: Vec<_> = responses.iter().filter(|r| r.status == 429).collect();
    let served: Vec<_> = responses.iter().filter(|r| r.status == 200).collect();
    assert_eq!(rejected.len(), 3, "queue bound 1 must bounce 3 of 4 burst requests");
    assert_eq!(served.len(), 1);
    for r in &rejected {
        assert_eq!(r.header("Retry-After"), Some("1"), "{}", r.head);
    }

    // the in-flight slow request was not disturbed by the overflow
    let (streamed, final_tokens, _) = slow_h.join().unwrap();
    assert_eq!(streamed.len(), 8);
    assert_eq!(streamed, final_tokens);

    // metrics saw it all
    let metrics = client::get(&addr, "/metrics").unwrap();
    assert_eq!(metrics.status, 200);
    assert!(metrics.body.contains("lisa_http_requests_total{code=\"429\"} 3"), "{}", metrics.body);
    assert_eq!(state.metrics.status_count(429), 3);
    assert_eq!(state.metrics.completions(), 2); // slow + the queued one
    assert!(state.metrics.ttft.count() >= 2);
    assert!(state.metrics.tok_rate.count() >= 2);

    state.request_shutdown();
    h.join().unwrap();
}

#[test]
fn shutdown_drains_the_in_flight_request_before_exiting() {
    let (addr, state, h) = start_stub(ServeConfig::default());
    let slow = addr.clone();
    let slow_h = thread::spawn(move || {
        post_stream_tokens(
            &slow,
            r#"{"tokens": [3], "max_new": 6, "seed": 40, "stream": true}"#,
        )
    });
    thread::sleep(Duration::from_millis(100)); // admitted and generating
    state.request_shutdown();
    // the client still receives the complete stream
    let (streamed, final_tokens, reason) = slow_h.join().unwrap();
    assert_eq!(streamed.len(), 6);
    assert_eq!(streamed, final_tokens);
    assert_eq!(reason, "max_new");
    // and the server actually exits (workers joined, loop returned)
    h.join().unwrap();
    assert!(client::get(&addr, "/healthz").is_err(), "listener must be closed");
}

#[test]
fn health_metrics_and_error_paths_speak_http() {
    let (addr, state, h) = start_stub(ServeConfig::default());

    let health = client::get(&addr, "/healthz").unwrap();
    assert_eq!(health.status, 200);
    assert_eq!(health.json().unwrap().get("status").unwrap().as_str(), Some("ok"));

    let m = client::get(&addr, "/metrics").unwrap();
    assert_eq!(m.status, 200);
    for series in [
        "lisa_http_requests_total{code=\"200\"}",
        "lisa_http_queue_depth",
        "lisa_serve_ttft_seconds_count",
        "lisa_serve_tokens_per_sec_count",
        "lisa_serve_uptime_seconds",
        "lisa_device_resident_bytes{format=\"f32\"}",
        "lisa_device_resident_bytes{format=\"i8\"}",
    ] {
        assert!(m.body.contains(series), "missing {series} in:\n{}", m.body);
    }

    let bad = client::post(&addr, "/v1/completions", "{not json").unwrap();
    assert_eq!(bad.status, 400);
    assert!(bad.body.contains("JSON"), "{}", bad.body);
    let missing = client::post(&addr, "/v1/completions", r#"{"max_new": 2}"#).unwrap();
    assert_eq!(missing.status, 400);
    let lost = client::get(&addr, "/nope").unwrap();
    assert_eq!(lost.status, 404);
    let method = client::post(&addr, "/metrics", "{}").unwrap();
    assert_eq!(method.status, 404); // POST routes only to /v1/completions

    state.request_shutdown();
    h.join().unwrap();
}

/// Hand-written wire bytes: `client::post` always emits one correct
/// `Content-Length`, so the framing taxonomy below needs raw writes.
/// Requests stop at the blank line (no body bytes) so a rejecting server
/// never leaves unread data behind — the close is a clean FIN, not RST.
fn raw_status(addr: &str, raw: &str) -> (u16, String) {
    use std::io::{Read, Write};
    use std::net::TcpStream;
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(raw.as_bytes()).unwrap();
    s.flush().unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let status = buf
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("no status line in {buf:?}"));
    (status, buf)
}

#[test]
fn content_length_taxonomy_over_real_sockets() {
    let (addr, state, h) = start_stub(ServeConfig::default());

    // non-numeric, signed, spaced, hex: 400 — never leniently parsed
    for bad in ["+2", "-2", "2 2", "0x10", "two"] {
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: lisa\r\nContent-Length: {bad}\r\n\r\n"
        );
        let (code, body) = raw_status(&addr, &raw);
        assert_eq!(code, 400, "Content-Length {bad:?}:\n{body}");
        assert!(body.contains("Content-Length"), "{body}");
    }

    // duplicated Content-Length: 400, even when the copies agree
    for dup in ["2", "3"] {
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: lisa\r\n\
             Content-Length: 2\r\nContent-Length: {dup}\r\n\r\n"
        );
        let (code, body) = raw_status(&addr, &raw);
        assert_eq!(code, 400, "{body}");
        assert!(body.contains("duplicate"), "{body}");
    }

    // over-cap and usize-overflowing lengths: 413 before any buffer is
    // sized — note no body bytes follow, yet the server answers at once
    for big in [format!("{}", proto::MAX_BODY + 1), "9".repeat(24)] {
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: lisa\r\nContent-Length: {big}\r\n\r\n"
        );
        let (code, body) = raw_status(&addr, &raw);
        assert_eq!(code, 413, "Content-Length {big}:\n{body}");
    }

    // every rejection is visible in the status metrics, and the server
    // is still healthy for well-formed traffic afterwards
    assert_eq!(state.metrics.status_count(400), 7);
    assert_eq!(state.metrics.status_count(413), 2);
    let (code, toks) = post_tokens(&addr, r#"{"tokens": [2, 4], "max_new": 3, "seed": 0}"#);
    assert_eq!((code, toks.len()), (200, 3));

    state.request_shutdown();
    h.join().unwrap();
}

#[test]
fn client_disconnect_cancels_the_row_and_counts_in_metrics() {
    let (addr, state, h) = start_stub(ServeConfig { event_buf: 4, ..ServeConfig::default() });

    // a long, slow, streamed request; read the response head plus the
    // first frames, then hang up mid-stream
    {
        use std::io::{Read, Write};
        use std::net::TcpStream;
        let body = r#"{"tokens": [2], "max_new": 50, "seed": 20, "stream": true}"#;
        let raw = format!(
            "POST /v1/completions HTTP/1.1\r\nHost: lisa\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut buf = [0u8; 256];
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "the stream must have started before the disconnect");
    } // drop = disconnect

    // the worker's failed write (or the dead event channel) flips the
    // request's CancelToken; the loop observes it between tokens and
    // drains the row with the cancelled class — poll until it lands
    let t0 = std::time::Instant::now();
    while state.metrics.fail_count(FailClass::Cancelled) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "cancellation never observed");
        thread::sleep(Duration::from_millis(20));
    }

    // the loop survived: the next client is served normally, and the
    // failure shows up under its class in the export
    let (code, toks) = post_tokens(&addr, r#"{"tokens": [2, 4], "max_new": 3, "seed": 0}"#);
    assert_eq!((code, toks.len()), (200, 3));
    let m = client::get(&addr, "/metrics").unwrap().body;
    assert!(m.contains("lisa_serve_failures_total{class=\"cancelled\"} 1"), "{m}");

    state.request_shutdown();
    h.join().unwrap();
}

/// Deterministic fuzz over the wire parser: truncated, byte-mangled and
/// interleaved heads/bodies must always yield a 4xx taxonomy error, a
/// clean drop (`Ok(None)`), or a well-formed request — never a panic,
/// and never a read past the framed body.
#[test]
fn proto_parser_survives_mangled_wire_bytes() {
    use std::io::{BufReader, Read};

    let body: &[u8] = br#"{"tokens": [2, 4, 6], "max_new": 5, "seed": 7, "stream": true}"#;
    let mut wire = format!(
        "POST /v1/completions HTTP/1.1\r\nHost: lisa\r\nContent-Length: {}\r\n\r\n",
        body.len()
    )
    .into_bytes();
    wire.extend_from_slice(body);

    let mut rng = Rng::new(0xFA_0175);
    for case in 0..2000u32 {
        let mut bytes = wire.clone();
        match case % 4 {
            // truncated anywhere: head, header boundary, or mid-body
            0 => bytes.truncate(rng.below(bytes.len())),
            // a single flipped bit
            1 => {
                let i = rng.below(bytes.len());
                bytes[i] ^= 1 << rng.below(8);
            }
            // injected garbage bytes
            2 => {
                let i = rng.below(bytes.len());
                let junk: Vec<u8> =
                    (0..rng.below(7) + 1).map(|_| rng.next_u64() as u8).collect();
                bytes.splice(i..i, junk);
            }
            // a second request spliced into the middle of the first
            _ => {
                let i = rng.below(bytes.len());
                let other = wire.clone();
                bytes.splice(i..i, other);
            }
        }
        let mut r = BufReader::new(&bytes[..]);
        // a mangled stream may still contain several parseable requests;
        // drain it to EOF or the first protocol error
        for _ in 0..100 {
            match proto::read_request(&mut r) {
                Ok(Some(req)) => {
                    assert!(req.body.len() <= proto::MAX_BODY, "case {case} over-read");
                    // the JSON layer must reject or accept, never panic
                    let _ = proto::CompletionReq::parse(&req.body);
                }
                Ok(None) => break,
                Err((code, msg)) => {
                    assert!(
                        (400..500).contains(&code),
                        "case {case}: non-4xx {code} ({msg})"
                    );
                    break;
                }
            }
        }
    }

    // framing is exact: two pipelined requests parse back to back and
    // leave nothing unread behind them
    let mut two = wire.clone();
    two.extend_from_slice(&wire);
    let mut r = BufReader::new(&two[..]);
    let a = proto::read_request(&mut r).unwrap().expect("first pipelined request");
    let b = proto::read_request(&mut r).unwrap().expect("second pipelined request");
    assert_eq!(a.body, body);
    assert_eq!(b.body, body);
    assert!(proto::read_request(&mut r).unwrap().is_none(), "phantom third request");
    let mut rest = Vec::new();
    r.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "parser left {} unread bytes", rest.len());
}

// ------------------------------------------------------------ artifact tier

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

/// Artifacts present *and* exported with the decode ABI.
fn have_decode() -> Option<Runtime> {
    if !artifacts().join("manifest.json").exists() {
        return None;
    }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    rt.manifest.supports_decode("pallas").then_some(rt)
}

const PARAM_SEED: u64 = 3;

/// Start the full stack on an ephemeral port: the server thread owns its
/// own `Runtime`/`Engine` (both are thread-bound) built from the same
/// artifacts and parameter seed the test uses for its solo baselines.
fn start_real(
    cfg: ServeConfig,
) -> (String, Arc<ServerState>, thread::JoinHandle<()>) {
    let vocab = { have_decode().unwrap().manifest.vocab };
    let front = HttpFrontend::bind(
        ServeConfig { addr: "127.0.0.1:0".into(), ..cfg },
        make_tok(vocab),
    )
    .expect("bind ephemeral");
    let addr = front.local_addr().unwrap().to_string();
    let state = front.state();
    let h = thread::spawn(move || {
        let rt = have_decode().expect("artifact presence checked by caller");
        let params = ModelParams::init(&rt.manifest, &mut Rng::new(PARAM_SEED));
        let mut eng = Engine::new(&rt);
        let mut sess = ServeSession::new(&mut eng, &params).unwrap();
        front.run(|src| sess.run_loop(src, EOS, PAD)).unwrap();
    });
    (addr, state, h)
}

fn solo(rt: &Runtime, params: &ModelParams, req: Request) -> Completion {
    let mut eng = Engine::new(rt);
    let mut sess = ServeSession::new(&mut eng, params).unwrap();
    sess.run(&[req], EOS, PAD).unwrap().remove(0)
}

/// `(prompt tokens, spec, seed, max_new)` for a mixed client population:
/// greedy rows run longer, sampled rows keep the short budgets the §9
/// float-parity caveat asks for (see it_serve.rs).
fn mixed_wire_requests(tok: &Tokenizer) -> Vec<(Vec<i32>, SamplerSpec, u64, usize)> {
    let texts = [
        "what is 12 plus 10 ?",
        "name the capital of france .",
        "what is 3 times 4 ?",
        "who built the eiffel tower ?",
        "what is 9 minus 2 ?",
        "name the capital of japan .",
    ];
    let specs = [
        SamplerSpec::Greedy,
        SamplerSpec::Temperature { temperature: 0.8 },
        SamplerSpec::TopK { k: 5, temperature: 1.0 },
    ];
    texts
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let spec = specs[i % specs.len()].clone();
            let budget = if spec == SamplerSpec::Greedy { 6 } else { 2 };
            (generate::encode_prompt(tok, t), spec, 1000 + i as u64, budget)
        })
        .collect()
}

fn wire_body(prompt: &[i32], spec: &SamplerSpec, seed: u64, max_new: usize, stream: bool) -> String {
    let sampler = match spec {
        SamplerSpec::Greedy => r#""sample": "greedy""#.to_string(),
        SamplerSpec::Temperature { temperature } => {
            format!(r#""sample": "temperature", "temperature": {temperature}"#)
        }
        SamplerSpec::TopK { k, temperature } => {
            format!(r#""sample": "top-k", "top_k": {k}, "temperature": {temperature}"#)
        }
        SamplerSpec::TopP { p, temperature } => {
            format!(r#""sample": "top-p", "top_p": {p}, "temperature": {temperature}"#)
        }
        other => panic!("no wire form for {other:?}"),
    };
    format!(
        r#"{{"tokens": {prompt:?}, "max_new": {max_new}, {sampler}, "seed": {seed}, "stream": {stream}}}"#
    )
}

#[test]
fn http_completions_match_solo_serve_sessions_streamed_and_not() {
    let Some(rt) = have_decode() else { return };
    let params = ModelParams::init(&rt.manifest, &mut Rng::new(PARAM_SEED));
    let tok = make_tok(rt.manifest.vocab);
    let reqs = mixed_wire_requests(&tok);
    let (addr, state, h) = start_real(ServeConfig::default());

    // concurrent mixed clients: even indices stream, odd don't
    let mut joins = Vec::new();
    for (i, (prompt, spec, seed, max_new)) in reqs.iter().cloned().enumerate() {
        let addr = addr.clone();
        joins.push(thread::spawn(move || {
            let stream = i % 2 == 0;
            let body = wire_body(&prompt, &spec, seed, max_new, stream);
            if stream {
                let (streamed, done, _) = post_stream_tokens(&addr, &body);
                assert_eq!(streamed, done, "request {i}: frames vs done tokens");
                done
            } else {
                let (code, toks) = post_tokens(&addr, &body);
                assert_eq!(code, 200, "request {i}");
                toks
            }
        }));
    }
    let served: Vec<Vec<i32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    // bit-parity with a solo session per request — batch placement and
    // transport (stream or not) must not change a completion
    for (i, ((prompt, spec, seed, max_new), got)) in reqs.iter().zip(&served).enumerate() {
        let want = solo(
            &rt,
            &params,
            Request::sampled(prompt.clone(), *max_new, spec.clone(), *seed),
        );
        assert_eq!(got, &want.tokens, "request {i} diverged from solo decode");
    }

    // the same request over both transports is also identical
    let (prompt, spec, seed, max_new) = reqs[0].clone();
    let (_, a) = post_tokens(&addr, &wire_body(&prompt, &spec, seed, max_new, false));
    let (_, b, _) = post_stream_tokens(&addr, &wire_body(&prompt, &spec, seed, max_new, true));
    assert_eq!(a, b, "transport changed the completion");

    state.request_shutdown();
    h.join().unwrap();
}

#[test]
fn stop_sequences_and_logit_bias_apply_over_http() {
    let Some(rt) = have_decode() else { return };
    let params = ModelParams::init(&rt.manifest, &mut Rng::new(PARAM_SEED));
    let tok = make_tok(rt.manifest.vocab);
    let prompt = generate::encode_prompt(&tok, "who built the eiffel tower ?");
    let base = solo(&rt, &params, Request::greedy(prompt.clone(), 8));
    let (addr, state, h) = start_real(ServeConfig::default());

    if base.tokens.len() >= 3 {
        // stop on the greedy trajectory's own [t1, t2]: the served run
        // must halt there and exclude the match
        let body = format!(
            r#"{{"tokens": {prompt:?}, "max_new": 8, "sample": "greedy", "stop_tokens": [[{}, {}]]}}"#,
            base.tokens[1], base.tokens[2]
        );
        let resp = client::post(&addr, "/v1/completions", &body).unwrap();
        assert_eq!(resp.status, 200);
        let j = resp.json().unwrap();
        assert_eq!(j.get("finish_reason").unwrap().as_str(), Some("stop_seq"));
        let got: Vec<i32> = j.get("tokens").unwrap().as_arr().unwrap().iter()
            .map(|x| x.as_f64().unwrap() as i32).collect();
        assert_eq!(got, base.tokens[..1].to_vec(), "matched suffix must be excluded");
    }

    // banning the greedy first choice provably removes it everywhere
    let banned = base.tokens[0];
    let body = format!(
        r#"{{"tokens": {prompt:?}, "max_new": 8, "sample": "greedy", "ban": [{banned}]}}"#
    );
    let resp = client::post(&addr, "/v1/completions", &body).unwrap();
    assert_eq!(resp.status, 200);
    let got: Vec<i32> = resp.json().unwrap().get("tokens").unwrap().as_arr().unwrap().iter()
        .map(|x| x.as_f64().unwrap() as i32).collect();
    assert!(!got.is_empty());
    assert!(got.iter().all(|&t| t != banned), "banned token appeared: {got:?}");

    state.request_shutdown();
    h.join().unwrap();
}

#[test]
fn burst_fills_metrics_and_overflow_spares_in_flight_rows() {
    let Some(rt) = have_decode() else { return };
    let params = ModelParams::init(&rt.manifest, &mut Rng::new(PARAM_SEED));
    let tok = make_tok(rt.manifest.vocab);
    let prompt = generate::encode_prompt(&tok, "name the capital of france .");
    let budget = 24usize;
    let want = solo(&rt, &params, Request::greedy(prompt.clone(), budget));
    let (addr, state, h) = start_real(ServeConfig { max_queue: 1, ..ServeConfig::default() });

    // far more concurrent identical greedy requests than rows + queue:
    // overflow must answer 429 and every accepted request must still be
    // bit-identical to the solo baseline (in-flight rows undisturbed)
    let mut joins = Vec::new();
    for _ in 0..16 {
        let addr = addr.clone();
        let body = format!(r#"{{"tokens": {prompt:?}, "max_new": {budget}, "sample": "greedy"}}"#);
        joins.push(thread::spawn(move || {
            client::post(&addr, "/v1/completions", &body).unwrap()
        }));
    }
    let responses: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let served = responses.iter().filter(|r| r.status == 200).count();
    let rejected = responses.iter().filter(|r| r.status == 429).count();
    assert_eq!(served + rejected, responses.len(), "only 200/429 expected");
    assert!(served >= 1, "someone must be served");
    assert!(rejected >= 1, "a 16-deep burst against a 1-deep queue must overflow");
    for r in responses.iter().filter(|r| r.status == 200) {
        let got: Vec<i32> = r.json().unwrap().get("tokens").unwrap().as_arr().unwrap().iter()
            .map(|x| x.as_f64().unwrap() as i32).collect();
        assert_eq!(got, want.tokens, "an accepted request diverged under overflow");
    }

    // acceptance: the burst leaves non-zero latency histograms and the
    // engine's ExecStats visible in the export
    assert!(state.metrics.ttft.count() > 0, "TTFT histogram is empty");
    assert!(state.metrics.tok_rate.count() > 0, "tokens/sec histogram is empty");
    let m = client::get(&addr, "/metrics").unwrap().body;
    let steps_line = m.lines().find(|l| l.starts_with("lisa_serve_decode_steps_total"))
        .expect("decode-steps series");
    let steps: f64 = steps_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    assert!(steps > 0.0, "{steps_line}");
    assert!(
        m.contains("lisa_segment_calls_total{segment=\"decode_step\"}"),
        "per-segment ExecStats missing:\n{m}"
    );

    state.request_shutdown();
    h.join().unwrap();
}
