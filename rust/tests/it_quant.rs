//! Quantized frozen-base residency: the ISSUE 10 conformance gate.
//!
//! Always-on tiers (no artifacts needed):
//! * quantize/dequantize round-trip error is bounded by half a scale
//!   step per channel, and a channel's absmax maps to ±127 exactly;
//! * the `quantized_bytes` arithmetic delivers the ≥3.5x upload shrink
//!   for every row count ≥ 28 (4r / (r+4), DESIGN.md §15);
//! * `DeviceCache` dual-format accounting: class swaps move bytes
//!   between the f32/i8 ledgers with exactly one re-upload and one
//!   `swaps` tick per transition.
//!
//! Artifact-gated tiers (quant-stamped AOT artifacts):
//! * the `LISA_QUANT=0` kill switch pins `Off` against `set_quant`;
//! * frozen eval under `--quant int8` uploads ≥3.5x fewer weight bytes
//!   than the f32 twin — byte-for-byte against the manifest shapes —
//!   while logits stay inside the documented drift bound and greedy
//!   argmax rows are token-identical;
//! * a LISA resample (trainable block 0 → trainable block 1) swaps
//!   exactly the 12 two-D block weights between formats, with exact
//!   upload-byte accounting in both directions;
//! * a mixed continuous-batching queue under `--quant int8` serves
//!   token-identical completions to the f32 session.
//!
//! Engine construction reads `LISA_QUANT`, so every test that builds an
//! `Engine` serializes on `ENV_LOCK` — tests in one binary share the
//! process environment across threads.

use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};

use lisa::engine::{Batch, Engine, KvMode, QuantMode, Request, ServeSession, TrainMask};
use lisa::model::ModelParams;
use lisa::opt::{dequantize, quantize_per_channel, quantized_bytes};
use lisa::runtime::{DeviceCache, HostTensor, HostTensorI32, Runtime, CLASS_F32, CLASS_I8};
use lisa::util::rng::Rng;

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Hold this across any `Engine::new` or `LISA_QUANT` mutation: the env
/// var is process-global and the test harness runs threads in parallel.
fn env_guard() -> MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

/// Artifacts present *and* stamped with the core q8 segment set.
fn have_quant() -> Option<Runtime> {
    if !artifacts().join("manifest.json").exists() {
        return None;
    }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    rt.manifest.supports_quant("pallas").then_some(rt)
}

/// Additionally carries the q8 decode twins (serving-path tier).
fn have_quant_decode() -> Option<Runtime> {
    let rt = have_quant()?;
    rt.manifest.supports_quant_decode("pallas").then_some(rt)
}

fn make_batch(m: &lisa::runtime::Manifest, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let n = m.batch * m.seq;
    let tokens: Vec<i32> = (0..n).map(|_| rng.below(m.vocab) as i32).collect();
    let targets: Vec<i32> = tokens
        .iter()
        .enumerate()
        .map(|(i, &t)| if i % 3 == 0 { -1 } else { t })
        .collect();
    Batch {
        tokens: HostTensorI32::from_vec(&[m.batch, m.seq], tokens),
        targets: HostTensorI32::from_vec(&[m.batch, m.seq], targets),
    }
}

fn rand_tensor(shape: &[usize], seed: u64) -> HostTensor {
    let mut rng = Rng::new(seed);
    let n: usize = shape.iter().product();
    let data: Vec<f32> = (0..n)
        .map(|_| (rng.below(20_000) as f32 / 10_000.0 - 1.0) * 0.7)
        .collect();
    HostTensor::from_vec(shape, data)
}

fn numel(shape: &[usize]) -> u64 {
    shape.iter().product::<usize>() as u64
}

fn f32_bytes(shape: &[usize]) -> u64 {
    4 * numel(shape)
}

// ---------------------------------------------------------------------------
// always-on tier: quantizer properties
// ---------------------------------------------------------------------------

#[test]
fn round_trip_error_bounded_by_half_scale_per_channel() {
    for (i, shape) in [[64usize, 128], [128, 512], [28, 4], [512, 128]]
        .iter()
        .enumerate()
    {
        let w = rand_tensor(shape, 100 + i as u64);
        let qt = quantize_per_channel(&w).unwrap();
        let d = dequantize(&qt);
        let (rows, cols) = (shape[0], shape[1]);
        for r in 0..rows {
            for c in 0..cols {
                let err = (w.data[r * cols + c] - d.data[r * cols + c]).abs();
                // round-half-even: |w - q*s| <= s/2 (+ float slack)
                let bound = qt.s.data[c] * 0.5 + 1e-6;
                assert!(
                    err <= bound,
                    "shape {shape:?} [{r},{c}]: err {err} > s/2 {bound}"
                );
            }
        }
    }
}

#[test]
fn channel_absmax_maps_to_full_scale() {
    // col 0 peaks at +2.0, col 1 at -0.5; the peak must land on ±127.
    let w = HostTensor::from_vec(&[3, 2], vec![0.1, -0.5, 2.0, 0.2, -1.0, 0.0]);
    let qt = quantize_per_channel(&w).unwrap();
    assert_eq!(qt.q.data[2], 127, "col-0 absmax (+2.0) -> +127");
    assert_eq!(qt.q.data[1], -127, "col-1 absmax (-0.5) -> -127");
    assert!((qt.s.data[0] - 2.0 / 127.0).abs() < 1e-7);
    assert!((qt.s.data[1] - 0.5 / 127.0).abs() < 1e-7);
}

#[test]
fn non_2d_tensors_refuse_to_quantize() {
    assert!(quantize_per_channel(&rand_tensor(&[8], 1)).is_err());
    assert!(quantize_per_channel(&rand_tensor(&[2, 2, 2], 2)).is_err());
}

#[test]
fn upload_shrink_ratio_is_at_least_3_5x_for_real_weight_rows() {
    // ratio = 4rc / (rc + 4c) = 4r / (r + 4): ≥ 3.5 ⟺ r ≥ 28.
    for shape in [[28usize, 4], [64, 64], [64, 256], [512, 128]] {
        let q8 = quantized_bytes(&shape) as f64;
        let f32b = f32_bytes(&shape) as f64;
        assert!(
            f32b / q8 >= 3.5,
            "shape {shape:?}: ratio {} < 3.5",
            f32b / q8
        );
    }
    // sanity of the bound itself: below 28 rows the ratio dips under
    let tiny = [16usize, 16];
    assert!(f32_bytes(&tiny) as f64 / quantized_bytes(&tiny) as f64 < 3.5);
}

// ---------------------------------------------------------------------------
// always-on tier: dual-format cache accounting
// ---------------------------------------------------------------------------

#[test]
fn cache_class_swap_moves_bytes_between_ledgers() {
    let mut cache: DeviceCache<u32, u32> = DeviceCache::new();

    // cold f32 upload
    let v = cache
        .get_or_upload_class(1, 7, CLASS_F32, || Ok((400u32, 400)))
        .unwrap();
    assert_eq!(v, 400);
    let s = cache.stats();
    assert_eq!((s.misses, s.hits, s.swaps), (1, 0, 0));
    assert_eq!(s.upload_bytes, 400);
    assert_eq!((s.resident_f32_bytes, s.resident_i8_bytes), (400, 0));

    // warm hit, same class: no upload
    cache
        .get_or_upload_class(1, 7, CLASS_F32, || panic!("must not re-upload"))
        .unwrap();
    assert_eq!(cache.stats().hits, 1);

    // demote to i8: one swap, one re-upload, bytes move ledgers
    let v = cache
        .get_or_upload_class(1, 7, CLASS_I8, || Ok((115u32, 115)))
        .unwrap();
    assert_eq!(v, 115);
    let s = cache.stats();
    assert_eq!(s.swaps, 1);
    assert_eq!(s.misses, 2, "a swap re-uploads through the miss path");
    assert_eq!(s.upload_bytes, 400 + 115);
    assert_eq!((s.resident_f32_bytes, s.resident_i8_bytes), (0, 115));
    assert_eq!(s.resident_bytes, 115);
    assert_eq!(s.entries, 1, "a swap replaces, never duplicates");

    // promote back to f32: the reverse transition is symmetric
    cache
        .get_or_upload_class(1, 7, CLASS_F32, || Ok((400u32, 400)))
        .unwrap();
    let s = cache.stats();
    assert_eq!(s.swaps, 2);
    assert_eq!(s.upload_bytes, 400 + 115 + 400);
    assert_eq!((s.resident_f32_bytes, s.resident_i8_bytes), (400, 0));

    // a second key's class is independent
    cache
        .get_or_upload_class(2, 7, CLASS_I8, || Ok((60u32, 60)))
        .unwrap();
    let s = cache.stats();
    assert_eq!((s.resident_f32_bytes, s.resident_i8_bytes), (400, 60));
    assert_eq!(s.resident_bytes, 460);
    assert_eq!(s.swaps, 2, "no swap across distinct keys");
}

// ---------------------------------------------------------------------------
// artifact-gated tier: engine semantics
// ---------------------------------------------------------------------------

#[test]
fn lisa_quant_env_pin_beats_set_quant() {
    let Some(rt) = have_quant() else { return };
    let _g = env_guard();

    std::env::set_var("LISA_QUANT", "0");
    let mut eng = Engine::new(&rt);
    assert_eq!(eng.quant(), QuantMode::Off);
    eng.set_quant(QuantMode::Int8);
    assert_eq!(eng.quant(), QuantMode::Off, "the kill switch is a pin");

    std::env::set_var("LISA_QUANT", "int8");
    let mut eng = Engine::new(&rt);
    assert_eq!(eng.quant(), QuantMode::Int8);
    eng.set_quant(QuantMode::Off);
    assert_eq!(eng.quant(), QuantMode::Off, "int8 start is not a pin");

    std::env::remove_var("LISA_QUANT");
    let eng = Engine::new(&rt);
    assert_eq!(eng.quant(), QuantMode::Off, "default is f32");
}

/// Expected quantized upload bytes for the whole frozen model (every
/// 2-D tensor as `(q, s)`, every 1-D norm gain as f32), straight from
/// the manifest/param shapes — the oracle the cache ledgers must hit.
fn expected_frozen_bytes(m: &lisa::runtime::Manifest, p: &ModelParams) -> (u64, u64) {
    let mut i8b = 0u64;
    let mut f32b = 0u64;
    for t in [&p.emb, &p.pos, &p.wh] {
        i8b += quantized_bytes(&t.shape) as u64;
    }
    f32b += f32_bytes(&p.gf.shape);
    for (_, shape) in &m.block_params {
        if shape.len() == 2 {
            i8b += m.n_layers as u64 * quantized_bytes(shape) as u64;
        } else {
            f32b += m.n_layers as u64 * f32_bytes(shape);
        }
    }
    (i8b, f32b)
}

// The ISSUE 10 acceptance gate, part 1: a fully frozen eval under
// `--quant int8` must upload ≥3.5x fewer weight bytes than the f32 twin
// (byte-exact against the manifest shapes), keep every logit inside the
// documented drift bound, and pick the same greedy token everywhere.
#[test]
fn frozen_eval_shrinks_uploads_3_5x_within_logit_drift_bound() {
    let Some(rt) = have_quant() else { return };
    let _g = env_guard();
    std::env::remove_var("LISA_QUANT");
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(3));
    let batch = make_batch(&m, 5);

    let mut ef = Engine::new(&rt);
    ef.device_flow = true;
    let lf = ef.logits(&params, &batch.tokens).unwrap();

    let mut eq = Engine::new(&rt);
    eq.device_flow = true;
    eq.set_quant(QuantMode::Int8);
    let lq = eq.logits(&params, &batch.tokens).unwrap();

    // -- drift bound (DESIGN.md §15): 4e-2, magnitude-normalized
    assert_eq!(lf.shape, lq.shape);
    let scale = lf.data.iter().fold(1.0f32, |a, x| a.max(x.abs()));
    let bound = 4e-2 * scale;
    let mut max_err = 0.0f32;
    for (a, b) in lf.data.iter().zip(&lq.data) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err <= bound, "logit drift {max_err} > bound {bound}");

    // -- greedy argmax identity at every position
    let v = m.vocab;
    for (row, (rf, rq)) in lf.data.chunks(v).zip(lq.data.chunks(v)).enumerate() {
        let am = |r: &[f32]| {
            r.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        assert_eq!(am(rf), am(rq), "argmax flips at position {row}");
    }

    // -- byte-exact ledger accounting, then the headline ratio
    let sf = ef.device_cache_stats();
    let sq = eq.device_cache_stats();
    let (want_i8, want_1d_f32) = expected_frozen_bytes(&m, &params);
    assert_eq!(sq.resident_i8_bytes, want_i8);
    assert_eq!(sq.resident_f32_bytes, want_1d_f32);
    assert_eq!(sq.upload_bytes, want_i8 + want_1d_f32);
    assert_eq!(sf.upload_bytes, sf.resident_bytes, "cold f32 run: no evictions");
    assert_eq!(sq.swaps, 0, "a frozen eval never changes format");

    // frozen-tensor (2-D) uploads: f32 twin bytes / quantized bytes
    let f32_2d = sf.upload_bytes - sq.resident_f32_bytes;
    let ratio = f32_2d as f64 / sq.resident_i8_bytes as f64;
    assert!(
        ratio >= 3.5,
        "frozen warm-upload shrink {ratio:.2}x < 3.5x (f32 2-D {f32_2d}B vs q8 {}B)",
        sq.resident_i8_bytes
    );
}

// The ISSUE 10 acceptance gate, part 2: a LISA resample that moves the
// trainable block from layer 0 to layer 1 must swap exactly the twelve
// 2-D block weights (six demoted f32→i8, six promoted i8→f32) with
// byte-exact uploads — and the reverse resample is symmetric.
#[test]
fn lisa_resample_swaps_block_residency_byte_for_byte() {
    let Some(rt) = have_quant() else { return };
    let _g = env_guard();
    std::env::remove_var("LISA_QUANT");
    let m = rt.manifest.clone();
    assert!(m.n_layers >= 2, "resample test needs two blocks");
    let params = ModelParams::init(&m, &mut Rng::new(3));
    let batch = make_batch(&m, 5);

    let mut eng = Engine::new(&rt);
    eng.device_flow = true;
    eng.set_quant(QuantMode::Int8);

    let mask_with = |l: usize| {
        let mut mk = TrainMask::none(m.n_layers);
        mk.embed = true;
        mk.head = true;
        mk.blocks[l] = true;
        mk
    };

    // per-block 2-D byte totals (all blocks share shapes)
    let two_d: Vec<&Vec<usize>> = m
        .block_params
        .iter()
        .filter(|(_, s)| s.len() == 2)
        .map(|(_, s)| s)
        .collect();
    assert_eq!(two_d.len(), 6, "block ABI: 6 weight matrices + 2 gains");
    let q8_block: u64 = two_d.iter().map(|s| quantized_bytes(s) as u64).sum();
    let f32_block: u64 = two_d.iter().map(|s| f32_bytes(s)).sum();

    eng.forward_backward(&params, &batch, &mask_with(0)).unwrap();
    let s0 = eng.device_cache_stats();

    // resample: block 0 freezes (f32→i8), block 1 promotes (i8→f32)
    eng.forward_backward(&params, &batch, &mask_with(1)).unwrap();
    let s1 = eng.device_cache_stats();
    assert_eq!(s1.swaps - s0.swaps, 12, "6 demotions + 6 promotions");
    assert_eq!(s1.misses - s0.misses, 12, "each swap re-uploads once");
    assert_eq!(
        s1.upload_bytes - s0.upload_bytes,
        q8_block + f32_block,
        "demotions upload quantized bytes, promotions full f32"
    );
    assert_eq!(s1.entries, s0.entries, "swaps replace entries in place");

    // exact residency after the resample: one trainable block f32, the
    // rest quantized; embed/head trainable (f32) and gains always f32
    let want_i8 = (m.n_layers as u64 - 1) * q8_block;
    let gains_f32: u64 = m
        .block_params
        .iter()
        .filter(|(_, s)| s.len() != 2)
        .map(|(_, s)| m.n_layers as u64 * f32_bytes(s))
        .sum();
    let mut want_f32 = f32_block + gains_f32;
    for t in [&params.emb, &params.pos, &params.gf, &params.wh] {
        want_f32 += f32_bytes(&t.shape);
    }
    assert_eq!(s1.resident_i8_bytes, want_i8);
    assert_eq!(s1.resident_f32_bytes, want_f32);

    // resample back: the mirror transition, same byte bill
    eng.forward_backward(&params, &batch, &mask_with(0)).unwrap();
    let s2 = eng.device_cache_stats();
    assert_eq!(s2.swaps - s1.swaps, 12);
    assert_eq!(s2.upload_bytes - s1.upload_bytes, q8_block + f32_block);
    assert_eq!(s2.resident_i8_bytes, want_i8);
    assert_eq!(s2.resident_f32_bytes, want_f32);
}

// ---------------------------------------------------------------------------
// artifact-gated tier: serving parity
// ---------------------------------------------------------------------------

/// Mixed continuous-batching queue (longer than the device batch so
/// admission streams queued rows into freed slots): greedy rows with
/// mixed prompt lengths and budgets, the shape the ISSUE 5 suite pins.
fn mixed_greedy_queue(m: &lisa::runtime::Manifest, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..2 * m.batch)
        .map(|i| {
            let len = 3 + rng.below((m.seq / 2).max(4) - 2);
            let prompt: Vec<i32> =
                (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            let budget = if i % m.batch == 0 { 16.min(m.seq / 4).max(2) } else { 2 + i % 3 };
            Request::greedy(prompt, budget)
        })
        .collect()
}

// The ISSUE 10 acceptance gate, part 3: `--quant int8` greedy decode
// over the mixed continuous queue is token-identical to the f32 run.
#[test]
fn quantized_mixed_queue_serves_token_identical_to_f32() {
    let Some(rt) = have_quant_decode() else { return };
    let _g = env_guard();
    std::env::remove_var("LISA_QUANT");
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(3));
    let reqs = mixed_greedy_queue(&m, 21);
    assert!(reqs.len() > m.batch, "queue must force admission");
    const PAD: i32 = 0;

    let served_f32 = {
        let mut eng = Engine::new(&rt);
        let mut sess = ServeSession::with_mode(&mut eng, &params, KvMode::Packed).unwrap();
        sess.run(&reqs, -1, PAD).unwrap()
    };
    let served_q8 = {
        let mut eng = Engine::new(&rt);
        eng.set_quant(QuantMode::Int8);
        let mut sess = ServeSession::with_mode(&mut eng, &params, KvMode::Packed).unwrap();
        sess.run(&reqs, -1, PAD).unwrap()
    };

    assert_eq!(served_f32.len(), served_q8.len());
    for (i, (a, b)) in served_f32.iter().zip(&served_q8).enumerate() {
        assert_eq!(
            a.tokens, b.tokens,
            "request {i}: quantized completion diverged from f32"
        );
        assert_eq!(a.stop, b.stop, "request {i}: stop reason diverged");
    }
}
