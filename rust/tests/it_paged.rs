//! Paged K/V conformance suite (decode ABI v2, DESIGN.md §12) — gated on
//! artifacts that carry the paged segment set, like `it_serve.rs` is
//! gated on the v1 decode ABI:
//!
//! * **layout parity** — the paged schedule must serve the PR 5 mixed
//!   continuous queue token-for-token identical to the packed-v1
//!   schedule: the K/V layout is an execution detail, never a semantic;
//! * **prefix reuse saves prefill** — a second request sharing a 100%
//!   prompt prefix must adopt the drained donor's cached pages and
//!   execute **zero** prefill segments (asserted via `ExecStats`): the
//!   un-paged remainder streams through `paged_step` columns instead;
//! * **no page leaks** — after a full queue drain every page is back in
//!   the allocator: rows hold nothing, and free + cached accounts for
//!   the whole pool minus the pinned scratch page.
//!
//! Parity caveat (same class as it_serve.rs): `paged_step` gathers page
//! rows where `decode_step` slices a packed window — the attention sums
//! run in a different order, so logits agree to float tolerance, not
//! bit-for-bit (python/tests/test_decode.py pins the tolerance).
//! Token-for-token equality relies on argmax margins / short sampled
//! budgets exactly as the packed-vs-legacy suites do.

use std::path::{Path, PathBuf};

use lisa::data::tokenizer::{EOS, PAD};
use lisa::data::{corpus, Tokenizer};
use lisa::engine::serve::request_seed;
use lisa::engine::{Engine, KvMode, Request, SamplerSpec, ServeSession, StopReason};
use lisa::eval::generate;
use lisa::model::ModelParams;
use lisa::runtime::Runtime;
use lisa::util::rng::Rng;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

/// Artifacts present *and* exported with the paged decode ABI (v2).
fn have_paged() -> Option<Runtime> {
    if !artifacts().join("manifest.json").exists() {
        return None;
    }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    rt.manifest.supports_paged("pallas").then_some(rt)
}

fn make_tok(rt: &Runtime) -> Tokenizer {
    let samples = corpus::gen_instruction_corpus(64, 11);
    Tokenizer::build(&corpus::sample_texts(&samples), rt.manifest.vocab)
}

/// The it_serve.rs mixed queue: longer than the batch, mixed prompt
/// lengths, budgets and sampling policies.
fn mixed_requests(tok: &Tokenizer, gen_seed: u64) -> Vec<Request> {
    let texts = [
        "what is 12 plus 10 ?",
        "name the capital of france .",
        "what is 3 times 4 ?",
        "who built the eiffel tower ?",
        "what is 9 minus 2 ?",
        "in what year was the eiffel tower built ?",
        "what is 7 times 8 ?",
        "name the capital of japan .",
    ];
    let specs = [
        SamplerSpec::Greedy,
        SamplerSpec::Temperature { temperature: 0.8 },
        SamplerSpec::TopK { k: 5, temperature: 1.0 },
        SamplerSpec::TopP { p: 0.9, temperature: 1.0 },
    ];
    texts
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let greedy = i % specs.len() == 0;
            Request::sampled(
                generate::encode_prompt(tok, t),
                if greedy { 3 + i } else { 2 + (i % 2) },
                specs[i % specs.len()].clone(),
                request_seed(gen_seed, i),
            )
        })
        .collect()
}

fn run_mode(
    rt: &Runtime,
    params: &ModelParams,
    reqs: &[Request],
    mode: KvMode,
) -> Vec<lisa::engine::Completion> {
    let mut eng = Engine::new(rt);
    let mut sess = ServeSession::with_mode(&mut eng, params, mode).unwrap();
    assert_eq!(sess.kv_mode(), mode);
    sess.run(reqs, EOS, PAD).unwrap()
}

/// A prompt long enough to span full pages (the corpus prompts are all
/// shorter than one tiny-config page). Plain token ids below `vocab` —
/// `Request` takes ids verbatim, no tokenizer round trip needed.
fn long_prompt(vocab: usize, len: usize, salt: i32) -> Vec<i32> {
    (0..len as i32).map(|i| 3 + (salt + i * 7) % (vocab as i32 - 4)).collect()
}

#[test]
fn paged_serving_matches_packed_token_for_token() {
    let Some(rt) = have_paged() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(3));
    let tok = make_tok(&rt);
    let reqs = mixed_requests(&tok, 42);
    assert!(reqs.len() > m.batch, "queue must force mid-decode admission");

    rt.reset_stats();
    let paged = run_mode(&rt, &params, &reqs, KvMode::Paged);
    let stats = rt.stats();
    assert!(stats.get("paged_step").is_some(), "paged mode must run paged_step");
    assert!(stats.get("paged_scatter").is_some(), "prefill must seed the pools");
    assert!(stats.get("pack_state").is_none(), "the packed layout must not run");
    assert!(stats.get("decode_step").is_none());

    let packed = run_mode(&rt, &params, &reqs, KvMode::Packed);
    assert_eq!(paged.len(), packed.len());
    for (i, (a, b)) in paged.iter().zip(&packed).enumerate() {
        assert_eq!(a.tokens, b.tokens, "request {i}: paged vs packed tokens");
        assert_eq!(a.stop, b.stop, "request {i}: stop reason");
        assert_eq!(a.prompt_truncated, b.prompt_truncated);
    }
}

// The ISSUE 7 acceptance gate: a second request sharing a 100% prompt
// prefix adopts the drained donor's registered pages and pays zero
// prefill segments — only the page-tail remainder of the prompt streams
// through paged_step columns.
#[test]
fn shared_prefix_request_executes_zero_prefill_segments() {
    let Some(rt) = have_paged() else { return };
    let m = rt.manifest.clone();
    let bt = m.page_t;
    let params = ModelParams::init(&m, &mut Rng::new(5));
    let eos = -1; // unreachable: budgets run exactly
    // 2.5 pages of prompt: two full (cacheable) pages + a tail
    let prompt = long_prompt(m.vocab, 2 * bt + bt / 2, 1);
    let full = (prompt.len() / bt) * bt;

    let mut eng = Engine::new(&rt);
    let mut sess = ServeSession::with_mode(&mut eng, &params, KvMode::Paged).unwrap();

    // donor: cold, so the whole prompt goes through one batch prefill
    let a = sess.run(&[Request::greedy(prompt.clone(), 4)], eos, PAD).unwrap().remove(0);
    assert_eq!(a.tokens.len(), 4);
    assert_eq!(sess.batch_prefills, 1);
    assert_eq!(sess.streamed_prompt_tokens, 0, "a solo cold prompt never streams");
    {
        let alloc = sess.page_allocator().expect("paged session");
        assert_eq!(alloc.outstanding(), 0, "drained donor must return its pages");
        assert_eq!(alloc.n_cached(), full / bt, "full prompt pages must be registered");
    }

    // adopter: same prompt, same session — the registered pages carry
    // positions [0, full); no prefill segment may run
    rt.reset_stats();
    let b = sess.run(&[Request::greedy(prompt.clone(), 4)], eos, PAD).unwrap().remove(0);
    let stats = rt.stats();
    assert!(stats.get("prefill_kv").is_none(), "shared prefix must skip prefill_kv");
    assert!(stats.get("block_fwd").is_none(), "shared prefix must skip the prompt forward");
    assert!(stats.get("embed_fwd").is_none());
    assert!(stats.get("paged_scatter").is_none(), "nothing to scatter without a prefill");
    assert!(stats.get("paged_step").is_some(), "the remainder streams through paged_step");
    assert_eq!(sess.batch_prefills, 1, "no second batch prefill");
    assert_eq!(
        sess.streamed_prompt_tokens as usize,
        prompt.len() - full,
        "exactly the un-paged prompt tail streams"
    );
    let alloc = sess.page_allocator().expect("paged session");
    assert_eq!(alloc.prefix_hits, 1, "the adopter must hit the prefix cache");
    assert_eq!(alloc.prefix_pages_served as usize, full / bt);

    // adoption must not change the completion (greedy, same prompt)
    assert_eq!(b.tokens, a.tokens, "prefix adoption changed the decode");
    assert_eq!(b.stop, StopReason::MaxNew);

    // a diverging prompt (same first page, different second) only adopts
    // the pages it actually shares
    let mut fork = prompt.clone();
    fork[bt + 1] ^= 1;
    sess.run(&[Request::greedy(fork, 2)], eos, PAD).unwrap();
    let alloc = sess.page_allocator().expect("paged session");
    assert_eq!(alloc.prefix_pages_served as usize, full / bt + 1, "fork shares one page");
}

#[test]
fn full_queue_drain_returns_every_page_to_the_allocator() {
    let Some(rt) = have_paged() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(7));
    let tok = make_tok(&rt);
    let eos = -1;

    // the mixed queue plus two distinct page-spanning prompts, so the
    // drain exercises both uncached short rows and registered long ones
    let mut reqs = mixed_requests(&tok, 43);
    reqs.push(Request::greedy(long_prompt(m.vocab, 2 * m.page_t + 3, 5), 3));
    reqs.push(Request::greedy(long_prompt(m.vocab, 2 * m.page_t + 3, 11), 3));

    let mut eng = Engine::new(&rt);
    let mut sess = ServeSession::with_mode(&mut eng, &params, KvMode::Paged).unwrap();
    let served = sess.run(&reqs, eos, PAD).unwrap();
    assert_eq!(served.len(), reqs.len());
    assert!(served.iter().all(|c| !c.tokens.is_empty()));

    let alloc = sess.page_allocator().expect("paged session");
    // the leak gate: no row holds a page, and free + cached is the whole
    // pool minus the pinned scratch page
    assert_eq!(alloc.outstanding(), 0, "pages leaked across the queue drain");
    assert_eq!(
        alloc.n_free() + alloc.n_cached(),
        m.page_n - 1,
        "free + cached must account for every non-scratch page"
    );
    // both long prompts registered their two full pages
    assert_eq!(alloc.n_cached(), 4);
}
