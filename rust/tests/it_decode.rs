//! Decode parity suite (artifact-gated, like `it_train.rs` — and
//! additionally gated on the decode ABI, so legacy artifact dirs skip):
//!
//! * batched KV-cached greedy decode must match the legacy full-forward
//!   greedy path **token-for-token** for every prompt in a mixed-length
//!   batch (including chunking past the artifact batch size, truncated
//!   prompts and stop-reason agreement);
//! * the cached path must run exactly one `decode_step` execution per
//!   generated batch-token (asserted via `ExecStats`) and upload **zero
//!   weight tensors** on a warm device cache — only the `[B, 1]` i32
//!   token/position columns cross the host boundary;
//! * cache invalidation must be airtight: decode after an optimizer step
//!   or a checkpoint restore must never serve stale weights (stale K/V is
//!   structurally impossible — the cache lives inside a `DecodeSession`,
//!   which borrows the engine for its whole lifetime);
//! * the host-roundtrip flow (`device_flow = false`) must agree with the
//!   device-resident flow bit-for-bit.

use std::path::{Path, PathBuf};

use lisa::data::tokenizer::{EOS, PAD};
use lisa::data::{corpus, encode_sft, DataLoader, Tokenizer};
use lisa::engine::{Completion, DecodeSession, Engine, KvMode, StopReason};
use lisa::eval::generate;
use lisa::model::{checkpoint, ModelParams};
use lisa::runtime::Runtime;
use lisa::strategy::StrategySpec;
use lisa::train::{TrainConfig, TrainSession};
use lisa::util::rng::Rng;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

/// Artifacts present *and* exported with the decode ABI.
fn have_decode() -> Option<Runtime> {
    if !artifacts().join("manifest.json").exists() {
        return None;
    }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    rt.manifest.supports_decode("pallas").then_some(rt)
}

fn make_tok(rt: &Runtime) -> Tokenizer {
    let samples = corpus::gen_instruction_corpus(64, 11);
    Tokenizer::build(&corpus::sample_texts(&samples), rt.manifest.vocab)
}

/// Mixed-length prompts; more than one artifact batch so chunking runs.
fn prompts(rt: &Runtime) -> Vec<String> {
    let mut p = vec![
        "what is 12 plus 10 ?".to_string(),
        "name the capital of france .".to_string(),
        "what is 3 times 4 ?".to_string(),
        "who built the eiffel tower ?".to_string(),
        "what is 9 minus 2 ?".to_string(),
    ];
    // one prompt past the window: truncation + near-empty completion
    p.push("what is 1 plus 2 ".repeat(rt.manifest.seq));
    p
}

fn decode_batch(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompts: &[String],
    max_new: usize,
) -> Vec<Completion> {
    let refs: Vec<&str> = prompts.iter().map(String::as_str).collect();
    generate::greedy_complete_batch(eng, params, tok, &refs, max_new).unwrap()
}

// Parity caveat: the cached path's q-length-1 attention is plain masked
// softmax while the legacy forward uses the flash kernel — the two agree
// to float tolerance, not bit-for-bit (python/tests/test_decode.py pins
// the logits at rtol 2e-4). Token-for-token equality therefore relies on
// argmax margins dwarfing that noise, which holds at init and for the
// trained tiny models these suites run; a near-exact logit tie could in
// principle flip one token. Both paths share one first-of-ties argmax
// (engine::decode::argmax) so tie-breaking itself cannot diverge.
#[test]
fn cached_decode_matches_legacy_token_for_token() {
    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(3));
    let tok = make_tok(&rt);
    let prompts = prompts(&rt);
    let max_new = 8;

    let mut eng = Engine::new(&rt);
    let cached = decode_batch(&mut eng, &params, &tok, &prompts, max_new);
    assert_eq!(cached.len(), prompts.len());
    for (i, p) in prompts.iter().enumerate() {
        let legacy = generate::greedy_complete_legacy(&mut eng, &params, &tok, p, max_new)
            .unwrap();
        assert_eq!(cached[i].tokens, legacy.tokens, "prompt {i} diverged");
        assert_eq!(cached[i].stop, legacy.stop, "prompt {i} stop reason");
        assert_eq!(
            cached[i].prompt_truncated, legacy.prompt_truncated,
            "prompt {i} truncation flag"
        );
    }
    // the oversized prompt was reported, not silently clipped
    assert!(cached.last().unwrap().prompt_truncated);
    assert!(cached.iter().take(5).all(|c| !c.prompt_truncated));

    // max_new = 0 decodes nothing on either path
    let none = decode_batch(&mut eng, &params, &tok, &prompts[..1], 0);
    assert!(none[0].tokens.is_empty());
    assert_eq!(none[0].stop, StopReason::MaxNew);
}

/// `decode_step` executions a chunk of completions needs: the first token
/// comes from prefill; every later token costs one step; a row stopped by
/// `<eos>` pays one more step (the one that surfaced it). Rows in a chunk
/// share steps, so the chunk costs the max over its rows.
fn expected_steps(completions: &[Completion], batch: usize) -> u64 {
    completions
        .chunks(batch)
        .map(|chunk| {
            chunk
                .iter()
                .map(|c| {
                    let k = c.tokens.len() as u64;
                    match c.stop {
                        StopReason::Eos => k,
                        _ => k.saturating_sub(1),
                    }
                })
                .max()
                .unwrap_or(0)
        })
        .sum()
}

#[test]
fn one_decode_step_per_token_and_zero_weight_uploads_when_warm() {
    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(5));
    let tok = make_tok(&rt);
    let all = prompts(&rt);
    let enc: Vec<Vec<i32>> = all.iter().map(|p| generate::encode_prompt(&tok, p)).collect();
    let max_new = 6;

    // pinned to the packed v1 layout: this test's upload arithmetic
    // (tok+pidx only) is the v1 contract — the paged path adds a page
    // table per step and has its own accounting suite (it_paged.rs)
    let mut eng = Engine::new(&rt);
    assert!(eng.device_flow, "device flow must be the default");
    // cold pass: compiles executables, uploads every weight tensor once
    {
        let mut sess = DecodeSession::with_mode(&mut eng, &params, KvMode::Packed).unwrap();
        sess.greedy(&enc, max_new, EOS, PAD).unwrap();
    }
    let cold = eng.device_cache_stats();

    rt.reset_stats();
    let (outs, steps) = {
        let mut sess = DecodeSession::with_mode(&mut eng, &params, KvMode::Packed).unwrap();
        let outs = sess.greedy(&enc, max_new, EOS, PAD).unwrap();
        (outs, sess.decode_steps())
    };

    // acceptance: zero weight tensors uploaded on a warm device cache
    let warm = eng.device_cache_stats();
    assert_eq!(
        warm.misses, cold.misses,
        "warm decode must serve every weight from the device cache"
    );

    let stats = rt.stats();
    let ds = stats.get("decode_step").expect("decode_step ran");
    // acceptance: exactly one decode_step execution per generated token
    assert_eq!(ds.calls, steps, "session counter vs ExecStats");
    assert_eq!(ds.calls, expected_steps(&outs, m.batch), "steps vs completions");
    // per execution only tok+pidx ([B,1] i32 each) are uploaded; the
    // state chains on device and the weights are cache-served
    assert_eq!(ds.uploads, 2 * ds.calls, "decode_step must upload only tok/pidx");
    assert!(ds.buf_hits > 0, "weights + state must be device-served");
    let dl = stats.get("decode_logits").expect("decode_logits ran");
    assert_eq!(dl.uploads, 0, "decode_logits reads only device-resident operands");

    // prefill is one full forward per *chunk*, never per token
    let n_chunks = enc.len().div_ceil(m.batch) as u64;
    let bf = stats.get("block_fwd").expect("prefill ran block_fwd");
    assert_eq!(bf.calls, m.n_layers as u64 * n_chunks);
    let pk = stats.get("prefill_kv").expect("prefill ran prefill_kv");
    assert_eq!(pk.calls, m.n_layers as u64 * n_chunks);
}

/// The ROADMAP serving satellite: prefill must skip the `head_logits`
/// call — and its `[B, T, V]` download — for a batch whose every row has
/// a forced first token, without changing a single emitted token.
#[test]
fn prefill_skips_head_logits_when_every_first_token_is_forced() {
    use lisa::engine::{Request, ServeSession};

    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(13));
    let tok = make_tok(&rt);
    let eos = -1; // unreachable, so every row emits >= 1 token
    let max_new = 4;
    // one static chunk: exactly the batch width
    let reqs: Vec<Request> = prompts(&rt)
        .iter()
        .take(m.batch)
        .map(|p| Request::greedy(generate::encode_prompt(&tok, p), max_new))
        .collect();

    // reference pass: unforced greedy, head_logits runs once for the chunk
    let mut eng = Engine::new(&rt);
    rt.reset_stats();
    let want = {
        let mut sess = ServeSession::new(&mut eng, &params).unwrap();
        sess.run_static(&reqs, eos, PAD).unwrap()
    };
    assert_eq!(rt.stats().get("head_logits").expect("unforced prefill").calls, 1);
    assert!(want.iter().all(|c| !c.tokens.is_empty()));

    // forced pass: feed each row its known first token
    let forced: Vec<Request> = reqs
        .iter()
        .zip(&want)
        .map(|(r, c)| {
            let mut r = r.clone();
            r.first_token = Some(c.tokens[0]);
            r
        })
        .collect();
    rt.reset_stats();
    let got = {
        let mut sess = ServeSession::new(&mut eng, &params).unwrap();
        sess.run_static(&forced, eos, PAD).unwrap()
    };
    // the saved call and its [B, T, V] download, via ExecStats
    assert!(
        rt.stats().get("head_logits").is_none(),
        "forced-first-token prefill must skip head_logits entirely"
    );
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_eq!(a.tokens, b.tokens, "forcing the first token changed row {i}");
        assert_eq!(a.stop, b.stop);
    }
    // prefill itself still ran (the K/V cache is still needed)
    assert_eq!(rt.stats().get("prefill_kv").expect("prefill ran").calls, m.n_layers as u64);
}

#[test]
fn decode_never_serves_stale_weights_after_step_or_restore() {
    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let tok = make_tok(&rt);
    let prompts = prompts(&rt);

    // -- optimizer step between decodes --------------------------------
    let samples = corpus::gen_instruction_corpus(96, 19);
    let enc: Vec<_> = samples.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    let mut dl = DataLoader::new(enc, m.batch, m.seq, 5);
    let cfg = TrainConfig { steps: 4, lr: 3e-3, warmup: 1, log_every: 0, ..Default::default() };
    let mut sess = TrainSession::new(&rt, &StrategySpec::lisa(2, 3), cfg).unwrap();

    // warm the engine's device cache with a decode...
    decode_batch(&mut sess.engine, &sess.params, &tok, &prompts, 6);
    // ...mutate the weights through the strategy (Touched invalidation)...
    for step in 0..4 {
        sess.step(step, &mut dl).unwrap();
    }
    // ...then decode again: must equal a completely fresh engine's answer
    let after = decode_batch(&mut sess.engine, &sess.params, &tok, &prompts, 6);
    let mut fresh = Engine::new(&rt);
    let want = decode_batch(&mut fresh, &sess.params, &tok, &prompts, 6);
    for (i, (a, b)) in after.iter().zip(&want).enumerate() {
        assert_eq!(a.tokens, b.tokens, "stale weights after optimizer step (prompt {i})");
    }

    // -- checkpoint restore between decodes ----------------------------
    // rewrite every weight in place (exactly what resume does) and
    // invalidate, as TrainSession::resume_checkpoint does
    let params_b = ModelParams::init(&m, &mut Rng::new(99));
    let mut sec = checkpoint::model_section(&params_b);
    checkpoint::load_model_section(&mut sec, &mut sess.params).unwrap();
    sess.engine.invalidate_all();
    let restored = decode_batch(&mut sess.engine, &sess.params, &tok, &prompts, 6);
    let mut fresh = Engine::new(&rt);
    let want = decode_batch(&mut fresh, &sess.params, &tok, &prompts, 6);
    for (i, (a, b)) in restored.iter().zip(&want).enumerate() {
        assert_eq!(a.tokens, b.tokens, "stale weights after restore (prompt {i})");
    }
}

#[test]
fn device_and_host_flow_decode_agree_bit_for_bit() {
    let Some(rt) = have_decode() else { return };
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(7));
    let tok = make_tok(&rt);
    let prompts = prompts(&rt);

    let mut dev = Engine::new(&rt);
    dev.device_flow = true;
    let a = decode_batch(&mut dev, &params, &tok, &prompts, 8);
    let mut host = Engine::new(&rt);
    host.device_flow = false;
    let b = decode_batch(&mut host, &params, &tok, &prompts, 8);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.tokens, y.tokens, "device/host flow diverged (prompt {i})");
        assert_eq!(x.stop, y.stop);
    }
}
