//! Property-based tests on coordinator invariants (routing, batching,
//! state management) via the hand-rolled `util::prop` framework.

use lisa::data::{corpus, encode_sft, split_train_val, DataLoader, Tokenizer};
use lisa::engine::TrainMask;
use lisa::lisa::{LisaConfig, LisaScheduler};
use lisa::model::ParamKey;
use lisa::opt::{adamw::AdamHp, AdamW, StatePolicy};
use lisa::prop_assert;
use lisa::util::prop::prop_check;
use lisa::util::rng::Rng;

#[test]
fn prop_lisa_mask_routing_invariants() {
    prop_check("lisa mask invariants", 200, |rng| {
        let n_layers = 2 + rng.below(30);
        let gamma = 1 + rng.below(n_layers);
        let k = 1 + rng.below(20);
        let seed = rng.next_u64();
        let mut s = LisaScheduler::new(LisaConfig::paper(gamma, k), n_layers, seed);
        let steps = 1 + rng.below(100);
        let mut prev: Option<TrainMask> = None;
        for step in 0..steps {
            let m = s.mask_for_step(step);
            prop_assert!(m.blocks.len() == n_layers);
            prop_assert!(m.n_trainable_blocks() == gamma,
                         "γ={gamma} but {} trainable", m.n_trainable_blocks());
            prop_assert!(m.embed && m.head, "E and H always trainable");
            // within a period the mask must be identical
            if step % k != 0 {
                if let Some(p) = &prev {
                    prop_assert!(&m == p, "mask changed inside period at step {step}");
                }
            }
            prev = Some(m);
        }
        Ok(())
    });
}

#[test]
fn prop_lisa_expected_unfreeze_rate_is_gamma_over_l() {
    prop_check("importance-sampling rate", 20, |rng| {
        let n_layers = 4 + rng.below(12);
        let gamma = 1 + rng.below(n_layers / 2);
        let seed = rng.next_u64();
        let mut s = LisaScheduler::new(LisaConfig::paper(gamma, 1), n_layers, seed);
        let trials = 3000;
        let mut counts = vec![0usize; n_layers];
        for step in 0..trials {
            s.mask_for_step(step);
            for &l in s.current_layers() {
                counts[l] += 1;
            }
        }
        let expect = trials as f64 * gamma as f64 / n_layers as f64;
        for (l, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            prop_assert!(dev < 0.25, "layer {l}: {c} vs {expect} (dev {dev:.2})");
        }
        Ok(())
    });
}

#[test]
fn prop_adamw_state_tracks_trainable_set_exactly() {
    prop_check("optimizer state management", 100, |rng| {
        let n_layers = 2 + rng.below(16);
        let mut opt = AdamW::new(AdamHp::default(), StatePolicy::Drop);
        let mut live: Vec<usize> = Vec::new();
        for _round in 0..10 {
            // sample a new trainable set and run one update per member
            let gamma = 1 + rng.below(n_layers);
            live = rng.sample_distinct(n_layers, gamma);
            for &l in &live {
                let mut p = vec![1.0f32; 8];
                let g = vec![0.1f32; 8];
                opt.step(ParamKey::Block(l, 0), true, &mut p, &g);
            }
            opt.retain_blocks(&live);
            // invariant: state exists exactly for the live block set
            for l in 0..n_layers {
                let has = opt.steps_of(ParamKey::Block(l, 0)) > 0;
                prop_assert!(
                    has == live.contains(&l),
                    "layer {l}: state={has} live={}",
                    live.contains(&l)
                );
            }
        }
        let _ = live;
        Ok(())
    });
}

#[test]
fn prop_adamw_is_elementwise_and_shift_invariant() {
    // updating a concatenated tensor == updating the pieces separately
    prop_check("adamw elementwise", 60, |rng| {
        let n1 = 1 + rng.below(64);
        let n2 = 1 + rng.below(64);
        let mut rng2 = Rng::new(rng.next_u64());
        let mk = |rng: &mut Rng, n: usize| {
            let mut v = vec![0f32; n];
            rng.fill_normal(&mut v, 1.0);
            v
        };
        let p1 = mk(&mut rng2, n1);
        let p2 = mk(&mut rng2, n2);
        let g1 = mk(&mut rng2, n1);
        let g2 = mk(&mut rng2, n2);

        let hp = AdamHp::default();
        let mut whole = AdamW::new(hp, StatePolicy::Keep);
        let mut cat_p: Vec<f32> = p1.iter().chain(&p2).copied().collect();
        let cat_g: Vec<f32> = g1.iter().chain(&g2).copied().collect();
        whole.step(ParamKey::Emb, true, &mut cat_p, &cat_g);

        let mut parts = AdamW::new(hp, StatePolicy::Keep);
        let mut q1 = p1.clone();
        let mut q2 = p2.clone();
        parts.step(ParamKey::Block(0, 0), true, &mut q1, &g1);
        parts.step(ParamKey::Block(1, 0), true, &mut q2, &g2);

        let joined: Vec<f32> = q1.iter().chain(&q2).copied().collect();
        lisa::prop_assert_allclose!(cat_p, joined, 1e-6, 1e-7);
        Ok(())
    });
}

#[test]
fn prop_dataloader_batching_covers_dataset() {
    prop_check("dataloader epoch coverage", 40, |rng| {
        let n = 4 + rng.below(60);
        let batch = 1 + rng.below(6);
        let seq = 16;
        let samples = corpus::gen_instruction_corpus(n, rng.next_u64());
        let tok = Tokenizer::build(&corpus::sample_texts(&samples), 512);
        let enc: Vec<_> = samples.iter().map(|s| encode_sft(&tok, s, seq)).collect();
        // the loader drops examples whose prompt fills the whole window
        // (zero supervised positions — they would poison the masked loss);
        // at seq=16 some corpus prompts do exactly that
        let n_supervised = enc.iter().filter(|e| e.n_supervised() > 0).count();
        if n_supervised == 0 {
            // every sampled prompt filled the window: constructing a
            // loader is (correctly) an error, nothing to batch-check
            prop_assert!(DataLoader::try_new(enc, batch, seq, rng.next_u64()).is_err());
            return Ok(());
        }
        let mut dl = DataLoader::new(enc, batch, seq, rng.next_u64());
        prop_assert!(
            dl.len() == n_supervised,
            "loader kept {} of {n} examples, expected the {n_supervised} supervised ones",
            dl.len()
        );

        // one epoch of next_batch must emit steps_per_epoch batches of the
        // right shape, and eval_batches must cover every surviving example
        // once
        for _ in 0..dl.steps_per_epoch() {
            let b = dl.next_batch();
            prop_assert!(b.tokens.shape == vec![batch, seq]);
            prop_assert!(b.targets.shape == vec![batch, seq]);
            // every supervised target is a valid token id
            for &t in b.targets.data.iter() {
                prop_assert!(t >= -1 && (t as i64) < 512, "bad target {t}");
            }
        }
        let total: usize = dl.eval_batches().iter().map(|(_, r)| r).sum();
        prop_assert!(total == dl.len(), "eval covered {total}/{}", dl.len());
        Ok(())
    });
}

#[test]
fn prop_split_never_leaks_between_train_and_val() {
    prop_check("train/val disjointness", 60, |rng| {
        let n = 10 + rng.below(200);
        let frac = 0.05 + rng.f64() * 0.4;
        let items: Vec<usize> = (0..n).collect();
        let (tr, va) = split_train_val(&items, frac, rng.next_u64());
        prop_assert!(tr.len() + va.len() == n);
        let vs: std::collections::BTreeSet<_> = va.iter().collect();
        prop_assert!(tr.iter().all(|x| !vs.contains(x)), "overlap detected");
        Ok(())
    });
}

#[test]
fn prop_checkpoint_roundtrip_arbitrary_tensors() {
    prop_check("checkpoint roundtrip", 30, |rng| {
        use lisa::model::checkpoint::{load_tensors, save_tensors};
        use lisa::runtime::HostTensor;
        let dir = std::env::temp_dir().join("lisa_prop_ckpt");
        std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
        let path = dir.join(format!("t{}.ckpt", rng.next_u64()));
        let n_tensors = 1 + rng.below(6);
        let mut tensors = Vec::new();
        for i in 0..n_tensors {
            let rank = 1 + rng.below(3);
            let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(8)).collect();
            let mut t = HostTensor::zeros(&shape);
            rng.fill_normal(&mut t.data, 1.0);
            tensors.push((format!("t{i}"), t));
        }
        let refs: Vec<(String, &HostTensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        save_tensors(&path, &refs).map_err(|e| e.to_string())?;
        let loaded = load_tensors(&path).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        prop_assert!(loaded.len() == n_tensors);
        for (name, t) in &tensors {
            prop_assert!(loaded.get(name) == Some(t), "tensor {name} corrupted");
        }
        Ok(())
    });
}

#[test]
fn prop_tokenizer_encode_ids_in_range() {
    prop_check("tokenizer id range", 40, |rng| {
        let vocab = 64 + rng.below(1000);
        let samples = corpus::gen_instruction_corpus(32, rng.next_u64());
        let texts = corpus::sample_texts(&samples);
        let tok = Tokenizer::build(&texts, vocab);
        prop_assert!(tok.vocab_size() <= vocab);
        for t in &texts {
            for id in tok.encode(t) {
                prop_assert!(id >= 0 && (id as usize) < tok.vocab_size());
            }
        }
        Ok(())
    });
}
