//! Device-resident flow conformance (artifact-gated, like `it_train.rs`):
//!
//! * the device-cached execution path must reproduce the host-roundtrip
//!   path **bit for bit** — loss curve, gradients-as-applied (via final
//!   params) and eval params — for every registered strategy;
//! * cache invalidation must be airtight: resume-from-checkpoint and the
//!   LoRA `eval_params` merge must never be served stale device buffers;
//! * with the cache warm, weight uploads must scale with the *trainable*
//!   tensor set only (the LISA frozen-majority win the tentpole is for).

use std::path::{Path, PathBuf};

use lisa::data::{corpus, encode_sft, DataLoader, Tokenizer};
use lisa::engine::Engine;
use lisa::model::{checkpoint, ModelParams};
use lisa::runtime::Runtime;
use lisa::strategy::StrategySpec;
use lisa::train::{TrainConfig, TrainSession};
use lisa::util::rng::Rng;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

fn have() -> bool {
    artifacts().join("manifest.json").exists()
}

fn make_loader(rt: &Runtime) -> DataLoader {
    let m = &rt.manifest;
    let samples = corpus::gen_instruction_corpus(96, 19);
    let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);
    let enc: Vec<_> = samples.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    DataLoader::new(enc, m.batch, m.seq, 5)
}

fn cfg() -> TrainConfig {
    TrainConfig {
        steps: 8,
        lr: 3e-3,
        warmup: 3,
        grad_accum: 2, // exercise within-step buffer reuse across microbatches
        log_every: 0,
        ..Default::default()
    }
}

fn specs() -> Vec<StrategySpec> {
    vec![
        StrategySpec::ft(),
        StrategySpec::lisa(2, 3),
        StrategySpec::lisa_fixed(2, 3),
        StrategySpec::lisa_grad(2, 3),
        StrategySpec::lora(),
        StrategySpec::galore(4).with("update-proj-gap", 4),
    ]
}

struct RunOut {
    losses: Vec<f32>,
    params: Vec<(String, Vec<f32>)>,
    eval_params: Vec<(String, Vec<f32>)>,
}

fn snapshot(p: &ModelParams) -> Vec<(String, Vec<f32>)> {
    p.iter().map(|(k, t)| (k.name(), t.data.clone())).collect()
}

fn run(spec: &StrategySpec, device_flow: bool) -> RunOut {
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let mut dl = make_loader(&rt);
    let mut sess = TrainSession::new(&rt, spec, cfg()).unwrap();
    sess.engine.device_flow = device_flow;
    let res = sess.run(&mut dl).unwrap();
    RunOut {
        losses: res.loss_curve.iter().map(|&(_, l)| l).collect(),
        params: snapshot(&sess.params),
        eval_params: snapshot(&sess.eval_params()),
    }
}

fn assert_params_eq(a: &[(String, Vec<f32>)], b: &[(String, Vec<f32>)], what: &str, arm: &str) {
    assert_eq!(a.len(), b.len(), "[{arm}] {what}: tensor count");
    for ((na, da), (nb, db)) in a.iter().zip(b) {
        assert_eq!(na, nb, "[{arm}] {what}: tensor order");
        let identical = da.len() == db.len()
            && da.iter().zip(db).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(
            identical,
            "[{arm}] {what}: tensor '{na}' differs between device and host paths"
        );
    }
}

#[test]
fn device_flow_reproduces_host_path_bit_for_bit() {
    if !have() {
        return;
    }
    for spec in specs() {
        let arm = spec.name.clone();
        let dev = run(&spec, true);
        let host = run(&spec, false);
        assert_eq!(dev.losses.len(), host.losses.len(), "[{arm}] curve length");
        for (i, (a, b)) in dev.losses.iter().zip(&host.losses).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "[{arm}] loss diverged at step {i}: device {a} vs host {b}"
            );
        }
        assert_params_eq(&dev.params, &host.params, "final params", &arm);
        assert_params_eq(&dev.eval_params, &host.eval_params, "eval params", &arm);
    }
}

#[test]
fn resume_from_checkpoint_never_serves_stale_buffers() {
    if !have() {
        return;
    }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let mut dl = make_loader(&rt);
    let batch = dl.next_batch();

    // Engine A warms its device cache on params_a...
    let params_a = ModelParams::init(&rt.manifest, &mut Rng::new(5));
    let params_b = ModelParams::init(&rt.manifest, &mut Rng::new(99));
    let mut eng = Engine::new(&rt);
    let loss_a = eng.forward_loss(&params_a, &batch).unwrap();

    // ...then the weights are rewritten *in place* (exactly what
    // checkpoint resume does) and the cache is invalidated, as
    // `TrainSession::resume_checkpoint` does.
    let mut params = params_a;
    let mut sec = checkpoint::model_section(&params_b);
    checkpoint::load_model_section(&mut sec, &mut params).unwrap();
    eng.invalidate_all();
    let loss_after = eng.forward_loss(&params, &batch).unwrap();

    // Reference: a completely fresh engine on the same weights.
    let mut fresh = Engine::new(&rt);
    let loss_fresh = fresh.forward_loss(&params, &batch).unwrap();
    assert!(
        loss_after.to_bits() == loss_fresh.to_bits(),
        "post-restore loss {loss_after} != fresh-engine loss {loss_fresh} — stale device buffers"
    );
    assert!(
        loss_after.to_bits() != loss_a.to_bits(),
        "restore changed every weight; identical loss means the old buffers were served"
    );
}

#[test]
fn lora_eval_params_never_serve_stale_buffers() {
    if !have() {
        return;
    }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let mut dl = make_loader(&rt);
    let mut sess = TrainSession::new(&rt, &StrategySpec::lora(), cfg()).unwrap();
    for step in 0..3 {
        sess.step(step, &mut dl).unwrap();
    }
    // The merged eval view is a different parameter store; evaluating it
    // through the *training* engine (whose cache is full of frozen base
    // weights under the same keys) must equal a fresh engine's answer.
    let merged = sess.eval_params();
    let batch = dl.next_batch();
    let through_train_engine = sess.engine.forward_loss(&merged, &batch).unwrap();
    let mut fresh = Engine::new(&rt);
    let through_fresh_engine = fresh.forward_loss(&merged, &batch).unwrap();
    assert!(
        through_train_engine.to_bits() == through_fresh_engine.to_bits(),
        "merged-LoRA eval through the training engine served stale base buffers \
         ({through_train_engine} vs {through_fresh_engine})"
    );
    // and the base model itself still evaluates unperturbed afterwards
    let base_loss = sess.engine.forward_loss(&sess.params, &batch).unwrap();
    let fresh_base = fresh.forward_loss(&sess.params, &batch).unwrap();
    assert!(base_loss.to_bits() == fresh_base.to_bits());
}

#[test]
fn warm_cache_uploads_scale_with_trainable_tensors_only() {
    if !have() {
        return;
    }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let m = rt.manifest.clone();
    let gamma = 1usize;
    let n_block_tensors = m.block_params.len();
    let mut dl = make_loader(&rt);
    // long period so steps 0 and 1 share one mask
    let spec = StrategySpec::lisa(gamma, 100);
    let mut sess = TrainSession::new(
        &rt,
        &spec,
        TrainConfig { steps: 0, lr: 1e-3, grad_accum: 1, log_every: 0, ..Default::default() },
    )
    .unwrap();
    assert!(sess.engine.device_flow, "device flow must be the default");

    // Cold step: every parameter tensor is uploaded into the cache once.
    sess.step(0, &mut dl).unwrap();
    let cold = sess.engine.device_cache_stats();
    assert_eq!(
        cold.misses as usize,
        m.n_layers * n_block_tensors + 4,
        "cold step must upload every weight tensor exactly once (+emb/pos/gf/wh)"
    );

    // Warm step, same mask: only what the optimizer touched re-uploads —
    // γ blocks' tensors plus embed/head. ~((L-γ)/L) of block-weight
    // uploads are gone, which is the tentpole's whole point.
    rt.reset_stats();
    sess.step(1, &mut dl).unwrap();
    let warm = sess.engine.device_cache_stats();
    let warm_misses = warm.misses - cold.misses;
    assert_eq!(
        warm_misses as usize,
        gamma * n_block_tensors + 4,
        "warm-step uploads must scale with the trainable subset only"
    );
    assert!(
        warm.hits > cold.hits,
        "frozen-block weights must be served from the device cache"
    );

    // Per-segment ExecStats: with chainable artifacts, block_fwd moves no
    // host data at all on a warm step (weights cached, h chained);
    // with legacy tuple-rooted artifacts the h literal is its only upload.
    let stats = rt.stats();
    let bf = stats.get("block_fwd").expect("block_fwd ran");
    let chainable = m.segment("block_fwd", "pallas").unwrap().device_chainable();
    if chainable {
        assert_eq!(
            bf.uploads, 0,
            "warm block_fwd must not upload anything (weights cached, h chained)"
        );
    } else {
        assert!(
            bf.uploads <= m.n_layers as u64,
            "warm block_fwd may upload at most the chained h per call"
        );
    }
    assert!(bf.buf_hits > 0, "block_fwd operands must be device-served");
}
