//! Deep engine integration: the masked backward (LISA's bwd_full/bwd_x
//! routing) must produce *identical* gradients to the full backward on the
//! unfrozen subset, LoRA zero-B must match base forward, and the eval
//! harness must be self-consistent.

use std::path::{Path, PathBuf};

use lisa::data::{corpus, encode_sft, DataLoader, Tokenizer};
use lisa::engine::{Batch, Engine, TrainMask};
use lisa::eval;
use lisa::lora::{forward_backward_lora, LoraState};
use lisa::model::ModelParams;
use lisa::runtime::{HostTensorI32, Runtime};
use lisa::util::rng::Rng;
use lisa::util::stats::allclose;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

fn make_batch(m: &lisa::runtime::Manifest, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let n = m.batch * m.seq;
    let tokens: Vec<i32> = (0..n).map(|_| rng.below(m.vocab) as i32).collect();
    let targets: Vec<i32> = tokens
        .iter()
        .enumerate()
        .map(|(i, &t)| if i % 3 == 0 { -1 } else { t })
        .collect();
    Batch {
        tokens: HostTensorI32::from_vec(&[m.batch, m.seq], tokens),
        targets: HostTensorI32::from_vec(&[m.batch, m.seq], targets),
    }
}

#[test]
fn masked_grads_equal_full_grads_on_unfrozen_subset() {
    if !artifacts().join("manifest.json").exists() { return; }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(3));
    let batch = make_batch(&m, 5);

    let mut eng = Engine::new(&rt);
    let full = eng
        .forward_backward(&params, &batch, &TrainMask::all(m.n_layers))
        .unwrap();

    // freeze all but block 1 (embed+head on)
    let mut mask = TrainMask::none(m.n_layers);
    mask.embed = true;
    mask.head = true;
    mask.blocks[1] = true;
    let masked = eng.forward_backward(&params, &batch, &mask).unwrap();

    assert!((full.loss - masked.loss).abs() < 1e-5, "losses must match");
    // unfrozen block grads identical
    let a = full.grads.blocks[1].as_ref().unwrap();
    let b = masked.grads.blocks[1].as_ref().unwrap();
    for (x, y) in a.iter().zip(b) {
        assert!(allclose(&x.data, &y.data, 1e-4, 1e-5), "block grads diverge");
    }
    // embed/head grads identical
    assert!(allclose(
        &full.grads.wh.as_ref().unwrap().data,
        &masked.grads.wh.as_ref().unwrap().data,
        1e-4, 1e-5
    ));
    assert!(allclose(
        &full.grads.emb.as_ref().unwrap().data,
        &masked.grads.emb.as_ref().unwrap().data,
        1e-4, 1e-5
    ));
    // frozen blocks carry no grads
    assert!(masked.grads.blocks[0].is_none());
    assert!(masked.grads.blocks[2].is_none());
}

#[test]
fn backward_early_stop_does_not_change_unfrozen_grads() {
    if !artifacts().join("manifest.json").exists() { return; }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(4));
    let batch = make_batch(&m, 6);
    let mut eng = Engine::new(&rt);

    // embed frozen, only top block trainable: backward should stop early
    let mut mask = TrainMask::none(m.n_layers);
    mask.head = true;
    mask.blocks[m.n_layers - 1] = true;
    let out = eng.forward_backward(&params, &batch, &mask).unwrap();
    assert!(eng.bwd_skipped as usize >= m.n_layers - 1, "must skip dead backward");
    assert!(out.grads.emb.is_none());

    // compare against the full-backward reference for the same block
    let full = eng
        .forward_backward(&params, &batch, &TrainMask::all(m.n_layers))
        .unwrap();
    let a = out.grads.blocks[m.n_layers - 1].as_ref().unwrap();
    let b = full.grads.blocks[m.n_layers - 1].as_ref().unwrap();
    for (x, y) in a.iter().zip(b) {
        assert!(allclose(&x.data, &y.data, 1e-4, 1e-5));
    }
}

#[test]
fn lora_zero_b_forward_matches_base_and_grads_flow() {
    if !artifacts().join("manifest.json").exists() { return; }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(7));
    let lora = LoraState::init(&m, &mut Rng::new(8));
    let batch = make_batch(&m, 9);
    let mut eng = Engine::new(&rt);

    let (loss_lora, grads) = forward_backward_lora(&mut eng, &params, &lora, &batch).unwrap();
    let loss_base = eng.forward_loss(&params, &batch).unwrap();
    assert!((loss_lora - loss_base).abs() < 1e-5, "B=0 ⇒ identical loss");

    // B grads must be nonzero (dL/dB = scale * (x A)^T dy ≠ 0 generically),
    // A grads are zero at B=0 (dL/dA = x^T dy B^T = 0).
    let gb = &grads[0][1];
    assert!(gb.data.iter().any(|&x| x != 0.0), "dB must flow");
    let ga = &grads[0][0];
    assert!(ga.data.iter().all(|&x| x.abs() < 1e-6), "dA must be 0 at B=0");
}

#[test]
fn eval_harness_consistency() {
    if !artifacts().join("manifest.json").exists() { return; }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(11));
    let samples = corpus::gen_instruction_corpus(48, 13);
    let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);
    let enc: Vec<_> = samples.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    let dl = DataLoader::new(enc, m.batch, m.seq, 1);
    let mut eng = Engine::new(&rt);

    let rep = eval::evaluate(&mut eng, &params, &dl).unwrap();
    assert!(rep.loss > 0.0 && rep.loss.is_finite());
    assert!((rep.ppl - rep.loss.exp()).abs() < 1e-6);
    assert!((0.0..=1.0).contains(&rep.token_acc));
    assert!((0.0..=1.0).contains(&rep.exact_match));
    // untrained model must be near chance on token accuracy
    assert!(rep.token_acc < 0.3, "untrained acc {}", rep.token_acc);

    // category scores bounded and averaged correctly
    let (cats, avg) = eval::category_scores(&mut eng, &params, &dl).unwrap();
    assert!(!cats.is_empty());
    for (_, s) in &cats {
        assert!((0.0..=10.0).contains(s));
    }
    let mean: f64 = cats.values().sum::<f64>() / cats.len() as f64;
    assert!((mean - avg).abs() < 1e-9);

    // early exit at full depth == full logits path
    let em_full = eval::exact_match_at_depth(&mut eng, &params, &dl, m.n_layers).unwrap();
    assert!((em_full - rep.exact_match).abs() < 1e-9);
}

#[test]
fn logits_at_depth_zero_differs_from_full() {
    if !artifacts().join("manifest.json").exists() { return; }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let m = rt.manifest.clone();
    let params = ModelParams::init(&m, &mut Rng::new(12));
    let batch = make_batch(&m, 14);
    let mut eng = Engine::new(&rt);
    let l0 = eng.logits_at(&params, &batch.tokens, 0).unwrap();
    let lf = eng.logits(&params, &batch.tokens).unwrap();
    assert_eq!(l0.shape, lf.shape);
    assert!(!allclose(&l0.data, &lf.data, 1e-3, 1e-3), "depth-0 must differ");
}
