//! End-to-end integration: every registered training strategy reduces the
//! loss on the tiny config, and LISA's scheduling behaviour shows up in
//! engine stats.

use std::path::{Path, PathBuf};

use lisa::data::{corpus, encode_sft, DataLoader, Tokenizer};
use lisa::runtime::Runtime;
use lisa::strategy::StrategySpec;
use lisa::train::{TrainConfig, TrainSession};

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

fn setup(rt: &Runtime) -> (Tokenizer, DataLoader) {
    let m = &rt.manifest;
    let samples = corpus::gen_instruction_corpus(128, 11);
    let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);
    let enc: Vec<_> = samples.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    let dl = DataLoader::new(enc, m.batch, m.seq, 5);
    (tok, dl)
}

fn run(spec: &StrategySpec, steps: usize) -> (f32, f32, lisa::train::TrainResult) {
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let (_tok, mut dl) = setup(&rt);
    let cfg = TrainConfig {
        steps,
        lr: 3e-3,
        warmup: 5,
        log_every: 0,
        ..Default::default()
    };
    let mut sess = TrainSession::new(&rt, spec, cfg).unwrap();
    let first_losses: Vec<f32> = (0..3)
        .map(|s| sess.step(s, &mut dl).unwrap())
        .collect();
    let res = sess.run(&mut dl).unwrap();
    (
        first_losses[0],
        res.final_train_loss,
        res,
    )
}

#[test]
fn ft_reduces_loss() {
    if !artifacts().join("manifest.json").exists() { return; }
    let (first, last, res) = run(&StrategySpec::ft(), 30);
    assert!(last < first * 0.9, "FT loss {first} -> {last}");
    assert_eq!(res.bwd_x_calls, 0, "FT never uses input-only backward");
    assert!(res.peak_mem > 0);
}

#[test]
fn lisa_reduces_loss_and_freezes_blocks() {
    if !artifacts().join("manifest.json").exists() { return; }
    let (first, last, res) = run(&StrategySpec::lisa(2, 5), 30);
    assert!(last < first * 0.9, "LISA loss {first} -> {last}");
    // tiny has 4 blocks, γ=2: every step does 2 full + 2 input-only bwd
    assert!(res.bwd_x_calls > 0, "LISA must freeze some blocks");
    assert!(res.bwd_full_calls > 0);
    let total_steps = (30 + 3) as u64;
    assert_eq!(res.bwd_full_calls + res.bwd_x_calls + res.bwd_skipped,
               total_steps * 4);
}

#[test]
fn lisa_grad_reduces_loss_and_freezes_blocks() {
    if !artifacts().join("manifest.json").exists() { return; }
    let (first, last, res) = run(&StrategySpec::lisa_grad(2, 5), 30);
    assert!(last < first * 0.9, "LISA-grad loss {first} -> {last}");
    // same γ invariant as uniform LISA: never trains all blocks at once
    assert!(res.bwd_x_calls > 0, "LISA-grad must freeze some blocks");
    assert!(res.bwd_full_calls > 0);
    let total_steps = (30 + 3) as u64;
    assert_eq!(res.bwd_full_calls + res.bwd_x_calls + res.bwd_skipped,
               total_steps * 4);
}

#[test]
fn lora_reduces_loss() {
    if !artifacts().join("manifest.json").exists() { return; }
    let (first, last, _res) = run(&StrategySpec::lora(), 30);
    assert!(last < first * 0.95, "LoRA loss {first} -> {last}");
}

#[test]
fn galore_reduces_loss() {
    if !artifacts().join("manifest.json").exists() { return; }
    let (first, last, _res) = run(
        &StrategySpec::galore(4).with("update-proj-gap", 10usize).with("scale", 1.0f32),
        30,
    );
    assert!(last < first * 0.95, "GaLore loss {first} -> {last}");
}

#[test]
fn cosine_schedule_trains_end_to_end() {
    if !artifacts().join("manifest.json").exists() { return; }
    let rt = Runtime::load(&artifacts(), "pallas").unwrap();
    let (_tok, mut dl) = setup(&rt);
    let cfg = TrainConfig {
        steps: 20,
        lr: 3e-3,
        warmup: 3,
        schedule: lisa::train::LrSchedule::WarmupCosine { min_factor: 0.1 },
        log_every: 0,
        ..Default::default()
    };
    let mut sess = TrainSession::new(&rt, &StrategySpec::ft(), cfg).unwrap();
    let res = sess.run(&mut dl).unwrap();
    let first = res.loss_curve.first().unwrap().1;
    assert!(res.final_train_loss < first, "cosine FT must still descend");
}
