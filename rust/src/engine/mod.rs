//! Layer-granular training engine + memory accounting.

pub mod memory;
pub mod trainer;

pub use memory::{MemCategory, MemoryMeter};
pub use trainer::{Batch, Engine, Grads, StepOutput, Touched, TrainMask};
