//! Layer-granular training engine + memory accounting + the serving
//! subsystem (static KV-cached decode and continuous batching).

pub mod decode;
pub mod memory;
pub mod serve;
pub mod trainer;

pub use decode::{Completion, DecodeSession, FailClass, PageAllocator, ServeFail, StopReason};
pub use memory::{MemCategory, MemoryMeter};
pub use serve::{
    CancelToken, Feed, KvMode, LoopStats, Request, RequestSink, RequestSource, Sampler,
    SamplerSpec, ServeSession,
};
pub use trainer::{Batch, Engine, Grads, QuantMode, StepOutput, Touched, TrainMask};
