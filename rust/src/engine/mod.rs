//! Layer-granular training engine + memory accounting + the batched
//! KV-cached decode session (serving).

pub mod decode;
pub mod memory;
pub mod trainer;

pub use decode::{Completion, DecodeSession, StopReason};
pub use memory::{MemCategory, MemoryMeter};
pub use trainer::{Batch, Engine, Grads, StepOutput, Touched, TrainMask};
