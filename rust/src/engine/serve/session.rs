//! Continuous-batching serve session over the decode ABI (DESIGN.md §10,
//! §11).
//!
//! [`ServeSession::run_loop`] drives one device-resident batch through
//! the decode segments and keeps every row busy: requests are pulled
//! incrementally from a [`RequestSource`] (an in-memory slice for
//! [`ServeSession::run`], a bounded channel for `serve_http`) and are
//! handed a row the moment a completion drains (EOS / budget / window /
//! stop sequence), instead of the whole batch blocking on its slowest
//! row. Each admission carries its own [`RequestSink`]; committed tokens
//! are emitted as each `decode_step` lands (stop-sequence tails held
//! back, never retracted), which is what the HTTP front end streams over
//! SSE. The row-slot lifecycle is
//!
//! ```text
//! Vacant -> Prefilling -> Decoding -> Drained -> (admission) Prefilling ...
//! ```
//!
//! **Two prefill modes, one invariant.** When no row holds in-flight K/V
//! (session start, or a full drain with requests still queued), admitted
//! prompts prefill as one batch through the training segments
//! (`embed_fwd -> (prefill_kv + block_fwd)^L -> [head_logits] ->
//! pack_state`). When busy rows exist, an admitted row *streams* its
//! prompt through `decode_step` — one K/V column per step, teacher-forced
//! — while the other rows keep decoding in the same executions. Either
//! way a step rewrites only each row's own current column: frozen and
//! drained rows replay their last `(tok, pidx)`, which rewrites the same
//! cache bytes (idempotent), so admission never perturbs a busy row and
//! rides the packed-state ABI without any new segment export.
//!
//! `head_logits` is skipped entirely when no prefilled row consumes it —
//! every first token forced, or every row zero-budget — saving the
//! `[B, T, V]` download (the ROADMAP serving item; asserted via
//! `ExecStats` in `tests/it_decode.rs`). The per-step `decode_logits`
//! download is likewise skipped on steps where no row reads it (only
//! mid-prompt columns streamed).
//!
//! Samplers are per-request seeded ([`super::sampler`]), so a completion
//! is a function of `(prompt, spec, seed)` alone — `tests/it_serve.rs`
//! asserts continuous-batching parity against solo decodes. Staleness is
//! structural, exactly as for the static path: the session borrows the
//! engine and the parameter store for its whole lifetime.
//!
//! **K/V layouts.** On a decode-ABI v2 artifact dir the session runs
//! [`KvMode::Paged`] by default (DESIGN.md §12): the packed per-row
//! window is replaced by fixed-size pages in a shared pool, a per-step
//! `[B, P]` page table routes each row's reads/writes, and a drained
//! row's fully prefilled prompt pages go to a prefix cache
//! ([`PageAllocator`]) so later requests sharing the prefix adopt them —
//! skipping that many prompt columns (and, for a 100% shared prefix, the
//! whole batch prefill). Token streams are identical in both modes
//! (`tests/it_paged.rs`); `LISA_PAGED=0` forces the packed v1 path.

// Clippy backstop for the no-panic serving contract (DESIGN.md §13,
// enforced structurally by lisa-lint's serve_panic pass).
#![warn(clippy::unwrap_used, clippy::expect_used)]
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::engine::decode::{
    clip_prompt, Completion, FailClass, PageAllocator, ServeFail, StopReason,
};
use crate::engine::memory::MemCategory;
use crate::engine::trainer::{Act, Engine, ParamOp, QuantMode, TrainMask};
use crate::model::ModelParams;
use crate::runtime::fault::{FaultError, FaultKind};
use crate::runtime::{HostTensor, HostTensorI32, Operand, DECODE_ABI, PAGED_ABI};

use super::sampler::{Sampler, SamplerSpec};

/// Per-request cancellation flag, shared between the connection thread
/// (which flips it on client disconnect or deadline) and the model thread
/// (which observes it between steps and drains the row, releasing its
/// pages). Cloning shares the flag.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// Which K/V layout a session runs on.
///
/// [`KvMode::Packed`] is decode ABI v1 (DESIGN.md §9): one
/// `[B, L*2T+1, D]` tensor, rebuilt from scratch by every batch prefill.
/// [`KvMode::Paged`] is decode ABI v2 (DESIGN.md §12): fixed-size K/V
/// pages in a shared per-layer-half pool, indexed by a per-step
/// `[B, P]` page table, with prompt pages reusable across requests
/// through the [`PageAllocator`] prefix cache. Both modes are
/// token-for-token identical (`tests/it_paged.rs`); v1 artifact dirs can
/// only run `Packed`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvMode {
    Packed,
    Paged,
}

/// Session-lifetime paged-mode state: the host-side page bookkeeping and
/// the device-resident `[rows, D]` pool tensor. Unlike the packed state
/// (rebuilt per prefill, dropped at loop exit), the pool *persists
/// across* [`ServeSession::run`] calls — that's what keeps cached prefix
/// pages adoptable by later bursts.
struct PagedPool {
    alloc: PageAllocator,
    /// Device-chained `[state_rows, D]` pool; `None` until first prefill.
    state: Option<Act>,
    /// Pages per row (`P` — the page-table width).
    p: usize,
    /// Pool tensor rows (`L*2*page_n*page_t + B`).
    rows: usize,
}

/// The per-step `[B, P]` page table: row r's logical page j maps to its
/// j-th allocated page, scratch (0) beyond — writes by pageless rows
/// land on scratch, reads of unwritten positions are masked out.
fn page_table(slots: &[RowSlot], bsz: usize, p: usize) -> HostTensorI32 {
    let mut t = vec![0i32; bsz * p];
    for (r, slot) in slots.iter().enumerate() {
        if let Some(occ) = &slot.0 {
            for (j, &g) in occ.pages.iter().enumerate().take(p) {
                t[r * p + j] = crate::util::cast::idx_i32(g as usize);
            }
        }
    }
    HostTensorI32::from_vec(&[bsz, p], t)
}

/// One generation request: a token-id prompt (including leading specials,
/// see `eval::generate::encode_prompt`) plus its decode policy.
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<i32>,
    /// Generation budget; 0 decodes nothing (and costs nothing).
    pub max_new: usize,
    /// Sampling policy; [`SamplerSpec::Greedy`] reproduces the static
    /// greedy path bit for bit.
    pub sampler: SamplerSpec,
    /// Seed of this request's sampler stream (ignored when the spec is
    /// greedy-degenerate).
    pub seed: u64,
    /// Forced first generated token: emitted without consulting the
    /// model. A batch whose every row is forced (or zero-budget) skips
    /// the prefill `head_logits` download.
    pub first_token: Option<i32>,
    /// Per-request stop sequences (token-id suffix match over the
    /// *generated* tokens). A match drains the row with
    /// [`StopReason::StopSeq`] and the matched suffix is excluded from
    /// the returned tokens. Empty sequences are ignored.
    pub stop: Vec<Vec<i32>>,
    /// Cancellation flag, observed between steps: once flipped the row is
    /// drained with [`FailClass::Cancelled`] and its pages are released.
    /// `None` makes the request uncancellable.
    pub cancel: Option<CancelToken>,
}

impl Request {
    pub fn greedy(prompt: Vec<i32>, max_new: usize) -> Request {
        Request {
            prompt,
            max_new,
            sampler: SamplerSpec::Greedy,
            seed: 0,
            first_token: None,
            stop: Vec::new(),
            cancel: None,
        }
    }

    pub fn sampled(prompt: Vec<i32>, max_new: usize, sampler: SamplerSpec, seed: u64) -> Request {
        Request { sampler, seed, ..Request::greedy(prompt, max_new) }
    }

    /// Builder-style stop-sequence attachment.
    pub fn with_stop(mut self, stop: Vec<Vec<i32>>) -> Request {
        self.stop = stop;
        self
    }
}

/// Pure per-row decode bookkeeping (unit-tested without a runtime):
/// mirrors the legacy greedy loop's stop conditions exactly so the cached
/// paths stay token-for-token compatible with it.
#[derive(Debug)]
pub(crate) struct RowPlan {
    /// Prompt plus everything generated so far.
    pub(crate) seq: Vec<i32>,
    truncated: bool,
    out: Vec<i32>,
    stop: Option<StopReason>,
    max_new: usize,
    seq_cap: usize,
    eos: i32,
    /// Per-request stop sequences (suffix-matched over `out`).
    stop_seqs: Vec<Vec<i32>>,
}

impl RowPlan {
    pub(crate) fn new(prompt: Vec<i32>, seq_cap: usize, max_new: usize, eos: i32) -> RowPlan {
        Self::with_stops(prompt, seq_cap, max_new, eos, Vec::new())
    }

    pub(crate) fn with_stops(
        mut prompt: Vec<i32>,
        seq_cap: usize,
        max_new: usize,
        eos: i32,
        mut stop_seqs: Vec<Vec<i32>>,
    ) -> RowPlan {
        assert!(!prompt.is_empty(), "decode rows need at least one token");
        let truncated = clip_prompt(&mut prompt, seq_cap);
        let stop = (max_new == 0).then_some(StopReason::MaxNew);
        // an empty stop sequence would match the empty suffix immediately
        stop_seqs.retain(|s| !s.is_empty());
        RowPlan { seq: prompt, truncated, out: Vec::new(), stop, max_new, seq_cap, eos, stop_seqs }
    }

    pub(crate) fn alive(&self) -> bool {
        self.stop.is_none()
    }

    /// Feed the token chosen for this row (sampled, argmax or forced).
    /// Stop-sequence matches win over the `max_new` budget when the same
    /// token triggers both — the matched suffix is excluded either way.
    pub(crate) fn push(&mut self, id: i32) {
        debug_assert!(self.alive());
        if id == self.eos {
            self.stop = Some(StopReason::Eos);
            return;
        }
        self.seq.push(id);
        self.out.push(id);
        if let Some(n) = self.stop_hit() {
            // `seq` keeps the matched tokens: their K/V columns are
            // already written and the frozen replay stays idempotent
            self.out.truncate(self.out.len() - n);
            self.stop = Some(StopReason::StopSeq);
        } else if self.out.len() >= self.max_new {
            self.stop = Some(StopReason::MaxNew);
        } else if self.seq.len() >= self.seq_cap {
            // the legacy loop breaks at the top of the next iteration
            self.stop = Some(StopReason::WindowFull);
        }
    }

    /// Length of the longest stop sequence that is a suffix of `out`.
    fn stop_hit(&self) -> Option<usize> {
        self.stop_seqs
            .iter()
            .filter(|s| self.out.ends_with(s))
            .map(Vec::len)
            .max()
    }

    /// How many generated tokens are safe to stream now: everything
    /// except the longest tail that could still grow into a stop-sequence
    /// match. Monotone non-decreasing across pushes (a new partial match
    /// extends the held tail by at most the one token just pushed), so
    /// streamed tokens are never retracted; on drain everything left in
    /// `out` flushes (a `StopSeq` drain has already truncated the match).
    pub(crate) fn committed(&self) -> usize {
        if self.stop.is_some() {
            return self.out.len();
        }
        let mut hold = 0;
        for s in &self.stop_seqs {
            let longest = (s.len() - 1).min(self.out.len());
            for h in (hold + 1..=longest).rev() {
                if self.out.ends_with(&s[..h]) {
                    hold = h;
                    break;
                }
            }
        }
        self.out.len() - hold
    }

    pub(crate) fn out(&self) -> &[i32] {
        &self.out
    }

    /// Terminal stop outside the sampling path — used when a scheduler
    /// contract is breached, so the row drains with `stop` instead of
    /// panicking the whole batch.
    pub(crate) fn halt(&mut self, stop: StopReason) {
        self.stop = Some(stop);
    }

    /// Upper bound on this row's final sequence length: everything in
    /// `seq` plus the remaining generation budget, clamped to the window.
    /// Page-budget reservation sizes a row's worst-case need from this.
    pub(crate) fn max_total_len(&self) -> usize {
        (self.seq.len() + self.max_new.saturating_sub(self.out.len())).min(self.seq_cap)
    }

    /// `(token, position)` this row contributes to the next `decode_step`.
    /// Done rows in a still-running batch freeze on their last token —
    /// rewriting the same cache slot with the same bytes (idempotent, and
    /// rows are independent, so live rows are unaffected).
    #[allow(clippy::expect_used)] // invariant: see the lint allow below
    pub(crate) fn step_input(&self) -> (i32, i32) {
        // lisa-lint: allow(serve_panic): the constructor asserts a non-empty prompt and `seq` only grows
        (*self.seq.last().expect("non-empty"), crate::util::cast::idx_i32(self.seq.len() - 1))
    }

    pub(crate) fn into_completion(self) -> Completion {
        Completion {
            tokens: self.out,
            prompt_truncated: self.truncated,
            stop: self.stop.unwrap_or(StopReason::MaxNew),
        }
    }
}

/// Per-request event receiver: the serve loop pushes committed tokens
/// (and the final [`Completion`]) into it from the model thread as each
/// `decode_step` lands. Implemented by the HTTP front end's channel sink
/// (`serve_http::server`) and by the in-memory collector behind
/// [`ServeSession::run`].
pub trait RequestSink {
    /// One newly committed generated token. Never retracted: tokens that
    /// could still complete a stop-sequence match are held back until
    /// they can't (see `RowPlan::committed`).
    fn on_token(&mut self, tok: i32);
    /// The row drained. `completion.tokens` repeats every token already
    /// delivered through [`RequestSink::on_token`].
    fn on_done(&mut self, completion: &Completion);
    /// The request failed (error drain, overload rejection, cancellation)
    /// and will never reach [`RequestSink::on_done`]. The default
    /// implementation folds the failure into a completion with
    /// [`StopReason::Error`] / [`StopReason::Cancelled`], so sinks
    /// without a failure channel still observe exactly one terminal
    /// event per request.
    fn on_fail(&mut self, fail: &ServeFail) {
        self.on_done(&Completion {
            tokens: fail.tokens.clone(),
            prompt_truncated: false,
            stop: fail.stop_reason(),
        });
    }
}

/// One admission poll outcome (see [`RequestSource::poll`]).
pub enum Feed {
    /// Admit this request into the freed row now; its events flow into
    /// the sink.
    Admit(Request, Box<dyn RequestSink>),
    /// Nothing queued right now — keep the live rows moving.
    Pending,
    /// No request will ever arrive again: drain in-flight rows and exit.
    Closed,
}

/// Counters [`RequestSource::observe`] sees once per loop iteration.
#[derive(Debug, Clone, Copy, Default)]
pub struct LoopStats {
    pub decode_steps: u64,
    pub batch_prefills: u64,
    pub streamed_prompt_tokens: u64,
    pub admitted: u64,
    /// Rows currently prefilling, decoding or parked.
    pub live_rows: usize,
    /// Transient execution failures absorbed by in-place retry.
    pub retries: u64,
    /// Rows that rebuilt their K/V from host bookkeeping after a fault.
    pub reprefills: u64,
    /// Rows drained with a typed error (fault budget exceeded, or shed
    /// under unrecoverable pool pressure).
    pub error_drains: u64,
    /// Rows preempted (pages released, parked) under pool pressure.
    pub preemptions: u64,
    /// Requests drained because their cancel token flipped.
    pub cancelled: u64,
    /// Admissions rejected by page-budget reservation (503 upstream).
    pub rejected: u64,
}

/// Feeds requests into [`ServeSession::run_loop`]. The in-memory slice
/// source behind [`ServeSession::run`] never blocks; the HTTP front end's
/// channel source blocks in `poll(idle = true)` so an idle server doesn't
/// spin.
pub trait RequestSource {
    /// Ask for the next request. `idle` is true when no row is live — the
    /// loop has nothing to overlap a wait with, so the source may (and
    /// should) block until a request arrives, the queue closes, or a
    /// short heartbeat elapses ([`Feed::Pending`] re-polls).
    fn poll(&mut self, idle: bool) -> Feed;
    /// Called once per loop iteration (admissions just handled, before
    /// the next prefill/step) and once more before [`run_loop`] returns.
    /// Metrics exporters snapshot [`crate::runtime::Runtime::stats`] here
    /// — this is the only hook that runs on the model thread, where the
    /// (`!Sync`) runtime is reachable.
    ///
    /// [`run_loop`]: ServeSession::run_loop
    fn observe(&mut self, _eng: &Engine, _stats: LoopStats) {}
}

/// Row-slot lifecycle (reported by [`RowSlot::state`]; the unit tier pins
/// the transitions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum SlotState {
    /// Never occupied (the queue ran out before this row was needed).
    Vacant,
    /// Streaming its prompt into the K/V cache (batched at session start,
    /// one column per `decode_step` when admitted mid-decode).
    Prefilling,
    /// Emitting tokens.
    Decoding,
    /// Preempted under page-pool pressure: pages released, K/V forgotten,
    /// waiting for headroom to re-prefill. The occupant (and its sampler
    /// stream) is intact, so an unparked row resumes token-identically.
    Parked,
    /// Completion finished; replays its frozen `(tok, pidx)` idempotently
    /// until harvested by the next admission (or the session end).
    Drained,
}

struct Occupant {
    plan: RowPlan,
    /// Prompt length after clipping — fixed at admission; `plan.seq`
    /// grows past it as tokens are generated.
    prompt_len: usize,
    /// Prompt tokens whose K/V columns are already written.
    fed: usize,
    sampler: Box<dyn Sampler>,
    first: Option<i32>,
    /// Where this request's tokens and completion go.
    sink: Box<dyn RequestSink>,
    /// Tokens already delivered to the sink (committed watermark).
    emitted: usize,
    /// Paged mode only: this row's K/V pages in logical order — adopted
    /// prefix pages first, then freshly allocated ones. Always empty in
    /// packed mode.
    pages: Vec<u32>,
    /// Execution failures charged to this row (bumped per quarantine);
    /// past the session's budget the row drains with a typed error.
    faults: u32,
    /// Preempted under pool pressure (see [`SlotState::Parked`]).
    parked: bool,
    /// How many times this row has been preempted; a second preemption
    /// drains it instead (the degradation ladder bottoms out).
    preempts: u32,
    /// Cancellation flag, observed by the loop between steps.
    cancel: Option<CancelToken>,
}

impl Occupant {
    fn state(&self) -> SlotState {
        if !self.plan.alive() {
            SlotState::Drained
        } else if self.parked {
            SlotState::Parked
        } else if self.fed < self.prompt_len {
            SlotState::Prefilling
        } else {
            SlotState::Decoding
        }
    }

    /// Forget the device K/V and schedule a full rebuild: the entire
    /// current sequence (prompt + generated tokens) becomes the "prompt"
    /// the next prefill teacher-forces. The sampler stream is untouched
    /// and failed steps never consumed a pick, so the rebuilt row
    /// continues token-identically — tokens are a function of
    /// `(prompt, spec, seed)` alone.
    fn re_prefill(&mut self) {
        self.prompt_len = self.plan.seq.len();
        self.fed = 0;
    }
}

/// One batch row and (maybe) the request occupying it.
#[derive(Default)]
pub(crate) struct RowSlot(Option<Occupant>);

impl RowSlot {
    pub(crate) fn state(&self) -> SlotState {
        self.0.as_ref().map_or(SlotState::Vacant, Occupant::state)
    }

    /// The row is spoken for: its request has not terminated. Parked rows
    /// count — their occupant is waiting for pool headroom, so admission
    /// must not overwrite them.
    pub(crate) fn live(&self) -> bool {
        matches!(
            self.state(),
            SlotState::Prefilling | SlotState::Decoding | SlotState::Parked
        )
    }

    /// No in-flight K/V this occupant still depends on — the row can take
    /// part in a fresh batch prefill.
    fn no_progress(&self) -> bool {
        match self.state() {
            SlotState::Vacant | SlotState::Drained | SlotState::Parked => true,
            SlotState::Prefilling => self.0.as_ref().map_or(true, |occ| occ.fed == 0),
            SlotState::Decoding => false,
        }
    }

    /// Whether the occupant's cancel token flipped while its request is
    /// still in flight (already-drained rows deliver normally).
    fn cancel_requested(&self) -> bool {
        self.0.as_ref().is_some_and(|occ| {
            occ.plan.alive() && occ.cancel.as_ref().is_some_and(CancelToken::is_cancelled)
        })
    }

    /// Give every page this row holds back to the allocator (refcounts
    /// drop; cache-adopted pages stay cached). No-op in packed mode.
    fn release_pages(&mut self, alloc: &mut PageAllocator) {
        if let Some(occ) = &mut self.0 {
            for g in std::mem::take(&mut occ.pages) {
                alloc.release(g);
            }
        }
    }

    /// Terminal error drain: fire [`RequestSink::on_fail`] with the tokens
    /// already delivered and free the row. Pages must already be released.
    fn fail(&mut self, class: FailClass, msg: &str) {
        let Some(occ) = self.0.take() else { return };
        debug_assert!(occ.pages.is_empty(), "pages must be released before fail");
        let mut fail = ServeFail::new(class, msg);
        fail.tokens = occ.plan.out().get(..occ.emitted).unwrap_or_default().to_vec();
        let mut sink = occ.sink;
        sink.on_fail(&fail);
    }

    /// Preempt under pool pressure: release every page, forget the device
    /// K/V (host bookkeeping rebuilds it on unpark) and park the row.
    fn park(&mut self, alloc: &mut PageAllocator) {
        self.release_pages(alloc);
        let Some(occ) = self.0.as_mut() else {
            debug_assert!(false, "parking an empty row");
            return;
        };
        occ.re_prefill();
        occ.parked = true;
        occ.preempts += 1;
    }

    fn admit(&mut self, req: Request, sink: Box<dyn RequestSink>, seq_cap: usize, eos: i32) {
        debug_assert!(!self.live(), "admitting into a live row");
        let sampler = req.sampler.build(req.seed);
        let plan = RowPlan::with_stops(req.prompt, seq_cap, req.max_new, eos, req.stop);
        let prompt_len = plan.seq.len();
        self.0 = Some(Occupant {
            plan,
            prompt_len,
            fed: 0,
            sampler,
            first: req.first_token,
            sink,
            emitted: 0,
            pages: Vec::new(),
            faults: 0,
            parked: false,
            preempts: 0,
            cancel: req.cancel,
        });
    }

    /// Paged admission: adopt cached prefix pages, then allocate the rest
    /// of the prompt's pages. Adopted pages are already prefilled, so
    /// `fed` starts at the adopted length — a multiple of `page_t`, at
    /// most `prompt_len - 1` ([`PageAllocator::lookup_prefix`] clamps) —
    /// and the row streams only the remaining prompt columns. A non-zero
    /// `fed` also keeps the row out of `no_progress`, so a 100% shared
    /// prefix re-runs *zero* batch-prefill segments (`tests/it_paged.rs`).
    fn attach_pages(&mut self, alloc: &mut PageAllocator) -> Result<()> {
        let Some(occ) = &mut self.0 else { return Ok(()) };
        debug_assert!(occ.pages.is_empty() && occ.fed == 0);
        if !occ.plan.alive() {
            return Ok(()); // zero-budget: drained at admission, no pages
        }
        let bt = alloc.page_t();
        occ.pages = alloc.lookup_prefix(&occ.plan.seq);
        occ.fed = occ.pages.len() * bt;
        let need = (occ.plan.seq.len() + bt - 1) / bt;
        while occ.pages.len() < need {
            occ.pages.push(alloc.alloc()?);
        }
        Ok(())
    }

    /// Paged decode growth: make sure the position this row writes next
    /// step has a backing page. Drained rows replay a position they
    /// already wrote (covered by construction) and rows that never wrote
    /// (zero-budget) fall through to scratch, so only live rows grow.
    fn ensure_page(&mut self, alloc: &mut PageAllocator) -> Result<()> {
        if !matches!(self.state(), SlotState::Prefilling | SlotState::Decoding) {
            return Ok(()); // parked rows hold no pages and write scratch
        }
        let Some(occ) = self.0.as_mut() else { return Ok(()) };
        let pos = match occ.state() {
            SlotState::Prefilling => occ.fed,
            _ => occ.plan.seq.len() - 1,
        };
        let need = pos / alloc.page_t() + 1;
        while occ.pages.len() < need {
            occ.pages.push(alloc.alloc()?);
        }
        Ok(())
    }

    /// Paged harvest, run just before [`RowSlot::take_done`]: register the
    /// drained row's fully prefilled prompt pages with the prefix cache
    /// (registration retains them first, so they survive the release),
    /// then release everything the row held.
    fn harvest_pages(&mut self, alloc: &mut PageAllocator) {
        if self.state() != SlotState::Drained {
            return;
        }
        let Some(occ) = self.0.as_mut() else { return };
        let pages = std::mem::take(&mut occ.pages);
        if occ.fed == occ.prompt_len {
            alloc.register_prefix(&occ.plan.seq[..occ.prompt_len], &pages);
        }
        for &g in &pages {
            alloc.release(g);
        }
    }

    /// Flush newly committed tokens to the occupant's sink.
    fn emit(&mut self) {
        if let Some(occ) = &mut self.0 {
            let c = occ.plan.committed();
            while occ.emitted < c {
                let Some(&tok) = occ.plan.out().get(occ.emitted) else { break };
                occ.sink.on_token(tok);
                occ.emitted += 1;
            }
        }
    }

    /// Harvest a drained occupant — flush its tail, fire
    /// [`RequestSink::on_done`], free the row. Returns whether a
    /// completion was delivered.
    fn take_done(&mut self) -> bool {
        if self.state() != SlotState::Drained {
            return false;
        }
        self.emit(); // drained: everything left in `out` is committed
        let Some(occ) = self.0.take() else { return false };
        let mut sink = occ.sink;
        sink.on_done(&occ.plan.into_completion());
        true
    }

    /// Whether this row consumes the prefill `head_logits` row (alive and
    /// not forced) — all-false across the batch skips the download.
    fn needs_prefill_logits(&self) -> bool {
        self.state() == SlotState::Prefilling
            && self.0.as_ref().is_some_and(|occ| occ.first.is_none())
    }

    /// Whether this row will read the *next* `decode_logits` row: it is
    /// decoding, or this step's feed completes its prompt unforced. When
    /// no row will, the whole `[B, 1, V]` download is skipped.
    fn consumes_next_logits(&self) -> bool {
        match &self.0 {
            None => false,
            Some(occ) => match occ.state() {
                SlotState::Decoding => true,
                SlotState::Prefilling => {
                    occ.fed + 1 == occ.prompt_len && occ.first.is_none()
                }
                SlotState::Vacant | SlotState::Parked | SlotState::Drained => false,
            },
        }
    }

    /// `(token, position)` columns this row feeds the next `decode_step`.
    fn step_input(&self, pad: i32) -> (i32, i32) {
        match &self.0 {
            None => (pad, 0),
            Some(occ) => match occ.state() {
                SlotState::Prefilling => {
                    (occ.plan.seq[occ.fed], crate::util::cast::idx_i32(occ.fed))
                }
                // parked rows hold no pages: write inertly onto scratch
                SlotState::Parked => (pad, 0),
                _ => occ.plan.step_input(),
            },
        }
    }

    /// Mark a batch-prefilled row fully fed and push its first token
    /// (forced, or picked from its prefill-logits row).
    fn finish_batch_prefill(
        &mut self,
        logits: Option<(&HostTensor, usize)>,
        t_max: usize,
        v: usize,
    ) {
        let Some(occ) = &mut self.0 else { return };
        if occ.state() != SlotState::Prefilling {
            return; // drained rows prefilled inertly (their grid row rides along)
        }
        occ.fed = occ.prompt_len;
        let tok = match (occ.first.take(), logits) {
            (Some(t), _) => t,
            (None, Some((lg, row))) => {
                let p = occ.prompt_len - 1;
                occ.sampler.pick(&lg.data[(row * t_max + p) * v..(row * t_max + p + 1) * v])
            }
            (None, None) => {
                // scheduler contract breach: an unforced row reached the
                // end of prefill with no logits downloaded. Drain this
                // row with an error instead of killing its neighbors.
                debug_assert!(false, "unforced rows need prefill logits");
                occ.plan.halt(StopReason::Error);
                self.emit();
                return;
            }
        };
        occ.plan.push(tok);
        self.emit();
    }

    /// Advance one decode step: a prefilling row records its fed column
    /// (emitting its first token once the prompt is fully cached), a
    /// decoding row samples its next token. `row_logits` is `None` only
    /// on steps [`RowSlot::consumes_next_logits`] reported nobody needs.
    fn consume(&mut self, row_logits: Option<&[f32]>) {
        let Some(occ) = &mut self.0 else { return };
        match occ.state() {
            SlotState::Prefilling => {
                occ.fed += 1;
                if occ.fed == occ.prompt_len {
                    let tok = match (occ.first.take(), row_logits) {
                        (Some(t), _) => t,
                        (None, Some(lg)) => occ.sampler.pick(lg),
                        (None, None) => {
                            // scheduler contract breach (see
                            // `consumes_next_logits`): drain the row
                            // instead of panicking the batch
                            debug_assert!(false, "scheduler downloads consumed logits");
                            occ.plan.halt(StopReason::Error);
                            self.emit();
                            return;
                        }
                    };
                    occ.plan.push(tok);
                }
            }
            SlotState::Decoding => {
                let Some(lg) = row_logits else {
                    debug_assert!(false, "scheduler downloads consumed logits");
                    occ.plan.halt(StopReason::Error);
                    self.emit();
                    return;
                };
                let tok = occ.sampler.pick(lg);
                occ.plan.push(tok);
            }
            SlotState::Vacant | SlotState::Parked | SlotState::Drained => {}
        }
        self.emit();
    }
}

/// A continuous-batching decode session over one engine + parameter
/// store. Construct per serving burst; the borrows make weight staleness
/// structurally impossible (DESIGN.md §9/§10).
pub struct ServeSession<'e, 'rt> {
    eng: &'e mut Engine<'rt>,
    params: &'e ModelParams,
    /// `Some` iff the session runs [`KvMode::Paged`].
    paged: Option<PagedPool>,
    /// Session-wide quantized serving (DESIGN.md §15): every step of this
    /// session runs the q8 twins, or none does. Decided once at
    /// construction from the engine's quant mode + the manifest's q8
    /// decode (and, in paged mode, paged) twin coverage.
    q8: bool,
    /// `decode_step` (or `paged_step`) executions across every batch of
    /// this session.
    pub decode_steps: u64,
    /// Whole-batch prefill passes (one per static chunk; continuous mode
    /// pays one at start plus one per full-drain refill).
    pub batch_prefills: u64,
    /// Prompt columns written through `decode_step` by mid-decode
    /// admissions (0 in static mode).
    pub streamed_prompt_tokens: u64,
    /// Requests admitted to a row (== requests served at session end).
    pub admitted: u64,
    /// Transient execution failures absorbed by in-place retry.
    pub retries: u64,
    /// Rows whose K/V was rebuilt from host bookkeeping after a fault.
    pub reprefills: u64,
    /// Rows drained with [`StopReason::Error`] (fault budget exceeded, or
    /// shed under unrecoverable pool pressure).
    pub error_drains: u64,
    /// Rows preempted (pages released, parked) under pool pressure.
    pub preemptions: u64,
    /// Requests drained because their [`CancelToken`] flipped.
    pub cancelled: u64,
    /// Admissions refused by page-budget reservation (503 upstream).
    pub rejected: u64,
    /// Max in-place retries of one failed execution before quarantining
    /// the batch (transient faults only; persistent ones skip straight to
    /// quarantine).
    retry_max: u32,
    /// Backoff before the n-th retry is `n * backoff_ms` milliseconds.
    backoff_ms: u64,
    /// Quarantines a row survives (by re-prefilling) before it drains
    /// with a typed error.
    row_fault_budget: u32,
}

impl<'e, 'rt> ServeSession<'e, 'rt> {
    /// Whether the loaded artifacts carry the decode ABI for this
    /// engine's backend (legacy dirs: no — callers fall back).
    pub fn supported(eng: &Engine) -> bool {
        eng.rt.manifest.supports_decode(&eng.rt.backend)
    }

    /// Whether the loaded artifacts additionally carry the paged decode
    /// ABI (v2: `paged_scatter` / `paged_step` / `paged_logits` plus the
    /// pool geometry) for this engine's backend.
    pub fn paged_supported(eng: &Engine) -> bool {
        eng.rt.manifest.supports_paged(&eng.rt.backend)
    }

    /// Auto-select the K/V layout: paged when the artifacts support it
    /// (`LISA_PAGED=0` forces the packed v1 path), packed otherwise.
    pub fn new(eng: &'e mut Engine<'rt>, params: &'e ModelParams) -> Result<Self> {
        let paged = Self::paged_supported(eng)
            && std::env::var("LISA_PAGED").map_or(true, |v| v != "0");
        Self::with_mode(eng, params, if paged { KvMode::Paged } else { KvMode::Packed })
    }

    /// Construct with an explicit K/V layout — parity suites pin
    /// [`KvMode::Packed`] on v2 artifact dirs to get the v1 baseline.
    pub fn with_mode(
        eng: &'e mut Engine<'rt>,
        params: &'e ModelParams,
        mode: KvMode,
    ) -> Result<Self> {
        ensure!(
            Self::supported(eng),
            "artifact dir '{}' carries no decode-ABI v{DECODE_ABI} segments for \
             backend '{}' — re-export with python/compile/aot.py or use the \
             legacy full-forward path",
            eng.rt.manifest.dir.display(),
            eng.rt.backend
        );
        let paged = match mode {
            KvMode::Packed => None,
            KvMode::Paged => {
                ensure!(
                    Self::paged_supported(eng),
                    "artifact dir '{}' carries no paged decode-ABI v{PAGED_ABI} \
                     segments for backend '{}' — re-export with \
                     python/compile/aot.py",
                    eng.rt.manifest.dir.display(),
                    eng.rt.backend
                );
                let m = &eng.rt.manifest;
                let mut alloc = PageAllocator::new(m.page_n, m.page_t);
                // page grants share the runtime's fault injector, so a
                // `pool:` plan starves the allocator deterministically
                alloc.set_fault_injector(eng.rt.fault_handle());
                Some(PagedPool {
                    alloc,
                    state: None,
                    p: m.pages_per_row,
                    rows: m.paged_state_rows(),
                })
            }
        };
        // Session-wide quant selection: the decode loop builds one operand
        // set and reuses it every step, so q8 is all-or-nothing per
        // session — on only when the decode q8 twins (and the paged ones,
        // in paged mode) are in the manifest. The engine's operand
        // builders follow its trainable mask, so pinning the mask here
        // keeps operand format and segment choice in lockstep: all-frozen
        // selects q8, all-trainable forces f32 even when the core q8 set
        // exists but the decode twins don't.
        let m = &eng.rt.manifest;
        let q8 = eng.quant() == QuantMode::Int8
            && m.supports_quant_decode(&eng.rt.backend)
            && (paged.is_none() || m.supports_quant_paged(&eng.rt.backend));
        let n_layers = m.n_layers;
        eng.set_train_mask(&if q8 {
            TrainMask::none(n_layers)
        } else {
            TrainMask::all(n_layers)
        });
        Ok(ServeSession {
            eng,
            params,
            paged,
            q8,
            decode_steps: 0,
            batch_prefills: 0,
            streamed_prompt_tokens: 0,
            admitted: 0,
            retries: 0,
            reprefills: 0,
            error_drains: 0,
            preemptions: 0,
            cancelled: 0,
            rejected: 0,
            retry_max: 2,
            backoff_ms: 2,
            row_fault_budget: 2,
        })
    }

    /// Tune the recovery ladder (defaults: 2 retries, 2 ms backoff unit,
    /// 2 quarantines per row). Chaos tests zero the backoff.
    pub fn set_recovery(&mut self, retry_max: u32, backoff_ms: u64, row_fault_budget: u32) {
        self.retry_max = retry_max;
        self.backoff_ms = backoff_ms;
        self.row_fault_budget = row_fault_budget;
    }

    /// The K/V layout this session runs on.
    pub fn kv_mode(&self) -> KvMode {
        if self.paged.is_some() {
            KvMode::Paged
        } else {
            KvMode::Packed
        }
    }

    /// Paged mode's allocator (refcount / prefix-cache observability);
    /// `None` in packed mode.
    pub fn page_allocator(&self) -> Option<&PageAllocator> {
        self.paged.as_ref().map(|p| &p.alloc)
    }

    /// Serve every request with continuous batching: one device-resident
    /// batch, queued requests admitted into rows as they drain. Returns
    /// one [`Completion`] per request, in request order. `eos` stops a
    /// row (not emitted); `pad` fills unused rows and prompt tails.
    pub fn run(&mut self, requests: &[Request], eos: i32, pad: i32) -> Result<Vec<Completion>> {
        use std::cell::RefCell;
        use std::rc::Rc;

        if requests.is_empty() {
            return Ok(Vec::new());
        }
        let done: Rc<RefCell<Vec<Option<Completion>>>> =
            Rc::new(RefCell::new(vec![None; requests.len()]));

        /// Collector sink: drops per-token events, files the completion
        /// under its request index (results return in request order).
        struct Collect {
            idx: usize,
            done: Rc<RefCell<Vec<Option<Completion>>>>,
        }
        impl RequestSink for Collect {
            fn on_token(&mut self, _tok: i32) {}
            fn on_done(&mut self, c: &Completion) {
                if let Some(slot) = self.done.borrow_mut().get_mut(self.idx) {
                    *slot = Some(c.clone());
                }
            }
        }

        /// Non-blocking source over an in-memory slice — the PR 5 burst
        /// semantics: the queue head is always ready, then the queue
        /// closes.
        struct SliceSrc<'a> {
            requests: &'a [Request],
            next: usize,
            done: Rc<RefCell<Vec<Option<Completion>>>>,
        }
        impl RequestSource for SliceSrc<'_> {
            fn poll(&mut self, _idle: bool) -> Feed {
                if self.next >= self.requests.len() {
                    return Feed::Closed;
                }
                let idx = self.next;
                self.next += 1;
                Feed::Admit(
                    self.requests[idx].clone(),
                    Box::new(Collect { idx, done: self.done.clone() }),
                )
            }
        }

        let mut src = SliceSrc { requests, next: 0, done: done.clone() };
        self.run_loop(&mut src, eos, pad)?;
        let out = done
            .borrow_mut()
            .drain(..)
            .map(|c| {
                // a row that exhausted the degradation ladder drained via
                // `on_fail`, leaving its slot empty: surface that as an
                // error instead of panicking the whole batch
                c.ok_or_else(|| anyhow::anyhow!("request failed before completing"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(out)
    }

    /// The static-batch schedule: requests processed in batch-width
    /// chunks, each chunk prefilled together and drained completely
    /// before the next starts. This is `DecodeSession::greedy`'s shape —
    /// the parity baseline and the bench's "before" arm.
    pub fn run_static(
        &mut self,
        requests: &[Request],
        eos: i32,
        pad: i32,
    ) -> Result<Vec<Completion>> {
        let bsz = self.eng.rt.manifest.batch;
        let mut out = Vec::with_capacity(requests.len());
        for chunk in requests.chunks(bsz) {
            // a chunk never outnumbers the rows, so the in-loop admission
            // has nothing left to admit: no mid-decode admission
            out.extend(self.run(chunk, eos, pad)?);
        }
        Ok(out)
    }

    /// The serve loop proper, generalized over *where requests come from*
    /// (an in-memory slice for [`ServeSession::run`], a bounded channel
    /// for the HTTP front end) and *where tokens go* (each admission
    /// carries its own [`RequestSink`]). Runs until the source reports
    /// [`Feed::Closed`] and every in-flight row has drained; events fire
    /// on this thread, the only one that touches the engine.
    pub fn run_loop(
        &mut self,
        src: &mut dyn RequestSource,
        eos: i32,
        pad: i32,
    ) -> Result<()> {
        let m = self.eng.rt.manifest.clone();
        let (bsz, t_max, v) = (m.batch, m.seq, m.vocab);
        let state_shape = vec![bsz, m.decode_state_rows(), m.d_model];
        let paged_shape = vec![m.paged_state_rows(), m.d_model];
        let logit1_shape = [bsz, 1, v];

        let mut slots: Vec<RowSlot> = (0..bsz).map(|_| RowSlot::default()).collect();
        let mut closed = false;
        // packed mode's state is loop-local (rebuilt by every batch
        // prefill); paged mode's pool lives in `self.paged` and persists
        // across run_loop calls so cached prefix pages stay adoptable
        let mut state: Option<Act> = None;
        // decode-loop parameter operands, built once on first use and
        // served from the device cache across every step of the session
        type DecOps<'p> = ([ParamOp<'p>; 2], Vec<Vec<ParamOp<'p>>>, [ParamOp<'p>; 2]);
        let mut dec_ops: Option<DecOps<'e>> = None;
        // consecutive failures of the execution the loop is stuck on;
        // reset whenever an iteration completes (or quarantine resolves it)
        let mut step_failures: u32 = 0;

        loop {
            // ---- cancellation: flipped tokens drain their row between
            // steps — pages released, neighbors untouched
            for slot in slots.iter_mut() {
                if !slot.cancel_requested() {
                    continue;
                }
                if let Some(pool) = self.paged.as_mut() {
                    slot.release_pages(&mut pool.alloc);
                }
                slot.fail(FailClass::Cancelled, "request cancelled");
                self.cancelled += 1;
            }

            // ---- pool pressure: re-prefill parked rows once there is
            // headroom (or shed one if nothing can ever run)
            self.unpark_parked(&mut slots);

            // ---- admission: harvest drained rows, hand freed rows to
            // the queue head
            for slot in slots.iter_mut() {
                loop {
                    if slot.live() {
                        break;
                    }
                    if let Some(pool) = self.paged.as_mut() {
                        slot.harvest_pages(&mut pool.alloc);
                    }
                    slot.take_done();
                    if closed {
                        break;
                    }
                    match src.poll(false) {
                        Feed::Admit(req, sink) => {
                            // a zero-budget request drains instantly (and a
                            // rejected one leaves the row free): the loop
                            // hands the row straight to the next request
                            self.try_admit(slot, req, sink, t_max, eos);
                        }
                        Feed::Pending => break,
                        Feed::Closed => {
                            closed = true;
                            break;
                        }
                    }
                }
            }
            let live = slots.iter().filter(|s| s.live()).count();
            src.observe(
                self.eng,
                LoopStats {
                    decode_steps: self.decode_steps,
                    batch_prefills: self.batch_prefills,
                    streamed_prompt_tokens: self.streamed_prompt_tokens,
                    admitted: self.admitted,
                    live_rows: live,
                    retries: self.retries,
                    reprefills: self.reprefills,
                    error_drains: self.error_drains,
                    preemptions: self.preemptions,
                    cancelled: self.cancelled,
                    rejected: self.rejected,
                },
            );
            if live == 0 {
                if closed {
                    break; // queue closed and every row drained
                }
                // idle: nothing to overlap a wait with — let the source
                // block until traffic (or its heartbeat) wakes us
                match src.poll(true) {
                    Feed::Admit(req, sink) => {
                        self.try_admit(&mut slots[0], req, sink, t_max, eos);
                    }
                    Feed::Pending => {}
                    Feed::Closed => closed = true,
                }
                continue;
            }

            // ---- prefill: batched while no row holds in-flight K/V;
            // otherwise admitted rows stream through decode_step below.
            // A paged row that adopted cached prefix pages counts as
            // in-flight (`fed > 0`), so it streams its remaining prompt
            // instead of re-running the prefill segments. Parked rows sit
            // this out (no pages); the `any Prefilling` guard keeps an
            // all-parked batch from prefilling nothing forever.
            if slots.iter().all(RowSlot::no_progress)
                && slots.iter().any(|s| s.state() == SlotState::Prefilling)
            {
                match self.batch_prefill(&mut slots, pad) {
                    Ok(s) => {
                        state = s;
                        step_failures = 0;
                    }
                    Err(e) => {
                        // nothing was consumed and the paged pool state
                        // survived (scatter restores it on failure), so the
                        // whole prefill can be retried or quarantined away
                        if self.absorb_failure(&e, "batch prefill", &mut slots, &mut step_failures)
                        {
                            state = None;
                        }
                    }
                }
                continue; // first tokens may have drained rows: re-admit
            }

            // ---- one decode step advances every row
            let (ep, blocks, ho) = match &mut dec_ops {
                Some(ops) => &*ops,
                cache => {
                    let ep = self.eng.embed_ops(self.params)?;
                    let mut blocks = Vec::with_capacity(m.n_layers);
                    for l in 0..m.n_layers {
                        blocks.push(self.eng.block_ops(self.params, l)?);
                    }
                    let ho = self.eng.head_ops(self.params)?;
                    &*cache.insert((ep, blocks, ho))
                }
            };

            // paged: grow each live row's page list to cover the position
            // it writes this step (one page at a time at page boundaries).
            // A failed grant is pool pressure, not a loop error: preempt
            // the row (first offense) or shed it (second) — its neighbors
            // keep their pages and keep decoding.
            if self.paged.is_some() {
                for slot in slots.iter_mut() {
                    // re-borrowed per row: `slot.fail` below needs the
                    // pool borrow released between iterations
                    let Some(pool) = self.paged.as_mut() else { break };
                    if let Err(e) = slot.ensure_page(&mut pool.alloc) {
                        if slot.0.as_ref().is_some_and(|o| o.preempts >= 1) {
                            slot.release_pages(&mut pool.alloc);
                            slot.fail(
                                FailClass::Overloaded,
                                &format!("preempted twice under page-pool pressure: {e:#}"),
                            );
                            self.error_drains += 1;
                        } else {
                            log::warn!("page pool pressure, preempting a row: {e:#}");
                            slot.park(&mut pool.alloc);
                            self.preemptions += 1;
                        }
                    }
                }
                // preemption may have idled the whole batch: let the next
                // iteration unpark/admit instead of stepping nothing
                if !slots
                    .iter()
                    .any(|s| matches!(s.state(), SlotState::Prefilling | SlotState::Decoding))
                {
                    continue;
                }
            }
            let (mut tokc, mut pidxc) =
                (Vec::with_capacity(bsz), Vec::with_capacity(bsz));
            let mut needs_logits = false;
            for slot in slots.iter() {
                if slot.state() == SlotState::Prefilling {
                    self.streamed_prompt_tokens += 1;
                }
                needs_logits |= slot.consumes_next_logits();
                let (t, p) = slot.step_input(pad);
                tokc.push(t);
                pidxc.push(p);
            }
            let tok = HostTensorI32::from_vec(&[bsz, 1], tokc);
            let pidx = HostTensorI32::from_vec(&[bsz, 1], pidxc);
            // paged: the `[B, P]` table is a per-step i32 input, uploaded
            // alongside tok/pidx (three small uploads instead of two)
            let table = self.paged.as_ref().map(|pool| page_table(&slots, bsz, pool.p));
            let st = match self.paged.as_mut() {
                Some(pool) => pool.state.take(),
                None => state.take(),
            };
            let Some(st) = st else {
                // loop invariant breach (live non-fresh rows imply a
                // prefilled state): quarantine rebuilds every live row's
                // K/V from scratch, restoring the invariant, instead of
                // panicking mid-burst
                debug_assert!(false, "live non-fresh rows imply a prefilled state");
                self.quarantine(&mut slots, "decode step found no prefilled state");
                state = None;
                continue;
            };
            let state_next = {
                let mut ops: Vec<Operand> = vec![Operand::I32(&tok), Operand::I32(&pidx)];
                if let Some(t) = &table {
                    ops.push(Operand::I32(t));
                }
                ops.push(st.operand());
                ep[0].push_operands(&mut ops);
                ep[1].push_operands(&mut ops);
                for bo in blocks {
                    for p in bo {
                        p.push_operands(&mut ops);
                    }
                }
                let (seg, shape) = match (table.is_some(), self.q8) {
                    (true, true) => (self.eng.ids.paged_step_q8, &paged_shape),
                    (true, false) => (self.eng.ids.paged_step, &paged_shape),
                    (false, true) => (self.eng.ids.decode_step_q8, &state_shape),
                    (false, false) => (self.eng.ids.decode_step, &state_shape),
                };
                self.eng.run_chain_act(seg, &ops, shape)
            };
            match state_next {
                Ok(next) => match self.paged.as_mut() {
                    Some(pool) => pool.state = Some(next),
                    None => state = Some(next),
                },
                Err(e) => {
                    // executions are functional: a failed step never
                    // touched `st`, so put it back and either retry the
                    // identical step or quarantine the batch
                    match self.paged.as_mut() {
                        Some(pool) => pool.state = Some(st),
                        None => state = Some(st),
                    }
                    if self.absorb_failure(&e, "decode step", &mut slots, &mut step_failures) {
                        state = None; // quarantine cleared the paged pool itself
                    }
                    continue;
                }
            }
            self.decode_steps += 1;
            // the [B, 1, V] download happens only when some row reads it —
            // a step that only streams mid-prompt columns skips it
            let lg = if needs_logits {
                let (st, seg) = match (self.paged.as_ref(), self.q8) {
                    (Some(pool), true) => (pool.state.as_ref(), self.eng.ids.paged_logits_q8),
                    (Some(pool), false) => (pool.state.as_ref(), self.eng.ids.paged_logits),
                    (None, true) => (state.as_ref(), self.eng.ids.decode_logits_q8),
                    (None, false) => (state.as_ref(), self.eng.ids.decode_logits),
                };
                let Some(st) = st else {
                    // unreachable: the step above just stored this state
                    debug_assert!(false, "decode step just stored a state");
                    continue;
                };
                let mut ops = vec![st.operand()];
                for p in ho {
                    p.push_operands(&mut ops);
                }
                match self.eng.run_chain_act(seg, &ops, &logit1_shape).and_then(Act::into_host) {
                    Ok(h) => Some(h),
                    Err(e) => {
                        // the state advanced but no row consumed anything:
                        // re-issuing the whole step next iteration rewrites
                        // the same columns with the same bytes (frozen-row
                        // idempotence), so retry is safe here too
                        if self.absorb_failure(
                            &e,
                            "logits download",
                            &mut slots,
                            &mut step_failures,
                        ) {
                            state = None;
                        }
                        continue;
                    }
                }
            } else {
                None
            };
            for (r, slot) in slots.iter_mut().enumerate() {
                slot.consume(lg.as_ref().map(|lg| &lg.data[r * v..(r + 1) * v]));
            }
            step_failures = 0;
        }

        // every row was harvested by the admission pass of the final
        // iteration. Packed state dies with the loop; the paged pool (and
        // its cached prefix pages) stays resident for the next burst.
        let resident = self
            .paged
            .as_ref()
            .and_then(|p| p.state.as_ref())
            .map_or(0, |s| s.bytes() as u64);
        self.eng.meter.set(MemCategory::Activations, resident);
        Ok(())
    }

    /// Admission with graceful degradation (DESIGN.md §13). A request
    /// whose cancel token already flipped drains immediately; in paged
    /// mode a request whose worst-case page need exceeds what the pool
    /// could free right now (free + idle-cached pages) is refused with
    /// [`FailClass::Overloaded`] — the HTTP layer maps that to 503 +
    /// `Retry-After` — instead of being admitted into certain preemption.
    /// On success the row is occupied and `admitted` is bumped; on any
    /// refusal the row stays free for the next queued request.
    fn try_admit(
        &mut self,
        slot: &mut RowSlot,
        req: Request,
        mut sink: Box<dyn RequestSink>,
        t_max: usize,
        eos: i32,
    ) {
        if req.cancel.as_ref().is_some_and(CancelToken::is_cancelled) {
            sink.on_fail(&ServeFail::new(FailClass::Cancelled, "cancelled before admission"));
            self.cancelled += 1;
            return;
        }
        if let Some(pool) = self.paged.as_ref() {
            // zero-budget requests drain at admission and take no pages
            if req.max_new > 0 {
                let bt = pool.alloc.page_t();
                let plen = req.prompt.len().min(t_max - 1); // clip_prompt bound
                let total = (plen + req.max_new).min(t_max);
                let need = (total.div_ceil(bt)).min(pool.p);
                let avail = pool.alloc.n_free() + pool.alloc.n_idle_cached();
                if need > avail {
                    sink.on_fail(&ServeFail::new(
                        FailClass::Overloaded,
                        format!(
                            "page pool at capacity ({need} pages needed, {avail} reclaimable)"
                        ),
                    ));
                    self.rejected += 1;
                    return;
                }
            }
        }
        slot.admit(req, sink, t_max, eos);
        if let Some(pool) = self.paged.as_mut() {
            if let Err(e) = slot.attach_pages(&mut pool.alloc) {
                // reservation raced an injected pool fault (or a sudden
                // adoption): refuse late rather than admit a pageless row
                slot.release_pages(&mut pool.alloc);
                slot.fail(
                    FailClass::Overloaded,
                    &format!("page pool exhausted at admission: {e:#}"),
                );
                self.rejected += 1;
                return;
            }
        }
        self.admitted += 1;
    }

    /// Decide what a failed execution means for the loop: bounded
    /// retry-with-backoff for transient failures (unclassified errors get
    /// the benefit of the doubt), quarantine once the budget is spent or
    /// the fault is known-persistent. Returns whether the batch was
    /// quarantined — the caller must then drop its packed state (the
    /// paged pool is cleared here).
    fn absorb_failure(
        &mut self,
        err: &anyhow::Error,
        what: &str,
        slots: &mut [RowSlot],
        step_failures: &mut u32,
    ) -> bool {
        let transient = err
            .downcast_ref::<FaultError>()
            .is_none_or(|f| f.kind == FaultKind::Transient);
        *step_failures += 1;
        if transient && *step_failures <= self.retry_max {
            self.retries += 1;
            log::warn!("serve: {what} failed (attempt {step_failures}), retrying: {err:#}");
            if self.backoff_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(
                    self.backoff_ms * u64::from(*step_failures),
                ));
            }
            return false;
        }
        *step_failures = 0;
        log::warn!("serve: {what} failed persistently, quarantining the batch: {err:#}");
        self.quarantine(slots, &format!("{what} failed: {err:#}"));
        true
    }

    /// Containment after an unrecoverable execution failure: the device
    /// K/V (shared state tensor) is suspect, but every row's tokens live
    /// in host bookkeeping, so each in-flight row either re-prefills its
    /// whole sequence (token-identical — the sampler stream never saw the
    /// failed step) or, past its fault budget, drains with a typed error.
    /// The prefix cache is flushed: its pages' device bytes die with the
    /// discarded pool state.
    fn quarantine(&mut self, slots: &mut [RowSlot], msg: &str) {
        for slot in slots.iter_mut() {
            match slot.state() {
                SlotState::Prefilling | SlotState::Decoding => {}
                SlotState::Drained => {
                    // its completion already fired, but its prompt pages
                    // now hold garbage: forget them so the harvest pass
                    // doesn't register a poisoned prefix
                    if let Some(occ) = &mut slot.0 {
                        occ.fed = 0;
                    }
                    continue;
                }
                // parked rows hold no K/V; vacant rows hold nothing
                SlotState::Vacant | SlotState::Parked => continue,
            }
            if let Some(pool) = self.paged.as_mut() {
                slot.release_pages(&mut pool.alloc);
            }
            let Some(occ) = slot.0.as_mut() else { continue };
            occ.faults += 1;
            if occ.faults > self.row_fault_budget {
                slot.fail(FailClass::Internal, msg);
                self.error_drains += 1;
                continue;
            }
            occ.re_prefill();
            self.reprefills += 1;
            // paged: cover the rebuilt sequence up front so the next batch
            // prefill scatters every column into a real page. No prefix
            // adoption here — the cache is about to be flushed.
            if let Some(pool) = self.paged.as_mut() {
                let Some(occ) = slot.0.as_mut() else { continue };
                let need = occ.plan.seq.len().div_ceil(pool.alloc.page_t());
                while occ.pages.len() < need {
                    match pool.alloc.alloc() {
                        Ok(g) => occ.pages.push(g),
                        Err(e) => {
                            // pool pressure on top of the fault: park
                            log::warn!("serve: quarantine preempts a row: {e:#}");
                            slot.park(&mut pool.alloc);
                            self.preemptions += 1;
                            break;
                        }
                    }
                }
            }
        }
        if let Some(pool) = self.paged.as_mut() {
            // cached pages' K/V dies with the pool state; survivors'
            // pages are rewritten by the next batch prefill from zeros
            pool.alloc.evict_idle();
            pool.state = None;
        }
    }

    /// Re-admit parked rows once the pool has headroom for their
    /// worst-case need; if nothing is runnable and no parked row fits,
    /// shed the largest one so the loop always makes progress.
    fn unpark_parked(&mut self, slots: &mut [RowSlot]) {
        if self.paged.is_none() {
            return;
        }
        for slot in slots.iter_mut() {
            if slot.state() != SlotState::Parked {
                continue;
            }
            let Some(pool) = self.paged.as_mut() else { return };
            let bt = pool.alloc.page_t();
            let avail = pool.alloc.n_free() + pool.alloc.n_idle_cached();
            let Some(occ) = slot.0.as_mut() else { continue };
            let need_full = (occ.plan.max_total_len().div_ceil(bt)).min(pool.p);
            if need_full > avail {
                continue; // not enough headroom yet — stay parked
            }
            // allocate the pages its current sequence needs now; the next
            // batch prefill rebuilds the K/V (fed == 0 after parking)
            let need_now = occ.plan.seq.len().div_ceil(bt);
            let mut granted = true;
            while occ.pages.len() < need_now {
                match pool.alloc.alloc() {
                    Ok(g) => occ.pages.push(g),
                    Err(_) => {
                        granted = false;
                        break;
                    }
                }
            }
            if granted {
                occ.parked = false; // Prefilling again, from scratch
            } else {
                slot.release_pages(&mut pool.alloc); // raced: stay parked
            }
        }
        // degradation ladder's last rung: nothing runnable and nothing
        // unparkable means the pool can never cover the parked rows —
        // shed the hungriest so the rest (and new admissions) can run
        let runnable = slots
            .iter()
            .any(|s| matches!(s.state(), SlotState::Prefilling | SlotState::Decoding));
        if runnable {
            return;
        }
        let victim = slots
            .iter_mut()
            .filter(|s| s.state() == SlotState::Parked)
            .max_by_key(|s| s.0.as_ref().map_or(0, |o| o.plan.seq.len()));
        if let Some(slot) = victim {
            let Some(pool) = self.paged.as_mut() else { return };
            slot.release_pages(&mut pool.alloc);
            slot.fail(
                FailClass::Overloaded,
                "page pool cannot cover any parked row",
            );
            self.error_drains += 1;
        }
    }

    /// Batched prefill of every occupied row's current sequence:
    /// `embed_fwd -> (prefill_kv + block_fwd)^L -> [head_logits]`, then
    /// either `pack_state` (packed mode — the state is returned) or
    /// `paged_scatter` (paged mode — the per-layer K/V lands in each
    /// row's pages inside `self.paged` and `None` is returned). The
    /// `head_logits` call (and its `[B, T, V]` download) is skipped when
    /// no row consumes it.
    fn batch_prefill(&mut self, slots: &mut [RowSlot], pad: i32) -> Result<Option<Act>> {
        let m = self.eng.rt.manifest.clone();
        let (bsz, t_max, d, v) = (m.batch, m.seq, m.d_model, m.vocab);
        let mut tokens = vec![pad; bsz * t_max];
        for (r, slot) in slots.iter().enumerate() {
            if let Some(occ) = &slot.0 {
                tokens[r * t_max..r * t_max + occ.plan.seq.len()]
                    .copy_from_slice(&occ.plan.seq);
            }
        }
        let tokens = HostTensorI32::from_vec(&[bsz, t_max], tokens);

        let ids = self.eng.ids;
        let hs = self.eng.h_shape();
        let kv_shape = vec![bsz, 2 * t_max, d];
        let state_shape = vec![bsz, m.decode_state_rows(), d];

        let eid = if self.q8 { ids.embed_fwd_q8 } else { ids.embed_fwd };
        let ep = self.eng.embed_ops(self.params)?;
        let mut ops = vec![Operand::I32(&tokens)];
        for p in &ep {
            p.push_operands(&mut ops);
        }
        let mut h = self.eng.run_chain_act(eid, &ops, &hs)?;
        drop(ops);
        let mut kvs: Vec<Act> = Vec::with_capacity(m.n_layers);
        // meter the real serving peak: the growing per-layer K/V buffers
        // plus the one live residual are resident together during prefill
        let mut kv_bytes = 0u64;
        self.eng.meter.set(MemCategory::Activations, h.bytes() as u64);
        let (kv_id, fwd_id) = if self.q8 {
            (ids.prefill_kv_q8, ids.block_fwd_q8)
        } else {
            (ids.prefill_kv, ids.block_fwd)
        };
        for l in 0..m.n_layers {
            let bo = self.eng.block_ops(self.params, l)?;
            // prefill_kv ABI: (h, g1, wk, wv) — block ABI indices 0/2/3
            // (under q8 the wk/wv entries expand to their (q, s) pairs)
            let mut kv_ops = vec![h.operand()];
            bo[0].push_operands(&mut kv_ops);
            bo[2].push_operands(&mut kv_ops);
            bo[3].push_operands(&mut kv_ops);
            let kv = self.eng.run_chain_act(kv_id, &kv_ops, &kv_shape)?;
            drop(kv_ops);
            kv_bytes += kv.bytes() as u64;
            kvs.push(kv);
            let mut ops = vec![h.operand()];
            for p in &bo {
                p.push_operands(&mut ops);
            }
            let h_next = self.eng.run_chain_act(fwd_id, &ops, &hs)?;
            drop(ops);
            h = h_next;
            self.eng
                .meter
                .set(MemCategory::Activations, kv_bytes + h.bytes() as u64);
        }
        // head_logits only when some prefilled row actually consumes it
        // (skipped for forced first tokens / zero-budget batches)
        let logits: Option<HostTensor> = if slots.iter().any(RowSlot::needs_prefill_logits) {
            let lid = if self.q8 { ids.head_logits_q8 } else { ids.head_logits };
            let ho = self.eng.head_ops(self.params)?;
            let mut ops = vec![h.operand()];
            for p in &ho {
                p.push_operands(&mut ops);
            }
            Some(
                self.eng
                    .run_chain_act(lid, &ops, &[bsz, t_max, v])?
                    .into_host()?,
            )
        } else {
            None
        };
        let state = if self.paged.is_some() {
            // paged: scatter each layer's [B, 2T, D] K/V into the rows'
            // pages. Rows without a page for a column (vacant rows, tails
            // past a row's last page) scatter onto scratch — garbage by
            // contract, masked out of every read. The previous pool state
            // (zeros before the first prefill) rides through unchanged
            // outside the written rows, so cached pages survive.
            let (p, rows, prev) = {
                let Some(pool) = self.paged.as_mut() else {
                    // unreachable: this branch is `self.paged.is_some()`
                    return Err(anyhow::anyhow!("paged scatter without a paged pool"));
                };
                let prev = match pool.state.take() {
                    Some(st) => st,
                    None => Act::Host(HostTensor::from_vec(
                        &[pool.rows, d],
                        vec![0.0; pool.rows * d],
                    )),
                };
                (pool.p, pool.rows, prev)
            };
            let table = page_table(slots, bsz, p);
            let scattered = {
                let mut ops: Vec<Operand> = vec![prev.operand(), Operand::I32(&table)];
                ops.extend(kvs.iter().map(Act::operand));
                self.eng.run_chain_act(ids.paged_scatter, &ops, &[rows, d])
            };
            let st = match scattered {
                Ok(st) => st,
                Err(e) => {
                    // scatter is functional: `prev` — and the cached
                    // prefix K/V inside it — is intact, so put it back
                    // and let the caller re-issue the whole prefill
                    if let Some(pool) = self.paged.as_mut() {
                        pool.state = Some(prev);
                    }
                    return Err(e);
                }
            };
            self.eng
                .meter
                .set(MemCategory::Activations, kv_bytes + st.bytes() as u64);
            drop(kvs);
            self.eng.meter.set(MemCategory::Activations, st.bytes() as u64);
            if let Some(pool) = self.paged.as_mut() {
                pool.state = Some(st);
            }
            None
        } else {
            let state = {
                let kv_ops: Vec<Operand> = kvs.iter().map(Act::operand).collect();
                self.eng.run_chain_act(ids.pack_state, &kv_ops, &state_shape)?
            };
            // packing peak: per-layer buffers and the packed state coexist
            self.eng
                .meter
                .set(MemCategory::Activations, kv_bytes + state.bytes() as u64);
            drop(kvs);
            self.eng.meter.set(MemCategory::Activations, state.bytes() as u64);
            Some(state)
        };
        self.batch_prefills += 1;

        // first token per prefilled row, from the logits at position len-1
        for (r, slot) in slots.iter_mut().enumerate() {
            slot.finish_batch_prefill(logits.as_ref().map(|lg| (lg, r)), t_max, v);
        }
        Ok(state)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests panic by design
mod tests {
    use super::*;

    // ---- RowPlan: the legacy-loop stop-condition mirror -----------------

    #[test]
    fn row_plan_mirrors_legacy_stop_conditions() {
        // eos on the first token: nothing emitted
        let mut r = RowPlan::new(vec![1, 5, 3], 16, 4, 2);
        assert!(r.alive());
        r.push(2);
        assert!(!r.alive());
        let c = r.into_completion();
        assert!(c.tokens.is_empty());
        assert_eq!(c.stop, StopReason::Eos);

        // max_new budget
        let mut r = RowPlan::new(vec![1, 5, 3], 16, 2, 2);
        r.push(7);
        assert!(r.alive());
        assert_eq!(r.step_input(), (7, 3));
        r.push(8);
        assert!(!r.alive());
        let c = r.into_completion();
        assert_eq!(c.tokens, vec![7, 8]);
        assert_eq!(c.stop, StopReason::MaxNew);
        assert!(!c.prompt_truncated);
    }

    #[test]
    fn row_plan_stops_when_the_window_fills() {
        // cap 5, prompt 3 long: room for exactly 2 generated tokens
        let mut r = RowPlan::new(vec![1, 5, 3], 5, 10, 2);
        r.push(7);
        assert!(r.alive());
        r.push(8);
        assert!(!r.alive());
        let c = r.into_completion();
        assert_eq!(c.tokens, vec![7, 8]);
        assert_eq!(c.stop, StopReason::WindowFull);
    }

    #[test]
    fn row_plan_truncates_oversized_prompts_like_legacy() {
        let prompt: Vec<i32> = (0..20).collect();
        let r = RowPlan::new(prompt, 8, 4, 2);
        assert!(r.truncated);
        assert_eq!(r.seq.len(), 7); // T - 1, legacy semantics
        assert_eq!(r.step_input(), (6, 6));
    }

    #[test]
    fn row_plan_max_new_zero_never_decodes() {
        let r = RowPlan::new(vec![1], 8, 0, 2);
        assert!(!r.alive());
        assert_eq!(r.into_completion().stop, StopReason::MaxNew);
    }

    #[test]
    fn frozen_rows_repeat_their_last_slot() {
        let mut r = RowPlan::new(vec![1, 4], 16, 1, 2);
        r.push(9);
        assert!(!r.alive());
        // frozen input: same token, same position, every step
        assert_eq!(r.step_input(), (9, 2));
        assert_eq!(r.step_input(), (9, 2));
    }

    // ---- stop sequences + streaming commit ------------------------------

    #[test]
    fn stop_sequence_drains_and_excludes_the_match() {
        let mut r = RowPlan::with_stops(vec![1], 32, 10, 2, vec![vec![7, 8]]);
        r.push(5);
        r.push(7);
        assert!(r.alive());
        r.push(8); // completes [7, 8]
        assert!(!r.alive());
        let c = r.into_completion();
        assert_eq!(c.tokens, vec![5], "matched suffix excluded");
        assert_eq!(c.stop, StopReason::StopSeq);
        assert_eq!(c.stop.label(), "stop_seq");
    }

    #[test]
    fn longest_stop_sequence_wins_and_empty_ones_are_ignored() {
        let mut r = RowPlan::with_stops(
            vec![1],
            32,
            10,
            2,
            vec![vec![], vec![8], vec![7, 8]],
        );
        r.push(5);
        r.push(7);
        r.push(8); // matches both [8] and [7, 8]: strip the longest
        let c = r.into_completion();
        assert_eq!(c.tokens, vec![5]);
        assert_eq!(c.stop, StopReason::StopSeq);
    }

    #[test]
    fn stop_sequence_wins_over_max_new_on_the_same_token() {
        let mut r = RowPlan::with_stops(vec![1], 32, 2, 2, vec![vec![5, 6]]);
        r.push(5);
        r.push(6); // budget reached AND stop matched
        let c = r.into_completion();
        assert_eq!(c.stop, StopReason::StopSeq);
        assert!(c.tokens.is_empty());
    }

    #[test]
    fn stop_matches_generated_tokens_only_not_the_prompt() {
        // prompt ends ... 7; stop [7, 8]: the generated 8 alone must NOT
        // complete a match across the prompt boundary
        let mut r = RowPlan::with_stops(vec![1, 7], 32, 4, 2, vec![vec![7, 8]]);
        r.push(8);
        assert!(r.alive());
        r.push(7);
        r.push(8);
        let c = r.into_completion();
        assert_eq!(c.tokens, vec![8]);
        assert_eq!(c.stop, StopReason::StopSeq);
    }

    #[test]
    fn committed_holds_back_partial_matches_and_never_retracts() {
        let mut r = RowPlan::with_stops(vec![1], 64, 20, 2, vec![vec![7, 8, 9]]);
        assert_eq!(r.committed(), 0);
        r.push(5);
        assert_eq!(r.committed(), 1);
        r.push(7); // could grow into [7, 8, 9]
        assert_eq!(r.committed(), 1);
        r.push(8); // still could
        assert_eq!(r.committed(), 1);
        r.push(4); // match broken: everything flushes
        assert_eq!(r.committed(), 4);
        r.push(7);
        r.push(8);
        r.push(9); // match: drained, committed == out.len() == truncated 4
        assert!(!r.alive());
        assert_eq!(r.committed(), 4);
        let c = r.into_completion();
        assert_eq!(c.tokens, vec![5, 7, 8, 4]);
    }

    #[test]
    fn committed_is_monotone_under_random_pushes() {
        // property: whatever lands, the committed watermark never moves
        // backwards (streamed tokens can never be retracted)
        let mut rng = crate::util::rng::Rng::new(71);
        for _ in 0..200 {
            let stops = vec![vec![3, 1], vec![1, 1, 4], vec![2]];
            let mut r = RowPlan::with_stops(vec![9], 4096, 1000, -1, stops);
            let mut last = 0;
            while r.alive() && r.out().len() < 40 {
                r.push(rng.below(5) as i32);
                let c = r.committed();
                assert!(c >= last, "committed retracted: {c} < {last}");
                assert!(c <= r.out().len());
                last = c;
            }
        }
    }

    // ---- RowSlot: the Vacant -> Prefilling -> Decoding -> Drained walk --

    use std::cell::RefCell;
    use std::rc::Rc;

    const EOS: i32 = 2;
    const PAD: i32 = 0;

    fn req(prompt: Vec<i32>, max_new: usize) -> Request {
        Request::greedy(prompt, max_new)
    }

    /// Sink that records the event stream for assertions.
    #[derive(Default)]
    struct Log {
        toks: Vec<i32>,
        done: Option<Completion>,
    }

    struct LogSink(Rc<RefCell<Log>>);

    impl RequestSink for LogSink {
        fn on_token(&mut self, tok: i32) {
            self.0.borrow_mut().toks.push(tok);
        }
        fn on_done(&mut self, c: &Completion) {
            self.0.borrow_mut().done = Some(c.clone());
        }
    }

    fn log_sink() -> (Box<dyn RequestSink>, Rc<RefCell<Log>>) {
        let log = Rc::new(RefCell::new(Log::default()));
        (Box::new(LogSink(log.clone())), log)
    }

    /// One decode-logits row that makes the greedy sampler pick `tok`.
    fn row_for(tok: i32, v: usize) -> Vec<f32> {
        let mut r = vec![0.0; v];
        r[tok as usize] = 5.0;
        r
    }

    #[test]
    fn slot_walks_the_lifecycle_via_streamed_admission() {
        let mut s = RowSlot::default();
        assert_eq!(s.state(), SlotState::Vacant);
        assert_eq!(s.step_input(PAD), (PAD, 0));
        assert!(!s.live());
        assert!(!s.take_done());

        let (sink, log) = log_sink();
        s.admit(req(vec![1, 5, 3], 2), sink, 16, EOS);
        assert_eq!(s.state(), SlotState::Prefilling);
        assert!(s.live() && s.needs_prefill_logits());

        // streamed prefill: one prompt column per step, teacher-forced
        assert_eq!(s.step_input(PAD), (1, 0));
        s.consume(Some(&row_for(9, 16))); // logits ignored mid-prompt
        assert_eq!(s.state(), SlotState::Prefilling);
        assert_eq!(s.step_input(PAD), (5, 1));
        s.consume(Some(&row_for(9, 16)));
        assert_eq!(s.step_input(PAD), (3, 2));
        s.consume(Some(&row_for(7, 16))); // last prompt column: first token
        assert_eq!(s.state(), SlotState::Decoding);
        assert_eq!(log.borrow().toks, vec![7], "first token streams as it lands");

        assert_eq!(s.step_input(PAD), (7, 3));
        s.consume(Some(&row_for(8, 16))); // budget of 2 reached
        assert_eq!(s.state(), SlotState::Drained);
        // drained rows freeze idempotently until harvested
        assert_eq!(s.step_input(PAD), (8, 4));
        assert_eq!(s.step_input(PAD), (8, 4));

        assert!(s.take_done());
        assert_eq!(s.state(), SlotState::Vacant);
        let log = log.borrow();
        assert_eq!(log.toks, vec![7, 8]);
        let c = log.done.as_ref().expect("on_done fired");
        assert_eq!(c.tokens, vec![7, 8]);
        assert_eq!(c.stop, StopReason::MaxNew);
    }

    #[test]
    fn slot_streams_respecting_stop_sequence_holdback() {
        let mut s = RowSlot::default();
        let (sink, log) = log_sink();
        let r = req(vec![1], 10).with_stop(vec![vec![8, 9]]);
        s.admit(r, sink, 64, EOS);
        s.consume(Some(&row_for(5, 16))); // last prompt column: first token
        assert_eq!(log.borrow().toks, vec![5]);
        s.consume(Some(&row_for(8, 16))); // could open [8, 9]: held back
        assert_eq!(log.borrow().toks, vec![5]);
        s.consume(Some(&row_for(4, 16))); // match broken: 8 and 4 flush
        assert_eq!(log.borrow().toks, vec![5, 8, 4]);
        s.consume(Some(&row_for(8, 16)));
        s.consume(Some(&row_for(9, 16))); // match: drains, suffix dropped
        assert_eq!(s.state(), SlotState::Drained);
        assert!(s.take_done());
        let log = log.borrow();
        assert_eq!(log.toks, vec![5, 8, 4], "held-back suffix never streamed");
        let c = log.done.as_ref().unwrap();
        assert_eq!(c.tokens, vec![5, 8, 4]);
        assert_eq!(c.stop, StopReason::StopSeq);
    }

    #[test]
    fn batch_prefill_completion_skips_streaming() {
        let mut s = RowSlot::default();
        let (sink, log) = log_sink();
        s.admit(req(vec![1, 5], 4), sink, 16, EOS);
        assert!(s.no_progress(), "fed == 0 joins a fresh batch prefill");
        let lg = HostTensor::from_vec(&[1, 16, 8], {
            let mut d = vec![0.0; 16 * 8];
            d[8 + 6] = 5.0; // position len-1 == 1 picks token 6 (vocab 8)
            d
        });
        s.finish_batch_prefill(Some((&lg, 0)), 16, 8);
        assert_eq!(s.state(), SlotState::Decoding);
        assert!(!s.no_progress());
        assert_eq!(s.step_input(PAD), (6, 2));
        assert_eq!(log.borrow().toks, vec![6], "prefill's first token streams");
    }

    #[test]
    fn forced_first_token_needs_no_prefill_logits() {
        let mut s = RowSlot::default();
        let mut r = req(vec![1, 5], 3);
        r.first_token = Some(4);
        s.admit(r, log_sink().0, 16, EOS);
        assert!(!s.needs_prefill_logits());
        s.finish_batch_prefill(None, 16, 8);
        assert_eq!(s.state(), SlotState::Decoding);
        assert_eq!(s.step_input(PAD), (4, 2));

        // forced also works through the streamed path
        let mut s = RowSlot::default();
        let mut r = req(vec![9], 3);
        r.first_token = Some(5);
        s.admit(r, log_sink().0, 16, EOS);
        assert_eq!(s.step_input(PAD), (9, 0));
        s.consume(Some(&row_for(2, 16))); // logits ignored: forced wins
        assert_eq!(s.step_input(PAD), (5, 1));
    }

    #[test]
    fn zero_budget_request_drains_on_admission() {
        let mut s = RowSlot::default();
        let (sink, log) = log_sink();
        s.admit(req(vec![1, 2, 3], 0), sink, 16, EOS);
        assert_eq!(s.state(), SlotState::Drained);
        assert!(!s.needs_prefill_logits());
        assert!(s.take_done());
        let log = log.borrow();
        assert!(log.toks.is_empty());
        let c = log.done.as_ref().unwrap();
        assert!(c.tokens.is_empty());
        assert_eq!(c.stop, StopReason::MaxNew);
    }

    // ---- non-StopSeq drains flush the stop-sequence holdback tail -------

    #[test]
    fn window_full_drain_flushes_the_held_back_stop_tail() {
        // cap 5, prompt 3: room for exactly 2 generated tokens. The
        // second one opens a partial [8, 9] match at the same moment the
        // window fills — the held token must flush with the WindowFull
        // drain, not be swallowed as if the stop had matched.
        let mut r = RowPlan::with_stops(vec![1, 5, 3], 5, 10, 2, vec![vec![8, 9]]);
        r.push(7);
        assert_eq!(r.committed(), 1);
        r.push(8); // partial match AND window full
        assert!(!r.alive());
        assert_eq!(r.committed(), 2, "drain flushes the held tail");
        let c = r.into_completion();
        assert_eq!(c.tokens, vec![7, 8]);
        assert_eq!(c.stop, StopReason::WindowFull);
    }

    #[test]
    fn slot_flushes_held_back_tail_when_the_window_fills() {
        let mut s = RowSlot::default();
        let (sink, log) = log_sink();
        let r = req(vec![1, 5], 10).with_stop(vec![vec![8, 9]]);
        s.admit(r, sink, 5, EOS); // cap 5: room for 3 generated tokens
        s.consume(Some(&row_for(4, 16)));
        s.consume(Some(&row_for(7, 16))); // prompt fed: first token 7
        s.consume(Some(&row_for(4, 16)));
        assert_eq!(log.borrow().toks, vec![7, 4]);
        s.consume(Some(&row_for(8, 16))); // opens [8, 9]; window fills
        assert_eq!(s.state(), SlotState::Drained);
        assert!(s.take_done());
        let log = log.borrow();
        assert_eq!(log.toks, vec![7, 4, 8], "held 8 streamed on drain");
        let c = log.done.as_ref().unwrap();
        assert_eq!(c.tokens, vec![7, 4, 8]);
        assert_eq!(c.stop, StopReason::WindowFull);
    }

    #[test]
    fn slot_flushes_held_back_tail_when_draining_for_max_new() {
        let mut s = RowSlot::default();
        let (sink, log) = log_sink();
        let r = req(vec![1], 2).with_stop(vec![vec![8, 9]]);
        s.admit(r, sink, 64, EOS);
        s.consume(Some(&row_for(5, 16))); // first token
        assert_eq!(log.borrow().toks, vec![5]);
        s.consume(Some(&row_for(8, 16))); // partial match + budget reached
        assert_eq!(s.state(), SlotState::Drained);
        assert!(s.take_done());
        let log = log.borrow();
        assert_eq!(log.toks, vec![5, 8], "tail flushed, not swallowed");
        let c = log.done.as_ref().unwrap();
        assert_eq!(c.tokens, vec![5, 8]);
        assert_eq!(c.stop, StopReason::MaxNew);
    }

    // ---- paged mode: page attachment, growth, harvest -------------------

    #[test]
    fn attach_pages_allocates_prompt_pages_and_streams_all_when_cold() {
        let mut a = PageAllocator::new(13, 2);
        let mut s = RowSlot::default();
        s.admit(req(vec![1, 2, 3, 4, 5], 1), log_sink().0, 16, EOS);
        s.attach_pages(&mut a).unwrap();
        let occ = s.0.as_ref().unwrap();
        assert_eq!(occ.pages.len(), 3, "ceil(5 / 2) pages at admission");
        assert_eq!(occ.fed, 0, "cold cache: stream the whole prompt");
        assert_eq!(a.outstanding(), 3);
    }

    #[test]
    fn drained_row_registers_its_prefix_and_a_twin_adopts_it() {
        let mut a = PageAllocator::new(13, 2);
        let prompt = vec![1, 2, 3, 4, 5];

        // donor: streams its prompt, emits one token, drains, harvests
        let mut s = RowSlot::default();
        s.admit(req(prompt.clone(), 1), log_sink().0, 16, EOS);
        s.attach_pages(&mut a).unwrap();
        let donor_pages = s.0.as_ref().unwrap().pages.clone();
        for _ in 0..5 {
            s.ensure_page(&mut a).unwrap();
            s.consume(Some(&row_for(7, 16)));
        }
        assert_eq!(s.state(), SlotState::Drained); // max_new 1
        s.harvest_pages(&mut a);
        assert!(s.take_done());
        assert_eq!(a.n_cached(), 2, "both full prompt pages cached");
        assert_eq!(a.outstanding(), 0, "donor's refs all released");

        // twin: adopts the 2 full pages, resumes at the shared boundary
        let mut s = RowSlot::default();
        s.admit(req(prompt, 1), log_sink().0, 16, EOS);
        s.attach_pages(&mut a).unwrap();
        let occ = s.0.as_ref().unwrap();
        assert_eq!(occ.fed, 4, "2 adopted pages x page_t 2");
        assert_eq!(occ.pages[..2], donor_pages[..2]);
        assert_eq!(s.state(), SlotState::Prefilling);
        assert_eq!(s.step_input(PAD), (5, 4), "streams only the last token");
        assert!(!s.no_progress(), "adopters never join a batch prefill");
        assert_eq!(a.prefix_hits, 1);
        assert_eq!(a.prefix_pages_served, 2);
    }

    #[test]
    fn zero_budget_rows_take_no_pages_and_register_nothing() {
        let mut a = PageAllocator::new(13, 2);
        let mut s = RowSlot::default();
        s.admit(req(vec![1, 2, 3], 0), log_sink().0, 16, EOS);
        s.attach_pages(&mut a).unwrap();
        assert_eq!(s.state(), SlotState::Drained);
        assert_eq!(a.outstanding(), 0, "no pages for an unprefilled row");
        s.harvest_pages(&mut a);
        assert!(s.take_done());
        assert_eq!(a.n_cached(), 0, "unprefilled prompts are never cached");
    }

    #[test]
    fn ensure_page_grows_exactly_at_page_boundaries() {
        let mut a = PageAllocator::new(13, 2);
        let mut s = RowSlot::default();
        s.admit(req(vec![1, 2], 6), log_sink().0, 64, EOS);
        s.attach_pages(&mut a).unwrap();
        assert_eq!(s.0.as_ref().unwrap().pages.len(), 1);
        s.ensure_page(&mut a).unwrap(); // writes position 0: covered
        s.consume(Some(&row_for(7, 16)));
        s.ensure_page(&mut a).unwrap(); // position 1: covered
        s.consume(Some(&row_for(7, 16))); // prompt fed, first token pushed
        s.ensure_page(&mut a).unwrap(); // position 2 next: page boundary
        assert_eq!(s.0.as_ref().unwrap().pages.len(), 2);
        s.consume(Some(&row_for(7, 16)));
        s.ensure_page(&mut a).unwrap(); // position 3: same page
        assert_eq!(s.0.as_ref().unwrap().pages.len(), 2);
    }

    #[test]
    fn page_table_maps_pages_in_logical_order_and_scratch_elsewhere() {
        let mut a = PageAllocator::new(13, 2);
        let mut slots = vec![RowSlot::default(), RowSlot::default()];
        slots[1].admit(req(vec![1, 2, 3], 1), log_sink().0, 16, EOS);
        slots[1].attach_pages(&mut a).unwrap();
        let pages = slots[1].0.as_ref().unwrap().pages.clone();
        let t = page_table(&slots, 2, 3);
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.data[..3], [0, 0, 0], "vacant row: all scratch");
        assert_eq!(t.data[3..5], [pages[0] as i32, pages[1] as i32]);
        assert_eq!(t.data[5], 0, "beyond the row's pages: scratch");
    }

    #[test]
    fn eos_as_first_streamed_token_drains_immediately() {
        let mut s = RowSlot::default();
        let (sink, log) = log_sink();
        s.admit(req(vec![1, 5], 4), sink, 16, EOS);
        s.consume(Some(&row_for(9, 16)));
        s.consume(Some(&row_for(EOS, 16))); // first token is <eos>
        assert_eq!(s.state(), SlotState::Drained);
        assert!(s.take_done());
        let log = log.borrow();
        assert!(log.toks.is_empty());
        let c = log.done.as_ref().unwrap();
        assert!(c.tokens.is_empty());
        assert_eq!(c.stop, StopReason::Eos);
    }

    // ---- fault isolation: cancel, error drain, park/re-prefill ----------

    #[test]
    fn cancel_token_is_shared_and_observed_only_while_in_flight() {
        let mut s = RowSlot::default();
        assert!(!s.cancel_requested(), "vacant rows have nothing to cancel");
        let token = CancelToken::new();
        let mut r = req(vec![1, 5], 4);
        r.cancel = Some(token.clone());
        s.admit(r, log_sink().0, 16, EOS);
        assert!(!s.cancel_requested());
        token.cancel();
        assert!(token.is_cancelled(), "clones share the flag");
        assert!(s.cancel_requested());

        // an uncancellable request never reports
        let mut s = RowSlot::default();
        s.admit(req(vec![1], 2), log_sink().0, 16, EOS);
        assert!(!s.cancel_requested());

        // a drained row delivers normally even if the flag flips late
        let mut s = RowSlot::default();
        let token = CancelToken::new();
        let mut r = req(vec![1], 0); // zero budget: drained at admission
        r.cancel = Some(token.clone());
        s.admit(r, log_sink().0, 16, EOS);
        token.cancel();
        assert!(!s.cancel_requested(), "finished completions still deliver");
    }

    #[test]
    fn fail_fires_on_fail_with_the_delivered_tokens() {
        let mut s = RowSlot::default();
        let (sink, log) = log_sink();
        let r = req(vec![1], 10).with_stop(vec![vec![8, 9]]);
        s.admit(r, sink, 64, EOS);
        s.consume(Some(&row_for(5, 16))); // first token streams
        s.consume(Some(&row_for(8, 16))); // held back (partial stop match)
        assert_eq!(log.borrow().toks, vec![5]);
        s.fail(FailClass::Internal, "injected failure");
        assert_eq!(s.state(), SlotState::Vacant, "the row is freed");
        let log = log.borrow();
        // the default on_fail folds into a Completion that repeats exactly
        // the delivered tokens — the held-back 8 is not smuggled out
        let c = log.done.as_ref().expect("terminal event fired");
        assert_eq!(c.tokens, vec![5]);
        assert_eq!(c.stop, StopReason::Error);
        assert_eq!(c.stop.label(), "error");
    }

    #[test]
    fn fail_with_cancelled_class_maps_to_the_cancelled_stop() {
        let mut s = RowSlot::default();
        let (sink, log) = log_sink();
        s.admit(req(vec![1, 2], 4), sink, 16, EOS);
        s.fail(FailClass::Cancelled, "client went away");
        let log = log.borrow();
        let c = log.done.as_ref().unwrap();
        assert!(c.tokens.is_empty());
        assert_eq!(c.stop, StopReason::Cancelled);
        assert_eq!(c.stop.label(), "cancelled");
    }

    #[test]
    fn park_releases_pages_and_re_prefill_rebuilds_token_identically() {
        let mut a = PageAllocator::new(13, 2);
        let mut s = RowSlot::default();
        s.admit(req(vec![1, 2, 3], 6), log_sink().0, 64, EOS);
        s.attach_pages(&mut a).unwrap();
        for _ in 0..4 {
            s.ensure_page(&mut a).unwrap();
            s.consume(Some(&row_for(7, 16)));
        }
        assert_eq!(s.state(), SlotState::Decoding);
        assert!(a.outstanding() > 0);

        s.park(&mut a);
        assert_eq!(s.state(), SlotState::Parked);
        assert_eq!(a.outstanding(), 0, "parking released every page");
        assert!(s.live(), "parked rows stay spoken for");
        assert!(s.no_progress(), "parked rows can join nothing");
        assert_eq!(s.step_input(PAD), (PAD, 0), "parked rows write scratch");
        assert!(!s.consumes_next_logits());
        s.ensure_page(&mut a).unwrap();
        assert_eq!(a.outstanding(), 0, "parked rows never grow pages");

        // unparking is re_prefill: the whole sequence (prompt + the 2
        // generated tokens) becomes the new prompt, sampler untouched
        let occ = s.0.as_mut().unwrap();
        assert_eq!(occ.prompt_len, 5, "3 prompt + 2 generated");
        assert_eq!(occ.fed, 0);
        assert_eq!(occ.preempts, 1);
        occ.parked = false;
        assert_eq!(s.state(), SlotState::Prefilling);
        assert!(s.needs_prefill_logits(), "resumes by sampling the next token");
    }

    #[test]
    fn max_total_len_tracks_budget_and_window() {
        let mut r = RowPlan::new(vec![1, 2, 3], 64, 4, EOS);
        assert_eq!(r.max_total_len(), 7, "3 prompt + 4 budget");
        r.push(9);
        assert_eq!(r.max_total_len(), 7, "spending budget moves nothing");
        let r = RowPlan::new(vec![1, 2, 3], 5, 100, EOS);
        assert_eq!(r.max_total_len(), 5, "clamped to the window");
    }
}
