//! Serving subsystem (DESIGN.md §10): continuous batching + pluggable
//! sampling over the decode ABI.
//!
//! Three layers:
//!
//! * [`session`] — [`ServeSession`]: the row-slot lifecycle
//!   (Vacant → Prefilling → Decoding → Drained) and the admission queue
//!   that hands freed rows to waiting requests mid-decode;
//! * [`sampler`] — the [`Sampler`] trait (greedy / temperature / top-k /
//!   top-p), seeded per request so decodes are reproducible and
//!   independent of batch placement;
//! * the shared `Engine` operand builders (`engine::trainer::ParamOp`)
//!   this subsystem is built on, so the device/host flow decision is
//!   never re-derived here.
//!
//! `engine::decode::DecodeSession` remains the static-batch greedy
//! wrapper over [`ServeSession`] — the parity baseline (`it_decode.rs`)
//! and the `LISA_DECODE=legacy` contract are unchanged.

// Clippy backstop for the no-panic serving contract (DESIGN.md §13,
// enforced structurally by lisa-lint's serve_panic pass).
#![warn(clippy::unwrap_used, clippy::expect_used)]
pub mod sampler;
pub mod session;

pub use sampler::{request_seed, Sampler, SamplerSpec};
pub use session::{
    CancelToken, Feed, KvMode, LoopStats, Request, RequestSink, RequestSource, ServeSession,
};
