//! Pluggable decode-time sampling over the `decode_logits` download
//! (DESIGN.md §10).
//!
//! **Determinism contract.** A sampler is seeded *per request* (one
//! [`Rng`] stream each, derived from the request's seed), so a completion
//! depends only on `(prompt, spec, seed)` — never on batch placement,
//! admission order, or what the neighbouring rows are doing. That is what
//! makes continuous batching testable: `tests/it_serve.rs` asserts every
//! served completion equals a solo static-batch decode of the same
//! request.
//!
//! **Degeneracies** (asserted in tests): `temperature <= 0` and
//! `top_k == 1` reproduce [`argmax`] token for token by construction —
//! both short-circuit into the same first-of-ties argmax the greedy path
//! and the legacy full-forward loop use, so "sampling off" can never
//! drift from the PR 4 parity baseline. `top_p <= 0` keeps only the head
//! of the nucleus (argmax again); `top_p >= 1` is full-vocab temperature
//! sampling.

// Clippy backstop for the no-panic serving contract (DESIGN.md §13,
// enforced structurally by lisa-lint's serve_panic pass).
#![warn(clippy::unwrap_used, clippy::expect_used)]
use anyhow::{bail, ensure, Result};

use crate::engine::decode::argmax;
use crate::util::rng::Rng;

/// Decode-time sampling policy — CLI-shaped (`--sample` / `--temperature`
/// / `--top-k` / `--top-p`), cloned into every [`super::Request`].
/// Build one stateful [`Sampler`] per request via [`SamplerSpec::build`].
#[derive(Debug, Clone, PartialEq, Default)]
pub enum SamplerSpec {
    /// First-of-ties argmax — the PR 4 behavior and the parity baseline.
    #[default]
    Greedy,
    /// Softmax at `temperature` over the full vocabulary.
    Temperature { temperature: f32 },
    /// Keep the `k` highest logits (first-of-ties order), renormalize at
    /// `temperature`.
    TopK { k: usize, temperature: f32 },
    /// Nucleus sampling: the smallest probability-sorted prefix with
    /// cumulative mass `>= p`, renormalized at `temperature`.
    TopP { p: f32, temperature: f32 },
    /// Per-request additive logit bias applied before the base policy
    /// picks (the HTTP `logit_bias` surface). A bias of
    /// `f32::NEG_INFINITY` bans the token outright — it can never be
    /// selected while any unbanned token remains.
    Biased {
        bias: Vec<(i32, f32)>,
        base: Box<SamplerSpec>,
    },
}

impl SamplerSpec {
    /// Parse the CLI surface: `mode` names the policy, the scalars ride
    /// along (`lisa ... --sample top-k --top-k 40 --temperature 0.8`).
    pub fn parse(mode: &str, temperature: f32, k: usize, p: f32) -> Result<SamplerSpec> {
        ensure!(
            temperature.is_finite() && temperature >= 0.0,
            "--temperature must be finite and >= 0 (got {temperature})"
        );
        Ok(match mode {
            "greedy" => SamplerSpec::Greedy,
            "temperature" => SamplerSpec::Temperature { temperature },
            "top-k" | "topk" => {
                ensure!(k >= 1, "--sample top-k needs --top-k >= 1");
                SamplerSpec::TopK { k, temperature }
            }
            "top-p" | "topp" | "nucleus" => {
                ensure!(
                    p.is_finite() && p > 0.0 && p <= 1.0,
                    "--sample top-p needs 0 < --top-p <= 1 (got {p})"
                );
                SamplerSpec::TopP { p, temperature }
            }
            other => bail!(
                "unknown sampling policy '{other}' — \
                 expected greedy|temperature|top-k|top-p"
            ),
        })
    }

    /// Wrap this spec with an additive logit bias (no-op when `bias` is
    /// empty). Nested wrapping composes: biases apply innermost-first.
    pub fn with_bias(self, bias: Vec<(i32, f32)>) -> SamplerSpec {
        if bias.is_empty() {
            return self;
        }
        SamplerSpec::Biased { bias, base: Box::new(self) }
    }

    /// Whether this spec provably degenerates to first-of-ties argmax (no
    /// RNG draw ever happens; the decode is greedy-deterministic). A
    /// non-empty bias is never greedy-degenerate here: it changes which
    /// token the argmax lands on, so the biased path must run.
    pub fn is_greedy(&self) -> bool {
        match self {
            SamplerSpec::Greedy => true,
            SamplerSpec::Temperature { temperature } => *temperature <= 0.0,
            SamplerSpec::TopK { k, temperature } => *k == 1 || *temperature <= 0.0,
            SamplerSpec::TopP { p, temperature } => *p <= 0.0 || *temperature <= 0.0,
            SamplerSpec::Biased { bias, base } => bias.is_empty() && base.is_greedy(),
        }
    }

    /// Stable display label for tables/bench arms.
    pub fn label(&self) -> String {
        match self {
            SamplerSpec::Greedy => "greedy".into(),
            SamplerSpec::Temperature { temperature } => format!("temperature(T={temperature})"),
            SamplerSpec::TopK { k, temperature } => format!("top-k(k={k},T={temperature})"),
            SamplerSpec::TopP { p, temperature } => format!("top-p(p={p},T={temperature})"),
            SamplerSpec::Biased { bias, base } => {
                format!("biased(n={},{})", bias.len(), base.label())
            }
        }
    }

    /// Instantiate the per-request sampler. `seed` is the request's own
    /// stream (see [`request_seed`]); greedy-degenerate specs never draw
    /// from it.
    pub fn build(&self, seed: u64) -> Box<dyn Sampler> {
        if self.is_greedy() {
            return Box::new(GreedySampler);
        }
        match self {
            // is_greedy() returned above; a stray Greedy spec still gets
            // a working sampler rather than a panic
            SamplerSpec::Greedy => Box::new(GreedySampler),
            SamplerSpec::Temperature { temperature } => Box::new(TemperatureSampler {
                temperature: *temperature,
                rng: Rng::new(seed),
            }),
            SamplerSpec::TopK { k, temperature } => Box::new(TopKSampler {
                k: *k,
                temperature: *temperature,
                rng: Rng::new(seed),
            }),
            SamplerSpec::TopP { p, temperature } => Box::new(TopPSampler {
                p: *p,
                temperature: *temperature,
                rng: Rng::new(seed),
            }),
            SamplerSpec::Biased { bias, base } => {
                if bias.is_empty() {
                    return base.build(seed);
                }
                Box::new(BiasedSampler {
                    bias: bias.clone(),
                    scratch: Vec::new(),
                    inner: base.build(seed),
                })
            }
        }
    }
}

/// Derive request `idx`'s sampler seed from one base seed (`--gen-seed`).
/// Pure function of `(base, idx)` so the solo-decode parity reference can
/// reproduce any request's stream without replaying the queue.
pub fn request_seed(base: u64, idx: usize) -> u64 {
    // golden-ratio stride, same constant family as util::rng's SplitMix64
    base ^ (idx as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// Picks the next token id from one row of decode logits `[V]`. Stateful:
/// owns the request's RNG stream, one draw per sampled token.
pub trait Sampler {
    fn pick(&mut self, logits: &[f32]) -> i32;
}

/// First-of-ties argmax (shared with the legacy path via
/// [`crate::engine::decode::argmax`]).
pub struct GreedySampler;

impl Sampler for GreedySampler {
    fn pick(&mut self, logits: &[f32]) -> i32 {
        argmax(logits)
    }
}

/// `(logit desc, index asc)` — the same first-of-ties order `argmax`
/// uses, as a total order (the index tiebreak means no two candidates
/// compare equal), so every cutoff below is deterministic.
fn by_logit_desc(logits: &[f32], a: usize, b: usize) -> std::cmp::Ordering {
    logits[b]
        .partial_cmp(&logits[a])
        .unwrap_or(std::cmp::Ordering::Equal)
        .then(a.cmp(&b))
}

/// Softmax weights at `temperature` for the given candidate logits,
/// max-subtracted for stability; f64 so the cumulative walk is exact
/// enough to be reproducible across platforms.
fn softmax_weights(logits: &[f32], idx: &[usize], temperature: f32) -> Vec<f64> {
    let t = temperature as f64;
    let mx = idx
        .iter()
        .map(|&i| logits[i] as f64)
        .fold(f64::NEG_INFINITY, f64::max);
    idx.iter()
        .map(|&i| (((logits[i] as f64) - mx) / t).exp())
        .collect()
}

/// Candidate indices fully sorted by [`by_logit_desc`] (top-p needs the
/// whole order to walk the nucleus).
fn sorted_candidates(logits: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| by_logit_desc(logits, a, b));
    idx
}

/// The `k` best candidates in [`by_logit_desc`] order without sorting
/// the whole vocabulary: O(V + k log k) select-then-sort. The selected
/// *set* is unique (total order), so this matches a full sort's prefix.
fn top_k_candidates(logits: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, |&a, &b| by_logit_desc(logits, a, b));
        idx.truncate(k);
    }
    idx.sort_by(|&a, &b| by_logit_desc(logits, a, b));
    idx
}

pub struct TemperatureSampler {
    temperature: f32,
    rng: Rng,
}

impl Sampler for TemperatureSampler {
    fn pick(&mut self, logits: &[f32]) -> i32 {
        // full-vocab softmax in token order: the drawn index IS the token
        let t = self.temperature as f64;
        let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
        let w: Vec<f64> = logits.iter().map(|&x| (((x as f64) - mx) / t).exp()).collect();
        self.rng.sample_weighted(&w) as i32
    }
}

pub struct TopKSampler {
    k: usize,
    temperature: f32,
    rng: Rng,
}

impl Sampler for TopKSampler {
    fn pick(&mut self, logits: &[f32]) -> i32 {
        let idx = top_k_candidates(logits, self.k.max(1));
        let w = softmax_weights(logits, &idx, self.temperature);
        idx[self.rng.sample_weighted(&w)] as i32
    }
}

pub struct TopPSampler {
    p: f32,
    temperature: f32,
    rng: Rng,
}

impl TopPSampler {
    /// Size of the nucleus: the smallest prefix of the probability-sorted
    /// candidates whose cumulative mass reaches `p` (always >= 1).
    fn nucleus_len(weights: &[f64], p: f64) -> usize {
        let total: f64 = weights.iter().sum();
        let mut cum = 0.0;
        for (n, w) in weights.iter().enumerate() {
            cum += w;
            if cum >= p * total {
                return n + 1;
            }
        }
        weights.len()
    }
}

impl Sampler for TopPSampler {
    fn pick(&mut self, logits: &[f32]) -> i32 {
        let mut idx = sorted_candidates(logits);
        // mass is measured at the sampling temperature (weights are
        // descending because the candidates are logit-sorted)
        let mut w = softmax_weights(logits, &idx, self.temperature);
        let n = Self::nucleus_len(&w, self.p as f64);
        idx.truncate(n);
        w.truncate(n);
        idx[self.rng.sample_weighted(&w)] as i32
    }
}

/// Adds a per-request bias to the logits row, then delegates to the base
/// sampler. `-inf` entries zero the token's softmax weight and sort it
/// below every finite logit, so it never enters a top-k/top-p cutoff
/// ahead of an unbanned token and is never drawn.
pub struct BiasedSampler {
    bias: Vec<(i32, f32)>,
    /// Reused biased copy of the logits row (no per-token allocation).
    scratch: Vec<f32>,
    inner: Box<dyn Sampler>,
}

impl Sampler for BiasedSampler {
    fn pick(&mut self, logits: &[f32]) -> i32 {
        self.scratch.clear();
        self.scratch.extend_from_slice(logits);
        for &(tok, b) in &self.bias {
            // out-of-vocab (or negative) ids are ignored, not a panic:
            // the model thread must survive any admitted request
            if let Some(x) = self.scratch.get_mut(tok as usize) {
                *x += b;
            }
        }
        // Every token banned: all downstream weights would be zero (an
        // assert in the RNG). Fall back to the unbiased argmax rather
        // than poisoning the model thread.
        if !self.scratch.iter().any(|x| x.is_finite()) {
            return argmax(logits);
        }
        // A `+inf` (or NaN-producing) bias can't flow into softmax
        // weights; `+inf` means "force this token", so resolve it by
        // argmax over the biased row (NaNs lose every comparison).
        if self.scratch.iter().any(|x| x.is_nan() || *x == f32::INFINITY) {
            return argmax(&self.scratch);
        }
        let pick = self.inner.pick(&self.scratch);
        // The weighted walk can only land on a zero-weight (banned)
        // token via a measure-zero float edge; re-pick so the ban holds
        // unconditionally.
        if self.scratch.get(pick as usize).map_or(false, |x| x.is_finite()) {
            pick
        } else {
            argmax(&self.scratch)
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests panic by design
mod tests {
    use super::*;

    fn logits() -> Vec<f32> {
        vec![0.5, 2.0, -1.0, 2.0, 1.5, -3.0, 0.0, 1.9]
    }

    #[test]
    fn zero_temperature_is_argmax_token_for_token() {
        let mut rng = Rng::new(3);
        for spec in [
            SamplerSpec::Temperature { temperature: 0.0 },
            SamplerSpec::TopK { k: 5, temperature: 0.0 },
            SamplerSpec::TopP { p: 0.9, temperature: 0.0 },
        ] {
            assert!(spec.is_greedy());
            let mut s = spec.build(7);
            for _ in 0..200 {
                let row: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
                assert_eq!(s.pick(&row), argmax(&row), "{spec:?}");
            }
        }
    }

    #[test]
    fn top_k_one_is_argmax_including_ties() {
        let mut s = SamplerSpec::TopK { k: 1, temperature: 1.0 }.build(11);
        assert!(SamplerSpec::TopK { k: 1, temperature: 1.0 }.is_greedy());
        assert_eq!(s.pick(&logits()), 1); // first of the 2.0 tie
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let row: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
            assert_eq!(s.pick(&row), argmax(&row));
        }
    }

    #[test]
    fn seeded_sampling_is_bit_reproducible() {
        for spec in [
            SamplerSpec::Temperature { temperature: 0.8 },
            SamplerSpec::TopK { k: 4, temperature: 1.2 },
            SamplerSpec::TopP { p: 0.85, temperature: 1.0 },
        ] {
            let mut a = spec.build(42);
            let mut b = spec.build(42);
            let mut rng = Rng::new(9);
            for _ in 0..300 {
                let row: Vec<f32> = (0..24).map(|_| rng.normal_f32()).collect();
                assert_eq!(a.pick(&row), b.pick(&row), "{spec:?}");
            }
        }
    }

    #[test]
    fn top_k_never_leaves_the_k_best() {
        let k = 3;
        let mut s = SamplerSpec::TopK { k, temperature: 2.0 }.build(1);
        let mut rng = Rng::new(13);
        for _ in 0..300 {
            let row: Vec<f32> = (0..20).map(|_| rng.normal_f32()).collect();
            let allowed: Vec<i32> =
                sorted_candidates(&row)[..k].iter().map(|&i| i as i32).collect();
            assert!(allowed.contains(&s.pick(&row)));
        }
    }

    #[test]
    fn top_p_mass_cutoff_property() {
        // property: the nucleus is the smallest sorted prefix with mass
        // >= p, and every drawn token lies inside it
        let mut rng = Rng::new(17);
        for _ in 0..200 {
            let row: Vec<f32> = (0..24).map(|_| rng.normal_f32() * 2.0).collect();
            let p = 0.05 + 0.9 * rng.f64() as f32;
            let idx = sorted_candidates(&row);
            let w = softmax_weights(&row, &idx, 1.0);
            let total: f64 = w.iter().sum();
            let n = TopPSampler::nucleus_len(&w, p as f64);
            let mass: f64 = w[..n].iter().sum::<f64>() / total;
            assert!(mass >= p as f64 - 1e-12, "mass {mass} < p {p}");
            if n > 1 {
                let prev: f64 = w[..n - 1].iter().sum::<f64>() / total;
                assert!(prev < p as f64, "prefix {} already reaches p {p}", n - 1);
            }
            let nucleus: Vec<i32> = idx[..n].iter().map(|&i| i as i32).collect();
            let mut s = SamplerSpec::TopP { p, temperature: 1.0 }.build(23);
            for _ in 0..20 {
                assert!(nucleus.contains(&s.pick(&row)));
            }
        }
    }

    #[test]
    fn top_k_selection_matches_the_full_sort_prefix() {
        let mut rng = Rng::new(21);
        for _ in 0..200 {
            let row: Vec<f32> = (0..40).map(|_| rng.normal_f32()).collect();
            let k = 1 + rng.below(12);
            assert_eq!(top_k_candidates(&row, k), &sorted_candidates(&row)[..k]);
        }
    }

    #[test]
    fn top_p_full_mass_covers_the_vocab() {
        let row = logits();
        let idx = sorted_candidates(&row);
        let w = softmax_weights(&row, &idx, 1.0);
        assert_eq!(TopPSampler::nucleus_len(&w, 1.0), row.len());
    }

    // ---- logit bias ----------------------------------------------------

    #[test]
    fn bias_shifts_the_greedy_pick() {
        // unbiased argmax of `logits()` is token 1 (first of the 2.0 tie)
        let spec = SamplerSpec::Greedy.with_bias(vec![(3, 1.0)]);
        assert!(!spec.is_greedy(), "a non-empty bias must run the biased path");
        let mut s = spec.build(7);
        assert_eq!(s.pick(&logits()), 3);
        // empty bias is a structural no-op
        let spec = SamplerSpec::Greedy.with_bias(vec![]);
        assert_eq!(spec, SamplerSpec::Greedy);
        assert!(spec.is_greedy());
    }

    #[test]
    fn neg_inf_bias_provably_bans_a_token() {
        // property: under every base policy, a -inf-biased token is never
        // drawn, whatever the logits row looks like
        let mut rng = Rng::new(29);
        for base in [
            SamplerSpec::Greedy,
            SamplerSpec::Temperature { temperature: 1.0 },
            SamplerSpec::TopK { k: 3, temperature: 0.7 },
            SamplerSpec::TopP { p: 0.95, temperature: 1.1 },
        ] {
            for trial in 0..50 {
                let banned = rng.below(16) as i32;
                let spec = base
                    .clone()
                    .with_bias(vec![(banned, f32::NEG_INFINITY)]);
                let mut s = spec.build(1000 + trial);
                for _ in 0..40 {
                    let mut row: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
                    // make the banned token the unbiased favourite so the
                    // ban is actually load-bearing
                    row[banned as usize] = 50.0;
                    assert_ne!(s.pick(&row), banned, "{}", spec.label());
                }
            }
        }
    }

    #[test]
    fn all_banned_falls_back_to_unbiased_argmax() {
        let bias: Vec<(i32, f32)> = (0..8).map(|t| (t, f32::NEG_INFINITY)).collect();
        for base in [
            SamplerSpec::Greedy,
            SamplerSpec::Temperature { temperature: 0.8 },
        ] {
            let mut s = base.clone().with_bias(bias.clone()).build(5);
            // no panic, and the pick is the unbiased argmax (token 1)
            assert_eq!(s.pick(&logits()), 1);
        }
    }

    #[test]
    fn bias_outside_the_vocab_is_ignored() {
        let spec = SamplerSpec::Temperature { temperature: 1.0 }
            .with_bias(vec![(-3, 10.0), (10_000, 10.0), (2, f32::NEG_INFINITY)]);
        let mut s = spec.build(9);
        for _ in 0..50 {
            let t = s.pick(&logits());
            assert!((0..8).contains(&t));
            assert_ne!(t, 2);
        }
    }

    #[test]
    fn pos_inf_bias_forces_the_token() {
        let mut s = SamplerSpec::TopP { p: 0.9, temperature: 1.0 }
            .with_bias(vec![(6, f32::INFINITY)])
            .build(4);
        for _ in 0..20 {
            assert_eq!(s.pick(&logits()), 6);
        }
    }

    #[test]
    fn biased_sampling_is_seed_reproducible_and_matches_pre_biased_logits() {
        // adding the bias up front and sampling unbiased must equal the
        // BiasedSampler on raw logits, draw for draw (same seed)
        let bias = vec![(0, 2.5f32), (4, -1.5f32), (7, 0.75f32)];
        let base = SamplerSpec::TopK { k: 5, temperature: 1.3 };
        let mut a = base.clone().with_bias(bias.clone()).build(77);
        let mut b = base.build(77);
        let mut rng = Rng::new(31);
        for _ in 0..200 {
            let row: Vec<f32> = (0..12).map(|_| rng.normal_f32()).collect();
            let mut shifted = row.clone();
            for &(t, v) in &bias {
                shifted[t as usize] += v;
            }
            assert_eq!(a.pick(&row), b.pick(&shifted));
        }
    }

    #[test]
    fn request_seed_is_per_index_stable() {
        assert_eq!(request_seed(42, 0), request_seed(42, 0));
        assert_ne!(request_seed(42, 0), request_seed(42, 1));
        assert_ne!(request_seed(42, 3), request_seed(43, 3));
    }

    #[test]
    fn parse_round_trips_the_cli_surface() {
        assert_eq!(SamplerSpec::parse("greedy", 1.0, 0, 1.0).unwrap(), SamplerSpec::Greedy);
        assert_eq!(
            SamplerSpec::parse("temperature", 0.7, 0, 1.0).unwrap(),
            SamplerSpec::Temperature { temperature: 0.7 }
        );
        assert_eq!(
            SamplerSpec::parse("top-k", 1.0, 40, 1.0).unwrap(),
            SamplerSpec::TopK { k: 40, temperature: 1.0 }
        );
        assert_eq!(
            SamplerSpec::parse("top-p", 1.0, 0, 0.9).unwrap(),
            SamplerSpec::TopP { p: 0.9, temperature: 1.0 }
        );
        assert!(SamplerSpec::parse("top-k", 1.0, 0, 1.0).is_err());
        assert!(SamplerSpec::parse("top-p", 1.0, 0, 0.0).is_err());
        assert!(SamplerSpec::parse("beam", 1.0, 0, 1.0).is_err());
        assert!(SamplerSpec::parse("temperature", f32::NAN, 0, 1.0).is_err());
    }
}
