//! Pluggable decode-time sampling over the `decode_logits` download
//! (DESIGN.md §10).
//!
//! **Determinism contract.** A sampler is seeded *per request* (one
//! [`Rng`] stream each, derived from the request's seed), so a completion
//! depends only on `(prompt, spec, seed)` — never on batch placement,
//! admission order, or what the neighbouring rows are doing. That is what
//! makes continuous batching testable: `tests/it_serve.rs` asserts every
//! served completion equals a solo static-batch decode of the same
//! request.
//!
//! **Degeneracies** (asserted in tests): `temperature <= 0` and
//! `top_k == 1` reproduce [`argmax`] token for token by construction —
//! both short-circuit into the same first-of-ties argmax the greedy path
//! and the legacy full-forward loop use, so "sampling off" can never
//! drift from the PR 4 parity baseline. `top_p <= 0` keeps only the head
//! of the nucleus (argmax again); `top_p >= 1` is full-vocab temperature
//! sampling.

use anyhow::{bail, ensure, Result};

use crate::engine::decode::argmax;
use crate::util::rng::Rng;

/// Decode-time sampling policy — CLI-shaped (`--sample` / `--temperature`
/// / `--top-k` / `--top-p`), cheap to copy into every [`super::Request`].
/// Build one stateful [`Sampler`] per request via [`SamplerSpec::build`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum SamplerSpec {
    /// First-of-ties argmax — the PR 4 behavior and the parity baseline.
    #[default]
    Greedy,
    /// Softmax at `temperature` over the full vocabulary.
    Temperature { temperature: f32 },
    /// Keep the `k` highest logits (first-of-ties order), renormalize at
    /// `temperature`.
    TopK { k: usize, temperature: f32 },
    /// Nucleus sampling: the smallest probability-sorted prefix with
    /// cumulative mass `>= p`, renormalized at `temperature`.
    TopP { p: f32, temperature: f32 },
}

impl SamplerSpec {
    /// Parse the CLI surface: `mode` names the policy, the scalars ride
    /// along (`lisa ... --sample top-k --top-k 40 --temperature 0.8`).
    pub fn parse(mode: &str, temperature: f32, k: usize, p: f32) -> Result<SamplerSpec> {
        ensure!(
            temperature.is_finite() && temperature >= 0.0,
            "--temperature must be finite and >= 0 (got {temperature})"
        );
        Ok(match mode {
            "greedy" => SamplerSpec::Greedy,
            "temperature" => SamplerSpec::Temperature { temperature },
            "top-k" | "topk" => {
                ensure!(k >= 1, "--sample top-k needs --top-k >= 1");
                SamplerSpec::TopK { k, temperature }
            }
            "top-p" | "topp" | "nucleus" => {
                ensure!(
                    p.is_finite() && p > 0.0 && p <= 1.0,
                    "--sample top-p needs 0 < --top-p <= 1 (got {p})"
                );
                SamplerSpec::TopP { p, temperature }
            }
            other => bail!(
                "unknown sampling policy '{other}' — \
                 expected greedy|temperature|top-k|top-p"
            ),
        })
    }

    /// Whether this spec provably degenerates to first-of-ties argmax (no
    /// RNG draw ever happens; the decode is greedy-deterministic).
    pub fn is_greedy(&self) -> bool {
        match *self {
            SamplerSpec::Greedy => true,
            SamplerSpec::Temperature { temperature } => temperature <= 0.0,
            SamplerSpec::TopK { k, temperature } => k == 1 || temperature <= 0.0,
            SamplerSpec::TopP { p, temperature } => p <= 0.0 || temperature <= 0.0,
        }
    }

    /// Stable display label for tables/bench arms.
    pub fn label(&self) -> String {
        match *self {
            SamplerSpec::Greedy => "greedy".into(),
            SamplerSpec::Temperature { temperature } => format!("temperature(T={temperature})"),
            SamplerSpec::TopK { k, temperature } => format!("top-k(k={k},T={temperature})"),
            SamplerSpec::TopP { p, temperature } => format!("top-p(p={p},T={temperature})"),
        }
    }

    /// Instantiate the per-request sampler. `seed` is the request's own
    /// stream (see [`request_seed`]); greedy-degenerate specs never draw
    /// from it.
    pub fn build(&self, seed: u64) -> Box<dyn Sampler> {
        if self.is_greedy() {
            return Box::new(GreedySampler);
        }
        match *self {
            SamplerSpec::Greedy => unreachable!("handled by is_greedy"),
            SamplerSpec::Temperature { temperature } => Box::new(TemperatureSampler {
                temperature,
                rng: Rng::new(seed),
            }),
            SamplerSpec::TopK { k, temperature } => Box::new(TopKSampler {
                k,
                temperature,
                rng: Rng::new(seed),
            }),
            SamplerSpec::TopP { p, temperature } => Box::new(TopPSampler {
                p,
                temperature,
                rng: Rng::new(seed),
            }),
        }
    }
}

/// Derive request `idx`'s sampler seed from one base seed (`--gen-seed`).
/// Pure function of `(base, idx)` so the solo-decode parity reference can
/// reproduce any request's stream without replaying the queue.
pub fn request_seed(base: u64, idx: usize) -> u64 {
    // golden-ratio stride, same constant family as util::rng's SplitMix64
    base ^ (idx as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// Picks the next token id from one row of decode logits `[V]`. Stateful:
/// owns the request's RNG stream, one draw per sampled token.
pub trait Sampler {
    fn pick(&mut self, logits: &[f32]) -> i32;
}

/// First-of-ties argmax (shared with the legacy path via
/// [`crate::engine::decode::argmax`]).
pub struct GreedySampler;

impl Sampler for GreedySampler {
    fn pick(&mut self, logits: &[f32]) -> i32 {
        argmax(logits)
    }
}

/// `(logit desc, index asc)` — the same first-of-ties order `argmax`
/// uses, as a total order (the index tiebreak means no two candidates
/// compare equal), so every cutoff below is deterministic.
fn by_logit_desc(logits: &[f32], a: usize, b: usize) -> std::cmp::Ordering {
    logits[b]
        .partial_cmp(&logits[a])
        .unwrap_or(std::cmp::Ordering::Equal)
        .then(a.cmp(&b))
}

/// Softmax weights at `temperature` for the given candidate logits,
/// max-subtracted for stability; f64 so the cumulative walk is exact
/// enough to be reproducible across platforms.
fn softmax_weights(logits: &[f32], idx: &[usize], temperature: f32) -> Vec<f64> {
    let t = temperature as f64;
    let mx = idx
        .iter()
        .map(|&i| logits[i] as f64)
        .fold(f64::NEG_INFINITY, f64::max);
    idx.iter()
        .map(|&i| (((logits[i] as f64) - mx) / t).exp())
        .collect()
}

/// Candidate indices fully sorted by [`by_logit_desc`] (top-p needs the
/// whole order to walk the nucleus).
fn sorted_candidates(logits: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| by_logit_desc(logits, a, b));
    idx
}

/// The `k` best candidates in [`by_logit_desc`] order without sorting
/// the whole vocabulary: O(V + k log k) select-then-sort. The selected
/// *set* is unique (total order), so this matches a full sort's prefix.
fn top_k_candidates(logits: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, |&a, &b| by_logit_desc(logits, a, b));
        idx.truncate(k);
    }
    idx.sort_by(|&a, &b| by_logit_desc(logits, a, b));
    idx
}

pub struct TemperatureSampler {
    temperature: f32,
    rng: Rng,
}

impl Sampler for TemperatureSampler {
    fn pick(&mut self, logits: &[f32]) -> i32 {
        // full-vocab softmax in token order: the drawn index IS the token
        let t = self.temperature as f64;
        let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
        let w: Vec<f64> = logits.iter().map(|&x| (((x as f64) - mx) / t).exp()).collect();
        self.rng.sample_weighted(&w) as i32
    }
}

pub struct TopKSampler {
    k: usize,
    temperature: f32,
    rng: Rng,
}

impl Sampler for TopKSampler {
    fn pick(&mut self, logits: &[f32]) -> i32 {
        let idx = top_k_candidates(logits, self.k.max(1));
        let w = softmax_weights(logits, &idx, self.temperature);
        idx[self.rng.sample_weighted(&w)] as i32
    }
}

pub struct TopPSampler {
    p: f32,
    temperature: f32,
    rng: Rng,
}

impl TopPSampler {
    /// Size of the nucleus: the smallest prefix of the probability-sorted
    /// candidates whose cumulative mass reaches `p` (always >= 1).
    fn nucleus_len(weights: &[f64], p: f64) -> usize {
        let total: f64 = weights.iter().sum();
        let mut cum = 0.0;
        for (n, w) in weights.iter().enumerate() {
            cum += w;
            if cum >= p * total {
                return n + 1;
            }
        }
        weights.len()
    }
}

impl Sampler for TopPSampler {
    fn pick(&mut self, logits: &[f32]) -> i32 {
        let mut idx = sorted_candidates(logits);
        // mass is measured at the sampling temperature (weights are
        // descending because the candidates are logit-sorted)
        let mut w = softmax_weights(logits, &idx, self.temperature);
        let n = Self::nucleus_len(&w, self.p as f64);
        idx.truncate(n);
        w.truncate(n);
        idx[self.rng.sample_weighted(&w)] as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits() -> Vec<f32> {
        vec![0.5, 2.0, -1.0, 2.0, 1.5, -3.0, 0.0, 1.9]
    }

    #[test]
    fn zero_temperature_is_argmax_token_for_token() {
        let mut rng = Rng::new(3);
        for spec in [
            SamplerSpec::Temperature { temperature: 0.0 },
            SamplerSpec::TopK { k: 5, temperature: 0.0 },
            SamplerSpec::TopP { p: 0.9, temperature: 0.0 },
        ] {
            assert!(spec.is_greedy());
            let mut s = spec.build(7);
            for _ in 0..200 {
                let row: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
                assert_eq!(s.pick(&row), argmax(&row), "{spec:?}");
            }
        }
    }

    #[test]
    fn top_k_one_is_argmax_including_ties() {
        let mut s = SamplerSpec::TopK { k: 1, temperature: 1.0 }.build(11);
        assert!(SamplerSpec::TopK { k: 1, temperature: 1.0 }.is_greedy());
        assert_eq!(s.pick(&logits()), 1); // first of the 2.0 tie
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let row: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
            assert_eq!(s.pick(&row), argmax(&row));
        }
    }

    #[test]
    fn seeded_sampling_is_bit_reproducible() {
        for spec in [
            SamplerSpec::Temperature { temperature: 0.8 },
            SamplerSpec::TopK { k: 4, temperature: 1.2 },
            SamplerSpec::TopP { p: 0.85, temperature: 1.0 },
        ] {
            let mut a = spec.build(42);
            let mut b = spec.build(42);
            let mut rng = Rng::new(9);
            for _ in 0..300 {
                let row: Vec<f32> = (0..24).map(|_| rng.normal_f32()).collect();
                assert_eq!(a.pick(&row), b.pick(&row), "{spec:?}");
            }
        }
    }

    #[test]
    fn top_k_never_leaves_the_k_best() {
        let k = 3;
        let mut s = SamplerSpec::TopK { k, temperature: 2.0 }.build(1);
        let mut rng = Rng::new(13);
        for _ in 0..300 {
            let row: Vec<f32> = (0..20).map(|_| rng.normal_f32()).collect();
            let allowed: Vec<i32> =
                sorted_candidates(&row)[..k].iter().map(|&i| i as i32).collect();
            assert!(allowed.contains(&s.pick(&row)));
        }
    }

    #[test]
    fn top_p_mass_cutoff_property() {
        // property: the nucleus is the smallest sorted prefix with mass
        // >= p, and every drawn token lies inside it
        let mut rng = Rng::new(17);
        for _ in 0..200 {
            let row: Vec<f32> = (0..24).map(|_| rng.normal_f32() * 2.0).collect();
            let p = 0.05 + 0.9 * rng.f64() as f32;
            let idx = sorted_candidates(&row);
            let w = softmax_weights(&row, &idx, 1.0);
            let total: f64 = w.iter().sum();
            let n = TopPSampler::nucleus_len(&w, p as f64);
            let mass: f64 = w[..n].iter().sum::<f64>() / total;
            assert!(mass >= p as f64 - 1e-12, "mass {mass} < p {p}");
            if n > 1 {
                let prev: f64 = w[..n - 1].iter().sum::<f64>() / total;
                assert!(prev < p as f64, "prefix {} already reaches p {p}", n - 1);
            }
            let nucleus: Vec<i32> = idx[..n].iter().map(|&i| i as i32).collect();
            let mut s = SamplerSpec::TopP { p, temperature: 1.0 }.build(23);
            for _ in 0..20 {
                assert!(nucleus.contains(&s.pick(&row)));
            }
        }
    }

    #[test]
    fn top_k_selection_matches_the_full_sort_prefix() {
        let mut rng = Rng::new(21);
        for _ in 0..200 {
            let row: Vec<f32> = (0..40).map(|_| rng.normal_f32()).collect();
            let k = 1 + rng.below(12);
            assert_eq!(top_k_candidates(&row, k), &sorted_candidates(&row)[..k]);
        }
    }

    #[test]
    fn top_p_full_mass_covers_the_vocab() {
        let row = logits();
        let idx = sorted_candidates(&row);
        let w = softmax_weights(&row, &idx, 1.0);
        assert_eq!(TopPSampler::nucleus_len(&w, 1.0), row.len());
    }

    #[test]
    fn request_seed_is_per_index_stable() {
        assert_eq!(request_seed(42, 0), request_seed(42, 0));
        assert_ne!(request_seed(42, 0), request_seed(42, 1));
        assert_ne!(request_seed(42, 3), request_seed(43, 3));
    }

    #[test]
    fn parse_round_trips_the_cli_surface() {
        assert_eq!(SamplerSpec::parse("greedy", 1.0, 0, 1.0).unwrap(), SamplerSpec::Greedy);
        assert_eq!(
            SamplerSpec::parse("temperature", 0.7, 0, 1.0).unwrap(),
            SamplerSpec::Temperature { temperature: 0.7 }
        );
        assert_eq!(
            SamplerSpec::parse("top-k", 1.0, 40, 1.0).unwrap(),
            SamplerSpec::TopK { k: 40, temperature: 1.0 }
        );
        assert_eq!(
            SamplerSpec::parse("top-p", 1.0, 0, 0.9).unwrap(),
            SamplerSpec::TopP { p: 0.9, temperature: 1.0 }
        );
        assert!(SamplerSpec::parse("top-k", 1.0, 0, 1.0).is_err());
        assert!(SamplerSpec::parse("top-p", 1.0, 0, 0.0).is_err());
        assert!(SamplerSpec::parse("beam", 1.0, 0, 1.0).is_err());
        assert!(SamplerSpec::parse("temperature", f32::NAN, 0, 1.0).is_err());
    }
}
