//! Byte-accurate accounting of training-state memory, the measured side of
//! Table 1 / Fig 3 (the analytical extrapolation to paper-scale models
//! lives in `membench`).
//!
//! Categories follow the paper's memory breakdown: weights, weight
//! gradients, optimizer state, activations. The engine/optimizer report
//! their live allocations; the meter tracks the running total's peak —
//! which is exactly what `torch.cuda.max_memory_allocated` gave the paper.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum MemCategory {
    Params,
    Grads,
    OptimState,
    Activations,
    LoraAdapters,
    /// Device-resident bytes held by the engine's buffer cache (the
    /// persistent weight uploads; the chained activation stash is metered
    /// under `Activations` regardless of which side of the boundary it
    /// lives on). On the CPU PJRT plugin these are real host RAM on top
    /// of the `HostTensor` copies, so the cache's cost is tracked where
    /// Table-1 observables are read — the speedup is never
    /// free-by-accounting.
    DeviceBuffers,
}

impl MemCategory {
    pub fn label(&self) -> &'static str {
        match self {
            MemCategory::Params => "params",
            MemCategory::Grads => "grads",
            MemCategory::OptimState => "optim",
            MemCategory::Activations => "activations",
            MemCategory::LoraAdapters => "lora",
            MemCategory::DeviceBuffers => "device",
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct MemoryMeter {
    current: BTreeMap<MemCategory, u64>,
    peak_total: u64,
    peak_by_cat: BTreeMap<MemCategory, u64>,
}

impl MemoryMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the live byte count of a category (absolute, not delta).
    pub fn set(&mut self, cat: MemCategory, bytes: u64) {
        self.current.insert(cat, bytes);
        let peak_cat = self.peak_by_cat.entry(cat).or_insert(0);
        *peak_cat = (*peak_cat).max(bytes);
        let total = self.total();
        self.peak_total = self.peak_total.max(total);
    }

    pub fn add(&mut self, cat: MemCategory, bytes: u64) {
        let cur = self.current.get(&cat).copied().unwrap_or(0);
        self.set(cat, cur + bytes);
    }

    pub fn sub(&mut self, cat: MemCategory, bytes: u64) {
        let cur = self.current.get(&cat).copied().unwrap_or(0);
        self.set(cat, cur.saturating_sub(bytes));
    }

    pub fn get(&self, cat: MemCategory) -> u64 {
        self.current.get(&cat).copied().unwrap_or(0)
    }

    pub fn total(&self) -> u64 {
        self.current.values().sum()
    }

    pub fn peak(&self) -> u64 {
        self.peak_total
    }

    pub fn peak_of(&self, cat: MemCategory) -> u64 {
        self.peak_by_cat.get(&cat).copied().unwrap_or(0)
    }

    pub fn reset_peak(&mut self) {
        self.peak_total = self.total();
        self.peak_by_cat = self.current.clone();
    }

    /// All categories in the canonical (breakdown/checkpoint) order.
    /// `DeviceBuffers` is appended last so checkpoints written before the
    /// category existed still restore (their blob is a prefix of this
    /// order).
    pub const ALL: [MemCategory; 6] = [
        MemCategory::Params,
        MemCategory::Grads,
        MemCategory::OptimState,
        MemCategory::Activations,
        MemCategory::LoraAdapters,
        MemCategory::DeviceBuffers,
    ];

    /// Max-merge a checkpointed peak state (total + per-category bytes in
    /// [`MemoryMeter::ALL`] order) into this meter, so a resumed run
    /// reports the whole run's peak — the Table-1 observable — not just
    /// the post-resume segment's.
    pub fn restore_peak(&mut self, peak_total: u64, peaks_by_cat: &[u64]) {
        self.peak_total = self.peak_total.max(peak_total);
        for (cat, &b) in Self::ALL.iter().zip(peaks_by_cat) {
            let e = self.peak_by_cat.entry(*cat).or_insert(0);
            *e = (*e).max(b);
        }
    }

    pub fn breakdown(&self) -> Vec<(&'static str, u64)> {
        Self::ALL.iter().map(|c| (c.label(), self.peak_of(*c))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_total_maximum() {
        let mut m = MemoryMeter::new();
        m.set(MemCategory::Params, 100);
        m.set(MemCategory::Activations, 50);
        assert_eq!(m.peak(), 150);
        m.set(MemCategory::Activations, 10);
        assert_eq!(m.total(), 110);
        assert_eq!(m.peak(), 150);
        m.set(MemCategory::Grads, 200);
        assert_eq!(m.peak(), 310);
    }

    #[test]
    fn add_sub_roundtrip() {
        let mut m = MemoryMeter::new();
        m.add(MemCategory::OptimState, 40);
        m.add(MemCategory::OptimState, 60);
        assert_eq!(m.get(MemCategory::OptimState), 100);
        m.sub(MemCategory::OptimState, 30);
        assert_eq!(m.get(MemCategory::OptimState), 70);
        m.sub(MemCategory::OptimState, 1000); // saturates, never underflows
        assert_eq!(m.get(MemCategory::OptimState), 0);
        assert_eq!(m.peak_of(MemCategory::OptimState), 100);
    }

    #[test]
    fn restore_peak_max_merges() {
        let mut m = MemoryMeter::new();
        m.set(MemCategory::Params, 100);
        m.restore_peak(900, &[50, 400, 0, 0, 0]);
        assert_eq!(m.peak(), 900);
        assert_eq!(m.peak_of(MemCategory::Params), 100, "live peak wins when larger");
        assert_eq!(m.peak_of(MemCategory::Grads), 400);
        // a smaller checkpointed peak never lowers the live one
        m.restore_peak(10, &[1, 1, 1, 1, 1]);
        assert_eq!(m.peak(), 900);
    }

    #[test]
    fn reset_peak_from_current() {
        let mut m = MemoryMeter::new();
        m.set(MemCategory::Params, 500);
        m.set(MemCategory::Grads, 500);
        m.set(MemCategory::Grads, 0);
        assert_eq!(m.peak(), 1000);
        m.reset_peak();
        assert_eq!(m.peak(), 500);
    }
}
