//! Batched KV-cached greedy decode — the static-batch serving primitive
//! (DESIGN.md §9).
//!
//! Since the serve subsystem landed (DESIGN.md §10), [`DecodeSession`] is
//! a thin wrapper over [`ServeSession`]: `greedy` turns each prompt into
//! a greedy [`Request`] and runs the *static* schedule — batch-width
//! chunks, each prefilled together and fully drained before the next
//! starts. That is byte-for-byte the PR 4 execution shape (same segment
//! sequence, one `decode_step` per generated batch-token, two `[B, 1]`
//! i32 uploads per step on a warm cache), so the `it_decode.rs` parity
//! guarantees carry over unchanged; continuous batching and sampling live
//! in [`crate::engine::serve`].
//!
//! This module keeps the pieces both paths share: [`Completion`] /
//! [`StopReason`], the prompt-clipping policy and the first-of-ties
//! [`argmax`] that the legacy full-forward loop (`eval::generate`) must
//! agree with token for token.

use anyhow::Result;

use crate::model::ModelParams;

use super::serve::{Request, ServeSession};
use super::trainer::Engine;

/// Why a row stopped emitting tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The model produced the end-of-sequence token (not emitted).
    Eos,
    /// The per-call `max_new` budget was reached.
    MaxNew,
    /// The `[B, T]` artifact window is full — no room for another token.
    WindowFull,
    /// A per-request stop sequence matched; the matched suffix is
    /// excluded from the returned tokens (serve subsystem only).
    StopSeq,
}

impl StopReason {
    /// Stable wire label (HTTP `finish_reason`, metrics labels).
    pub fn label(&self) -> &'static str {
        match self {
            StopReason::Eos => "eos",
            StopReason::MaxNew => "max_new",
            StopReason::WindowFull => "window_full",
            StopReason::StopSeq => "stop_seq",
        }
    }
}

/// One prompt's decode result.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Generated token ids (response only, no specials).
    pub tokens: Vec<i32>,
    /// The prompt exceeded the artifact window and was truncated to
    /// `T - 1` tokens before prefill (also logged at warn level).
    pub prompt_truncated: bool,
    pub stop: StopReason,
}

/// Clip a prompt to the `cap - 1` tokens the decode window can serve,
/// warning loudly; returns whether it clipped. One site for the policy
/// *and* its report, shared by the serve planner and the legacy
/// full-forward path (`eval::generate`) so the two can't drift apart —
/// `it_decode.rs` asserts their `prompt_truncated` flags agree.
pub(crate) fn clip_prompt(seq: &mut Vec<i32>, cap: usize) -> bool {
    if seq.len() < cap {
        return false;
    }
    log::warn!(
        "decode: prompt of {} tokens exceeds the {cap}-token artifact window — \
         truncated to {} (completion will be near-empty)",
        seq.len(),
        cap - 1
    );
    seq.truncate(cap - 1);
    true
}

/// First-of-ties argmax. Shared between the greedy sampler, the serve
/// degeneracies (`temperature <= 0`, `top_k == 1`) and the legacy
/// full-forward path: token-for-token parity depends on every path
/// tie-breaking identically.
pub(crate) fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in row.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

/// A batched KV-cached greedy decoder over one engine + parameter store:
/// the static-batch wrapper over [`ServeSession`].
///
/// Fills every row of the `[B, T]` artifacts with a different prompt
/// (chunking when there are more prompts than rows) and pays one
/// `decode_step` execution per generated token instead of a full L-block
/// re-forward.
pub struct DecodeSession<'e, 'rt> {
    serve: ServeSession<'e, 'rt>,
}

impl<'e, 'rt> DecodeSession<'e, 'rt> {
    /// Whether the loaded artifacts carry the decode ABI for this
    /// engine's backend (legacy dirs: no — callers fall back).
    pub fn supported(eng: &Engine) -> bool {
        ServeSession::supported(eng)
    }

    pub fn new(eng: &'e mut Engine<'rt>, params: &'e ModelParams) -> Result<Self> {
        Ok(DecodeSession { serve: ServeSession::new(eng, params)? })
    }

    /// `decode_step` executions across every chunk of this session.
    pub fn decode_steps(&self) -> u64 {
        self.serve.decode_steps
    }

    /// Greedily complete every prompt (token-id sequences including any
    /// leading specials). Returns one [`Completion`] per prompt, in order.
    /// `eos` stops a row (not emitted); `pad` fills unused batch slots
    /// and prompt tails during prefill.
    pub fn greedy(
        &mut self,
        prompts: &[Vec<i32>],
        max_new: usize,
        eos: i32,
        pad: i32,
    ) -> Result<Vec<Completion>> {
        let reqs: Vec<Request> = prompts
            .iter()
            .map(|p| Request::greedy(p.clone(), max_new))
            .collect();
        self.serve.run_static(&reqs, eos, pad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_of_ties() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn clip_prompt_reports_and_truncates_to_cap_minus_one() {
        let mut seq: Vec<i32> = (0..10).collect();
        assert!(clip_prompt(&mut seq, 8));
        assert_eq!(seq.len(), 7);
        let mut short = vec![1, 2, 3];
        assert!(!clip_prompt(&mut short, 8));
        assert_eq!(short.len(), 3);
    }
}
