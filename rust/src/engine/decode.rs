//! Batched KV-cached greedy decode — the static-batch serving primitive
//! (DESIGN.md §9).
//!
//! Since the serve subsystem landed (DESIGN.md §10), [`DecodeSession`] is
//! a thin wrapper over [`ServeSession`]: `greedy` turns each prompt into
//! a greedy [`Request`] and runs the *static* schedule — batch-width
//! chunks, each prefilled together and fully drained before the next
//! starts. That is byte-for-byte the PR 4 execution shape (same segment
//! sequence, one `decode_step` per generated batch-token, two `[B, 1]`
//! i32 uploads per step on a warm cache), so the `it_decode.rs` parity
//! guarantees carry over unchanged; continuous batching and sampling live
//! in [`crate::engine::serve`].
//!
//! This module keeps the pieces both paths share: [`Completion`] /
//! [`StopReason`], the prompt-clipping policy and the first-of-ties
//! [`argmax`] that the legacy full-forward loop (`eval::generate`) must
//! agree with token for token.

// Clippy backstop for the no-panic serving contract (DESIGN.md §13,
// enforced structurally by lisa-lint's serve_panic pass).
#![warn(clippy::unwrap_used, clippy::expect_used)]
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::Result;

use crate::model::ModelParams;
use crate::runtime::fault::{FaultError, FaultInjector};

use super::serve::{KvMode, Request, ServeSession};
use super::trainer::Engine;

/// Why a row stopped emitting tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The model produced the end-of-sequence token (not emitted).
    Eos,
    /// The per-call `max_new` budget was reached.
    MaxNew,
    /// The `[B, T]` artifact window is full — no room for another token.
    WindowFull,
    /// A per-request stop sequence matched; the matched suffix is
    /// excluded from the returned tokens (serve subsystem only).
    StopSeq,
    /// The row was drained by a failure (segment error, pool pressure);
    /// the completion carries whatever tokens were emitted before it.
    Error,
    /// The request was cancelled (client disconnect or deadline).
    Cancelled,
}

impl StopReason {
    /// Stable wire label (HTTP `finish_reason`, metrics labels).
    pub fn label(&self) -> &'static str {
        match self {
            StopReason::Eos => "eos",
            StopReason::MaxNew => "max_new",
            StopReason::WindowFull => "window_full",
            StopReason::StopSeq => "stop_seq",
            StopReason::Error => "error",
            StopReason::Cancelled => "cancelled",
        }
    }
}

/// Failure class of an error-drained request: the HTTP status family and
/// the `/metrics` counter label are both derived from this.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailClass {
    /// Unrecoverable runtime error (HTTP 500).
    Internal,
    /// Resource pressure — the request was rejected or preempted to
    /// protect the rest of the batch; safe to retry (HTTP 503).
    Overloaded,
    /// Cancelled by the client or a deadline; nobody is listening.
    Cancelled,
}

impl FailClass {
    /// Stable label (metrics `class="..."`, logs).
    pub fn label(&self) -> &'static str {
        match self {
            FailClass::Internal => "internal",
            FailClass::Overloaded => "overloaded",
            FailClass::Cancelled => "cancelled",
        }
    }
}

/// A failed (error-drained) request, as delivered to its sink: the
/// failure class, a human-readable reason, and any tokens that were
/// already emitted before the failure.
#[derive(Debug, Clone)]
pub struct ServeFail {
    pub class: FailClass,
    pub message: String,
    pub tokens: Vec<i32>,
}

impl ServeFail {
    pub fn new(class: FailClass, message: impl Into<String>) -> ServeFail {
        ServeFail { class, message: message.into(), tokens: Vec::new() }
    }

    /// The [`StopReason`] a sink without a failure channel reports.
    pub fn stop_reason(&self) -> StopReason {
        match self.class {
            FailClass::Cancelled => StopReason::Cancelled,
            _ => StopReason::Error,
        }
    }
}

/// One prompt's decode result.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Generated token ids (response only, no specials).
    pub tokens: Vec<i32>,
    /// The prompt exceeded the artifact window and was truncated to
    /// `T - 1` tokens before prefill (also logged at warn level).
    pub prompt_truncated: bool,
    pub stop: StopReason,
}

/// Clip a prompt to the `cap - 1` tokens the decode window can serve,
/// warning loudly; returns whether it clipped. One site for the policy
/// *and* its report, shared by the serve planner and the legacy
/// full-forward path (`eval::generate`) so the two can't drift apart —
/// `it_decode.rs` asserts their `prompt_truncated` flags agree.
pub(crate) fn clip_prompt(seq: &mut Vec<i32>, cap: usize) -> bool {
    if seq.len() < cap {
        return false;
    }
    log::warn!(
        "decode: prompt of {} tokens exceeds the {cap}-token artifact window — \
         truncated to {} (completion will be near-empty)",
        seq.len(),
        cap - 1
    );
    seq.truncate(cap - 1);
    true
}

/// First-of-ties argmax. Shared between the greedy sampler, the serve
/// degeneracies (`temperature <= 0`, `top_k == 1`) and the legacy
/// full-forward path: token-for-token parity depends on every path
/// tie-breaking identically.
pub(crate) fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in row.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    crate::util::cast::idx_i32(best)
}

// ---------------------------------------------------------------------------
// Paged K/V pool: block allocator + prompt-prefix cache (decode ABI v2,
// DESIGN.md §12)
// ---------------------------------------------------------------------------

/// Seed of every prompt's page-key hash chain (arbitrary fixed constant;
/// baked into no artifact, so it can change freely).
const CHAIN_SEED: u64 = 0x0005_ca1a_b1e0_dd1e;

/// FNV-1a over the block's token bytes, chained through `parent` so a
/// page's key commits to the *entire* prefix before it, not just its own
/// tokens: `key_i = h(key_{i-1}, tokens[i*bt .. (i+1)*bt])`.
fn chain_key(parent: u64, block: &[i32]) -> u64 {
    let mut h = parent ^ 0xcbf2_9ce4_8422_2325;
    for &t in block {
        for b in t.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0100_0000_01b3);
        }
    }
    h
}

/// One cached, fully prefilled prompt page. The entry holds exactly one
/// refcount on `page` for as long as it lives in the cache.
struct CachedPage {
    page: u32,
    /// Chain key of the preceding page ([`CHAIN_SEED`] for page 0 of a
    /// prompt), verified on lookup alongside `tokens` so a 64-bit hash
    /// collision can never alias two different prefixes.
    parent: u64,
    /// The `page_t` prompt tokens whose K/V this page holds.
    tokens: Vec<i32>,
    /// Allocator tick of the last registration or adoption: the LRU
    /// ordering key for eviction under pool pressure.
    last_used: u64,
}

/// Refcounted allocator over the fixed-size K/V page pool of a paged
/// (`decode_abi == 2`) artifact, plus the prompt-prefix cache that lets a
/// request adopt pages another request already prefilled (DESIGN.md §12).
///
/// Page ids index the per-layer-half pools of the device-resident state
/// tensor; the allocator itself is pure host bookkeeping. Page 0 is the
/// *scratch* page: never handed out, it absorbs the writes of vacant and
/// pageless rows (whatever lands there is garbage by contract — the
/// position mask keeps it out of every real row's attention).
///
/// Lifecycle: [`PageAllocator::alloc`] hands a page to a row at admission
/// (refcount 1); adopting a cached prefix page bumps its count instead of
/// recomputing it; harvest releases every page a row held. A page returns
/// to the free list when its count hits zero — cache entries each hold
/// one count, so cached prefixes survive their donor row and are evicted
/// (idle entries only) when the pool runs dry.
pub struct PageAllocator {
    page_t: usize,
    /// Per-page refcounts, indexed by page id; `refs[0]` pins scratch.
    refs: Vec<u32>,
    /// Free page ids; low ids are handed out first (determinism only).
    free: Vec<u32>,
    cache: BTreeMap<u64, CachedPage>,
    /// Monotonic use counter driving the LRU ordering of cache entries.
    tick: u64,
    /// Deterministic fault injection (shared with the runtime); `None`
    /// outside a serve/decode session.
    fault: Option<Rc<RefCell<FaultInjector>>>,
    /// Prompts that adopted at least one cached page.
    pub prefix_hits: u64,
    /// Prefilled pages served from the cache instead of recomputed.
    pub prefix_pages_served: u64,
    /// Cache entries evicted to satisfy allocations.
    pub evictions: u64,
}

impl PageAllocator {
    /// `n_pages` is the whole pool (`page_n` from the manifest),
    /// *including* the reserved scratch page 0.
    pub fn new(n_pages: usize, page_t: usize) -> PageAllocator {
        assert!(n_pages >= 2, "pool needs scratch + at least one real page");
        assert!(page_t > 0);
        let mut refs = vec![0u32; n_pages];
        refs[0] = 1; // scratch: pinned forever
        PageAllocator {
            page_t,
            refs,
            free: (1..crate::util::cast::idx_u32(n_pages)).rev().collect(),
            cache: BTreeMap::new(),
            tick: 0,
            fault: None,
            prefix_hits: 0,
            prefix_pages_served: 0,
            evictions: 0,
        }
    }

    pub fn page_t(&self) -> usize {
        self.page_t
    }

    /// Arm deterministic fault injection on this allocator (the serve
    /// session shares the runtime's injector so `pool:` plans fire here).
    pub fn set_fault_injector(&mut self, fault: Rc<RefCell<FaultInjector>>) {
        self.fault = Some(fault);
    }

    /// Allocate one page (refcount 1), evicting the least-recently-used
    /// idle cached prefix if the free list is dry. Errors only when every
    /// page is pinned by a live row — the default export geometry
    /// (`page_n = (B+1)*P + 1`) makes that unreachable for `B` rows of at
    /// most `P` pages each — or when an armed `pool:` fault plan fires.
    /// Both failures carry a typed [`FaultError`] with
    /// [`FaultKind::PoolExhausted`](crate::runtime::FaultKind) so the
    /// serve loop classifies earned and injected pressure identically.
    pub fn alloc(&mut self) -> Result<u32> {
        if let Some(f) = &self.fault {
            if let Some(e) = f.borrow_mut().on_alloc() {
                return Err(anyhow::Error::new(e));
            }
        }
        if self.free.is_empty() {
            self.evict_lru();
        }
        match self.free.pop() {
            Some(g) => {
                debug_assert_eq!(self.refs[g as usize], 0);
                self.refs[g as usize] = 1;
                Ok(g)
            }
            None => Err(anyhow::Error::new(FaultError::pool_exhausted()).context(format!(
                "paged K/V pool exhausted: all {} pages are held by live rows",
                self.refs.len()
            ))),
        }
    }

    /// Bump a page's refcount (prefix adoption).
    pub fn retain(&mut self, page: u32) {
        debug_assert_ne!(page, 0, "scratch is never adopted");
        debug_assert!(self.refs[page as usize] > 0, "retain of a free page");
        self.refs[page as usize] += 1;
    }

    /// Drop one refcount; the page rejoins the free list at zero.
    /// Releasing scratch is a no-op (vacant table entries all read 0).
    pub fn release(&mut self, page: u32) {
        if page == 0 {
            return;
        }
        let r = &mut self.refs[page as usize];
        debug_assert!(*r > 0, "release of a free page");
        *r -= 1;
        if *r == 0 {
            self.free.push(page);
        }
    }

    /// Evict the single least-recently-used idle cache entry (refcount 1
    /// — only the cache itself holds its page). Entries adopted by live
    /// rows are untouchable. Under pool pressure `alloc` calls this once
    /// per grant, so a hot prefix keeps its pages while cold ones are
    /// reclaimed one at a time; returns whether an entry was evicted.
    pub fn evict_lru(&mut self) -> bool {
        let lru: Option<u64> = self
            .cache
            .iter()
            .filter(|(_, e)| self.refs[e.page as usize] == 1)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| *k);
        match lru.and_then(|k| self.cache.remove(&k)) {
            Some(e) => {
                self.release(e.page);
                self.evictions += 1;
                true
            }
            None => false,
        }
    }

    /// Evict every idle cache entry (bulk flush — explicit callers only;
    /// pool-pressure eviction goes through the LRU path in `alloc`).
    pub fn evict_idle(&mut self) {
        while self.evict_lru() {}
    }

    /// Longest cached chain of fully prefilled pages matching `prompt`'s
    /// leading tokens, each page retained for the caller. Covers at most
    /// `(prompt.len() - 1) / page_t` pages: the last prompt token is
    /// always left to recompute so the adopting row still produces
    /// first-token logits (DESIGN.md §12 `shared_len` invariant).
    pub fn lookup_prefix(&mut self, prompt: &[i32]) -> Vec<u32> {
        let bt = self.page_t;
        let max_pages = prompt.len().saturating_sub(1) / bt;
        let mut key = CHAIN_SEED;
        let mut adopted = Vec::new();
        let mut keys = Vec::new();
        for i in 0..max_pages {
            let block = &prompt[i * bt..(i + 1) * bt];
            let next = chain_key(key, block);
            match self.cache.get(&next) {
                Some(e) if e.parent == key && e.tokens == block => {
                    adopted.push(e.page);
                    keys.push(next);
                }
                _ => break,
            }
            key = next;
        }
        self.tick += 1;
        for k in keys {
            // an adoption is a use: the whole matched chain moves to the
            // front of the LRU order
            if let Some(e) = self.cache.get_mut(&k) {
                e.last_used = self.tick;
            }
        }
        for &g in &adopted {
            self.retain(g);
        }
        if !adopted.is_empty() {
            self.prefix_hits += 1;
            self.prefix_pages_served += adopted.len() as u64;
        }
        adopted
    }

    /// Register a drained row's *fully prefilled* prompt pages. Only full
    /// pages are cacheable (a partial page would be rewritten by whoever
    /// adopts it); first registration of a chain key wins, so aliased
    /// re-registrations by adopters are no-ops. Each new entry takes one
    /// refcount on its page.
    pub fn register_prefix(&mut self, prompt: &[i32], pages: &[u32]) {
        let bt = self.page_t;
        let full = (prompt.len() / bt).min(pages.len());
        let mut key = CHAIN_SEED;
        self.tick += 1;
        let now = self.tick;
        for i in 0..full {
            let block = &prompt[i * bt..(i + 1) * bt];
            let next = chain_key(key, block);
            if let std::collections::btree_map::Entry::Vacant(v) = self.cache.entry(next) {
                let g = pages[i];
                debug_assert_ne!(g, 0, "prompt pages are real pages");
                self.refs[g as usize] += 1;
                v.insert(CachedPage {
                    page: g,
                    parent: key,
                    tokens: block.to_vec(),
                    last_used: now,
                });
            }
            key = next;
        }
    }

    // -- observability (metrics + leak assertions in `it_paged.rs`) -------

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn n_cached(&self) -> usize {
        self.cache.len()
    }

    /// Cache entries only the cache itself still holds — the pages LRU
    /// eviction could reclaim right now. `n_free() + n_idle_cached()` is
    /// the admission-time page budget.
    pub fn n_idle_cached(&self) -> usize {
        self.cache.values().filter(|e| self.refs[e.page as usize] == 1).count()
    }

    /// Refcounts held by rows: total non-scratch counts minus the one
    /// count each cache entry owns. Zero after a full queue drain — the
    /// no-leak invariant `it_paged.rs` asserts.
    pub fn outstanding(&self) -> usize {
        let total: u32 = self.refs.iter().skip(1).sum();
        total as usize - self.cache.len()
    }
}

/// A batched KV-cached greedy decoder over one engine + parameter store:
/// the static-batch wrapper over [`ServeSession`].
///
/// Fills every row of the `[B, T]` artifacts with a different prompt
/// (chunking when there are more prompts than rows) and pays one
/// `decode_step` execution per generated token instead of a full L-block
/// re-forward.
pub struct DecodeSession<'e, 'rt> {
    serve: ServeSession<'e, 'rt>,
}

impl<'e, 'rt> DecodeSession<'e, 'rt> {
    /// Whether the loaded artifacts carry the decode ABI for this
    /// engine's backend (legacy dirs: no — callers fall back).
    pub fn supported(eng: &Engine) -> bool {
        ServeSession::supported(eng)
    }

    pub fn new(eng: &'e mut Engine<'rt>, params: &'e ModelParams) -> Result<Self> {
        Ok(DecodeSession { serve: ServeSession::new(eng, params)? })
    }

    /// Force a specific K/V layout. Parity suites pin [`KvMode::Packed`]
    /// so their per-segment `ExecStats` assertions don't depend on which
    /// decode ABI the artifact dir happens to carry.
    pub fn with_mode(
        eng: &'e mut Engine<'rt>,
        params: &'e ModelParams,
        mode: KvMode,
    ) -> Result<Self> {
        Ok(DecodeSession { serve: ServeSession::with_mode(eng, params, mode)? })
    }

    /// `decode_step` executions across every chunk of this session.
    pub fn decode_steps(&self) -> u64 {
        self.serve.decode_steps
    }

    /// Greedily complete every prompt (token-id sequences including any
    /// leading specials). Returns one [`Completion`] per prompt, in order.
    /// `eos` stops a row (not emitted); `pad` fills unused batch slots
    /// and prompt tails during prefill.
    pub fn greedy(
        &mut self,
        prompts: &[Vec<i32>],
        max_new: usize,
        eos: i32,
        pad: i32,
    ) -> Result<Vec<Completion>> {
        let reqs: Vec<Request> = prompts
            .iter()
            .map(|p| Request::greedy(p.clone(), max_new))
            .collect();
        self.serve.run_static(&reqs, eos, pad)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_of_ties() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn clip_prompt_reports_and_truncates_to_cap_minus_one() {
        let mut seq: Vec<i32> = (0..10).collect();
        assert!(clip_prompt(&mut seq, 8));
        assert_eq!(seq.len(), 7);
        let mut short = vec![1, 2, 3];
        assert!(!clip_prompt(&mut short, 8));
        assert_eq!(short.len(), 3);
    }

    // ---- PageAllocator + prefix cache (pure host bookkeeping) -----------

    #[test]
    fn allocator_hands_out_real_pages_and_recycles_on_release() {
        let mut a = PageAllocator::new(5, 4); // scratch + 4 real pages
        assert_eq!(a.n_free(), 4);
        let g1 = a.alloc().unwrap();
        let g2 = a.alloc().unwrap();
        assert!(g1 != 0 && g2 != 0 && g1 != g2, "scratch never allocated");
        assert_eq!(a.n_free(), 2);
        assert_eq!(a.outstanding(), 2);
        a.release(g1);
        assert_eq!(a.n_free(), 3);
        assert_eq!(a.outstanding(), 1);
        // releasing scratch (a vacant table entry) is a no-op
        a.release(0);
        assert_eq!(a.n_free(), 3);
        a.release(g2);
        assert_eq!(a.outstanding(), 0);
        assert_eq!(a.n_free(), 4);
    }

    #[test]
    fn allocator_errors_when_every_page_is_row_held() {
        let mut a = PageAllocator::new(3, 4);
        let _g1 = a.alloc().unwrap();
        let _g2 = a.alloc().unwrap();
        assert!(a.alloc().is_err(), "no idle cache to evict: must error");
    }

    #[test]
    fn retain_defers_release_until_the_last_holder() {
        let mut a = PageAllocator::new(3, 4);
        let g = a.alloc().unwrap();
        a.retain(g);
        a.release(g);
        assert_eq!(a.n_free(), 1, "still held once");
        a.release(g);
        assert_eq!(a.n_free(), 2);
    }

    #[test]
    fn prefix_cache_round_trips_full_pages_only() {
        let mut a = PageAllocator::new(9, 2);
        // donor prompt: 5 tokens over page_t = 2 -> pages [p0 p1 | tail]
        let prompt = vec![10, 11, 12, 13, 14];
        let pages = vec![a.alloc().unwrap(), a.alloc().unwrap(), a.alloc().unwrap()];
        a.register_prefix(&prompt, &pages);
        assert_eq!(a.n_cached(), 2, "only the 2 full pages are cacheable");
        // donor harvest: cache keeps the registered pages alive
        for &g in &pages {
            a.release(g);
        }
        assert_eq!(a.outstanding(), 0);
        assert_eq!(a.n_free(), 8 - 2);

        // identical prompt adopts both full pages, each retained
        let adopted = a.lookup_prefix(&prompt);
        assert_eq!(adopted, pages[..2]);
        assert_eq!(a.prefix_hits, 1);
        assert_eq!(a.prefix_pages_served, 2);
        assert_eq!(a.outstanding(), 2);
        for &g in &adopted {
            a.release(g);
        }

        // sharing only the first block adopts exactly one page
        let partial = a.lookup_prefix(&[10, 11, 99, 13]);
        assert_eq!(partial, pages[..1]);
        a.release(partial[0]);

        // a different first block adopts nothing
        assert!(a.lookup_prefix(&[99, 11, 12, 13]).is_empty());
        assert_eq!(a.prefix_hits, 2);
    }

    #[test]
    fn lookup_always_leaves_the_last_prompt_token_to_recompute() {
        let mut a = PageAllocator::new(9, 2);
        let prompt = vec![1, 2, 3, 4]; // exactly 2 full pages
        let pages = vec![a.alloc().unwrap(), a.alloc().unwrap()];
        a.register_prefix(&prompt, &pages);
        // a 100% identical prompt may adopt only page 0: position 3 (the
        // last token) must be recomputed for first-token logits
        let adopted = a.lookup_prefix(&prompt);
        assert_eq!(adopted, pages[..1]);
        a.release(adopted[0]);
        // a longer prompt sharing both blocks adopts both
        let adopted = a.lookup_prefix(&[1, 2, 3, 4, 5]);
        assert_eq!(adopted, pages[..2]);
    }

    #[test]
    fn first_registration_wins_and_aliased_reregistration_is_a_noop() {
        let mut a = PageAllocator::new(9, 2);
        let prompt = vec![7, 8];
        let g1 = a.alloc().unwrap();
        a.register_prefix(&prompt, &[g1]);
        let before = a.n_free();
        // an adopter re-registering the same chain must not double-count
        a.register_prefix(&prompt, &[g1]);
        assert_eq!(a.n_cached(), 1);
        a.release(g1);
        assert_eq!(a.outstanding(), 0);
        // exactly one cache refcount holds g1
        a.evict_idle();
        assert_eq!(a.n_free(), before + 1);
        assert_eq!(a.n_cached(), 0);
        assert_eq!(a.evictions, 1);
    }

    #[test]
    fn exhaustion_evicts_idle_cache_entries_but_not_adopted_ones() {
        let mut a = PageAllocator::new(4, 2); // 3 real pages
        let d1 = a.alloc().unwrap();
        let d2 = a.alloc().unwrap();
        a.register_prefix(&[1, 2], &[d1]); // idle once the donor releases
        a.register_prefix(&[5, 6], &[d2]);
        a.release(d1);
        a.release(d2);
        // adopt [5, 6]: its page is now row-held, [1, 2]'s is idle
        let adopted = a.lookup_prefix(&[5, 6, 9]);
        assert_eq!(adopted, vec![d2]);
        let g3 = a.alloc().unwrap();
        // pool dry: the next alloc must evict the idle entry, not d2's
        let g4 = a.alloc().unwrap();
        assert_eq!(g4, d1, "idle cached page recycled");
        assert_eq!(a.evictions, 1);
        assert!(a.lookup_prefix(&[1, 2, 9]).is_empty(), "evicted");
        assert_eq!(a.lookup_prefix(&[5, 6, 9]), vec![d2], "survivor intact");
        let _ = (g3, g4);
    }

    #[test]
    fn eviction_under_pressure_is_lru_one_entry_at_a_time() {
        let mut a = PageAllocator::new(4, 2); // 3 real pages
        let d1 = a.alloc().unwrap();
        let d2 = a.alloc().unwrap();
        let d3 = a.alloc().unwrap();
        a.register_prefix(&[1, 2], &[d1]); // oldest registration
        a.register_prefix(&[5, 6], &[d2]);
        a.register_prefix(&[8, 9], &[d3]);
        for g in [d1, d2, d3] {
            a.release(g); // all three idle, LRU order d1 < d2 < d3
        }
        // touching [1, 2] moves the oldest entry to the front...
        let adopted = a.lookup_prefix(&[1, 2, 7]);
        assert_eq!(adopted, vec![d1]);
        a.release(d1);
        // ...so pressure reclaims d2 first, then d3, and d1 last
        assert_eq!(a.alloc().unwrap(), d2, "least-recently-used evicted first");
        assert_eq!(a.n_cached(), 2, "one entry per grant, not a bulk flush");
        assert_eq!(a.alloc().unwrap(), d3);
        assert_eq!(a.alloc().unwrap(), d1);
        assert_eq!(a.evictions, 3);
        assert_eq!(a.n_cached(), 0);
    }

    #[test]
    fn adopted_entries_survive_lru_eviction() {
        let mut a = PageAllocator::new(3, 2); // 2 real pages
        let d1 = a.alloc().unwrap();
        let d2 = a.alloc().unwrap();
        a.register_prefix(&[1, 2], &[d1]);
        a.register_prefix(&[5, 6], &[d2]);
        a.release(d1);
        a.release(d2);
        // [1, 2] is LRU *and* row-held: eviction must skip it
        let adopted = a.lookup_prefix(&[1, 2, 7]);
        assert_eq!(adopted, vec![d1]);
        assert_eq!(a.alloc().unwrap(), d2, "idle entry evicted, adopted one kept");
        assert_eq!(a.lookup_prefix(&[1, 2, 9]), vec![d1], "survivor intact");
    }

    #[test]
    fn real_exhaustion_and_injected_pool_faults_are_both_typed() {
        use crate::runtime::fault::{FaultError, FaultInjector, FaultKind};

        let mut a = PageAllocator::new(3, 4);
        let _g1 = a.alloc().unwrap();
        let _g2 = a.alloc().unwrap();
        let err = a.alloc().unwrap_err();
        let f = err.downcast_ref::<FaultError>().expect("earned exhaustion is typed");
        assert_eq!(f.kind, FaultKind::PoolExhausted);
        assert!(format!("{err:#}").contains("pool exhausted"), "{err:#}");

        let mut a = PageAllocator::new(8, 4);
        let inj = Rc::new(RefCell::new(FaultInjector::parse("pool:nth=2").unwrap()));
        a.set_fault_injector(inj.clone());
        assert!(a.alloc().is_ok());
        let err = a.alloc().unwrap_err();
        let f = err.downcast_ref::<FaultError>().expect("injected fault is typed");
        assert_eq!((f.kind, f.hit), (FaultKind::PoolExhausted, 2));
        assert_eq!(inj.borrow().injected, 1);
        assert!(a.alloc().is_ok(), "plan spent: the pool recovers");
    }

    #[test]
    fn chain_keys_commit_to_the_whole_prefix() {
        // same second block after different first blocks must not collide
        let k1 = chain_key(chain_key(CHAIN_SEED, &[1, 2]), &[3, 4]);
        let k2 = chain_key(chain_key(CHAIN_SEED, &[9, 9]), &[3, 4]);
        assert_ne!(k1, k2);
        assert_ne!(chain_key(CHAIN_SEED, &[1]), chain_key(CHAIN_SEED, &[2]));
    }
}
