//! Batched KV-cached greedy decode — the serving-scale primitive
//! (DESIGN.md §9).
//!
//! [`DecodeSession`] schedules the decode-ABI segments over the runtime:
//!
//! ```text
//! prefill:  embed_fwd -> (prefill_kv + block_fwd)^L -> head_logits
//!           pack_state(kv_0..kv_{L-1}) -> state
//! per token: decode_step(tok, pidx, state, weights...) -> state'
//!            decode_logits(state') -> [B, 1, V]   (the only download)
//! ```
//!
//! The whole-model cache lives in ONE packed device tensor
//! `[B, L*2T+1, D]` (per-layer K rows, V rows, final h row) so it chains
//! between `decode_step` executions through the bare-root single-output
//! path (`Runtime::run_chained`) without ever touching the host — the
//! PJRT wrapper can only hand tuple-rooted outputs back as one fused
//! host literal, which is exactly why the state is packed rather than a
//! tuple of per-layer tensors. Weights come from the engine's
//! [`crate::runtime::DeviceCache`]: on a warm cache a decode step uploads
//! only the two `[B, 1]` i32 token/position columns, zero weight tensors.
//!
//! Staleness is structural: a session borrows the engine and the
//! parameter store for its whole lifetime, so no optimizer step or
//! checkpoint restore can interleave with a live K/V cache — after any
//! mutation a fresh session re-prefills, and the weight buffers it pulls
//! go through the store-generation-stamped cache (DESIGN.md §8).

use anyhow::{ensure, Result};

use crate::model::ModelParams;
use crate::runtime::{HostTensorI32, Operand, DECODE_ABI};

use super::memory::MemCategory;
use super::trainer::{Act, Engine};

/// Why a row stopped emitting tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The model produced the end-of-sequence token (not emitted).
    Eos,
    /// The per-call `max_new` budget was reached.
    MaxNew,
    /// The `[B, T]` artifact window is full — no room for another token.
    WindowFull,
}

/// One prompt's decode result.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Generated token ids (response only, no specials).
    pub tokens: Vec<i32>,
    /// The prompt exceeded the artifact window and was truncated to
    /// `T - 1` tokens before prefill (also logged at warn level).
    pub prompt_truncated: bool,
    pub stop: StopReason,
}

/// Pure per-row decode bookkeeping (unit-tested without a runtime):
/// mirrors the legacy greedy loop's stop conditions exactly so the
/// cached path stays token-for-token compatible.
#[derive(Debug)]
struct RowPlan {
    /// Prompt plus everything generated so far.
    seq: Vec<i32>,
    truncated: bool,
    out: Vec<i32>,
    stop: Option<StopReason>,
    max_new: usize,
    seq_cap: usize,
    eos: i32,
}

/// Clip a prompt to the `cap - 1` tokens the decode window can serve,
/// warning loudly; returns whether it clipped. One site for the policy
/// *and* its report, shared by the cached planner and the legacy
/// full-forward path (`eval::generate`) so the two can't drift apart —
/// `it_decode.rs` asserts their `prompt_truncated` flags agree.
pub(crate) fn clip_prompt(seq: &mut Vec<i32>, cap: usize) -> bool {
    if seq.len() < cap {
        return false;
    }
    log::warn!(
        "decode: prompt of {} tokens exceeds the {cap}-token artifact window — \
         truncated to {} (completion will be near-empty)",
        seq.len(),
        cap - 1
    );
    seq.truncate(cap - 1);
    true
}

/// First-of-ties argmax. Shared with the legacy full-forward path:
/// token-for-token parity depends on both paths tie-breaking identically.
pub(crate) fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in row.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

impl RowPlan {
    fn new(mut prompt: Vec<i32>, seq_cap: usize, max_new: usize, eos: i32) -> RowPlan {
        assert!(!prompt.is_empty(), "decode rows need at least one token");
        let truncated = clip_prompt(&mut prompt, seq_cap);
        let stop = (max_new == 0).then_some(StopReason::MaxNew);
        RowPlan { seq: prompt, truncated, out: Vec::new(), stop, max_new, seq_cap, eos }
    }

    fn alive(&self) -> bool {
        self.stop.is_none()
    }

    /// Feed the argmax token the model produced for this row.
    fn push(&mut self, id: i32) {
        debug_assert!(self.alive());
        if id == self.eos {
            self.stop = Some(StopReason::Eos);
            return;
        }
        self.seq.push(id);
        self.out.push(id);
        if self.out.len() >= self.max_new {
            self.stop = Some(StopReason::MaxNew);
        } else if self.seq.len() >= self.seq_cap {
            // the legacy loop breaks at the top of the next iteration
            self.stop = Some(StopReason::WindowFull);
        }
    }

    /// `(token, position)` this row contributes to the next `decode_step`.
    /// Done rows in a still-running batch freeze on their last token —
    /// rewriting the same cache slot with the same bytes (idempotent, and
    /// rows are independent, so live rows are unaffected).
    fn step_input(&self) -> (i32, i32) {
        (*self.seq.last().expect("non-empty"), (self.seq.len() - 1) as i32)
    }

    fn into_completion(self) -> Completion {
        Completion {
            tokens: self.out,
            prompt_truncated: self.truncated,
            stop: self.stop.unwrap_or(StopReason::MaxNew),
        }
    }
}

/// A batched KV-cached greedy decoder over one engine + parameter store.
///
/// Fills every row of the `[B, T]` artifacts with a different prompt
/// (chunking when there are more prompts than rows) and pays one
/// `decode_step` execution per generated token instead of a full L-block
/// re-forward.
pub struct DecodeSession<'e, 'rt> {
    eng: &'e mut Engine<'rt>,
    params: &'e ModelParams,
    /// `decode_step` executions across every chunk of this session.
    pub decode_steps: u64,
}

impl<'e, 'rt> DecodeSession<'e, 'rt> {
    /// Whether the loaded artifacts carry the decode ABI for this
    /// engine's backend (legacy dirs: no — callers fall back).
    pub fn supported(eng: &Engine) -> bool {
        eng.rt.manifest.supports_decode(&eng.rt.backend)
    }

    pub fn new(eng: &'e mut Engine<'rt>, params: &'e ModelParams) -> Result<Self> {
        ensure!(
            Self::supported(eng),
            "artifact dir '{}' carries no decode-ABI v{DECODE_ABI} segments for \
             backend '{}' — re-export with python/compile/aot.py or use the \
             legacy full-forward path",
            eng.rt.manifest.dir.display(),
            eng.rt.backend
        );
        Ok(DecodeSession { eng, params, decode_steps: 0 })
    }

    /// Greedily complete every prompt (token-id sequences including any
    /// leading specials). Returns one [`Completion`] per prompt, in order.
    /// `eos` stops a row (not emitted); `pad` fills unused batch slots
    /// and prompt tails during prefill.
    pub fn greedy(
        &mut self,
        prompts: &[Vec<i32>],
        max_new: usize,
        eos: i32,
        pad: i32,
    ) -> Result<Vec<Completion>> {
        let bsz = self.eng.rt.manifest.batch;
        let mut out = Vec::with_capacity(prompts.len());
        for chunk in prompts.chunks(bsz) {
            out.extend(self.greedy_chunk(chunk, max_new, eos, pad)?);
        }
        Ok(out)
    }

    fn greedy_chunk(
        &mut self,
        prompts: &[Vec<i32>],
        max_new: usize,
        eos: i32,
        pad: i32,
    ) -> Result<Vec<Completion>> {
        let m = self.eng.rt.manifest.clone();
        let (bsz, t_max, d, v) = (m.batch, m.seq, m.d_model, m.vocab);
        debug_assert!(!prompts.is_empty() && prompts.len() <= bsz);
        // oversized prompts are clipped (and warned about) by RowPlan::new
        let mut rows: Vec<RowPlan> = prompts
            .iter()
            .map(|p| RowPlan::new(p.clone(), t_max, max_new, eos))
            .collect();
        // unused batch slots decode nothing (max_new = 0)
        while rows.len() < bsz {
            rows.push(RowPlan::new(vec![pad], t_max, 0, eos));
        }

        // ---- prefill: embed -> (prefill_kv + block_fwd)^L -> head_logits
        let mut tokens = vec![pad; bsz * t_max];
        for (r, plan) in rows.iter().enumerate() {
            tokens[r * t_max..r * t_max + plan.seq.len()].copy_from_slice(&plan.seq);
        }
        let tokens = HostTensorI32::from_vec(&[bsz, t_max], tokens);

        let ids = self.eng.ids;
        let device_flow = self.eng.device_flow;
        let hs = self.eng.h_shape();
        let kv_shape = vec![bsz, 2 * t_max, d];
        let state_shape = vec![bsz, m.decode_state_rows(), d];

        let mut h = if device_flow {
            let (emb, pos) = self.eng.embed_bufs(self.params)?;
            let ops = [Operand::I32(&tokens), Operand::Buf(&emb), Operand::Buf(&pos)];
            self.eng.run_chain_act(ids.embed_fwd, &ops, &hs)?
        } else {
            let ops = [
                Operand::I32(&tokens),
                Operand::F32(&self.params.emb),
                Operand::F32(&self.params.pos),
            ];
            self.eng.run_chain_act(ids.embed_fwd, &ops, &hs)?
        };
        let mut kvs: Vec<Act> = Vec::with_capacity(m.n_layers);
        // meter the real serving peak: the growing per-layer K/V buffers
        // plus the one live residual are resident together during prefill
        let mut kv_bytes = 0u64;
        self.eng.meter.set(MemCategory::Activations, h.bytes() as u64);
        for l in 0..m.n_layers {
            let h_next = if device_flow {
                let bufs = self.eng.block_bufs(self.params, l)?;
                // prefill_kv ABI: (h, g1, wk, wv) — block ABI indices 0/2/3
                let kv_ops = [
                    h.operand(),
                    Operand::Buf(&bufs[0]),
                    Operand::Buf(&bufs[2]),
                    Operand::Buf(&bufs[3]),
                ];
                kvs.push(self.eng.run_chain_act(ids.prefill_kv, &kv_ops, &kv_shape)?);
                let mut ops = vec![h.operand()];
                ops.extend(bufs.iter().map(|b| Operand::Buf(b.as_ref())));
                self.eng.run_chain_act(ids.block_fwd, &ops, &hs)?
            } else {
                let layer = &self.params.blocks[l];
                let kv_ops = [
                    h.operand(),
                    Operand::F32(&layer[0]),
                    Operand::F32(&layer[2]),
                    Operand::F32(&layer[3]),
                ];
                kvs.push(self.eng.run_chain_act(ids.prefill_kv, &kv_ops, &kv_shape)?);
                let mut ops = vec![h.operand()];
                ops.extend(layer.iter().map(Operand::F32));
                self.eng.run_chain_act(ids.block_fwd, &ops, &hs)?
            };
            h = h_next;
            kv_bytes += kvs.last().expect("pushed").bytes() as u64;
            self.eng.meter.set(MemCategory::Activations, kv_bytes + h.bytes() as u64);
        }
        let logit_shape = [bsz, t_max, v];
        let logits = if device_flow {
            let (gf, wh) = self.eng.head_bufs(self.params)?;
            let ops = [h.operand(), Operand::Buf(&gf), Operand::Buf(&wh)];
            self.eng.run_chain_act(ids.head_logits, &ops, &logit_shape)?.into_host()?
        } else {
            let ops = [
                h.operand(),
                Operand::F32(&self.params.gf),
                Operand::F32(&self.params.wh),
            ];
            self.eng.run_chain_act(ids.head_logits, &ops, &logit_shape)?.into_host()?
        };
        let mut state = {
            let kv_ops: Vec<Operand> = kvs.iter().map(Act::operand).collect();
            self.eng.run_chain_act(ids.pack_state, &kv_ops, &state_shape)?
        };
        // packing peak: the per-layer buffers and the packed state coexist
        self.eng.meter.set(MemCategory::Activations, kv_bytes + state.bytes() as u64);
        drop(kvs);
        self.eng.meter.set(MemCategory::Activations, state.bytes() as u64);

        // first token per row, from the prefill logits at position len-1
        for (r, plan) in rows.iter_mut().enumerate() {
            if !plan.alive() {
                continue;
            }
            let p = plan.seq.len() - 1;
            plan.push(argmax(&logits.data[(r * t_max + p) * v..(r * t_max + p + 1) * v]));
        }

        // ---- decode loop: one decode_step execution per generated token
        let (embp, blockb, headp) = if device_flow {
            let mut blocks = Vec::with_capacity(m.n_layers);
            for l in 0..m.n_layers {
                blocks.push(self.eng.block_bufs(self.params, l)?);
            }
            (
                Some(self.eng.embed_bufs(self.params)?),
                blocks,
                Some(self.eng.head_bufs(self.params)?),
            )
        } else {
            (None, Vec::new(), None)
        };
        let logit1_shape = [bsz, 1, v];
        while rows.iter().any(RowPlan::alive) {
            let (mut tok, mut pidx) = (Vec::with_capacity(bsz), Vec::with_capacity(bsz));
            for plan in &rows {
                let (t, p) = plan.step_input();
                tok.push(t);
                pidx.push(p);
            }
            let tok = HostTensorI32::from_vec(&[bsz, 1], tok);
            let pidx = HostTensorI32::from_vec(&[bsz, 1], pidx);
            let state_next = {
                let mut ops: Vec<Operand> =
                    vec![Operand::I32(&tok), Operand::I32(&pidx), state.operand()];
                if let Some((emb, pos)) = &embp {
                    ops.push(Operand::Buf(emb));
                    ops.push(Operand::Buf(pos));
                    for bufs in &blockb {
                        ops.extend(bufs.iter().map(|b| Operand::Buf(b.as_ref())));
                    }
                } else {
                    ops.push(Operand::F32(&self.params.emb));
                    ops.push(Operand::F32(&self.params.pos));
                    for layer in &self.params.blocks {
                        ops.extend(layer.iter().map(Operand::F32));
                    }
                }
                self.eng.run_chain_act(ids.decode_step, &ops, &state_shape)?
            };
            state = state_next;
            self.decode_steps += 1;
            let lg = {
                let ops = if let Some((gf, wh)) = &headp {
                    [state.operand(), Operand::Buf(gf), Operand::Buf(wh)]
                } else {
                    [
                        state.operand(),
                        Operand::F32(&self.params.gf),
                        Operand::F32(&self.params.wh),
                    ]
                };
                self.eng.run_chain_act(ids.decode_logits, &ops, &logit1_shape)?.into_host()?
            };
            for (r, plan) in rows.iter_mut().enumerate() {
                if !plan.alive() {
                    continue;
                }
                plan.push(argmax(&lg.data[r * v..(r + 1) * v]));
            }
        }
        self.eng.meter.set(MemCategory::Activations, 0);
        Ok(rows
            .into_iter()
            .take(prompts.len())
            .map(RowPlan::into_completion)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_plan_mirrors_legacy_stop_conditions() {
        // eos on the first token: nothing emitted
        let mut r = RowPlan::new(vec![1, 5, 3], 16, 4, 2);
        assert!(r.alive());
        r.push(2);
        assert!(!r.alive());
        let c = r.into_completion();
        assert!(c.tokens.is_empty());
        assert_eq!(c.stop, StopReason::Eos);

        // max_new budget
        let mut r = RowPlan::new(vec![1, 5, 3], 16, 2, 2);
        r.push(7);
        assert!(r.alive());
        assert_eq!(r.step_input(), (7, 3));
        r.push(8);
        assert!(!r.alive());
        let c = r.into_completion();
        assert_eq!(c.tokens, vec![7, 8]);
        assert_eq!(c.stop, StopReason::MaxNew);
        assert!(!c.prompt_truncated);
    }

    #[test]
    fn row_plan_stops_when_the_window_fills() {
        // cap 5, prompt 3 long: room for exactly 2 generated tokens
        let mut r = RowPlan::new(vec![1, 5, 3], 5, 10, 2);
        r.push(7);
        assert!(r.alive());
        r.push(8);
        assert!(!r.alive());
        let c = r.into_completion();
        assert_eq!(c.tokens, vec![7, 8]);
        assert_eq!(c.stop, StopReason::WindowFull);
    }

    #[test]
    fn row_plan_truncates_oversized_prompts_like_legacy() {
        let prompt: Vec<i32> = (0..20).collect();
        let r = RowPlan::new(prompt, 8, 4, 2);
        assert!(r.truncated);
        assert_eq!(r.seq.len(), 7); // T - 1, legacy semantics
        assert_eq!(r.step_input(), (6, 6));
    }

    #[test]
    fn row_plan_max_new_zero_never_decodes() {
        let r = RowPlan::new(vec![1], 8, 0, 2);
        assert!(!r.alive());
        assert_eq!(r.into_completion().stop, StopReason::MaxNew);
    }

    #[test]
    fn frozen_rows_repeat_their_last_slot() {
        let mut r = RowPlan::new(vec![1, 4], 16, 1, 2);
        r.push(9);
        assert!(!r.alive());
        // frozen input: same token, same position, every step
        assert_eq!(r.step_input(), (9, 2));
        assert_eq!(r.step_input(), (9, 2));
    }

    #[test]
    fn argmax_picks_first_of_ties() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
