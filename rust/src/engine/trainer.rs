//! The layer-granular training engine — the L3 heart of this reproduction.
//!
//! The model is compiled as per-segment executables (embed / block / head).
//! This engine schedules them:
//!
//! ```text
//! forward:   embed_fwd -> block_fwd^L (stash inputs) -> head_fwd_bwd
//! backward:  for l = L-1..0:  block_bwd_full  (trainable: dh + dθ)
//!                             block_bwd_x     (frozen:    dh only)
//!            embed_bwd if the embedding is trainable
//! ```
//!
//! That per-block `bwd_full` vs `bwd_x` choice is what makes LISA's savings
//! *real* here: frozen blocks never compute weight gradients (FLOPs) and
//! never hold them (bytes). The backward walk also stops early once no
//! trainable tensor remains below the current block.
//!
//! Backward segments rematerialize the forward internally (per-block
//! gradient checkpointing), so the activation stash is exactly one
//! `[B, T, D]` residual per block.

use anyhow::Result;
use xla::Literal;

use crate::model::ModelParams;
use crate::runtime::{HostTensor, HostTensorI32, Operand, Runtime};

use super::memory::{MemCategory, MemoryMeter};

/// Which components are trainable this step (LISA resamples this every K
/// steps; FT sets everything true; LoRA uses its own path).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TrainMask {
    pub embed: bool,
    pub head: bool,
    pub blocks: Vec<bool>,
}

impl TrainMask {
    pub fn all(n_layers: usize) -> Self {
        TrainMask { embed: true, head: true, blocks: vec![true; n_layers] }
    }

    pub fn none(n_layers: usize) -> Self {
        TrainMask { embed: false, head: false, blocks: vec![false; n_layers] }
    }

    pub fn n_trainable_blocks(&self) -> usize {
        self.blocks.iter().filter(|&&b| b).count()
    }

    /// Index of the lowest trainable block, if any.
    pub fn lowest_trainable_block(&self) -> Option<usize> {
        self.blocks.iter().position(|&b| b)
    }
}

/// One training batch: token ids and (shifted, prompt-masked) targets.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: HostTensorI32,
    pub targets: HostTensorI32,
}

/// Gradients for the trainable subset; `None` = frozen, never computed.
#[derive(Debug, Clone, Default)]
pub struct Grads {
    pub emb: Option<HostTensor>,
    pub pos: Option<HostTensor>,
    pub blocks: Vec<Option<Vec<HostTensor>>>,
    pub gf: Option<HostTensor>,
    pub wh: Option<HostTensor>,
}

impl Grads {
    pub fn bytes(&self) -> u64 {
        let mut b = 0u64;
        for t in [&self.emb, &self.pos, &self.gf, &self.wh].into_iter().flatten() {
            b += t.bytes() as u64;
        }
        for blk in self.blocks.iter().flatten() {
            for t in blk {
                b += t.bytes() as u64;
            }
        }
        b
    }

    /// Accumulate `other` into `self` (microbatch accumulation). Both must
    /// cover the same trainable subset.
    pub fn add_assign(&mut self, other: &Grads) {
        fn acc(a: &mut Option<HostTensor>, b: &Option<HostTensor>) {
            match (a, b) {
                (Some(x), Some(y)) => x.add_assign(y),
                (None, None) => {}
                _ => panic!("grad accumulation over mismatched masks"),
            }
        }
        acc(&mut self.emb, &other.emb);
        acc(&mut self.pos, &other.pos);
        acc(&mut self.gf, &other.gf);
        acc(&mut self.wh, &other.wh);
        assert_eq!(self.blocks.len(), other.blocks.len());
        for (a, b) in self.blocks.iter_mut().zip(&other.blocks) {
            match (a, b) {
                (Some(xs), Some(ys)) => {
                    for (x, y) in xs.iter_mut().zip(ys) {
                        x.add_assign(y);
                    }
                }
                (None, None) => {}
                _ => panic!("grad accumulation over mismatched masks"),
            }
        }
    }

    pub fn scale(&mut self, s: f32) {
        for t in [&mut self.emb, &mut self.pos, &mut self.gf, &mut self.wh]
            .into_iter()
            .flatten()
        {
            t.scale(s);
        }
        for blk in self.blocks.iter_mut().flatten() {
            for t in blk {
                t.scale(s);
            }
        }
    }

    /// Per-block gradient L2 norms; `None` = frozen this step (no gradient
    /// was ever computed). Feeds the gradient-adaptive sampler
    /// (`strategy::lisa_grad`).
    pub fn block_norms(&self) -> Vec<Option<f64>> {
        self.blocks
            .iter()
            .map(|blk| {
                blk.as_ref().map(|ts| {
                    ts.iter().map(|t| t.l2_norm().powi(2)).sum::<f64>().sqrt()
                })
            })
            .collect()
    }

    /// Global gradient L2 norm over the trainable subset.
    pub fn global_norm(&self) -> f64 {
        let mut sq = 0.0;
        for t in [&self.emb, &self.pos, &self.gf, &self.wh].into_iter().flatten() {
            sq += t.l2_norm().powi(2);
        }
        for blk in self.blocks.iter().flatten() {
            for t in blk {
                sq += t.l2_norm().powi(2);
            }
        }
        sq.sqrt()
    }
}

/// Output of one forward/backward microbatch.
pub struct StepOutput {
    pub loss: f32,
    pub grads: Grads,
}

/// The engine: schedules segment executables over the runtime.
pub struct Engine<'rt> {
    pub rt: &'rt Runtime,
    pub meter: MemoryMeter,
    /// Statistics: per-step counts of full vs input-only block backwards
    /// (the Fig 4 iteration-time mechanism).
    pub bwd_full_calls: u64,
    pub bwd_x_calls: u64,
    pub bwd_skipped: u64,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime) -> Self {
        Engine {
            rt,
            meter: MemoryMeter::new(),
            bwd_full_calls: 0,
            bwd_x_calls: 0,
            bwd_skipped: 0,
        }
    }

    fn h_shape(&self) -> Vec<usize> {
        let m = &self.rt.manifest;
        vec![m.batch, m.seq, m.d_model]
    }

    fn block_ops<'a>(
        h: &'a HostTensor,
        params: &'a [HostTensor],
    ) -> Vec<Operand<'a>> {
        let mut ops: Vec<Operand<'a>> = Vec::with_capacity(1 + params.len());
        ops.push(Operand::F32(h));
        ops.extend(params.iter().map(Operand::F32));
        ops
    }

    /// Forward through embed + all blocks, returning every block input plus
    /// the final hidden state (stash[l] is the input of block l).
    fn forward_stash(
        &mut self,
        params: &ModelParams,
        tokens: &HostTensorI32,
    ) -> Result<Vec<HostTensor>> {
        let hs = self.h_shape();
        let out = self.rt.run(
            "embed_fwd",
            &[Operand::I32(tokens), Operand::F32(&params.emb), Operand::F32(&params.pos)],
        )?;
        let mut h = HostTensor::from_literal(&out[0], &hs)?;
        let mut stash = Vec::with_capacity(params.blocks.len() + 1);
        let mut act_bytes = 0u64;
        for layer in &params.blocks {
            act_bytes += h.bytes() as u64;
            self.meter.set(MemCategory::Activations, act_bytes);
            let out = self.rt.run("block_fwd", &Self::block_ops(&h, layer))?;
            let h_next = HostTensor::from_literal(&out[0], &hs)?;
            stash.push(h);
            h = h_next;
        }
        self.meter.set(MemCategory::Activations, act_bytes + h.bytes() as u64);
        stash.push(h);
        Ok(stash)
    }

    /// Full-parameter / LISA forward+backward over the trainable mask.
    pub fn forward_backward(
        &mut self,
        params: &ModelParams,
        batch: &Batch,
        mask: &TrainMask,
    ) -> Result<StepOutput> {
        let m = &self.rt.manifest;
        assert_eq!(mask.blocks.len(), m.n_layers, "mask arity");
        let hs = self.h_shape();
        self.meter.set(MemCategory::Params, params.bytes() as u64);

        let mut stash = self.forward_stash(params, &batch.tokens)?;
        let h_last = stash.pop().expect("stash has final h");

        // Head: fused loss + grads (head trainable) or loss + dh only.
        let head_seg = if mask.head { "head_fwd_bwd" } else { "head_fwd_bwd_x" };
        let outs = self.rt.run(
            head_seg,
            &[
                Operand::F32(&h_last),
                Operand::F32(&params.gf),
                Operand::F32(&params.wh),
                Operand::I32(&batch.targets),
            ],
        )?;
        let loss = HostTensor::scalar_from_literal(&outs[0])?;
        let mut dh = HostTensor::from_literal(&outs[1], &hs)?;
        let mut grads = Grads {
            blocks: vec![None; m.n_layers],
            ..Default::default()
        };
        if mask.head {
            grads.gf = Some(HostTensor::from_literal(&outs[2], &[m.d_model])?);
            grads.wh = Some(HostTensor::from_literal(&outs[3], &[m.d_model, m.vocab])?);
        }
        drop(outs);

        // Backward walk. Stop once nothing below needs gradients.
        let lowest = if mask.embed {
            0
        } else {
            mask.lowest_trainable_block().unwrap_or(m.n_layers)
        };
        let mut grad_bytes = grads.bytes();
        self.meter.set(MemCategory::Grads, grad_bytes);
        for l in (0..m.n_layers).rev() {
            if l < lowest {
                // No trainable tensors at or below this block: the dL/dx
                // chain is dead weight — skip it entirely.
                self.bwd_skipped += 1;
                continue;
            }
            let h_in = &stash[l];
            if mask.blocks[l] {
                self.bwd_full_calls += 1;
                let mut ops = vec![Operand::F32(&dh), Operand::F32(h_in)];
                ops.extend(params.blocks[l].iter().map(Operand::F32));
                let outs = self.rt.run("block_bwd_full", &ops)?;
                let new_dh = HostTensor::from_literal(&outs[0], &hs)?;
                let mut dthetas = Vec::with_capacity(params.blocks[l].len());
                for (o, (_, shape)) in outs[1..].iter().zip(&m.block_params) {
                    dthetas.push(HostTensor::from_literal(o, shape)?);
                }
                grad_bytes += dthetas.iter().map(|t| t.bytes() as u64).sum::<u64>();
                self.meter.set(MemCategory::Grads, grad_bytes);
                grads.blocks[l] = Some(dthetas);
                dh = new_dh;
            } else {
                self.bwd_x_calls += 1;
                let mut ops = vec![Operand::F32(&dh), Operand::F32(h_in)];
                ops.extend(params.blocks[l].iter().map(Operand::F32));
                let outs = self.rt.run("block_bwd_x", &ops)?;
                dh = HostTensor::from_literal(&outs[0], &hs)?;
            }
        }

        if mask.embed {
            let outs = self
                .rt
                .run("embed_bwd", &[Operand::F32(&dh), Operand::I32(&batch.tokens)])?;
            grads.emb = Some(HostTensor::from_literal(&outs[0], &[m.vocab, m.d_model])?);
            grads.pos = Some(HostTensor::from_literal(&outs[1], &[m.seq, m.d_model])?);
            grad_bytes = grads.bytes();
            self.meter.set(MemCategory::Grads, grad_bytes);
        }

        self.meter.set(MemCategory::Activations, 0);
        Ok(StepOutput { loss, grads })
    }

    /// Eval-only forward loss (no gradients, no stash retention).
    pub fn forward_loss(&mut self, params: &ModelParams, batch: &Batch) -> Result<f32> {
        let hs = self.h_shape();
        let out = self.rt.run(
            "embed_fwd",
            &[
                Operand::I32(&batch.tokens),
                Operand::F32(&params.emb),
                Operand::F32(&params.pos),
            ],
        )?;
        let mut h = HostTensor::from_literal(&out[0], &hs)?;
        for layer in &params.blocks {
            let out = self.rt.run("block_fwd", &Self::block_ops(&h, layer))?;
            h = HostTensor::from_literal(&out[0], &hs)?;
        }
        let outs = self.rt.run(
            "head_loss",
            &[
                Operand::F32(&h),
                Operand::F32(&params.gf),
                Operand::F32(&params.wh),
                Operand::I32(&batch.targets),
            ],
        )?;
        HostTensor::scalar_from_literal(&outs[0])
    }

    /// Logits after running the first `n_blocks` blocks (DoLa-style early
    /// exit when `n_blocks < L`; full model when `n_blocks == L`).
    pub fn logits_at(
        &mut self,
        params: &ModelParams,
        tokens: &HostTensorI32,
        n_blocks: usize,
    ) -> Result<HostTensor> {
        let m = &self.rt.manifest;
        assert!(n_blocks <= m.n_layers);
        let hs = self.h_shape();
        let out = self.rt.run(
            "embed_fwd",
            &[Operand::I32(tokens), Operand::F32(&params.emb), Operand::F32(&params.pos)],
        )?;
        let mut h = HostTensor::from_literal(&out[0], &hs)?;
        for layer in params.blocks.iter().take(n_blocks) {
            let out = self.rt.run("block_fwd", &Self::block_ops(&h, layer))?;
            h = HostTensor::from_literal(&out[0], &hs)?;
        }
        let outs = self.rt.run(
            "head_logits",
            &[Operand::F32(&h), Operand::F32(&params.gf), Operand::F32(&params.wh)],
        )?;
        HostTensor::from_literal(&outs[0], &[m.batch, m.seq, m.vocab])
    }

    pub fn logits(
        &mut self,
        params: &ModelParams,
        tokens: &HostTensorI32,
    ) -> Result<HostTensor> {
        self.logits_at(params, tokens, self.rt.manifest.n_layers)
    }

    /// Raw literal output passthrough used by the LoRA engine extension.
    pub(crate) fn run_raw(&self, name: &str, ops: &[Operand]) -> Result<Vec<Literal>> {
        self.rt.run(name, ops)
    }
}
