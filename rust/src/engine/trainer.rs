//! The layer-granular training engine — the L3 heart of this reproduction.
//!
//! The model is compiled as per-segment executables (embed / block / head).
//! This engine schedules them:
//!
//! ```text
//! forward:   embed_fwd -> block_fwd^L (stash inputs) -> head_fwd_bwd
//! backward:  for l = L-1..0:  block_bwd_full  (trainable: dh + dθ)
//!                             block_bwd_x     (frozen:    dh only)
//!            embed_bwd if the embedding is trainable
//! ```
//!
//! That per-block `bwd_full` vs `bwd_x` choice is what makes LISA's savings
//! *real* here: frozen blocks never compute weight gradients (FLOPs) and
//! never hold them (bytes). The backward walk also stops early once no
//! trainable tensor remains below the current block.
//!
//! Backward segments rematerialize the forward internally (per-block
//! gradient checkpointing), so the activation stash is exactly one
//! `[B, T, D]` residual per block.
//!
//! **Device-resident data flow** (DESIGN.md §8): with `device_flow` on
//! (the default), weight tensors are uploaded once into a
//! [`DeviceCache`] keyed by [`ParamKey`] + parameter-store generation and
//! re-served as `Operand::Buf` until a strategy reports them mutated
//! ([`Touched`]); the residual stream `h`/`dh` chains between segments as
//! device buffers wherever the artifacts are device-chainable. The host
//! path (`device_flow = false`) reproduces the original
//! upload-everything/download-everything schedule bit for bit — it is the
//! differential baseline for `tests/it_device.rs` and the bench's
//! before/after comparison.

use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{Context, Result};
use xla::Literal;

use crate::model::{ModelParams, ParamKey};
use crate::opt::quant::{quantize_per_channel, QuantTensor};
use crate::runtime::{
    ChainVal, DeviceCache, DeviceTensor, HostTensor, HostTensorI32, Operand, Runtime, SegId,
    CLASS_F32, CLASS_I8,
};

use super::memory::{MemCategory, MemoryMeter};

/// Residency/compute format for frozen-base weights (DESIGN.md §15).
/// `Int8` routes frozen tensors through the `*_q8` fused-dequant segments
/// with int8+scales device residency; trainable tensors always stay f32.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    Off,
    Int8,
}

/// Which components are trainable this step (LISA resamples this every K
/// steps; FT sets everything true; LoRA uses its own path).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TrainMask {
    pub embed: bool,
    pub head: bool,
    pub blocks: Vec<bool>,
}

impl TrainMask {
    pub fn all(n_layers: usize) -> Self {
        TrainMask { embed: true, head: true, blocks: vec![true; n_layers] }
    }

    pub fn none(n_layers: usize) -> Self {
        TrainMask { embed: false, head: false, blocks: vec![false; n_layers] }
    }

    pub fn n_trainable_blocks(&self) -> usize {
        self.blocks.iter().filter(|&&b| b).count()
    }

    /// Index of the lowest trainable block, if any.
    pub fn lowest_trainable_block(&self) -> Option<usize> {
        self.blocks.iter().position(|&b| b)
    }
}

/// One training batch: token ids and (shifted, prompt-masked) targets.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: HostTensorI32,
    pub targets: HostTensorI32,
}

/// Gradients for the trainable subset; `None` = frozen, never computed.
#[derive(Debug, Clone, Default)]
pub struct Grads {
    pub emb: Option<HostTensor>,
    pub pos: Option<HostTensor>,
    pub blocks: Vec<Option<Vec<HostTensor>>>,
    pub gf: Option<HostTensor>,
    pub wh: Option<HostTensor>,
}

impl Grads {
    pub fn bytes(&self) -> u64 {
        let mut b = 0u64;
        for t in [&self.emb, &self.pos, &self.gf, &self.wh].into_iter().flatten() {
            b += t.bytes() as u64;
        }
        for blk in self.blocks.iter().flatten() {
            for t in blk {
                b += t.bytes() as u64;
            }
        }
        b
    }

    /// Accumulate `other` into `self` (microbatch accumulation). Both must
    /// cover the same trainable subset.
    pub fn add_assign(&mut self, other: &Grads) {
        fn acc(a: &mut Option<HostTensor>, b: &Option<HostTensor>) {
            match (a, b) {
                (Some(x), Some(y)) => x.add_assign(y),
                (None, None) => {}
                _ => panic!("grad accumulation over mismatched masks"),
            }
        }
        acc(&mut self.emb, &other.emb);
        acc(&mut self.pos, &other.pos);
        acc(&mut self.gf, &other.gf);
        acc(&mut self.wh, &other.wh);
        assert_eq!(self.blocks.len(), other.blocks.len());
        for (a, b) in self.blocks.iter_mut().zip(&other.blocks) {
            match (a, b) {
                (Some(xs), Some(ys)) => {
                    for (x, y) in xs.iter_mut().zip(ys) {
                        x.add_assign(y);
                    }
                }
                (None, None) => {}
                _ => panic!("grad accumulation over mismatched masks"),
            }
        }
    }

    pub fn scale(&mut self, s: f32) {
        for t in [&mut self.emb, &mut self.pos, &mut self.gf, &mut self.wh]
            .into_iter()
            .flatten()
        {
            t.scale(s);
        }
        for blk in self.blocks.iter_mut().flatten() {
            for t in blk {
                t.scale(s);
            }
        }
    }

    /// Per-block gradient L2 norms; `None` = frozen this step (no gradient
    /// was ever computed). Feeds the gradient-adaptive sampler
    /// (`strategy::lisa_grad`).
    pub fn block_norms(&self) -> Vec<Option<f64>> {
        self.blocks
            .iter()
            .map(|blk| {
                blk.as_ref().map(|ts| {
                    ts.iter().map(|t| t.l2_norm().powi(2)).sum::<f64>().sqrt()
                })
            })
            .collect()
    }

    /// Global gradient L2 norm over the trainable subset.
    pub fn global_norm(&self) -> f64 {
        let mut sq = 0.0;
        for t in [&self.emb, &self.pos, &self.gf, &self.wh].into_iter().flatten() {
            sq += t.l2_norm().powi(2);
        }
        for blk in self.blocks.iter().flatten() {
            for t in blk {
                sq += t.l2_norm().powi(2);
            }
        }
        sq.sqrt()
    }
}

/// Which parameter tensors a `Strategy::apply` actually mutated — the
/// device-cache invalidation contract (DESIGN.md §8). The training loop
/// forwards this to [`Engine::invalidate`]; a strategy that under-reports
/// would train against stale weights, which `tests/it_device.rs` guards
/// against differentially.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Touched {
    /// Nothing changed (vanilla, or a step with no accumulated grads).
    None,
    /// Exactly these keys changed (the common case: the trainable subset).
    Keys(Vec<ParamKey>),
    /// Everything may have changed (checkpoint restore, store swap).
    All,
}

impl Touched {
    /// The keys a gradient application touches: every tensor present in
    /// `grads` — which by construction is exactly the trainable subset.
    pub fn from_grads(grads: &Grads) -> Touched {
        let mut keys = Vec::new();
        if grads.emb.is_some() {
            keys.push(ParamKey::Emb);
        }
        if grads.pos.is_some() {
            keys.push(ParamKey::Pos);
        }
        for (l, blk) in grads.blocks.iter().enumerate() {
            if let Some(ts) = blk {
                keys.extend((0..ts.len()).map(|t| ParamKey::Block(l, t)));
            }
        }
        if grads.gf.is_some() {
            keys.push(ParamKey::HeadNorm);
        }
        if grads.wh.is_some() {
            keys.push(ParamKey::HeadProj);
        }
        if keys.is_empty() {
            Touched::None
        } else {
            Touched::Keys(keys)
        }
    }
}

/// Output of one forward/backward microbatch.
pub struct StepOutput {
    pub loss: f32,
    pub grads: Grads,
}

/// A value of the residual stream between segments: host tensor (legacy
/// path), a downloaded literal awaiting its single consumer (device path
/// through tuple-rooted segments), or a live device buffer (device path
/// through chainable segments — no host transfer at all).
pub(crate) enum Act {
    Host(HostTensor),
    Lit { lit: Literal, shape: Vec<usize> },
    Dev(DeviceTensor),
}

impl Act {
    pub(crate) fn operand(&self) -> Operand<'_> {
        match self {
            Act::Host(t) => Operand::F32(t),
            Act::Lit { lit, .. } => Operand::Lit(lit),
            Act::Dev(dt) => Operand::Buf(dt),
        }
    }

    pub(crate) fn bytes(&self) -> usize {
        match self {
            Act::Host(t) => t.bytes(),
            Act::Lit { shape, .. } => crate::runtime::numel(shape) * 4,
            Act::Dev(dt) => dt.bytes(),
        }
    }

    pub(crate) fn into_host(self) -> Result<HostTensor> {
        match self {
            Act::Host(t) => Ok(t),
            Act::Lit { lit, shape } => HostTensor::from_literal(&lit, &shape),
            Act::Dev(dt) => dt.to_host(),
        }
    }
}

/// One parameter operand resolved for the engine's current flow mode: a
/// cached device buffer under `device_flow`, the borrowed host tensor
/// otherwise. Produced by the `Engine` operand builders
/// ([`Engine::embed_ops`] / [`Engine::block_ops`] / [`Engine::head_ops`] /
/// [`Engine::adapter_ops`]) — the single home of the
/// `if device_flow { Operand::Buf } else { Operand::F32 }` decision that
/// used to be repeated across the trainer, the LoRA path and the decode
/// loops.
pub(crate) enum ParamOp<'p> {
    Dev(Rc<DeviceTensor>),
    Host(&'p HostTensor),
    /// Quantized pair resident on device: `(q, scales)` buffers. Expands
    /// to two segment operands.
    DevQ8(Rc<DeviceTensor>, Rc<DeviceTensor>),
    /// Quantized pair on the host path (uploaded per call, still a
    /// quarter of the f32 wire bytes).
    HostQ8(Rc<QuantTensor>),
}

impl ParamOp<'_> {
    /// Append this parameter's segment operand(s): one for f32, the
    /// `(q, s)` pair for quantized tensors — which is why every operand
    /// list is built by pushing, not by a 1:1 map.
    pub(crate) fn push_operands<'o>(&'o self, ops: &mut Vec<Operand<'o>>) {
        match self {
            ParamOp::Dev(b) => ops.push(Operand::Buf(b)),
            ParamOp::Host(t) => ops.push(Operand::F32(t)),
            ParamOp::DevQ8(q, s) => {
                ops.push(Operand::Buf(q));
                ops.push(Operand::Buf(s));
            }
            ParamOp::HostQ8(p) => {
                ops.push(Operand::I8(&p.q));
                ops.push(Operand::F32(&p.s));
            }
        }
    }
}

/// A parameter's cached device residency: one f32 buffer, or the
/// quantized `(q, scales)` pair — the two classes of the dual-format
/// [`DeviceCache`] (`CLASS_F32` / `CLASS_I8`).
#[derive(Clone)]
pub(crate) enum DevParam {
    F32(Rc<DeviceTensor>),
    Q8(Rc<DeviceTensor>, Rc<DeviceTensor>),
}

/// Interned handles for every segment the engine schedules (resolved once
/// in `Engine::new`; compilation stays lazy).
#[derive(Debug, Clone, Copy)]
pub(crate) struct SegIds {
    pub embed_fwd: SegId,
    pub embed_bwd: SegId,
    pub block_fwd: SegId,
    pub block_bwd_full: SegId,
    pub block_bwd_x: SegId,
    pub block_fwd_lora: SegId,
    pub block_bwd_lora: SegId,
    pub head_fwd_bwd: SegId,
    pub head_fwd_bwd_x: SegId,
    pub head_loss: SegId,
    pub head_logits: SegId,
    // serving: batched KV-cached decode (interned unconditionally; they
    // compile lazily, so legacy artifact dirs without the decode ABI load
    // fine and only error if the cached path is actually requested)
    pub prefill_kv: SegId,
    pub pack_state: SegId,
    pub decode_step: SegId,
    pub decode_logits: SegId,
    // serving: paged K/V cache (decode ABI v2, DESIGN.md §12); same
    // lazy-compile contract, so v1 artifact dirs still load
    pub paged_scatter: SegId,
    pub paged_step: SegId,
    pub paged_logits: SegId,
    // quantized frozen-base twins (DESIGN.md §15); interned
    // unconditionally under the same lazy-compile contract, selected only
    // when the manifest's quant block gates them on
    pub embed_fwd_q8: SegId,
    pub block_fwd_q8: SegId,
    pub block_bwd_x_q8: SegId,
    pub block_fwd_lora_q8: SegId,
    pub block_bwd_lora_q8: SegId,
    pub head_fwd_bwd_x_q8: SegId,
    pub head_loss_q8: SegId,
    pub head_logits_q8: SegId,
    pub prefill_kv_q8: SegId,
    pub decode_step_q8: SegId,
    pub decode_logits_q8: SegId,
    pub paged_step_q8: SegId,
    pub paged_logits_q8: SegId,
}

/// The engine: schedules segment executables over the runtime.
pub struct Engine<'rt> {
    pub rt: &'rt Runtime,
    pub meter: MemoryMeter,
    /// Statistics: per-step counts of full vs input-only block backwards
    /// (the Fig 4 iteration-time mechanism).
    pub bwd_full_calls: u64,
    pub bwd_x_calls: u64,
    pub bwd_skipped: u64,
    /// Device-resident flow toggle. On by default; `LISA_DEVICE_FLOW=0`
    /// (or setting the field) restores the seed's host-roundtrip schedule
    /// — the bit-for-bit baseline for equivalence tests and benches.
    pub device_flow: bool,
    cache: DeviceCache<ParamKey, DevParam>,
    /// Host-side quantized bytes, keyed `(key, store-generation)` like the
    /// device cache; invalidated together with it so a mutated tensor is
    /// never served stale codes.
    qhost: BTreeMap<(ParamKey, u64), Rc<QuantTensor>>,
    /// Frozen-base quantization mode. `LISA_QUANT=0`/`off` pins `Off`
    /// (the kill switch beats `set_quant`); `LISA_QUANT=int8`/`1` starts
    /// in `Int8`.
    quant: QuantMode,
    quant_pinned: bool,
    /// Last trainable mask seen: the per-key frozen/trainable oracle the
    /// operand builders select q8 vs f32 with. Starts all-frozen, which
    /// is exactly right for eval/decode/LoRA engines that never call
    /// [`Engine::forward_backward`].
    train_mask: TrainMask,
    pub(crate) ids: SegIds,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime) -> Self {
        let device_flow = std::env::var("LISA_DEVICE_FLOW")
            .map(|v| v != "0")
            .unwrap_or(true);
        let (quant, quant_pinned) = match std::env::var("LISA_QUANT").as_deref() {
            Ok("0") | Ok("off") => (QuantMode::Off, true),
            Ok("int8") | Ok("1") => (QuantMode::Int8, false),
            _ => (QuantMode::Off, false),
        };
        let n_layers = rt.manifest.n_layers;
        Engine {
            rt,
            meter: MemoryMeter::new(),
            bwd_full_calls: 0,
            bwd_x_calls: 0,
            bwd_skipped: 0,
            device_flow,
            cache: DeviceCache::new(),
            qhost: BTreeMap::new(),
            quant,
            quant_pinned,
            train_mask: TrainMask::none(n_layers),
            ids: SegIds {
                embed_fwd: rt.seg_id("embed_fwd"),
                embed_bwd: rt.seg_id("embed_bwd"),
                block_fwd: rt.seg_id("block_fwd"),
                block_bwd_full: rt.seg_id("block_bwd_full"),
                block_bwd_x: rt.seg_id("block_bwd_x"),
                block_fwd_lora: rt.seg_id("block_fwd_lora"),
                block_bwd_lora: rt.seg_id("block_bwd_lora"),
                head_fwd_bwd: rt.seg_id("head_fwd_bwd"),
                head_fwd_bwd_x: rt.seg_id("head_fwd_bwd_x"),
                head_loss: rt.seg_id("head_loss"),
                head_logits: rt.seg_id("head_logits"),
                prefill_kv: rt.seg_id("prefill_kv"),
                pack_state: rt.seg_id("pack_state"),
                decode_step: rt.seg_id("decode_step"),
                decode_logits: rt.seg_id("decode_logits"),
                paged_scatter: rt.seg_id("paged_scatter"),
                paged_step: rt.seg_id("paged_step"),
                paged_logits: rt.seg_id("paged_logits"),
                embed_fwd_q8: rt.seg_id("embed_fwd_q8"),
                block_fwd_q8: rt.seg_id("block_fwd_q8"),
                block_bwd_x_q8: rt.seg_id("block_bwd_x_q8"),
                block_fwd_lora_q8: rt.seg_id("block_fwd_lora_q8"),
                block_bwd_lora_q8: rt.seg_id("block_bwd_lora_q8"),
                head_fwd_bwd_x_q8: rt.seg_id("head_fwd_bwd_x_q8"),
                head_loss_q8: rt.seg_id("head_loss_q8"),
                head_logits_q8: rt.seg_id("head_logits_q8"),
                prefill_kv_q8: rt.seg_id("prefill_kv_q8"),
                decode_step_q8: rt.seg_id("decode_step_q8"),
                decode_logits_q8: rt.seg_id("decode_logits_q8"),
                paged_step_q8: rt.seg_id("paged_step_q8"),
                paged_logits_q8: rt.seg_id("paged_logits_q8"),
            },
        }
    }

    // -- quantization ------------------------------------------------------

    /// Request a quantization mode (`--quant`). A `LISA_QUANT=0`/`off`
    /// pin wins: the env kill switch cannot be overridden from code.
    pub fn set_quant(&mut self, mode: QuantMode) {
        if !self.quant_pinned {
            self.quant = mode;
        }
    }

    pub fn quant(&self) -> QuantMode {
        self.quant
    }

    /// Record the trainable mask the q8/f32 per-key selection reads.
    /// [`Engine::forward_backward`] does this on every call; strategies
    /// that resample between steps don't need to call it directly.
    pub fn set_train_mask(&mut self, mask: &TrainMask) {
        self.train_mask = mask.clone();
    }

    /// Quantized segments are in play at all (mode on + artifacts carry
    /// the core q8 set for this backend).
    pub(crate) fn q8_avail(&self) -> bool {
        self.quant == QuantMode::Int8
            && self.rt.manifest.supports_quant(&self.rt.backend)
    }

    pub(crate) fn q8_embed(&self) -> bool {
        self.q8_avail() && !self.train_mask.embed
    }

    pub(crate) fn q8_head(&self) -> bool {
        self.q8_avail() && !self.train_mask.head
    }

    pub(crate) fn q8_block(&self, l: usize) -> bool {
        self.q8_avail() && !self.train_mask.blocks.get(l).copied().unwrap_or(false)
    }

    // -- device cache ------------------------------------------------------

    /// Drop cached device buffers for the keys a strategy mutated. The
    /// host-side quantized codes go with them: stale int8 of a moved
    /// tensor is as wrong as a stale device buffer.
    pub fn invalidate(&mut self, touched: &Touched) {
        match touched {
            Touched::None => {}
            Touched::All => {
                self.cache.invalidate_all();
                self.qhost.clear();
            }
            Touched::Keys(keys) => {
                for k in keys {
                    self.cache.invalidate(k);
                }
                self.qhost.retain(|(k, _), _| !keys.contains(k));
            }
        }
        self.sync_device_meter();
    }

    /// Drop every cached device buffer (checkpoint restore, store swap).
    pub fn invalidate_all(&mut self) {
        self.cache.invalidate_all();
        self.qhost.clear();
        self.sync_device_meter();
    }

    pub fn device_cache_stats(&self) -> crate::runtime::CacheStats {
        self.cache.stats()
    }

    fn sync_device_meter(&mut self) {
        self.meter
            .set(MemCategory::DeviceBuffers, self.cache.resident_bytes());
    }

    /// Cached device buffer for one parameter tensor (uploads on miss).
    /// Asking for f32 evicts a quantized residency of the same tensor and
    /// vice versa — the cache's class swap, which is how a LISA resample
    /// flips a tensor's format with exactly one upload.
    pub(crate) fn param_buf(
        &mut self,
        key: ParamKey,
        src: u64,
        t: &HostTensor,
    ) -> Result<Rc<DeviceTensor>> {
        let rt = self.rt;
        let v = self.cache.get_or_upload_class(key, src, CLASS_F32, || {
            let dt = DeviceTensor::from_host(&rt.client, t)?;
            let bytes = dt.bytes() as u64;
            Ok((DevParam::F32(Rc::new(dt)), bytes))
        })?;
        match v {
            DevParam::F32(b) => Ok(b),
            DevParam::Q8(..) => unreachable!("CLASS_F32 entry holds f32"),
        }
    }

    /// Cached device residency for one *quantized* parameter: the
    /// `(q, scales)` buffer pair under `CLASS_I8`.
    pub(crate) fn param_buf_q8(
        &mut self,
        key: ParamKey,
        src: u64,
        qt: &QuantTensor,
    ) -> Result<(Rc<DeviceTensor>, Rc<DeviceTensor>)> {
        let rt = self.rt;
        let v = self.cache.get_or_upload_class(key, src, CLASS_I8, || {
            let q = DeviceTensor::from_host_i8(&rt.client, &qt.q)?;
            let s = DeviceTensor::from_host(&rt.client, &qt.s)?;
            let bytes = (q.bytes() + s.bytes()) as u64;
            Ok((DevParam::Q8(Rc::new(q), Rc::new(s)), bytes))
        })?;
        match v {
            DevParam::Q8(q, s) => Ok((q, s)),
            DevParam::F32(_) => unreachable!("CLASS_I8 entry holds q8"),
        }
    }

    /// Host-side quantized codes for one tensor, memoized per
    /// `(key, store-generation)` so the absmax scan runs once per freeze
    /// period, not once per step.
    fn qhost(&mut self, key: ParamKey, src: u64, t: &HostTensor) -> Result<Rc<QuantTensor>> {
        if let Some(q) = self.qhost.get(&(key, src)) {
            return Ok(q.clone());
        }
        let qt = Rc::new(quantize_per_channel(t)?);
        self.qhost.insert((key, src), qt.clone());
        Ok(qt)
    }

    /// One frozen parameter as a q8 [`ParamOp`] for the current flow mode.
    fn q8_op<'p>(&mut self, key: ParamKey, src: u64, t: &HostTensor) -> Result<ParamOp<'p>> {
        let qt = self.qhost(key, src, t)?;
        Ok(if self.device_flow {
            let (q, s) = self.param_buf_q8(key, src, &qt)?;
            ParamOp::DevQ8(q, s)
        } else {
            ParamOp::HostQ8(qt)
        })
    }

    /// Cached device buffers for every tensor of block `l`, ABI order.
    pub(crate) fn block_bufs(
        &mut self,
        params: &ModelParams,
        l: usize,
    ) -> Result<Vec<Rc<DeviceTensor>>> {
        let src = params.store_id();
        let out = params.blocks[l]
            .iter()
            .enumerate()
            .map(|(t, x)| self.param_buf(ParamKey::Block(l, t), src, x))
            .collect();
        self.sync_device_meter();
        out
    }

    /// Cached device buffers for the head (gf, wh).
    pub(crate) fn head_bufs(
        &mut self,
        params: &ModelParams,
    ) -> Result<(Rc<DeviceTensor>, Rc<DeviceTensor>)> {
        let src = params.store_id();
        let gf = self.param_buf(ParamKey::HeadNorm, src, &params.gf)?;
        let wh = self.param_buf(ParamKey::HeadProj, src, &params.wh)?;
        self.sync_device_meter();
        Ok((gf, wh))
    }

    /// Cached device buffers for the embedding (emb, pos).
    pub(crate) fn embed_bufs(
        &mut self,
        params: &ModelParams,
    ) -> Result<(Rc<DeviceTensor>, Rc<DeviceTensor>)> {
        let src = params.store_id();
        let emb = self.param_buf(ParamKey::Emb, src, &params.emb)?;
        let pos = self.param_buf(ParamKey::Pos, src, &params.pos)?;
        self.sync_device_meter();
        Ok((emb, pos))
    }

    /// Cached device buffers for the LoRA adapters of layer `l`, ABI
    /// order (lives here so every parameter-buffer path shares one cache
    /// API and the device meter).
    pub(crate) fn adapter_bufs(
        &mut self,
        lora: &crate::lora::LoraState,
        l: usize,
    ) -> Result<Vec<Rc<DeviceTensor>>> {
        let src = lora.store_id();
        let out = lora.adapters[l]
            .iter()
            .enumerate()
            .map(|(i, t)| self.param_buf(ParamKey::Lora(l, i), src, t))
            .collect();
        self.sync_device_meter();
        out
    }

    // -- operand builders --------------------------------------------------
    // Every schedule (trainer forward/backward, LoRA, serve prefill/decode)
    // builds its parameter operands through these, so the device/host flow
    // decision is made in exactly one place per tensor group.

    /// `[emb, pos]` operands for `embed_fwd` / `decode_step` (or their q8
    /// twins when the embedding is frozen and quantization is on — the
    /// caller picks the segment with the same [`Engine::q8_embed`]
    /// predicate this builder uses).
    pub(crate) fn embed_ops<'p>(
        &mut self,
        params: &'p ModelParams,
    ) -> Result<[ParamOp<'p>; 2]> {
        if self.q8_embed() {
            let src = params.store_id();
            let emb = self.q8_op(ParamKey::Emb, src, &params.emb)?;
            let pos = self.q8_op(ParamKey::Pos, src, &params.pos)?;
            self.sync_device_meter();
            return Ok([emb, pos]);
        }
        Ok(if self.device_flow {
            let (emb, pos) = self.embed_bufs(params)?;
            [ParamOp::Dev(emb), ParamOp::Dev(pos)]
        } else {
            [ParamOp::Host(&params.emb), ParamOp::Host(&params.pos)]
        })
    }

    /// `[gf, wh]` operands for the head segments. Under q8 the norm gain
    /// `gf` stays f32 (1-D tensors never quantize) and `wh` becomes the
    /// `(q, s)` pair.
    pub(crate) fn head_ops<'p>(
        &mut self,
        params: &'p ModelParams,
    ) -> Result<[ParamOp<'p>; 2]> {
        if self.q8_head() {
            let src = params.store_id();
            let gf = if self.device_flow {
                ParamOp::Dev(self.param_buf(ParamKey::HeadNorm, src, &params.gf)?)
            } else {
                ParamOp::Host(&params.gf)
            };
            let wh = self.q8_op(ParamKey::HeadProj, src, &params.wh)?;
            self.sync_device_meter();
            return Ok([gf, wh]);
        }
        Ok(if self.device_flow {
            let (gf, wh) = self.head_bufs(params)?;
            [ParamOp::Dev(gf), ParamOp::Dev(wh)]
        } else {
            [ParamOp::Host(&params.gf), ParamOp::Host(&params.wh)]
        })
    }

    /// Block `l`'s tensors in ABI order. Under q8 (frozen block, quant
    /// on) every 2-D weight becomes its `(q, s)` pair in place while the
    /// norm gains stay f32 — exactly the 14-operand q8 block ABI.
    pub(crate) fn block_ops<'p>(
        &mut self,
        params: &'p ModelParams,
        l: usize,
    ) -> Result<Vec<ParamOp<'p>>> {
        if self.q8_block(l) {
            let src = params.store_id();
            let mut out = Vec::with_capacity(params.blocks[l].len());
            for (t, x) in params.blocks[l].iter().enumerate() {
                let key = ParamKey::Block(l, t);
                if x.shape.len() == 2 {
                    out.push(self.q8_op(key, src, x)?);
                } else if self.device_flow {
                    out.push(ParamOp::Dev(self.param_buf(key, src, x)?));
                } else {
                    out.push(ParamOp::Host(x));
                }
            }
            self.sync_device_meter();
            return Ok(out);
        }
        Ok(if self.device_flow {
            self.block_bufs(params, l)?.into_iter().map(ParamOp::Dev).collect()
        } else {
            params.blocks[l].iter().map(ParamOp::Host).collect()
        })
    }

    /// LoRA adapter tensors of layer `l` in ABI order.
    pub(crate) fn adapter_ops<'p>(
        &mut self,
        lora: &'p crate::lora::LoraState,
        l: usize,
    ) -> Result<Vec<ParamOp<'p>>> {
        Ok(if self.device_flow {
            self.adapter_bufs(lora, l)?.into_iter().map(ParamOp::Dev).collect()
        } else {
            lora.adapters[l].iter().map(ParamOp::Host).collect()
        })
    }

    // -- execution helpers -------------------------------------------------

    pub(crate) fn h_shape(&self) -> Vec<usize> {
        let m = &self.rt.manifest;
        vec![m.batch, m.seq, m.d_model]
    }

    /// Run a single-output segment, keeping the result chained: a device
    /// buffer when the artifact allows it, otherwise the downloaded value
    /// (as a literal on the device path, a host tensor on the host path).
    pub(crate) fn run_chain_act(
        &self,
        id: SegId,
        ops: &[Operand],
        shape: &[usize],
    ) -> Result<Act> {
        if self.device_flow {
            match self.rt.run_chained(id, ops)? {
                ChainVal::Dev(dt) => Ok(Act::Dev(dt)),
                ChainVal::Host(mut lits) => {
                    let lit = lits.swap_remove(0);
                    Ok(Act::Lit { lit, shape: shape.to_vec() })
                }
            }
        } else {
            let outs = self.rt.run_id(id, ops)?;
            Ok(Act::Host(HostTensor::from_literal(&outs[0], shape)?))
        }
    }

    /// Wrap a multi-output segment's chained value (`dh`) for its single
    /// downstream consumer.
    pub(crate) fn act_from_literal(&self, lit: Literal, shape: &[usize]) -> Result<Act> {
        if self.device_flow {
            Ok(Act::Lit { lit, shape: shape.to_vec() })
        } else {
            // host path converts eagerly, matching the seed schedule
            Ok(Act::Host(HostTensor::from_literal(&lit, shape)?))
        }
    }

    /// Forward through embed + all blocks, returning every block input plus
    /// the final hidden state (stash[l] is the input of block l).
    fn forward_stash(
        &mut self,
        params: &ModelParams,
        tokens: &HostTensorI32,
    ) -> Result<Vec<Act>> {
        let hs = self.h_shape();
        let eid = if self.q8_embed() { self.ids.embed_fwd_q8 } else { self.ids.embed_fwd };
        let ep = self.embed_ops(params)?;
        let mut ops = vec![Operand::I32(tokens)];
        for p in &ep {
            p.push_operands(&mut ops);
        }
        let mut h = self.run_chain_act(eid, &ops, &hs)?;
        let mut stash = Vec::with_capacity(params.blocks.len() + 1);
        let mut act_bytes = 0u64;
        for l in 0..params.blocks.len() {
            act_bytes += h.bytes() as u64;
            self.meter.set(MemCategory::Activations, act_bytes);
            let fid = if self.q8_block(l) { self.ids.block_fwd_q8 } else { self.ids.block_fwd };
            let bo = self.block_ops(params, l)?;
            let mut ops = vec![h.operand()];
            for p in &bo {
                p.push_operands(&mut ops);
            }
            let h_next = self.run_chain_act(fid, &ops, &hs)?;
            drop(ops);
            stash.push(h);
            h = h_next;
        }
        self.meter
            .set(MemCategory::Activations, act_bytes + h.bytes() as u64);
        stash.push(h);
        Ok(stash)
    }

    /// Full-parameter / LISA forward+backward over the trainable mask.
    pub fn forward_backward(
        &mut self,
        params: &ModelParams,
        batch: &Batch,
        mask: &TrainMask,
    ) -> Result<StepOutput> {
        let rt = self.rt;
        let m = &rt.manifest;
        assert_eq!(mask.blocks.len(), m.n_layers, "mask arity");
        self.set_train_mask(mask);
        let hs = self.h_shape();
        self.meter.set(MemCategory::Params, params.bytes() as u64);

        let mut stash = self.forward_stash(params, &batch.tokens)?;
        let h_last = stash.pop().expect("stash has final h");

        // Head: fused loss + grads (head trainable) or loss + dh only
        // (through the q8 twin when the frozen head is quantized).
        let head_id = if mask.head {
            self.ids.head_fwd_bwd
        } else if self.q8_head() {
            self.ids.head_fwd_bwd_x_q8
        } else {
            self.ids.head_fwd_bwd_x
        };
        let ho = self.head_ops(params)?;
        let mut ops = vec![h_last.operand()];
        for p in &ho {
            p.push_operands(&mut ops);
        }
        ops.push(Operand::I32(&batch.targets));
        let outs = self.rt.run_id(head_id, &ops)?;
        let mut it = outs.into_iter();
        let loss =
            HostTensor::scalar_from_literal(&it.next().context("head: missing loss")?)?;
        let dh_lit = it.next().context("head: missing dh")?;
        let mut grads = Grads {
            blocks: vec![None; m.n_layers],
            ..Default::default()
        };
        if mask.head {
            grads.gf = Some(HostTensor::from_literal(
                &it.next().context("head: missing d(gf)")?,
                &[m.d_model],
            )?);
            grads.wh = Some(HostTensor::from_literal(
                &it.next().context("head: missing d(wh)")?,
                &[m.d_model, m.vocab],
            )?);
        }
        drop(it);
        let mut dh = self.act_from_literal(dh_lit, &hs)?;

        // Backward walk. Stop once nothing below needs gradients.
        let lowest = if mask.embed {
            0
        } else {
            mask.lowest_trainable_block().unwrap_or(m.n_layers)
        };
        let mut grad_bytes = grads.bytes();
        self.meter.set(MemCategory::Grads, grad_bytes);
        for l in (0..m.n_layers).rev() {
            if l < lowest {
                // No trainable tensors at or below this block: the dL/dx
                // chain is dead weight — skip it entirely.
                self.bwd_skipped += 1;
                continue;
            }
            if mask.blocks[l] {
                self.bwd_full_calls += 1;
                let outs = {
                    // trainable: always f32 (block_ops returns f32 here
                    // by construction — the mask says not frozen)
                    let bo = self.block_ops(params, l)?;
                    let mut ops = vec![dh.operand(), stash[l].operand()];
                    for p in &bo {
                        p.push_operands(&mut ops);
                    }
                    self.rt.run_id(self.ids.block_bwd_full, &ops)?
                };
                let mut it = outs.into_iter();
                let new_dh_lit = it.next().context("bwd_full: missing dh")?;
                let mut dthetas = Vec::with_capacity(params.blocks[l].len());
                for (o, (_, shape)) in it.zip(&m.block_params) {
                    dthetas.push(HostTensor::from_literal(&o, shape)?);
                }
                grad_bytes += dthetas.iter().map(|t| t.bytes() as u64).sum::<u64>();
                self.meter.set(MemCategory::Grads, grad_bytes);
                grads.blocks[l] = Some(dthetas);
                dh = self.act_from_literal(new_dh_lit, &hs)?;
            } else {
                self.bwd_x_calls += 1;
                // Single-output segment: the dh chain through frozen blocks
                // stays device-resident under chainable artifacts — the
                // LISA frozen-majority walk never touches the host.
                dh = {
                    let xid = if self.q8_block(l) {
                        self.ids.block_bwd_x_q8
                    } else {
                        self.ids.block_bwd_x
                    };
                    let bo = self.block_ops(params, l)?;
                    let mut ops = vec![dh.operand(), stash[l].operand()];
                    for p in &bo {
                        p.push_operands(&mut ops);
                    }
                    self.run_chain_act(xid, &ops, &hs)?
                };
            }
        }

        if mask.embed {
            let ops = [dh.operand(), Operand::I32(&batch.tokens)];
            let outs = self.rt.run_id(self.ids.embed_bwd, &ops)?;
            grads.emb = Some(HostTensor::from_literal(&outs[0], &[m.vocab, m.d_model])?);
            grads.pos = Some(HostTensor::from_literal(&outs[1], &[m.seq, m.d_model])?);
            grad_bytes = grads.bytes();
            self.meter.set(MemCategory::Grads, grad_bytes);
        }

        self.meter.set(MemCategory::Activations, 0);
        Ok(StepOutput { loss, grads })
    }

    /// Eval-only forward loss (no gradients, no stash retention).
    pub fn forward_loss(&mut self, params: &ModelParams, batch: &Batch) -> Result<f32> {
        let h = self.forward_chain(params, &batch.tokens, self.rt.manifest.n_layers)?;
        let lid = if self.q8_head() { self.ids.head_loss_q8 } else { self.ids.head_loss };
        let ho = self.head_ops(params)?;
        let mut ops = vec![h.operand()];
        for p in &ho {
            p.push_operands(&mut ops);
        }
        ops.push(Operand::I32(&batch.targets));
        self.run_scalar(lid, &ops)
    }

    /// Chain embed + the first `n_blocks` blocks (no stash).
    fn forward_chain(
        &mut self,
        params: &ModelParams,
        tokens: &HostTensorI32,
        n_blocks: usize,
    ) -> Result<Act> {
        let hs = self.h_shape();
        let eid = if self.q8_embed() { self.ids.embed_fwd_q8 } else { self.ids.embed_fwd };
        let ep = self.embed_ops(params)?;
        let mut ops = vec![Operand::I32(tokens)];
        for p in &ep {
            p.push_operands(&mut ops);
        }
        let mut h = self.run_chain_act(eid, &ops, &hs)?;
        for l in 0..n_blocks.min(params.blocks.len()) {
            h = {
                let fid = if self.q8_block(l) { self.ids.block_fwd_q8 } else { self.ids.block_fwd };
                let bo = self.block_ops(params, l)?;
                let mut ops = vec![h.operand()];
                for p in &bo {
                    p.push_operands(&mut ops);
                }
                self.run_chain_act(fid, &ops, &hs)?
            };
        }
        Ok(h)
    }

    fn run_scalar(&self, id: SegId, ops: &[Operand]) -> Result<f32> {
        if self.device_flow {
            match self.rt.run_chained(id, ops)? {
                ChainVal::Dev(dt) => HostTensor::scalar_from_literal(&dt.to_literal()?),
                ChainVal::Host(lits) => HostTensor::scalar_from_literal(&lits[0]),
            }
        } else {
            let outs = self.rt.run_id(id, ops)?;
            HostTensor::scalar_from_literal(&outs[0])
        }
    }

    /// Logits after running the first `n_blocks` blocks (DoLa-style early
    /// exit when `n_blocks < L`; full model when `n_blocks == L`).
    pub fn logits_at(
        &mut self,
        params: &ModelParams,
        tokens: &HostTensorI32,
        n_blocks: usize,
    ) -> Result<HostTensor> {
        let rt = self.rt;
        let m = &rt.manifest;
        assert!(n_blocks <= m.n_layers);
        let h = self.forward_chain(params, tokens, n_blocks)?;
        let shape = [m.batch, m.seq, m.vocab];
        let lid = if self.q8_head() { self.ids.head_logits_q8 } else { self.ids.head_logits };
        let ho = self.head_ops(params)?;
        let mut ops = vec![h.operand()];
        for p in &ho {
            p.push_operands(&mut ops);
        }
        self.run_chain_act(lid, &ops, &shape)?.into_host()
    }

    pub fn logits(
        &mut self,
        params: &ModelParams,
        tokens: &HostTensorI32,
    ) -> Result<HostTensor> {
        self.logits_at(params, tokens, self.rt.manifest.n_layers)
    }
}
