//! Persistent device-buffer cache — the core of the device-resident
//! parameter flow.
//!
//! One entry per parameter tensor, keyed by the caller's key type
//! (`model::ParamKey` in the engine) and stamped with the *store
//! generation id* of the parameter store it was uploaded from
//! (`ModelParams::store_id`). A lookup hits only when both the key and
//! the generation match, so a merged LoRA eval model, a CPT fork or any
//! other `ModelParams` instance can never be served another store's
//! bytes. In-place mutation (the optimizer update, checkpoint restore)
//! keeps the generation — that is exactly what the strategy invalidation
//! contract covers: `Strategy::apply` reports the keys it touched and the
//! training loop invalidates them here, so an upload happens only when a
//! tensor actually changed. For LISA with a frozen-majority mask that
//! turns ~`(L-γ)/L` of all per-step weight uploads into cache hits.
//!
//! Each key holds up to [`MAX_GENERATIONS`] concurrent generations with
//! LRU eviction inside the key. That is what keeps a periodic
//! merged-model eval (LoRA: a fresh store generation every time) from
//! evicting the warm *training* generation: the training entries are
//! touched every step and survive; the previous eval's entries go cold
//! and are the ones replaced.
//!
//! The cache is value-generic so the eviction/stamping logic is unit
//! tested without a PJRT client; the engine instantiates it with
//! `Rc<DeviceTensor>`.

use std::collections::BTreeMap;

use anyhow::Result;

/// Concurrent store generations kept per key: the training store plus
/// one eval/fork view. A third generation evicts the least-recently-used.
pub const MAX_GENERATIONS: usize = 2;

/// Residency class of a cached value: full-precision f32.
pub const CLASS_F32: u8 = 0;
/// Residency class of a cached value: quantized int8 + scales
/// (DESIGN.md §15). A key holds exactly one class per store generation;
/// asking for the other class evicts and re-uploads (a *swap*).
pub const CLASS_I8: u8 = 1;

struct Entry<V> {
    val: V,
    /// Store-generation id the value was uploaded from.
    src: u64,
    /// Residency class ([`CLASS_F32`] / [`CLASS_I8`]).
    class: u8,
    bytes: u64,
    /// Logical timestamp of the last hit/upload (LRU within the key).
    last_use: u64,
}

/// Cumulative cache counters (reported next to `ExecStats` so upload
/// traffic is observable per run).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub invalidations: u64,
    /// Same-`(key, src)` format transitions — a frozen tensor promoted to
    /// trainable (i8→f32) or demoted back on a LISA resample (f32→i8).
    pub swaps: u64,
    /// Cumulative device bytes uploaded through `make` closures.
    pub upload_bytes: u64,
    pub entries: u64,
    pub resident_bytes: u64,
    /// Resident bytes currently held as full-precision f32.
    pub resident_f32_bytes: u64,
    /// Resident bytes currently held as quantized int8 (+scales).
    pub resident_i8_bytes: u64,
}

pub struct DeviceCache<K: Ord + Copy, V> {
    entries: BTreeMap<K, Vec<Entry<V>>>,
    tick: u64,
    hits: u64,
    misses: u64,
    invalidations: u64,
    swaps: u64,
    upload_bytes: u64,
    resident_bytes: u64,
    /// Resident bytes by class, indexed [`CLASS_F32`] / [`CLASS_I8`].
    class_bytes: [u64; 2],
}

impl<K: Ord + Copy, V> Default for DeviceCache<K, V> {
    fn default() -> Self {
        DeviceCache {
            entries: BTreeMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            invalidations: 0,
            swaps: 0,
            upload_bytes: 0,
            resident_bytes: 0,
            class_bytes: [0; 2],
        }
    }
}

fn cls(class: u8) -> usize {
    (class.min(1)) as usize
}

impl<K: Ord + Copy, V: Clone> DeviceCache<K, V> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Serve the cached value for `(key, src)` or upload a fresh one via
    /// `make` (which returns the value plus its device byte size). Other
    /// generations of the same key are left resident (up to
    /// [`MAX_GENERATIONS`]); beyond that the least-recently-used one is
    /// released. Callers with a single residency format ([`CLASS_F32`]).
    pub fn get_or_upload(
        &mut self,
        key: K,
        src: u64,
        make: impl FnOnce() -> Result<(V, u64)>,
    ) -> Result<V> {
        self.get_or_upload_class(key, src, CLASS_F32, make)
    }

    /// As [`Self::get_or_upload`], but format-aware: a hit requires both
    /// the store generation *and* the residency class to match. The same
    /// `(key, src)` resident in the *other* class is evicted first and the
    /// transition counted in [`CacheStats::swaps`] — this is how a LISA
    /// resample turns a frozen int8 tensor into a trainable f32 one (and
    /// back) with exactly one upload per direction (DESIGN.md §15).
    pub fn get_or_upload_class(
        &mut self,
        key: K,
        src: u64,
        class: u8,
        make: impl FnOnce() -> Result<(V, u64)>,
    ) -> Result<V> {
        self.tick += 1;
        if let Some(list) = self.entries.get_mut(&key) {
            if let Some(pos) = list.iter().position(|e| e.src == src) {
                if list[pos].class == class {
                    list[pos].last_use = self.tick;
                    self.hits += 1;
                    return Ok(list[pos].val.clone());
                }
                let old = list.remove(pos);
                self.resident_bytes -= old.bytes;
                self.class_bytes[cls(old.class)] -= old.bytes;
                self.swaps += 1;
            }
        }
        self.misses += 1;
        let (val, bytes) = make()?;
        self.upload_bytes += bytes;
        let tick = self.tick;
        let list = self.entries.entry(key).or_default();
        list.push(Entry { val: val.clone(), src, class, bytes, last_use: tick });
        self.resident_bytes += bytes;
        self.class_bytes[cls(class)] += bytes;
        if list.len() > MAX_GENERATIONS {
            let (lru, _) = list
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_use)
                .expect("non-empty list");
            let old = list.remove(lru);
            self.resident_bytes -= old.bytes;
            self.class_bytes[cls(old.class)] -= old.bytes;
        }
        Ok(val)
    }

    /// Drop every generation of `key` (the tensor was mutated in place);
    /// the next lookup re-uploads. Returns whether anything was resident.
    ///
    /// All generations go, not just the mutating store's: identity-
    /// sharing views (`ModelParams::eval_view`) rely on byte equality
    /// with their source, so once the source moved nothing under this
    /// key is trustworthy.
    pub fn invalidate(&mut self, key: &K) -> bool {
        match self.entries.remove(key) {
            Some(list) => {
                self.invalidations += list.len() as u64;
                for e in &list {
                    self.resident_bytes -= e.bytes;
                    self.class_bytes[cls(e.class)] -= e.bytes;
                }
                true
            }
            None => false,
        }
    }

    /// Drop everything (checkpoint restore, store swap).
    pub fn invalidate_all(&mut self) {
        self.invalidations += self.len() as u64;
        self.entries.clear();
        self.resident_bytes = 0;
        self.class_bytes = [0; 2];
    }

    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// Total resident entries across all keys and generations.
    pub fn len(&self) -> usize {
        self.entries.values().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            invalidations: self.invalidations,
            swaps: self.swaps,
            upload_bytes: self.upload_bytes,
            entries: self.len() as u64,
            resident_bytes: self.resident_bytes,
            resident_f32_bytes: self.class_bytes[cls(CLASS_F32)],
            resident_i8_bytes: self.class_bytes[cls(CLASS_I8)],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn up(v: &str, b: u64) -> impl FnOnce() -> Result<(String, u64)> + '_ {
        move || Ok((v.to_string(), b))
    }

    #[test]
    fn hit_after_upload_miss_after_invalidate() {
        let mut c: DeviceCache<u32, String> = DeviceCache::new();
        assert_eq!(c.get_or_upload(1, 10, up("a", 4)).unwrap(), "a");
        // second lookup: hit, the closure must not run
        assert_eq!(
            c.get_or_upload(1, 10, || panic!("must not re-upload")).unwrap(),
            "a"
        );
        assert!(c.invalidate(&1));
        assert!(!c.invalidate(&1), "double invalidate is a no-op");
        assert_eq!(c.get_or_upload(1, 10, up("b", 4)).unwrap(), "b");
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.invalidations), (1, 2, 1));
    }

    #[test]
    fn generations_coexist_and_never_serve_stale() {
        let mut c: DeviceCache<u32, String> = DeviceCache::new();
        c.get_or_upload(7, 100, up("train", 8)).unwrap();
        // same key, different store (e.g. merged LoRA eval params):
        // uploaded alongside, never served the training bytes
        assert_eq!(c.get_or_upload(7, 101, up("merged", 8)).unwrap(), "merged");
        // and back: the training generation survived the eval
        assert_eq!(
            c.get_or_upload(7, 100, || panic!("train gen must survive")).unwrap(),
            "train"
        );
        assert_eq!(c.stats().entries, 2);
        assert_eq!(c.resident_bytes(), 16);
    }

    #[test]
    fn third_generation_evicts_the_coldest() {
        let mut c: DeviceCache<u32, String> = DeviceCache::new();
        c.get_or_upload(7, 1, up("train", 8)).unwrap();
        c.get_or_upload(7, 2, up("eval-1", 8)).unwrap();
        // the training generation is touched again (every step does)...
        c.get_or_upload(7, 1, || panic!("hit expected")).unwrap();
        // ...so the next eval generation evicts eval-1, not train
        c.get_or_upload(7, 3, up("eval-2", 8)).unwrap();
        assert_eq!(c.stats().entries, 2);
        assert_eq!(c.resident_bytes(), 16);
        c.get_or_upload(7, 1, || panic!("train gen must still be resident"))
            .unwrap();
        // eval-1 is gone: looking it up re-uploads
        assert_eq!(c.get_or_upload(7, 2, up("eval-1b", 8)).unwrap(), "eval-1b");
    }

    #[test]
    fn invalidate_drops_every_generation_of_the_key() {
        let mut c: DeviceCache<u32, String> = DeviceCache::new();
        c.get_or_upload(1, 10, up("a", 100)).unwrap();
        c.get_or_upload(1, 11, up("b", 50)).unwrap();
        c.get_or_upload(2, 10, up("c", 7)).unwrap();
        assert_eq!(c.resident_bytes(), 157);
        assert!(c.invalidate(&1));
        assert_eq!(c.resident_bytes(), 7);
        assert_eq!(c.stats().invalidations, 2);
        c.invalidate_all();
        assert_eq!(c.resident_bytes(), 0);
        assert!(c.is_empty());
        assert_eq!(c.stats().invalidations, 3);
    }

    #[test]
    fn class_swap_evicts_the_other_format_and_counts_bytes() {
        let mut c: DeviceCache<u32, String> = DeviceCache::new();
        // frozen weight resident as int8+scales (a quarter of the bytes)
        c.get_or_upload_class(1, 10, CLASS_I8, up("q8", 25)).unwrap();
        let s = c.stats();
        assert_eq!((s.resident_i8_bytes, s.resident_f32_bytes), (25, 0));
        assert_eq!(s.upload_bytes, 25);
        // LISA resample promotes it to trainable: same (key, src), other
        // class — the int8 copy is evicted, one f32 upload, one swap
        assert_eq!(
            c.get_or_upload_class(1, 10, CLASS_F32, up("f32", 100)).unwrap(),
            "f32"
        );
        let s = c.stats();
        assert_eq!(s.swaps, 1);
        assert_eq!((s.resident_i8_bytes, s.resident_f32_bytes), (0, 100));
        assert_eq!(s.resident_bytes, 100);
        assert_eq!(s.entries, 1, "swap replaces, never duplicates");
        // ...and demoted back on the next resample: second swap
        c.get_or_upload_class(1, 10, CLASS_I8, up("q8b", 25)).unwrap();
        let s = c.stats();
        assert_eq!(s.swaps, 2);
        assert_eq!((s.resident_i8_bytes, s.resident_f32_bytes), (25, 0));
        assert_eq!(s.upload_bytes, 150);
        // steady state: same class is a plain hit, no re-upload
        c.get_or_upload_class(1, 10, CLASS_I8, || panic!("hit expected"))
            .unwrap();
    }

    #[test]
    fn legacy_get_or_upload_is_class_f32_and_per_class_books_balance() {
        let mut c: DeviceCache<u32, String> = DeviceCache::new();
        c.get_or_upload(1, 1, up("a", 8)).unwrap();
        c.get_or_upload_class(2, 1, CLASS_I8, up("b", 2)).unwrap();
        let s = c.stats();
        assert_eq!((s.resident_f32_bytes, s.resident_i8_bytes), (8, 2));
        assert_eq!(s.resident_bytes, 10);
        // invalidation returns the class ledger to zero, not just the total
        assert!(c.invalidate(&2));
        assert_eq!(c.stats().resident_i8_bytes, 0);
        c.invalidate_all();
        let s = c.stats();
        assert_eq!((s.resident_f32_bytes, s.resident_i8_bytes), (0, 0));
        // LRU eviction of a mixed-class key keeps the ledger balanced too
        c.get_or_upload_class(7, 1, CLASS_I8, up("x", 2)).unwrap();
        c.get_or_upload_class(7, 2, CLASS_F32, up("y", 8)).unwrap();
        c.get_or_upload_class(7, 1, CLASS_I8, || panic!("hit expected"))
            .unwrap();
        c.get_or_upload_class(7, 3, CLASS_F32, up("z", 8)).unwrap(); // evicts src=2
        let s = c.stats();
        assert_eq!((s.resident_i8_bytes, s.resident_f32_bytes), (2, 8));
        assert_eq!(s.swaps, 0, "different src is a generation, not a swap");
    }

    #[test]
    fn upload_error_leaves_cache_unchanged() {
        let mut c: DeviceCache<u32, String> = DeviceCache::new();
        c.get_or_upload(1, 1, up("a", 4)).unwrap();
        let err = c.get_or_upload(2, 1, || anyhow::bail!("device OOM"));
        assert!(err.is_err());
        assert_eq!(c.len(), 1);
        assert_eq!(c.resident_bytes(), 4);
        // the failed key stays a miss, the good key stays a hit
        assert_eq!(c.get_or_upload(1, 1, || panic!("hit expected")).unwrap(), "a");
    }
}
