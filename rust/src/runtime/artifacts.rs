//! Artifact manifest + loader: the contract between `python/compile/aot.py`
//! and the Rust engine.
//!
//! Each config directory under `artifacts/` holds one `<segment>.<backend>`
//! HLO-text module per entry in `manifest.json`. The loader validates the
//! manifest signature against what the engine expects at call time —
//! operand count/shape/dtype mismatches fail at load or call, never as
//! silent garbage.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I8,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            "int8" => Ok(DType::I8),
            other => bail!("unsupported dtype in manifest: {other}"),
        }
    }

    /// Bytes per element — what upload accounting and the memory meter
    /// count for a tensor of this dtype.
    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSig> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("sig missing shape"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(
            j.get("dtype")
                .and_then(|d| d.as_str())
                .ok_or_else(|| anyhow!("sig missing dtype"))?,
        )?;
        Ok(TensorSig { shape, dtype })
    }
}

#[derive(Debug, Clone)]
pub struct SegmentSig {
    pub file: String,
    pub operands: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    /// Whether the module root is a tuple. Single-output segments are
    /// exported with a bare root (`aot.py` `return_tuple=False`) so their
    /// output buffer can feed the next segment without a host round-trip;
    /// multi-output segments — and every pre-existing artifact, where the
    /// manifest lacks the field — are tuple-rooted and unwrapped on the
    /// host as before.
    pub tuple_root: bool,
}

impl SegmentSig {
    /// True when execution can hand back the output as a device buffer
    /// (`Runtime::run_chained` returns `ChainVal::Dev`).
    pub fn device_chainable(&self) -> bool {
        !self.tuple_root && self.outputs.len() == 1
    }
}

/// Parsed `manifest.json` for one model config.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub mlp_ratio: usize,
    pub lora_rank: usize,
    pub lora_alpha: f64,
    pub n_params: usize,
    /// Block parameter shapes in ABI order (g1, wq, wk, wv, wo, g2, w1, w2).
    pub block_params: Vec<(String, Vec<usize>)>,
    /// LoRA adapter shapes in ABI order (aq, bq, ..., a2, b2).
    pub lora_params: Vec<(String, Vec<usize>)>,
    /// Decode-ABI version the exporter stamped (DESIGN.md §9/§12). `0` —
    /// including manifests from before the field existed — means the
    /// artifact dir carries no KV-cached decode segments; the serving
    /// path then falls back to the legacy full-forward loop. `2` adds the
    /// paged-cache segments on top of the complete v1 set.
    pub decode_abi: u64,
    /// Paged-cache geometry (ABI v2, DESIGN.md §12): token slots per K/V
    /// page, page-table width per row, and pool pages per layer-half.
    /// All zero for v0/v1 manifests.
    pub page_t: usize,
    pub pages_per_row: usize,
    pub page_n: usize,
    /// Quantized-base mode the exporter stamped (DESIGN.md §15): the
    /// `"quant": {"mode": ...}` block's mode string, empty when absent —
    /// every pre-quant manifest — meaning the dir carries no `*_q8`
    /// segments and the engine pins pure f32.
    pub quant_mode: String,
    /// key = "<segment>.<backend>"
    pub segments: BTreeMap<String, SegmentSig>,
}

/// Segment names of decode ABI v1, in prefill→decode order.
pub const DECODE_SEGMENTS: [&str; 4] =
    ["prefill_kv", "pack_state", "decode_step", "decode_logits"];

/// Oldest decode-ABI version the engine implements.
pub const DECODE_ABI: u64 = 1;

/// Segment names decode ABI v2 adds (paged K/V cache, DESIGN.md §12).
pub const PAGED_SEGMENTS: [&str; 3] = ["paged_scatter", "paged_step", "paged_logits"];

/// Newest decode-ABI version the engine implements.
pub const PAGED_ABI: u64 = 2;

/// The quantized-base mode string the engine implements (DESIGN.md §15):
/// per-output-channel int8 with dequant fused into the segment matmuls.
pub const QUANT_MODE: &str = "int8-chan";

/// Core quantized segment set: the training/eval twins every quant-capable
/// dir must carry (the backward twins that emit weight gradients have no
/// q8 variant by construction — trainable tensors are always f32).
pub const QUANT_SEGMENTS: [&str; 8] = [
    "embed_fwd_q8",
    "block_fwd_q8",
    "block_bwd_x_q8",
    "block_fwd_lora_q8",
    "block_bwd_lora_q8",
    "head_fwd_bwd_x_q8",
    "head_loss_q8",
    "head_logits_q8",
];

/// Quantized twins of the packed-decode (ABI v1) serving segments.
pub const QUANT_DECODE_SEGMENTS: [&str; 3] =
    ["prefill_kv_q8", "decode_step_q8", "decode_logits_q8"];

/// Quantized twins of the paged (ABI v2) serving segments.
pub const QUANT_PAGED_SEGMENTS: [&str; 2] = ["paged_step_q8", "paged_logits_q8"];

/// One field of the optional `"paged"` geometry object (ABI v2); absent —
/// every v0/v1 manifest — reads as 0, which `supports_paged` rejects.
fn paged_us(j: &Json, k: &str) -> usize {
    j.get("paged")
        .and_then(|p| p.get(k))
        .and_then(|v| v.as_usize())
        .unwrap_or(0)
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let cfg = j.get("config").ok_or_else(|| anyhow!("manifest missing config"))?;
        let us = |k: &str| -> Result<usize> {
            cfg.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("config missing {k}"))
        };

        let named_shapes = |shapes_key: &str, names_key: &str| -> Result<Vec<(String, Vec<usize>)>> {
            let shapes = j
                .get(shapes_key)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("manifest missing {shapes_key}"))?;
            let names = j
                .get(names_key)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("manifest missing {names_key}"))?;
            if shapes.len() != names.len() {
                bail!("{shapes_key}/{names_key} length mismatch");
            }
            names
                .iter()
                .zip(shapes)
                .map(|(n, s)| {
                    let name = n.as_str().ok_or_else(|| anyhow!("bad name"))?.to_string();
                    let dims = s
                        .as_arr()
                        .ok_or_else(|| anyhow!("bad shape"))?
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                        .collect::<Result<Vec<_>>>()?;
                    Ok((name, dims))
                })
                .collect()
        };

        let mut segments = BTreeMap::new();
        for (key, seg) in j
            .get("segments")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow!("manifest missing segments"))?
        {
            let file = seg
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("segment {key} missing file"))?
                .to_string();
            let sigs = |k: &str| -> Result<Vec<TensorSig>> {
                seg.get(k)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("segment {key} missing {k}"))?
                    .iter()
                    .map(TensorSig::from_json)
                    .collect()
            };
            let tuple_root = seg
                .get("tuple_root")
                .and_then(|v| v.as_bool())
                .unwrap_or(true);
            segments.insert(
                key.clone(),
                SegmentSig {
                    file,
                    operands: sigs("operands")?,
                    outputs: sigs("outputs")?,
                    tuple_root,
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            name: cfg
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("config missing name"))?
                .to_string(),
            d_model: us("d_model")?,
            n_layers: us("n_layers")?,
            n_heads: us("n_heads")?,
            vocab: us("vocab")?,
            seq: us("seq")?,
            batch: us("batch")?,
            mlp_ratio: us("mlp_ratio")?,
            lora_rank: us("lora_rank")?,
            lora_alpha: cfg
                .get("lora_alpha")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("config missing lora_alpha"))?,
            n_params: us("n_params")?,
            block_params: named_shapes("block_params", "block_param_names")?,
            lora_params: named_shapes("lora_params", "lora_param_names")?,
            decode_abi: j
                .get("decode_abi")
                .and_then(|v| v.as_usize())
                .unwrap_or(0) as u64,
            page_t: paged_us(&j, "page_t"),
            pages_per_row: paged_us(&j, "pages_per_row"),
            page_n: paged_us(&j, "page_n"),
            quant_mode: j
                .get("quant")
                .and_then(|q| q.get("mode"))
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            segments,
        })
    }

    /// Whether this artifact dir carries the KV-cached decode segments the
    /// engine's `DecodeSession` schedules (ABI-versioned; a newer or
    /// missing ABI, or any missing segment, disables the cached path —
    /// the caller falls back to legacy full-forward greedy). A v2 (paged)
    /// manifest still supports the v1 schedule: the paged set is a strict
    /// superset and the packed segments remain the parity baseline.
    pub fn supports_decode(&self, backend: &str) -> bool {
        (DECODE_ABI..=PAGED_ABI).contains(&self.decode_abi)
            && DECODE_SEGMENTS
                .iter()
                .all(|n| self.segments.contains_key(&format!("{n}.{backend}")))
    }

    /// Whether this artifact dir additionally carries the paged-cache
    /// segments and geometry of decode ABI v2 (DESIGN.md §12). Requires
    /// `supports_decode` too — batch prefill reuses the v1 prompt
    /// pipeline verbatim.
    pub fn supports_paged(&self, backend: &str) -> bool {
        self.decode_abi == PAGED_ABI
            && self.page_t > 0
            && self.pages_per_row > 0
            && self.page_n > 0
            && self.supports_decode(backend)
            && PAGED_SEGMENTS
                .iter()
                .all(|n| self.segments.contains_key(&format!("{n}.{backend}")))
    }

    /// Whether this artifact dir carries the quantized-base core set
    /// (DESIGN.md §15): the stamped mode must be exactly the one the
    /// engine implements AND every core q8 segment must be present for
    /// `backend` — same completeness rule as the decode ABI, so a partial
    /// export (or an unknown future mode, e.g. int4) reads as "f32 only"
    /// and legacy dirs load unchanged.
    pub fn supports_quant(&self, backend: &str) -> bool {
        self.quant_mode == QUANT_MODE
            && QUANT_SEGMENTS
                .iter()
                .all(|n| self.segments.contains_key(&format!("{n}.{backend}")))
    }

    /// Whether the packed-decode (v1) serving schedule can run quantized:
    /// the core set plus every decode twin.
    pub fn supports_quant_decode(&self, backend: &str) -> bool {
        self.supports_quant(backend)
            && self.supports_decode(backend)
            && QUANT_DECODE_SEGMENTS
                .iter()
                .all(|n| self.segments.contains_key(&format!("{n}.{backend}")))
    }

    /// Whether the paged (v2) serving schedule can run quantized: the
    /// quantized decode set plus every paged twin.
    pub fn supports_quant_paged(&self, backend: &str) -> bool {
        self.supports_quant_decode(backend)
            && self.supports_paged(backend)
            && QUANT_PAGED_SEGMENTS
                .iter()
                .all(|n| self.segments.contains_key(&format!("{n}.{backend}")))
    }

    /// Rows of the packed decode state `[B, L*2T+1, D]` (DESIGN.md §9).
    pub fn decode_state_rows(&self) -> usize {
        self.n_layers * 2 * self.seq + 1
    }

    /// Rows of the paged decode state `[L*2*N*page_t + B, D]`
    /// (DESIGN.md §12): one K and one V pool of `page_n` pages per layer
    /// plus the B trailing hidden-state rows.
    pub fn paged_state_rows(&self) -> usize {
        self.n_layers * 2 * self.page_n * self.page_t + self.batch
    }

    pub fn segment(&self, name: &str, backend: &str) -> Result<&SegmentSig> {
        let key = format!("{name}.{backend}");
        self.segments
            .get(&key)
            .ok_or_else(|| anyhow!("manifest has no segment '{key}' (have: {:?})",
                                   self.segments.keys().collect::<Vec<_>>()))
    }

    pub fn hlo_path(&self, sig: &SegmentSig) -> PathBuf {
        self.dir.join(&sig.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "config": {"name": "t", "d_model": 8, "n_layers": 2, "n_heads": 2,
                 "vocab": 16, "seq": 4, "batch": 1, "mlp_ratio": 4,
                 "lora_rank": 2, "lora_alpha": 4.0, "n_params": 100},
      "block_params": [[8], [8, 8]],
      "block_param_names": ["g1", "wq"],
      "lora_params": [[8, 2]],
      "lora_param_names": ["aq"],
      "segments": {
        "block_fwd.jnp": {
          "file": "block_fwd.jnp.hlo.txt",
          "operands": [{"shape": [1, 4, 8], "dtype": "float32"}],
          "outputs": [{"shape": [1, 4, 8], "dtype": "float32"}],
          "tuple_root": false
        },
        "head_fwd_bwd.jnp": {
          "file": "head_fwd_bwd.jnp.hlo.txt",
          "operands": [{"shape": [1, 4, 8], "dtype": "float32"}],
          "outputs": [{"shape": [], "dtype": "float32"},
                      {"shape": [1, 4, 8], "dtype": "float32"}]
        }
      }
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("lisa_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), MINI).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.d_model, 8);
        assert_eq!(m.block_params[1], ("wq".to_string(), vec![8, 8]));
        let seg = m.segment("block_fwd", "jnp").unwrap();
        assert_eq!(seg.operands[0].shape, vec![1, 4, 8]);
        assert_eq!(seg.operands[0].dtype, DType::F32);
        assert!(!seg.tuple_root);
        assert!(seg.device_chainable());
        // missing flag defaults to the legacy tuple-rooted convention
        let head = m.segment("head_fwd_bwd", "jnp").unwrap();
        assert!(head.tuple_root);
        assert!(!head.device_chainable());
        assert!(m.segment("nope", "jnp").is_err());
    }

    #[test]
    fn decode_abi_gates_the_cached_path() {
        let dir = std::env::temp_dir().join("lisa_manifest_decode_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), MINI).unwrap();
        // legacy manifest: no decode_abi field -> 0 -> unsupported
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.decode_abi, 0);
        assert!(!m.supports_decode("jnp"));
        assert_eq!(m.decode_state_rows(), 2 * 2 * 4 + 1);

        // versioned manifest with every decode segment present
        let mut text = MINI.replace(
            "\"segments\": {",
            r#""decode_abi": 1, "segments": {"#,
        );
        let seg = |name: &str| {
            format!(
                r#""{name}.jnp": {{"file": "{name}.jnp.hlo.txt",
                    "operands": [{{"shape": [1, 4, 8], "dtype": "float32"}}],
                    "outputs": [{{"shape": [1, 4, 8], "dtype": "float32"}}],
                    "tuple_root": false}},"#
            )
        };
        let extra: String = super::DECODE_SEGMENTS.iter().map(|n| seg(n)).collect();
        text = text.replace("\"segments\": {", &format!("\"segments\": {{{extra}"));
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.decode_abi, 1);
        assert!(m.supports_decode("jnp"));
        // the other backend has no decode segments
        assert!(!m.supports_decode("pallas"));
        // a v1 manifest never claims the paged path
        assert!(!m.supports_paged("jnp"));
        assert_eq!(m.page_t, 0);
    }

    #[test]
    fn paged_abi_gates_the_paged_path_and_v1_still_loads() {
        let dir = std::env::temp_dir().join("lisa_manifest_paged_test");
        std::fs::create_dir_all(&dir).unwrap();
        let seg = |name: &str| {
            format!(
                r#""{name}.jnp": {{"file": "{name}.jnp.hlo.txt",
                    "operands": [{{"shape": [1, 4, 8], "dtype": "float32"}}],
                    "outputs": [{{"shape": [1, 4, 8], "dtype": "float32"}}],
                    "tuple_root": false}},"#
            )
        };
        let extra: String = super::DECODE_SEGMENTS
            .iter()
            .chain(super::PAGED_SEGMENTS.iter())
            .map(|n| seg(n))
            .collect();
        let text = MINI
            .replace(
                "\"segments\": {",
                r#""decode_abi": 2,
                   "paged": {"page_t": 2, "pages_per_row": 2, "page_n": 5,
                             "state_rows": 41},
                   "segments": {"#,
            )
            .replace("\"segments\": {", &format!("\"segments\": {{{extra}"));
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.decode_abi, 2);
        assert_eq!((m.page_t, m.pages_per_row, m.page_n), (2, 2, 5));
        // a v2 dir serves BOTH schedules: paged, and packed-v1 as the
        // parity baseline
        assert!(m.supports_paged("jnp"));
        assert!(m.supports_decode("jnp"));
        assert!(!m.supports_paged("pallas"));
        // L*2*N*page_t + B
        assert_eq!(m.paged_state_rows(), 2 * 2 * 5 * 2 + 1);

        // decode_abi 2 without the paged segment set (partial export)
        // falls back to v1-only
        let text2 = MINI.replace(
            "\"segments\": {",
            &format!(
                r#""decode_abi": 2,
                   "paged": {{"page_t": 2, "pages_per_row": 2, "page_n": 5}},
                   "segments": {{{}"#,
                super::DECODE_SEGMENTS.iter().map(|n| seg(n)).collect::<String>()
            ),
        );
        std::fs::write(dir.join("manifest.json"), text2).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.supports_paged("jnp"));
        assert!(m.supports_decode("jnp"));

        // a future ABI the engine doesn't implement disables everything
        let text3 = MINI.replace("\"segments\": {", r#""decode_abi": 3, "segments": {"#);
        std::fs::write(dir.join("manifest.json"), text3).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.supports_decode("jnp"));
        assert!(!m.supports_paged("jnp"));
    }

    #[test]
    fn rejects_bad_dtype() {
        let j = Json::parse(r#"{"shape": [1], "dtype": "float64"}"#).unwrap();
        assert!(TensorSig::from_json(&j).is_err());
    }

    #[test]
    fn parses_int8_dtype_and_sizes() {
        let j = Json::parse(r#"{"shape": [4, 2], "dtype": "int8"}"#).unwrap();
        let sig = TensorSig::from_json(&j).unwrap();
        assert_eq!(sig.dtype, DType::I8);
        assert_eq!(sig.dtype.size_bytes(), 1);
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::I32.size_bytes(), 4);
    }

    #[test]
    fn quant_block_gates_the_q8_path() {
        let dir = std::env::temp_dir().join("lisa_manifest_quant_test");
        std::fs::create_dir_all(&dir).unwrap();
        // legacy manifest: no quant block -> f32 only
        std::fs::write(dir.join("manifest.json"), MINI).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.quant_mode, "");
        assert!(!m.supports_quant("jnp"));

        let seg = |name: &str| {
            format!(
                r#""{name}.jnp": {{"file": "{name}.jnp.hlo.txt",
                    "operands": [{{"shape": [8, 8], "dtype": "int8"}},
                                 {{"shape": [8], "dtype": "float32"}}],
                    "outputs": [{{"shape": [1, 4, 8], "dtype": "float32"}}],
                    "tuple_root": false}},"#
            )
        };

        // mode stamped but segments incomplete (partial export): rejected
        let core_minus_one: String =
            super::QUANT_SEGMENTS.iter().skip(1).map(|n| seg(n)).collect();
        let text = MINI.replace(
            "\"segments\": {",
            &format!(
                r#""quant": {{"mode": "int8-chan"}}, "segments": {{{core_minus_one}"#
            ),
        );
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.quant_mode, "int8-chan");
        assert!(!m.supports_quant("jnp"), "partial q8 export must not claim quant");

        // full core set: quant yes, quant_decode still no (no decode twins)
        let core: String = super::QUANT_SEGMENTS.iter().map(|n| seg(n)).collect();
        let text = MINI.replace(
            "\"segments\": {",
            &format!(r#""quant": {{"mode": "int8-chan"}}, "segments": {{{core}"#),
        );
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.supports_quant("jnp"));
        assert!(!m.supports_quant("pallas"), "other backend has no q8 set");
        assert!(!m.supports_quant_decode("jnp"));
        assert!(!m.supports_quant_paged("jnp"));

        // an unknown future mode (int4) reads as f32-only
        let text = MINI.replace(
            "\"segments\": {",
            &format!(r#""quant": {{"mode": "int4-nf4"}}, "segments": {{{core}"#),
        );
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.quant_mode, "int4-nf4");
        assert!(!m.supports_quant("jnp"));

        // core + decode twins + v1 decode set: quant_decode yes
        let all: String = super::QUANT_SEGMENTS
            .iter()
            .chain(super::QUANT_DECODE_SEGMENTS.iter())
            .chain(super::DECODE_SEGMENTS.iter())
            .map(|n| seg(n))
            .collect();
        let text = MINI.replace(
            "\"segments\": {",
            &format!(
                r#""decode_abi": 1, "quant": {{"mode": "int8-chan"}},
                   "segments": {{{all}"#
            ),
        );
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.supports_quant_decode("jnp"));
        assert!(!m.supports_quant_paged("jnp"), "v1 dir can't claim paged q8");
    }
}
