//! Deterministic fault injection for the execution hot path.
//!
//! Production hardware faults (a wedged device, a failed allocation, a
//! transient runtime hiccup) are not reproducible in CI. This module makes
//! them so: a [`FaultInjector`] holds a list of [`FaultPlan`]s — "fail the
//! Nth execution of segment X", "fail every Kth page allocation" — and the
//! runtime consults it at the top of every segment execution while the
//! [`PageAllocator`](crate::engine::PageAllocator) consults it on every
//! page grant. Counting is per-site and strictly deterministic, so a chaos
//! test that replays the same request mix under the same plan sees the
//! fault land on exactly the same step every run.
//!
//! Plans come from the `LISA_FAULT` environment variable (or
//! `Runtime::set_fault_plan` in tests), a `;`-separated list:
//!
//! ```text
//! seg:<name>:nth=<k>[:every=<k>][:count=<n>|:count=*][:transient|:persistent]
//! pool:nth=<k>[:every=<k>][:count=<n>|:count=*]
//! ```
//!
//! * `seg:<name>` targets a segment by manifest name; a trailing `*`
//!   makes it a prefix match (`seg:blk_*` hits every block segment).
//! * `nth` is the 1-based execution index at which the plan first fires
//!   (default 1); `every` repeats it each `every` executions after that
//!   (default: fire once, at `nth` only).
//! * `count` caps the total number of firings (`*` = unlimited; default
//!   unlimited — a plan without `every` fires once regardless).
//! * `transient` faults are expected to succeed on retry; `persistent`
//!   faults fail every retry of the same execution. Default `transient`.
//!   Pool plans always surface as [`FaultKind::PoolExhausted`].
//!
//! Injected failures surface as [`FaultError`] inside `anyhow::Error`, so
//! the serve loop can `downcast_ref::<FaultError>()` to classify them; the
//! allocator's *real* exhaustion error reuses the same type with
//! `hit == 0`, giving pool pressure one classification path whether it was
//! injected or earned.

// Clippy backstop for the no-panic serving contract (DESIGN.md §13,
// enforced structurally by lisa-lint's serve_panic pass).
#![warn(clippy::unwrap_used, clippy::expect_used)]
use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

/// How an injected (or classified) failure behaves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Goes away if the same work is retried (spurious runtime error).
    Transient,
    /// Fails every retry; the work must be abandoned or re-planned.
    Persistent,
    /// A page-pool allocation failure: schedulable, not fatal.
    PoolExhausted,
}

impl FaultKind {
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Transient => "transient",
            FaultKind::Persistent => "persistent",
            FaultKind::PoolExhausted => "pool-exhausted",
        }
    }
}

/// A typed injected failure. Carried inside `anyhow::Error`; consumers
/// classify with `err.downcast_ref::<FaultError>()`.
#[derive(Debug, Clone)]
pub struct FaultError {
    pub kind: FaultKind,
    /// The site that failed: a segment name, or `"page_pool"`.
    pub site: String,
    /// 1-based execution index at which the plan fired (0 for errors that
    /// were not injected but reuse this type for classification).
    pub hit: u64,
}

impl FaultError {
    /// The allocator's real (non-injected) exhaustion error: same type as
    /// an injected pool fault so callers classify both the same way.
    pub fn pool_exhausted() -> FaultError {
        FaultError { kind: FaultKind::PoolExhausted, site: "page_pool".to_string(), hit: 0 }
    }
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.hit == 0 {
            write!(f, "{} failure at {}", self.kind.label(), self.site)
        } else {
            write!(
                f,
                "injected {} fault at {} (execution #{})",
                self.kind.label(),
                self.site,
                self.hit
            )
        }
    }
}

impl std::error::Error for FaultError {}

#[derive(Debug, Clone, PartialEq, Eq)]
enum Target {
    /// Segment-name match; `prefix` selects starts-with matching.
    Segment { name: String, prefix: bool },
    Pool,
}

/// One parsed fault plan (see the module docs for the spec grammar).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    target: Target,
    nth: u64,
    every: u64,
    /// Firings left; `None` = unlimited.
    remaining: Option<u64>,
    kind: FaultKind,
}

impl FaultPlan {
    fn matches_count(&self, n: u64) -> bool {
        if self.remaining == Some(0) {
            return false;
        }
        if self.every > 0 {
            n >= self.nth && (n - self.nth) % self.every == 0
        } else {
            n == self.nth
        }
    }

    fn matches_site(&self, site: Option<&str>) -> bool {
        match (&self.target, site) {
            (Target::Pool, None) => true,
            (Target::Segment { name, prefix }, Some(s)) => {
                if *prefix {
                    s.starts_with(name.as_str())
                } else {
                    s == name
                }
            }
            _ => false,
        }
    }
}

/// Deterministic fault injector: per-site execution counters + plans.
#[derive(Debug, Default)]
pub struct FaultInjector {
    plans: Vec<FaultPlan>,
    seg_counts: BTreeMap<String, u64>,
    alloc_count: u64,
    /// Total faults injected so far (observability + test assertions).
    pub injected: u64,
}

impl FaultInjector {
    /// Parse a `;`-separated plan spec. An empty/whitespace spec yields an
    /// injector with no plans.
    pub fn parse(spec: &str) -> Result<FaultInjector> {
        let mut plans = Vec::new();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            plans.push(Self::parse_plan(part)?);
        }
        Ok(FaultInjector { plans, ..FaultInjector::default() })
    }

    fn parse_plan(part: &str) -> Result<FaultPlan> {
        let mut fields = part.split(':');
        let target = match fields.next() {
            Some("seg") => {
                let name = fields.next().filter(|n| !n.is_empty()).map(str::to_string);
                match name {
                    Some(mut name) => {
                        let prefix = name.ends_with('*');
                        if prefix {
                            name.pop();
                        }
                        Target::Segment { name, prefix }
                    }
                    None => bail!("fault plan {part:?}: seg needs a segment name"),
                }
            }
            Some("pool") => Target::Pool,
            _ => bail!("fault plan {part:?}: must start with seg:<name> or pool"),
        };
        let mut nth = 1u64;
        let mut every = 0u64;
        let mut remaining = None;
        let mut kind = match target {
            Target::Pool => FaultKind::PoolExhausted,
            Target::Segment { .. } => FaultKind::Transient,
        };
        for f in fields {
            if let Some(v) = f.strip_prefix("nth=") {
                nth = v.parse().ok().filter(|&n| n >= 1).ok_or_else(|| {
                    anyhow::anyhow!("fault plan {part:?}: nth must be an integer >= 1")
                })?;
            } else if let Some(v) = f.strip_prefix("every=") {
                every = v.parse().ok().filter(|&n| n >= 1).ok_or_else(|| {
                    anyhow::anyhow!("fault plan {part:?}: every must be an integer >= 1")
                })?;
            } else if let Some(v) = f.strip_prefix("count=") {
                remaining = if v == "*" {
                    None
                } else {
                    Some(v.parse::<u64>().ok().filter(|&n| n >= 1).ok_or_else(|| {
                        anyhow::anyhow!("fault plan {part:?}: count must be >= 1 or *")
                    })?)
                };
            } else if f == "transient" || f == "persistent" {
                if target == Target::Pool {
                    bail!("fault plan {part:?}: pool faults are always pool-exhausted");
                }
                kind = if f == "transient" {
                    FaultKind::Transient
                } else {
                    FaultKind::Persistent
                };
            } else {
                bail!("fault plan {part:?}: unknown field {f:?}");
            }
        }
        Ok(FaultPlan { target, nth, every, remaining, kind })
    }

    /// Read `LISA_FAULT`; an unset/empty variable yields no plans, a
    /// malformed spec is logged and ignored (a typo must not take down a
    /// production server at boot).
    pub fn from_env() -> FaultInjector {
        match std::env::var("LISA_FAULT") {
            Ok(spec) if !spec.trim().is_empty() => match Self::parse(&spec) {
                Ok(inj) => {
                    log::warn!("fault injection armed: LISA_FAULT={spec}");
                    inj
                }
                Err(e) => {
                    log::warn!("ignoring malformed LISA_FAULT={spec:?}: {e:#}");
                    FaultInjector::default()
                }
            },
            _ => FaultInjector::default(),
        }
    }

    /// True when no plans are armed (hot paths skip all bookkeeping).
    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    fn fire(plans: &mut [FaultPlan], injected: &mut u64, site: &str, n: u64) -> Option<FaultError> {
        let is_pool = site == "page_pool";
        for p in plans.iter_mut() {
            let site_arg = if is_pool { None } else { Some(site) };
            if p.matches_site(site_arg) && p.matches_count(n) {
                if let Some(r) = &mut p.remaining {
                    *r -= 1;
                }
                *injected += 1;
                return Some(FaultError { kind: p.kind, site: site.to_string(), hit: n });
            }
        }
        None
    }

    /// Called by the runtime before executing segment `name`. Advances the
    /// per-segment execution counter and returns the fault to inject, if
    /// any. A transient fault does NOT consume the execution slot: the
    /// retry of the same logical execution re-runs under the same index
    /// and succeeds (its plan already fired), while a persistent plan with
    /// `count=*` keeps failing the retries too.
    pub fn on_segment(&mut self, name: &str) -> Option<FaultError> {
        if self.plans.is_empty() {
            return None;
        }
        let n = {
            let c = self.seg_counts.entry(name.to_string()).or_insert(0);
            *c += 1;
            *c
        };
        let hit = Self::fire(&mut self.plans, &mut self.injected, name, n);
        if let Some(e) = &hit {
            if e.kind == FaultKind::Transient {
                // the failed execution never ran: rewind so the retry
                // replays the same index (now spent) and goes through
                if let Some(c) = self.seg_counts.get_mut(name) {
                    *c -= 1;
                }
            }
        }
        hit
    }

    /// Called by the page allocator before granting a page.
    pub fn on_alloc(&mut self) -> Option<FaultError> {
        if self.plans.is_empty() {
            return None;
        }
        self.alloc_count += 1;
        Self::fire(&mut self.plans, &mut self.injected, "page_pool", self.alloc_count)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests panic by design
mod tests {
    use super::*;

    fn seg_hits(inj: &mut FaultInjector, name: &str, n: usize) -> Vec<bool> {
        (0..n).map(|_| inj.on_segment(name).is_some()).collect()
    }

    #[test]
    fn nth_plan_fires_exactly_once_at_the_nth_execution() {
        let mut inj = FaultInjector::parse("seg:step:nth=3:persistent").unwrap();
        assert_eq!(seg_hits(&mut inj, "step", 5), vec![false, false, true, false, false]);
        assert_eq!(inj.injected, 1);
        // other segments share nothing with the targeted one
        assert_eq!(seg_hits(&mut inj, "other", 4), vec![false; 4]);
    }

    #[test]
    fn transient_fault_leaves_the_execution_slot_for_the_retry() {
        let mut inj = FaultInjector::parse("seg:step:nth=2:transient").unwrap();
        let e = [
            inj.on_segment("step"), // #1: clean
            inj.on_segment("step"), // #2: fires, counter rewound
            inj.on_segment("step"), // retry of #2: plan spent, clean
            inj.on_segment("step"), // #3: clean
        ];
        assert!(e[0].is_none() && e[2].is_none() && e[3].is_none());
        let f = e[1].as_ref().unwrap();
        assert_eq!((f.kind, f.hit), (FaultKind::Transient, 2));
    }

    #[test]
    fn every_and_count_control_repetition() {
        let mut inj = FaultInjector::parse("seg:step:nth=2:every=3:count=2:persistent").unwrap();
        // fires at 2 and 5, then the count cap stops 8
        let hits = seg_hits(&mut inj, "step", 9);
        let fired: Vec<usize> =
            hits.iter().enumerate().filter(|(_, h)| **h).map(|(i, _)| i + 1).collect();
        assert_eq!(fired, vec![2, 5]);

        let mut inj = FaultInjector::parse("seg:step:every=2:count=*:persistent").unwrap();
        let hits = seg_hits(&mut inj, "step", 6);
        assert_eq!(hits, vec![true, false, true, false, true, false]);
    }

    #[test]
    fn prefix_target_matches_any_segment_with_that_stem() {
        let mut inj = FaultInjector::parse("seg:blk_*:nth=1:count=2:persistent").unwrap();
        assert!(inj.on_segment("blk_0_fwd").is_some());
        assert!(inj.on_segment("embed_fwd").is_none());
        assert!(inj.on_segment("blk_1_fwd").is_some()); // separate counter, nth=1
        assert!(inj.on_segment("blk_2_fwd").is_none()); // count exhausted
    }

    #[test]
    fn pool_plans_fire_on_allocation_counts_with_pool_exhausted_kind() {
        let mut inj = FaultInjector::parse("pool:nth=2").unwrap();
        assert!(inj.on_alloc().is_none());
        let e = inj.on_alloc().unwrap();
        assert_eq!((e.kind, e.site.as_str(), e.hit), (FaultKind::PoolExhausted, "page_pool", 2));
        assert!(inj.on_alloc().is_none());
        // segment executions never consume the alloc counter
        let mut inj = FaultInjector::parse("pool:nth=1").unwrap();
        assert!(inj.on_segment("step").is_none());
        assert!(inj.on_alloc().is_some());
    }

    #[test]
    fn multiple_plans_are_independent() {
        let mut inj =
            FaultInjector::parse("seg:a:nth=1:persistent; pool:nth=1; seg:b:nth=2").unwrap();
        assert!(inj.on_segment("a").is_some());
        assert!(inj.on_segment("b").is_none());
        assert!(inj.on_segment("b").is_some());
        assert!(inj.on_alloc().is_some());
        assert_eq!(inj.injected, 3);
    }

    #[test]
    fn malformed_specs_are_rejected_with_a_reason() {
        for (spec, needle) in [
            ("step:nth=1", "seg:<name> or pool"),
            ("seg::nth=1", "needs a segment name"),
            ("seg:x:nth=0", "nth"),
            ("seg:x:every=zero", "every"),
            ("seg:x:count=0", "count"),
            ("seg:x:flaky", "unknown field"),
            ("pool:persistent", "always pool-exhausted"),
        ] {
            let err = format!("{:#}", FaultInjector::parse(spec).unwrap_err());
            assert!(err.contains(needle), "{spec} -> {err}");
        }
        assert!(FaultInjector::parse("").unwrap().is_empty());
        assert!(FaultInjector::parse(" ; ").unwrap().is_empty());
    }

    #[test]
    fn fault_error_classifies_through_anyhow_downcast() {
        let mut inj = FaultInjector::parse("seg:x:nth=1:persistent").unwrap();
        let err: anyhow::Error = inj.on_segment("x").unwrap().into();
        let err = err.context("executing segment x");
        let f = err.downcast_ref::<FaultError>().expect("typed fault survives context");
        assert_eq!(f.kind, FaultKind::Persistent);
        let real = anyhow::Error::new(FaultError::pool_exhausted());
        assert_eq!(real.downcast_ref::<FaultError>().unwrap().kind, FaultKind::PoolExhausted);
    }
}
