//! PJRT runtime: compile HLO-text artifacts once, execute them from the
//! training hot path.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU plugin):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`. Executables are cached per `(segment, backend)`; every
//! execution validates operand signatures from the manifest and unwraps the
//! `return_tuple=True` tuple the AOT exporter emits.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::artifacts::{DType, Manifest, SegmentSig};
use super::tensor::{HostTensor, HostTensorI32};

/// A training-step operand: f32 tensor, i32 tensor, or a borrowed literal.
pub enum Operand<'a> {
    F32(&'a HostTensor),
    I32(&'a HostTensorI32),
    Lit(&'a Literal),
}

/// One compiled segment + its manifest signature.
pub struct Segment {
    pub name: String,
    pub sig: SegmentSig,
    exe: PjRtLoadedExecutable,
    client: PjRtClient,
}

impl Segment {
    /// Execute with signature checking; returns the decomposed output tuple.
    ///
    /// Inputs are uploaded with `buffer_from_host_buffer` + `execute_b`
    /// rather than `execute`: the xla crate's `execute` leaks every input
    /// device buffer (its C shim `release()`s them and never frees —
    /// ~1 MB/step on the tiny config, OOM at experiment scale). Owning the
    /// input `PjRtBuffer`s on the Rust side makes Drop reclaim them.
    pub fn run(&self, operands: &[Operand]) -> Result<Vec<Literal>> {
        if operands.len() != self.sig.operands.len() {
            bail!(
                "segment {}: got {} operands, expected {}",
                self.name,
                operands.len(),
                self.sig.operands.len()
            );
        }
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(operands.len());
        for (i, (op, sig)) in operands.iter().zip(&self.sig.operands).enumerate() {
            let buf = match op {
                Operand::F32(t) => {
                    if sig.dtype != DType::F32 || t.shape != sig.shape {
                        bail!(
                            "segment {} operand {i}: shape/dtype mismatch \
                             (got f32 {:?}, want {:?} {:?})",
                            self.name, t.shape, sig.dtype, sig.shape
                        );
                    }
                    self.client
                        .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)?
                }
                Operand::I32(t) => {
                    if sig.dtype != DType::I32 || t.shape != sig.shape {
                        bail!(
                            "segment {} operand {i}: shape/dtype mismatch \
                             (got i32 {:?}, want {:?} {:?})",
                            self.name, t.shape, sig.dtype, sig.shape
                        );
                    }
                    self.client
                        .buffer_from_host_buffer::<i32>(&t.data, &t.shape, None)?
                }
                Operand::Lit(l) => self
                    .client
                    .buffer_from_host_literal(None, l)
                    .with_context(|| format!("uploading literal operand {i}"))?,
            };
            bufs.push(buf);
        }
        let out_bufs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&bufs.iter().collect::<Vec<_>>())
            .with_context(|| format!("executing segment {}", self.name))?;
        drop(bufs); // reclaim input device buffers
        let lit = out_bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {}", self.name))?;
        drop(out_bufs);
        let parts = lit
            .to_tuple()
            .with_context(|| format!("untupling output of {}", self.name))?;
        if parts.len() != self.sig.outputs.len() {
            bail!(
                "segment {}: got {} outputs, expected {}",
                self.name,
                parts.len(),
                self.sig.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Convenience: run and convert every output to a HostTensor using the
    /// manifest output shapes.
    pub fn run_host(&self, operands: &[Operand]) -> Result<Vec<HostTensor>> {
        let outs = self.run(operands)?;
        outs.iter()
            .zip(&self.sig.outputs)
            .map(|(lit, sig)| HostTensor::from_literal(lit, &sig.shape))
            .collect()
    }
}

/// Cumulative per-segment execution stats (the L3 profile in §Perf).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub total_ns: u128,
}

/// The runtime: one PJRT CPU client + compiled segment cache.
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    pub backend: String,
    cache: RefCell<BTreeMap<String, std::rc::Rc<Segment>>>,
    stats: RefCell<BTreeMap<String, ExecStats>>,
}

impl Runtime {
    /// `artifacts_dir` is e.g. `artifacts/tiny`; `backend` is `pallas`/`jnp`.
    pub fn load(artifacts_dir: &Path, backend: &str) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "runtime: config={} platform={} devices={} backend={backend}",
            manifest.name,
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            client,
            manifest,
            backend: backend.to_string(),
            cache: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(BTreeMap::new()),
        })
    }

    /// Get (compiling + caching on first use) a segment executable.
    pub fn segment(&self, name: &str) -> Result<std::rc::Rc<Segment>> {
        if let Some(seg) = self.cache.borrow().get(name) {
            return Ok(seg.clone());
        }
        let sig = self.manifest.segment(name, &self.backend)?.clone();
        let path = self.manifest.hlo_path(&sig);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        log::debug!(
            "compiled {name}.{} in {:.2}s",
            self.backend,
            t0.elapsed().as_secs_f64()
        );
        let seg = std::rc::Rc::new(Segment {
            name: name.to_string(),
            sig,
            exe,
            client: self.client.clone(),
        });
        self.cache.borrow_mut().insert(name.to_string(), seg.clone());
        Ok(seg)
    }

    /// Execute a segment by name, with timing stats.
    pub fn run(&self, name: &str, operands: &[Operand]) -> Result<Vec<Literal>> {
        let seg = self.segment(name)?;
        let t0 = Instant::now();
        let out = seg.run(operands)?;
        let dt = t0.elapsed().as_nanos();
        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total_ns += dt;
        Ok(out)
    }

    pub fn stats(&self) -> BTreeMap<String, ExecStats> {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        self.stats.borrow_mut().clear();
    }

    /// Pre-compile a list of segments (warm start before timed runs).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.segment(n)?;
        }
        Ok(())
    }
}
