//! PJRT runtime: compile HLO-text artifacts once, execute them from the
//! training hot path.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU plugin):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`. Executables are cached per `(segment, backend)`; every
//! execution validates operand signatures from the manifest.
//!
//! Two execution shapes exist:
//!
//! * tuple-rooted segments (multi-output, and every legacy artifact)
//!   download their output tuple as one literal and untuple on the host;
//! * bare-rooted single-output segments (`SegmentSig::device_chainable`)
//!   can return their output *as a device buffer* via
//!   [`Runtime::run_chained`], which is how the residual stream `h`/`dh`
//!   flows between block segments without touching the host.
//!
//! Segment handles are interned ([`SegId`]): the engine resolves each hot
//! segment name once and every later call is an index into a vector — no
//! per-call `String` allocation, no double `BTreeMap` lookup for the
//! executable cache and the stats table.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::artifacts::{DType, Manifest, SegmentSig};
use super::fault::FaultInjector;
use super::tensor::{DeviceTensor, HostTensor, HostTensorI32, HostTensorI8};

/// A training-step operand: host f32/i32/i8 tensor (uploaded per call), a
/// borrowed literal, or an already-device-resident buffer (no transfer).
pub enum Operand<'a> {
    F32(&'a HostTensor),
    I32(&'a HostTensorI32),
    /// Quantized frozen weight (one byte per element on the wire —
    /// DESIGN.md §15).
    I8(&'a HostTensorI8),
    Lit(&'a Literal),
    Buf(&'a DeviceTensor),
}

/// One compiled segment + its manifest signature.
pub struct Segment {
    pub name: String,
    pub sig: SegmentSig,
    exe: PjRtLoadedExecutable,
    client: PjRtClient,
}

/// Input buffer for one execution: freshly uploaded (owned, reclaimed on
/// drop right after the call) or borrowed from a cache / chained output.
enum InBuf<'a> {
    Owned(xla::PjRtBuffer),
    Ext(&'a xla::PjRtBuffer),
}

impl Segment {
    /// Upload/borrow the operand buffers with signature checking.
    ///
    /// Host inputs go through `buffer_from_host_buffer` + `execute_b`
    /// rather than `execute`: the xla crate's `execute` leaks every input
    /// device buffer (its C shim `release()`s them and never frees —
    /// ~1 MB/step on the tiny config, OOM at experiment scale). Owning the
    /// fresh input `PjRtBuffer`s on the Rust side makes Drop reclaim them;
    /// `Operand::Buf` inputs are borrowed and live on in their cache.
    fn input_buffers<'a>(&self, operands: &'a [Operand<'a>]) -> Result<Vec<InBuf<'a>>> {
        if operands.len() != self.sig.operands.len() {
            bail!(
                "segment {}: got {} operands, expected {}",
                self.name,
                operands.len(),
                self.sig.operands.len()
            );
        }
        let mut bufs: Vec<InBuf<'a>> = Vec::with_capacity(operands.len());
        for (i, (op, sig)) in operands.iter().zip(&self.sig.operands).enumerate() {
            let buf = match op {
                Operand::F32(t) => {
                    if sig.dtype != DType::F32 || t.shape != sig.shape {
                        bail!(
                            "segment {} operand {i}: shape/dtype mismatch \
                             (got f32 {:?}, want {:?} {:?})",
                            self.name, t.shape, sig.dtype, sig.shape
                        );
                    }
                    InBuf::Owned(
                        self.client
                            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)?,
                    )
                }
                Operand::I32(t) => {
                    if sig.dtype != DType::I32 || t.shape != sig.shape {
                        bail!(
                            "segment {} operand {i}: shape/dtype mismatch \
                             (got i32 {:?}, want {:?} {:?})",
                            self.name, t.shape, sig.dtype, sig.shape
                        );
                    }
                    InBuf::Owned(
                        self.client
                            .buffer_from_host_buffer::<i32>(&t.data, &t.shape, None)?,
                    )
                }
                Operand::I8(t) => {
                    if sig.dtype != DType::I8 || t.shape != sig.shape {
                        bail!(
                            "segment {} operand {i}: shape/dtype mismatch \
                             (got i8 {:?}, want {:?} {:?})",
                            self.name, t.shape, sig.dtype, sig.shape
                        );
                    }
                    InBuf::Owned(
                        self.client
                            .buffer_from_host_buffer::<i8>(&t.data, &t.shape, None)?,
                    )
                }
                Operand::Lit(l) => InBuf::Owned(
                    self.client
                        .buffer_from_host_literal(None, l)
                        .with_context(|| format!("uploading literal operand {i}"))?,
                ),
                Operand::Buf(dt) => {
                    if dt.dtype != sig.dtype || dt.shape != sig.shape {
                        bail!(
                            "segment {} operand {i}: shape/dtype mismatch \
                             (got device {:?} {:?}, want {:?} {:?})",
                            self.name, dt.dtype, dt.shape, sig.dtype, sig.shape
                        );
                    }
                    InBuf::Ext(dt.buffer())
                }
            };
            bufs.push(buf);
        }
        Ok(bufs)
    }

    fn execute(&self, operands: &[Operand]) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        let bufs = self.input_buffers(operands)?;
        let refs: Vec<&xla::PjRtBuffer> = bufs
            .iter()
            .map(|b| match b {
                InBuf::Owned(x) => x,
                InBuf::Ext(r) => *r,
            })
            .collect();
        let out = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .with_context(|| format!("executing segment {}", self.name))?;
        drop(refs);
        drop(bufs); // reclaim freshly-uploaded input device buffers
        Ok(out)
    }

    /// Execute with signature checking; returns the decomposed outputs as
    /// host literals (the tuple root is downloaded and untupled; a bare
    /// root is downloaded directly).
    pub fn run(&self, operands: &[Operand]) -> Result<Vec<Literal>> {
        let out_bufs = self.execute(operands)?;
        let lit = out_bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {}", self.name))?;
        drop(out_bufs);
        let parts = if self.sig.tuple_root {
            lit.to_tuple()
                .with_context(|| format!("untupling output of {}", self.name))?
        } else {
            vec![lit]
        };
        if parts.len() != self.sig.outputs.len() {
            bail!(
                "segment {}: got {} outputs, expected {}",
                self.name,
                parts.len(),
                self.sig.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Execute a device-chainable segment, keeping its single output on
    /// the device (zero host transfer on the output side).
    pub fn run_device(&self, operands: &[Operand]) -> Result<DeviceTensor> {
        if !self.sig.device_chainable() {
            bail!(
                "segment {}: not device-chainable (tuple_root={}, {} outputs)",
                self.name,
                self.sig.tuple_root,
                self.sig.outputs.len()
            );
        }
        let mut out_bufs = self.execute(operands)?;
        let buf = out_bufs
            .get_mut(0)
            .and_then(|d| (!d.is_empty()).then(|| d.remove(0)))
            .with_context(|| format!("segment {}: no output buffer", self.name))?;
        Ok(DeviceTensor::wrap(buf, self.sig.outputs[0].shape.clone()))
    }

    /// Convenience: run and convert every output to a HostTensor using the
    /// manifest output shapes.
    pub fn run_host(&self, operands: &[Operand]) -> Result<Vec<HostTensor>> {
        let outs = self.run(operands)?;
        outs.iter()
            .zip(&self.sig.outputs)
            .map(|(lit, sig)| HostTensor::from_literal(lit, &sig.shape))
            .collect()
    }
}

/// Cumulative per-segment execution stats (the L3 profile in §Perf).
/// Upload counters make the device-residency win observable: with the
/// cache warm, `uploads`/`upload_bytes` scale with the *trainable* tensor
/// set only while `buf_hits` counts operands served from device.
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub total_ns: u128,
    /// Host→device operand transfers performed (F32/I32/Lit operands).
    pub uploads: u64,
    pub upload_bytes: u64,
    /// Operands that were already device-resident (`Operand::Buf`).
    pub buf_hits: u64,
}

/// Interned segment handle: index into the runtime's slot table. Resolve
/// once (`Runtime::seg_id`), then every `run_id` call is a vector index —
/// no `String` allocation or map lookup on the hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegId(usize);

/// Output of [`Runtime::run_chained`]: the single output stayed on device,
/// or the host literals of a tuple-rooted segment.
pub enum ChainVal {
    Dev(DeviceTensor),
    Host(Vec<Literal>),
}

struct SegSlot {
    name: String,
    seg: Option<Rc<Segment>>,
    stats: ExecStats,
}

/// The runtime: one PJRT CPU client + compiled segment cache.
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    pub backend: String,
    ids: RefCell<BTreeMap<String, SegId>>,
    slots: RefCell<Vec<SegSlot>>,
    /// Deterministic fault injection (armed from `LISA_FAULT` or
    /// [`Runtime::set_fault_plan`]); shared with the page allocator.
    fault: Rc<RefCell<FaultInjector>>,
}

impl Runtime {
    /// `artifacts_dir` is e.g. `artifacts/tiny`; `backend` is `pallas`/`jnp`.
    pub fn load(artifacts_dir: &Path, backend: &str) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "runtime: config={} platform={} devices={} backend={backend}",
            manifest.name,
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            client,
            manifest,
            backend: backend.to_string(),
            ids: RefCell::new(BTreeMap::new()),
            slots: RefCell::new(Vec::new()),
            fault: Rc::new(RefCell::new(FaultInjector::from_env())),
        })
    }

    /// Replace the armed fault plans (tests / `--fault`). An empty spec
    /// disarms injection.
    pub fn set_fault_plan(&self, spec: &str) -> Result<()> {
        *self.fault.borrow_mut() = FaultInjector::parse(spec)?;
        Ok(())
    }

    /// Shared handle to the injector, for wiring into the page allocator.
    pub fn fault_handle(&self) -> Rc<RefCell<FaultInjector>> {
        self.fault.clone()
    }

    /// Consult the injector before executing segment `id`.
    fn check_fault(&self, id: SegId) -> Result<()> {
        let mut f = self.fault.borrow_mut();
        if f.is_empty() {
            return Ok(());
        }
        let name = self.slots.borrow()[id.0].name.clone();
        match f.on_segment(&name) {
            Some(e) => Err(e.into()),
            None => Ok(()),
        }
    }

    /// Intern a segment name (no compilation; that stays lazy).
    pub fn seg_id(&self, name: &str) -> SegId {
        if let Some(&id) = self.ids.borrow().get(name) {
            return id;
        }
        let mut slots = self.slots.borrow_mut();
        let id = SegId(slots.len());
        slots.push(SegSlot {
            name: name.to_string(),
            seg: None,
            stats: ExecStats::default(),
        });
        self.ids.borrow_mut().insert(name.to_string(), id);
        id
    }

    fn compile(&self, name: &str) -> Result<Rc<Segment>> {
        let sig = self.manifest.segment(name, &self.backend)?.clone();
        let path = self.manifest.hlo_path(&sig);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        log::debug!(
            "compiled {name}.{} in {:.2}s",
            self.backend,
            t0.elapsed().as_secs_f64()
        );
        Ok(Rc::new(Segment {
            name: name.to_string(),
            sig,
            exe,
            client: self.client.clone(),
        }))
    }

    /// Get (compiling + caching on first use) a segment executable.
    pub fn segment(&self, name: &str) -> Result<Rc<Segment>> {
        self.segment_by_id(self.seg_id(name))
    }

    pub fn segment_by_id(&self, id: SegId) -> Result<Rc<Segment>> {
        if let Some(seg) = &self.slots.borrow()[id.0].seg {
            return Ok(seg.clone());
        }
        let name = self.slots.borrow()[id.0].name.clone();
        let seg = self.compile(&name)?;
        self.slots.borrow_mut()[id.0].seg = Some(seg.clone());
        Ok(seg)
    }

    fn record(&self, id: SegId, operands: &[Operand], dt_ns: u128) {
        let mut slots = self.slots.borrow_mut();
        let e = &mut slots[id.0].stats;
        e.calls += 1;
        e.total_ns += dt_ns;
        for op in operands {
            match op {
                Operand::F32(t) => {
                    e.uploads += 1;
                    e.upload_bytes += t.bytes() as u64;
                }
                Operand::I32(t) => {
                    e.uploads += 1;
                    e.upload_bytes += t.bytes() as u64;
                }
                Operand::I8(t) => {
                    e.uploads += 1;
                    e.upload_bytes += t.bytes() as u64;
                }
                Operand::Lit(l) => {
                    e.uploads += 1;
                    e.upload_bytes += (l.element_count() * 4) as u64;
                }
                Operand::Buf(_) => e.buf_hits += 1,
            }
        }
    }

    /// Execute an interned segment, outputs as host literals.
    pub fn run_id(&self, id: SegId, operands: &[Operand]) -> Result<Vec<Literal>> {
        self.check_fault(id)?;
        let seg = self.segment_by_id(id)?;
        let t0 = Instant::now();
        let out = seg.run(operands)?;
        self.record(id, operands, t0.elapsed().as_nanos());
        Ok(out)
    }

    /// Execute an interned segment, keeping a chainable output on device
    /// when the artifact allows it (falling back to host literals for
    /// tuple-rooted/legacy artifacts).
    pub fn run_chained(&self, id: SegId, operands: &[Operand]) -> Result<ChainVal> {
        self.check_fault(id)?;
        let seg = self.segment_by_id(id)?;
        let t0 = Instant::now();
        let out = if seg.sig.device_chainable() {
            ChainVal::Dev(seg.run_device(operands)?)
        } else {
            ChainVal::Host(seg.run(operands)?)
        };
        self.record(id, operands, t0.elapsed().as_nanos());
        Ok(out)
    }

    /// Execute a segment by name, with timing stats.
    pub fn run(&self, name: &str, operands: &[Operand]) -> Result<Vec<Literal>> {
        self.run_id(self.seg_id(name), operands)
    }

    pub fn stats(&self) -> BTreeMap<String, ExecStats> {
        self.slots
            .borrow()
            .iter()
            .filter(|s| s.stats.calls > 0)
            .map(|s| (s.name.clone(), s.stats.clone()))
            .collect()
    }

    pub fn reset_stats(&self) {
        for s in self.slots.borrow_mut().iter_mut() {
            s.stats = ExecStats::default();
        }
    }

    /// Pre-compile a list of segments (warm start before timed runs).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.segment(n)?;
        }
        Ok(())
    }
}
