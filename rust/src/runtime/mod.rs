//! PJRT runtime layer: load AOT HLO-text artifacts and execute them from
//! the Rust training hot path (Python is never on this path).

pub mod artifacts;
pub mod client;
pub mod tensor;

pub use artifacts::{DType, Manifest, SegmentSig, TensorSig};
pub use client::{ExecStats, Operand, Runtime, Segment};
pub use tensor::{numel, HostTensor, HostTensorI32};
