//! PJRT runtime layer: load AOT HLO-text artifacts and execute them from
//! the Rust training hot path (Python is never on this path).
//!
//! Data-movement contract (DESIGN.md §8): parameters are uploaded once and
//! cached as device buffers ([`DeviceCache`]); chained activations flow
//! between segments as [`DeviceTensor`]s via [`ChainVal`]; the host only
//! ever downloads what it consumes (loss scalars, gradients).

pub mod artifacts;
pub mod client;
pub mod device_cache;
pub mod fault;
pub mod tensor;

pub use artifacts::{
    DType, Manifest, SegmentSig, TensorSig, DECODE_ABI, DECODE_SEGMENTS, PAGED_ABI, PAGED_SEGMENTS,
    QUANT_DECODE_SEGMENTS, QUANT_MODE, QUANT_PAGED_SEGMENTS, QUANT_SEGMENTS,
};
pub use client::{ChainVal, ExecStats, Operand, Runtime, SegId, Segment};
pub use device_cache::{CacheStats, DeviceCache, CLASS_F32, CLASS_I8};
pub use fault::{FaultError, FaultInjector, FaultKind, FaultPlan};
pub use tensor::{numel, DeviceTensor, HostTensor, HostTensorI32, HostTensorI8};
