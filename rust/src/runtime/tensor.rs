//! Host-side tensors and the bridge to `xla::Literal`.
//!
//! The coordinator keeps all training state (parameters, optimizer moments,
//! activation stash) as [`HostTensor`]s — plain shaped `Vec<f32>` /
//! `Vec<i32>` buffers — and converts to/from PJRT literals at executable
//! boundaries. Buffers are reused across steps by the engine; conversion is
//! a memcpy, never a reshape/copy chain.

use std::rc::Rc;

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, PjRtClient};

/// Dense float32 host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Dense int32 host tensor (token ids / targets).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

/// Dense int8 host tensor (quantized frozen weights, DESIGN.md §15).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensorI8 {
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        HostTensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Convert to an `xla::Literal` (memcpy of the raw buffer).
    pub fn to_literal(&self) -> Result<Literal> {
        // SAFETY: viewing the f32 buffer as its own bytes — same
        // allocation, same length, stricter source alignment, lifetime
        // bound to `&self` for the duration of the copy below.
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const u8,
                self.data.len() * 4,
            )
        };
        Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &self.shape,
            bytes,
        )
        .context("creating f32 literal")
    }

    /// Read back from a literal, checking dtype and element count.
    pub fn from_literal(lit: &Literal, shape: &[usize]) -> Result<Self> {
        let n = numel(shape);
        if lit.element_count() != n {
            bail!(
                "literal has {} elements, expected {} for shape {:?}",
                lit.element_count(),
                n,
                shape
            );
        }
        let data = lit.to_vec::<f32>().context("reading f32 literal")?;
        Ok(HostTensor { shape: shape.to_vec(), data })
    }

    /// Read a scalar f32 from a rank-0/1-element literal.
    pub fn scalar_from_literal(lit: &Literal) -> Result<f32> {
        let v = lit.to_vec::<f32>().context("reading scalar literal")?;
        if v.len() != 1 {
            bail!("expected scalar literal, got {} elements", v.len());
        }
        Ok(v[0])
    }

    /// In-place elementwise add (gradient accumulation).
    pub fn add_assign(&mut self, other: &HostTensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// In-place scale (gradient averaging across microbatches).
    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    pub fn l2_norm(&self) -> f64 {
        crate::util::stats::l2_norm(&self.data)
    }
}

/// A shaped f32 tensor resident on the PJRT device.
///
/// Holds the underlying `PjRtBuffer` behind an `Rc` so the device cache
/// and in-flight operand lists can share one upload; dropping the last
/// clone releases the device memory. This is the currency of the
/// device-resident hot path: weights live here between steps
/// (`runtime::DeviceCache`) and the residual stream `h`/`dh` flows between
/// segments as `Operand::Buf` without a host round-trip.
#[derive(Clone)]
pub struct DeviceTensor {
    pub shape: Vec<usize>,
    /// Element dtype of the resident buffer. `F32` for every activation
    /// and full-precision weight; `I8` for quantized frozen weights
    /// (DESIGN.md §15) — what makes `bytes()` count real device bytes.
    pub dtype: crate::runtime::artifacts::DType,
    buf: Rc<xla::PjRtBuffer>,
}

impl std::fmt::Debug for DeviceTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DeviceTensor{:?}/{:?}", self.shape, self.dtype)
    }
}

impl DeviceTensor {
    /// Upload a host tensor (one memcpy host→device).
    pub fn from_host(client: &PjRtClient, t: &HostTensor) -> Result<DeviceTensor> {
        let buf = client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .context("uploading host tensor to device")?;
        Ok(DeviceTensor {
            shape: t.shape.clone(),
            dtype: crate::runtime::artifacts::DType::F32,
            buf: Rc::new(buf),
        })
    }

    /// Upload a quantized int8 host tensor (one memcpy, a quarter of the
    /// f32 bytes — the residency win of DESIGN.md §15).
    pub fn from_host_i8(client: &PjRtClient, t: &HostTensorI8) -> Result<DeviceTensor> {
        let buf = client
            .buffer_from_host_buffer::<i8>(&t.data, &t.shape, None)
            .context("uploading i8 host tensor to device")?;
        Ok(DeviceTensor {
            shape: t.shape.clone(),
            dtype: crate::runtime::artifacts::DType::I8,
            buf: Rc::new(buf),
        })
    }

    /// Adopt an execution output buffer (no transfer at all). Segment
    /// outputs are always f32 in this ABI.
    pub(crate) fn wrap(buf: xla::PjRtBuffer, shape: Vec<usize>) -> DeviceTensor {
        DeviceTensor {
            shape,
            dtype: crate::runtime::artifacts::DType::F32,
            buf: Rc::new(buf),
        }
    }

    pub fn buffer(&self) -> &xla::PjRtBuffer {
        &self.buf
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    /// Real device bytes: dtype-sized, so an i8 resident tensor counts a
    /// quarter of its f32 twin.
    pub fn bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }

    /// Download to a host literal (the only host transfer the device flow
    /// ever pays for a chained tensor — and only when the host asks).
    pub fn to_literal(&self) -> Result<Literal> {
        self.buf
            .to_literal_sync()
            .context("downloading device tensor")
    }

    pub fn to_host(&self) -> Result<HostTensor> {
        HostTensor::from_literal(&self.to_literal()?, &self.shape)
    }
}

impl HostTensorI8 {
    pub fn zeros(shape: &[usize]) -> Self {
        HostTensorI8 { shape: shape.to_vec(), data: vec![0; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i8>) -> Self {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        HostTensorI8 { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// One byte per element — the point of the format.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    pub fn to_literal(&self) -> Result<Literal> {
        // SAFETY: as for `HostTensor::to_literal` — an i8 buffer viewed
        // as its own bytes for the duration of the copy (i8 -> u8 is a
        // same-size, same-alignment reinterpretation).
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len())
        };
        Literal::create_from_shape_and_untyped_data(ElementType::S8, &self.shape, bytes)
            .context("creating s8 literal")
    }
}

impl HostTensorI32 {
    pub fn zeros(shape: &[usize]) -> Self {
        HostTensorI32 { shape: shape.to_vec(), data: vec![0; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        HostTensorI32 { shape: shape.to_vec(), data }
    }

    pub fn to_literal(&self) -> Result<Literal> {
        // SAFETY: as for `HostTensor::to_literal` — an i32 buffer viewed
        // as its own bytes for the duration of the copy.
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const u8,
                self.data.len() * 4,
            )
        };
        Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &self.shape,
            bytes,
        )
        .context("creating s32 literal")
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_numel() {
        let t = HostTensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.bytes(), 96);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn accumulate_and_scale() {
        let mut a = HostTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = HostTensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        a.add_assign(&b);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.0, 1.5, 2.0, 2.5]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let mut a = HostTensor::zeros(&[2]);
        a.add_assign(&HostTensor::zeros(&[3]));
    }

    // Literal round-trips are covered by integration tests (they need the
    // PJRT shared library at runtime).
}
