//! # LISA — Layerwise Importance Sampled AdamW (NeurIPS 2024) in Rust+JAX+Pallas
//!
//! Reproduction of Pan et al., *"LISA: Layerwise Importance Sampling for
//! Memory-Efficient Large Language Model Fine-Tuning"* as a three-layer
//! stack: Pallas kernels (L1) and JAX segment functions (L2) are AOT-lowered
//! to HLO-text artifacts at build time; this crate (L3) owns the entire
//! training runtime — the layer-granular forward/backward engine, the
//! strategy layer (every fine-tuning method behind one trait + registry,
//! see `strategy::`), the LISA sampler, optimizers (AdamW / GaLore / LoRA
//! adapters), synthetic corpora, evaluation, the memory model and the
//! experiment harness reproducing every table and figure of the paper.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the results.

pub mod util;
pub mod runtime;
pub mod model;
pub mod engine;
pub mod lisa;
pub mod opt;
pub mod lora;
pub mod data;
pub mod eval;
pub mod serve_http;
pub mod strategy;
pub mod train;
pub mod membench;
pub mod exp;
