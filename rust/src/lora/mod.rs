//! LoRA baseline (Hu et al. 2022): rank-`r` adapters on every linear layer
//! of every block (q/k/v/o/w1/w2 — the paper's "all linear layers" setup),
//! base weights frozen, B-zero init so training starts at the base model.
//!
//! The adapters ride dedicated artifacts (`block_fwd_lora` /
//! `block_bwd_lora`) whose backward produces gradients *only* for A/B —
//! the base-weight gradient matmuls are never emitted, which is LoRA's
//! compute/memory profile done honestly rather than masked.

use anyhow::{Context, Result};

use crate::engine::trainer::ParamOp;
use crate::engine::{Batch, Engine, MemCategory};
use crate::model::{ModelParams, ParamKey};
use crate::opt::linalg::matmul_nn;
use crate::opt::AdamW;
use crate::runtime::{HostTensor, Manifest, Operand};

/// Which block tensor each (A, B) adapter pair merges into:
/// (aq,bq)->wq, (ak,bk)->wk, (av,bv)->wv, (ao,bo)->wo, (a1,b1)->w1,
/// (a2,b2)->w2 — indices in the block ABI order (g1,wq,wk,wv,wo,g2,w1,w2).
pub const ADAPTER_TARGETS: [usize; 6] = [1, 2, 3, 4, 6, 7];

#[derive(Debug)]
pub struct LoraState {
    /// `adapters[l]` = the 12 tensors (aq,bq,...,a2,b2) of layer `l`.
    pub adapters: Vec<Vec<HostTensor>>,
    pub rank: usize,
    pub alpha: f64,
    /// Store-generation id for the engine's device cache (same contract
    /// as `ModelParams::store_id`).
    store_id: u64,
}

impl Clone for LoraState {
    fn clone(&self) -> Self {
        LoraState {
            adapters: self.adapters.clone(),
            rank: self.rank,
            alpha: self.alpha,
            store_id: crate::model::params::next_store_id(),
        }
    }
}

impl LoraState {
    /// A ~ N(0, 1/r), B = 0 (the reference init: ΔW = 0 at step 0).
    pub fn init(m: &Manifest, rng: &mut crate::util::rng::Rng) -> LoraState {
        let std = 1.0 / (m.lora_rank as f32);
        let mut adapters = Vec::with_capacity(m.n_layers);
        for _ in 0..m.n_layers {
            let mut layer = Vec::with_capacity(m.lora_params.len());
            for (name, shape) in &m.lora_params {
                let mut t = HostTensor::zeros(shape);
                if name.starts_with('a') {
                    rng.fill_normal(&mut t.data, std);
                }
                layer.push(t);
            }
            adapters.push(layer);
        }
        LoraState {
            adapters,
            rank: m.lora_rank,
            alpha: m.lora_alpha,
            store_id: crate::model::params::next_store_id(),
        }
    }

    pub fn store_id(&self) -> u64 {
        self.store_id
    }

    /// Every adapter's cache key — what a LoRA optimizer step mutates
    /// (the `Touched` report of `strategy::LoraStrategy::apply`).
    pub fn touched_keys(&self) -> Vec<ParamKey> {
        self.adapters
            .iter()
            .enumerate()
            .flat_map(|(l, layer)| (0..layer.len()).map(move |i| ParamKey::Lora(l, i)))
            .collect()
    }

    pub fn scaling(&self) -> f32 {
        (self.alpha / self.rank as f64) as f32
    }

    pub fn n_params(&self) -> usize {
        self.adapters.iter().flatten().map(|t| t.numel()).sum()
    }

    pub fn bytes(&self) -> u64 {
        (self.n_params() * 4) as u64
    }

    /// Merge adapters back into the base weights (LoRA's deploy move):
    /// `W += scale * A @ B` for each adapted linear.
    pub fn merge_into(&self, params: &mut ModelParams) {
        let s = self.scaling();
        for (l, layer) in self.adapters.iter().enumerate() {
            for (pair, &target) in ADAPTER_TARGETS.iter().enumerate() {
                let a = &layer[2 * pair];
                let b = &layer[2 * pair + 1];
                let (din, r) = (a.shape[0], a.shape[1]);
                let dout = b.shape[1];
                let delta = matmul_nn(&a.data, &b.data, din, r, dout);
                let w = &mut params.blocks[l][target];
                assert_eq!(w.shape, vec![din, dout]);
                for (wi, di) in w.data.iter_mut().zip(&delta) {
                    *wi += s * di;
                }
            }
        }
    }
}

/// Adapter gradients: `grads[l]` mirrors `LoraState.adapters[l]`.
pub type LoraGrads = Vec<Vec<HostTensor>>;

pub fn lora_grads_bytes(g: &LoraGrads) -> u64 {
    g.iter().flatten().map(|t| t.bytes() as u64).sum()
}

pub fn lora_grads_add_assign(a: &mut LoraGrads, b: &LoraGrads) {
    assert_eq!(a.len(), b.len());
    for (la, lb) in a.iter_mut().zip(b) {
        for (x, y) in la.iter_mut().zip(lb) {
            x.add_assign(y);
        }
    }
}

pub fn lora_grads_scale(g: &mut LoraGrads, s: f32) {
    for layer in g.iter_mut() {
        for t in layer {
            t.scale(s);
        }
    }
}

/// LoRA forward + backward over the whole model (base weights and
/// embed/head frozen; returns loss + adapter grads).
///
/// Under the device flow the frozen base weights are the best possible
/// cache customers: they are *never* invalidated, so after the first
/// microbatch only the adapters (invalidated once per optimizer step) and
/// the token batch ever cross the host→device boundary.
pub fn forward_backward_lora(
    eng: &mut Engine,
    params: &ModelParams,
    lora: &LoraState,
    batch: &Batch,
) -> Result<(f32, LoraGrads)> {
    let rt = eng.rt;
    let m = &rt.manifest;
    let ids = eng.ids;
    let hs = vec![m.batch, m.seq, m.d_model];
    eng.meter.set(MemCategory::Params, params.bytes() as u64);
    eng.meter.set(MemCategory::LoraAdapters, lora.bytes());
    // Forward, stashing block inputs. The whole base is frozen, so with
    // quantization on every base group routes through its q8 twin.
    let eid = if eng.q8_embed() { ids.embed_fwd_q8 } else { ids.embed_fwd };
    let ep = eng.embed_ops(params)?;
    let mut ops = vec![Operand::I32(&batch.tokens)];
    for p in &ep {
        p.push_operands(&mut ops);
    }
    let mut h = eng.run_chain_act(eid, &ops, &hs)?;
    drop(ops);
    let mut stash = Vec::with_capacity(m.n_layers);
    let mut act = 0u64;
    for l in 0..m.n_layers {
        act += h.bytes() as u64;
        eng.meter.set(MemCategory::Activations, act);
        let h_next = {
            let fid = if eng.q8_block(l) { ids.block_fwd_lora_q8 } else { ids.block_fwd_lora };
            let base = eng.block_ops(params, l)?;
            let adap = eng.adapter_ops(lora, l)?;
            let mut ops = vec![h.operand()];
            for p in &base {
                p.push_operands(&mut ops);
            }
            for p in &adap {
                p.push_operands(&mut ops);
            }
            eng.run_chain_act(fid, &ops, &hs)?
        };
        stash.push(h);
        h = h_next;
    }

    // Frozen head: loss + dh only.
    let hid = if eng.q8_head() { ids.head_fwd_bwd_x_q8 } else { ids.head_fwd_bwd_x };
    let ho = eng.head_ops(params)?;
    let outs = {
        let mut ops = vec![h.operand()];
        for p in &ho {
            p.push_operands(&mut ops);
        }
        ops.push(Operand::I32(&batch.targets));
        rt.run_id(hid, &ops)?
    };
    let mut it = outs.into_iter();
    let loss = HostTensor::scalar_from_literal(&it.next().context("head: missing loss")?)?;
    let dh_lit = it.next().context("head: missing dh")?;
    drop(it);
    let mut dh = eng.act_from_literal(dh_lit, &hs)?;

    // Backward: adapter grads in every block; stop after block 0 (embedding
    // is frozen in LoRA mode, so d(embed) is never needed).
    let mut grads: LoraGrads = Vec::with_capacity(m.n_layers);
    grads.resize_with(m.n_layers, Vec::new);
    let mut grad_bytes = 0u64;
    for l in (0..m.n_layers).rev() {
        let outs = {
            let bid = if eng.q8_block(l) { ids.block_bwd_lora_q8 } else { ids.block_bwd_lora };
            let base = eng.block_ops(params, l)?;
            let adap = eng.adapter_ops(lora, l)?;
            let mut ops = vec![dh.operand(), stash[l].operand()];
            for p in &base {
                p.push_operands(&mut ops);
            }
            for p in &adap {
                p.push_operands(&mut ops);
            }
            rt.run_id(bid, &ops)?
        };
        let mut it = outs.into_iter();
        let new_dh_lit = it.next().context("bwd_lora: missing dh")?;
        let mut layer_grads = Vec::with_capacity(m.lora_params.len());
        for (o, (_, shape)) in it.zip(&m.lora_params) {
            layer_grads.push(HostTensor::from_literal(&o, shape)?);
        }
        grad_bytes += layer_grads.iter().map(|t| t.bytes() as u64).sum::<u64>();
        eng.meter.set(MemCategory::Grads, grad_bytes);
        grads[l] = layer_grads;
        dh = eng.act_from_literal(new_dh_lit, &hs)?;
    }
    eng.meter.set(MemCategory::Activations, 0);
    Ok((loss, grads))
}

/// Apply adapter gradients with AdamW (every adapter is a decayed matrix).
pub fn apply_lora_grads(opt: &mut AdamW, lora: &mut LoraState, grads: &LoraGrads) {
    for (l, (layer, gs)) in lora.adapters.iter_mut().zip(grads).enumerate() {
        for (t, (a, g)) in layer.iter_mut().zip(gs).enumerate() {
            opt.step(ParamKey::Lora(l, t), true, &mut a.data, &g.data);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::path::Path;

    fn tiny_manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn init_b_zero_a_nonzero() {
        let Some(m) = tiny_manifest() else { return };
        let lora = LoraState::init(&m, &mut Rng::new(1));
        // even indices are A (nonzero), odd are B (zero)
        assert!(lora.adapters[0][0].data.iter().any(|&x| x != 0.0));
        assert!(lora.adapters[0][1].data.iter().all(|&x| x == 0.0));
        assert_eq!(lora.adapters.len(), m.n_layers);
    }

    #[test]
    fn merge_with_zero_b_is_identity() {
        let Some(m) = tiny_manifest() else { return };
        let mut rng = Rng::new(2);
        let mut params = ModelParams::init(&m, &mut rng);
        let before = params.blocks[0][1].data.clone();
        let lora = LoraState::init(&m, &mut rng);
        lora.merge_into(&mut params);
        assert_eq!(params.blocks[0][1].data, before);
    }

    #[test]
    fn merge_applies_scaled_delta() {
        let Some(m) = tiny_manifest() else { return };
        let mut rng = Rng::new(3);
        let mut params = ModelParams::init(&m, &mut rng);
        let mut lora = LoraState::init(&m, &mut rng);
        // set B = 1 everywhere for layer 0, pair 0 (wq)
        lora.adapters[0][1].fill(1.0);
        let before = params.blocks[0][1].data.clone();
        lora.merge_into(&mut params);
        let after = &params.blocks[0][1].data;
        let changed = after.iter().zip(&before).filter(|(a, b)| a != b).count();
        assert!(changed > 0, "merge must change wq");
        // other layers untouched
        assert_eq!(params.blocks[1][1].data,
                   ModelParams::init(&m, &mut Rng::new(3)).blocks[1][1].data);
    }

    #[test]
    fn grad_helpers() {
        let g1: LoraGrads = vec![vec![HostTensor::from_vec(&[2], vec![1.0, 2.0])]];
        let mut g2 = g1.clone();
        lora_grads_add_assign(&mut g2, &g1);
        lora_grads_scale(&mut g2, 0.5);
        assert_eq!(g2[0][0].data, vec![1.0, 2.0]);
        assert_eq!(lora_grads_bytes(&g2), 8);
    }
}
