//! Dense f32 linear algebra for the GaLore projector (no BLAS crate in the
//! image). Matrices are row-major `&[f32]` with explicit dims. Sizes here
//! are small (projection ranks ≤ 64, model dims ≤ a few thousand), so a
//! cache-blocked naive kernel is adequate; the training FLOPs live in XLA.

/// c[m,n] = a[m,k] @ b[k,n]
pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// c[k,n] = a[m,k]^T @ b[m,n]
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    let mut c = vec![0f32; k * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// c[m,k] = a[m,n] @ b[k,n]^T
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0f32; m * k];
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        for j in 0..k {
            let brow = &b[j * n..(j + 1) * n];
            let mut s = 0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                s += x * y;
            }
            c[i * k + j] = s;
        }
    }
    c
}

/// In-place modified Gram–Schmidt on the columns of q[m,r] (row-major).
/// Degenerate columns are replaced by deterministic unit vectors.
pub fn orthonormalize_columns(q: &mut [f32], m: usize, r: usize) {
    assert_eq!(q.len(), m * r);
    for j in 0..r {
        // subtract projections on previous columns
        for p in 0..j {
            let mut dot = 0f32;
            for i in 0..m {
                dot += q[i * r + j] * q[i * r + p];
            }
            for i in 0..m {
                q[i * r + j] -= dot * q[i * r + p];
            }
        }
        let mut norm = 0f32;
        for i in 0..m {
            norm += q[i * r + j] * q[i * r + j];
        }
        let norm = norm.sqrt();
        if norm > 1e-8 {
            for i in 0..m {
                q[i * r + j] /= norm;
            }
        } else {
            // degenerate: deterministic basis vector e_{j mod m}
            for i in 0..m {
                q[i * r + j] = if i == j % m { 1.0 } else { 0.0 };
            }
            // re-orthogonalize against previous columns once
            for p in 0..j {
                let mut dot = 0f32;
                for i in 0..m {
                    dot += q[i * r + j] * q[i * r + p];
                }
                for i in 0..m {
                    q[i * r + j] -= dot * q[i * r + p];
                }
            }
        }
    }
}

/// Top-`r` left-singular-subspace estimate of g[m,n] by subspace (block
/// power) iteration on G Gᵀ. Returns P[m,r] with orthonormal columns.
pub fn top_left_subspace(
    g: &[f32],
    m: usize,
    n: usize,
    r: usize,
    iters: usize,
    rng: &mut crate::util::rng::Rng,
) -> Vec<f32> {
    assert!(r <= m, "rank {r} > rows {m}");
    let mut q = vec![0f32; m * r];
    rng.fill_normal(&mut q, 1.0);
    orthonormalize_columns(&mut q, m, r);
    for _ in 0..iters {
        // z = Gᵀ q  : [n, r]
        let z = matmul_tn(g, &q, m, n, r);
        // q = G z   : [m, r]
        q = matmul_nn(g, &z, m, n, r);
        orthonormalize_columns(&mut q, m, r);
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_small_known() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let c = matmul_nn(&[1., 2., 3., 4.], &[5., 6., 7., 8.], 2, 2, 2);
        assert_eq!(c, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transposed_variants_agree() {
        let mut rng = Rng::new(1);
        let (m, k, n) = (5, 4, 3);
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; k * n];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let c = matmul_nn(&a, &b, m, k, n);
        // aT stored as [k,m]
        let mut at = vec![0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let c2 = matmul_tn(&at, &b, k, m, n);
        for (x, y) in c.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4);
        }
        // bT stored as [n,k]
        let mut bt = vec![0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        let c3 = matmul_nt(&a, &bt, m, k, n);
        for (x, y) in c.iter().zip(&c3) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gram_schmidt_orthonormal() {
        let mut rng = Rng::new(2);
        let (m, r) = (10, 4);
        let mut q = vec![0f32; m * r];
        rng.fill_normal(&mut q, 1.0);
        orthonormalize_columns(&mut q, m, r);
        for a in 0..r {
            for b in 0..r {
                let mut dot = 0f32;
                for i in 0..m {
                    dot += q[i * r + a] * q[i * r + b];
                }
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "({a},{b}) dot={dot}");
            }
        }
    }

    #[test]
    fn power_iteration_finds_dominant_subspace() {
        // G = u1 s1 v1ᵀ + u2 s2 v2ᵀ with s1 >> s2: P should span {e0, e1}.
        let (m, n) = (6, 8);
        let mut g = vec![0f32; m * n];
        for j in 0..n {
            g[0 * n + j] = 10.0 * ((j as f32) * 0.3).sin();
            g[1 * n + j] = 8.0 * ((j as f32) * 0.7).cos();
            g[4 * n + j] = 0.01 * ((j as f32) * 1.3).sin();
        }
        let mut rng = Rng::new(3);
        let p = top_left_subspace(&g, m, n, 2, 30, &mut rng);
        // Projector should capture nearly all the energy of rows 0 and 1.
        // energy of e0 within span(P): sum_j P[0,j]^2
        let e0: f32 = (0..2).map(|j| p[0 * 2 + j] * p[0 * 2 + j]).sum();
        let e1: f32 = (0..2).map(|j| p[1 * 2 + j] * p[1 * 2 + j]).sum();
        assert!(e0 > 0.99, "e0={e0}");
        assert!(e1 > 0.99, "e1={e1}");
    }
}
