//! Dense f32 linear algebra for the GaLore projector and the LoRA merge
//! (no BLAS crate in the image). Matrices are row-major `&[f32]` with
//! explicit dims.
//!
//! The kernels are cache-blocked and parallelized over `util::threadpool`
//! (output-row chunks per worker, k/i tiles inside), with one invariant
//! that the agreement tests pin down: **per output element, the
//! floating-point accumulation order is identical to the serial kernel**
//! — tiles only split loops, they never reorder a single element's
//! partial sums, and each worker owns a disjoint row range. So
//! `workers = 1` and `workers = N` are bit-identical, and GaLore /
//! LoRA-merge trajectories do not depend on the machine's core count.
//!
//! The old `av == 0.0` skip in the inner loops is gone: on dense
//! gradients the branch is pure misprediction cost, and `c += 0.0 * b`
//! is bit-identical to skipping for finite inputs.

use crate::util::threadpool;

/// k-dimension tile: keeps the active slice of `b` in cache while a
/// worker sweeps its rows.
const TILE: usize = 64;

fn auto_workers(flops: usize) -> usize {
    // Thread spawn/join costs ~10µs; only fan out when there is real work.
    if flops < (1 << 21) {
        1
    } else {
        threadpool::default_workers()
    }
}

/// Run `body(first_row, rows_chunk)` over disjoint row chunks of `c`.
fn par_rows<F>(c: &mut [f32], rows: usize, row_len: usize, workers: usize, body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(c.len(), rows * row_len);
    let parts = threadpool::chunks(rows, workers);
    if parts.len() <= 1 {
        body(0, c);
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = c;
        let mut row = 0;
        for (_, len) in parts {
            let (head, tail) = rest.split_at_mut(len * row_len);
            let body = &body;
            let first = row;
            scope.spawn(move || body(first, head));
            rest = tail;
            row += len;
        }
    });
}

/// c[m,n] = a[m,k] @ b[k,n]
pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_nn_with_workers(a, b, m, k, n, auto_workers(m * k * n))
}

pub fn matmul_nn_with_workers(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    workers: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0f32; m * n];
    par_rows(&mut c, m, n, workers, |r0, chunk| {
        let mut kk0 = 0;
        while kk0 < k {
            let kk1 = (kk0 + TILE).min(k);
            for (ri, crow) in chunk.chunks_mut(n).enumerate() {
                let arow = &a[(r0 + ri) * k..(r0 + ri + 1) * k];
                for (kk, &av) in arow.iter().enumerate().take(kk1).skip(kk0) {
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
            kk0 = kk1;
        }
    });
    c
}

/// c[k,n] = a[m,k]^T @ b[m,n]
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_tn_with_workers(a, b, m, k, n, auto_workers(m * k * n))
}

pub fn matmul_tn_with_workers(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    workers: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    let mut c = vec![0f32; k * n];
    par_rows(&mut c, k, n, workers, |k0, chunk| {
        let mut i0 = 0;
        while i0 < m {
            let i1 = (i0 + TILE).min(m);
            for (rk, crow) in chunk.chunks_mut(n).enumerate() {
                let kk = k0 + rk;
                for i in i0..i1 {
                    let av = a[i * k + kk];
                    let brow = &b[i * n..(i + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
            i0 = i1;
        }
    });
    c
}

/// c[m,k] = a[m,n] @ b[k,n]^T
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    matmul_nt_with_workers(a, b, m, n, k, auto_workers(m * n * k))
}

pub fn matmul_nt_with_workers(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    workers: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0f32; m * k];
    par_rows(&mut c, m, k, workers, |r0, chunk| {
        for (ri, crow) in chunk.chunks_mut(k).enumerate() {
            let arow = &a[(r0 + ri) * n..(r0 + ri + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let brow = &b[j * n..(j + 1) * n];
                let mut s = 0f32;
                for (&x, &y) in arow.iter().zip(brow) {
                    s += x * y;
                }
                *cv = s;
            }
        }
    });
    c
}

/// In-place modified Gram–Schmidt on the columns of q[m,r] (row-major).
/// Degenerate columns are replaced by deterministic unit vectors.
pub fn orthonormalize_columns(q: &mut [f32], m: usize, r: usize) {
    assert_eq!(q.len(), m * r);
    for j in 0..r {
        // subtract projections on previous columns
        for p in 0..j {
            let mut dot = 0f32;
            for i in 0..m {
                dot += q[i * r + j] * q[i * r + p];
            }
            for i in 0..m {
                q[i * r + j] -= dot * q[i * r + p];
            }
        }
        let mut norm = 0f32;
        for i in 0..m {
            norm += q[i * r + j] * q[i * r + j];
        }
        let norm = norm.sqrt();
        if norm > 1e-8 {
            for i in 0..m {
                q[i * r + j] /= norm;
            }
        } else {
            // degenerate: deterministic basis vector e_{j mod m}
            for i in 0..m {
                q[i * r + j] = if i == j % m { 1.0 } else { 0.0 };
            }
            // re-orthogonalize against previous columns once
            for p in 0..j {
                let mut dot = 0f32;
                for i in 0..m {
                    dot += q[i * r + j] * q[i * r + p];
                }
                for i in 0..m {
                    q[i * r + j] -= dot * q[i * r + p];
                }
            }
        }
    }
}

/// Top-`r` left-singular-subspace estimate of g[m,n] by subspace (block
/// power) iteration on G Gᵀ. Returns P[m,r] with orthonormal columns.
pub fn top_left_subspace(
    g: &[f32],
    m: usize,
    n: usize,
    r: usize,
    iters: usize,
    rng: &mut crate::util::rng::Rng,
) -> Vec<f32> {
    assert!(r <= m, "rank {r} > rows {m}");
    let mut q = vec![0f32; m * r];
    rng.fill_normal(&mut q, 1.0);
    orthonormalize_columns(&mut q, m, r);
    for _ in 0..iters {
        // z = Gᵀ q  : [n, r]
        let z = matmul_tn(g, &q, m, n, r);
        // q = G z   : [m, r]
        q = matmul_nn(g, &z, m, n, r);
        orthonormalize_columns(&mut q, m, r);
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_small_known() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let c = matmul_nn(&[1., 2., 3., 4.], &[5., 6., 7., 8.], 2, 2, 2);
        assert_eq!(c, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transposed_variants_agree() {
        let mut rng = Rng::new(1);
        let (m, k, n) = (5, 4, 3);
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; k * n];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let c = matmul_nn(&a, &b, m, k, n);
        // aT stored as [k,m]
        let mut at = vec![0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let c2 = matmul_tn(&at, &b, k, m, n);
        for (x, y) in c.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4);
        }
        // bT stored as [n,k]
        let mut bt = vec![0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        let c3 = matmul_nt(&a, &bt, m, k, n);
        for (x, y) in c.iter().zip(&c3) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    /// The satellite contract: threaded + tiled kernels are bit-identical
    /// to the single-worker kernel, across shapes that exercise partial
    /// tiles, uneven worker splits, zeros in the data, and the
    /// tall/wide/square cases GaLore feeds them.
    #[test]
    fn parallel_kernels_match_serial_bitwise() {
        let mut rng = Rng::new(42);
        let shapes = [
            (1usize, 1usize, 1usize),
            (7, 5, 3),
            (64, 64, 64),
            (130, 33, 70),   // partial k-tiles + uneven row split
            (3, 200, 17),    // fewer rows than workers
            (97, 128, 257),
        ];
        for &(m, k, n) in &shapes {
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; k * n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            // sprinkle exact zeros (the removed skip-branch case)
            for i in (0..a.len()).step_by(7) {
                a[i] = 0.0;
            }
            for workers in [2usize, 3, 8] {
                let s = matmul_nn_with_workers(&a, &b, m, k, n, 1);
                let p = matmul_nn_with_workers(&a, &b, m, k, n, workers);
                assert!(
                    s.iter().zip(&p).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "nn {m}x{k}x{n} diverges at {workers} workers"
                );
            }
            // tn: a stored [k_rows= m rows...] — reuse buffers with the
            // matching dims (a:[m,k] b:[m,n'] with n' = n)
            let mut b2 = vec![0f32; m * n];
            rng.fill_normal(&mut b2, 1.0);
            for workers in [2usize, 5] {
                let s = matmul_tn_with_workers(&a, &b2, m, k, n, 1);
                let p = matmul_tn_with_workers(&a, &b2, m, k, n, workers);
                assert!(
                    s.iter().zip(&p).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "tn {m}x{k}x{n} diverges at {workers} workers"
                );
            }
            let mut b3 = vec![0f32; n * k];
            rng.fill_normal(&mut b3, 1.0);
            for workers in [2usize, 5] {
                let s = matmul_nt_with_workers(&a, &b3, m, k, n, 1);
                let p = matmul_nt_with_workers(&a, &b3, m, k, n, workers);
                assert!(
                    s.iter().zip(&p).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "nt {m}x{k}x{n} diverges at {workers} workers"
                );
            }
        }
    }

    #[test]
    fn gram_schmidt_orthonormal() {
        let mut rng = Rng::new(2);
        let (m, r) = (10, 4);
        let mut q = vec![0f32; m * r];
        rng.fill_normal(&mut q, 1.0);
        orthonormalize_columns(&mut q, m, r);
        for a in 0..r {
            for b in 0..r {
                let mut dot = 0f32;
                for i in 0..m {
                    dot += q[i * r + a] * q[i * r + b];
                }
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "({a},{b}) dot={dot}");
            }
        }
    }

    #[test]
    fn power_iteration_finds_dominant_subspace() {
        // G = u1 s1 v1ᵀ + u2 s2 v2ᵀ with s1 >> s2: P should span {e0, e1}.
        let (m, n) = (6, 8);
        let mut g = vec![0f32; m * n];
        for j in 0..n {
            g[j] = 10.0 * ((j as f32) * 0.3).sin();
            g[n + j] = 8.0 * ((j as f32) * 0.7).cos();
            g[4 * n + j] = 0.01 * ((j as f32) * 1.3).sin();
        }
        let mut rng = Rng::new(3);
        let p = top_left_subspace(&g, m, n, 2, 30, &mut rng);
        // Projector should capture nearly all the energy of rows 0 and 1.
        // energy of e0 within span(P): sum_j P[0,j]^2
        let e0: f32 = (0..2).map(|j| p[j] * p[j]).sum();
        let e1: f32 = (0..2).map(|j| p[2 + j] * p[2 + j]).sum();
        assert!(e0 > 0.99, "e0={e0}");
        assert!(e1 > 0.99, "e1={e1}");
    }
}
