//! GaLore (Zhao et al. 2024) — the paper's strongest memory-efficient
//! baseline: gradients of 2-D parameters are projected into a rank-`r`
//! subspace, AdamW runs in that compact space, and the normalized update is
//! projected back. The projection basis is refreshed every
//! `update_proj_gap` steps from the current gradient's dominant subspace
//! (block power iteration — our from-scratch stand-in for the paper's SVD,
//! see `linalg::top_left_subspace`).
//!
//! Projection side follows the GaLore reference: project the *shorter*
//! dimension, so moments are `r × long_dim` instead of `m × n`.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::model::checkpoint::Section;
use crate::model::ParamKey;
use crate::util::rng::Rng;

use super::adamw::{adamw_chunk, AdamHp};
use super::linalg;

#[derive(Debug, Clone, Copy)]
pub struct GaloreHp {
    pub adam: AdamHp,
    pub rank: usize,
    pub update_proj_gap: usize,
    /// GaLore's α scale applied to the projected-back update.
    pub scale: f32,
    pub power_iters: usize,
}

impl Default for GaloreHp {
    fn default() -> Self {
        GaloreHp {
            adam: AdamHp::default(),
            rank: 32,
            update_proj_gap: 200,
            scale: 0.25,
            power_iters: 5,
        }
    }
}

#[derive(Debug)]
struct Slot {
    t: u64,
    /// Orthonormal basis of the projected (shorter) side: [short, r].
    proj: Vec<f32>,
    /// Step the projection was last refreshed.
    proj_step: u64,
    m: Vec<f32>,
    v: Vec<f32>,
}

#[derive(Debug)]
pub struct Galore {
    pub hp: GaloreHp,
    rng: Rng,
    state: BTreeMap<ParamKey, Slot>,
}

impl Galore {
    pub fn new(hp: GaloreHp, seed: u64) -> Self {
        Galore { hp, rng: Rng::new(seed), state: BTreeMap::new() }
    }

    /// One update for a 2-D tensor of shape [rows, cols]. 1-D tensors (norm
    /// gains) should be routed to a plain AdamW by the caller.
    pub fn step_matrix(
        &mut self,
        key: ParamKey,
        decay: bool,
        p: &mut [f32],
        g: &[f32],
        rows: usize,
        cols: usize,
    ) {
        assert_eq!(p.len(), rows * cols);
        assert_eq!(g.len(), rows * cols);
        let r = self.hp.rank.min(rows.min(cols));
        let left = rows <= cols; // project the shorter side
        let (_short, long) = if left { (rows, cols) } else { (cols, rows) };

        let refresh_gap = self.hp.update_proj_gap as u64;
        let need_new = !self.state.contains_key(&key);
        if need_new {
            self.state.insert(
                key,
                Slot {
                    t: 0,
                    proj: Vec::new(),
                    proj_step: 0,
                    m: vec![0.0; r * long],
                    v: vec![0.0; r * long],
                },
            );
        }
        // Refresh projection from the *current* gradient if due.
        let refresh = {
            let slot = self.state.get(&key).unwrap();
            slot.proj.is_empty() || slot.t - slot.proj_step >= refresh_gap
        };
        if refresh {
            // Basis of the short side's dominant subspace of G.
            let basis = if left {
                linalg::top_left_subspace(g, rows, cols, r, self.hp.power_iters, &mut self.rng)
            } else {
                // right singular subspace of G = left subspace of Gᵀ;
                // build Gᵀ (cols x rows) explicitly (small: short ≤ long).
                let mut gt = vec![0f32; cols * rows];
                for i in 0..rows {
                    for j in 0..cols {
                        gt[j * rows + i] = g[i * cols + j];
                    }
                }
                linalg::top_left_subspace(&gt, cols, rows, r, self.hp.power_iters, &mut self.rng)
            };
            let slot = self.state.get_mut(&key).unwrap();
            // When the basis rotates, the old moments live in the old
            // coordinates; GaLore's reference keeps them (approximation) —
            // we do the same and note it in DESIGN.md §6.
            slot.proj = basis;
            slot.proj_step = slot.t;
        }

        let slot = self.state.get_mut(&key).unwrap();
        slot.t += 1;

        // Project: left: Gp = Pᵀ G [r, cols]; right: Gp = (G P)ᵀ [r, rows].
        let gp: Vec<f32> = if left {
            // proj: [rows, r]; want PᵀG: [r, cols]
            linalg::matmul_tn(&slot.proj, g, rows, r, cols)
        } else {
            // proj: [cols, r]; G P: [rows, r]; transpose to [r, rows]
            let gpr = linalg::matmul_nn(g, &slot.proj, rows, cols, r);
            let mut t = vec![0f32; r * rows];
            for i in 0..rows {
                for j in 0..r {
                    t[j * rows + i] = gpr[i * r + j];
                }
            }
            t
        };
        debug_assert_eq!(gp.len(), r * long);

        // AdamW in the projected space, writing the normalized update into
        // a scratch "parameter" initialized at zero: after one adamw step
        // from p=0 with wd=0, scratch = -lr * norm_update, so the
        // projected-back delta is scale * scratch.
        let mut scratch = vec![0f32; r * long];
        let mut hp = self.hp.adam;
        hp.weight_decay = 0.0;
        adamw_chunk(&mut scratch, &gp, &mut slot.m, &mut slot.v, &hp, false, slot.t);

        // Project back and apply: ΔW = scale * (P scratch) (left) or
        // scale * (scratch stored [r, rows])ᵀ P ᵀ ... assembled per side.
        if left {
            // P [rows, r] @ scratch [r, cols] -> [rows, cols]
            let delta = linalg::matmul_nn(&slot.proj, &scratch, rows, r, cols);
            for (pi, di) in p.iter_mut().zip(&delta) {
                *pi += self.hp.scale * di;
            }
        } else {
            // scratchᵀ [rows, r] @ projᵀ [r, cols]: compute rowsxcols
            // via (scratch [r, rows])ᵀ and proj [cols, r].
            let mut st = vec![0f32; rows * r];
            for j in 0..r {
                for i in 0..rows {
                    st[i * r + j] = scratch[j * rows + i];
                }
            }
            let delta = linalg::matmul_nt(&st, &slot.proj, rows, r, cols);
            for (pi, di) in p.iter_mut().zip(&delta) {
                *pi += self.hp.scale * di;
            }
        }

        // Decoupled weight decay in full space (matches GaLore + AdamW).
        if decay && self.hp.adam.weight_decay > 0.0 {
            let f = self.hp.adam.lr * self.hp.adam.weight_decay;
            for pi in p.iter_mut() {
                *pi -= f * *pi;
            }
        }
    }

    /// Drop per-block state (projected moments *and* projection basis) of
    /// blocks not in `live` — the GaLore side of LISA's
    /// `StatePolicy::Drop`. Non-block keys (embed/head) always survive.
    pub fn retain_blocks(&mut self, live: &[usize]) {
        self.state.retain(|k, _| match k {
            ParamKey::Block(l, _) => live.contains(l),
            _ => true,
        });
    }

    /// Optimizer-state bytes: rank-r moments (the GaLore memory win) plus
    /// the projection bases.
    pub fn state_bytes(&self) -> u64 {
        self.state
            .values()
            .map(|s| ((s.m.len() + s.v.len() + s.proj.len()) as u64) * 4)
            .sum()
    }

    pub fn n_slots(&self) -> usize {
        self.state.len()
    }

    /// Serialize the projector state: per-slot moments + basis + step
    /// counters, plus the basis-refresh RNG stream (resume protocol).
    /// Bases and moments are borrowed into the section — no copy.
    pub fn save_state<'a>(&'a self, sec: &mut Section<'a>, prefix: &str) {
        // the slots' proj/m/v layouts are rank-dependent; persist the rank
        // so resuming under a different --galore-rank fails loudly instead
        // of indexing garbage
        sec.put_u64(&format!("{prefix}hp.rank"), self.hp.rank as u64);
        sec.put_rng(&format!("{prefix}rng"), &self.rng);
        let keys: Vec<String> = self.state.keys().map(|k| k.name()).collect();
        sec.put_str(&format!("{prefix}keys"), &keys.join(","));
        for (k, s) in &self.state {
            let n = k.name();
            sec.put_u64(&format!("{prefix}{n}.t"), s.t);
            sec.put_u64(&format!("{prefix}{n}.proj_step"), s.proj_step);
            sec.put_f32s(&format!("{prefix}{n}.proj"), &s.proj);
            sec.put_f32s(&format!("{prefix}{n}.m"), &s.m);
            sec.put_f32s(&format!("{prefix}{n}.v"), &s.v);
        }
    }

    /// Restore the state written by [`Galore::save_state`], replacing any
    /// existing state. Slot layouts are validated against the configured
    /// rank and (where the oracle knows them) the parameter shapes, so an
    /// inconsistent checkpoint errors here instead of projecting garbage.
    pub fn load_state(
        &mut self,
        sec: &mut Section<'_>,
        prefix: &str,
        shape: super::ShapeFn<'_>,
    ) -> Result<()> {
        let rank = sec.take_u64(&format!("{prefix}hp.rank"))?;
        ensure!(
            rank == self.hp.rank as u64,
            "checkpoint GaLore rank {rank} != configured rank {}",
            self.hp.rank
        );
        self.rng = sec.take_rng(&format!("{prefix}rng"))?;
        self.state.clear();
        let keys = sec.take_str(&format!("{prefix}keys"))?;
        for n in keys.split(',').filter(|s| !s.is_empty()) {
            let key = ParamKey::parse(n)?;
            let t = sec.take_u64(&format!("{prefix}{n}.t"))?;
            let proj_step = sec.take_u64(&format!("{prefix}{n}.proj_step"))?;
            let proj = sec.take_f32s(&format!("{prefix}{n}.proj"))?;
            let m = sec.take_f32s(&format!("{prefix}{n}.m"))?;
            let v = sec.take_f32s(&format!("{prefix}{n}.v"))?;
            ensure!(
                m.len() == v.len(),
                "galore slot '{n}': m/v length mismatch ({} vs {})",
                m.len(),
                v.len()
            );
            ensure!(
                proj_step <= t,
                "galore slot '{n}': proj_step {proj_step} > t {t}"
            );
            if let Some(s) = shape(key) {
                ensure!(s.len() == 2, "galore slot '{n}': parameter is not 2-D");
                let (rows, cols) = (s[0], s[1]);
                let r = self.hp.rank.min(rows.min(cols));
                let (short, long) = (rows.min(cols), rows.max(cols));
                ensure!(
                    proj.len() == short * r && m.len() == r * long,
                    "galore slot '{n}': basis/moment sizes ({}, {}) don't fit a \
                     [{rows}, {cols}] parameter at rank {r}",
                    proj.len(),
                    m.len()
                );
            }
            self.state.insert(key, Slot { t, proj, proj_step, m, v });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_is_rank_r_not_full() {
        let hp = GaloreHp { rank: 4, ..Default::default() };
        let mut g = Galore::new(hp, 1);
        let (rows, cols) = (16, 64);
        let mut p = vec![0f32; rows * cols];
        let grad = vec![0.1f32; rows * cols];
        g.step_matrix(ParamKey::Block(0, 1), true, &mut p, &grad, rows, cols);
        // moments: 2 * r * long = 2*4*64 f32, proj: short*r = 16*4
        assert_eq!(g.state_bytes(), ((2 * 4 * 64 + 16 * 4) * 4) as u64);
    }

    #[test]
    fn descends_on_least_squares() {
        // f(W) = ||W - A||_F^2 / 2, grad = W - A. GaLore with rank >= rank(A)
        // should drive W toward A.
        let (rows, cols) = (8, 12);
        let mut a = vec![0f32; rows * cols];
        // rank-2 target
        for i in 0..rows {
            for j in 0..cols {
                a[i * cols + j] = (i as f32 * 0.5) + ((j % 3) as f32);
            }
        }
        let hp = GaloreHp {
            adam: AdamHp { lr: 0.05, weight_decay: 0.0, ..Default::default() },
            rank: 6,
            update_proj_gap: 20,
            scale: 1.0,
            power_iters: 10,
        };
        let mut g = Galore::new(hp, 2);
        let mut w = vec![0f32; rows * cols];
        let loss = |w: &[f32]| -> f32 {
            w.iter().zip(&a).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        let l0 = loss(&w);
        for _ in 0..400 {
            let grad: Vec<f32> = w.iter().zip(&a).map(|(x, y)| x - y).collect();
            g.step_matrix(ParamKey::Block(0, 1), false, &mut w, &grad, rows, cols);
        }
        let l1 = loss(&w);
        assert!(l1 < l0 * 0.05, "loss {l0} -> {l1}");
    }

    #[test]
    fn state_roundtrip_continues_bitwise_across_refresh() {
        // update_proj_gap=2 so the continuation crosses a basis refresh —
        // the restored RNG stream must reproduce the same power-iteration
        // draws the uninterrupted run makes.
        let hp = GaloreHp {
            adam: AdamHp { lr: 0.05, weight_decay: 0.01, ..Default::default() },
            rank: 3,
            update_proj_gap: 2,
            scale: 0.5,
            power_iters: 4,
        };
        let (rows, cols) = (6usize, 10usize);
        let mut rng = crate::util::rng::Rng::new(21);
        let mut p_a = vec![0f32; rows * cols];
        rng.fill_normal(&mut p_a, 0.5);
        let mut p_b = p_a.clone();
        let grads: Vec<Vec<f32>> = (0..7)
            .map(|_| {
                let mut g = vec![0f32; rows * cols];
                rng.fill_normal(&mut g, 0.1);
                g
            })
            .collect();

        let key = ParamKey::Block(1, 1);
        let mut a = Galore::new(hp, 5);
        let mut b = Galore::new(hp, 5);
        for g in &grads[..3] {
            a.step_matrix(key, true, &mut p_a, g, rows, cols);
            b.step_matrix(key, true, &mut p_b, g, rows, cols);
        }
        let mut sec = Section::new("strategy");
        a.save_state(&mut sec, "opt.galore.");
        let mut a2 = Galore::new(hp, 999); // wrong seed on purpose
        let shape = |_| Some(vec![rows, cols]);
        a2.load_state(&mut sec, "opt.galore.", &shape).unwrap();
        assert!(sec.is_empty(), "load must consume every entry");
        assert_eq!(a2.state_bytes(), b.state_bytes());
        for g in &grads[3..] {
            a2.step_matrix(key, true, &mut p_a, g, rows, cols);
            b.step_matrix(key, true, &mut p_b, g, rows, cols);
        }
        assert_eq!(p_a, p_b, "resumed GaLore must be bit-identical");
    }

    #[test]
    fn state_load_rejects_rank_mismatch() {
        let hp4 = GaloreHp { rank: 4, ..Default::default() };
        let mut a = Galore::new(hp4, 1);
        let (rows, cols) = (8usize, 12usize);
        let mut p = vec![0.1f32; rows * cols];
        let g = vec![0.1f32; rows * cols];
        a.step_matrix(ParamKey::Block(0, 1), true, &mut p, &g, rows, cols);
        let mut sec = Section::new("strategy");
        a.save_state(&mut sec, "opt.galore.");
        let mut b = Galore::new(GaloreHp { rank: 8, ..Default::default() }, 1);
        let err = b.load_state(&mut sec, "opt.galore.", &|_| None).unwrap_err();
        assert!(err.to_string().contains("rank"), "got: {err}");

        // same rank but a slot that doesn't fit the declared parameter
        let mut sec = Section::new("strategy");
        a.save_state(&mut sec, "opt.galore.");
        let mut c = Galore::new(hp4, 1);
        let err = c
            .load_state(&mut sec, "opt.galore.", &|_| Some(vec![20, 30]))
            .unwrap_err();
        assert!(err.to_string().contains("don't fit"), "got: {err}");
    }

    #[test]
    fn wide_and_tall_matrices_both_work() {
        let hp = GaloreHp { rank: 2, ..Default::default() };
        let mut g = Galore::new(hp, 3);
        for (rows, cols) in [(4usize, 10usize), (10, 4)] {
            let mut p = vec![0.5f32; rows * cols];
            let grad = vec![0.1f32; rows * cols];
            g.step_matrix(ParamKey::Block(rows, cols), false, &mut p, &grad, rows, cols);
            assert!(p.iter().all(|x| x.is_finite()));
            // gradient is rank-1 all-ones direction: update must be nonzero
            assert!(p.iter().any(|&x| (x - 0.5).abs() > 1e-6));
        }
    }
}
