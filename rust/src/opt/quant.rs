//! Per-output-channel int8 weight quantization (DESIGN.md §15).
//!
//! Frozen-base weights are stored and uploaded as `(i8 q, f32 scales)`
//! pairs: `scale[c] = absmax(w[:, c]) / 127`, `q = clip(rhe(w / scale),
//! -127, 127)` with round-half-even — bit-for-bit the convention of
//! `python/compile/kernels/quant.py`, which the q8 Pallas segments fuse
//! the dequant against. Only 2-D tensors quantize; 1-D norm gains stay
//! f32 at the call sites. Checkpoints NEVER contain quantized bytes —
//! quantization is a device-residency format, not a storage format.

use anyhow::{bail, Result};

use crate::runtime::tensor::{HostTensor, HostTensorI8};

/// A quantized host-side weight: int8 values + per-output-channel f32
/// scales over the last axis.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantTensor {
    pub q: HostTensorI8,
    pub s: HostTensor,
}

impl QuantTensor {
    /// Host bytes of the pair (what a device upload of both costs).
    pub fn bytes(&self) -> usize {
        self.q.bytes() + self.s.bytes()
    }
}

/// Device/upload bytes for a 2-D `[rows, cols]` tensor held as int8 +
/// per-channel scales: `rows*cols` q bytes + `cols*4` scale bytes. The
/// f32 twin costs `rows*cols*4`, so the shrink ratio is `4r / (r + 4)` —
/// ≥ 3.5x for every r ≥ 28, i.e. any real weight matrix.
pub fn quantized_bytes(shape: &[usize]) -> usize {
    assert_eq!(shape.len(), 2, "only 2-D tensors quantize");
    shape[0] * shape[1] + shape[1] * 4
}

/// Quantize a 2-D f32 tensor to int8 with per-output-channel absmax
/// scales. Errors on non-2-D shapes and on NaN/Inf (a corrupt weight
/// must fail loudly, not round to garbage).
pub fn quantize_per_channel(w: &HostTensor) -> Result<QuantTensor> {
    if w.shape.len() != 2 {
        bail!("only 2-D tensors quantize (got shape {:?})", w.shape);
    }
    let (rows, cols) = (w.shape[0], w.shape[1]);
    if !w.data.iter().all(|x| x.is_finite()) {
        bail!("quantize_per_channel: NaN/Inf in weight tensor");
    }
    let mut s = vec![0.0f32; cols];
    for r in 0..rows {
        let row = &w.data[r * cols..(r + 1) * cols];
        for (c, x) in row.iter().enumerate() {
            let a = x.abs();
            if a > s[c] {
                s[c] = a;
            }
        }
    }
    for v in s.iter_mut() {
        *v /= 127.0;
    }
    let mut q = vec![0i8; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let sc = s[c];
            // absmax == 0 means the whole channel is zero: scale 0, q 0.
            if sc > 0.0 {
                let v = (w.data[r * cols + c] / sc).round_ties_even();
                q[r * cols + c] = crate::util::cast::sat_i8(v);
            }
        }
    }
    Ok(QuantTensor {
        q: HostTensorI8::from_vec(&w.shape, q),
        s: HostTensor::from_vec(&[cols], s),
    })
}

/// Inverse of [`quantize_per_channel`] (reference/tests; the hot path
/// never materializes this — dequant is fused into the q8 segments).
pub fn dequantize(t: &QuantTensor) -> HostTensor {
    let (rows, cols) = (t.q.shape[0], t.q.shape[1]);
    let mut w = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            w[r * cols + c] = t.q.data[r * cols + c] as f32 * t.s.data[c];
        }
    }
    HostTensor::from_vec(&t.q.shape, w)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: Vec<f32>) -> HostTensor {
        HostTensor::from_vec(shape, data)
    }

    #[test]
    fn scale_is_per_output_channel_absmax_over_127() {
        let w = t(&[2, 3], vec![1.0, -2.0, 0.5, -4.0, 1.0, 0.25]);
        let qt = quantize_per_channel(&w).unwrap();
        assert_eq!(qt.s.shape, vec![3]);
        for (c, want) in [4.0f32, 2.0, 0.5].iter().enumerate() {
            assert!((qt.s.data[c] - want / 127.0).abs() < 1e-7);
        }
        // the absmax element of each channel lands exactly on ±127
        assert_eq!(qt.q.data[3], -127); // w[1,0] = -4.0
        assert_eq!(qt.q.data[1], -127); // w[0,1] = -2.0
    }

    #[test]
    fn round_trip_error_is_bounded_by_half_scale() {
        // deterministic pseudo-random weights, no RNG dep
        let mut v = Vec::with_capacity(64 * 16);
        let mut x = 0x9e3779b97f4a7c15u64;
        for _ in 0..64 * 16 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            v.push(((x >> 33) as i32 % 2000) as f32 / 1000.0);
        }
        let w = t(&[64, 16], v);
        let qt = quantize_per_channel(&w).unwrap();
        let back = dequantize(&qt);
        for r in 0..64 {
            for c in 0..16 {
                let err = (w.data[r * 16 + c] - back.data[r * 16 + c]).abs();
                assert!(
                    err <= qt.s.data[c] * 0.5 + 1e-6,
                    "err {err} > half-scale {} at [{r},{c}]",
                    qt.s.data[c] * 0.5
                );
            }
        }
    }

    #[test]
    fn rounding_is_half_even_matching_the_exporter() {
        // scale = 1/127 per channel via absmax 1.0, so w*127 is the
        // pre-round value: 63.5 -> 64, 62.5 -> 62 (banker's rounding)
        let w = t(&[3, 2], vec![63.5 / 127.0, 62.5 / 127.0, -63.5 / 127.0,
                                -62.5 / 127.0, 1.0, 1.0]);
        let qt = quantize_per_channel(&w).unwrap();
        assert_eq!(&qt.q.data[..4], &[64, 62, -64, -62]);
    }

    #[test]
    fn zero_channel_gets_zero_scale_and_zero_codes() {
        let w = t(&[2, 2], vec![0.0, 3.0, 0.0, -1.0]);
        let qt = quantize_per_channel(&w).unwrap();
        assert_eq!(qt.s.data[0], 0.0);
        assert_eq!((qt.q.data[0], qt.q.data[2]), (0, 0));
        let back = dequantize(&qt);
        assert_eq!((back.data[0], back.data[2]), (0.0, 0.0));
    }

    #[test]
    fn nan_and_inf_are_rejected_not_rounded() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let w = t(&[1, 2], vec![1.0, bad]);
            let err = quantize_per_channel(&w).unwrap_err();
            assert!(err.to_string().contains("NaN/Inf"), "{err}");
        }
    }

    #[test]
    fn non_2d_is_rejected() {
        let err = quantize_per_channel(&t(&[4], vec![1.0; 4])).unwrap_err();
        assert!(err.to_string().contains("only 2-D"), "{err}");
    }

    #[test]
    fn quantized_bytes_matches_the_pair_and_shrinks_3_5x() {
        let w = t(&[128, 64], vec![0.5; 128 * 64]);
        let qt = quantize_per_channel(&w).unwrap();
        assert_eq!(qt.bytes(), quantized_bytes(&[128, 64]));
        let f32_bytes = 128 * 64 * 4;
        let ratio = f32_bytes as f64 / quantized_bytes(&[128, 64]) as f64;
        assert!(ratio >= 3.5, "ratio {ratio}");
    }
}
