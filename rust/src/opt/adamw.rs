//! AdamW (Loshchilov & Hutter, decoupled weight decay) over host tensors —
//! the production optimizer of the coordinator (the fused Pallas variant is
//! the `adamw_update` artifact, compared in EXPERIMENTS.md §Perf).
//!
//! State is allocated *lazily per parameter key*: with LISA only the
//! currently-unfrozen blocks (plus embed/head) ever hold moments, which is
//! exactly the paper's memory claim. Two policies for re-frozen blocks:
//!
//! * `StatePolicy::Keep` — moments persist across sampling periods (what
//!   LMFlow's published LISA implementation does);
//! * `StatePolicy::Drop` — moments are freed when a block is re-frozen (the
//!   paper's Table-1 memory arithmetic).

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::model::checkpoint::Section;
use crate::model::ParamKey;
use crate::util::threadpool;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatePolicy {
    Keep,
    Drop,
}

#[derive(Debug, Clone, Copy)]
pub struct AdamHp {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamHp {
    fn default() -> Self {
        AdamHp { lr: 1e-4, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.01 }
    }
}

#[derive(Debug, Clone)]
struct Slot {
    t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
}

#[derive(Debug)]
pub struct AdamW {
    pub hp: AdamHp,
    pub policy: StatePolicy,
    /// Threads for the elementwise update (1 = serial).
    pub workers: usize,
    state: BTreeMap<ParamKey, Slot>,
}

/// Serial fused update over one chunk. `t` is the 1-based step for this
/// tensor (bias correction is per-tensor: a freshly-unfrozen block starts
/// its schedule at t=1, matching a fresh optimizer state).
#[inline]
pub fn adamw_chunk(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    hp: &AdamHp,
    decay: bool,
    t: u64,
) {
    let b1 = hp.beta1;
    let b2 = hp.beta2;
    let bc1 = 1.0 - b1.powi(t as i32);
    let bc2 = 1.0 - b2.powi(t as i32);
    let wd = if decay { hp.weight_decay } else { 0.0 };
    let lr = hp.lr;
    let eps = hp.eps;
    for i in 0..p.len() {
        let gi = g[i];
        let mi = b1 * m[i] + (1.0 - b1) * gi;
        let vi = b2 * v[i] + (1.0 - b2) * gi * gi;
        m[i] = mi;
        v[i] = vi;
        let mhat = mi / bc1;
        let vhat = vi / bc2;
        p[i] -= lr * (mhat / (vhat.sqrt() + eps) + wd * p[i]);
    }
}

impl AdamW {
    pub fn new(hp: AdamHp, policy: StatePolicy) -> Self {
        AdamW { hp, policy, workers: 1, state: BTreeMap::new() }
    }

    /// One update for one tensor. Allocates state lazily on first touch.
    pub fn step(&mut self, key: ParamKey, decay: bool, p: &mut [f32], g: &[f32]) {
        assert_eq!(p.len(), g.len(), "param/grad length mismatch for {key:?}");
        let slot = self.state.entry(key).or_insert_with(|| Slot {
            t: 0,
            m: vec![0.0; p.len()],
            v: vec![0.0; p.len()],
        });
        slot.t += 1;
        let t = slot.t;
        if self.workers <= 1 || p.len() < 1 << 16 {
            adamw_chunk(p, g, &mut slot.m, &mut slot.v, &self.hp, decay, t);
        } else {
            // Split p/g/m/v into aligned disjoint chunks across threads.
            let parts = threadpool::chunks(p.len(), self.workers);
            let hp = self.hp;
            std::thread::scope(|scope| {
                let mut pr = &mut p[..];
                let mut gr = &g[..];
                let mut mr = &mut slot.m[..];
                let mut vr = &mut slot.v[..];
                for (_, len) in parts {
                    let (ph, pt) = pr.split_at_mut(len);
                    let (gh, gt) = gr.split_at(len);
                    let (mh, mt) = mr.split_at_mut(len);
                    let (vh, vt) = vr.split_at_mut(len);
                    scope.spawn(move || adamw_chunk(ph, gh, mh, vh, &hp, decay, t));
                    pr = pt;
                    gr = gt;
                    mr = mt;
                    vr = vt;
                }
            });
        }
    }

    /// Enforce the state policy after a resample: keep only `live` keys
    /// (plus any non-block keys) under `Drop`.
    pub fn retain_blocks(&mut self, live: &[usize]) {
        if self.policy == StatePolicy::Keep {
            return;
        }
        self.state.retain(|k, _| match k {
            ParamKey::Block(l, _) => live.contains(l),
            _ => true,
        });
    }

    /// Bytes held by optimizer moments (2 f32 per parameter with state).
    pub fn state_bytes(&self) -> u64 {
        self.state
            .values()
            .map(|s| (s.m.len() + s.v.len()) as u64 * 4)
            .sum()
    }

    pub fn n_slots(&self) -> usize {
        self.state.len()
    }

    /// Step count recorded for a key (diagnostics).
    pub fn steps_of(&self, key: ParamKey) -> u64 {
        self.state.get(&key).map(|s| s.t).unwrap_or(0)
    }

    /// Serialize every moment slot into `sec` under `prefix` (checkpoint
    /// resume protocol — DESIGN.md §7). Moment buffers are borrowed into
    /// the section (the streaming writer CRCs them in place — no copy).
    /// Hyperparameters and policy are *not* persisted: they are re-derived
    /// from the training config, so a resumed run and an uninterrupted run
    /// share one source of truth.
    pub fn save_state<'a>(&'a self, sec: &mut Section<'a>, prefix: &str) {
        let keys: Vec<String> = self.state.keys().map(|k| k.name()).collect();
        sec.put_str(&format!("{prefix}keys"), &keys.join(","));
        for (k, s) in &self.state {
            let n = k.name();
            sec.put_u64(&format!("{prefix}{n}.t"), s.t);
            sec.put_f32s(&format!("{prefix}{n}.m"), &s.m);
            sec.put_f32s(&format!("{prefix}{n}.v"), &s.v);
        }
    }

    /// Restore the slots written by [`AdamW::save_state`], replacing any
    /// existing state. Each slot is size-checked against `shape` so an
    /// inconsistent (but CRC-valid) checkpoint errors here instead of
    /// panicking inside `adamw_chunk` on the next step.
    pub fn load_state(
        &mut self,
        sec: &mut Section<'_>,
        prefix: &str,
        shape: super::ShapeFn<'_>,
    ) -> Result<()> {
        self.state.clear();
        let keys = sec.take_str(&format!("{prefix}keys"))?;
        for n in keys.split(',').filter(|s| !s.is_empty()) {
            let key = ParamKey::parse(n)?;
            let t = sec.take_u64(&format!("{prefix}{n}.t"))?;
            let m = sec.take_f32s(&format!("{prefix}{n}.m"))?;
            let v = sec.take_f32s(&format!("{prefix}{n}.v"))?;
            ensure!(
                m.len() == v.len(),
                "optimizer slot '{n}': m/v length mismatch ({} vs {})",
                m.len(),
                v.len()
            );
            if let Some(s) = shape(key) {
                let numel: usize = s.iter().product();
                ensure!(
                    m.len() == numel,
                    "optimizer slot '{n}': {} moments but parameter has {numel} elements",
                    m.len()
                );
            }
            self.state.insert(key, Slot { t, m, v });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-6 + 1e-5 * b.abs()
    }

    /// Hand-computed single-element AdamW step.
    #[test]
    fn matches_hand_computation() {
        let hp = AdamHp { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 };
        let mut o = AdamW::new(hp, StatePolicy::Keep);
        let mut p = [1.0f32];
        o.step(ParamKey::Emb, false, &mut p, &[0.5]);
        // t=1: m=0.05, v=0.00025; mhat=0.5, vhat=0.25; upd = 0.1*0.5/(0.5+1e-8)
        assert!(close(p[0], 1.0 - 0.1 * 0.5 / (0.25f32.sqrt() + 1e-8)), "p={}", p[0]);
    }

    #[test]
    fn weight_decay_is_decoupled() {
        let hp = AdamHp { lr: 0.1, weight_decay: 0.5, ..Default::default() };
        let mut o = AdamW::new(hp, StatePolicy::Keep);
        let mut p = [2.0f32];
        // zero gradient: only decay acts: p -= lr * wd * p
        o.step(ParamKey::Emb, true, &mut p, &[0.0]);
        assert!(close(p[0], 2.0 - 0.1 * 0.5 * 2.0), "p={}", p[0]);
        // decay disabled for non-decayed tensors
        let mut q = [2.0f32];
        o.step(ParamKey::Pos, false, &mut q, &[0.0]);
        assert_eq!(q[0], 2.0);
    }

    #[test]
    fn lazy_state_and_drop_policy() {
        let mut o = AdamW::new(AdamHp::default(), StatePolicy::Drop);
        assert_eq!(o.state_bytes(), 0);
        let mut p = vec![1.0f32; 100];
        let g = vec![0.1f32; 100];
        o.step(ParamKey::Block(3, 0), true, &mut p, &g);
        o.step(ParamKey::Block(5, 0), true, &mut p, &g);
        o.step(ParamKey::Emb, false, &mut p, &g);
        assert_eq!(o.state_bytes(), 3 * 200 * 4);
        o.retain_blocks(&[5]);
        // block 3 dropped; embed kept (non-block state survives Drop)
        assert_eq!(o.n_slots(), 2);
        assert_eq!(o.steps_of(ParamKey::Block(3, 0)), 0);
        assert_eq!(o.steps_of(ParamKey::Block(5, 0)), 1);
    }

    #[test]
    fn keep_policy_preserves_state() {
        let mut o = AdamW::new(AdamHp::default(), StatePolicy::Keep);
        let mut p = vec![1.0f32; 10];
        o.step(ParamKey::Block(0, 0), true, &mut p, &vec![0.1; 10]);
        o.retain_blocks(&[7]);
        assert_eq!(o.steps_of(ParamKey::Block(0, 0)), 1);
    }

    #[test]
    fn parallel_matches_serial() {
        let n = 200_000;
        let mut rng = crate::util::rng::Rng::new(4);
        let mut p1 = vec![0f32; n];
        rng.fill_normal(&mut p1, 1.0);
        let mut g = vec![0f32; n];
        rng.fill_normal(&mut g, 0.1);
        let mut p2 = p1.clone();

        let hp = AdamHp::default();
        let mut serial = AdamW::new(hp, StatePolicy::Keep);
        serial.workers = 1;
        let mut par = AdamW::new(hp, StatePolicy::Keep);
        par.workers = 8;
        for _ in 0..3 {
            serial.step(ParamKey::Emb, true, &mut p1, &g);
            par.step(ParamKey::Emb, true, &mut p2, &g);
        }
        assert_eq!(p1, p2, "parallel AdamW must be bit-identical to serial");
    }

    #[test]
    fn state_roundtrip_continues_bitwise() {
        let hp = AdamHp { lr: 0.05, ..Default::default() };
        let mut rng = crate::util::rng::Rng::new(8);
        let mut p_a = vec![0f32; 64];
        rng.fill_normal(&mut p_a, 1.0);
        let mut p_b = p_a.clone();
        let grads: Vec<Vec<f32>> = (0..6)
            .map(|_| {
                let mut g = vec![0f32; 64];
                rng.fill_normal(&mut g, 0.1);
                g
            })
            .collect();

        let mut a = AdamW::new(hp, StatePolicy::Keep);
        for g in &grads[..3] {
            a.step(ParamKey::Block(2, 1), true, &mut p_a, g);
        }
        let mut sec = Section::new("strategy");
        a.save_state(&mut sec, "opt.adam.");

        // an interrupted run: fresh optimizer, restore, continue
        let mut b = AdamW::new(hp, StatePolicy::Keep);
        for g in &grads[..3] {
            b.step(ParamKey::Block(2, 1), true, &mut p_b, g);
        }
        let mut b2 = AdamW::new(hp, StatePolicy::Keep);
        let shape = |k: ParamKey| (k == ParamKey::Block(2, 1)).then(|| vec![64usize]);
        b2.load_state(&mut sec, "opt.adam.", &shape).unwrap();
        assert!(sec.is_empty(), "load must consume every entry");
        assert_eq!(b2.steps_of(ParamKey::Block(2, 1)), 3);
        assert_eq!(b2.state_bytes(), b.state_bytes());
        for g in &grads[3..] {
            a.step(ParamKey::Block(2, 1), true, &mut p_a, g);
            b2.step(ParamKey::Block(2, 1), true, &mut p_b, g);
        }
        assert_eq!(p_a, p_b, "resumed AdamW must be bit-identical");

        // sanity: skipping the restore diverges (the test has teeth)
        let mut p_c = p_b.clone();
        let mut fresh = AdamW::new(hp, StatePolicy::Keep);
        fresh.step(ParamKey::Block(2, 1), true, &mut p_c, &grads[5]);
        assert_ne!(p_c, p_b);
    }

    #[test]
    fn empty_state_roundtrip() {
        let o = AdamW::new(AdamHp::default(), StatePolicy::Keep);
        let mut sec = Section::new("strategy");
        o.save_state(&mut sec, "opt.adam.");
        let mut o2 = AdamW::new(AdamHp::default(), StatePolicy::Keep);
        o2.load_state(&mut sec, "opt.adam.", &|_| None).unwrap();
        assert_eq!(o2.state_bytes(), 0);
        assert!(sec.is_empty());
    }

    #[test]
    fn load_rejects_moment_size_mismatch() {
        // a CRC-valid but inconsistent checkpoint (moments shorter than
        // the parameter) must error at load, not index out of bounds on
        // the next step
        let mut o = AdamW::new(AdamHp::default(), StatePolicy::Keep);
        let mut p = vec![1.0f32; 16];
        o.step(ParamKey::Emb, false, &mut p, &[0.1; 16]);
        let mut sec = Section::new("strategy");
        o.save_state(&mut sec, "opt.adam.");
        let mut o2 = AdamW::new(AdamHp::default(), StatePolicy::Keep);
        let err = o2
            .load_state(&mut sec, "opt.adam.", &|_| Some(vec![4, 8]))
            .unwrap_err();
        assert!(err.to_string().contains("moments"), "got: {err}");
    }

    #[test]
    fn descends_on_quadratic() {
        // minimize f(p) = p^2 with gradient 2p
        let mut o = AdamW::new(
            AdamHp { lr: 0.05, weight_decay: 0.0, ..Default::default() },
            StatePolicy::Keep,
        );
        let mut p = [3.0f32];
        for _ in 0..300 {
            let g = [2.0 * p[0]];
            o.step(ParamKey::Emb, false, &mut p, &g);
        }
        assert!(p[0].abs() < 0.05, "did not converge: p={}", p[0]);
    }
}
