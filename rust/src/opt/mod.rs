//! Optimizers: AdamW (production), GaLore (baseline), SGD (tests).

pub mod adamw;
pub mod galore;
pub mod linalg;
pub mod quant;

pub use adamw::{AdamHp, AdamW, StatePolicy};
pub use galore::{Galore, GaloreHp};
pub use quant::{dequantize, quantize_per_channel, quantized_bytes, QuantTensor};

use crate::engine::Grads;
use crate::model::{ModelParams, ParamKey};

/// Expected-shape oracle for checkpoint restoration: maps a parameter key
/// to its tensor shape so restored optimizer state can be size-validated
/// at load time (a CRC-valid but inconsistent file must error, never
/// panic mid-step). `None` = shape unknown to the caller; the check is
/// skipped for that key.
pub type ShapeFn<'a> = &'a dyn Fn(ParamKey) -> Option<Vec<usize>>;

/// Plain SGD, used by optimizer-equivalence tests.
#[derive(Debug, Clone, Copy)]
pub struct Sgd {
    pub lr: f32,
}

impl Sgd {
    pub fn step(&self, p: &mut [f32], g: &[f32]) {
        for (pi, gi) in p.iter_mut().zip(g) {
            *pi -= self.lr * gi;
        }
    }
}

/// The method-level optimizer the training loop drives: applies a `Grads`
/// (whatever trainable subset it carries) to the model.
pub enum Optimizer {
    AdamW(AdamW),
    /// GaLore routes 2-D tensors through the projector and 1-D tensors
    /// through an internal AdamW (GaLore's reference does the same).
    Galore { proj: Galore, aux: AdamW },
}

impl Optimizer {
    pub fn adamw(hp: AdamHp, policy: StatePolicy) -> Self {
        Optimizer::AdamW(AdamW::new(hp, policy))
    }

    pub fn galore(hp: GaloreHp, policy: StatePolicy, seed: u64) -> Self {
        Optimizer::Galore {
            proj: Galore::new(hp, seed),
            aux: AdamW::new(hp.adam, policy),
        }
    }

    pub fn set_lr(&mut self, lr: f32) {
        match self {
            Optimizer::AdamW(o) => o.hp.lr = lr,
            Optimizer::Galore { proj, aux } => {
                proj.hp.adam.lr = lr;
                aux.hp.lr = lr;
            }
        }
    }

    pub fn lr(&self) -> f32 {
        match self {
            Optimizer::AdamW(o) => o.hp.lr,
            Optimizer::Galore { proj, .. } => proj.hp.adam.lr,
        }
    }

    fn step_tensor(
        &mut self,
        key: ParamKey,
        decay: bool,
        shape: &[usize],
        p: &mut [f32],
        g: &[f32],
    ) {
        match self {
            Optimizer::AdamW(o) => o.step(key, decay, p, g),
            Optimizer::Galore { proj, aux } => {
                if shape.len() == 2 {
                    proj.step_matrix(key, decay, p, g, shape[0], shape[1]);
                } else {
                    aux.step(key, decay, p, g);
                }
            }
        }
    }

    /// Apply a gradient set to the model. Only tensors present in `grads`
    /// move; everything else is untouched (frozen).
    pub fn apply(&mut self, params: &mut ModelParams, grads: &Grads,
                 block_names: &[(String, Vec<usize>)]) {
        if let Some(g) = &grads.emb {
            let shape = params.emb.shape.clone();
            self.step_tensor(ParamKey::Emb, false, &shape, &mut params.emb.data, &g.data);
        }
        if let Some(g) = &grads.pos {
            let shape = params.pos.shape.clone();
            self.step_tensor(ParamKey::Pos, false, &shape, &mut params.pos.data, &g.data);
        }
        for (l, blk) in grads.blocks.iter().enumerate() {
            let Some(gs) = blk else { continue };
            for (t, g) in gs.iter().enumerate() {
                let key = ParamKey::Block(l, t);
                let decay = key.decayed(block_names);
                let shape = params.blocks[l][t].shape.clone();
                self.step_tensor(key, decay, &shape, &mut params.blocks[l][t].data, &g.data);
            }
        }
        if let Some(g) = &grads.gf {
            let shape = params.gf.shape.clone();
            self.step_tensor(ParamKey::HeadNorm, false, &shape, &mut params.gf.data, &g.data);
        }
        if let Some(g) = &grads.wh {
            let shape = params.wh.shape.clone();
            self.step_tensor(ParamKey::HeadProj, true, &shape, &mut params.wh.data, &g.data);
        }
    }

    /// Post-resample state policy hook (LISA `Drop` mode). Propagates to
    /// every arm: the GaLore projector drops both the projected moments and
    /// the basis of re-frozen blocks, so `StatePolicy::Drop` is never
    /// silently ignored.
    pub fn retain_blocks(&mut self, live: &[usize]) {
        match self {
            Optimizer::AdamW(o) => o.retain_blocks(live),
            Optimizer::Galore { proj, aux } => {
                aux.retain_blocks(live);
                if aux.policy == StatePolicy::Drop {
                    proj.retain_blocks(live);
                }
            }
        }
    }

    pub fn state_bytes(&self) -> u64 {
        match self {
            Optimizer::AdamW(o) => o.state_bytes(),
            Optimizer::Galore { proj, aux } => proj.state_bytes() + aux.state_bytes(),
        }
    }

    /// Serialize all optimizer state into a checkpoint section (resume
    /// protocol; moments are borrowed, not cloned). A "kind" tag guards
    /// against resuming a run with a different optimizer arm.
    pub fn save_state<'a>(&'a self, sec: &mut crate::model::checkpoint::Section<'a>) {
        match self {
            Optimizer::AdamW(o) => save_adamw_state(o, sec),
            Optimizer::Galore { proj, aux } => {
                sec.put_str("opt.kind", "galore");
                proj.save_state(sec, "opt.galore.");
                aux.save_state(sec, "opt.adam.");
            }
        }
    }

    /// Restore the state written by [`Optimizer::save_state`], validating
    /// slot sizes against `shape` where known.
    pub fn load_state(
        &mut self,
        sec: &mut crate::model::checkpoint::Section<'_>,
        shape: ShapeFn<'_>,
    ) -> anyhow::Result<()> {
        match self {
            Optimizer::AdamW(o) => load_adamw_state(o, sec, shape),
            Optimizer::Galore { proj, aux } => {
                let kind = sec.take_str("opt.kind")?;
                anyhow::ensure!(
                    kind == "galore",
                    "checkpoint optimizer kind '{kind}' != configured 'galore'"
                );
                proj.load_state(sec, "opt.galore.", shape)?;
                aux.load_state(sec, "opt.adam.", shape)
            }
        }
    }
}

/// The tagged-AdamW checkpoint convention ("opt.kind" + "opt.adam."
/// prefix), shared by the [`Optimizer`] enum and strategies that own a
/// bare [`AdamW`] (LoRA) — one definition so the two can never diverge.
pub fn save_adamw_state<'a>(o: &'a AdamW, sec: &mut crate::model::checkpoint::Section<'a>) {
    sec.put_str("opt.kind", "adamw");
    o.save_state(sec, "opt.adam.");
}

/// Inverse of [`save_adamw_state`].
pub fn load_adamw_state(
    o: &mut AdamW,
    sec: &mut crate::model::checkpoint::Section<'_>,
    shape: ShapeFn<'_>,
) -> anyhow::Result<()> {
    let kind = sec.take_str("opt.kind")?;
    anyhow::ensure!(
        kind == "adamw",
        "checkpoint optimizer kind '{kind}' != configured 'adamw'"
    );
    o.load_state(sec, "opt.adam.", shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_descends() {
        let sgd = Sgd { lr: 0.1 };
        let mut p = [5.0f32];
        for _ in 0..100 {
            let g = [2.0 * p[0]];
            sgd.step(&mut p, &g);
        }
        assert!(p[0].abs() < 1e-3);
    }

    #[test]
    fn optimizer_lr_plumbing() {
        let mut o = Optimizer::adamw(AdamHp::default(), StatePolicy::Keep);
        o.set_lr(0.5);
        assert_eq!(o.lr(), 0.5);
        let mut g = Optimizer::galore(GaloreHp::default(), StatePolicy::Keep, 0);
        g.set_lr(0.25);
        assert_eq!(g.lr(), 0.25);
    }

    fn galore_with_state(policy: StatePolicy) -> Optimizer {
        let hp = GaloreHp { rank: 2, ..Default::default() };
        let mut o = Optimizer::galore(hp, policy, 0);
        let (rows, cols) = (4usize, 6usize);
        let mut p = vec![0.1f32; rows * cols];
        let g = vec![0.1f32; rows * cols];
        let mut b = vec![0.5f32; 8];
        let gb = vec![0.1f32; 8];
        let Optimizer::Galore { proj, aux } = &mut o else { unreachable!() };
        proj.step_matrix(ParamKey::Block(0, 1), true, &mut p, &g, rows, cols);
        proj.step_matrix(ParamKey::Block(2, 1), true, &mut p, &g, rows, cols);
        aux.step(ParamKey::Block(0, 0), false, &mut b, &gb);
        aux.step(ParamKey::HeadNorm, false, &mut b, &gb);
        o
    }

    #[test]
    fn galore_retain_blocks_propagates_under_drop() {
        let mut o = galore_with_state(StatePolicy::Drop);
        o.retain_blocks(&[2]);
        let Optimizer::Galore { proj, aux } = &o else { unreachable!() };
        // block 0 dropped from both the projector and the aux AdamW;
        // the non-block HeadNorm slot survives
        assert_eq!(proj.n_slots(), 1);
        assert_eq!(aux.n_slots(), 1);
    }

    #[test]
    fn galore_retain_blocks_noop_under_keep() {
        let mut o = galore_with_state(StatePolicy::Keep);
        o.retain_blocks(&[2]);
        let Optimizer::Galore { proj, aux } = &o else { unreachable!() };
        assert_eq!(proj.n_slots(), 2);
        assert_eq!(aux.n_slots(), 2);
    }
}
