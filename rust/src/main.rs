//! `lisa` — the coordinator CLI.
//!
//! ```text
//! lisa train  --config small --method lisa --steps 120 ...   one training run
//! lisa serve  --config small --ckpt results/model.ckpt ...   HTTP serving front end
//! lisa exp <id> [--config C] [--scale 0.5]                   reproduce a paper table/figure
//! lisa exp list                                              list experiments + strategies
//! lisa exp all                                               the full reproduction suite
//! lisa memory                                                Table-1 memory grid only
//! lisa info --config small                                   manifest/artifact info
//! ```
//!
//! `--method` resolves through the strategy registry
//! (`strategy::registry()`), so any registered strategy — including ones
//! added after this file was written — is trainable with no CLI edits.

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, Result};

use lisa::data::{corpus, encode_sft, split_train_val, DataLoader, Tokenizer};
use lisa::exp::{self, Ctx};
use lisa::opt::StatePolicy;
use lisa::strategy::{self, StrategySpec};
use lisa::train::{CheckpointConf, LrSchedule, TrainConfig, TrainSession};
use lisa::util::cli::Args;

const SPEC: &[(&str, &str, &str)] = &[
    ("config", "", "model config (tiny|small|base|e2e100m)"),
    ("artifacts", "artifacts", "artifacts root directory"),
    ("results", "results", "results output directory"),
    ("backend", "pallas", "kernel backend artifacts to load (pallas|jnp)"),
    ("method", "lisa", "train: any registered strategy (see `lisa exp list`)"),
    ("steps", "", "training steps (experiment default if empty)"),
    ("lr", "", "peak learning rate (method default if empty)"),
    ("lr-schedule", "warmup", "lr schedule: constant|warmup|cosine"),
    ("warmup", "10", "linear warmup steps"),
    ("weight-decay", "0.01", "AdamW decoupled weight decay"),
    ("max-grad-norm", "1.0", "global gradient-norm clip ('none' disables)"),
    ("gamma", "2", "LISA: sampled intermediate layers γ"),
    ("period", "10", "LISA: sampling period K"),
    ("lisa-state", "keep", "LISA optimizer-state policy on refreeze: keep|drop"),
    ("galore-rank", "16", "GaLore projection rank"),
    ("galore-gap", "50", "GaLore projection refresh interval (steps)"),
    ("galore-scale", "1.0", "GaLore update scale α"),
    ("grad-accum", "1", "microbatch accumulation"),
    ("device-flow", "", "train: device-resident params/activations (on|off; default on, or LISA_DEVICE_FLOW)"),
    ("quant", "", "int8 frozen-base weights (int8|off; default off, or LISA_QUANT)"),
    ("save-every", "0", "checkpoint full training state every N steps (0 = final save only)"),
    ("ckpt", "", "training-state checkpoint path (default <results>/train-<method>.state)"),
    ("resume", "", "resume training from a --save-every checkpoint"),
    ("seed", "42", "master seed"),
    ("sample", "greedy", "decode sampling policy: greedy|temperature|top-k|top-p"),
    ("temperature", "1.0", "decode: softmax temperature (0 = argmax)"),
    ("top-k", "40", "decode: top-k cutoff (with --sample top-k; 1 = argmax)"),
    ("top-p", "0.9", "decode: nucleus mass cutoff (with --sample top-p)"),
    ("gen-seed", "42", "decode: base seed of the per-request sampler streams"),
    ("addr", "127.0.0.1:8080", "serve: bind address host:port (port 0 = ephemeral)"),
    ("http-workers", "4", "serve: HTTP worker threads"),
    ("max-queue", "32", "serve: admission-queue bound (further requests get 429)"),
    ("max-new", "32", "serve: default per-request generation budget"),
    ("max-new-cap", "256", "serve: hard per-request cap on max_new (larger asks are clamped)"),
    ("event-buf", "512", "serve: per-request event buffer (stalled clients beyond it are dropped)"),
    ("fault", "", "serve: deterministic fault plan (LISA_FAULT syntax; chaos testing)"),
    ("scale", "1.0", "experiment step-budget multiplier"),
    ("samples", "480", "train: corpus size"),
    ("eval", "true", "train: evaluate on the val split afterwards"),
];

/// Build a strategy spec from the CLI: the method name routes through the
/// registry; method-specific flags ride along as spec options (builders
/// read the keys they understand).
fn parse_spec(a: &Args) -> Result<StrategySpec> {
    let name = a.get("method");
    if strategy::lookup(&name).is_none() {
        bail!(
            "unknown method '{name}' — registered: {}",
            strategy::names().join(", ")
        );
    }
    Ok(StrategySpec::new(&name)
        .with("gamma", a.get_usize("gamma")?)
        .with("period", a.get_usize("period")?)
        .with("rank", a.get_usize("galore-rank")?)
        .with("update-proj-gap", a.get_usize("galore-gap")?)
        .with("scale", a.get_f64("galore-scale")?))
}

fn parse_max_grad_norm(a: &Args) -> Result<Option<f64>> {
    Ok(match a.get("max-grad-norm").as_str() {
        "none" | "off" => None,
        s => {
            let v: f64 = s
                .parse()
                .map_err(|_| anyhow::anyhow!("--max-grad-norm expects a number or 'none'"))?;
            if v > 0.0 {
                Some(v)
            } else {
                None
            }
        }
    })
}

fn parse_sampler(a: &Args) -> Result<lisa::engine::SamplerSpec> {
    lisa::engine::SamplerSpec::parse(
        &a.get("sample"),
        a.get_f64("temperature")? as f32,
        a.get_usize("top-k")?,
        a.get_f64("top-p")? as f32,
    )
}

/// `--quant` resolves to the `LISA_QUANT` environment variable before any
/// engine is constructed, so every entry point (train, serve, exp, memory)
/// picks it up through the one code path engines already read. An explicit
/// `--quant off` pins pure-f32 (the engine refuses later `set_quant` calls),
/// matching the env kill-switch semantics.
fn apply_quant_flag(a: &Args) -> Result<()> {
    if let Some(v) = a.get_opt("quant") {
        match v.as_str() {
            "int8" | "1" => std::env::set_var("LISA_QUANT", "int8"),
            "off" | "0" => std::env::set_var("LISA_QUANT", "0"),
            other => bail!("--quant expects int8|off (got '{other}')"),
        }
    }
    Ok(())
}

fn ctx_from(a: &Args) -> Result<Ctx> {
    Ok(Ctx {
        artifacts: PathBuf::from(a.get("artifacts")),
        results: PathBuf::from(a.get("results")),
        backend: a.get("backend"),
        scale: a.get_f64("scale").unwrap_or(1.0),
        seed: a.get_u64("seed").unwrap_or(42),
        save_every: a.get_usize("save-every").unwrap_or(0),
        resume: a.get_opt("resume").map(PathBuf::from),
        sampler: parse_sampler(a)?,
        gen_seed: a.get_u64("gen-seed")?,
    })
}

fn cmd_train(a: &Args) -> Result<()> {
    let ctx = ctx_from(a)?;
    let config = a.get_opt("config").unwrap_or_else(|| "small".into());
    let rt = ctx.runtime(&config)?;
    let m = rt.manifest.clone();
    let spec = parse_spec(a)?;
    let steps = a.get_opt("steps").map(|s| s.parse()).transpose()?.unwrap_or(100);
    let lr = a
        .get_opt("lr")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or_else(|| spec.default_lr());
    let cfg = TrainConfig {
        steps,
        lr,
        warmup: a.get_usize("warmup")?,
        schedule: LrSchedule::parse(&a.get("lr-schedule"))?,
        weight_decay: a.get_f64("weight-decay")? as f32,
        max_grad_norm: parse_max_grad_norm(a)?,
        grad_accum: a.get_usize("grad-accum")?,
        seed: ctx.seed,
        state_policy: if a.get("lisa-state") == "drop" {
            StatePolicy::Drop
        } else {
            StatePolicy::Keep
        },
        ..Default::default()
    };

    let samples = corpus::gen_instruction_corpus(a.get_usize("samples")?, ctx.seed);
    let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);
    let (tr, va) = split_train_val(&samples, 0.1, ctx.seed ^ 0x517);
    let enc_tr: Vec<_> = tr.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    let enc_va: Vec<_> = va.iter().map(|s| encode_sft(&tok, s, m.seq)).collect();
    let mut train_dl = DataLoader::new(enc_tr, m.batch, m.seq, ctx.seed);
    let val_dl = DataLoader::new(enc_va, m.batch, m.seq, ctx.seed);

    // `--save-every N` checkpoints periodically; `--ckpt` alone still
    // writes the terminal checkpoint (every=0 = final save only), so the
    // flag is never silently ignored.
    let ckpt = if ctx.save_every > 0 || a.get_opt("ckpt").is_some() {
        let path = a
            .get_opt("ckpt")
            .map(PathBuf::from)
            .unwrap_or_else(|| ctx.results.join(format!("train-{}.state", spec.name)));
        if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)?;
        }
        Some(CheckpointConf { path, every: ctx.save_every })
    } else {
        None
    };

    let mut sess = TrainSession::new(&rt, &spec, cfg)?;
    // An explicit flag overrides LISA_DEVICE_FLOW in both directions;
    // leaving it unset keeps the engine default (env-controlled).
    if let Some(v) = a.get_opt("device-flow") {
        sess.engine.device_flow = !matches!(v.as_str(), "off" | "0" | "false");
    }
    let res = sess.run_resumable(&mut train_dl, ckpt.as_ref(), ctx.resume.as_deref())?;
    if let Some(c) = &ckpt {
        println!("checkpoint: {}", c.path.display());
    }
    println!(
        "done [{}]: final train loss {:.4}, median {:.0} ms/step, peak mem {}",
        sess.label(),
        res.final_train_loss,
        res.median_step_ms(),
        lisa::util::table::human_bytes(res.peak_mem)
    );
    if a.get_bool("eval") {
        let params = sess.eval_params();
        let rep = lisa::eval::evaluate(&mut sess.engine, &params, &val_dl)?;
        println!(
            "val: loss {:.4} ppl {:.2} token-acc {:.3} exact-match {:.3}",
            rep.loss, rep.ppl, rep.token_acc, rep.exact_match
        );
    }
    Ok(())
}

/// `lisa serve`: HTTP front end over the continuous-batching decode
/// loop (DESIGN.md §11). The engine stays on this thread; HTTP workers
/// only enqueue requests and forward token events.
fn cmd_serve(a: &Args) -> Result<()> {
    use lisa::engine::{Engine, ServeSession};
    use lisa::serve_http::{install_sigint, HttpFrontend, ServeConfig};

    let ctx = ctx_from(a)?;
    let config = a.get_opt("config").unwrap_or_else(|| "small".into());
    let rt = ctx.runtime(&config)?;
    let m = &rt.manifest;
    if !m.supports_decode(&rt.backend) {
        bail!(
            "artifact dir '{}' carries no decode-ABI segments for backend '{}' — \
             `lisa serve` needs the KV-cached decode path (re-export with \
             python/compile/aot.py)",
            m.dir.display(),
            rt.backend
        );
    }

    // Deterministic fault injection (DESIGN.md §13): `--fault` overrides
    // any LISA_FAULT already picked up from the environment.
    if let Some(spec) = a.get_opt("fault") {
        rt.set_fault_plan(&spec)?;
        println!("fault injection armed: {spec}");
    }

    // Synthetic-corpus tokenizer, same construction as training: a server
    // for a checkpoint trained with `--samples N --seed S` must be
    // started with the same two flags to agree on the vocabulary.
    let samples = corpus::gen_instruction_corpus(a.get_usize("samples")?, ctx.seed);
    let tok = Tokenizer::build(&corpus::sample_texts(&samples), m.vocab);

    let mut rng = lisa::util::rng::Rng::new(ctx.seed);
    let mut params = lisa::model::ModelParams::init(m, &mut rng);
    match a.get_opt("ckpt") {
        Some(p) => {
            let path = PathBuf::from(p);
            lisa::model::checkpoint::load_model(&path, &mut params)?;
            println!("loaded model checkpoint {}", path.display());
        }
        None => println!("no --ckpt given: serving seed-{} initialized weights", ctx.seed),
    }

    let cfg = ServeConfig {
        addr: a.get("addr"),
        workers: a.get_usize("http-workers")?.max(1),
        max_queue: a.get_usize("max-queue")?.max(1),
        default_max_new: a.get_usize("max-new")?.max(1),
        max_new_cap: a.get_usize("max-new-cap")?.max(1),
        event_buf: a.get_usize("event-buf")?.max(1),
        default_spec: ctx.sampler.clone(),
        gen_seed: ctx.gen_seed,
        ..Default::default()
    };
    let (eos, pad) = (cfg.eos, cfg.pad);
    let front = HttpFrontend::bind(cfg, tok)?;
    install_sigint();
    println!(
        "serving {config} ({:.1}M params, {} decode rows) on http://{} — ^C drains and exits",
        m.n_params as f64 / 1e6,
        m.batch,
        front.local_addr()?
    );

    let mut eng = Engine::new(&rt);
    let mut sess = ServeSession::new(&mut eng, &params)?;
    front.run(|src| sess.run_loop(src, eos, pad))?;
    println!("drained in-flight requests; exiting");
    Ok(())
}

fn real_main() -> Result<()> {
    lisa::util::logger::init();
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::parse(&raw, SPEC)?;
    if a.wants_help() || a.positional.is_empty() {
        print!("{}", a.help("lisa <train|serve|exp|memory|info> [options]"));
        println!("\nexperiments:");
        exp::list();
        return Ok(());
    }
    apply_quant_flag(&a)?;
    match a.positional[0].as_str() {
        "train" => cmd_train(&a),
        "serve" => cmd_serve(&a),
        "exp" => {
            let id = a.positional.get(1).map(|s| s.as_str()).unwrap_or("list");
            if id == "list" {
                exp::list();
                return Ok(());
            }
            let ctx = ctx_from(&a)?;
            let steps = a.get_opt("steps").map(|s| s.parse()).transpose()?;
            let cfg_override = a.get_opt("config");
            exp::run(&ctx, id, cfg_override.as_deref(), steps)
        }
        "memory" => {
            let ctx = ctx_from(&a)?;
            let cfg = a.get_opt("config").unwrap_or_else(|| "tiny".into());
            exp::perfmem::tab1_memory(&ctx, &cfg)?;
            exp::perfmem::fig3_memory(&ctx, &cfg)
        }
        "info" => {
            let ctx = ctx_from(&a)?;
            let cfg = a.get_opt("config").unwrap_or_else(|| "small".into());
            let rt = ctx.runtime(&cfg)?;
            let m = &rt.manifest;
            println!(
                "config {}: {:.2}M params, d_model={} layers={} heads={} vocab={} seq={} batch={}",
                m.name,
                m.n_params as f64 / 1e6,
                m.d_model,
                m.n_layers,
                m.n_heads,
                m.vocab,
                m.seq,
                m.batch
            );
            println!(
                "decode ABI: v{} ({})",
                m.decode_abi,
                if m.supports_decode(&rt.backend) {
                    "KV-cached decode + continuous batching available"
                } else {
                    "no cached decode for this backend — serving falls back to \
                     legacy full-forward"
                }
            );
            println!(
                "quant: {}",
                if m.supports_quant(&rt.backend) {
                    let mut caps = vec!["train"];
                    if m.supports_quant_decode(&rt.backend) {
                        caps.push("decode");
                    }
                    if m.supports_quant_paged(&rt.backend) {
                        caps.push("paged");
                    }
                    format!("int8-chan frozen-base available ({})", caps.join("+"))
                } else {
                    "f32 only (no q8 segment twins exported)".into()
                }
            );
            println!("segments ({}):", m.segments.len());
            for (k, s) in &m.segments {
                println!(
                    "  {k:<28} {} operands -> {} outputs{}",
                    s.operands.len(),
                    s.outputs.len(),
                    if s.device_chainable() { "  [device-chainable]" } else { "" }
                );
            }
            Ok(())
        }
        other => bail!("unknown command '{other}' (try --help)"),
    }
}

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
