//! The training loop: a thin deterministic driver over `Box<dyn Strategy>`.
//!
//! Method-specific behaviour (which layers train, which optimizer runs,
//! whether updates land in the base weights or in adapters) lives entirely
//! in `strategy::` — one registered [`crate::strategy::Strategy`] per
//! method. `TrainSession` only owns the engine, the parameters and the
//! schedule, and drives the strategy through the per-step protocol:
//!
//! ```text
//! lr = cfg.lr_at(step)            -> strategy.set_lr(lr)
//! mask = strategy.mask_for_step() -> strategy.on_resample()
//! for each microbatch:               strategy.accumulate_step(...)
//! strategy.apply(...)                (mean, clip, optimizer update)
//! ```

pub mod schedule;

pub use self::schedule::LrSchedule;

use std::time::Instant;

use anyhow::Result;

use crate::engine::Engine;
use crate::model::ModelParams;
use crate::opt::StatePolicy;
use crate::runtime::Runtime;
use crate::strategy::{Strategy, StrategySpec};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    /// Peak learning rate; `schedule` shapes it over time.
    pub lr: f32,
    pub warmup: usize,
    pub schedule: LrSchedule,
    pub grad_accum: usize,
    pub weight_decay: f32,
    pub max_grad_norm: Option<f64>,
    pub seed: u64,
    /// LISA optimizer-state policy on re-freeze (DESIGN.md §6).
    pub state_policy: StatePolicy,
    /// Record layerwise weight norms every N steps (0 = never) — Fig 2.
    pub weight_norm_every: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            lr: 1e-3,
            warmup: 10,
            schedule: LrSchedule::Warmup,
            grad_accum: 1,
            weight_decay: 0.01,
            max_grad_norm: Some(1.0),
            seed: 42,
            state_policy: StatePolicy::Keep,
            weight_norm_every: 0,
            log_every: 20,
        }
    }
}

impl TrainConfig {
    /// Scheduled learning rate for 0-based step `step`.
    pub fn lr_at(&self, step: usize) -> f32 {
        self.schedule.lr_at(step, self.lr, self.warmup, self.steps)
    }
}

/// Everything an experiment needs afterwards.
pub struct TrainResult {
    pub loss_curve: Vec<(usize, f32)>,
    /// Layerwise weight-norm trajectory: (step, norms[emb, blocks.., head]).
    pub weight_norms: Vec<(usize, Vec<f64>)>,
    pub peak_mem: u64,
    pub mem_breakdown: Vec<(&'static str, u64)>,
    pub step_times_ms: Vec<f64>,
    pub bwd_full_calls: u64,
    pub bwd_x_calls: u64,
    pub bwd_skipped: u64,
    pub final_train_loss: f32,
}

impl TrainResult {
    pub fn mean_step_ms(&self) -> f64 {
        crate::util::stats::mean(&self.step_times_ms)
    }

    pub fn median_step_ms(&self) -> f64 {
        crate::util::stats::median(&self.step_times_ms)
    }
}

/// One training arm: model + a boxed strategy (optimizer state and any
/// auxiliary parameters live inside the strategy).
pub struct TrainSession<'rt> {
    pub engine: Engine<'rt>,
    pub params: ModelParams,
    pub cfg: TrainConfig,
    strategy: Box<dyn Strategy>,
}

impl<'rt> TrainSession<'rt> {
    /// Fresh-initialized parameters + a strategy built from the registry.
    pub fn new(rt: &'rt Runtime, spec: &StrategySpec, cfg: TrainConfig) -> Result<TrainSession<'rt>> {
        let mut rng = Rng::new(cfg.seed);
        let params = ModelParams::init(&rt.manifest, &mut rng);
        Self::with_params(rt, spec, cfg, params)
    }

    /// Start from existing parameters (continual-pretraining pipelines).
    pub fn with_params(
        rt: &'rt Runtime,
        spec: &StrategySpec,
        cfg: TrainConfig,
        params: ModelParams,
    ) -> Result<TrainSession<'rt>> {
        let strategy = spec.build(&rt.manifest, &cfg)?;
        Ok(Self::from_strategy(rt, strategy, cfg, params))
    }

    /// Drive an already-constructed strategy (programmatic extension point;
    /// the strategy need not be registered).
    pub fn from_strategy(
        rt: &'rt Runtime,
        strategy: Box<dyn Strategy>,
        cfg: TrainConfig,
        params: ModelParams,
    ) -> TrainSession<'rt> {
        // 0 would make step() silently return NaN (0/0) with no update.
        assert!(cfg.grad_accum >= 1, "grad_accum must be >= 1");
        TrainSession { engine: Engine::new(rt), params, cfg, strategy }
    }

    pub fn label(&self) -> &'static str {
        self.strategy.label()
    }

    pub fn strategy(&self) -> &dyn Strategy {
        self.strategy.as_ref()
    }

    /// One optimizer step (with microbatch accumulation). Returns the mean
    /// microbatch loss.
    pub fn step(&mut self, step: usize, loader: &mut crate::data::DataLoader) -> Result<f32> {
        if self.strategy.is_noop() {
            return Ok(0.0);
        }
        self.strategy.set_lr(self.cfg.lr_at(step));
        let mask = self.strategy.mask_for_step(step);
        self.strategy.on_resample();

        let mut mean_loss = 0.0f32;
        for _ in 0..self.cfg.grad_accum {
            let batch = loader.next_batch();
            mean_loss +=
                self.strategy
                    .accumulate_step(&mut self.engine, &self.params, &batch, &mask)?;
        }
        self.strategy.apply(
            &mut self.engine,
            &mut self.params,
            self.cfg.grad_accum,
            self.cfg.max_grad_norm,
        )?;
        Ok(mean_loss / self.cfg.grad_accum as f32)
    }

    /// Run the full schedule, recording curves.
    pub fn run(&mut self, loader: &mut crate::data::DataLoader) -> Result<TrainResult> {
        let mut loss_curve = Vec::with_capacity(self.cfg.steps);
        let mut weight_norms = Vec::new();
        let mut step_times = Vec::with_capacity(self.cfg.steps);
        let mut last = 0.0f32;
        for step in 0..self.cfg.steps {
            let t0 = Instant::now();
            last = self.step(step, loader)?;
            step_times.push(t0.elapsed().as_secs_f64() * 1e3);
            loss_curve.push((step, last));
            if self.cfg.weight_norm_every > 0 && step % self.cfg.weight_norm_every == 0 {
                weight_norms.push((step, self.effective_weight_norms()));
            }
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                log::info!(
                    "[{}] step {step}/{} loss {last:.4} lr {:.2e}",
                    self.strategy.label(),
                    self.cfg.steps,
                    self.cfg.lr_at(step)
                );
            }
        }
        if self.cfg.weight_norm_every > 0 {
            weight_norms.push((self.cfg.steps, self.effective_weight_norms()));
        }
        Ok(TrainResult {
            loss_curve,
            weight_norms,
            peak_mem: self.engine.meter.peak(),
            mem_breakdown: self.engine.meter.breakdown(),
            step_times_ms: step_times,
            bwd_full_calls: self.engine.bwd_full_calls,
            bwd_x_calls: self.engine.bwd_x_calls,
            bwd_skipped: self.engine.bwd_skipped,
            final_train_loss: last,
        })
    }

    /// Layerwise norms of the *effective* weights (LoRA: base + merged
    /// delta — the observable Fig 2 plots).
    pub fn effective_weight_norms(&self) -> Vec<f64> {
        self.strategy.effective_weight_norms(&self.params)
    }

    /// Merged-parameter view for evaluation (LoRA merges adapters back).
    pub fn eval_params(&self) -> ModelParams {
        self.strategy.eval_params(&self.params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_schedule_matches_legacy_warmup() {
        // The pre-refactor lr_at: lr * (step+1)/warmup during warmup, then lr.
        let cfg = TrainConfig { lr: 1.0, warmup: 10, ..Default::default() };
        assert!((cfg.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((cfg.lr_at(9) - 1.0).abs() < 1e-6);
        assert_eq!(cfg.lr_at(50), 1.0);
    }

    #[test]
    fn cosine_schedule_reaches_floor_at_horizon() {
        let cfg = TrainConfig {
            lr: 1.0,
            warmup: 5,
            steps: 50,
            schedule: LrSchedule::WarmupCosine { min_factor: 0.0 },
            ..Default::default()
        };
        assert!(cfg.lr_at(50) < 1e-3);
        assert!(cfg.lr_at(5) > 0.99);
    }
}
