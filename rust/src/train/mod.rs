//! The training loop: ties the engine, the LISA scheduler, the optimizers
//! and the data pipeline together — one `TrainSession` per experiment arm.
//!
//! Methods (the paper's comparison set):
//! * `Vanilla` — no training (baseline rows in Tables 2/3/5)
//! * `Full`    — full-parameter AdamW (FT)
//! * `Lisa`    — Algorithm 1 (this paper)
//! * `Lora`    — adapters on all linear layers
//! * `Galore`  — rank-r gradient projection

use std::time::Instant;

use anyhow::Result;

use crate::engine::{Engine, Grads, MemCategory, TrainMask};
use crate::lisa::{LisaConfig, LisaScheduler};
use crate::lora::{self, LoraState};
use crate::model::ModelParams;
use crate::opt::{AdamHp, AdamW, GaloreHp, Optimizer, StatePolicy};
use crate::runtime::Runtime;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub enum Method {
    Vanilla,
    Full,
    Lisa(LisaConfig),
    Lora,
    Galore(GaloreHp),
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Vanilla => "vanilla",
            Method::Full => "ft",
            Method::Lisa(c) if c.fixed => "lisa-fix",
            Method::Lisa(_) => "lisa",
            Method::Lora => "lora",
            Method::Galore(_) => "galore",
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub grad_accum: usize,
    pub weight_decay: f32,
    pub max_grad_norm: Option<f64>,
    pub seed: u64,
    /// LISA optimizer-state policy on re-freeze (DESIGN.md §6).
    pub state_policy: StatePolicy,
    /// Record layerwise weight norms every N steps (0 = never) — Fig 2.
    pub weight_norm_every: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            lr: 1e-3,
            warmup: 10,
            grad_accum: 1,
            weight_decay: 0.01,
            max_grad_norm: Some(1.0),
            seed: 42,
            state_policy: StatePolicy::Keep,
            weight_norm_every: 0,
            log_every: 20,
        }
    }
}

/// Everything an experiment needs afterwards.
pub struct TrainResult {
    pub loss_curve: Vec<(usize, f32)>,
    /// Layerwise weight-norm trajectory: (step, norms[emb, blocks.., head]).
    pub weight_norms: Vec<(usize, Vec<f64>)>,
    pub peak_mem: u64,
    pub mem_breakdown: Vec<(&'static str, u64)>,
    pub step_times_ms: Vec<f64>,
    pub bwd_full_calls: u64,
    pub bwd_x_calls: u64,
    pub bwd_skipped: u64,
    pub final_train_loss: f32,
}

impl TrainResult {
    pub fn mean_step_ms(&self) -> f64 {
        crate::util::stats::mean(&self.step_times_ms)
    }

    pub fn median_step_ms(&self) -> f64 {
        crate::util::stats::median(&self.step_times_ms)
    }
}

/// One training arm: model + method-specific optimizer state.
pub struct TrainSession<'rt> {
    pub engine: Engine<'rt>,
    pub params: ModelParams,
    pub lora: Option<LoraState>,
    pub method: Method,
    pub cfg: TrainConfig,
    optimizer: Optimizer,
    lora_opt: Option<AdamW>,
    scheduler: Option<LisaScheduler>,
}

impl<'rt> TrainSession<'rt> {
    pub fn new(rt: &'rt Runtime, method: Method, cfg: TrainConfig) -> TrainSession<'rt> {
        let mut rng = Rng::new(cfg.seed);
        let params = ModelParams::init(&rt.manifest, &mut rng);
        Self::with_params(rt, method, cfg, params)
    }

    /// Start from existing parameters (continual-pretraining pipelines).
    pub fn with_params(
        rt: &'rt Runtime,
        method: Method,
        cfg: TrainConfig,
        params: ModelParams,
    ) -> TrainSession<'rt> {
        let hp = AdamHp { lr: cfg.lr, weight_decay: cfg.weight_decay, ..Default::default() };
        let mut rng = Rng::new(cfg.seed ^ 0x10c4);
        let (optimizer, lora, lora_opt, scheduler) = match &method {
            Method::Vanilla | Method::Full => {
                (Optimizer::adamw(hp, StatePolicy::Keep), None, None, None)
            }
            Method::Lisa(lc) => (
                Optimizer::adamw(hp, cfg.state_policy),
                None,
                None,
                Some(LisaScheduler::new(lc.clone(), rt.manifest.n_layers, cfg.seed ^ 0x115a)),
            ),
            Method::Lora => (
                Optimizer::adamw(hp, StatePolicy::Keep),
                Some(LoraState::init(&rt.manifest, &mut rng)),
                Some(AdamW::new(hp, StatePolicy::Keep)),
                None,
            ),
            Method::Galore(ghp) => {
                let mut ghp = *ghp;
                ghp.adam = hp;
                (Optimizer::galore(ghp, cfg.seed ^ 0x6a10), None, None, None)
            }
        };
        TrainSession {
            engine: Engine::new(rt),
            params,
            lora,
            method,
            cfg,
            optimizer,
            lora_opt,
            scheduler,
        }
    }

    fn lr_at(&self, step: usize) -> f32 {
        if self.cfg.warmup > 0 && step < self.cfg.warmup {
            self.cfg.lr * (step + 1) as f32 / self.cfg.warmup as f32
        } else {
            self.cfg.lr
        }
    }

    /// One optimizer step (with microbatch accumulation). Returns the mean
    /// microbatch loss.
    pub fn step(&mut self, step: usize, loader: &mut crate::data::DataLoader) -> Result<f32> {
        let lr = self.lr_at(step);
        self.optimizer.set_lr(lr);
        if let Some(o) = &mut self.lora_opt {
            o.hp.lr = lr;
        }

        let mask = match (&self.method, &mut self.scheduler) {
            (Method::Vanilla, _) => return Ok(0.0),
            (Method::Lisa(_), Some(sched)) => {
                let mask = sched.mask_for_step(step);
                // state policy: drop moments of re-frozen blocks
                self.optimizer.retain_blocks(sched.current_layers());
                mask
            }
            (Method::Lora, _) => TrainMask::none(self.params.n_layers()),
            _ => TrainMask::all(self.params.n_layers()),
        };

        let mut mean_loss = 0.0f32;
        match self.method {
            Method::Lora => {
                let lora = self.lora.as_ref().expect("lora state");
                let mut acc: Option<lora::LoraGrads> = None;
                for _ in 0..self.cfg.grad_accum {
                    let batch = loader.next_batch();
                    let (loss, grads) =
                        lora::forward_backward_lora(&mut self.engine, &self.params, lora, &batch)?;
                    mean_loss += loss;
                    match &mut acc {
                        None => acc = Some(grads),
                        Some(a) => lora::lora_grads_add_assign(a, &grads),
                    }
                }
                let mut grads = acc.unwrap();
                if self.cfg.grad_accum > 1 {
                    lora::lora_grads_scale(&mut grads, 1.0 / self.cfg.grad_accum as f32);
                }
                let opt = self.lora_opt.as_mut().expect("lora optimizer");
                lora::apply_lora_grads(opt, self.lora.as_mut().unwrap(), &grads);
                self.engine
                    .meter
                    .set(MemCategory::OptimState, opt.state_bytes());
            }
            _ => {
                let mut acc: Option<Grads> = None;
                for _ in 0..self.cfg.grad_accum {
                    let batch = loader.next_batch();
                    let out = self.engine.forward_backward(&self.params, &batch, &mask)?;
                    mean_loss += out.loss;
                    match &mut acc {
                        None => acc = Some(out.grads),
                        Some(a) => a.add_assign(&out.grads),
                    }
                }
                let mut grads = acc.unwrap();
                if self.cfg.grad_accum > 1 {
                    grads.scale(1.0 / self.cfg.grad_accum as f32);
                }
                if let Some(max) = self.cfg.max_grad_norm {
                    let norm = grads.global_norm();
                    if norm > max {
                        grads.scale((max / norm) as f32);
                    }
                }
                self.optimizer.apply(
                    &mut self.params,
                    &grads,
                    &self.engine.rt.manifest.block_params,
                );
                self.engine
                    .meter
                    .set(MemCategory::OptimState, self.optimizer.state_bytes());
            }
        }
        Ok(mean_loss / self.cfg.grad_accum as f32)
    }

    /// Run the full schedule, recording curves.
    pub fn run(&mut self, loader: &mut crate::data::DataLoader) -> Result<TrainResult> {
        let mut loss_curve = Vec::with_capacity(self.cfg.steps);
        let mut weight_norms = Vec::new();
        let mut step_times = Vec::with_capacity(self.cfg.steps);
        let mut last = 0.0f32;
        for step in 0..self.cfg.steps {
            let t0 = Instant::now();
            last = self.step(step, loader)?;
            step_times.push(t0.elapsed().as_secs_f64() * 1e3);
            loss_curve.push((step, last));
            if self.cfg.weight_norm_every > 0 && step % self.cfg.weight_norm_every == 0 {
                weight_norms.push((step, self.effective_weight_norms()));
            }
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                log::info!(
                    "[{}] step {step}/{} loss {last:.4} lr {:.2e}",
                    self.method.label(),
                    self.cfg.steps,
                    self.lr_at(step)
                );
            }
        }
        if self.cfg.weight_norm_every > 0 {
            weight_norms.push((self.cfg.steps, self.effective_weight_norms()));
        }
        Ok(TrainResult {
            loss_curve,
            weight_norms,
            peak_mem: self.engine.meter.peak(),
            mem_breakdown: self.engine.meter.breakdown(),
            step_times_ms: step_times,
            bwd_full_calls: self.engine.bwd_full_calls,
            bwd_x_calls: self.engine.bwd_x_calls,
            bwd_skipped: self.engine.bwd_skipped,
            final_train_loss: last,
        })
    }

    /// Layerwise norms of the *effective* weights (LoRA: base + merged
    /// delta — the observable Fig 2 plots).
    pub fn effective_weight_norms(&self) -> Vec<f64> {
        match &self.lora {
            None => self.params.layer_weight_norms(),
            Some(l) => {
                let mut p = self.params.clone();
                l.merge_into(&mut p);
                p.layer_weight_norms()
            }
        }
    }

    /// Merged-parameter view for evaluation (LoRA merges adapters back).
    pub fn eval_params(&self) -> ModelParams {
        match &self.lora {
            None => self.params.clone(),
            Some(l) => {
                let mut p = self.params.clone();
                l.merge_into(&mut p);
                p
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_labels() {
        assert_eq!(Method::Full.label(), "ft");
        assert_eq!(Method::Lisa(LisaConfig::paper(2, 5)).label(), "lisa");
        let mut fixed = LisaConfig::paper(2, 5);
        fixed.fixed = true;
        assert_eq!(Method::Lisa(fixed).label(), "lisa-fix");
    }

    #[test]
    fn warmup_schedule() {
        // lr_at is pure; check via a free function clone of the logic
        let cfg = TrainConfig { lr: 1.0, warmup: 10, ..Default::default() };
        let lr_at = |step: usize| -> f32 {
            if cfg.warmup > 0 && step < cfg.warmup {
                cfg.lr * (step + 1) as f32 / cfg.warmup as f32
            } else {
                cfg.lr
            }
        };
        assert!((lr_at(0) - 0.1).abs() < 1e-6);
        assert!((lr_at(9) - 1.0).abs() < 1e-6);
        assert_eq!(lr_at(50), 1.0);
    }
}
