//! The training loop: a thin deterministic driver over `Box<dyn Strategy>`.
//!
//! Method-specific behaviour (which layers train, which optimizer runs,
//! whether updates land in the base weights or in adapters) lives entirely
//! in `strategy::` — one registered [`crate::strategy::Strategy`] per
//! method. `TrainSession` only owns the engine, the parameters and the
//! schedule, and drives the strategy through the per-step protocol:
//!
//! ```text
//! lr = cfg.lr_at(step)            -> strategy.set_lr(lr)
//! mask = strategy.mask_for_step() -> strategy.on_resample()
//! for each microbatch:               strategy.accumulate_step(...)
//! strategy.apply(...)                (mean, clip, optimizer update)
//! ```

pub mod schedule;

pub use self::schedule::LrSchedule;

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::data::DataLoader;
use crate::engine::Engine;
use crate::model::{checkpoint, ModelParams};
use crate::opt::StatePolicy;
use crate::runtime::Runtime;
use crate::strategy::{Strategy, StrategySpec};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    /// Peak learning rate; `schedule` shapes it over time.
    pub lr: f32,
    pub warmup: usize,
    pub schedule: LrSchedule,
    pub grad_accum: usize,
    pub weight_decay: f32,
    pub max_grad_norm: Option<f64>,
    pub seed: u64,
    /// LISA optimizer-state policy on re-freeze (DESIGN.md §6).
    pub state_policy: StatePolicy,
    /// Record layerwise weight norms every N steps (0 = never) — Fig 2.
    pub weight_norm_every: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            lr: 1e-3,
            warmup: 10,
            schedule: LrSchedule::Warmup,
            grad_accum: 1,
            weight_decay: 0.01,
            max_grad_norm: Some(1.0),
            seed: 42,
            state_policy: StatePolicy::Keep,
            weight_norm_every: 0,
            log_every: 20,
        }
    }
}

impl TrainConfig {
    /// Scheduled learning rate for 0-based step `step`.
    pub fn lr_at(&self, step: usize) -> f32 {
        self.schedule.lr_at(step, self.lr, self.warmup, self.steps)
    }
}

/// Periodic full-state checkpointing for [`TrainSession::run_resumable`]:
/// write the complete training state to `path` every `every` optimizer
/// steps (0 = only once, at the end of the run). Writes are atomic
/// (tmp+rename), so a kill mid-save leaves the previous checkpoint intact.
#[derive(Debug, Clone)]
pub struct CheckpointConf {
    pub path: PathBuf,
    pub every: usize,
}

/// Everything an experiment needs afterwards.
pub struct TrainResult {
    pub loss_curve: Vec<(usize, f32)>,
    /// Layerwise weight-norm trajectory: (step, norms[emb, blocks.., head]).
    pub weight_norms: Vec<(usize, Vec<f64>)>,
    pub peak_mem: u64,
    pub mem_breakdown: Vec<(&'static str, u64)>,
    pub step_times_ms: Vec<f64>,
    pub bwd_full_calls: u64,
    pub bwd_x_calls: u64,
    pub bwd_skipped: u64,
    pub final_train_loss: f32,
}

impl TrainResult {
    pub fn mean_step_ms(&self) -> f64 {
        crate::util::stats::mean(&self.step_times_ms)
    }

    pub fn median_step_ms(&self) -> f64 {
        crate::util::stats::median(&self.step_times_ms)
    }
}

/// One training arm: model + a boxed strategy (optimizer state and any
/// auxiliary parameters live inside the strategy).
pub struct TrainSession<'rt> {
    pub engine: Engine<'rt>,
    pub params: ModelParams,
    pub cfg: TrainConfig,
    strategy: Box<dyn Strategy>,
}

impl<'rt> TrainSession<'rt> {
    /// Fresh-initialized parameters + a strategy built from the registry.
    pub fn new(rt: &'rt Runtime, spec: &StrategySpec, cfg: TrainConfig) -> Result<TrainSession<'rt>> {
        let mut rng = Rng::new(cfg.seed);
        let params = ModelParams::init(&rt.manifest, &mut rng);
        Self::with_params(rt, spec, cfg, params)
    }

    /// Start from existing parameters (continual-pretraining pipelines).
    pub fn with_params(
        rt: &'rt Runtime,
        spec: &StrategySpec,
        cfg: TrainConfig,
        params: ModelParams,
    ) -> Result<TrainSession<'rt>> {
        let strategy = spec.build(&rt.manifest, &cfg)?;
        Ok(Self::from_strategy(rt, strategy, cfg, params))
    }

    /// Drive an already-constructed strategy (programmatic extension point;
    /// the strategy need not be registered).
    pub fn from_strategy(
        rt: &'rt Runtime,
        strategy: Box<dyn Strategy>,
        cfg: TrainConfig,
        params: ModelParams,
    ) -> TrainSession<'rt> {
        // 0 would make step() silently return NaN (0/0) with no update.
        assert!(cfg.grad_accum >= 1, "grad_accum must be >= 1");
        TrainSession { engine: Engine::new(rt), params, cfg, strategy }
    }

    pub fn label(&self) -> &'static str {
        self.strategy.label()
    }

    pub fn strategy(&self) -> &dyn Strategy {
        self.strategy.as_ref()
    }

    /// One optimizer step (with microbatch accumulation). Returns the mean
    /// microbatch loss.
    pub fn step(&mut self, step: usize, loader: &mut crate::data::DataLoader) -> Result<f32> {
        if self.strategy.is_noop() {
            return Ok(0.0);
        }
        self.strategy.set_lr(self.cfg.lr_at(step));
        let mask = self.strategy.mask_for_step(step);
        self.strategy.on_resample();

        let mut mean_loss = 0.0f32;
        for _ in 0..self.cfg.grad_accum {
            let batch = loader.next_batch();
            mean_loss +=
                self.strategy
                    .accumulate_step(&mut self.engine, &self.params, &batch, &mask)?;
        }
        // The strategy reports which parameter tensors its update mutated;
        // the engine drops exactly those device buffers, so next step's
        // uploads scale with the trainable subset (DESIGN.md §8).
        let touched = self.strategy.apply(
            &mut self.engine,
            &mut self.params,
            self.cfg.grad_accum,
            self.cfg.max_grad_norm,
        )?;
        self.engine.invalidate(&touched);
        Ok(mean_loss / self.cfg.grad_accum as f32)
    }

    /// Run the full schedule, recording curves.
    pub fn run(&mut self, loader: &mut DataLoader) -> Result<TrainResult> {
        self.run_from(loader, 0, None)
    }

    /// Crash-safe run: optionally resume from a checkpoint written by a
    /// previous (interrupted) run, and optionally write periodic
    /// checkpoints. The resumed segment replays the uninterrupted run
    /// bit-for-bit (`rust/tests/it_resume.rs`); its `TrainResult` covers
    /// only the steps it actually executed.
    pub fn run_resumable(
        &mut self,
        loader: &mut DataLoader,
        ckpt: Option<&CheckpointConf>,
        resume: Option<&Path>,
    ) -> Result<TrainResult> {
        let start = match resume {
            Some(path) => {
                let next = self.resume_checkpoint(path, loader)?;
                log::info!(
                    "[{}] resumed from {} at step {next}/{}",
                    self.strategy.label(),
                    path.display(),
                    self.cfg.steps
                );
                next
            }
            None => 0,
        };
        self.run_from(loader, start, ckpt)
    }

    fn run_from(
        &mut self,
        loader: &mut DataLoader,
        start: usize,
        ckpt: Option<&CheckpointConf>,
    ) -> Result<TrainResult> {
        let steps = self.cfg.steps;
        let mut loss_curve = Vec::with_capacity(steps.saturating_sub(start));
        let mut weight_norms = Vec::new();
        let mut step_times = Vec::with_capacity(steps.saturating_sub(start));
        let mut last = 0.0f32;
        for step in start..steps {
            let t0 = Instant::now();
            last = self.step(step, loader)?;
            step_times.push(t0.elapsed().as_secs_f64() * 1e3);
            loss_curve.push((step, last));
            if self.cfg.weight_norm_every > 0 && step % self.cfg.weight_norm_every == 0 {
                weight_norms.push((step, self.effective_weight_norms()));
            }
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                log::info!(
                    "[{}] step {step}/{} loss {last:.4} lr {:.2e}",
                    self.strategy.label(),
                    steps,
                    self.cfg.lr_at(step)
                );
            }
            if let Some(c) = ckpt {
                if c.every > 0 && (step + 1) % c.every == 0 && step + 1 < steps {
                    self.save_checkpoint(&c.path, step + 1, loader)?;
                }
            }
        }
        if let Some(c) = ckpt {
            // terminal checkpoint: a restarted job resumes to "done"
            self.save_checkpoint(&c.path, steps, loader)?;
        }
        if self.cfg.weight_norm_every > 0 {
            weight_norms.push((steps, self.effective_weight_norms()));
        }
        Ok(TrainResult {
            loss_curve,
            weight_norms,
            peak_mem: self.engine.meter.peak(),
            mem_breakdown: self.engine.meter.breakdown(),
            step_times_ms: step_times,
            bwd_full_calls: self.engine.bwd_full_calls,
            bwd_x_calls: self.engine.bwd_x_calls,
            bwd_skipped: self.engine.bwd_skipped,
            final_train_loss: last,
        })
    }

    /// Layerwise norms of the *effective* weights (LoRA: base + merged
    /// delta — the observable Fig 2 plots).
    pub fn effective_weight_norms(&self) -> Vec<f64> {
        self.strategy.effective_weight_norms(&self.params)
    }

    /// Merged-parameter view for evaluation (LoRA merges adapters back).
    pub fn eval_params(&self) -> ModelParams {
        self.strategy.eval_params(&self.params)
    }

    /// Write the complete training state — model weights, strategy state
    /// (optimizer moments, sampler RNG/EMA, adapters), loader cursor and
    /// the clock position — as a v2 checkpoint. `next_step` is the first
    /// step the resumed run will execute. Atomic: a kill mid-save leaves
    /// the previous checkpoint intact. Call only at optimizer-step
    /// boundaries (never mid-accumulation).
    pub fn save_checkpoint(
        &self,
        path: &Path,
        next_step: usize,
        loader: &DataLoader,
    ) -> Result<()> {
        let mut meta = checkpoint::Section::new("meta");
        meta.put_str("label", self.strategy.label());
        meta.put_u64("next_step", next_step as u64);
        meta.put_u64("seed", self.cfg.seed);
        meta.put_u64("steps_total", self.cfg.steps as u64);
        let mut strat = checkpoint::Section::new("strategy");
        self.strategy.save_state(&mut strat)?;
        let mut ld = checkpoint::Section::new("loader");
        loader.save_state(&mut ld);
        // engine observables (peak memory, backward-call counters) so the
        // resumed run's TrainResult reports whole-run numbers, not just
        // the post-resume segment's
        let mut eng = checkpoint::Section::new("engine");
        eng.put_u64("bwd_full_calls", self.engine.bwd_full_calls);
        eng.put_u64("bwd_x_calls", self.engine.bwd_x_calls);
        eng.put_u64("bwd_skipped", self.engine.bwd_skipped);
        eng.put_u64("meter.peak", self.engine.meter.peak());
        eng.put_u64s(
            "meter.peak_by_cat",
            self.engine.meter.breakdown().iter().map(|&(_, b)| b).collect(),
        );
        checkpoint::save_sections(
            path,
            &[meta, checkpoint::model_section(&self.params), strat, ld, eng],
        )
    }

    /// Restore the state written by [`TrainSession::save_checkpoint`] into
    /// this freshly-built session (same spec/config) and `loader` (same
    /// dataset). Returns the step to continue from. Every mismatch — a
    /// different method, seed, model shape or dataset size — is an error,
    /// not a silent divergence.
    pub fn resume_checkpoint(
        &mut self,
        path: &Path,
        loader: &mut DataLoader,
    ) -> Result<usize> {
        let mut sections = checkpoint::load_sections(path)?;

        let mut meta = checkpoint::take_section(&mut sections, "meta")?;
        let label = meta.take_str("label")?;
        ensure!(
            label == self.strategy.label(),
            "checkpoint was written by method '{label}', this session runs '{}'",
            self.strategy.label()
        );
        let seed = meta.take_u64("seed")?;
        ensure!(
            seed == self.cfg.seed,
            "checkpoint seed {seed} != configured seed {} — the data/sampler \
             streams would not replay",
            self.cfg.seed
        );
        let next_step = meta.take_u64("next_step")? as usize;
        let steps_total = meta.take_u64("steps_total")? as usize;
        // A checkpoint that is already past this session's horizon must not
        // resume: run_from would execute zero steps and then rewrite the
        // terminal checkpoint as next_step=cfg.steps while the state is
        // really at `next_step` — re-training those steps on a later,
        // longer resume. Shrinking the horizon requires a fresh run.
        ensure!(
            next_step <= self.cfg.steps,
            "checkpoint is at step {next_step} (of a {steps_total}-step run) but this \
             session trains only {} steps — cannot resume into a shorter schedule",
            self.cfg.steps
        );
        checkpoint::ensure_consumed(&meta)?;

        let mut model = checkpoint::take_section(&mut sections, "model")?;
        checkpoint::load_model_section(&mut model, &mut self.params)?;

        let mut strat = checkpoint::take_section(&mut sections, "strategy")?;
        self.strategy.load_state(&mut strat, &self.params)?;
        checkpoint::ensure_consumed(&strat)?;

        let mut ld = checkpoint::take_section(&mut sections, "loader")?;
        loader.load_state(&mut ld)?;
        checkpoint::ensure_consumed(&ld)?;

        let mut eng = checkpoint::take_section(&mut sections, "engine")?;
        self.engine.bwd_full_calls = eng.take_u64("bwd_full_calls")?;
        self.engine.bwd_x_calls = eng.take_u64("bwd_x_calls")?;
        self.engine.bwd_skipped = eng.take_u64("bwd_skipped")?;
        let peak = eng.take_u64("meter.peak")?;
        let by_cat = eng.take_u64s("meter.peak_by_cat")?;
        // `<=`: checkpoints written before a category existed carry a
        // prefix of the canonical order (ALL only ever appends).
        ensure!(
            by_cat.len() <= crate::engine::MemoryMeter::ALL.len(),
            "meter peak blob has {} categories, expected at most {}",
            by_cat.len(),
            crate::engine::MemoryMeter::ALL.len()
        );
        self.engine.meter.restore_peak(peak, &by_cat);
        checkpoint::ensure_consumed(&eng)?;

        ensure!(
            sections.is_empty(),
            "checkpoint has {} unexpected sections ({:?}) — written by a \
             different version?",
            sections.len(),
            sections.iter().map(|s| s.name.clone()).take(4).collect::<Vec<_>>()
        );
        // Model weights and strategy state were rewritten in place: every
        // cached device buffer is now stale.
        self.engine.invalidate_all();
        Ok(next_step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_schedule_matches_legacy_warmup() {
        // The pre-refactor lr_at: lr * (step+1)/warmup during warmup, then lr.
        let cfg = TrainConfig { lr: 1.0, warmup: 10, ..Default::default() };
        assert!((cfg.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((cfg.lr_at(9) - 1.0).abs() < 1e-6);
        assert_eq!(cfg.lr_at(50), 1.0);
    }

    #[test]
    fn cosine_schedule_reaches_floor_at_horizon() {
        let cfg = TrainConfig {
            lr: 1.0,
            warmup: 5,
            steps: 50,
            schedule: LrSchedule::WarmupCosine { min_factor: 0.0 },
            ..Default::default()
        };
        assert!(cfg.lr_at(50) < 1e-3);
        assert!(cfg.lr_at(5) > 0.99);
    }
}
