//! Learning-rate schedules, extracted from the training loop so every
//! strategy and driver shares one implementation (the old `lr_at` was
//! warmup-only and copy-pasted into tests).
//!
//! All schedules are pure functions of `(step, peak, warmup, total_steps)`;
//! `TrainConfig` carries one and `TrainSession` queries it each step.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum LrSchedule {
    /// Peak learning rate from step 0 (no warmup).
    Constant,
    /// Linear warmup over `warmup` steps, then constant at peak — the
    /// original training-loop behaviour and the default.
    #[default]
    Warmup,
    /// Linear warmup, then cosine decay from peak to `min_factor * peak`
    /// over the remaining `total_steps - warmup` steps.
    WarmupCosine { min_factor: f32 },
}

impl LrSchedule {
    /// Parse a CLI name: `constant`, `warmup` (alias `linear-warmup`),
    /// `cosine` (alias `warmup-cosine`, decays to zero).
    pub fn parse(s: &str) -> Result<LrSchedule> {
        Ok(match s {
            "constant" => LrSchedule::Constant,
            "warmup" | "linear-warmup" => LrSchedule::Warmup,
            "cosine" | "warmup-cosine" => LrSchedule::WarmupCosine { min_factor: 0.0 },
            other => bail!("unknown lr schedule '{other}' (constant|warmup|cosine)"),
        })
    }

    /// Learning rate for 0-based optimizer step `step`. `total_steps` is
    /// only consulted by the cosine tail; schedules stay well-defined when
    /// callers step past it (the cosine clamps at its floor).
    pub fn lr_at(&self, step: usize, peak: f32, warmup: usize, total_steps: usize) -> f32 {
        if warmup > 0 && step < warmup && *self != LrSchedule::Constant {
            return peak * (step + 1) as f32 / warmup as f32;
        }
        match *self {
            LrSchedule::Constant | LrSchedule::Warmup => peak,
            LrSchedule::WarmupCosine { min_factor } => {
                let decay_steps = total_steps.saturating_sub(warmup).max(1);
                let t = ((step.saturating_sub(warmup)) as f32 / decay_steps as f32).min(1.0);
                let floor = peak * min_factor;
                floor + (peak - floor) * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_matches_legacy_formula() {
        // The pre-refactor training loop: lr * (step+1)/warmup, then lr.
        let s = LrSchedule::Warmup;
        assert!((s.lr_at(0, 1.0, 10, 100) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(9, 1.0, 10, 100) - 1.0).abs() < 1e-6);
        assert_eq!(s.lr_at(50, 1.0, 10, 100), 1.0);
        // warmup=0 degenerates to constant
        assert_eq!(s.lr_at(0, 1.0, 0, 100), 1.0);
    }

    #[test]
    fn constant_ignores_warmup() {
        let s = LrSchedule::Constant;
        for step in [0usize, 3, 50] {
            assert_eq!(s.lr_at(step, 0.5, 10, 100), 0.5);
        }
    }

    #[test]
    fn cosine_decays_from_peak_to_floor() {
        let s = LrSchedule::WarmupCosine { min_factor: 0.1 };
        // warmup ramp identical to Warmup
        assert!((s.lr_at(0, 1.0, 10, 110) - 0.1).abs() < 1e-6);
        // at end of warmup: peak
        assert!((s.lr_at(10, 1.0, 10, 110) - 1.0).abs() < 1e-4);
        // midpoint of decay: halfway between peak and floor
        assert!((s.lr_at(60, 1.0, 10, 110) - 0.55).abs() < 1e-3);
        // at/after the horizon: floor, clamped
        assert!((s.lr_at(110, 1.0, 10, 110) - 0.1).abs() < 1e-4);
        assert!((s.lr_at(500, 1.0, 10, 110) - 0.1).abs() < 1e-4);
    }

    #[test]
    fn cosine_is_monotone_after_warmup() {
        let s = LrSchedule::WarmupCosine { min_factor: 0.0 };
        let mut prev = f32::MAX;
        for step in 10..100 {
            let lr = s.lr_at(step, 1.0, 10, 100);
            assert!(lr <= prev + 1e-7, "step {step}: {lr} > {prev}");
            prev = lr;
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(LrSchedule::parse("constant").unwrap(), LrSchedule::Constant);
        assert_eq!(LrSchedule::parse("warmup").unwrap(), LrSchedule::Warmup);
        assert_eq!(
            LrSchedule::parse("cosine").unwrap(),
            LrSchedule::WarmupCosine { min_factor: 0.0 }
        );
        assert!(LrSchedule::parse("bogus").is_err());
    }
}
