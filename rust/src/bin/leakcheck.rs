//! Leak regression check for the runtime execute path (the upstream `xla`
//! crate's `execute` leaks input buffers; we use `execute_b` — this binary
//! verifies RSS stays flat over thousands of calls).
use std::path::Path;
use lisa::model::ModelParams;
use lisa::runtime::{HostTensor, Operand, Runtime};
use lisa::util::rng::Rng;

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/statm").unwrap();
    let pages: f64 = s.split_whitespace().nth(1).unwrap().parse().unwrap();
    pages * 4096.0 / 1e6
}

fn main() {
    let rt = Runtime::load(Path::new("artifacts/tiny"), "pallas").unwrap();
    let m = rt.manifest.clone();
    let mut rng = Rng::new(7);
    let params = ModelParams::init(&m, &mut rng);
    let mut h = HostTensor::zeros(&[m.batch, m.seq, m.d_model]);
    rng.fill_normal(&mut h.data, 1.0);
    // lisa-lint: allow(operand_builder): deliberately drives the raw execute path to measure buffer leaks
    let mut ops: Vec<Operand> = vec![Operand::F32(&h)];
    ops.extend(params.blocks[0].iter().map(Operand::F32));
    rt.run("block_fwd", &ops).unwrap();
    let r0 = rss_mb();
    let iters: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2000);
    for i in 0..iters {
        let out = rt.run("block_fwd", &ops).unwrap();
        drop(out);
        if i % 500 == 499 {
            println!("iter {i}: rss {:.1} MB (delta {:+.1})", rss_mb(), rss_mb() - r0);
        }
    }
    let delta = rss_mb() - r0;
    assert!(delta < 50.0, "leak detected: {delta:.1} MB over {iters} calls");
    println!("leakcheck OK ({delta:+.1} MB over {iters} calls)");
}
