//! The dense-gradient strategies: full-parameter AdamW (`ft`), GaLore
//! projection (`galore`) — both train every layer every step and differ
//! only in the optimizer they own — and the no-op `vanilla` baseline.

use anyhow::Result;

use crate::engine::{Batch, Engine, Touched, TrainMask};
use crate::model::checkpoint::Section;
use crate::model::ModelParams;
use crate::opt::{GaloreHp, Optimizer, StatePolicy};
use crate::runtime::Manifest;
use crate::train::TrainConfig;

use super::{adam_hp, GradPath, Strategy};

/// Full-mask training with any `Optimizer` (AdamW for `ft`, the projector
/// stack for `galore`).
pub struct DenseStrategy {
    label: &'static str,
    n_layers: usize,
    path: GradPath,
}

impl DenseStrategy {
    pub fn full(m: &Manifest, cfg: &TrainConfig) -> DenseStrategy {
        DenseStrategy {
            label: "ft",
            n_layers: m.n_layers,
            path: GradPath::new(Optimizer::adamw(adam_hp(cfg), StatePolicy::Keep)),
        }
    }

    pub fn galore(hp: GaloreHp, m: &Manifest, cfg: &TrainConfig) -> DenseStrategy {
        DenseStrategy {
            label: "galore",
            n_layers: m.n_layers,
            path: GradPath::new(Optimizer::galore(hp, StatePolicy::Keep, cfg.seed ^ 0x6a10)),
        }
    }
}

impl Strategy for DenseStrategy {
    fn label(&self) -> &'static str {
        self.label
    }

    fn set_lr(&mut self, lr: f32) {
        self.path.opt.set_lr(lr);
    }

    fn mask_for_step(&mut self, _step: usize) -> TrainMask {
        TrainMask::all(self.n_layers)
    }

    fn accumulate_step(
        &mut self,
        engine: &mut Engine<'_>,
        params: &ModelParams,
        batch: &Batch,
        mask: &TrainMask,
    ) -> Result<f32> {
        self.path.accumulate(engine, params, batch, mask)
    }

    fn apply(
        &mut self,
        engine: &mut Engine<'_>,
        params: &mut ModelParams,
        grad_accum: usize,
        max_grad_norm: Option<f64>,
    ) -> Result<Touched> {
        Ok(self.path.apply_finished(engine, params, grad_accum, max_grad_norm))
    }

    fn state_bytes(&self) -> u64 {
        self.path.opt.state_bytes()
    }

    fn save_state<'a>(&'a self, sec: &mut Section<'a>) -> Result<()> {
        self.path.save_state(sec);
        Ok(())
    }

    fn load_state(&mut self, sec: &mut Section<'_>, params: &ModelParams) -> Result<()> {
        self.path.load_state(sec, &super::param_shape_oracle(params))
    }
}

/// The untrained baseline: every step is a no-op (the driver short-circuits
/// on `is_noop`, so no batches are consumed). Stateless, so the default
/// `save_state`/`load_state` (nothing persisted) are exactly right.
pub struct VanillaStrategy {
    n_layers: usize,
}

impl VanillaStrategy {
    pub fn new(n_layers: usize) -> VanillaStrategy {
        VanillaStrategy { n_layers }
    }
}

impl Strategy for VanillaStrategy {
    fn label(&self) -> &'static str {
        "vanilla"
    }

    fn is_noop(&self) -> bool {
        true
    }

    fn set_lr(&mut self, _lr: f32) {}

    fn mask_for_step(&mut self, _step: usize) -> TrainMask {
        TrainMask::none(self.n_layers)
    }

    fn accumulate_step(
        &mut self,
        _engine: &mut Engine<'_>,
        _params: &ModelParams,
        _batch: &Batch,
        _mask: &TrainMask,
    ) -> Result<f32> {
        Ok(0.0)
    }

    fn apply(
        &mut self,
        _engine: &mut Engine<'_>,
        _params: &mut ModelParams,
        _grad_accum: usize,
        _max_grad_norm: Option<f64>,
    ) -> Result<Touched> {
        Ok(Touched::None)
    }

    fn state_bytes(&self) -> u64 {
        0
    }
}
