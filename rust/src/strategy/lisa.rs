//! LISA as a strategy: wraps the paper's `LisaScheduler` (Algorithm 1,
//! uniform / weighted / fixed sampling) around an AdamW whose state policy
//! decides whether re-frozen blocks keep their moments (DESIGN.md §6).

use anyhow::Result;

use crate::engine::{Batch, Engine, Touched, TrainMask};
use crate::lisa::{LisaConfig, LisaScheduler};
use crate::model::checkpoint::Section;
use crate::model::ModelParams;
use crate::opt::Optimizer;
use crate::runtime::Manifest;
use crate::train::TrainConfig;

use super::{adam_hp, GradPath, Strategy};

pub struct LisaStrategy {
    label: &'static str,
    sched: LisaScheduler,
    path: GradPath,
}

impl LisaStrategy {
    pub fn new(lc: LisaConfig, m: &Manifest, cfg: &TrainConfig) -> LisaStrategy {
        let label = if lc.fixed { "lisa-fix" } else { "lisa" };
        LisaStrategy {
            label,
            // Seed offset matches the pre-refactor TrainSession so existing
            // curves replay identically.
            sched: LisaScheduler::new(lc, m.n_layers, cfg.seed ^ 0x115a),
            path: GradPath::new(Optimizer::adamw(adam_hp(cfg), cfg.state_policy)),
        }
    }

    pub fn scheduler(&self) -> &LisaScheduler {
        &self.sched
    }
}

impl Strategy for LisaStrategy {
    fn label(&self) -> &'static str {
        self.label
    }

    fn set_lr(&mut self, lr: f32) {
        self.path.opt.set_lr(lr);
    }

    fn mask_for_step(&mut self, step: usize) -> TrainMask {
        self.sched.mask_for_step(step)
    }

    fn on_resample(&mut self) {
        // State policy: under Drop, free moments of re-frozen blocks.
        self.path.opt.retain_blocks(self.sched.current_layers());
    }

    fn accumulate_step(
        &mut self,
        engine: &mut Engine<'_>,
        params: &ModelParams,
        batch: &Batch,
        mask: &TrainMask,
    ) -> Result<f32> {
        self.path.accumulate(engine, params, batch, mask)
    }

    fn apply(
        &mut self,
        engine: &mut Engine<'_>,
        params: &mut ModelParams,
        grad_accum: usize,
        max_grad_norm: Option<f64>,
    ) -> Result<Touched> {
        Ok(self.path.apply_finished(engine, params, grad_accum, max_grad_norm))
    }

    fn state_bytes(&self) -> u64 {
        self.path.opt.state_bytes()
    }

    fn save_state<'a>(&'a self, sec: &mut Section<'a>) -> Result<()> {
        self.sched.save_state(sec);
        self.path.save_state(sec);
        Ok(())
    }

    fn load_state(&mut self, sec: &mut Section<'_>, params: &ModelParams) -> Result<()> {
        self.sched.load_state(sec)?;
        self.path.load_state(sec, &super::param_shape_oracle(params))
    }
}
