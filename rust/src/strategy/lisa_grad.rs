//! LISA-grad: gradient-adaptive layerwise importance sampling (the GRASS
//! direction from PAPERS.md). Instead of the paper's uniform draw, each
//! resample weights intermediate blocks by a running EMA of their gradient
//! norms — blocks whose gradients have been large lately are unfrozen more
//! often. Reuses the weighted-without-replacement sampler from `lisa::`
//! and the per-block norm machinery from `engine::Grads`.
//!
//! The EMA starts at 1.0 for every block (first draw ≈ uniform) and only
//! updates for blocks that were unfrozen (their gradients are the only
//! ones ever computed — importance estimates are on-policy, as in GRASS).

use anyhow::Result;

use crate::engine::{Batch, Engine, Grads, Touched, TrainMask};
use crate::lisa::sample_weighted_distinct;
use crate::model::checkpoint::Section;
use crate::model::ModelParams;
use crate::opt::Optimizer;
use crate::train::TrainConfig;
use crate::util::rng::Rng;

use super::{adam_hp, GradPath, Strategy};

/// Floor on sampling weights so every block keeps nonzero probability
/// (mirrors `lisa::importance_weights`).
const WEIGHT_FLOOR: f64 = 1e-6;

pub struct LisaGradStrategy {
    gamma: usize,
    period_k: usize,
    ema_beta: f64,
    /// Per-block gradient-norm EMA, the sampling weight.
    ema: Vec<f64>,
    rng: Rng,
    current: Vec<usize>,
    resamples: usize,
    path: GradPath,
}

impl LisaGradStrategy {
    pub fn new(
        gamma: usize,
        period_k: usize,
        ema_beta: f64,
        n_layers: usize,
        cfg: &TrainConfig,
    ) -> LisaGradStrategy {
        assert!(gamma <= n_layers, "γ={gamma} > L={n_layers}");
        assert!(period_k >= 1, "K must be >= 1");
        LisaGradStrategy {
            gamma,
            period_k,
            ema_beta,
            ema: vec![1.0; n_layers],
            rng: Rng::new(cfg.seed ^ 0x6e11),
            current: Vec::new(),
            resamples: 0,
            path: GradPath::new(Optimizer::adamw(adam_hp(cfg), cfg.state_policy)),
        }
    }

    /// Fold one step's per-block gradient norms into the EMA (frozen
    /// blocks carry `None` and are left untouched).
    fn observe(&mut self, grads: &Grads) {
        for (l, norm) in grads.block_norms().into_iter().enumerate() {
            if let Some(n) = norm {
                self.ema[l] = self.ema_beta * self.ema[l]
                    + (1.0 - self.ema_beta) * n.max(WEIGHT_FLOOR);
            }
        }
    }

    pub fn current_layers(&self) -> &[usize] {
        &self.current
    }

    pub fn n_resamples(&self) -> usize {
        self.resamples
    }

    pub fn ema_weights(&self) -> &[f64] {
        &self.ema
    }
}

impl Strategy for LisaGradStrategy {
    fn label(&self) -> &'static str {
        "lisa-grad"
    }

    fn set_lr(&mut self, lr: f32) {
        self.path.opt.set_lr(lr);
    }

    fn mask_for_step(&mut self, step: usize) -> TrainMask {
        if self.current.is_empty() || step % self.period_k == 0 {
            let w: Vec<f64> = self.ema.iter().map(|&e| e.max(WEIGHT_FLOOR)).collect();
            self.current = sample_weighted_distinct(&mut self.rng, &w, self.gamma);
            self.resamples += 1;
        }
        let mut blocks = vec![false; self.ema.len()];
        for &l in &self.current {
            blocks[l] = true;
        }
        // Embedding and LM head stay trainable every step (Algorithm 1).
        TrainMask { embed: true, head: true, blocks }
    }

    fn on_resample(&mut self) {
        self.path.opt.retain_blocks(&self.current);
    }

    fn accumulate_step(
        &mut self,
        engine: &mut Engine<'_>,
        params: &ModelParams,
        batch: &Batch,
        mask: &TrainMask,
    ) -> Result<f32> {
        self.path.accumulate(engine, params, batch, mask)
    }

    fn apply(
        &mut self,
        engine: &mut Engine<'_>,
        params: &mut ModelParams,
        grad_accum: usize,
        max_grad_norm: Option<f64>,
    ) -> Result<Touched> {
        match self.path.finish(grad_accum, max_grad_norm) {
            Some(grads) => {
                self.observe(&grads);
                Ok(self.path.apply_grads(&grads, engine, params))
            }
            None => Ok(Touched::None),
        }
    }

    fn state_bytes(&self) -> u64 {
        self.path.opt.state_bytes()
    }

    fn save_state<'a>(&'a self, sec: &mut Section<'a>) -> Result<()> {
        sec.put_rng("sampler.rng", &self.rng);
        sec.put_u64s(
            "sampler.current",
            self.current.iter().map(|&l| l as u64).collect(),
        );
        sec.put_u64("sampler.resamples", self.resamples as u64);
        sec.put_f64s("sampler.ema", &self.ema);
        self.path.save_state(sec);
        Ok(())
    }

    fn load_state(&mut self, sec: &mut Section<'_>, params: &ModelParams) -> Result<()> {
        use anyhow::ensure;
        let n_layers = self.ema.len();
        self.rng = sec.take_rng("sampler.rng")?;
        let current = sec.take_u64s("sampler.current")?;
        ensure!(
            current.len() <= n_layers && current.iter().all(|&l| (l as usize) < n_layers),
            "sampler state does not fit {n_layers} layers"
        );
        self.current = current.into_iter().map(|l| l as usize).collect();
        self.resamples = sec.take_u64("sampler.resamples")? as usize;
        let ema = sec.take_f64s("sampler.ema")?;
        ensure!(
            ema.len() == n_layers,
            "EMA arity {} != n_layers {n_layers}",
            ema.len()
        );
        ensure!(
            ema.iter().all(|e| e.is_finite() && *e >= 0.0),
            "corrupt EMA weights in checkpoint"
        );
        self.ema = ema;
        self.path.load_state(sec, &super::param_shape_oracle(params))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn strat(gamma: usize, k: usize, n_layers: usize, seed: u64) -> LisaGradStrategy {
        let cfg = TrainConfig { seed, ..Default::default() };
        LisaGradStrategy::new(gamma, k, 0.5, n_layers, &cfg)
    }

    /// Synthetic Grads: block `hot` gets a large gradient, the rest small.
    fn synthetic_grads(n_layers: usize, hot: usize, live: &[usize]) -> Grads {
        let mut blocks = vec![None; n_layers];
        for &l in live {
            let v = if l == hot { 100.0 } else { 0.01 };
            blocks[l] = Some(vec![HostTensor::from_vec(&[2], vec![v, v])]);
        }
        Grads { blocks, ..Default::default() }
    }

    #[test]
    fn gamma_invariant_and_determinism() {
        let mut a = strat(3, 4, 8, 7);
        let mut b = strat(3, 4, 8, 7);
        for step in 0..40 {
            let ma = a.mask_for_step(step);
            let mb = b.mask_for_step(step);
            assert_eq!(ma, mb, "seeded replay diverged at step {step}");
            assert_eq!(ma.n_trainable_blocks(), 3);
            assert!(ma.embed && ma.head);
            assert_eq!(ma.blocks.len(), 8);
        }
        assert_eq!(a.n_resamples(), 10);
        // a different seed diverges somewhere
        let seq = |seed: u64| -> Vec<TrainMask> {
            let mut s = strat(3, 4, 8, seed);
            (0..40).map(|i| s.mask_for_step(i)).collect()
        };
        assert_ne!(seq(7), seq(8));
    }

    #[test]
    fn ema_tracks_observed_norms() {
        let mut s = strat(2, 1, 4, 3);
        assert_eq!(s.ema_weights(), &[1.0; 4]);
        s.observe(&synthetic_grads(4, 2, &[1, 2]));
        // observed blocks moved, frozen blocks untouched
        assert_eq!(s.ema_weights()[0], 1.0);
        assert_eq!(s.ema_weights()[3], 1.0);
        assert!(s.ema_weights()[2] > 50.0, "hot block must dominate");
        assert!(s.ema_weights()[1] < 1.0, "cold observed block decays");
    }

    #[test]
    fn sampling_follows_gradient_importance() {
        let mut s = strat(1, 1, 4, 9);
        // make block 2's EMA dominate
        for _ in 0..6 {
            s.observe(&synthetic_grads(4, 2, &[0, 1, 2, 3]));
        }
        let mut hits = 0;
        for step in 0..200 {
            let m = s.mask_for_step(step);
            if m.blocks[2] {
                hits += 1;
            }
        }
        assert!(hits > 180, "block 2 sampled only {hits}/200");
    }
}
