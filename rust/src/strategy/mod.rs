//! Fine-tuning strategies behind one trait + a name→constructor registry.
//!
//! Every training method — full-parameter AdamW, LISA and its variants,
//! LoRA adapters, GaLore projection — implements [`Strategy`]; the training
//! loop (`train::TrainSession`) is a thin generic driver over
//! `Box<dyn Strategy>` and never dispatches on a method enum. Adding a new
//! method means writing one impl and one [`Registration`] row (see
//! DESIGN.md §3 — it fits in ~30 lines); the CLI (`lisa train --method`),
//! `lisa exp list` discovery and every experiment driver pick it up through
//! the registry with no further edits.
//!
//! Registered strategies:
//!
//! | name        | summary                                            |
//! |-------------|----------------------------------------------------|
//! | `vanilla`   | no training (baseline rows)                        |
//! | `ft`        | full-parameter AdamW (alias `full`)                |
//! | `lisa`      | Algorithm 1, uniform or weighted sampling          |
//! | `lisa-fix`  | one fixed layer draw (Table 11 ablation)           |
//! | `lisa-grad` | GRASS-style gradient-adaptive importance sampling  |
//! | `lora`      | rank-r adapters on all linear layers               |
//! | `galore`    | rank-r gradient projection                         |

pub mod dense;
pub mod lisa;
pub mod lisa_grad;
pub mod lora;

pub use self::dense::{DenseStrategy, VanillaStrategy};
pub use self::lisa::LisaStrategy;
pub use self::lisa_grad::LisaGradStrategy;
pub use self::lora::LoraStrategy;

use anyhow::{anyhow, ensure, Result};

use crate::engine::{Batch, Engine, Grads, MemCategory, Touched, TrainMask};
use crate::lisa::{LayerDist, LisaConfig};
use crate::model::checkpoint::Section;
use crate::model::ModelParams;
use crate::opt::{AdamHp, GaloreHp, Optimizer};
use crate::runtime::Manifest;
use crate::train::TrainConfig;

/// One fine-tuning method: owns its optimizer state, layer-selection state
/// and any auxiliary parameters (LoRA adapters). The training loop drives
/// it through this interface only.
pub trait Strategy {
    /// Stable arm label for tables/curves ("ft", "lisa", "lora", ...).
    fn label(&self) -> &'static str;

    /// True for strategies that perform no updates (the vanilla baseline);
    /// the driver short-circuits the whole step.
    fn is_noop(&self) -> bool {
        false
    }

    /// Propagate the scheduled learning rate into the owned optimizer(s).
    fn set_lr(&mut self, lr: f32);

    /// Trainable mask for 0-based optimizer step `step`. Sampling
    /// strategies resample here on period boundaries.
    fn mask_for_step(&mut self, step: usize) -> TrainMask;

    /// Called once per step right after `mask_for_step` — the
    /// optimizer-state policy hook (LISA `StatePolicy::Drop` frees moments
    /// of re-frozen blocks here). Default: nothing.
    fn on_resample(&mut self) {}

    /// One microbatch: forward/backward under `mask`, accumulate gradients
    /// into internal state, return the microbatch loss.
    fn accumulate_step(
        &mut self,
        engine: &mut Engine<'_>,
        params: &ModelParams,
        batch: &Batch,
        mask: &TrainMask,
    ) -> Result<f32>;

    /// Consume the accumulated gradients: mean over `grad_accum`
    /// microbatches, clip to `max_grad_norm` where the method does so, and
    /// apply the optimizer update to `params` (or to internal adapters).
    ///
    /// Returns the parameter keys the update mutated — the device-cache
    /// invalidation contract (DESIGN.md §8). Under-reporting makes the
    /// engine serve stale device buffers; over-reporting only costs
    /// re-uploads. Every mutation path must be covered: the optimizer
    /// update here, plus anything exotic a strategy does to `params`.
    fn apply(
        &mut self,
        engine: &mut Engine<'_>,
        params: &mut ModelParams,
        grad_accum: usize,
        max_grad_norm: Option<f64>,
    ) -> Result<Touched>;

    /// Bytes currently held by optimizer state (the Table-1 observable).
    fn state_bytes(&self) -> u64;

    /// Parameters to evaluate: the base model for in-place methods, the
    /// merged model for adapter methods (LoRA's deploy move). The default
    /// is an `eval_view` — same bytes, same store generation — so
    /// periodic evals reuse the engine's warm device cache instead of
    /// evicting it; strategies whose eval weights differ from `base`
    /// (LoRA) must return a real clone (fresh generation).
    fn eval_params(&self, base: &ModelParams) -> ModelParams {
        base.eval_view()
    }

    /// Layerwise norms of the *effective* weights (Fig 2 observable).
    fn effective_weight_norms(&self, base: &ModelParams) -> Vec<f64> {
        base.layer_weight_norms()
    }

    /// Serialize every piece of mutable training state — optimizer
    /// moments, sampler RNG/EMA/draw history, auxiliary parameters — into
    /// `sec`, such that [`Strategy::load_state`] on a freshly built
    /// strategy of the same spec continues the run bit-for-bit
    /// (`rust/tests/it_resume.rs` is the conformance suite). Called only
    /// at optimizer-step boundaries, so per-step accumulators are always
    /// empty. Tensor-sized state (moments, adapters) is *borrowed* into
    /// the section, so saving costs no copy. Default: stateless (the
    /// vanilla baseline).
    fn save_state<'a>(&'a self, _sec: &mut Section<'a>) -> Result<()> {
        Ok(())
    }

    /// Restore the state written by [`Strategy::save_state`]. `params` are
    /// the already-restored (shape-checked) model weights — the size
    /// oracle for validating optimizer slots, so an inconsistent
    /// checkpoint errors here instead of panicking mid-step. Must consume
    /// every entry it wrote; the session errors on leftovers, so a
    /// checkpoint from a different method/config fails loudly instead of
    /// resuming wrong. Default: stateless.
    fn load_state(&mut self, _sec: &mut Section<'_>, _params: &ModelParams) -> Result<()> {
        Ok(())
    }
}

/// Shape oracle over the base model for [`crate::opt::ShapeFn`] callers.
pub(crate) fn param_shape_oracle(
    params: &ModelParams,
) -> impl Fn(crate::model::ParamKey) -> Option<Vec<usize>> + '_ {
    |key| params.get(key).map(|t| t.shape.clone())
}

// ---------------------------------------------------------------------------
// Shared machinery for strategies that carry full `Grads`.
// ---------------------------------------------------------------------------

/// Microbatch gradient accumulator (full-`Grads` strategies).
#[derive(Debug, Default)]
pub struct GradAccum {
    acc: Option<Grads>,
}

impl GradAccum {
    pub fn is_empty(&self) -> bool {
        self.acc.is_none()
    }

    pub fn add(&mut self, g: Grads) {
        match &mut self.acc {
            None => self.acc = Some(g),
            Some(a) => a.add_assign(&g),
        }
    }

    /// Mean over `grad_accum` microbatches plus optional global-norm clip;
    /// `None` when nothing was accumulated this step.
    pub fn finish(&mut self, grad_accum: usize, max_grad_norm: Option<f64>) -> Option<Grads> {
        let mut g = self.acc.take()?;
        if grad_accum > 1 {
            g.scale(1.0 / grad_accum as f32);
        }
        if let Some(max) = max_grad_norm {
            let norm = g.global_norm();
            if norm > max {
                g.scale((max / norm) as f32);
            }
        }
        Some(g)
    }
}

/// Optimizer + accumulator pair owning the full-`Grads` step protocol
/// (forward/backward → accumulate → mean → clip → optimizer update) shared
/// by every strategy that trains base weights (ft, galore, LISA variants).
pub struct GradPath {
    pub opt: Optimizer,
    accum: GradAccum,
}

impl GradPath {
    pub fn new(opt: Optimizer) -> GradPath {
        GradPath { opt, accum: GradAccum::default() }
    }

    /// One microbatch: forward/backward under `mask`, accumulate, return
    /// the loss.
    pub fn accumulate(
        &mut self,
        engine: &mut Engine<'_>,
        params: &ModelParams,
        batch: &Batch,
        mask: &TrainMask,
    ) -> Result<f32> {
        let out = engine.forward_backward(params, batch, mask)?;
        self.accum.add(out.grads);
        Ok(out.loss)
    }

    /// Mean + clip the accumulated gradients (see [`GradAccum::finish`]).
    pub fn finish(&mut self, grad_accum: usize, max_grad_norm: Option<f64>) -> Option<Grads> {
        self.accum.finish(grad_accum, max_grad_norm)
    }

    /// Apply a finished gradient set through the optimizer + refresh the
    /// meter. Returns the mutated keys for device-cache invalidation.
    pub fn apply_grads(
        &mut self,
        grads: &Grads,
        engine: &mut Engine<'_>,
        params: &mut ModelParams,
    ) -> Touched {
        let rt = engine.rt;
        self.opt.apply(params, grads, &rt.manifest.block_params);
        engine.meter.set(MemCategory::OptimState, self.opt.state_bytes());
        Touched::from_grads(grads)
    }

    /// Serialize the owned optimizer (the accumulator never persists —
    /// checkpoints happen at step boundaries where it is empty).
    pub fn save_state<'a>(&'a self, sec: &mut Section<'a>) {
        debug_assert!(self.accum.is_empty(), "checkpoint mid-accumulation");
        self.opt.save_state(sec);
    }

    pub fn load_state(
        &mut self,
        sec: &mut Section<'_>,
        shape: crate::opt::ShapeFn<'_>,
    ) -> Result<()> {
        self.accum = GradAccum::default();
        self.opt.load_state(sec, shape)
    }

    /// `finish` + `apply_grads` in one go — the whole `Strategy::apply`
    /// body for strategies with no per-step observation.
    pub fn apply_finished(
        &mut self,
        engine: &mut Engine<'_>,
        params: &mut ModelParams,
        grad_accum: usize,
        max_grad_norm: Option<f64>,
    ) -> Touched {
        match self.finish(grad_accum, max_grad_norm) {
            Some(grads) => self.apply_grads(&grads, engine, params),
            None => Touched::None,
        }
    }
}

/// AdamW hyperparameters every strategy derives from the train config.
pub(crate) fn adam_hp(cfg: &TrainConfig) -> AdamHp {
    AdamHp { lr: cfg.lr, weight_decay: cfg.weight_decay, ..Default::default() }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Method-specific options, CLI-shaped (string key → value). Builders read
/// the keys they understand and ignore the rest, so one spec can be routed
/// to any strategy.
#[derive(Debug, Clone, Default)]
pub struct StrategyOpts {
    pairs: Vec<(String, String)>,
}

impl StrategyOpts {
    pub fn set(&mut self, key: &str, val: impl std::fmt::Display) {
        let v = val.to_string();
        match self.pairs.iter_mut().find(|(k, _)| k.as_str() == key) {
            Some(p) => p.1 = v,
            None => self.pairs.push((key.to_string(), v)),
        }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .find(|(k, _)| k.as_str() == key)
            .map(|(_, v)| v.as_str())
    }

    fn parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("strategy option '{key}': cannot parse '{s}'")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        self.parsed(key, default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        self.parsed(key, default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        self.parsed(key, default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        self.parsed(key, default)
    }

    /// Comma-separated f64 list (`"0.25,1.0,0.25"`).
    pub fn f64_list(&self, key: &str) -> Result<Option<Vec<f64>>> {
        let Some(s) = self.get(key) else { return Ok(None) };
        let mut out = Vec::new();
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let v: f64 = part
                .trim()
                .parse()
                .map_err(|_| anyhow!("strategy option '{key}': cannot parse '{part}' as f64"))?;
            out.push(v);
        }
        Ok(Some(out))
    }
}

/// Declarative arm description: a registered name plus its options. The
/// experiment drivers and the CLI both build arms from these, so the set of
/// runnable methods is exactly the registry.
#[derive(Debug, Clone)]
pub struct StrategySpec {
    pub name: String,
    pub opts: StrategyOpts,
}

impl StrategySpec {
    pub fn new(name: &str) -> StrategySpec {
        StrategySpec { name: name.to_string(), opts: StrategyOpts::default() }
    }

    pub fn with(mut self, key: &str, val: impl std::fmt::Display) -> StrategySpec {
        self.opts.set(key, val);
        self
    }

    // Sugar for the common arms (still plain specs underneath).
    pub fn vanilla() -> StrategySpec {
        StrategySpec::new("vanilla")
    }

    pub fn ft() -> StrategySpec {
        StrategySpec::new("ft")
    }

    pub fn lora() -> StrategySpec {
        StrategySpec::new("lora")
    }

    pub fn galore(rank: usize) -> StrategySpec {
        StrategySpec::new("galore").with("rank", rank)
    }

    pub fn lisa(gamma: usize, period: usize) -> StrategySpec {
        StrategySpec::new("lisa").with("gamma", gamma).with("period", period)
    }

    pub fn lisa_fixed(gamma: usize, period: usize) -> StrategySpec {
        StrategySpec::new("lisa-fix").with("gamma", gamma).with("period", period)
    }

    pub fn lisa_weighted(gamma: usize, period: usize, weights: &[f64]) -> StrategySpec {
        let w = weights.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
        StrategySpec::lisa(gamma, period).with("weights", w)
    }

    pub fn lisa_grad(gamma: usize, period: usize) -> StrategySpec {
        StrategySpec::new("lisa-grad").with("gamma", gamma).with("period", period)
    }

    /// Alias-aware name check (`spec.is("vanilla")`).
    pub fn is(&self, name: &str) -> bool {
        canonical(&self.name) == canonical(name)
    }

    /// Paper-scaled default learning rate (Table 15 search: LISA/LoRA run
    /// ~10x the FT rate).
    pub fn default_lr(&self) -> f32 {
        lookup(&self.name).map(|r| r.default_lr).unwrap_or(1e-3)
    }

    pub fn build(&self, m: &Manifest, cfg: &TrainConfig) -> Result<Box<dyn Strategy>> {
        let reg = lookup(&self.name).ok_or_else(|| {
            anyhow!("unknown strategy '{}' — registered: {}", self.name, names().join(", "))
        })?;
        (reg.build)(&self.opts, m, cfg)
    }
}

/// One registry row. To add a method: implement [`Strategy`], write a
/// builder with this signature, append a row to [`REGISTRY`].
pub struct Registration {
    pub name: &'static str,
    pub summary: &'static str,
    pub default_lr: f32,
    pub build: fn(&StrategyOpts, &Manifest, &TrainConfig) -> Result<Box<dyn Strategy>>,
}

static REGISTRY: &[Registration] = &[
    Registration {
        name: "vanilla",
        summary: "no training (baseline rows in Tables 2/3/5)",
        default_lr: 0.0,
        build: build_vanilla,
    },
    Registration {
        name: "ft",
        summary: "full-parameter AdamW fine-tuning (alias: full)",
        default_lr: 1e-3,
        build: build_ft,
    },
    Registration {
        name: "lisa",
        summary: "layerwise importance sampled AdamW (Algorithm 1; uniform or --weights)",
        default_lr: 3e-3,
        build: build_lisa,
    },
    Registration {
        name: "lisa-fix",
        summary: "LISA with a single fixed layer draw (Table 11 ablation)",
        default_lr: 3e-3,
        build: build_lisa_fix,
    },
    Registration {
        name: "lisa-grad",
        summary: "gradient-adaptive LISA: resample by per-block grad-norm EMA (GRASS direction)",
        default_lr: 3e-3,
        build: build_lisa_grad,
    },
    Registration {
        name: "lora",
        summary: "rank-r adapters on all linear layers, base weights frozen",
        default_lr: 3e-3,
        build: build_lora,
    },
    Registration {
        name: "galore",
        summary: "rank-r gradient projection (GaLore baseline)",
        default_lr: 1e-3,
        build: build_galore,
    },
];

pub fn registry() -> &'static [Registration] {
    REGISTRY
}

pub fn lookup(name: &str) -> Option<&'static Registration> {
    let name = match name {
        "full" => "ft",
        "lisa-fixed" => "lisa-fix",
        n => n,
    };
    REGISTRY.iter().find(|r| r.name == name)
}

pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|r| r.name).collect()
}

/// Resolve aliases to the registered name; unknown names pass through.
pub fn canonical(name: &str) -> &str {
    lookup(name).map(|r| r.name).unwrap_or(name)
}

// ---------------------------------------------------------------------------
// Builders
// ---------------------------------------------------------------------------

fn build_vanilla(_o: &StrategyOpts, m: &Manifest, _cfg: &TrainConfig) -> Result<Box<dyn Strategy>> {
    Ok(Box::new(VanillaStrategy::new(m.n_layers)))
}

fn build_ft(_o: &StrategyOpts, m: &Manifest, cfg: &TrainConfig) -> Result<Box<dyn Strategy>> {
    Ok(Box::new(DenseStrategy::full(m, cfg)))
}

fn lisa_config(o: &StrategyOpts, m: &Manifest, fixed: bool) -> Result<LisaConfig> {
    let mut lc = LisaConfig::paper(o.usize_or("gamma", 2)?, o.usize_or("period", 10)?);
    lc.fixed = o.bool_or("fixed", fixed)?;
    lc.train_embed = o.bool_or("train-embed", true)?;
    lc.train_head = o.bool_or("train-head", true)?;
    if let Some(w) = o.f64_list("weights")? {
        ensure!(
            w.len() == m.n_layers,
            "lisa weights arity {} != n_layers {}",
            w.len(),
            m.n_layers
        );
        lc.dist = LayerDist::Weighted(w);
    }
    ensure!(lc.gamma <= m.n_layers, "γ={} > L={}", lc.gamma, m.n_layers);
    Ok(lc)
}

fn build_lisa(o: &StrategyOpts, m: &Manifest, cfg: &TrainConfig) -> Result<Box<dyn Strategy>> {
    Ok(Box::new(LisaStrategy::new(lisa_config(o, m, false)?, m, cfg)))
}

fn build_lisa_fix(o: &StrategyOpts, m: &Manifest, cfg: &TrainConfig) -> Result<Box<dyn Strategy>> {
    Ok(Box::new(LisaStrategy::new(lisa_config(o, m, true)?, m, cfg)))
}

fn build_lisa_grad(o: &StrategyOpts, m: &Manifest, cfg: &TrainConfig) -> Result<Box<dyn Strategy>> {
    let gamma = o.usize_or("gamma", 2)?;
    let period = o.usize_or("period", 10)?;
    let beta = o.f64_or("ema-beta", 0.9)?;
    ensure!(gamma <= m.n_layers, "γ={} > L={}", gamma, m.n_layers);
    ensure!((0.0..1.0).contains(&beta), "ema-beta must be in [0, 1), got {beta}");
    Ok(Box::new(LisaGradStrategy::new(gamma, period, beta, m.n_layers, cfg)))
}

fn build_lora(_o: &StrategyOpts, m: &Manifest, cfg: &TrainConfig) -> Result<Box<dyn Strategy>> {
    Ok(Box::new(LoraStrategy::new(m, cfg)))
}

fn build_galore(o: &StrategyOpts, m: &Manifest, cfg: &TrainConfig) -> Result<Box<dyn Strategy>> {
    let d = GaloreHp::default();
    let hp = GaloreHp {
        adam: adam_hp(cfg),
        rank: o.usize_or("rank", d.rank)?,
        update_proj_gap: o.usize_or("update-proj-gap", d.update_proj_gap)?,
        scale: o.f32_or("scale", d.scale)?,
        power_iters: o.usize_or("power-iters", d.power_iters)?,
    };
    Ok(Box::new(DenseStrategy::galore(hp, m, cfg)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lookup_and_aliases() {
        for n in ["vanilla", "ft", "lisa", "lisa-fix", "lisa-grad", "lora", "galore"] {
            assert!(lookup(n).is_some(), "missing registration '{n}'");
        }
        assert_eq!(lookup("full").unwrap().name, "ft");
        assert_eq!(canonical("full"), "ft");
        assert_eq!(canonical("nope"), "nope");
        assert!(lookup("nope").is_none());
    }

    #[test]
    fn spec_is_alias_aware() {
        assert!(StrategySpec::new("full").is("ft"));
        assert!(StrategySpec::vanilla().is("vanilla"));
        assert!(!StrategySpec::ft().is("lisa"));
    }

    #[test]
    fn default_lrs_match_paper_scaling() {
        assert_eq!(StrategySpec::vanilla().default_lr(), 0.0);
        assert_eq!(StrategySpec::ft().default_lr(), 1e-3);
        assert_eq!(StrategySpec::lisa(2, 5).default_lr(), 3e-3);
        assert_eq!(StrategySpec::lora().default_lr(), 3e-3);
        assert_eq!(StrategySpec::galore(8).default_lr(), 1e-3);
        assert_eq!(StrategySpec::lisa_grad(2, 5).default_lr(), 3e-3);
    }

    #[test]
    fn opts_roundtrip_and_overwrite() {
        let mut o = StrategyOpts::default();
        o.set("gamma", 4usize);
        o.set("gamma", 8usize);
        o.set("scale", 1.0f32);
        assert_eq!(o.usize_or("gamma", 2).unwrap(), 8);
        assert_eq!(o.f32_or("scale", 0.25).unwrap(), 1.0);
        assert_eq!(o.usize_or("missing", 7).unwrap(), 7);
        assert_eq!(o.get("scale"), Some("1"));
    }

    #[test]
    fn weights_list_roundtrip() {
        let spec = StrategySpec::lisa_weighted(2, 5, &[0.25, 1.0, 0.5]);
        let w = spec.opts.f64_list("weights").unwrap().unwrap();
        assert_eq!(w, vec![0.25, 1.0, 0.5]);
        assert!(StrategySpec::lisa(2, 5).opts.f64_list("weights").unwrap().is_none());
    }
}
