//! LoRA as a strategy: owns the adapter tensors and their AdamW; the base
//! model is never touched during training and merged only for evaluation.

use anyhow::Result;

use crate::engine::{Batch, Engine, MemCategory, Touched, TrainMask};
use crate::lora::{self, LoraGrads, LoraState};
use crate::model::checkpoint::Section;
use crate::model::ModelParams;
use crate::opt::{AdamW, StatePolicy};
use crate::runtime::Manifest;
use crate::train::TrainConfig;
use crate::util::rng::Rng;

use super::{adam_hp, Strategy};

pub struct LoraStrategy {
    lora: LoraState,
    opt: AdamW,
    acc: Option<LoraGrads>,
    n_layers: usize,
}

impl LoraStrategy {
    pub fn new(m: &Manifest, cfg: &TrainConfig) -> LoraStrategy {
        // Seed offset matches the pre-refactor TrainSession adapter init.
        let mut rng = Rng::new(cfg.seed ^ 0x10c4);
        LoraStrategy {
            lora: LoraState::init(m, &mut rng),
            opt: AdamW::new(adam_hp(cfg), StatePolicy::Keep),
            acc: None,
            n_layers: m.n_layers,
        }
    }

    pub fn adapters(&self) -> &LoraState {
        &self.lora
    }
}

impl Strategy for LoraStrategy {
    fn label(&self) -> &'static str {
        "lora"
    }

    fn set_lr(&mut self, lr: f32) {
        self.opt.hp.lr = lr;
    }

    fn mask_for_step(&mut self, _step: usize) -> TrainMask {
        // Base weights and embed/head are frozen; training happens in the
        // adapters via the dedicated LoRA artifacts.
        TrainMask::none(self.n_layers)
    }

    fn accumulate_step(
        &mut self,
        engine: &mut Engine<'_>,
        params: &ModelParams,
        batch: &Batch,
        _mask: &TrainMask,
    ) -> Result<f32> {
        let (loss, grads) = lora::forward_backward_lora(engine, params, &self.lora, batch)?;
        match &mut self.acc {
            None => self.acc = Some(grads),
            Some(a) => lora::lora_grads_add_assign(a, &grads),
        }
        Ok(loss)
    }

    fn apply(
        &mut self,
        engine: &mut Engine<'_>,
        _params: &mut ModelParams,
        grad_accum: usize,
        _max_grad_norm: Option<f64>,
    ) -> Result<Touched> {
        let Some(mut grads) = self.acc.take() else { return Ok(Touched::None) };
        if grad_accum > 1 {
            lora::lora_grads_scale(&mut grads, 1.0 / grad_accum as f32);
        }
        lora::apply_lora_grads(&mut self.opt, &mut self.lora, &grads);
        engine.meter.set(MemCategory::OptimState, self.opt.state_bytes());
        // Base weights stay frozen (their cached device buffers survive
        // forever under LoRA); only the adapters were mutated.
        Ok(Touched::Keys(self.lora.touched_keys()))
    }

    fn state_bytes(&self) -> u64 {
        self.opt.state_bytes()
    }

    fn eval_params(&self, base: &ModelParams) -> ModelParams {
        let mut p = base.clone();
        self.lora.merge_into(&mut p);
        p
    }

    fn effective_weight_norms(&self, base: &ModelParams) -> Vec<f64> {
        self.eval_params(base).layer_weight_norms()
    }

    fn save_state<'a>(&'a self, sec: &mut Section<'a>) -> Result<()> {
        debug_assert!(self.acc.is_none(), "checkpoint mid-accumulation");
        for (l, layer) in self.lora.adapters.iter().enumerate() {
            for (i, t) in layer.iter().enumerate() {
                sec.put_tensor(&format!("adapter.{l}.{i}"), t);
            }
        }
        crate::opt::save_adamw_state(&self.opt, sec);
        Ok(())
    }

    fn load_state(&mut self, sec: &mut Section<'_>, _params: &ModelParams) -> Result<()> {
        use anyhow::ensure;
        for (l, layer) in self.lora.adapters.iter_mut().enumerate() {
            for (i, t) in layer.iter_mut().enumerate() {
                let name = format!("adapter.{l}.{i}");
                let loaded = sec.take_tensor(&name)?;
                ensure!(
                    loaded.shape == t.shape,
                    "adapter '{name}': shape {:?} != expected {:?}",
                    loaded.shape,
                    t.shape
                );
                *t = loaded;
            }
        }
        self.acc = None;
        // the optimizer's slots live on the adapters, not the base model —
        // size-check them against the (just-restored) adapter shapes
        let adapters = &self.lora.adapters;
        let shape = |key: crate::model::ParamKey| -> Option<Vec<usize>> {
            match key {
                crate::model::ParamKey::Lora(l, i) => {
                    adapters.get(l)?.get(i).map(|t| t.shape.clone())
                }
                _ => None,
            }
        };
        crate::opt::load_adamw_state(&mut self.opt, sec, &shape)
    }
}
