//! Serving metrics for `GET /metrics` (DESIGN.md §11): request/status
//! counters, queue depth, TTFT and per-request throughput histograms,
//! plus a snapshot of the engine's per-segment `ExecStats` and the serve
//! loop's `LoopStats`, rendered in the Prometheus text exposition format.
//!
//! Everything the HTTP workers touch per request is an atomic or a
//! lock-free `Histogram`; the only lock is around the engine snapshot,
//! which the model thread refreshes (throttled, from `observe`) and the
//! `/metrics` handler clones — neither side ever holds it across I/O.

// Clippy backstop for the no-panic serving contract (DESIGN.md §13,
// enforced structurally by lisa-lint's serve_panic pass).
#![warn(clippy::unwrap_used, clippy::expect_used)]
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::engine::{FailClass, LoopStats};
use crate::runtime::{CacheStats, ExecStats};
use crate::util::hist::Histogram;

/// Status codes with dedicated counters; anything else lands in `other`.
const STATUS_CODES: [u16; 8] = [200, 400, 404, 405, 413, 429, 500, 503];

/// Failure classes with dedicated counters (`lisa_serve_failures_total`).
const FAIL_CLASSES: [FailClass; 3] =
    [FailClass::Internal, FailClass::Overloaded, FailClass::Cancelled];

/// Engine-side observables, copied out of the model thread.
#[derive(Debug, Default, Clone)]
pub struct EngineSnapshot {
    pub segments: BTreeMap<String, ExecStats>,
    pub loops: LoopStats,
    /// Device parameter-cache snapshot; feeds the per-format
    /// resident-bytes gauges (quantized residency, DESIGN.md §15).
    pub cache: CacheStats,
}

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    /// Seconds from admission-queue entry to the first committed token.
    pub ttft: Histogram,
    /// Generated tokens per wall-clock second, one sample per finished
    /// request (wall clock includes queueing and prefill — the number a
    /// client actually experiences).
    pub tok_rate: Histogram,
    /// Requests sitting in the admission queue right now.
    queue_depth: AtomicUsize,
    status: [AtomicU64; STATUS_CODES.len()],
    status_other: AtomicU64,
    /// Terminal request failures by [`FailClass`], counted at the sink
    /// (the serve loop's `on_fail`), independent of what HTTP status the
    /// worker later manages to write.
    failures: [AtomicU64; FAIL_CLASSES.len()],
    tokens_out: AtomicU64,
    completions: AtomicU64,
    /// Set by request completion, cleared by the model thread when it
    /// refreshes the engine snapshot — keeps `observe` cheap on the
    /// decode hot path while guaranteeing a fresh snapshot after bursts.
    dirty: AtomicBool,
    engine: Mutex<EngineSnapshot>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            ttft: Histogram::exponential(1e-3, 2.0, 15), // 1 ms .. ~16 s
            tok_rate: Histogram::exponential(1.0, 2.0, 16), // 1 .. ~32k tok/s
            queue_depth: AtomicUsize::new(0),
            status: Default::default(),
            status_other: AtomicU64::new(0),
            failures: Default::default(),
            tokens_out: AtomicU64::new(0),
            completions: AtomicU64::new(0),
            dirty: AtomicBool::new(false),
            engine: Mutex::new(EngineSnapshot::default()),
        }
    }

    pub fn inc_status(&self, code: u16) {
        match STATUS_CODES.iter().position(|c| *c == code) {
            Some(i) => self.status[i].fetch_add(1, Ordering::Relaxed),
            None => self.status_other.fetch_add(1, Ordering::Relaxed),
        };
    }

    pub fn status_count(&self, code: u16) -> u64 {
        match STATUS_CODES.iter().position(|c| *c == code) {
            Some(i) => self.status[i].load(Ordering::Relaxed),
            None => self.status_other.load(Ordering::Relaxed),
        }
    }

    /// Count a terminal request failure by class.
    pub fn fail(&self, class: FailClass) {
        if let Some(i) = FAIL_CLASSES.iter().position(|c| *c == class) {
            self.failures[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn fail_count(&self, class: FailClass) -> u64 {
        match FAIL_CLASSES.iter().position(|c| *c == class) {
            Some(i) => self.failures[i].load(Ordering::Relaxed),
            None => 0,
        }
    }

    pub fn enqueue(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dequeue(&self) {
        // saturating: enqueue/dequeue race only in the direction of a
        // transiently high reading, never an underflow panic
        let _ = self
            .queue_depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| d.checked_sub(1));
    }

    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Called by the sink when a request finishes: `n` generated tokens
    /// over `dur_s` of wall clock.
    pub fn request_done(&self, n: u64, dur_s: f64) {
        if n > 0 && dur_s > 0.0 {
            self.tok_rate.observe(n as f64 / dur_s);
        }
        self.tokens_out.fetch_add(n, Ordering::Relaxed);
        self.completions.fetch_add(1, Ordering::Relaxed);
        self.dirty.store(true, Ordering::Release);
    }

    pub fn completions(&self) -> u64 {
        self.completions.load(Ordering::Relaxed)
    }

    pub fn tokens_out(&self) -> u64 {
        self.tokens_out.load(Ordering::Relaxed)
    }

    /// True once per completion burst: the model thread uses this to
    /// decide when a full (segment-stats) snapshot refresh is due.
    pub fn take_dirty(&self) -> bool {
        self.dirty.swap(false, Ordering::Acquire)
    }

    pub fn set_engine(&self, snap: EngineSnapshot) {
        // a writer that panicked mid-store left a stale-but-consistent
        // snapshot behind: metrics keep flowing rather than cascading
        // the poison into /metrics handlers
        *self.engine.lock().unwrap_or_else(std::sync::PoisonError::into_inner) = snap;
    }

    /// Cheap per-iteration update: loop counters only, segments kept.
    pub fn set_loop(&self, loops: LoopStats) {
        self.engine.lock().unwrap_or_else(std::sync::PoisonError::into_inner).loops = loops;
    }

    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Prometheus text exposition (version 0.0.4).
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut o = String::with_capacity(4096);

        let _ = writeln!(o, "# HELP lisa_http_requests_total HTTP responses by status code.");
        let _ = writeln!(o, "# TYPE lisa_http_requests_total counter");
        for (i, code) in STATUS_CODES.iter().enumerate() {
            let _ = writeln!(
                o,
                "lisa_http_requests_total{{code=\"{code}\"}} {}",
                self.status[i].load(Ordering::Relaxed)
            );
        }
        let _ = writeln!(
            o,
            "lisa_http_requests_total{{code=\"other\"}} {}",
            self.status_other.load(Ordering::Relaxed)
        );

        let _ = writeln!(o, "# HELP lisa_http_queue_depth Requests waiting in the admission queue.");
        let _ = writeln!(o, "# TYPE lisa_http_queue_depth gauge");
        let _ = writeln!(o, "lisa_http_queue_depth {}", self.queue_depth());

        let _ = writeln!(o, "# HELP lisa_serve_completions_total Finished completion requests.");
        let _ = writeln!(o, "# TYPE lisa_serve_completions_total counter");
        let _ = writeln!(o, "lisa_serve_completions_total {}", self.completions());

        let _ = writeln!(o, "# HELP lisa_generated_tokens_total Tokens delivered to clients.");
        let _ = writeln!(o, "# TYPE lisa_generated_tokens_total counter");
        let _ = writeln!(o, "lisa_generated_tokens_total {}", self.tokens_out());

        let _ = writeln!(o, "# HELP lisa_serve_ttft_seconds Queue entry to first committed token.");
        let _ = writeln!(o, "# TYPE lisa_serve_ttft_seconds histogram");
        self.ttft.render_prometheus("lisa_serve_ttft_seconds", &mut o);
        for (q, name) in [(0.5, "lisa_serve_ttft_p50_seconds"), (0.99, "lisa_serve_ttft_p99_seconds")] {
            let _ = writeln!(o, "# TYPE {name} gauge");
            let _ = writeln!(o, "{name} {}", self.ttft.quantile(q));
        }

        let _ = writeln!(o, "# HELP lisa_serve_tokens_per_sec Per-request generation throughput.");
        let _ = writeln!(o, "# TYPE lisa_serve_tokens_per_sec histogram");
        self.tok_rate.render_prometheus("lisa_serve_tokens_per_sec", &mut o);
        for (q, name) in [(0.5, "lisa_serve_tokens_per_sec_p50"), (0.99, "lisa_serve_tokens_per_sec_p99")] {
            let _ = writeln!(o, "# TYPE {name} gauge");
            let _ = writeln!(o, "{name} {}", self.tok_rate.quantile(q));
        }

        let _ = writeln!(
            o,
            "# HELP lisa_serve_failures_total Terminal request failures by class \
             (internal = error drain, overloaded = pool pressure, cancelled = client gone)."
        );
        let _ = writeln!(o, "# TYPE lisa_serve_failures_total counter");
        for (i, class) in FAIL_CLASSES.iter().enumerate() {
            let _ = writeln!(
                o,
                "lisa_serve_failures_total{{class=\"{}\"}} {}",
                class.label(),
                self.failures[i].load(Ordering::Relaxed)
            );
        }

        let _ = writeln!(o, "# HELP lisa_serve_uptime_seconds Seconds since the server started.");
        let _ = writeln!(o, "# TYPE lisa_serve_uptime_seconds gauge");
        let _ = writeln!(o, "lisa_serve_uptime_seconds {}", self.uptime_s());

        let snap = self.engine.lock().unwrap_or_else(std::sync::PoisonError::into_inner).clone();
        let l = snap.loops;
        for (name, help, v) in [
            ("lisa_serve_decode_steps_total", "Batched decode_step executions.", l.decode_steps),
            ("lisa_serve_batch_prefills_total", "Batched prefill executions.", l.batch_prefills),
            (
                "lisa_serve_streamed_prompt_tokens_total",
                "Prompt tokens streamed through vacant decode rows.",
                l.streamed_prompt_tokens,
            ),
            ("lisa_serve_admitted_total", "Requests admitted into decode rows.", l.admitted),
            ("lisa_serve_retries_total", "Failed executions retried in place.", l.retries),
            (
                "lisa_serve_reprefills_total",
                "Rows rebuilt from scratch after a quarantine.",
                l.reprefills,
            ),
            (
                "lisa_serve_error_drains_total",
                "Rows drained with a terminal error.",
                l.error_drains,
            ),
            (
                "lisa_serve_preemptions_total",
                "Rows parked (pages released) under pool pressure.",
                l.preemptions,
            ),
            ("lisa_serve_cancelled_total", "Rows drained on client cancellation.", l.cancelled),
            (
                "lisa_serve_rejected_total",
                "Requests refused at admission (pool reservation failed).",
                l.rejected,
            ),
        ] {
            let _ = writeln!(o, "# HELP {name} {help}");
            let _ = writeln!(o, "# TYPE {name} counter");
            let _ = writeln!(o, "{name} {v}");
        }
        let _ = writeln!(o, "# HELP lisa_serve_live_rows Decode rows currently occupied.");
        let _ = writeln!(o, "# TYPE lisa_serve_live_rows gauge");
        let _ = writeln!(o, "lisa_serve_live_rows {}", l.live_rows);

        let _ = writeln!(
            o,
            "# HELP lisa_device_resident_bytes Parameter bytes resident on device by storage format."
        );
        let _ = writeln!(o, "# TYPE lisa_device_resident_bytes gauge");
        let _ = writeln!(
            o,
            "lisa_device_resident_bytes{{format=\"f32\"}} {}",
            snap.cache.resident_f32_bytes
        );
        let _ = writeln!(
            o,
            "lisa_device_resident_bytes{{format=\"i8\"}} {}",
            snap.cache.resident_i8_bytes
        );

        if !snap.segments.is_empty() {
            let _ = writeln!(o, "# HELP lisa_segment_calls_total Executions per compiled segment.");
            let _ = writeln!(o, "# TYPE lisa_segment_calls_total counter");
            for (seg, s) in &snap.segments {
                let _ = writeln!(o, "lisa_segment_calls_total{{segment=\"{seg}\"}} {}", s.calls);
            }
            let _ = writeln!(o, "# HELP lisa_segment_seconds_total Wall clock per compiled segment.");
            let _ = writeln!(o, "# TYPE lisa_segment_seconds_total counter");
            for (seg, s) in &snap.segments {
                let _ = writeln!(
                    o,
                    "lisa_segment_seconds_total{{segment=\"{seg}\"}} {}",
                    s.total_ns as f64 / 1e9
                );
            }
            let _ = writeln!(o, "# HELP lisa_segment_upload_bytes_total Host-to-device bytes per segment.");
            let _ = writeln!(o, "# TYPE lisa_segment_upload_bytes_total counter");
            for (seg, s) in &snap.segments {
                let _ = writeln!(
                    o,
                    "lisa_segment_upload_bytes_total{{segment=\"{seg}\"}} {}",
                    s.upload_bytes
                );
            }
        }
        o
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_show_up_in_the_export() {
        let m = Metrics::new();
        m.inc_status(200);
        m.inc_status(200);
        m.inc_status(429);
        m.inc_status(999); // unknown bucket
        m.enqueue();
        m.enqueue();
        m.dequeue();
        m.ttft.observe(0.05);
        m.request_done(32, 2.0);
        let text = m.render();
        assert!(text.contains("lisa_http_requests_total{code=\"200\"} 2"), "{text}");
        assert!(text.contains("lisa_http_requests_total{code=\"429\"} 1"), "{text}");
        assert!(text.contains("lisa_http_requests_total{code=\"other\"} 1"), "{text}");
        assert!(text.contains("lisa_http_queue_depth 1"), "{text}");
        assert!(text.contains("lisa_generated_tokens_total 32"), "{text}");
        assert!(text.contains("lisa_serve_completions_total 1"), "{text}");
        assert!(text.contains("lisa_serve_ttft_seconds_count 1"), "{text}");
        assert!(text.contains("lisa_serve_tokens_per_sec_count 1"), "{text}");
        assert_eq!(m.status_count(200), 2);
    }

    #[test]
    fn queue_depth_never_underflows() {
        let m = Metrics::new();
        m.dequeue();
        m.dequeue();
        assert_eq!(m.queue_depth(), 0);
        m.enqueue();
        assert_eq!(m.queue_depth(), 1);
    }

    #[test]
    fn dirty_flag_is_set_by_completions_and_consumed_once() {
        let m = Metrics::new();
        assert!(!m.take_dirty());
        m.request_done(1, 0.1);
        assert!(m.take_dirty());
        assert!(!m.take_dirty());
    }

    #[test]
    fn failure_classes_count_independently_and_render() {
        let m = Metrics::new();
        m.fail(FailClass::Internal);
        m.fail(FailClass::Overloaded);
        m.fail(FailClass::Overloaded);
        assert_eq!(m.fail_count(FailClass::Internal), 1);
        assert_eq!(m.fail_count(FailClass::Overloaded), 2);
        assert_eq!(m.fail_count(FailClass::Cancelled), 0);
        let text = m.render();
        assert!(text.contains("lisa_serve_failures_total{class=\"internal\"} 1"), "{text}");
        assert!(text.contains("lisa_serve_failures_total{class=\"overloaded\"} 2"), "{text}");
        assert!(text.contains("lisa_serve_failures_total{class=\"cancelled\"} 0"), "{text}");
    }

    #[test]
    fn recovery_loop_counters_render() {
        let m = Metrics::new();
        let loops = LoopStats {
            retries: 4,
            reprefills: 2,
            error_drains: 1,
            preemptions: 3,
            cancelled: 5,
            rejected: 6,
            ..Default::default()
        };
        m.set_loop(loops);
        let text = m.render();
        assert!(text.contains("lisa_serve_retries_total 4"), "{text}");
        assert!(text.contains("lisa_serve_reprefills_total 2"), "{text}");
        assert!(text.contains("lisa_serve_error_drains_total 1"), "{text}");
        assert!(text.contains("lisa_serve_preemptions_total 3"), "{text}");
        assert!(text.contains("lisa_serve_cancelled_total 5"), "{text}");
        assert!(text.contains("lisa_serve_rejected_total 6"), "{text}");
    }

    #[test]
    fn engine_snapshot_round_trips() {
        let m = Metrics::new();
        let mut segments = BTreeMap::new();
        segments.insert(
            "decode_step".to_string(),
            ExecStats { calls: 7, total_ns: 3_000_000_000, ..Default::default() },
        );
        let loops = LoopStats { decode_steps: 7, admitted: 3, ..Default::default() };
        let cache = CacheStats { resident_f32_bytes: 4096, resident_i8_bytes: 1024, ..Default::default() };
        m.set_engine(EngineSnapshot { segments, loops, cache });
        let text = m.render();
        assert!(text.contains("lisa_segment_calls_total{segment=\"decode_step\"} 7"), "{text}");
        assert!(text.contains("lisa_serve_decode_steps_total 7"), "{text}");
        assert!(text.contains("lisa_serve_admitted_total 3"), "{text}");
        assert!(text.contains("lisa_device_resident_bytes{format=\"f32\"} 4096"), "{text}");
        assert!(text.contains("lisa_device_resident_bytes{format=\"i8\"} 1024"), "{text}");
    }

    #[test]
    fn resident_bytes_gauges_render_zero_before_any_snapshot() {
        let text = Metrics::new().render();
        assert!(text.contains("lisa_device_resident_bytes{format=\"f32\"} 0"), "{text}");
        assert!(text.contains("lisa_device_resident_bytes{format=\"i8\"} 0"), "{text}");
    }
}
