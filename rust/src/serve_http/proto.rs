//! Wire protocol for `lisa serve` (DESIGN.md §11): a minimal HTTP/1.1
//! request reader, the `/v1/completions` JSON schema, SSE framing, and a
//! raw-TCP client used by the integration tests and the serving bench.
//!
//! Scope is deliberately narrow — one request per connection,
//! `Connection: close` on every response, bodies sized by
//! `Content-Length` only (no chunked *requests*). Streaming responses
//! carry no `Content-Length`; HTTP/1.1 defines their end as the server
//! closing the connection, which keeps the framing trivial on both
//! sides. This is not a general web server; it is the smallest surface
//! that makes `ServeSession` reachable over a socket.

// Clippy backstop for the no-panic serving contract (DESIGN.md §13,
// enforced structurally by lisa-lint's serve_panic pass).
#![warn(clippy::unwrap_used, clippy::expect_used)]
use std::collections::BTreeMap;
use std::io::{BufRead, Read, Write};

use anyhow::{anyhow, bail, Result};

use crate::engine::SamplerSpec;
use crate::util::json::Json;

/// Request bodies beyond this are refused with 413 before reading them.
pub const MAX_BODY: usize = 1 << 20;

/// Request line + headers beyond this are refused with 400 — the reader
/// never buffers more head bytes than this, so a hostile peer can't grow
/// a header line without bound.
pub const MAX_HEAD: usize = 16 << 10;

/// Stop sequences per request / tokens per stop sequence are capped so a
/// hostile request can't turn the per-token suffix scan quadratic.
pub const MAX_STOP_SEQS: usize = 8;
pub const MAX_STOP_LEN: usize = 32;

/// A parsed HTTP request: header keys are lowercased, the body is raw.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

/// Read one request off the stream. `Ok(None)` means the peer closed
/// without sending anything (not an error — just hang up too); protocol
/// violations come back as `(status, message)` for an error response.
pub fn read_request<R: BufRead>(
    r: &mut R,
) -> std::result::Result<Option<HttpRequest>, (u16, String)> {
    // the whole head reads through a byte cap: a header line can never
    // grow the line buffer past MAX_HEAD no matter what the peer sends
    let mut head = r.by_ref().take(MAX_HEAD as u64);
    let mut line = String::new();
    match head.read_line(&mut line) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(_) => return Ok(None), // reset/timeout before a request: drop quietly
    }
    let mut parts = line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if v.starts_with("HTTP/1.") => {
            (m.to_string(), p.to_string())
        }
        _ => return Err((400, format!("malformed request line {:?}", line.trim_end()))),
    };
    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        match head.read_line(&mut h) {
            Ok(0) => return Err((400, "connection closed inside headers".to_string())),
            Ok(_) => {}
            Err(e) => return Err((400, format!("reading headers: {e}"))),
        }
        if !h.ends_with('\n') && head.limit() == 0 {
            return Err((400, format!("head exceeds the {MAX_HEAD}-byte cap")));
        }
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            let k = k.trim().to_ascii_lowercase();
            // a duplicated Content-Length is a request-smuggling staple:
            // never pick one silently (RFC 9112 §6.3 says reject)
            if headers.insert(k.clone(), v.trim().to_string()).is_some()
                && k == "content-length"
            {
                return Err((400, "duplicate Content-Length header".to_string()));
            }
        }
    }
    drop(head);
    // strict digit-only parse: `parse::<usize>` alone would admit a
    // leading `+`, and the value must be vetted *before* it sizes any
    // buffer — over-cap (or usize-overflowing) lengths 413 right here
    let len: usize = match headers.get("content-length") {
        Some(v) => {
            if v.is_empty() || !v.bytes().all(|b| b.is_ascii_digit()) {
                return Err((400, format!("bad Content-Length {v:?}")));
            }
            match v.parse::<usize>() {
                Ok(n) if n <= MAX_BODY => n,
                _ => {
                    return Err((
                        413,
                        format!("body of {v} bytes exceeds the {MAX_BODY}-byte cap"),
                    ))
                }
            }
        }
        None => 0,
    };
    let mut body = vec![0u8; len];
    if len > 0 {
        r.read_exact(&mut body)
            .map_err(|e| (400, format!("short body: {e}")))?;
    }
    Ok(Some(HttpRequest { method, path, headers, body }))
}

pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete (non-streaming) response and flush it.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {status} {}\r\n", reason(status))?;
    write!(
        w,
        "Content-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n",
        body.len()
    )?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// JSON error envelope: `{"error": {"code": N, "message": "..."}}`.
pub fn error_body(status: u16, msg: &str) -> Vec<u8> {
    Json::obj(vec![(
        "error",
        Json::obj(vec![
            ("code", Json::num(status as f64)),
            ("message", Json::str(msg)),
        ]),
    )])
    .to_string()
    .into_bytes()
}

/// One SSE frame (`data: <json>\n\n`).
pub fn sse_frame(data: &Json) -> String {
    format!("data: {data}\n\n")
}

/// The terminal SSE frame.
pub const SSE_DONE: &str = "data: [DONE]\n\n";

/// A `/v1/completions` request as it arrives on the wire. Prompt text is
/// kept as text here — the server owns the tokenizer and resolves
/// `prompt`/`stop` strings to ids at admission time.
///
/// Accepted keys:
/// - `prompt` (string) or `tokens` ([int]; takes precedence, used
///   verbatim — callers wanting bit-parity with an offline
///   `ServeSession` run send exact ids)
/// - `max_new` (int; clamped to the server's `--max-new-cap`)
/// - `sample` ("greedy" | "temperature" | "top-k" | "top-p") with
///   `temperature`, `top_k`, `top_p`; if `sample` is absent but
///   `temperature` is present, "temperature" is implied; all absent →
///   the server's default sampler
/// - `logit_bias` ([[token, bias]]; bias is a number or the string
///   "-inf"/"inf") and `ban` ([int], shorthand for bias = -inf)
/// - `stop` ([string], tokenized by the server) and `stop_tokens`
///   ([[int]]) — generation stops when the output ends with any
///   sequence; the match is excluded from the result
/// - `seed` (int; absent → server-assigned, deterministic per request
///   index under `--gen-seed`)
/// - `stream` (bool; true → SSE token stream, false → one JSON body)
#[derive(Debug, Clone, Default)]
pub struct CompletionReq {
    pub prompt: Option<String>,
    pub tokens: Option<Vec<i32>>,
    pub max_new: Option<usize>,
    pub sampler: Option<SamplerSpec>,
    pub bias: Vec<(i32, f32)>,
    pub stop_texts: Vec<String>,
    pub stop_tokens: Vec<Vec<i32>>,
    pub seed: Option<u64>,
    pub stream: bool,
}

fn as_token(j: &Json, what: &str) -> Result<i32> {
    let n = j.as_f64().ok_or_else(|| anyhow!("{what} must be an integer"))?;
    if n.fract() != 0.0 || !(0.0..=i32::MAX as f64).contains(&n) {
        bail!("{what} must be a non-negative integer (got {n})");
    }
    Ok(n as i32)
}

fn as_bias(j: &Json) -> Result<f32> {
    if let Some(s) = j.as_str() {
        return match s {
            "-inf" | "-Inf" | "-Infinity" => Ok(f32::NEG_INFINITY),
            "inf" | "Inf" | "Infinity" => Ok(f32::INFINITY),
            other => bail!("logit_bias value {other:?} is not a number or \"-inf\"/\"inf\""),
        };
    }
    let n = j.as_f64().ok_or_else(|| anyhow!("logit_bias value must be a number"))?;
    if n.is_nan() {
        bail!("logit_bias value must not be NaN");
    }
    Ok(n as f32)
}

fn token_list(j: &Json, what: &str) -> Result<Vec<i32>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("{what} must be an array of integers"))?
        .iter()
        .map(|t| as_token(t, what))
        .collect()
}

impl CompletionReq {
    pub fn parse(body: &[u8]) -> Result<CompletionReq> {
        let text = std::str::from_utf8(body).map_err(|_| anyhow!("body is not UTF-8"))?;
        let j = Json::parse(text).map_err(|e| anyhow!("invalid JSON: {e}"))?;
        if j.as_obj().is_none() {
            bail!("body must be a JSON object");
        }

        let prompt = match j.get("prompt") {
            Some(p) => Some(
                p.as_str()
                    .ok_or_else(|| anyhow!("prompt must be a string"))?
                    .to_string(),
            ),
            None => None,
        };
        let tokens = match j.get("tokens") {
            Some(t) => Some(token_list(t, "tokens")?),
            None => None,
        };
        if prompt.is_none() && tokens.is_none() {
            bail!("request needs a prompt (string) or tokens (array of ids)");
        }

        let max_new = match j.get("max_new") {
            Some(m) => {
                let m = m.as_usize().ok_or_else(|| anyhow!("max_new must be a non-negative integer"))?;
                if m == 0 {
                    bail!("max_new must be >= 1");
                }
                Some(m)
            }
            None => None,
        };

        let temperature = match j.get("temperature") {
            Some(t) => Some(t.as_f64().ok_or_else(|| anyhow!("temperature must be a number"))? as f32),
            None => None,
        };
        let top_k = match j.get("top_k") {
            Some(k) => Some(k.as_usize().ok_or_else(|| anyhow!("top_k must be a non-negative integer"))?),
            None => None,
        };
        let top_p = match j.get("top_p") {
            Some(p) => Some(p.as_f64().ok_or_else(|| anyhow!("top_p must be a number"))? as f32),
            None => None,
        };
        let mode = match j.get("sample") {
            Some(s) => Some(
                s.as_str()
                    .ok_or_else(|| anyhow!("sample must be a policy name string"))?
                    .to_string(),
            ),
            // `{"temperature": 0.7}` without an explicit policy means
            // temperature sampling, not a silently-ignored knob
            None => temperature.map(|_| "temperature".to_string()),
        };
        let sampler = match mode {
            Some(m) => Some(SamplerSpec::parse(
                &m,
                temperature.unwrap_or(1.0),
                top_k.unwrap_or(40),
                top_p.unwrap_or(0.9),
            )?),
            None => None,
        };

        let mut bias: Vec<(i32, f32)> = Vec::new();
        if let Some(b) = j.get("logit_bias") {
            for pair in b.as_arr().ok_or_else(|| anyhow!("logit_bias must be [[token, bias], ...]"))? {
                let arr = pair.as_arr().filter(|a| a.len() == 2).ok_or_else(|| {
                    anyhow!("logit_bias entries must be [token, bias] pairs")
                })?;
                bias.push((as_token(&arr[0], "logit_bias token")?, as_bias(&arr[1])?));
            }
        }
        if let Some(b) = j.get("ban") {
            for t in token_list(b, "ban")? {
                bias.push((t, f32::NEG_INFINITY));
            }
        }

        let mut stop_texts = Vec::new();
        if let Some(s) = j.get("stop") {
            for t in s.as_arr().ok_or_else(|| anyhow!("stop must be an array of strings"))? {
                stop_texts.push(
                    t.as_str()
                        .ok_or_else(|| anyhow!("stop entries must be strings"))?
                        .to_string(),
                );
            }
        }
        let mut stop_tokens = Vec::new();
        if let Some(s) = j.get("stop_tokens") {
            for seq in s.as_arr().ok_or_else(|| anyhow!("stop_tokens must be an array of token arrays"))? {
                stop_tokens.push(token_list(seq, "stop_tokens")?);
            }
        }
        if stop_texts.len() + stop_tokens.len() > MAX_STOP_SEQS {
            bail!("at most {MAX_STOP_SEQS} stop sequences per request");
        }
        if stop_tokens.iter().any(|s| s.len() > MAX_STOP_LEN) {
            bail!("stop sequences are capped at {MAX_STOP_LEN} tokens");
        }

        let seed = match j.get("seed") {
            Some(s) => {
                let n = s.as_f64().ok_or_else(|| anyhow!("seed must be a non-negative integer"))?;
                if n.fract() != 0.0 || n < 0.0 {
                    bail!("seed must be a non-negative integer (got {n})");
                }
                Some(n as u64)
            }
            None => None,
        };
        let stream = match j.get("stream") {
            Some(s) => s.as_bool().ok_or_else(|| anyhow!("stream must be a boolean"))?,
            None => false,
        };

        Ok(CompletionReq {
            prompt,
            tokens,
            max_new,
            sampler,
            bias,
            stop_texts,
            stop_tokens,
            seed,
            stream,
        })
    }
}

/// Raw-TCP HTTP client, just enough for the tests and the serving bench:
/// one request, read to EOF (the server always closes), split head/body.
pub mod client {
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::time::Duration;

    use anyhow::{anyhow, Context, Result};

    use crate::util::json::Json;

    /// Status code, raw header block, body.
    pub struct Response {
        pub status: u16,
        pub head: String,
        pub body: String,
    }

    impl Response {
        pub fn header(&self, name: &str) -> Option<&str> {
            let lower = name.to_ascii_lowercase();
            self.head.lines().find_map(|l| {
                let (k, v) = l.split_once(':')?;
                (k.trim().to_ascii_lowercase() == lower).then(|| v.trim())
            })
        }

        pub fn json(&self) -> Result<Json> {
            Json::parse(&self.body).map_err(|e| anyhow!("response body: {e}"))
        }

        /// Parsed SSE data frames, `[DONE]` excluded.
        pub fn sse_frames(&self) -> Result<Vec<Json>> {
            self.body
                .lines()
                .filter_map(|l| l.strip_prefix("data: "))
                .filter(|d| *d != "[DONE]")
                .map(|d| Json::parse(d).map_err(|e| anyhow!("SSE frame {d:?}: {e}")))
                .collect()
        }
    }

    fn roundtrip(addr: &str, raw: &str) -> Result<Response> {
        let mut s = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        s.set_read_timeout(Some(Duration::from_secs(120)))?;
        s.set_nodelay(true)?;
        s.write_all(raw.as_bytes())?;
        s.flush()?;
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).context("reading response")?;
        let text = String::from_utf8(buf).context("response is not UTF-8")?;
        let (head, body) = text
            .split_once("\r\n\r\n")
            .ok_or_else(|| anyhow!("no header/body separator in response: {text:?}"))?;
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad status line: {head:?}"))?;
        Ok(Response { status, head: head.to_string(), body: body.to_string() })
    }

    pub fn get(addr: &str, path: &str) -> Result<Response> {
        roundtrip(
            addr,
            &format!("GET {path} HTTP/1.1\r\nHost: lisa\r\nConnection: close\r\n\r\n"),
        )
    }

    pub fn post(addr: &str, path: &str, body: &str) -> Result<Response> {
        roundtrip(
            addr,
            &format!(
                "POST {path} HTTP/1.1\r\nHost: lisa\r\nContent-Type: application/json\r\n\
                 Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
                body.len()
            ),
        )
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests panic by design
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse_ok(body: &str) -> CompletionReq {
        CompletionReq::parse(body.as_bytes()).unwrap()
    }

    #[test]
    fn minimal_prompt_request_parses_with_defaults() {
        let r = parse_ok(r#"{"prompt": "hello world"}"#);
        assert_eq!(r.prompt.as_deref(), Some("hello world"));
        assert!(r.tokens.is_none() && r.sampler.is_none() && r.seed.is_none());
        assert!(!r.stream && r.bias.is_empty() && r.max_new.is_none());
    }

    #[test]
    fn full_request_round_trips_every_field() {
        let r = parse_ok(
            r#"{"tokens": [1, 9, 3], "max_new": 8, "sample": "top-k", "top_k": 5,
               "temperature": 0.5, "logit_bias": [[7, -2.5], [8, "-inf"]], "ban": [9],
               "stop_tokens": [[6, 7]], "seed": 11, "stream": true}"#,
        );
        assert_eq!(r.tokens, Some(vec![1, 9, 3]));
        assert_eq!(r.max_new, Some(8));
        assert_eq!(r.sampler, Some(SamplerSpec::TopK { k: 5, temperature: 0.5 }));
        assert_eq!(r.bias.len(), 3);
        assert_eq!(r.bias[1], (8, f32::NEG_INFINITY));
        assert_eq!(r.bias[2], (9, f32::NEG_INFINITY));
        assert_eq!(r.stop_tokens, vec![vec![6, 7]]);
        assert_eq!(r.seed, Some(11));
        assert!(r.stream);
    }

    #[test]
    fn temperature_without_sample_implies_temperature_policy() {
        let r = parse_ok(r#"{"prompt": "x", "temperature": 0.7}"#);
        assert_eq!(r.sampler, Some(SamplerSpec::Temperature { temperature: 0.7 }));
    }

    #[test]
    fn bad_requests_are_rejected_with_a_reason() {
        for (body, needle) in [
            (r#"{"max_new": 4}"#, "prompt"),
            (r#"{"prompt": "x", "max_new": 0}"#, "max_new"),
            (r#"{"prompt": "x", "seed": -1}"#, "seed"),
            (r#"{"prompt": "x", "logit_bias": [[1]]}"#, "pairs"),
            (r#"{"prompt": "x", "tokens": [1.5]}"#, "integer"),
            (r#"{"prompt": "x", "sample": "magic"}"#, "magic"),
            (r#"not json"#, "JSON"),
            (r#"[1, 2]"#, "object"),
        ] {
            let err = format!("{:#}", CompletionReq::parse(body.as_bytes()).unwrap_err());
            assert!(err.contains(needle), "{body} -> {err}");
        }
    }

    #[test]
    fn stop_sequence_caps_are_enforced() {
        let many: Vec<String> = (0..MAX_STOP_SEQS + 1).map(|i| format!("\"s{i}\"")).collect();
        let body = format!(r#"{{"prompt": "x", "stop": [{}]}}"#, many.join(","));
        assert!(CompletionReq::parse(body.as_bytes()).is_err());
        let long: Vec<String> = (0..MAX_STOP_LEN + 1).map(|i| i.to_string()).collect();
        let body = format!(r#"{{"prompt": "x", "stop_tokens": [[{}]]}}"#, long.join(","));
        assert!(CompletionReq::parse(body.as_bytes()).is_err());
    }

    #[test]
    fn http_request_reader_handles_the_happy_path_and_violations() {
        let raw = b"POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 2\r\n\r\nhi";
        let req = read_request(&mut BufReader::new(&raw[..])).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.headers.get("host").map(String::as_str), Some("x"));
        assert_eq!(req.body, b"hi");

        // empty connection: None, not an error
        assert!(read_request(&mut BufReader::new(&b""[..])).unwrap().is_none());
        // garbage request line: 400
        let raw = b"whatever\r\n\r\n";
        assert_eq!(read_request(&mut BufReader::new(&raw[..])).unwrap_err().0, 400);
        // oversized body: 413 before the body is read
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert_eq!(read_request(&mut BufReader::new(raw.as_bytes())).unwrap_err().0, 413);
    }

    fn read_err(raw: &str) -> (u16, String) {
        read_request(&mut BufReader::new(raw.as_bytes())).unwrap_err()
    }

    #[test]
    fn content_length_must_be_a_single_plain_digit_string() {
        // `parse::<usize>` alone would accept the leading `+`
        for bad in ["+2", "-2", "2 2", "0x10", "2,2", "", "two"] {
            let raw = format!("POST / HTTP/1.1\r\nContent-Length: {bad}\r\n\r\nhi");
            let (code, msg) = read_err(&raw);
            assert_eq!(code, 400, "Content-Length {bad:?} -> {msg}");
            assert!(msg.contains("Content-Length"), "{msg}");
        }
        // duplicate headers must never pick one silently, even when equal
        for dup in ["2", "3"] {
            let raw = format!(
                "POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: {dup}\r\n\r\nhi"
            );
            let (code, msg) = read_err(&raw);
            assert_eq!(code, 400, "{msg}");
            assert!(msg.contains("duplicate"), "{msg}");
        }
        // a value that overflows usize is over-cap, not a panic: 413
        let raw = "POST / HTTP/1.1\r\nContent-Length: 99999999999999999999999\r\n\r\n";
        assert_eq!(read_err(raw).0, 413);
        // other duplicated headers stay legal (last one wins)
        let raw = b"GET / HTTP/1.1\r\nX-A: 1\r\nX-A: 2\r\n\r\n";
        let req = read_request(&mut BufReader::new(&raw[..])).unwrap().unwrap();
        assert_eq!(req.headers.get("x-a").map(String::as_str), Some("2"));
    }

    #[test]
    fn head_larger_than_the_cap_is_rejected_not_buffered() {
        // one giant header line: the reader must stop at MAX_HEAD rather
        // than grow its line buffer to match the peer's appetite
        let raw = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(2 * MAX_HEAD));
        let (code, msg) = read_err(&raw);
        assert_eq!(code, 400, "{msg}");
        assert!(msg.contains("cap"), "{msg}");
        // a head just under the cap still parses
        let raw = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(1024));
        assert!(read_request(&mut BufReader::new(raw.as_bytes())).unwrap().is_some());
    }

    #[test]
    fn responses_are_well_formed() {
        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json", &[("Retry-After", "1")], b"{}")
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
    }
}
