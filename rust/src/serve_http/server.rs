//! The `lisa serve` front end (DESIGN.md §11): a dependency-light
//! HTTP/1.1 server over [`ServeSession`]'s continuous-batching loop.
//!
//! Threading contract: the engine is `!Send` (it holds `Rc`/`RefCell`
//! device state), so the model loop runs on the thread that calls
//! [`HttpFrontend::run`] and *never* migrates. HTTP workers run on
//! scoped threads and only parse requests, enqueue [`Admission`]s into a
//! bounded channel, and forward token events back to their client. The
//! bounded channel is the backpressure boundary: `try_send` failing
//! means the queue is full and the worker answers `429 Too Many
//! Requests` with `Retry-After` — in-flight rows are never disturbed.
//!
//! Per-request event channels are *bounded* in the other direction too
//! (model → worker, [`ServeConfig::event_buf`] events): the model thread
//! never blocks on them — a client that stalls past the buffer (or hangs
//! up) is marked dead, its [`CancelToken`] flips, and the serve loop
//! drains the row between steps, releasing its K/V pages (DESIGN.md
//! §13). Failures surface the same way: [`RequestSink::on_fail`] crosses
//! the channel as [`Event::Fail`] and maps to `500` (internal), `503 +
//! Retry-After` (overloaded) or a terminal SSE error frame, with a
//! per-class `lisa_serve_failures_total` counter.
//!
//! Shutdown: `SIGINT` or `SIGTERM` (or
//! [`ServerState::request_shutdown`]) makes the channel source report
//! `Closed`; the serve loop stops admitting, drains in-flight rows
//! (their clients get complete responses), and returns.
//! Queued-but-unadmitted requests are then bounced — their event
//! channels close and the waiting workers answer `503`. A second signal
//! exits immediately.
//!
//! [`ServeSession`]: crate::engine::ServeSession

// Clippy backstop for the no-panic serving contract (DESIGN.md §13,
// enforced structurally by lisa-lint's serve_panic pass).
#![warn(clippy::unwrap_used, clippy::expect_used)]
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::data::tokenizer::{Tokenizer, EOS, PAD};
use crate::engine::serve::request_seed;
use crate::engine::{
    CancelToken, Completion, Engine, FailClass, Feed, LoopStats, Request, RequestSink,
    RequestSource, SamplerSpec, ServeFail,
};
use crate::util::json::Json;

use super::metrics::{EngineSnapshot, Metrics};
use super::proto::{self, CompletionReq, MAX_STOP_LEN};

/// How often idle workers re-check the (nonblocking) listener.
const ACCEPT_POLL: Duration = Duration::from_millis(10);
/// How long the idle model loop blocks on the admission channel per
/// tick (bounds shutdown latency when no requests are live).
const IDLE_POLL: Duration = Duration::from_millis(50);
/// Socket read/write timeouts on accepted connections.
const IO_TIMEOUT: Duration = Duration::from_secs(30);
/// Worker-side ceiling on one completion (queue wait + full decode).
const REQUEST_DEADLINE: Duration = Duration::from_secs(600);

/// Serving knobs, resolved from the CLI in `lisa serve`.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (tests).
    pub addr: String,
    /// HTTP worker threads (the model always has exactly one thread).
    pub workers: usize,
    /// Admission-queue bound; the 429 threshold.
    pub max_queue: usize,
    /// `max_new` when the request doesn't say.
    pub default_max_new: usize,
    /// Hard per-request generation budget; larger asks are clamped.
    pub max_new_cap: usize,
    /// Sampler when the request doesn't specify one.
    pub default_spec: SamplerSpec,
    /// Base seed for server-assigned per-request sampler streams.
    pub gen_seed: u64,
    pub eos: i32,
    pub pad: i32,
    /// Model → worker event buffer per request. A client that stalls
    /// long enough to fill it is dropped and its row cancelled — the
    /// model thread never blocks on a slow consumer.
    pub event_buf: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:8080".to_string(),
            workers: 4,
            max_queue: 32,
            default_max_new: 32,
            max_new_cap: 256,
            default_spec: SamplerSpec::Greedy,
            gen_seed: 42,
            eos: EOS,
            pad: PAD,
            event_buf: 512,
        }
    }
}

/// Shared server state: config, tokenizer, metrics, shutdown flag.
pub struct ServerState {
    pub cfg: ServeConfig,
    pub tok: Tokenizer,
    pub metrics: Metrics,
    shutdown: AtomicBool,
    /// Monotone request counter; feeds server-assigned sampler seeds.
    seq: AtomicU64,
}

impl ServerState {
    pub fn new(cfg: ServeConfig, tok: Tokenizer) -> ServerState {
        ServerState {
            cfg,
            tok,
            metrics: Metrics::new(),
            shutdown: AtomicBool::new(false),
            seq: AtomicU64::new(0),
        }
    }

    /// Graceful-shutdown requested (programmatically or via SIGINT)?
    pub fn stopping(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || sigint_received()
    }

    /// Programmatic equivalent of one SIGINT: stop admitting, drain.
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

/// Model → worker event stream for one request.
enum Event {
    Token(i32),
    Done(Completion),
    /// Terminal failure (error drain, overload rejection, cancellation).
    Fail(ServeFail),
}

/// The per-request sink the model thread drives. `Send` so it can cross
/// the admission channel; after admission it lives on the model thread.
struct HttpSink {
    tx: SyncSender<Event>,
    /// Shared with the request handed to the serve loop: flipped when the
    /// client is unreachable so the loop drains the row between steps.
    cancel: CancelToken,
    /// The event channel stalled or closed — stop sending, row cancelled.
    dead: bool,
    state: Arc<ServerState>,
    /// Queue-entry time: TTFT measures what the client experiences.
    t0: Instant,
    saw_first: bool,
    n: u64,
}

impl HttpSink {
    /// Non-blocking send with drop-on-stall: the model thread must never
    /// wait on a client. A full buffer (stalled reader) or a closed one
    /// (worker gone: client hung up, deadline hit) marks the sink dead
    /// and cancels the row so its pages free up instead of decoding to
    /// nobody.
    fn push(&mut self, ev: Event) {
        if self.dead {
            return;
        }
        if self.tx.try_send(ev).is_err() {
            self.dead = true;
            self.cancel.cancel();
        }
    }
}

impl RequestSink for HttpSink {
    fn on_token(&mut self, tok: i32) {
        if !self.saw_first {
            self.saw_first = true;
            self.state.metrics.ttft.observe(self.t0.elapsed().as_secs_f64());
        }
        self.n += 1;
        self.push(Event::Token(tok));
    }

    fn on_done(&mut self, completion: &Completion) {
        self.state.metrics.request_done(self.n, self.t0.elapsed().as_secs_f64());
        self.push(Event::Done(completion.clone()));
    }

    fn on_fail(&mut self, fail: &ServeFail) {
        self.state.metrics.fail(fail.class);
        self.push(Event::Fail(fail.clone()));
    }
}

/// What crosses the bounded admission channel.
struct Admission {
    req: Request,
    sink: HttpSink,
}

/// [`RequestSource`] over the admission channel: `try_recv` while rows
/// are live, short blocking waits when idle, `Closed` once shutdown is
/// requested. `observe` publishes loop counters every iteration and a
/// full per-segment `ExecStats` snapshot when completions marked the
/// metrics dirty (or 250 ms elapsed) — the decode hot path never pays
/// for a full snapshot per token.
pub struct ChannelSource {
    rx: Receiver<Admission>,
    state: Arc<ServerState>,
    last_refresh: Option<Instant>,
}

impl RequestSource for ChannelSource {
    fn poll(&mut self, idle: bool) -> Feed {
        if self.state.stopping() {
            return Feed::Closed;
        }
        let adm = if idle {
            match self.rx.recv_timeout(IDLE_POLL) {
                Ok(a) => a,
                Err(RecvTimeoutError::Timeout) => return Feed::Pending,
                Err(RecvTimeoutError::Disconnected) => return Feed::Closed,
            }
        } else {
            match self.rx.try_recv() {
                Ok(a) => a,
                Err(TryRecvError::Empty) => return Feed::Pending,
                Err(TryRecvError::Disconnected) => return Feed::Closed,
            }
        };
        self.state.metrics.dequeue();
        Feed::Admit(adm.req, Box::new(adm.sink))
    }

    fn observe(&mut self, eng: &Engine, stats: LoopStats) {
        let refresh = self.state.metrics.take_dirty()
            || self.last_refresh.map_or(true, |t| t.elapsed() > Duration::from_millis(250));
        if refresh {
            self.last_refresh = Some(Instant::now());
            self.state
                .metrics
                .set_engine(EngineSnapshot {
                    segments: eng.rt.stats(),
                    loops: stats,
                    cache: eng.device_cache_stats(),
                });
        } else {
            self.state.metrics.set_loop(stats);
        }
    }
}

/// The bound listener plus everything `run` needs. Constructed with
/// [`HttpFrontend::bind`] (so tests can read the ephemeral port before
/// starting the model), consumed by [`HttpFrontend::run`].
pub struct HttpFrontend {
    listener: TcpListener,
    state: Arc<ServerState>,
    tx: SyncSender<Admission>,
    rx: Receiver<Admission>,
}

impl HttpFrontend {
    pub fn bind(cfg: ServeConfig, tok: Tokenizer) -> Result<HttpFrontend> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        // nonblocking so workers can poll the shutdown flag between accepts
        listener.set_nonblocking(true).context("listener nonblocking")?;
        let (tx, rx) = mpsc::sync_channel(cfg.max_queue.max(1));
        let state = Arc::new(ServerState::new(cfg, tok));
        Ok(HttpFrontend { listener, state, tx, rx })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn state(&self) -> Arc<ServerState> {
        Arc::clone(&self.state)
    }

    /// Serve until `model` returns. `model` receives the channel-backed
    /// [`RequestSource`] and is expected to hand it to
    /// [`ServeSession::run_loop`] on *this* thread (the engine is
    /// `!Send`); the integration tests drive it with a stub loop
    /// instead. Workers are joined before this returns.
    ///
    /// [`ServeSession::run_loop`]: crate::engine::ServeSession::run_loop
    pub fn run<T>(self, model: impl FnOnce(&mut ChannelSource) -> T) -> T {
        let HttpFrontend { listener, state, tx, rx } = self;
        let mut src =
            ChannelSource { rx, state: Arc::clone(&state), last_refresh: None };
        std::thread::scope(|s| {
            for _ in 0..state.cfg.workers.max(1) {
                let st = Arc::clone(&state);
                let tx = tx.clone();
                let listener = &listener;
                s.spawn(move || worker_loop(listener, st, tx));
            }
            drop(tx); // workers hold the only senders now
            let out = model(&mut src);
            // model loop exited: stop accepting, then bounce queued
            // admissions until every worker is gone — a dropped
            // admission closes its event channel, so no worker can
            // block forever on a stream the loop will never feed
            state.request_shutdown();
            loop {
                match src.rx.recv_timeout(ACCEPT_POLL) {
                    Ok(adm) => {
                        state.metrics.dequeue();
                        drop(adm);
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            out
        })
    }
}

fn worker_loop(listener: &TcpListener, st: Arc<ServerState>, tx: SyncSender<Admission>) {
    while !st.stopping() {
        match listener.accept() {
            Ok((stream, _peer)) => handle_conn(stream, &st, &tx),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn handle_conn(stream: TcpStream, st: &Arc<ServerState>, tx: &SyncSender<Admission>) {
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut w = stream;
    let req = match proto::read_request(&mut reader) {
        Ok(Some(r)) => r,
        Ok(None) => return, // peer hung up without a request
        Err((code, msg)) => return respond_error(&mut w, st, code, &msg),
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let body = Json::obj(vec![
                ("status", Json::str(if st.stopping() { "stopping" } else { "ok" })),
                ("queue_depth", Json::num(st.metrics.queue_depth() as f64)),
                ("uptime_s", Json::num(st.metrics.uptime_s())),
            ]);
            st.metrics.inc_status(200);
            let _ = proto::write_response(
                &mut w,
                200,
                "application/json",
                &[],
                body.to_string().as_bytes(),
            );
        }
        ("GET", "/metrics") => {
            st.metrics.inc_status(200);
            let _ = proto::write_response(
                &mut w,
                200,
                "text/plain; version=0.0.4",
                &[],
                st.metrics.render().as_bytes(),
            );
        }
        ("POST", "/v1/completions") => completions(&mut w, st, tx, &req.body),
        ("GET", _) | ("POST", _) => respond_error(&mut w, st, 404, "no such endpoint"),
        (m, _) => respond_error(&mut w, st, 405, &format!("method {m} not supported")),
    }
}

fn completions(
    w: &mut TcpStream,
    st: &Arc<ServerState>,
    tx: &SyncSender<Admission>,
    body: &[u8],
) {
    if st.stopping() {
        return respond_error(w, st, 503, "server is shutting down");
    }
    let creq = match CompletionReq::parse(body) {
        Ok(c) => c,
        Err(e) => return respond_error(w, st, 400, &format!("{e:#}")),
    };
    let stream_mode = creq.stream;
    let mut req = match build_request(st, &creq) {
        Ok(r) => r,
        Err(e) => return respond_error(w, st, 400, &format!("{e:#}")),
    };
    let cancel = CancelToken::new();
    req.cancel = Some(cancel.clone());
    let (etx, erx) = mpsc::sync_channel(st.cfg.event_buf.max(1));
    let sink = HttpSink {
        tx: etx,
        cancel: cancel.clone(),
        dead: false,
        state: Arc::clone(st),
        t0: Instant::now(),
        saw_first: false,
        n: 0,
    };
    match tx.try_send(Admission { req, sink }) {
        Ok(()) => st.metrics.enqueue(),
        Err(TrySendError::Full(_)) => {
            st.metrics.inc_status(429);
            let _ = proto::write_response(
                w,
                429,
                "application/json",
                &[("Retry-After", "1")],
                &proto::error_body(429, "admission queue is full — retry shortly"),
            );
            return;
        }
        Err(TrySendError::Disconnected(_)) => {
            return respond_error(w, st, 503, "model loop has exited");
        }
    }
    if stream_mode {
        respond_stream(w, st, erx, &cancel);
    } else {
        respond_full(w, st, erx, &cancel);
    }
    // nobody reads events past this point (the responder returned or the
    // client went away): flip the token so a still-decoding row drains
    // and frees its pages. Completed rows ignore a late cancel.
    cancel.cancel();
}

/// Resolve a wire request against the server's tokenizer and limits.
fn build_request(st: &ServerState, c: &CompletionReq) -> Result<Request> {
    let prompt = match &c.tokens {
        Some(t) => {
            let vocab = st.tok.vocab_size() as i32;
            if let Some(bad) = t.iter().find(|&&id| id < 0 || id >= vocab) {
                bail!("token id {bad} outside the vocabulary (size {vocab})");
            }
            t.clone()
        }
        None => crate::eval::generate::encode_prompt(
            &st.tok,
            c.prompt.as_deref().unwrap_or_default(),
        ),
    };
    ensure!(!prompt.is_empty(), "empty prompt");
    let max_new = c
        .max_new
        .unwrap_or(st.cfg.default_max_new)
        .min(st.cfg.max_new_cap.max(1));
    let mut stop = c.stop_tokens.clone();
    for text in &c.stop_texts {
        let ids = st.tok.encode(text);
        ensure!(
            ids.len() <= MAX_STOP_LEN,
            "stop string {text:?} tokenizes to {} tokens (cap {MAX_STOP_LEN})",
            ids.len()
        );
        stop.push(ids); // empty encodings are ignored by the row plan
    }
    let sampler = c
        .sampler
        .clone()
        .unwrap_or_else(|| st.cfg.default_spec.clone())
        .with_bias(c.bias.clone());
    let seed = c.seed.unwrap_or_else(|| {
        request_seed(st.cfg.gen_seed, st.seq.fetch_add(1, Ordering::Relaxed) as usize)
    });
    Ok(Request {
        prompt,
        max_new,
        sampler,
        seed,
        first_token: None,
        stop,
        cancel: None, // attached per connection in `completions`
    })
}

fn completion_json(st: &ServerState, c: &Completion) -> Json {
    Json::obj(vec![
        (
            "tokens",
            Json::Arr(c.tokens.iter().map(|t| Json::num(*t as f64)).collect()),
        ),
        ("text", Json::str(&st.tok.decode(&c.tokens))),
        ("n", Json::num(c.tokens.len() as f64)),
        ("finish_reason", Json::str(c.stop.label())),
        ("prompt_truncated", Json::Bool(c.prompt_truncated)),
    ])
}

/// Status line + extra headers for a failed request. Overloaded maps to
/// 503 with `Retry-After` (the pool will drain); internal errors and
/// cancellations (a deadline can cancel a request whose client is still
/// connected) map to 500.
fn fail_status(f: &ServeFail) -> (u16, &'static [(&'static str, &'static str)]) {
    match f.class {
        FailClass::Overloaded => (503, &[("Retry-After", "1")]),
        FailClass::Internal | FailClass::Cancelled => (500, &[]),
    }
}

fn respond_full(w: &mut TcpStream, st: &ServerState, erx: Receiver<Event>, cancel: &CancelToken) {
    // tokens also arrive here; the completion repeats them, so the
    // non-streaming path just waits for Done
    let completion = loop {
        match erx.recv_timeout(REQUEST_DEADLINE) {
            Ok(Event::Token(_)) => {}
            Ok(Event::Done(c)) => break c,
            Ok(Event::Fail(f)) => {
                let (code, extra) = fail_status(&f);
                st.metrics.inc_status(code);
                let _ = proto::write_response(
                    w,
                    code,
                    "application/json",
                    extra,
                    &proto::error_body(code, &f.message),
                );
                return;
            }
            Err(RecvTimeoutError::Disconnected) => {
                return respond_error(w, st, 503, "request dropped: server shutting down");
            }
            Err(RecvTimeoutError::Timeout) => {
                cancel.cancel(); // free the row; nobody will read the result
                return respond_error(w, st, 500, "completion deadline exceeded");
            }
        }
    };
    st.metrics.inc_status(200);
    let _ = proto::write_response(
        w,
        200,
        "application/json",
        &[],
        completion_json(st, &completion).to_string().as_bytes(),
    );
}

fn respond_stream(
    w: &mut TcpStream,
    st: &ServerState,
    erx: Receiver<Event>,
    cancel: &CancelToken,
) {
    st.metrics.inc_status(200);
    let head = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
                Cache-Control: no-cache\r\nConnection: close\r\n\r\n";
    if w.write_all(head.as_bytes()).and_then(|_| w.flush()).is_err() {
        cancel.cancel(); // client already gone: drain the row
        return;
    }
    loop {
        match erx.recv_timeout(REQUEST_DEADLINE) {
            Ok(Event::Token(t)) => {
                let frame = proto::sse_frame(&Json::obj(vec![
                    ("token", Json::num(t as f64)),
                    ("text", Json::str(st.tok.token(t).unwrap_or("<unk>"))),
                ]));
                if w.write_all(frame.as_bytes()).and_then(|_| w.flush()).is_err() {
                    // client went away mid-stream: cancel so the row's
                    // pages free up instead of decoding to nobody
                    cancel.cancel();
                    return;
                }
            }
            Ok(Event::Done(c)) => {
                let mut done = completion_json(st, &c);
                if let Json::Obj(m) = &mut done {
                    m.insert("done".to_string(), Json::Bool(true));
                }
                let _ = w.write_all(proto::sse_frame(&done).as_bytes());
                let _ = w.write_all(proto::SSE_DONE.as_bytes());
                let _ = w.flush();
                return;
            }
            Ok(Event::Fail(f)) => {
                // the stream already committed a 200: surface the failure
                // as a terminal SSE error frame with its class
                let frame = proto::sse_frame(&Json::obj(vec![
                    ("error", Json::str(&f.message)),
                    ("class", Json::str(f.class.label())),
                ]));
                let _ = w.write_all(frame.as_bytes());
                let _ = w.flush();
                return;
            }
            Err(e) => {
                let msg = match e {
                    RecvTimeoutError::Disconnected => "dropped: server shutting down",
                    RecvTimeoutError::Timeout => {
                        cancel.cancel();
                        "completion deadline exceeded"
                    }
                };
                let frame = proto::sse_frame(&Json::obj(vec![("error", Json::str(msg))]));
                let _ = w.write_all(frame.as_bytes());
                let _ = w.flush();
                return;
            }
        }
    }
}

fn respond_error(w: &mut TcpStream, st: &ServerState, code: u16, msg: &str) {
    st.metrics.inc_status(code);
    let _ = proto::write_response(
        w,
        code,
        "application/json",
        &[],
        &proto::error_body(code, msg),
    );
}

// ------------------------------------------------------ SIGINT / SIGTERM

static SIGINT_FLAG: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" {
    fn signal(signum: i32, handler: usize) -> usize;
    fn _exit(code: i32) -> !;
}

#[cfg(unix)]
extern "C" fn on_sigint(_sig: i32) {
    if SIGINT_FLAG.swap(true, Ordering::SeqCst) {
        // second signal: the operator wants out *now*, skip the drain
        // SAFETY: `_exit` is async-signal-safe and never returns;
        // nothing here allocates or takes locks.
        unsafe { _exit(130) }
    }
}

/// Install handlers that turn `SIGINT` *and* `SIGTERM` into a graceful
/// drain (raw POSIX `signal(2)` through the C ABI — the image carries no
/// signal crate). Orchestrators stop containers with SIGTERM, so it must
/// behave exactly like ^C: stop admitting, drain in-flight rows, exit.
/// Idempotent; a second signal of either kind exits immediately with
/// status 130.
pub fn install_sigint() {
    // SAFETY: `signal(2)` with a handler that only touches an atomic
    // flag or calls `_exit` — both async-signal-safe; the handler
    // pointer outlives the process (it is a plain fn item).
    #[cfg(unix)]
    unsafe {
        signal(2 /* SIGINT */, on_sigint as usize);
        signal(15 /* SIGTERM */, on_sigint as usize);
    }
}

/// Has SIGINT or SIGTERM fired since [`install_sigint`]? Folded into
/// [`ServerState::stopping`], checked by workers and the model loop.
pub fn sigint_received() -> bool {
    SIGINT_FLAG.load(Ordering::SeqCst)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)] // tests panic by design
mod tests {
    use super::*;

    fn tiny_state(cfg: ServeConfig) -> ServerState {
        let texts = vec![
            "the quick brown fox".to_string(),
            "jumps over the lazy dog".to_string(),
        ];
        ServerState::new(cfg, Tokenizer::build(&texts, 32))
    }

    fn wire(body: &str) -> CompletionReq {
        CompletionReq::parse(body.as_bytes()).unwrap()
    }

    #[test]
    fn build_request_applies_server_defaults() {
        let st = tiny_state(ServeConfig {
            default_max_new: 7,
            default_spec: SamplerSpec::Temperature { temperature: 0.5 },
            ..Default::default()
        });
        let r = build_request(&st, &wire(r#"{"prompt": "quick fox"}"#)).unwrap();
        assert_eq!(r.max_new, 7);
        assert_eq!(r.sampler, SamplerSpec::Temperature { temperature: 0.5 });
        assert!(!r.prompt.is_empty());
        // BOS ... SEP framing, same as the offline eval path
        assert_eq!(r.prompt[0], crate::data::tokenizer::BOS);
        assert_eq!(*r.prompt.last().unwrap(), crate::data::tokenizer::SEP);
    }

    #[test]
    fn build_request_clamps_max_new_and_validates_tokens() {
        let st = tiny_state(ServeConfig { max_new_cap: 8, ..Default::default() });
        let r = build_request(&st, &wire(r#"{"prompt": "x", "max_new": 999}"#)).unwrap();
        assert_eq!(r.max_new, 8);
        let vocab = st.tok.vocab_size() as i32;
        let bad = format!(r#"{{"tokens": [1, {vocab}]}}"#);
        let err = build_request(&st, &wire(&bad)).unwrap_err().to_string();
        assert!(err.contains("outside the vocabulary"), "{err}");
    }

    #[test]
    fn explicit_tokens_bypass_the_tokenizer() {
        let st = tiny_state(ServeConfig::default());
        let r = build_request(&st, &wire(r#"{"tokens": [1, 9, 3]}"#)).unwrap();
        assert_eq!(r.prompt, vec![1, 9, 3]);
    }

    #[test]
    fn stop_strings_are_tokenized_and_merged_with_stop_tokens() {
        let st = tiny_state(ServeConfig::default());
        let c = wire(r#"{"prompt": "x", "stop": ["quick fox"], "stop_tokens": [[6, 7]]}"#);
        let r = build_request(&st, &c).unwrap();
        assert_eq!(r.stop.len(), 2);
        assert_eq!(r.stop[0], vec![6, 7]);
        assert_eq!(r.stop[1], st.tok.encode("quick fox"));
    }

    #[test]
    fn server_assigned_seeds_differ_per_request() {
        let st = tiny_state(ServeConfig::default());
        let a = build_request(&st, &wire(r#"{"prompt": "x"}"#)).unwrap();
        let b = build_request(&st, &wire(r#"{"prompt": "x"}"#)).unwrap();
        assert_ne!(a.seed, b.seed);
        let c = build_request(&st, &wire(r#"{"prompt": "x", "seed": 5}"#)).unwrap();
        assert_eq!(c.seed, 5);
    }

    #[test]
    fn logit_bias_lands_in_the_sampler_spec() {
        let st = tiny_state(ServeConfig::default());
        let r = build_request(&st, &wire(r#"{"prompt": "x", "ban": [9]}"#)).unwrap();
        assert!(matches!(&r.sampler, SamplerSpec::Biased { bias, .. }
            if bias.as_slice() == [(9, f32::NEG_INFINITY)]));
    }

    #[test]
    fn shutdown_flag_flips_stopping() {
        let st = tiny_state(ServeConfig::default());
        assert!(!st.stopping());
        st.request_shutdown();
        assert!(st.stopping());
    }

    fn sink_with_buf(buf: usize) -> (HttpSink, Receiver<Event>) {
        let (tx, rx) = mpsc::sync_channel(buf);
        let sink = HttpSink {
            tx,
            cancel: CancelToken::new(),
            dead: false,
            state: Arc::new(tiny_state(ServeConfig::default())),
            t0: Instant::now(),
            saw_first: false,
            n: 0,
        };
        (sink, rx)
    }

    #[test]
    fn stalled_event_buffer_kills_the_sink_and_cancels_the_row() {
        // nobody reads rx: the second token overflows the 1-slot buffer
        let (mut sink, rx) = sink_with_buf(1);
        sink.on_token(5);
        assert!(!sink.dead);
        assert!(!sink.cancel.is_cancelled());
        sink.on_token(6); // buffer full: drop the client, cancel the row
        assert!(sink.dead);
        assert!(sink.cancel.is_cancelled(), "stall flips the cancel token");
        sink.on_token(7); // dead sinks no-op; the model thread never blocks
        let delivered: Vec<_> = rx.try_iter().collect();
        assert_eq!(delivered.len(), 1, "only the pre-stall token crossed");
    }

    #[test]
    fn disconnected_event_channel_cancels_the_row() {
        let (mut sink, rx) = sink_with_buf(8);
        drop(rx); // worker returned: client hung up or deadline hit
        sink.on_token(5);
        assert!(sink.dead);
        assert!(sink.cancel.is_cancelled());
    }

    #[test]
    fn on_fail_counts_by_class_and_forwards_the_event() {
        let (mut sink, rx) = sink_with_buf(8);
        let before = sink.state.metrics.fail_count(FailClass::Overloaded);
        sink.on_fail(&ServeFail::new(FailClass::Overloaded, "pool full"));
        assert_eq!(sink.state.metrics.fail_count(FailClass::Overloaded), before + 1);
        match rx.try_recv() {
            Ok(Event::Fail(f)) => {
                assert_eq!(f.class, FailClass::Overloaded);
                assert_eq!(f.message, "pool full");
            }
            other => panic!("expected Event::Fail, got {:?}", other.map(|_| "event")),
        }
    }

    #[test]
    fn fail_status_maps_classes_to_http() {
        let (code, extra) = fail_status(&ServeFail::new(FailClass::Overloaded, "x"));
        assert_eq!(code, 503);
        assert_eq!(extra, &[("Retry-After", "1")]);
        let (code, extra) = fail_status(&ServeFail::new(FailClass::Internal, "x"));
        assert_eq!(code, 500);
        assert!(extra.is_empty());
        let (code, _) = fail_status(&ServeFail::new(FailClass::Cancelled, "x"));
        assert_eq!(code, 500);
    }
}
