//! `lisa serve` — an HTTP/1.1 front end over the continuous-batching
//! serve loop (DESIGN.md §11).
//!
//! Built entirely on `std::net` + the crate's own substrates (no async
//! runtime, no HTTP crate): [`proto`] owns the wire format, [`metrics`]
//! the Prometheus export, and [`server`] the threading contract — one
//! model thread driving [`ServeSession::run_loop`] through a bounded
//! admission channel, N scoped HTTP workers, 429 backpressure past the
//! queue bound, and a SIGINT-triggered graceful drain.
//!
//! [`ServeSession::run_loop`]: crate::engine::ServeSession::run_loop

pub mod metrics;
pub mod proto;
pub mod server;

pub use metrics::{EngineSnapshot, Metrics};
pub use proto::CompletionReq;
pub use server::{
    install_sigint, sigint_received, ChannelSource, HttpFrontend, ServeConfig, ServerState,
};
