//! Analytical GPU-memory model — regenerates Table 1 / Fig 3 at paper
//! scale (models we obviously cannot instantiate on this CPU testbed) and
//! calibrates against the byte-accurate `MemoryMeter` numbers of the small
//! configs we *do* run.
//!
//! Assumptions (documented in EXPERIMENTS.md): mixed-precision training in
//! the paper's setup stores fp16 weights (2 B/param), fp16 gradients
//! (2 B/param) and fp16 Adam moments (2+2 B/param); activations are modeled
//! without gradient checkpointing/flash attention — both excluded by the
//! paper's §4.1 protocol: per layer `B·T·(c_act·(D + D_ff) + H·T)` fp16
//! values plus the logit block. Sequence length 1024, batch 1 (paper §4.1).

use crate::util::table::{human_bytes, Table};

/// Paper-scale architecture entry (never lowered to artifacts).
#[derive(Debug, Clone, Copy)]
pub struct PaperModel {
    pub name: &'static str,
    pub d_model: u64,
    pub n_layers: u64,
    pub n_heads: u64,
    pub d_ff: u64,
    pub vocab: u64,
    /// Query heads per KV head (grouped-query attention; 1 = MHA).
    pub gqa_groups: u64,
    /// Embedding/head weight tying (GPT-2 style).
    pub tied_embeddings: bool,
    /// true => model parallel across 4 GPUs (the 70B row).
    pub model_parallel: bool,
}

pub const PAPER_MODELS: [PaperModel; 5] = [
    PaperModel { name: "GPT2-Small", d_model: 768, n_layers: 12, n_heads: 12,
                 d_ff: 3072, vocab: 50257, gqa_groups: 1,
                 tied_embeddings: true, model_parallel: false },
    PaperModel { name: "TinyLlama", d_model: 2048, n_layers: 22, n_heads: 32,
                 d_ff: 5632, vocab: 32000, gqa_groups: 8,
                 tied_embeddings: false, model_parallel: false },
    PaperModel { name: "Mistral-7B", d_model: 4096, n_layers: 32, n_heads: 32,
                 d_ff: 14336, vocab: 32000, gqa_groups: 4,
                 tied_embeddings: false, model_parallel: false },
    PaperModel { name: "LLaMA-2-7B", d_model: 4096, n_layers: 32, n_heads: 32,
                 d_ff: 11008, vocab: 32000, gqa_groups: 1,
                 tied_embeddings: false, model_parallel: false },
    PaperModel { name: "LLaMA-2-70B", d_model: 8192, n_layers: 80, n_heads: 64,
                 d_ff: 28672, vocab: 32000, gqa_groups: 8,
                 tied_embeddings: false, model_parallel: true },
];

/// Training method for the memory estimate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemMethod {
    Vanilla,
    Lora { rank: u64 },
    /// γ intermediate blocks + embedding + head unfrozen.
    Lisa { extra_layers: u64 },
}

pub const BYTES_W: u64 = 2; // fp16 weights
pub const BYTES_G: u64 = 2; // fp16 grads
pub const BYTES_OPT: u64 = 4; // fp16 m + v
/// Activation multiplier per (D + D_ff) hidden value (empirical constant
/// capturing the ~8 saved tensors per block without checkpointing).
pub const C_ACT: u64 = 8;
pub const SEQ: u64 = 1024;
pub const BATCH: u64 = 1;

impl PaperModel {
    pub fn params_per_block(&self) -> u64 {
        // q + o are full D*D; k + v shrink by the GQA group factor;
        // LLaMA-family uses gated MLP (3 matrices), GPT-2 uses 2.
        let mlp = if self.name == "GPT2-Small" { 2 } else { 3 };
        let d2 = self.d_model * self.d_model;
        2 * d2 + 2 * d2 / self.gqa_groups
            + mlp * self.d_model * self.d_ff + 2 * self.d_model
    }

    pub fn params_embed_head(&self) -> u64 {
        let emb = self.vocab * self.d_model;
        (if self.tied_embeddings { emb } else { 2 * emb }) + self.d_model
    }

    pub fn n_params(&self) -> u64 {
        self.params_embed_head() + self.n_layers * self.params_per_block()
    }

    fn act_bytes(&self, extra_adapter: bool) -> u64 {
        let per_layer = C_ACT * (self.d_model + self.d_ff) + self.n_heads * SEQ;
        let mut b = BATCH * SEQ * per_layer * self.n_layers * BYTES_W;
        b += BATCH * SEQ * self.vocab * 2 * BYTES_W; // logits + probs
        if extra_adapter {
            b += b / 8; // adapter activations (~12% in our measured runs)
        }
        b
    }

    /// Peak training bytes per GPU (paper setup: 4 GPUs; only opt/grad
    /// state of the *trained* subset exists; model-parallel rows shard
    /// weights+activations across the 4 GPUs).
    pub fn peak_bytes(&self, method: MemMethod) -> u64 {
        let n = self.n_params();
        let trained: u64 = match method {
            MemMethod::Vanilla => n,
            MemMethod::Lora { rank } => {
                // adapters on q,k,v,o + mlp matrices of every block
                let mlp = if self.name == "GPT2-Small" { 2 } else { 3 };
                let per_block = 4 * (2 * self.d_model * rank)
                    + mlp * rank * (self.d_model + self.d_ff);
                self.n_layers * per_block
            }
            MemMethod::Lisa { extra_layers } => {
                self.params_embed_head() + extra_layers * self.params_per_block()
            }
        };
        let weights = n * BYTES_W
            + if matches!(method, MemMethod::Lora { .. }) { trained * BYTES_W } else { 0 };
        let dynamic = trained * (BYTES_G + BYTES_OPT);
        let act = self.act_bytes(matches!(method, MemMethod::Lora { .. }));
        let total = weights + dynamic + act;
        if self.model_parallel {
            total / 4 + act / 8 // shard weights/state; activation overlap
        } else {
            total
        }
    }
}

/// The Table-1 grid: rows = models, columns = vanilla / LoRA ranks /
/// LISA activation configs.
pub fn table1() -> Table {
    let mut t = Table::new(vec![
        "Model", "Vanilla", "LoRA r=128", "LoRA r=256", "LoRA r=512",
        "LISA E+H", "LISA E+H+2L", "LISA E+H+4L",
    ]);
    for m in PAPER_MODELS {
        let f = |b: u64| human_bytes(b);
        t.row(vec![
            m.name.to_string(),
            f(m.peak_bytes(MemMethod::Vanilla)),
            f(m.peak_bytes(MemMethod::Lora { rank: 128 })),
            f(m.peak_bytes(MemMethod::Lora { rank: 256 })),
            f(m.peak_bytes(MemMethod::Lora { rank: 512 })),
            f(m.peak_bytes(MemMethod::Lisa { extra_layers: 0 })),
            f(m.peak_bytes(MemMethod::Lisa { extra_layers: 2 })),
            f(m.peak_bytes(MemMethod::Lisa { extra_layers: 4 })),
        ]);
    }
    t
}

/// Fig 3: memory breakdown for LLaMA-2-7B by method.
pub fn fig3_breakdown() -> Table {
    let m = PAPER_MODELS[3];
    let mut t = Table::new(vec!["method", "weights", "grads", "optim", "activations", "total"]);
    let rows: Vec<(&str, MemMethod)> = vec![
        ("FT", MemMethod::Vanilla),
        ("LoRA r=128", MemMethod::Lora { rank: 128 }),
        ("LISA E+H+2L", MemMethod::Lisa { extra_layers: 2 }),
    ];
    for (label, method) in rows {
        let n = m.n_params();
        let trained: u64 = match method {
            MemMethod::Vanilla => n,
            MemMethod::Lora { rank } => {
                let per_block = 4 * (2 * m.d_model * rank) + 3 * rank * (m.d_model + m.d_ff);
                m.n_layers * per_block
            }
            MemMethod::Lisa { extra_layers } => {
                m.params_embed_head() + extra_layers * m.params_per_block()
            }
        };
        let w = n * BYTES_W
            + if matches!(method, MemMethod::Lora { .. }) { trained * BYTES_W } else { 0 };
        let g = trained * BYTES_G;
        let o = trained * BYTES_OPT;
        let a = m.act_bytes(matches!(method, MemMethod::Lora { .. }));
        t.row(vec![
            label.to_string(),
            human_bytes(w),
            human_bytes(g),
            human_bytes(o),
            human_bytes(a),
            human_bytes(w + g + o + a),
        ]);
    }
    t
}

/// LoRA adapter parameter count (rank-r on every linear of every block).
pub fn lora_params(m: &PaperModel, rank: u64) -> u64 {
    let mlp = if m.name == "GPT2-Small" { 2 } else { 3 };
    let per_block =
        4 * (2 * m.d_model * rank) + mlp * rank * (m.d_model + m.d_ff);
    m.n_layers * per_block
}

/// FLOP model for one training step (Fig 4's mechanism at paper scale):
/// forward 2·(N + adapters)·tokens, input-grad backward through everything
/// the loss flows through, weight-grad matmuls only for trained tensors.
pub fn step_flops(m: &PaperModel, method: MemMethod) -> u64 {
    let tokens = BATCH * SEQ;
    let n = m.n_params();
    match method {
        MemMethod::Vanilla => 6 * n * tokens,
        MemMethod::Lora { rank } => {
            let a = lora_params(m, rank);
            // fwd through base+adapters, xgrad through base+adapters,
            // wgrad only for adapters
            (2 * (n + a) + 2 * (n + a) + 2 * a) * tokens
        }
        MemMethod::Lisa { extra_layers } => {
            let nu = m.params_embed_head() + extra_layers * m.params_per_block();
            (2 * n + 2 * n + 2 * nu) * tokens
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_are_plausible() {
        let names: Vec<(&str, f64)> = PAPER_MODELS
            .iter()
            .map(|m| (m.name, m.n_params() as f64 / 1e9))
            .collect();
        let get = |n: &str| names.iter().find(|(x, _)| *x == n).unwrap().1;
        assert!((get("GPT2-Small") - 0.124).abs() < 0.03, "{}", get("GPT2-Small"));
        assert!((get("TinyLlama") - 1.1).abs() < 0.25);
        assert!((get("LLaMA-2-7B") - 6.7).abs() < 1.0);
        assert!((get("LLaMA-2-70B") - 69.0).abs() < 8.0);
    }

    #[test]
    fn orderings_match_paper_table1() {
        for m in PAPER_MODELS {
            let vanilla = m.peak_bytes(MemMethod::Vanilla);
            let lora128 = m.peak_bytes(MemMethod::Lora { rank: 128 });
            let lora512 = m.peak_bytes(MemMethod::Lora { rank: 512 });
            let lisa_eh = m.peak_bytes(MemMethod::Lisa { extra_layers: 0 });
            let lisa2 = m.peak_bytes(MemMethod::Lisa { extra_layers: 2 });
            let lisa4 = m.peak_bytes(MemMethod::Lisa { extra_layers: 4 });
            // the paper's qualitative structure
            assert!(vanilla > lora128, "{}", m.name);
            assert!(lora128 < lora512, "{}", m.name);
            // paper's GPT2 row has LISA E+H == LoRA r128 (both 3.3G): allow 10%
            assert!(lisa_eh as f64 <= lora128 as f64 * 1.10,
                    "{}: LISA E+H must not exceed LoRA r128 by >10%", m.name);
            assert!(lisa_eh < lisa2 && lisa2 < lisa4, "{}", m.name);
        }
    }

    #[test]
    fn seven_b_magnitudes_near_paper() {
        // paper: vanilla 59G, LoRA-128 23G, LISA E+H+2L 23G for LLaMA-2-7B.
        let m = PAPER_MODELS[3];
        let g = |b: u64| b as f64 / (1u64 << 30) as f64;
        let vanilla = g(m.peak_bytes(MemMethod::Vanilla));
        let lora = g(m.peak_bytes(MemMethod::Lora { rank: 128 }));
        let lisa = g(m.peak_bytes(MemMethod::Lisa { extra_layers: 2 }));
        assert!((40.0..80.0).contains(&vanilla), "vanilla={vanilla:.1}G");
        assert!((15.0..32.0).contains(&lora), "lora={lora:.1}G");
        assert!((15.0..32.0).contains(&lisa), "lisa={lisa:.1}G");
    }

    #[test]
    fn flops_ordering_gives_lisa_speedup() {
        let m = PAPER_MODELS[3];
        let ft = step_flops(&m, MemMethod::Vanilla);
        let lisa = step_flops(&m, MemMethod::Lisa { extra_layers: 2 });
        let lora = step_flops(&m, MemMethod::Lora { rank: 128 });
        assert!(lisa < lora && lora < ft);
        // paper: ~2.9x over FT
        let speedup = ft as f64 / lisa as f64;
        assert!((1.3..2.0).contains(&speedup), "speedup={speedup:.2}");
    }
}
