//! Checkpointing: a small self-describing binary format (no serde in the
//! image). Two versions coexist:
//!
//! **v1** — flat weight-only tensor list (the seed format, still readable):
//!
//! ```text
//! magic "LISAckpt" | u32 version=1 | u32 n_tensors
//! per tensor: u32 name_len | name bytes | u32 rank | u64 dims[rank]
//!             | f32 data[numel]
//! ```
//!
//! **v2** — the full training-state format (DESIGN.md §7): named sections,
//! two dtypes (f32 tensors and raw u64 blobs for RNG/counter state), one
//! CRC-32 per serialized record, and atomic tmp+rename writes so a `kill`
//! mid-save never clobbers the previous checkpoint:
//!
//! ```text
//! magic "LISAckpt" | u32 version=2 | u32 n_sections
//! per section: u32 name_len | name | u32 n_entries | u32 crc(header)
//! per entry:   u32 name_len | name | u8 dtype(0=f32,1=u64) | u32 rank
//!              | u64 dims[rank] | data bytes | u32 crc(entry)
//! ```
//!
//! Each CRC covers every serialized byte of its record (length fields
//! included), so truncation or bit corruption anywhere after the 16-byte
//! preamble is detected; the preamble itself is guarded by the magic,
//! version and end-of-file position checks. Every length read from a file
//! is validated against the remaining file size *before* any allocation —
//! a corrupt header can neither overflow `numel` nor demand gigabytes.
//!
//! Little-endian throughout. Used by the continual-pretraining pipeline
//! (Table 4: CPT checkpoint -> fine-tune), the e2e example, and the
//! crash-safe resume protocol (`train::TrainSession::save_checkpoint`).

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::runtime::HostTensor;
use crate::util::crc32::Crc32;

use super::params::ModelParams;

const MAGIC: &[u8; 8] = b"LISAckpt";
const V1: u32 = 1;
const V2: u32 = 2;
const MAX_NAME: usize = 4096;
const MAX_RANK: usize = 8;

// ---------------------------------------------------------------------------
// v2 data model: sections of named blobs
// ---------------------------------------------------------------------------

/// One serialized value: an f32 tensor (weights, moments) or a raw u64
/// blob (RNG streams, cursors, counters, bit-cast f64s). Tensor payloads
/// are `Cow`: the save path *borrows* the live training tensors (no
/// transient copy of the model per checkpoint), the load path owns them.
#[derive(Debug, Clone, PartialEq)]
pub enum Blob<'a> {
    F32 { shape: Vec<usize>, data: Cow<'a, [f32]> },
    U64(Vec<u64>),
}

/// A named group of blobs — one per subsystem in a training-state
/// checkpoint ("meta", "model", "strategy", "loader"). Readers *take*
/// entries out, so after a component restored itself the section must be
/// empty; leftovers mean the file was written by a different
/// configuration and the load fails loudly instead of resuming wrong.
///
/// The lifetime is the writer-side borrow: `put_tensor`/`put_f32s` borrow
/// the caller's buffers and [`save_sections`] streams them through the
/// CRC accumulator without cloning. Sections returned by a loader are
/// `Section<'static>` (fully owned).
#[derive(Debug, Clone, PartialEq)]
pub struct Section<'a> {
    pub name: String,
    entries: BTreeMap<String, Blob<'a>>,
}

impl<'a> Section<'a> {
    pub fn new(name: &str) -> Section<'a> {
        Section { name: name.to_string(), entries: BTreeMap::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Remaining (unconsumed) entry names — for error messages.
    pub fn keys(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    /// Borrow a tensor into the section (zero-copy; the tensor must
    /// outlive the section — the normal save path, where sections are
    /// built and written within one call).
    pub fn put_tensor(&mut self, key: &str, t: &'a HostTensor) {
        self.entries.insert(
            key.to_string(),
            Blob::F32 { shape: t.shape.clone(), data: Cow::Borrowed(&t.data) },
        );
    }

    /// Owned-tensor variant for callers that build sections from
    /// temporaries (tests, format tooling).
    pub fn put_tensor_owned(&mut self, key: &str, t: HostTensor) {
        self.entries.insert(
            key.to_string(),
            Blob::F32 { shape: t.shape, data: Cow::Owned(t.data) },
        );
    }

    /// Rank-1 f32 buffer, borrowed (optimizer moments — shape lives with
    /// the params).
    pub fn put_f32s(&mut self, key: &str, data: &'a [f32]) {
        self.entries.insert(
            key.to_string(),
            Blob::F32 { shape: vec![data.len()], data: Cow::Borrowed(data) },
        );
    }

    pub fn put_u64s(&mut self, key: &str, data: Vec<u64>) {
        self.entries.insert(key.to_string(), Blob::U64(data));
    }

    pub fn put_u64(&mut self, key: &str, v: u64) {
        self.put_u64s(key, vec![v]);
    }

    /// f64s stored bit-exactly (EMA norms survive the round-trip).
    pub fn put_f64s(&mut self, key: &str, data: &[f64]) {
        self.put_u64s(key, data.iter().map(|x| x.to_bits()).collect());
    }

    /// UTF-8 string packed into a u64 blob: word 0 = byte length, then the
    /// bytes in little-endian words.
    pub fn put_str(&mut self, key: &str, s: &str) {
        let bytes = s.as_bytes();
        let mut words = vec![bytes.len() as u64];
        for chunk in bytes.chunks(8) {
            let mut w = [0u8; 8];
            w[..chunk.len()].copy_from_slice(chunk);
            words.push(u64::from_le_bytes(w));
        }
        self.put_u64s(key, words);
    }

    fn take(&mut self, key: &str) -> Result<Blob<'a>> {
        self.entries.remove(key).with_context(|| {
            format!("checkpoint section '{}' missing entry '{key}'", self.name)
        })
    }

    pub fn take_tensor(&mut self, key: &str) -> Result<HostTensor> {
        match self.take(key)? {
            Blob::F32 { shape, data } => {
                let data = data.into_owned();
                ensure!(
                    crate::runtime::numel(&shape) == data.len(),
                    "entry '{key}': shape {shape:?} does not fit {} elements",
                    data.len()
                );
                Ok(HostTensor { shape, data })
            }
            Blob::U64(_) => bail!("entry '{key}' is u64, expected f32 tensor"),
        }
    }

    pub fn take_f32s(&mut self, key: &str) -> Result<Vec<f32>> {
        Ok(self.take_tensor(key)?.data)
    }

    pub fn take_u64s(&mut self, key: &str) -> Result<Vec<u64>> {
        match self.take(key)? {
            Blob::U64(v) => Ok(v),
            Blob::F32 { .. } => bail!("entry '{key}' is f32, expected u64 blob"),
        }
    }

    pub fn take_u64(&mut self, key: &str) -> Result<u64> {
        let v = self.take_u64s(key)?;
        ensure!(v.len() == 1, "entry '{key}': expected one u64, got {}", v.len());
        Ok(v[0])
    }

    pub fn take_f64s(&mut self, key: &str) -> Result<Vec<f64>> {
        Ok(self.take_u64s(key)?.into_iter().map(f64::from_bits).collect())
    }

    pub fn take_str(&mut self, key: &str) -> Result<String> {
        let words = self.take_u64s(key)?;
        ensure!(!words.is_empty(), "entry '{key}': empty string blob");
        let len = words[0] as usize;
        ensure!(
            len <= (words.len() - 1) * 8,
            "entry '{key}': string length {len} exceeds blob"
        );
        let mut bytes = Vec::with_capacity(len);
        for w in &words[1..] {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        bytes.truncate(len);
        String::from_utf8(bytes).with_context(|| format!("entry '{key}' not utf8"))
    }

    /// Fixed-width RNG state helpers (the "raw u64 blob" convention).
    pub fn put_rng(&mut self, key: &str, rng: &crate::util::rng::Rng) {
        self.put_u64s(key, rng.state().to_vec());
    }

    pub fn take_rng(&mut self, key: &str) -> Result<crate::util::rng::Rng> {
        let v = self.take_u64s(key)?;
        ensure!(v.len() == 4, "entry '{key}': RNG state has {} words, expected 4", v.len());
        crate::util::rng::Rng::from_state([v[0], v[1], v[2], v[3]])
    }
}

/// Error unless every entry of `sec` was consumed — the guard against
/// silently resuming from a checkpoint written by a different config.
pub fn ensure_consumed(sec: &Section<'_>) -> Result<()> {
    ensure!(
        sec.is_empty(),
        "checkpoint section '{}' has {} unexpected entries (e.g. {:?}) — \
         written by a different configuration?",
        sec.name,
        sec.len(),
        sec.keys().into_iter().take(4).collect::<Vec<_>>()
    );
    Ok(())
}

/// Remove and return the named section from a loaded checkpoint.
pub fn take_section<'a>(sections: &mut Vec<Section<'a>>, name: &str) -> Result<Section<'a>> {
    let i = sections
        .iter()
        .position(|s| s.name == name)
        .with_context(|| format!("checkpoint has no '{name}' section"))?;
    Ok(sections.remove(i))
}

// ---------------------------------------------------------------------------
// Atomic writes
// ---------------------------------------------------------------------------

fn tmp_path(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "ckpt".to_string());
    path.with_file_name(format!("{name}.tmp"))
}

/// Write via tmp file + fsync + rename: a crash at any point leaves either
/// the previous file or the new one, never a torn half-write.
fn atomic_write(
    path: &Path,
    write: impl FnOnce(&mut std::io::BufWriter<std::fs::File>) -> Result<()>,
) -> Result<()> {
    let tmp = tmp_path(path);
    let res = (|| -> Result<()> {
        let f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = std::io::BufWriter::new(f);
        write(&mut w)?;
        w.flush()?;
        w.get_ref().sync_all()?;
        Ok(())
    })();
    if let Err(e) = res {
        std::fs::remove_file(&tmp).ok();
        return Err(e);
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    // Durability, not just process-kill atomicity: the rename itself must
    // reach disk before we report success, or a power loss could revert
    // to the previous directory entry after training moved on.
    #[cfg(unix)]
    if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::File::open(parent)
            .and_then(|d| d.sync_all())
            .with_context(|| format!("fsyncing {}", parent.display()))?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Serialization helpers
// ---------------------------------------------------------------------------

fn f32s_as_bytes(data: &[f32]) -> &[u8] {
    // SAFETY: an f32 slice is valid to view as initialized bytes — same
    // allocation, same length in bytes, stricter source alignment, and
    // the borrow pins the data for the returned lifetime.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

fn u64s_as_bytes(data: &[u64]) -> &[u8] {
    // u64 is little-endian on every platform this runs on (x86-64/aarch64);
    // the format is defined as LE and the loader reads words explicitly.
    // SAFETY: as above — a u64 slice viewed as bytes covers the same
    // allocation with a stricter source alignment.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 8) }
}

/// Serialize one v2 record (section header or entry) into `buf`.
fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_named(buf: &mut Vec<u8>, name: &str) {
    push_u32(buf, name.len() as u32);
    buf.extend_from_slice(name.as_bytes());
}

/// Stream one record from its parts: the CRC-32 accumulator runs over the
/// borrowed slices directly, so large tensor payloads are never copied
/// into an intermediate record buffer (let alone into an owned `Section`).
fn write_record_parts(w: &mut impl Write, parts: &[&[u8]]) -> Result<()> {
    let mut crc = Crc32::new();
    for p in parts {
        crc.update(p);
    }
    for p in parts {
        w.write_all(p)?;
    }
    w.write_all(&crc.finish().to_le_bytes())?;
    Ok(())
}

/// Checked reader: tracks the bytes remaining in the file so every length
/// field is validated before allocation, and feeds parsed bytes to a CRC
/// accumulator for record verification.
struct Rd<R: Read> {
    r: R,
    remaining: u64,
    crc: Crc32,
}

impl<R: Read> Rd<R> {
    fn new(r: R, len: u64) -> Rd<R> {
        Rd { r, remaining: len, crc: Crc32::new() }
    }

    fn fill(&mut self, buf: &mut [u8]) -> Result<()> {
        ensure!(
            buf.len() as u64 <= self.remaining,
            "corrupt checkpoint: record needs {} bytes but only {} remain",
            buf.len(),
            self.remaining
        );
        self.r.read_exact(buf).context("truncated checkpoint")?;
        self.remaining -= buf.len() as u64;
        self.crc.update(buf);
        Ok(())
    }

    fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.fill(&mut b)?;
        Ok(b[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.fill(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.fill(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    fn name(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        ensure!(len <= MAX_NAME, "corrupt checkpoint: name_len={len}");
        let mut bytes = vec![0u8; len];
        self.fill(&mut bytes)?;
        String::from_utf8(bytes).context("checkpoint name not utf8")
    }

    /// Validated shape read: bounded rank, overflow-checked numel, and the
    /// payload must fit in the remaining file — checked *before* the data
    /// buffer is allocated (an adversarial header can otherwise demand
    /// `usize::MAX` elements).
    fn shape(&mut self, width: u64) -> Result<(Vec<usize>, usize)> {
        let rank = self.u32()? as usize;
        ensure!(rank <= MAX_RANK, "corrupt checkpoint: rank={rank}");
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            let d = self.u64()?;
            ensure!(d <= usize::MAX as u64, "corrupt checkpoint: dim={d}");
            shape.push(d as usize);
        }
        let numel = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .context("corrupt checkpoint: shape product overflows")?;
        let bytes = (numel as u64)
            .checked_mul(width)
            .context("corrupt checkpoint: payload size overflows")?;
        ensure!(
            bytes <= self.remaining,
            "corrupt checkpoint: tensor of {bytes} bytes but only {} remain",
            self.remaining
        );
        Ok((shape, numel))
    }

    fn f32_data(&mut self, numel: usize) -> Result<Vec<f32>> {
        let mut data = vec![0f32; numel];
        // SAFETY: the zero-initialized f32 buffer is viewed as exactly
        // its own `numel * 4` bytes; every bit pattern is a valid f32,
        // so filling the bytes cannot create an invalid value.
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
        };
        self.fill(bytes)?;
        Ok(data)
    }

    fn u64_data(&mut self, numel: usize) -> Result<Vec<u64>> {
        let mut data = Vec::with_capacity(numel);
        for _ in 0..numel {
            data.push(self.u64()?);
        }
        Ok(data)
    }

    fn crc_reset(&mut self) {
        self.crc = Crc32::new();
    }

    /// Read the stored record CRC (not fed back into the accumulator) and
    /// compare against everything parsed since `crc_reset`.
    fn crc_check(&mut self, what: &str) -> Result<()> {
        let want = self.crc.finish();
        ensure!(4 <= self.remaining, "truncated checkpoint: missing {what} crc");
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b).context("truncated checkpoint")?;
        self.remaining -= 4;
        let got = u32::from_le_bytes(b);
        ensure!(
            got == want,
            "corrupt checkpoint: {what} crc mismatch ({got:#010x} != {want:#010x})"
        );
        Ok(())
    }
}

fn open_versioned(path: &Path) -> Result<(Rd<std::io::BufReader<std::fs::File>>, u32)> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let len = f.metadata()?.len();
    let mut rd = Rd::new(std::io::BufReader::new(f), len);
    let mut magic = [0u8; 8];
    rd.fill(&mut magic)
        .with_context(|| format!("{} is not a LISA checkpoint", path.display()))?;
    ensure!(&magic == MAGIC, "{} is not a LISA checkpoint", path.display());
    let version = rd.u32()?;
    ensure!(
        version == V1 || version == V2,
        "unsupported checkpoint version {version}"
    );
    Ok((rd, version))
}

// ---------------------------------------------------------------------------
// v1: flat weight-only tensor list (legacy, still read + written)
// ---------------------------------------------------------------------------

/// Legacy v1 writer (weight-only flat list); kept for compatibility
/// fixtures and external tooling. New code should write sections via
/// [`save_sections`]. The write is atomic like every checkpoint write.
pub fn save_tensors(path: &Path, tensors: &[(String, &HostTensor)]) -> Result<()> {
    atomic_write(path, |f| {
        f.write_all(MAGIC)?;
        f.write_all(&V1.to_le_bytes())?;
        f.write_all(&(tensors.len() as u32).to_le_bytes())?;
        for (name, t) in tensors {
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            f.write_all(f32s_as_bytes(&t.data))?;
        }
        Ok(())
    })
}

fn parse_v1(rd: &mut Rd<impl Read>) -> Result<BTreeMap<String, HostTensor>> {
    let n = rd.u32()? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name = rd.name()?;
        let (shape, numel) = rd.shape(4)?;
        let data = rd.f32_data(numel)?;
        out.insert(name, HostTensor { shape, data });
    }
    ensure!(
        rd.remaining == 0,
        "corrupt checkpoint: {} trailing bytes",
        rd.remaining
    );
    Ok(out)
}

/// Read a v1 flat tensor file. v2 files are section-structured — load
/// those with [`load_sections`] (or [`load_model`], which accepts both).
pub fn load_tensors(path: &Path) -> Result<BTreeMap<String, HostTensor>> {
    let (mut rd, version) = open_versioned(path)?;
    ensure!(
        version == V1,
        "{} is a v{version} sectioned checkpoint, not a v1 tensor list",
        path.display()
    );
    parse_v1(&mut rd)
}

// ---------------------------------------------------------------------------
// v2: sectioned, CRC-guarded
// ---------------------------------------------------------------------------

/// Write a v2 sectioned checkpoint atomically (tmp + fsync + rename).
/// The writer streams: record headers go through one small reused buffer
/// and tensor payloads are CRC'd and written straight from the borrowed
/// slices — a save never materializes a second copy of the model.
pub fn save_sections(path: &Path, sections: &[Section<'_>]) -> Result<()> {
    atomic_write(path, |f| {
        f.write_all(MAGIC)?;
        f.write_all(&V2.to_le_bytes())?;
        f.write_all(&(sections.len() as u32).to_le_bytes())?;
        let mut head = Vec::with_capacity(256);
        for sec in sections {
            head.clear();
            push_named(&mut head, &sec.name);
            push_u32(&mut head, sec.entries.len() as u32);
            write_record_parts(f, &[&head])?;
            for (key, blob) in &sec.entries {
                head.clear();
                push_named(&mut head, key);
                let payload: &[u8] = match blob {
                    Blob::F32 { shape, data } => {
                        head.push(0u8);
                        push_u32(&mut head, shape.len() as u32);
                        for &d in shape.iter() {
                            push_u64(&mut head, d as u64);
                        }
                        f32s_as_bytes(data)
                    }
                    Blob::U64(v) => {
                        head.push(1u8);
                        push_u32(&mut head, 1); // rank-1 by construction
                        push_u64(&mut head, v.len() as u64);
                        u64s_as_bytes(v)
                    }
                };
                write_record_parts(f, &[&head, payload])?;
            }
        }
        Ok(())
    })
}

fn parse_v2(rd: &mut Rd<impl Read>) -> Result<Vec<Section<'static>>> {
    let n_sections = rd.u32()? as usize;
    let mut out = Vec::new();
    for _ in 0..n_sections {
        rd.crc_reset();
        let name = rd.name()?;
        let n_entries = rd.u32()? as usize;
        rd.crc_check("section header")?;
        let mut sec = Section::new(&name);
        for _ in 0..n_entries {
            rd.crc_reset();
            let key = rd.name()?;
            let dtype = rd.u8()?;
            let blob = match dtype {
                0 => {
                    let (shape, numel) = rd.shape(4)?;
                    let data = rd.f32_data(numel)?;
                    Blob::F32 { shape, data: Cow::Owned(data) }
                }
                1 => {
                    let (shape, numel) = rd.shape(8)?;
                    ensure!(shape.len() == 1, "u64 blob '{key}' must be rank-1");
                    Blob::U64(rd.u64_data(numel)?)
                }
                d => bail!("corrupt checkpoint: unknown dtype {d} for '{key}'"),
            };
            rd.crc_check("entry")?;
            ensure!(
                sec.entries.insert(key.clone(), blob).is_none(),
                "corrupt checkpoint: duplicate entry '{key}' in section '{name}'"
            );
        }
        out.push(sec);
    }
    ensure!(
        rd.remaining == 0,
        "corrupt checkpoint: {} trailing bytes",
        rd.remaining
    );
    Ok(out)
}

/// Read a v2 sectioned checkpoint, verifying every record CRC.
pub fn load_sections(path: &Path) -> Result<Vec<Section<'static>>> {
    let (mut rd, version) = open_versioned(path)?;
    ensure!(
        version == V2,
        "{} is a v{version} checkpoint, expected a v2 sectioned file",
        path.display()
    );
    parse_v2(&mut rd)
}

// ---------------------------------------------------------------------------
// Model weights on top of both formats
// ---------------------------------------------------------------------------

/// Canonical tensor naming for a full model checkpoint.
pub(crate) fn model_tensor_list(p: &ModelParams) -> Vec<(String, &HostTensor)> {
    let mut v: Vec<(String, &HostTensor)> = vec![
        ("emb".into(), &p.emb),
        ("pos".into(), &p.pos),
        ("gf".into(), &p.gf),
        ("wh".into(), &p.wh),
    ];
    for (l, layer) in p.blocks.iter().enumerate() {
        for (t, x) in layer.iter().enumerate() {
            v.push((format!("block.{l}.{t}"), x));
        }
    }
    v
}

/// The "model" section of a training-state checkpoint. Borrows every
/// weight tensor — building and writing it costs no parameter copy.
pub fn model_section(p: &ModelParams) -> Section<'_> {
    let mut sec = Section::new("model");
    for (name, t) in model_tensor_list(p) {
        sec.put_tensor(&name, t);
    }
    sec
}

/// Restore model weights from a "model" section (shape-checked, every
/// tensor must be present, nothing may be left over).
pub fn load_model_section(sec: &mut Section<'_>, into: &mut ModelParams) -> Result<()> {
    let mut take = |name: &str, dst: &mut HostTensor| -> Result<()> {
        let t = sec.take_tensor(name)?;
        ensure!(
            t.shape == dst.shape,
            "tensor '{name}': shape {:?} != expected {:?}",
            t.shape,
            dst.shape
        );
        *dst = t;
        Ok(())
    };
    take("emb", &mut into.emb)?;
    take("pos", &mut into.pos)?;
    take("gf", &mut into.gf)?;
    take("wh", &mut into.wh)?;
    for l in 0..into.blocks.len() {
        for t in 0..into.blocks[l].len() {
            let name = format!("block.{l}.{t}");
            let x = sec.take_tensor(&name)?;
            ensure!(
                x.shape == into.blocks[l][t].shape,
                "tensor '{name}': shape mismatch"
            );
            into.blocks[l][t] = x;
        }
    }
    ensure_consumed(sec)
}

/// Write a weights-only checkpoint (v2, one "model" section, atomic).
pub fn save_model(path: &Path, p: &ModelParams) -> Result<()> {
    save_sections(path, &[model_section(p)])
}

/// Read model weights from either a v1 weight-only file or any v2
/// checkpoint containing a "model" section (including full training-state
/// checkpoints — the extra sections are ignored).
pub fn load_model(path: &Path, into: &mut ModelParams) -> Result<()> {
    let (mut rd, version) = open_versioned(path)?;
    if version == V1 {
        let mut sec = Section::new("model");
        for (name, t) in parse_v1(&mut rd)? {
            sec.put_tensor_owned(&name, t);
        }
        return load_model_section(&mut sec, into);
    }
    let mut sections = parse_v2(&mut rd)?;
    let mut model = take_section(&mut sections, "model")?;
    load_model_section(&mut model, into)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lisa_ckpt_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn tensor_roundtrip() {
        let path = tdir("v1rt").join("t.ckpt");
        let a = HostTensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = HostTensor::from_vec(&[4], vec![9.0; 4]);
        save_tensors(&path, &[("a".into(), &a), ("b".into(), &b)]).unwrap();
        let m = load_tensors(&path).unwrap();
        assert_eq!(m["a"], a);
        assert_eq!(m["b"], b);
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tdir("garbage").join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load_tensors(&path).is_err());
        assert!(load_sections(&path).is_err());
    }

    #[test]
    fn rejects_huge_numel_header_without_allocating() {
        // A v1 header declaring a [2^40, 2^40] tensor: numel overflows and
        // the payload exceeds the file; the loader must Err before any
        // allocation (the seed code allocated vec![0f32; numel] first).
        let path = tdir("huge").join("huge.ckpt");
        let mut f = Vec::new();
        f.extend_from_slice(MAGIC);
        f.extend_from_slice(&1u32.to_le_bytes());
        f.extend_from_slice(&1u32.to_le_bytes()); // one tensor
        f.extend_from_slice(&1u32.to_le_bytes()); // name_len
        f.push(b'x');
        f.extend_from_slice(&2u32.to_le_bytes()); // rank 2
        f.extend_from_slice(&(1u64 << 40).to_le_bytes());
        f.extend_from_slice(&(1u64 << 40).to_le_bytes());
        std::fs::write(&path, &f).unwrap();
        let err = format!("{:#}", load_tensors(&path).unwrap_err());
        assert!(err.contains("corrupt"), "got: {err}");
    }

    #[test]
    fn sections_roundtrip_all_dtypes() {
        let path = tdir("v2rt").join("s.ckpt");
        let w = HostTensor::from_vec(&[2, 2], vec![1.0, -2.0, 3.5, 0.0]);
        let moments = [0.5f32; 9];
        let mut a = Section::new("alpha");
        a.put_tensor("w", &w);
        a.put_u64s("rng", vec![1, 2, 3, 4]);
        a.put_u64("step", 7);
        a.put_f64s("ema", &[0.1, -3.7, f64::MIN_POSITIVE]);
        a.put_str("label", "lisa-grad");
        let mut b = Section::new("beta");
        b.put_f32s("m", &moments);
        save_sections(&path, &[a.clone(), b.clone()]).unwrap();

        let mut loaded = load_sections(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        let mut la = take_section(&mut loaded, "alpha").unwrap();
        assert_eq!(la.take_tensor("w").unwrap().data, vec![1.0, -2.0, 3.5, 0.0]);
        assert_eq!(la.take_u64s("rng").unwrap(), vec![1, 2, 3, 4]);
        assert_eq!(la.take_u64("step").unwrap(), 7);
        let ema = la.take_f64s("ema").unwrap();
        assert_eq!(ema[1].to_bits(), (-3.7f64).to_bits());
        assert_eq!(la.take_str("label").unwrap(), "lisa-grad");
        assert!(la.is_empty());
        let mut lb = take_section(&mut loaded, "beta").unwrap();
        assert_eq!(lb.take_f32s("m").unwrap(), vec![0.5; 9]);
        assert!(take_section(&mut loaded, "alpha").is_err());
    }

    #[test]
    fn missing_and_wrong_dtype_entries_error() {
        let mut s = Section::new("x");
        s.put_u64("n", 3);
        assert!(s.clone().take_tensor("n").is_err());
        assert!(s.clone().take_u64s("absent").is_err());
        assert!(ensure_consumed(&s).is_err());
        s.take_u64("n").unwrap();
        assert!(ensure_consumed(&s).is_ok());
    }

    #[test]
    fn str_blob_edge_cases() {
        let mut s = Section::new("x");
        s.put_str("empty", "");
        s.put_str("seven", "1234567");
        s.put_str("eight", "12345678");
        s.put_str("nine", "123456789");
        assert_eq!(s.take_str("empty").unwrap(), "");
        assert_eq!(s.take_str("seven").unwrap(), "1234567");
        assert_eq!(s.take_str("eight").unwrap(), "12345678");
        assert_eq!(s.take_str("nine").unwrap(), "123456789");
    }

    #[test]
    fn v2_bit_flip_in_tensor_data_is_detected() {
        let path = tdir("flip").join("f.ckpt");
        let w = [1.0f32; 32];
        let mut s = Section::new("m");
        s.put_f32s("w", &w);
        save_sections(&path, &[s]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() - 40; // inside the f32 payload
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", load_sections(&path).unwrap_err());
        assert!(err.contains("crc"), "got: {err}");
    }

    #[test]
    fn save_failure_leaves_previous_checkpoint_intact() {
        let dir = tdir("atomic");
        let path = dir.join("state.ckpt");
        let mut s = Section::new("m");
        s.put_u64("gen", 1);
        save_sections(&path, &[s.clone()]).unwrap();

        // Failure injection: a directory squatting on the tmp path makes
        // File::create fail, standing in for a crash mid-write.
        let tmp = tmp_path(&path);
        std::fs::create_dir_all(&tmp).unwrap();
        let mut s2 = Section::new("m");
        s2.put_u64("gen", 2);
        assert!(save_sections(&path, &[s2.clone()]).is_err());
        let mut loaded = load_sections(&path).unwrap();
        assert_eq!(loaded[0].take_u64("gen").unwrap(), 1, "old checkpoint must survive");

        // A stale tmp left by a killed writer must not break the next save.
        std::fs::remove_dir_all(&tmp).unwrap();
        std::fs::write(&tmp, b"half-written garbage from a dead process").unwrap();
        save_sections(&path, &[s2]).unwrap();
        let mut loaded = load_sections(&path).unwrap();
        assert_eq!(loaded[0].take_u64("gen").unwrap(), 2);
        assert!(!tmp.exists(), "tmp must be consumed by the rename");
    }
}
