//! Checkpointing: a small self-describing binary format (no serde in the
//! image). Layout:
//!
//! ```text
//! magic "LISAckpt" | u32 version | u32 n_tensors
//! per tensor: u32 name_len | name bytes | u32 rank | u64 dims[rank]
//!             | f32 data[numel]
//! ```
//!
//! Little-endian throughout. Used by the continual-pretraining pipeline
//! (Table 4: CPT checkpoint -> fine-tune) and the e2e example.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::HostTensor;

use super::params::ModelParams;

const MAGIC: &[u8; 8] = b"LISAckpt";
const VERSION: u32 = 1;

pub fn save_tensors(path: &Path, tensors: &[(String, &HostTensor)]) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
        };
        f.write_all(bytes)?;
    }
    Ok(())
}

pub fn load_tensors(path: &Path) -> Result<BTreeMap<String, HostTensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a LISA checkpoint", path.display());
    }
    let mut u32buf = [0u8; 4];
    f.read_exact(&mut u32buf)?;
    let version = u32::from_le_bytes(u32buf);
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    f.read_exact(&mut u32buf)?;
    let n = u32::from_le_bytes(u32buf) as usize;

    let mut out = BTreeMap::new();
    for _ in 0..n {
        f.read_exact(&mut u32buf)?;
        let name_len = u32::from_le_bytes(u32buf) as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name_len={name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf8")?;
        f.read_exact(&mut u32buf)?;
        let rank = u32::from_le_bytes(u32buf) as usize;
        if rank > 8 {
            bail!("corrupt checkpoint: rank={rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        let mut u64buf = [0u8; 8];
        for _ in 0..rank {
            f.read_exact(&mut u64buf)?;
            shape.push(u64::from_le_bytes(u64buf) as usize);
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
        };
        f.read_exact(bytes)?;
        out.insert(name, HostTensor { shape, data });
    }
    Ok(out)
}

/// Canonical tensor naming for a full model checkpoint.
fn model_tensor_list(p: &ModelParams) -> Vec<(String, &HostTensor)> {
    let mut v: Vec<(String, &HostTensor)> = vec![
        ("emb".into(), &p.emb),
        ("pos".into(), &p.pos),
        ("gf".into(), &p.gf),
        ("wh".into(), &p.wh),
    ];
    for (l, layer) in p.blocks.iter().enumerate() {
        for (t, x) in layer.iter().enumerate() {
            v.push((format!("block.{l}.{t}"), x));
        }
    }
    v
}

pub fn save_model(path: &Path, p: &ModelParams) -> Result<()> {
    save_tensors(path, &model_tensor_list(p))
}

pub fn load_model(path: &Path, into: &mut ModelParams) -> Result<()> {
    let mut tensors = load_tensors(path)?;
    let mut take = |name: &str, dst: &mut HostTensor| -> Result<()> {
        let t = tensors
            .remove(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint missing tensor '{name}'"))?;
        if t.shape != dst.shape {
            bail!("tensor '{name}': shape {:?} != expected {:?}", t.shape, dst.shape);
        }
        *dst = t;
        Ok(())
    };
    take("emb", &mut into.emb)?;
    take("pos", &mut into.pos)?;
    take("gf", &mut into.gf)?;
    take("wh", &mut into.wh)?;
    for l in 0..into.blocks.len() {
        for t in 0..into.blocks[l].len() {
            let name = format!("block.{l}.{t}");
            let x = tensors
                .remove(&name)
                .ok_or_else(|| anyhow::anyhow!("checkpoint missing tensor '{name}'"))?;
            if x.shape != into.blocks[l][t].shape {
                bail!("tensor '{name}': shape mismatch");
            }
            into.blocks[l][t] = x;
        }
    }
    if !tensors.is_empty() {
        bail!("checkpoint has {} unexpected tensors", tensors.len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip() {
        let dir = std::env::temp_dir().join("lisa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        let a = HostTensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = HostTensor::from_vec(&[4], vec![9.0; 4]);
        save_tensors(&path, &[("a".into(), &a), ("b".into(), &b)]).unwrap();
        let m = load_tensors(&path).unwrap();
        assert_eq!(m["a"], a);
        assert_eq!(m["b"], b);
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("lisa_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load_tensors(&path).is_err());
    }
}
