//! Model-side state owned by the coordinator: parameter store, init,
//! checkpointing. The architecture itself lives in the AOT artifacts; this
//! module only knows shapes (from the manifest) and bytes.

pub mod checkpoint;
pub mod params;

pub use params::{ModelParams, ParamKey};
