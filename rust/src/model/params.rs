//! Parameter store: every tensor of the transformer, host-side, in the ABI
//! order the artifacts expect (see `ModelConfig.block_param_shapes` /
//! `manifest.json`).



use crate::runtime::{HostTensor, Manifest};
use crate::util::rng::Rng;

/// Identifies one parameter tensor; the optimizer keys its state on this.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ParamKey {
    Emb,
    Pos,
    /// (layer index, tensor index within the block ABI order)
    Block(usize, usize),
    /// LoRA adapter: (layer index, adapter index within the LoRA ABI order)
    Lora(usize, usize),
    HeadNorm,
    HeadProj,
}

impl ParamKey {
    /// Stable string form used by checkpoint files to key optimizer state
    /// ("emb", "block.3.1", "lora.0.4", ...). Matches the model-tensor
    /// naming of `checkpoint::save_model` where both exist.
    pub fn name(&self) -> String {
        match self {
            ParamKey::Emb => "emb".to_string(),
            ParamKey::Pos => "pos".to_string(),
            ParamKey::Block(l, t) => format!("block.{l}.{t}"),
            ParamKey::Lora(l, t) => format!("lora.{l}.{t}"),
            ParamKey::HeadNorm => "gf".to_string(),
            ParamKey::HeadProj => "wh".to_string(),
        }
    }

    /// Inverse of [`ParamKey::name`]; errors on anything it did not write
    /// (checkpoint robustness: corrupt keys must not panic downstream).
    pub fn parse(s: &str) -> anyhow::Result<ParamKey> {
        let indexed = |rest: &str| -> Option<(usize, usize)> {
            let (l, t) = rest.split_once('.')?;
            Some((l.parse().ok()?, t.parse().ok()?))
        };
        match s {
            "emb" => Ok(ParamKey::Emb),
            "pos" => Ok(ParamKey::Pos),
            "gf" => Ok(ParamKey::HeadNorm),
            "wh" => Ok(ParamKey::HeadProj),
            _ => {
                if let Some(rest) = s.strip_prefix("block.") {
                    if let Some((l, t)) = indexed(rest) {
                        return Ok(ParamKey::Block(l, t));
                    }
                } else if let Some(rest) = s.strip_prefix("lora.") {
                    if let Some((l, t)) = indexed(rest) {
                        return Ok(ParamKey::Lora(l, t));
                    }
                }
                anyhow::bail!("unparseable parameter key '{s}'")
            }
        }
    }

    /// True for tensors that receive weight decay (matrices only — norm
    /// gains and embeddings are excluded, the standard AdamW convention).
    pub fn decayed(&self, block_param_names: &[(String, Vec<usize>)]) -> bool {
        match self {
            ParamKey::Emb | ParamKey::Pos | ParamKey::HeadNorm => false,
            ParamKey::HeadProj => true,
            ParamKey::Lora(..) => true,
            ParamKey::Block(_, t) => block_param_names
                .get(*t)
                .map(|(_, shape)| shape.len() > 1)
                .unwrap_or(false),
        }
    }
}

/// Process-unique id for a parameter store *generation*. Every distinct
/// `ModelParams` (or `lora::LoraState`) instance — fresh init, clone,
/// merged eval view — gets its own id, so the engine's device cache can
/// tell "same tensors as last step" from "a different store that happens
/// to use the same keys" without comparing data.
pub(crate) fn next_store_id() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// All trainable tensors of one model instance.
#[derive(Debug)]
pub struct ModelParams {
    pub emb: HostTensor,
    pub pos: HostTensor,
    /// `blocks[l]` holds the block-ABI-ordered tensors of layer `l`.
    pub blocks: Vec<Vec<HostTensor>>,
    pub gf: HostTensor,
    pub wh: HostTensor,
    /// Store-generation id (see [`next_store_id`]). In-place mutation
    /// keeps the id — that is what the strategy invalidation contract
    /// (`strategy::Strategy::apply` → `engine::Touched`) covers.
    store_id: u64,
}

impl Clone for ModelParams {
    fn clone(&self) -> Self {
        // A clone is a *different* store: its tensors may diverge from the
        // original (LoRA merge, CPT forks), so it must never share cached
        // device buffers keyed to the source id.
        ModelParams {
            emb: self.emb.clone(),
            pos: self.pos.clone(),
            blocks: self.blocks.clone(),
            gf: self.gf.clone(),
            wh: self.wh.clone(),
            store_id: next_store_id(),
        }
    }
}

impl ModelParams {
    /// The store-generation id the engine's device cache stamps uploads
    /// with.
    pub fn store_id(&self) -> u64 {
        self.store_id
    }

    /// Read-only evaluation view: clones the tensor data but *shares* the
    /// store-generation id, so feeding it to an engine whose cache is
    /// warm on the original serves the cached buffers (the bytes are
    /// identical by construction) instead of evicting the whole cache.
    /// Contract: a view must stay byte-identical to its source for as
    /// long as both can reach the same engine — anything that produces
    /// genuinely different eval weights (LoRA's merge) must use
    /// `clone()`, which takes a fresh generation.
    pub fn eval_view(&self) -> ModelParams {
        ModelParams {
            emb: self.emb.clone(),
            pos: self.pos.clone(),
            blocks: self.blocks.clone(),
            gf: self.gf.clone(),
            wh: self.wh.clone(),
            store_id: self.store_id,
        }
    }
    /// GPT-2-style init: N(0, 0.02) embeddings and matrices, unit norm
    /// gains, residual-out projections (wo, w2) scaled by 1/sqrt(2L).
    pub fn init(m: &Manifest, rng: &mut Rng) -> ModelParams {
        let std = 0.02f32;
        let resid_scale = 1.0 / ((2 * m.n_layers) as f32).sqrt();

        let mut emb = HostTensor::zeros(&[m.vocab, m.d_model]);
        rng.fill_normal(&mut emb.data, std);
        let mut pos = HostTensor::zeros(&[m.seq, m.d_model]);
        rng.fill_normal(&mut pos.data, std * 0.5);

        let mut blocks = Vec::with_capacity(m.n_layers);
        for _ in 0..m.n_layers {
            let mut layer = Vec::with_capacity(m.block_params.len());
            for (name, shape) in &m.block_params {
                let mut t = HostTensor::zeros(shape);
                match name.as_str() {
                    "g1" | "g2" => t.fill(1.0),
                    "wo" | "w2" => rng.fill_normal(&mut t.data, std * resid_scale),
                    _ => rng.fill_normal(&mut t.data, std),
                }
                layer.push(t);
            }
            blocks.push(layer);
        }

        let mut gf = HostTensor::zeros(&[m.d_model]);
        gf.fill(1.0);
        let mut wh = HostTensor::zeros(&[m.d_model, m.vocab]);
        rng.fill_normal(&mut wh.data, std);

        ModelParams { emb, pos, blocks, gf, wh, store_id: next_store_id() }
    }

    pub fn n_layers(&self) -> usize {
        self.blocks.len()
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.iter().map(|(_, t)| t.numel()).sum()
    }

    /// Total parameter bytes (f32).
    pub fn bytes(&self) -> usize {
        self.n_params() * 4
    }

    /// Iterate every tensor with its key (immutable).
    pub fn iter(&self) -> impl Iterator<Item = (ParamKey, &HostTensor)> {
        let blocks = self
            .blocks
            .iter()
            .enumerate()
            .flat_map(|(l, ts)| {
                ts.iter().enumerate().map(move |(t, x)| (ParamKey::Block(l, t), x))
            });
        [(ParamKey::Emb, &self.emb), (ParamKey::Pos, &self.pos)]
            .into_iter()
            .chain(blocks)
            .chain([(ParamKey::HeadNorm, &self.gf), (ParamKey::HeadProj, &self.wh)])
    }

    /// Tensor for a key, if it exists in this model (LoRA adapters live in
    /// `lora::LoraState`, so `Lora` keys return `None` here).
    pub fn get(&self, key: ParamKey) -> Option<&HostTensor> {
        match key {
            ParamKey::Emb => Some(&self.emb),
            ParamKey::Pos => Some(&self.pos),
            ParamKey::Block(l, t) => self.blocks.get(l)?.get(t),
            ParamKey::HeadNorm => Some(&self.gf),
            ParamKey::HeadProj => Some(&self.wh),
            ParamKey::Lora(..) => None,
        }
    }

    pub fn get_mut(&mut self, key: ParamKey) -> &mut HostTensor {
        match key {
            ParamKey::Emb => &mut self.emb,
            ParamKey::Pos => &mut self.pos,
            ParamKey::Block(l, t) => &mut self.blocks[l][t],
            ParamKey::HeadNorm => &mut self.gf,
            ParamKey::HeadProj => &mut self.wh,
            ParamKey::Lora(..) => panic!("LoRA adapters live in lora::LoraState"),
        }
    }

    /// Mean per-layer weight norm, the Fig 2 / Fig 12 observable:
    /// index 0 = embedding, 1..=L = blocks, L+1 = head.
    pub fn layer_weight_norms(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.blocks.len() + 2);
        out.push(self.emb.l2_norm());
        for layer in &self.blocks {
            let norm: f64 = layer.iter().map(|t| t.l2_norm().powi(2)).sum::<f64>().sqrt();
            out.push(norm);
        }
        out.push(self.wh.l2_norm());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::Manifest;
    use std::path::Path;

    fn tiny_manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn init_matches_manifest_count() {
        let Some(m) = tiny_manifest() else { return };
        let mut rng = Rng::new(1);
        let p = ModelParams::init(&m, &mut rng);
        assert_eq!(p.n_params(), m.n_params, "init count vs aot.py count");
        assert_eq!(p.n_layers(), m.n_layers);
    }

    #[test]
    fn init_is_seed_deterministic() {
        let Some(m) = tiny_manifest() else { return };
        let a = ModelParams::init(&m, &mut Rng::new(9));
        let b = ModelParams::init(&m, &mut Rng::new(9));
        assert_eq!(a.emb.data, b.emb.data);
        assert_eq!(a.blocks[1][3].data, b.blocks[1][3].data);
    }

    #[test]
    fn norm_gains_are_ones() {
        let Some(m) = tiny_manifest() else { return };
        let p = ModelParams::init(&m, &mut Rng::new(2));
        // g1 is ABI index 0, g2 index 5
        assert!(p.blocks[0][0].data.iter().all(|&x| x == 1.0));
        assert!(p.blocks[0][5].data.iter().all(|&x| x == 1.0));
        assert!(p.gf.data.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn weight_norms_shape() {
        let Some(m) = tiny_manifest() else { return };
        let p = ModelParams::init(&m, &mut Rng::new(2));
        let norms = p.layer_weight_norms();
        assert_eq!(norms.len(), m.n_layers + 2);
        assert!(norms.iter().all(|&n| n > 0.0));
    }

    #[test]
    fn param_key_name_roundtrip() {
        let keys = [
            ParamKey::Emb,
            ParamKey::Pos,
            ParamKey::Block(0, 0),
            ParamKey::Block(13, 7),
            ParamKey::Lora(2, 11),
            ParamKey::HeadNorm,
            ParamKey::HeadProj,
        ];
        for k in keys {
            assert_eq!(ParamKey::parse(&k.name()).unwrap(), k, "roundtrip of {k:?}");
        }
        for bad in ["", "block", "block.1", "block.x.y", "lora.1.", "emb2"] {
            assert!(ParamKey::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn decay_policy() {
        let names = vec![
            ("g1".to_string(), vec![8usize]),
            ("wq".to_string(), vec![8, 8]),
        ];
        assert!(!ParamKey::Emb.decayed(&names));
        assert!(!ParamKey::Block(0, 0).decayed(&names));
        assert!(ParamKey::Block(0, 1).decayed(&names));
        assert!(ParamKey::HeadProj.decayed(&names));
    }
}
