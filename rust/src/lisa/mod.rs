//! The paper's algorithm: Layerwise Importance Sampled AdamW (Algorithm 1).
//!
//! Every `K` optimizer steps:
//!   1. freeze all intermediate blocks,
//!   2. always keep the embedding and LM-head trainable,
//!   3. sample `γ` intermediate blocks to unfreeze.
//!
//! The practical sampler (paper §3.2) draws exactly `γ` blocks uniformly —
//! this upper-bounds unfrozen-layer memory. The general importance-sampling
//! variant (`LayerDist::Weighted`, the `p^(ℓ) = w̃^(ℓ)/w^(ℓ)` rule from the
//! motivation and the Limitations section) samples each block independently
//! or by weighted choice without replacement; it backs the extension
//! experiment `exp lisa-weighted`.

use crate::engine::TrainMask;
use crate::util::rng::Rng;

/// Sampling distribution over intermediate blocks.
#[derive(Debug, Clone)]
pub enum LayerDist {
    /// Exactly γ blocks, uniform without replacement (the paper's LISA).
    Uniform,
    /// Exactly γ blocks, weighted without replacement by the given
    /// per-block importance (the w̃/w rule; weights need not normalize).
    Weighted(Vec<f64>),
}

#[derive(Debug, Clone)]
pub struct LisaConfig {
    /// γ — number of intermediate blocks unfrozen per sampling period.
    pub gamma: usize,
    /// K — optimizer steps between resamples.
    pub period_k: usize,
    /// Train embedding every step (paper: yes).
    pub train_embed: bool,
    /// Train LM head every step (paper: yes).
    pub train_head: bool,
    pub dist: LayerDist,
    /// LISA-fix ablation (Table 11): sample once at step 0 and never again.
    pub fixed: bool,
}

impl LisaConfig {
    pub fn paper(gamma: usize, period_k: usize) -> Self {
        LisaConfig {
            gamma,
            period_k,
            train_embed: true,
            train_head: true,
            dist: LayerDist::Uniform,
            fixed: false,
        }
    }
}

/// Stateful scheduler: owns the RNG stream for layer selection so runs are
/// reproducible per seed (Table 7 / Fig 10).
#[derive(Debug, Clone)]
pub struct LisaScheduler {
    cfg: LisaConfig,
    n_layers: usize,
    rng: Rng,
    current: Vec<usize>,
    /// History of sampled sets (ablation/diagnostics).
    pub history: Vec<Vec<usize>>,
    resamples: usize,
}

impl LisaScheduler {
    pub fn new(cfg: LisaConfig, n_layers: usize, seed: u64) -> Self {
        assert!(cfg.gamma <= n_layers, "γ={} > L={}", cfg.gamma, n_layers);
        assert!(cfg.period_k >= 1, "K must be >= 1");
        LisaScheduler {
            cfg,
            n_layers,
            rng: Rng::new(seed),
            current: Vec::new(),
            history: Vec::new(),
            resamples: 0,
        }
    }

    fn resample(&mut self) {
        self.current = match &self.cfg.dist {
            LayerDist::Uniform => self.rng.sample_distinct(self.n_layers, self.cfg.gamma),
            LayerDist::Weighted(w) => {
                assert_eq!(w.len(), self.n_layers, "weight arity");
                sample_weighted_distinct(&mut self.rng, w, self.cfg.gamma)
            }
        };
        self.history.push(self.current.clone());
        self.resamples += 1;
    }

    /// The trainable mask for optimizer step `step` (0-based). Resamples on
    /// period boundaries (Algorithm 1 line 3), except in `fixed` mode.
    pub fn mask_for_step(&mut self, step: usize) -> TrainMask {
        let boundary = step % self.cfg.period_k == 0;
        if self.current.is_empty() || (boundary && !(self.cfg.fixed && self.resamples > 0)) {
            self.resample();
        }
        let mut blocks = vec![false; self.n_layers];
        for &l in &self.current {
            blocks[l] = true;
        }
        TrainMask {
            embed: self.cfg.train_embed,
            head: self.cfg.train_head,
            blocks,
        }
    }

    pub fn current_layers(&self) -> &[usize] {
        &self.current
    }

    pub fn n_resamples(&self) -> usize {
        self.resamples
    }

    /// Serialize the sampler state (RNG stream, live layer set, draw count
    /// and history) so a resumed run draws the exact same layer sequence
    /// the uninterrupted run would have (resume protocol, DESIGN.md §7).
    pub fn save_state(&self, sec: &mut crate::model::checkpoint::Section<'_>) {
        sec.put_rng("sampler.rng", &self.rng);
        sec.put_u64s(
            "sampler.current",
            self.current.iter().map(|&l| l as u64).collect(),
        );
        sec.put_u64("sampler.resamples", self.resamples as u64);
        // history entries are always γ long (the sampler invariant), so a
        // flat blob chunked by γ reconstructs it exactly
        sec.put_u64s(
            "sampler.history",
            self.history.iter().flatten().map(|&l| l as u64).collect(),
        );
    }

    /// Restore the state written by [`LisaScheduler::save_state`].
    pub fn load_state(
        &mut self,
        sec: &mut crate::model::checkpoint::Section<'_>,
    ) -> anyhow::Result<()> {
        use anyhow::ensure;
        self.rng = sec.take_rng("sampler.rng")?;
        let current = sec.take_u64s("sampler.current")?;
        // The γ invariant the sampler panics to protect elsewhere: a live
        // layer set is exactly γ *distinct* in-range blocks. A corrupt or
        // hand-edited checkpoint must not resume into a run that silently
        // trains the wrong number of blocks. (Empty is legal: a
        // checkpoint written before the first resample.)
        ensure!(
            current.is_empty() || current.len() == self.cfg.gamma,
            "sampler state holds {} live layers but γ = {} — corrupt checkpoint \
             or a different LISA config",
            current.len(),
            self.cfg.gamma
        );
        let mut seen = vec![false; self.n_layers];
        for &l in &current {
            let l = l as usize;
            ensure!(
                l < self.n_layers,
                "sampler state names layer {l} but the model has {} layers",
                self.n_layers
            );
            ensure!(
                !std::mem::replace(&mut seen[l], true),
                "sampler state lists layer {l} twice — the γ invariant needs \
                 distinct blocks"
            );
        }
        self.current = current.into_iter().map(|l| l as usize).collect();
        self.resamples = sec.take_u64("sampler.resamples")? as usize;
        let flat = sec.take_u64s("sampler.history")?;
        ensure!(
            flat.len() == self.resamples * self.cfg.gamma,
            "sampler history length {} != resamples {} x gamma {}",
            flat.len(),
            self.resamples,
            self.cfg.gamma
        );
        self.history = if self.cfg.gamma == 0 {
            vec![Vec::new(); self.resamples]
        } else {
            flat.chunks(self.cfg.gamma)
                .map(|c| c.iter().map(|&l| l as usize).collect())
                .collect()
        };
        Ok(())
    }
}

/// Weighted sampling without replacement: `k` distinct indices drawn
/// proportionally to `w`, each draw removing its index from the mass.
/// Returned sorted. Shared by the weighted `LisaScheduler` and the
/// gradient-adaptive strategy (`strategy::lisa_grad`).
///
/// Panics when the positive weight mass runs out before `k` draws —
/// silently under-sampling would break the γ invariant (every period must
/// unfreeze exactly γ blocks), so exhaustion is a configuration error.
pub fn sample_weighted_distinct(rng: &mut Rng, w: &[f64], k: usize) -> Vec<usize> {
    assert!(k <= w.len(), "sample_weighted_distinct: k={} > n={}", k, w.len());
    let mut w = w.to_vec();
    let mut out = Vec::with_capacity(k);
    for draw in 0..k {
        let mass: f64 = w.iter().sum();
        assert!(
            mass.is_finite() && mass > 0.0,
            "weighted mass exhausted after {draw}/{k} draws — need at least {k} strictly \
             positive weights"
        );
        let i = rng.sample_weighted(&w);
        out.push(i);
        w[i] = 0.0;
    }
    out.sort_unstable();
    out
}

/// The importance weights LISA's motivation derives from LoRA's layerwise
/// weight-norm skew: `p^(ℓ) ∝ w̃^(ℓ) / w^(ℓ)` where w̃ are LoRA-run norms
/// and w full-parameter norms (§3.2). Clamped to a small floor so every
/// layer keeps nonzero probability.
pub fn importance_weights(lora_norms: &[f64], ft_norms: &[f64]) -> Vec<f64> {
    assert_eq!(lora_norms.len(), ft_norms.len());
    lora_norms
        .iter()
        .zip(ft_norms)
        .map(|(&ln, &fn_)| (ln / fn_.max(1e-12)).max(1e-6))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_gamma_blocks_every_period() {
        let mut s = LisaScheduler::new(LisaConfig::paper(2, 5), 8, 42);
        for step in 0..50 {
            let m = s.mask_for_step(step);
            assert_eq!(m.n_trainable_blocks(), 2, "step {step}");
            assert!(m.embed && m.head);
        }
        assert_eq!(s.n_resamples(), 10);
    }

    #[test]
    fn mask_stable_within_period() {
        let mut s = LisaScheduler::new(LisaConfig::paper(3, 10), 12, 7);
        let m0 = s.mask_for_step(0);
        for step in 1..10 {
            assert_eq!(s.mask_for_step(step), m0);
        }
        // Likely different after the boundary (probability of equality is
        // 1/C(12,3) per draw; over 20 periods this is vanishing).
        let mut changed = false;
        for p in 1..20 {
            if s.mask_for_step(p * 10) != m0 {
                changed = true;
                break;
            }
        }
        assert!(changed);
    }

    #[test]
    fn seed_determinism_and_divergence() {
        let seq = |seed: u64| -> Vec<Vec<usize>> {
            let mut s = LisaScheduler::new(LisaConfig::paper(2, 1), 10, seed);
            (0..20).map(|i| {
                s.mask_for_step(i);
                s.current_layers().to_vec()
            }).collect()
        };
        assert_eq!(seq(1), seq(1));
        assert_ne!(seq(1), seq(2));
    }

    #[test]
    fn fixed_mode_never_resamples() {
        let mut cfg = LisaConfig::paper(2, 3);
        cfg.fixed = true;
        let mut s = LisaScheduler::new(cfg, 8, 5);
        let m0 = s.mask_for_step(0);
        for step in 1..60 {
            assert_eq!(s.mask_for_step(step), m0);
        }
        assert_eq!(s.n_resamples(), 1);
    }

    #[test]
    fn scheduler_state_roundtrip_continues_identically() {
        for fixed in [false, true] {
            let mut cfg = LisaConfig::paper(3, 4);
            cfg.fixed = fixed;
            let mut full = LisaScheduler::new(cfg.clone(), 10, 77);
            let mut part1 = LisaScheduler::new(cfg.clone(), 10, 77);
            for step in 0..13 {
                assert_eq!(full.mask_for_step(step), part1.mask_for_step(step));
            }
            let mut sec = crate::model::checkpoint::Section::new("strategy");
            part1.save_state(&mut sec);
            // resume into a scheduler built with a different seed: the
            // restored stream must win
            let mut part2 = LisaScheduler::new(cfg, 10, 999);
            part2.load_state(&mut sec).unwrap();
            assert!(sec.is_empty());
            assert_eq!(part2.history, full.history);
            assert_eq!(part2.n_resamples(), full.n_resamples());
            for step in 13..60 {
                assert_eq!(
                    full.mask_for_step(step),
                    part2.mask_for_step(step),
                    "fixed={fixed} diverged at step {step}"
                );
            }
        }
    }

    /// Hand-build a sampler-state section (what a corrupt/hand-edited
    /// checkpoint would deserialize to).
    fn sampler_section(current: Vec<u64>, history: Vec<u64>) -> crate::model::checkpoint::Section<'static> {
        let mut sec = crate::model::checkpoint::Section::new("strategy");
        sec.put_rng("sampler.rng", &Rng::new(7));
        sec.put_u64s("sampler.current", current);
        sec.put_u64("sampler.resamples", 1);
        sec.put_u64s("sampler.history", history);
        sec
    }

    #[test]
    fn load_state_rejects_wrong_cardinality_and_duplicates() {
        // γ=2 over 4 layers
        let fresh = || LisaScheduler::new(LisaConfig::paper(2, 3), 4, 1);

        // the γ invariant: a non-empty live set must be exactly γ blocks
        let mut s = fresh();
        let err = s.load_state(&mut sampler_section(vec![1], vec![1, 3])).unwrap_err();
        assert!(err.to_string().contains("γ"), "got: {err}");

        // ...of *distinct* blocks
        let mut s = fresh();
        let err = s.load_state(&mut sampler_section(vec![3, 3], vec![1, 3])).unwrap_err();
        assert!(err.to_string().contains("twice"), "got: {err}");

        // ...all in range
        let mut s = fresh();
        let err = s.load_state(&mut sampler_section(vec![1, 9], vec![1, 3])).unwrap_err();
        assert!(err.to_string().contains("9"), "got: {err}");

        // a well-formed section still loads
        let mut s = fresh();
        s.load_state(&mut sampler_section(vec![1, 3], vec![1, 3])).unwrap();
        assert_eq!(s.current_layers(), &[1, 3]);
        assert_eq!(s.n_resamples(), 1);
    }

    #[test]
    fn uniform_coverage_is_roughly_even() {
        let mut s = LisaScheduler::new(LisaConfig::paper(2, 1), 8, 11);
        let mut counts = vec![0usize; 8];
        let trials = 4000;
        for step in 0..trials {
            s.mask_for_step(step);
            for &l in s.current_layers() {
                counts[l] += 1;
            }
        }
        let expect = trials as f64 * 2.0 / 8.0;
        for (l, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.15, "layer {l}: count {c} vs expect {expect}");
        }
    }

    #[test]
    fn weighted_dist_respects_weights() {
        let mut w = vec![1.0; 8];
        w[3] = 0.0; // never sample layer 3
        w[0] = 50.0; // almost always sample layer 0
        let mut cfg = LisaConfig::paper(2, 1);
        cfg.dist = LayerDist::Weighted(w);
        let mut s = LisaScheduler::new(cfg, 8, 3);
        let mut c0 = 0;
        for step in 0..500 {
            s.mask_for_step(step);
            assert!(!s.current_layers().contains(&3));
            if s.current_layers().contains(&0) {
                c0 += 1;
            }
        }
        assert!(c0 > 450, "layer 0 sampled only {c0}/500");
    }

    #[test]
    fn importance_weights_formula() {
        let w = importance_weights(&[10.0, 1.0, 0.0], &[10.0, 10.0, 5.0]);
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert!((w[1] - 0.1).abs() < 1e-12);
        assert_eq!(w[2], 1e-6); // floored
    }

    #[test]
    #[should_panic(expected = "γ")]
    fn gamma_exceeding_layers_rejected() {
        LisaScheduler::new(LisaConfig::paper(9, 1), 8, 0);
    }

    #[test]
    fn weighted_distinct_covers_positive_support() {
        let mut rng = Rng::new(2);
        // exactly k positive weights: the draw must return them all
        let got = sample_weighted_distinct(&mut rng, &[0.0, 3.0, 0.0, 1.0, 2.0], 3);
        assert_eq!(got, vec![1, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "weighted mass exhausted")]
    fn weighted_distinct_errors_instead_of_undersampling() {
        let mut rng = Rng::new(2);
        // only one positive weight but two draws requested
        sample_weighted_distinct(&mut rng, &[0.0, 1.0, 0.0, 0.0], 2);
    }

    #[test]
    #[should_panic(expected = "weighted mass exhausted")]
    fn scheduler_resample_errors_when_mass_runs_out() {
        // γ=2 but only one block has positive weight: the old sampler
        // silently returned 1 block, breaking the γ invariant.
        let mut cfg = LisaConfig::paper(2, 1);
        cfg.dist = LayerDist::Weighted(vec![0.0, 1.0, 0.0, 0.0]);
        let mut s = LisaScheduler::new(cfg, 4, 1);
        s.mask_for_step(0);
    }
}
