//! Greedy autoregressive generation through the segment executables —
//! makes trained checkpoints *usable*, and powers the qualitative samples
//! in the e2e run.
//!
//! The artifacts are fixed-shape `[B, T]`, so generation teacher-forces the
//! prompt into row 0, then repeatedly runs the full forward and appends the
//! argmax at the last filled position. O(T) forwards per sample — fine for
//! the short answers our corpora use (the serving-optimized path would
//! export a KV-cached decode segment; noted as future work in DESIGN.md).

use anyhow::Result;

use crate::data::tokenizer::{Tokenizer, BOS, EOS, PAD, SEP};
use crate::engine::Engine;
use crate::model::ModelParams;
use crate::runtime::HostTensorI32;

/// Greedily complete `prompt`, returning the generated token ids (response
/// only, `<eos>`-terminated or length-capped).
pub fn greedy_complete(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompt: &str,
    max_new: usize,
) -> Result<Vec<i32>> {
    let m = eng.rt.manifest.clone();
    let mut seq = vec![BOS];
    seq.extend(tok.encode(prompt));
    seq.push(SEP);
    if seq.len() >= m.seq {
        seq.truncate(m.seq - 1);
    }
    let prompt_len = seq.len();
    let mut out = Vec::new();

    for _ in 0..max_new {
        if seq.len() >= m.seq {
            break;
        }
        let mut tokens = vec![PAD; m.batch * m.seq];
        tokens[..seq.len()].copy_from_slice(&seq);
        let t = HostTensorI32::from_vec(&[m.batch, m.seq], tokens);
        let logits = eng.logits(params, &t)?; // [B, T, V]
        let pos = seq.len() - 1;
        let row = &logits.data[pos * m.vocab..(pos + 1) * m.vocab];
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (i, &x) in row.iter().enumerate() {
            if x > bv {
                bv = x;
                best = i;
            }
        }
        let id = best as i32;
        if id == EOS {
            break;
        }
        seq.push(id);
        out.push(id);
    }
    let _ = prompt_len;
    Ok(out)
}

/// Convenience: decode the completion to text.
pub fn greedy_complete_text(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompt: &str,
    max_new: usize,
) -> Result<String> {
    let ids = greedy_complete(eng, params, tok, prompt, max_new)?;
    Ok(tok.decode(&ids))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;
    use std::path::Path;

    #[test]
    fn generates_bounded_valid_tokens() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let rt = Runtime::load(&dir, "pallas").unwrap();
        let m = rt.manifest.clone();
        let params = ModelParams::init(&m, &mut Rng::new(1));
        let samples = crate::data::corpus::gen_instruction_corpus(32, 1);
        let tok = Tokenizer::build(&crate::data::corpus::sample_texts(&samples), m.vocab);
        let mut eng = Engine::new(&rt);
        let ids = greedy_complete(&mut eng, &params, &tok, "what is 12 plus 10 ?", 6).unwrap();
        assert!(ids.len() <= 6);
        assert!(ids.iter().all(|&i| (i as usize) < m.vocab));
        // determinism
        let ids2 = greedy_complete(&mut eng, &params, &tok, "what is 12 plus 10 ?", 6).unwrap();
        assert_eq!(ids, ids2);
    }
}
