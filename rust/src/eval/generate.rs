//! Autoregressive generation through the segment executables — makes
//! trained checkpoints *usable*, and powers the qualitative samples and
//! generative metrics in the experiment drivers.
//!
//! Two paths exist (DESIGN.md §9/§10):
//!
//! * **continuous-batching KV-cached decode** (the default wherever the
//!   artifacts carry the decode ABI): [`ServeSession`] keeps every row of
//!   the `[B, T]` artifacts busy — queued prompts are admitted into rows
//!   freed mid-decode — and pays one `decode_step` execution per
//!   generated token;
//! * **legacy full-forward** ([`complete_legacy`]): O(T) full forwards
//!   per sample through row 0 only. Kept as the differential baseline
//!   (`rust/tests/it_decode.rs`, the `decode/*` bench arms) and as the
//!   fallback for legacy artifact dirs; force it with
//!   `LISA_DECODE=legacy`.
//!
//! Sampling (`SamplerSpec`: greedy / temperature / top-k / top-p) applies
//! identically on both paths; samplers are seeded per request
//! ([`request_seed`]), so a completion depends only on
//! `(prompt, spec, seed)` — not on the batch it rode in.
//!
//! Prompts longer than the artifact window are truncated to `T - 1`
//! tokens — loudly: a warning is logged and the returned [`Completion`]
//! carries `prompt_truncated` so callers can tell a near-empty answer
//! from a confident one.

use anyhow::Result;

use crate::data::tokenizer::{Tokenizer, BOS, EOS, PAD, SEP};
use crate::engine::serve::{request_seed, Request, SamplerSpec, ServeSession};
use crate::engine::{Completion, Engine, StopReason};
use crate::model::ModelParams;
use crate::runtime::HostTensorI32;

/// `<bos> prompt <sep>` — the decode-time input convention (matches
/// `data::encode_sft`'s prompt half).
pub fn encode_prompt(tok: &Tokenizer, prompt: &str) -> Vec<i32> {
    let mut seq = vec![BOS];
    seq.extend(tok.encode(prompt));
    seq.push(SEP);
    seq
}

/// True when [`complete_batch`] will take the KV-cached serving path for
/// this engine (the single source of truth for the routing — reporting
/// code should ask this instead of re-deriving the gate).
pub fn uses_cached_decode(eng: &Engine) -> bool {
    let forced = std::env::var("LISA_DECODE").map(|v| v == "legacy").unwrap_or(false);
    !forced && ServeSession::supported(eng)
}

/// Complete a batch of prompts under a sampling policy, one
/// [`Completion`] per prompt in order. Continuous-batching KV-cached
/// decode when the artifacts support it, legacy full-forward otherwise
/// (or under `LISA_DECODE=legacy`). Request `i` samples from the stream
/// seeded `request_seed(gen_seed, i)` on either path.
pub fn complete_batch(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompts: &[&str],
    max_new: usize,
    spec: SamplerSpec,
    gen_seed: u64,
) -> Result<Vec<Completion>> {
    if !uses_cached_decode(eng) {
        return prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                complete_legacy(
                    eng,
                    params,
                    tok,
                    p,
                    max_new,
                    spec.clone(),
                    request_seed(gen_seed, i),
                )
            })
            .collect();
    }
    let reqs: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            Request::sampled(
                encode_prompt(tok, p),
                max_new,
                spec.clone(),
                request_seed(gen_seed, i),
            )
        })
        .collect();
    let mut sess = ServeSession::new(eng, params)?;
    sess.run(&reqs, EOS, PAD)
}

/// Greedy [`complete_batch`] — the PR 4 surface, kept because greedy is
/// the parity baseline every differential suite runs.
pub fn greedy_complete_batch(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompts: &[&str],
    max_new: usize,
) -> Result<Vec<Completion>> {
    complete_batch(eng, params, tok, prompts, max_new, SamplerSpec::Greedy, 0)
}

/// Greedily complete `prompt`, returning the generated token ids (response
/// only, `<eos>`-terminated or length-capped). Thin wrapper over
/// [`greedy_complete_batch`].
pub fn greedy_complete(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompt: &str,
    max_new: usize,
) -> Result<Vec<i32>> {
    let mut out = greedy_complete_batch(eng, params, tok, &[prompt], max_new)?;
    Ok(out.pop().expect("one completion per prompt").tokens)
}

/// The pre-decode-ABI path: teacher-force the prompt into batch row 0,
/// re-run the full forward per emitted token, sample from the same
/// policy. One full L-block forward per token — the baseline the cached
/// paths are measured against.
pub fn complete_legacy(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompt: &str,
    max_new: usize,
    spec: SamplerSpec,
    seed: u64,
) -> Result<Completion> {
    let m = eng.rt.manifest.clone();
    let mut sampler = spec.build(seed);
    let mut seq = encode_prompt(tok, prompt);
    // same clipping policy + warn as the serve planner (shared helper,
    // so the prompt_truncated flags the parity suite compares can't drift)
    let prompt_truncated = crate::engine::decode::clip_prompt(&mut seq, m.seq);
    let mut out = Vec::new();
    let mut stop = StopReason::MaxNew;

    for _ in 0..max_new {
        if seq.len() >= m.seq {
            stop = StopReason::WindowFull;
            break;
        }
        let mut tokens = vec![PAD; m.batch * m.seq];
        tokens[..seq.len()].copy_from_slice(&seq);
        let t = HostTensorI32::from_vec(&[m.batch, m.seq], tokens);
        let logits = eng.logits(params, &t)?; // [B, T, V]
        let pos = seq.len() - 1;
        // one sampler draw per emitted token, same stream shape as the
        // cached paths — greedy degenerates to the shared first-of-ties
        // argmax, so tie-breaking itself cannot diverge
        let id = sampler.pick(&logits.data[pos * m.vocab..(pos + 1) * m.vocab]);
        if id == EOS {
            stop = StopReason::Eos;
            break;
        }
        seq.push(id);
        out.push(id);
    }
    Ok(Completion { tokens: out, prompt_truncated, stop })
}

/// Greedy [`complete_legacy`] — the differential-baseline surface used by
/// the parity suites and benches.
pub fn greedy_complete_legacy(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompt: &str,
    max_new: usize,
) -> Result<Completion> {
    complete_legacy(eng, params, tok, prompt, max_new, SamplerSpec::Greedy, 0)
}

/// Convenience: decode the completion to text.
pub fn greedy_complete_text(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompt: &str,
    max_new: usize,
) -> Result<String> {
    let ids = greedy_complete(eng, params, tok, prompt, max_new)?;
    Ok(tok.decode(&ids))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;
    use std::path::Path;

    #[test]
    fn generates_bounded_valid_tokens() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let rt = Runtime::load(&dir, "pallas").unwrap();
        let m = rt.manifest.clone();
        let params = ModelParams::init(&m, &mut Rng::new(1));
        let samples = crate::data::corpus::gen_instruction_corpus(32, 1);
        let tok = Tokenizer::build(&crate::data::corpus::sample_texts(&samples), m.vocab);
        let mut eng = Engine::new(&rt);
        let ids = greedy_complete(&mut eng, &params, &tok, "what is 12 plus 10 ?", 6).unwrap();
        assert!(ids.len() <= 6);
        assert!(ids.iter().all(|&i| (i as usize) < m.vocab));
        // determinism
        let ids2 = greedy_complete(&mut eng, &params, &tok, "what is 12 plus 10 ?", 6).unwrap();
        assert_eq!(ids, ids2);
    }

    #[test]
    fn legacy_path_reports_truncation() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let rt = Runtime::load(&dir, "pallas").unwrap();
        let m = rt.manifest.clone();
        let params = ModelParams::init(&m, &mut Rng::new(1));
        let samples = crate::data::corpus::gen_instruction_corpus(32, 1);
        let tok = Tokenizer::build(&crate::data::corpus::sample_texts(&samples), m.vocab);
        let mut eng = Engine::new(&rt);
        let long = "what is 1 plus 2 ".repeat(m.seq); // way past the window
        let c = greedy_complete_legacy(&mut eng, &params, &tok, &long, 4).unwrap();
        assert!(c.prompt_truncated);
        let short = greedy_complete_legacy(&mut eng, &params, &tok, "what is 1 plus 2 ?", 4)
            .unwrap();
        assert!(!short.prompt_truncated);
    }
}
