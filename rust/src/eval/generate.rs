//! Greedy autoregressive generation through the segment executables —
//! makes trained checkpoints *usable*, and powers the qualitative samples
//! and generative metrics in the experiment drivers.
//!
//! Two paths exist (DESIGN.md §9):
//!
//! * **batched KV-cached decode** (the default wherever the artifacts
//!   carry the decode ABI): [`DecodeSession`] fills every row of the
//!   `[B, T]` artifacts with a different prompt and pays one
//!   `decode_step` execution per generated token;
//! * **legacy full-forward** ([`greedy_complete_legacy`]): O(T) full
//!   forwards per sample through row 0 only. Kept as the differential
//!   baseline (`rust/tests/it_decode.rs`, the `decode/*` bench arms) and
//!   as the fallback for legacy artifact dirs; force it with
//!   `LISA_DECODE=legacy`.
//!
//! Prompts longer than the artifact window are truncated to `T - 1`
//! tokens — loudly: a warning is logged and the returned [`Completion`]
//! carries `prompt_truncated` so callers can tell a near-empty answer
//! from a confident one.

use anyhow::Result;

use crate::data::tokenizer::{Tokenizer, BOS, EOS, PAD, SEP};
use crate::engine::{Completion, DecodeSession, Engine, StopReason};
use crate::model::ModelParams;
use crate::runtime::HostTensorI32;

/// `<bos> prompt <sep>` — the decode-time input convention (matches
/// `data::encode_sft`'s prompt half).
pub fn encode_prompt(tok: &Tokenizer, prompt: &str) -> Vec<i32> {
    let mut seq = vec![BOS];
    seq.extend(tok.encode(prompt));
    seq.push(SEP);
    seq
}

/// True when [`greedy_complete_batch`] will take the batched KV-cached
/// path for this engine (the single source of truth for the routing —
/// reporting code should ask this instead of re-deriving the gate).
pub fn uses_cached_decode(eng: &Engine) -> bool {
    let forced = std::env::var("LISA_DECODE").map(|v| v == "legacy").unwrap_or(false);
    !forced && DecodeSession::supported(eng)
}

/// Greedily complete a batch of prompts, one [`Completion`] per prompt in
/// order. Batched KV-cached decode when the artifacts support it, legacy
/// full-forward otherwise (or under `LISA_DECODE=legacy`).
pub fn greedy_complete_batch(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompts: &[&str],
    max_new: usize,
) -> Result<Vec<Completion>> {
    if !uses_cached_decode(eng) {
        return prompts
            .iter()
            .map(|p| greedy_complete_legacy(eng, params, tok, p, max_new))
            .collect();
    }
    let encoded: Vec<Vec<i32>> = prompts.iter().map(|p| encode_prompt(tok, p)).collect();
    let mut sess = DecodeSession::new(eng, params)?;
    sess.greedy(&encoded, max_new, EOS, PAD)
}

/// Greedily complete `prompt`, returning the generated token ids (response
/// only, `<eos>`-terminated or length-capped). Thin wrapper over
/// [`greedy_complete_batch`].
pub fn greedy_complete(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompt: &str,
    max_new: usize,
) -> Result<Vec<i32>> {
    let mut out = greedy_complete_batch(eng, params, tok, &[prompt], max_new)?;
    Ok(out.pop().expect("one completion per prompt").tokens)
}

/// The pre-decode-ABI path: teacher-force the prompt into batch row 0,
/// re-run the full forward per emitted token. One full L-block forward
/// per token — the baseline the cached path is measured against.
pub fn greedy_complete_legacy(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompt: &str,
    max_new: usize,
) -> Result<Completion> {
    let m = eng.rt.manifest.clone();
    let mut seq = encode_prompt(tok, prompt);
    // same clipping policy + warn as the cached planner (shared helper,
    // so the prompt_truncated flags the parity suite compares can't drift)
    let prompt_truncated = crate::engine::decode::clip_prompt(&mut seq, m.seq);
    let mut out = Vec::new();
    let mut stop = StopReason::MaxNew;

    for _ in 0..max_new {
        if seq.len() >= m.seq {
            stop = StopReason::WindowFull;
            break;
        }
        let mut tokens = vec![PAD; m.batch * m.seq];
        tokens[..seq.len()].copy_from_slice(&seq);
        let t = HostTensorI32::from_vec(&[m.batch, m.seq], tokens);
        let logits = eng.logits(params, &t)?; // [B, T, V]
        let pos = seq.len() - 1;
        // shared first-of-ties argmax — tie-breaking identical to the
        // cached path by construction
        let id = crate::engine::decode::argmax(
            &logits.data[pos * m.vocab..(pos + 1) * m.vocab],
        );
        if id == EOS {
            stop = StopReason::Eos;
            break;
        }
        seq.push(id);
        out.push(id);
    }
    Ok(Completion { tokens: out, prompt_truncated, stop })
}

/// Convenience: decode the completion to text.
pub fn greedy_complete_text(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    prompt: &str,
    max_new: usize,
) -> Result<String> {
    let ids = greedy_complete(eng, params, tok, prompt, max_new)?;
    Ok(tok.decode(&ids))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;
    use std::path::Path;

    #[test]
    fn generates_bounded_valid_tokens() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let rt = Runtime::load(&dir, "pallas").unwrap();
        let m = rt.manifest.clone();
        let params = ModelParams::init(&m, &mut Rng::new(1));
        let samples = crate::data::corpus::gen_instruction_corpus(32, 1);
        let tok = Tokenizer::build(&crate::data::corpus::sample_texts(&samples), m.vocab);
        let mut eng = Engine::new(&rt);
        let ids = greedy_complete(&mut eng, &params, &tok, "what is 12 plus 10 ?", 6).unwrap();
        assert!(ids.len() <= 6);
        assert!(ids.iter().all(|&i| (i as usize) < m.vocab));
        // determinism
        let ids2 = greedy_complete(&mut eng, &params, &tok, "what is 12 plus 10 ?", 6).unwrap();
        assert_eq!(ids, ids2);
    }

    #[test]
    fn legacy_path_reports_truncation() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let rt = Runtime::load(&dir, "pallas").unwrap();
        let m = rt.manifest.clone();
        let params = ModelParams::init(&m, &mut Rng::new(1));
        let samples = crate::data::corpus::gen_instruction_corpus(32, 1);
        let tok = Tokenizer::build(&crate::data::corpus::sample_texts(&samples), m.vocab);
        let mut eng = Engine::new(&rt);
        let long = "what is 1 plus 2 ".repeat(m.seq); // way past the window
        let c = greedy_complete_legacy(&mut eng, &params, &tok, &long, 4).unwrap();
        assert!(c.prompt_truncated);
        let short = greedy_complete_legacy(&mut eng, &params, &tok, "what is 1 plus 2 ?", 4)
            .unwrap();
        assert!(!short.prompt_truncated);
    }
}
