//! Evaluation harness: perplexity, teacher-forced token accuracy,
//! exact-match answers, per-category MT-Bench-proxy scores, long-tail fact
//! recall (the memorization probe) and DoLa-style early-exit evaluation.
//!
//! Scoring substitutions vs the paper (DESIGN.md §4): there is no GPT-4
//! judge offline, so the MT-Bench proxy is `10 × teacher-forced accuracy on
//! the scored span` per category (answer span when the sample has one, the
//! whole response otherwise) — it preserves the orderings the paper's
//! tables establish, which is the reproduction target.

pub mod generate;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::data::corpus::{Category, FactTable};
use crate::data::loader::DataLoader;
use crate::data::tokenizer::Tokenizer;
use crate::data::{encode_sft, Encoded};
use crate::engine::Engine;
use crate::model::ModelParams;
use crate::runtime::HostTensor;

#[derive(Debug, Clone, Default)]
pub struct EvalReport {
    pub loss: f64,
    pub ppl: f64,
    pub token_acc: f64,
    pub exact_match: f64,
    pub n_examples: usize,
}

/// Mean val loss weighted by supervised-token counts, plus perplexity.
pub fn eval_loss(eng: &mut Engine, params: &ModelParams, dl: &DataLoader) -> Result<(f64, f64)> {
    let mut total = 0.0f64;
    let mut weight = 0.0f64;
    for (batch, _) in dl.eval_batches() {
        let n_valid = batch.targets.data.iter().filter(|&&t| t >= 0).count();
        if n_valid == 0 {
            continue;
        }
        let loss = eng.forward_loss(params, &batch)? as f64;
        total += loss * n_valid as f64;
        weight += n_valid as f64;
    }
    let mean = if weight > 0.0 { total / weight } else { 0.0 };
    Ok((mean, mean.exp()))
}

/// Argmax over the vocab for each row position. logits: [B, T, V].
fn argmax_tokens(logits: &HostTensor) -> Vec<i32> {
    let v = *logits.shape.last().unwrap();
    logits
        .data
        .chunks_exact(v)
        .map(|row| {
            let mut best = 0usize;
            let mut bv = f32::NEG_INFINITY;
            for (i, &x) in row.iter().enumerate() {
                if x > bv {
                    bv = x;
                    best = i;
                }
            }
            best as i32
        })
        .collect()
}

/// Per-example teacher-forced correctness on a span of target positions.
struct SpanScore {
    correct: usize,
    total: usize,
    all_correct: bool,
}

fn score_spans(
    eng: &mut Engine,
    params: &ModelParams,
    dl: &DataLoader,
    n_blocks: Option<usize>,
) -> Result<Vec<SpanScore>> {
    let seq = dl.examples()[0].tokens.len();
    let mut out = Vec::with_capacity(dl.len());
    let mut idx = 0usize;
    for (batch, n_real) in dl.eval_batches() {
        let logits = match n_blocks {
            Some(nb) => eng.logits_at(params, &batch.tokens, nb)?,
            None => eng.logits(params, &batch.tokens)?,
        };
        let preds = argmax_tokens(&logits);
        for row in 0..n_real {
            let e = &dl.examples()[idx];
            idx += 1;
            let (a, b) = match e.answer_span {
                Some(span) => span,
                None => (0, seq),
            };
            let mut correct = 0;
            let mut total = 0;
            for t in a..b {
                if e.targets[t] < 0 {
                    continue;
                }
                total += 1;
                if preds[row * seq + t] == e.targets[t] {
                    correct += 1;
                }
            }
            out.push(SpanScore { correct, total, all_correct: total > 0 && correct == total });
        }
    }
    Ok(out)
}

/// Full report: loss/ppl + token accuracy + exact match over answer spans.
pub fn evaluate(eng: &mut Engine, params: &ModelParams, dl: &DataLoader) -> Result<EvalReport> {
    let (loss, ppl) = eval_loss(eng, params, dl)?;
    let spans = score_spans(eng, params, dl, None)?;
    let (mut c, mut t, mut em, mut em_n) = (0usize, 0usize, 0usize, 0usize);
    for (s, e) in spans.iter().zip(dl.examples()) {
        c += s.correct;
        t += s.total;
        if e.answer_span.is_some() {
            em_n += 1;
            em += s.all_correct as usize;
        }
    }
    Ok(EvalReport {
        loss,
        ppl,
        token_acc: if t > 0 { c as f64 / t as f64 } else { 0.0 },
        exact_match: if em_n > 0 { em as f64 / em_n as f64 } else { 0.0 },
        n_examples: dl.len(),
    })
}

/// Generative exact match: decode each sample's prompt through the
/// serving path ([`generate::complete_batch`] — continuous-batching
/// KV-cached decode wherever the artifacts support it) under the given
/// sampling policy, and score the completion against the encoded
/// reference response. Unlike [`EvalReport::exact_match`]
/// (teacher-forced), the model must produce the whole answer on its own —
/// the deployment-shaped metric. `SamplerSpec::Greedy` + any seed
/// reproduces the PR 4 numbers.
pub fn generative_exact_match(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    samples: &[crate::data::Sample],
    max_new: usize,
    spec: crate::engine::SamplerSpec,
    gen_seed: u64,
) -> Result<f64> {
    Ok(generative_completions(eng, params, tok, samples, max_new, spec, gen_seed)?.0)
}

/// [`generative_exact_match`] plus the decoded completions themselves, so
/// callers that also want to display samples don't pay a second decode.
pub fn generative_completions(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
    samples: &[crate::data::Sample],
    max_new: usize,
    spec: crate::engine::SamplerSpec,
    gen_seed: u64,
) -> Result<(f64, Vec<crate::engine::Completion>)> {
    if samples.is_empty() {
        return Ok((0.0, Vec::new()));
    }
    let prompts: Vec<&str> = samples.iter().map(|s| s.prompt.as_str()).collect();
    let outs = generate::complete_batch(eng, params, tok, &prompts, max_new, spec, gen_seed)?;
    let em = outs
        .iter()
        .zip(samples)
        .filter(|(c, s)| c.tokens == tok.encode(&s.response))
        .count();
    Ok((em as f64 / samples.len() as f64, outs))
}

/// Exact match at an early-exit depth (Table 12: DoLa-style evaluation).
pub fn exact_match_at_depth(
    eng: &mut Engine,
    params: &ModelParams,
    dl: &DataLoader,
    n_blocks: usize,
) -> Result<f64> {
    let spans = score_spans(eng, params, dl, Some(n_blocks))?;
    let (mut em, mut n) = (0usize, 0usize);
    for (s, e) in spans.iter().zip(dl.examples()) {
        if e.answer_span.is_some() {
            n += 1;
            em += s.all_correct as usize;
        }
    }
    Ok(if n > 0 { em as f64 / n as f64 } else { 0.0 })
}

/// MT-Bench proxy: per-category `10 × span accuracy` (answer span when
/// present, response otherwise), plus the category average.
pub fn category_scores(
    eng: &mut Engine,
    params: &ModelParams,
    dl: &DataLoader,
) -> Result<(BTreeMap<Category, f64>, f64)> {
    let spans = score_spans(eng, params, dl, None)?;
    let mut acc: BTreeMap<Category, (usize, usize)> = BTreeMap::new();
    for (s, e) in spans.iter().zip(dl.examples()) {
        let Some(cat) = e.category else { continue };
        let ent = acc.entry(cat).or_insert((0, 0));
        ent.0 += s.correct;
        ent.1 += s.total;
    }
    let scores: BTreeMap<Category, f64> = acc
        .into_iter()
        .map(|(cat, (c, t))| (cat, if t > 0 { 10.0 * c as f64 / t as f64 } else { 0.0 }))
        .collect();
    let avg = if scores.is_empty() {
        0.0
    } else {
        scores.values().sum::<f64>() / scores.len() as f64
    };
    Ok((scores, avg))
}

/// Long-tail memorization probe (the Fig 5 substitution): ask the
/// canonical fact table's humanities questions, report (head, tail)
/// exact-match where head = the 8 most frequent facts.
pub fn fact_recall(
    eng: &mut Engine,
    params: &ModelParams,
    tok: &Tokenizer,
) -> Result<(f64, f64)> {
    let m = &eng.rt.manifest;
    let facts = FactTable::canonical();
    let mut samples = Vec::new();
    for f in &facts.facts {
        let year: String = f
            .year
            .to_string()
            .chars()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(" ");
        samples.push(crate::data::Sample {
            prompt: format!("who built {} ?", f.entity),
            response: format!("answer : {}", f.builder),
            category: Category::Humanities,
            answer: Some(f.builder.clone()),
            fact_id: Some(samples.len() / 2),
        });
        samples.push(crate::data::Sample {
            prompt: format!("in what year was {} built ?", f.entity),
            response: format!("answer : {year}"),
            category: Category::Humanities,
            answer: Some(year),
            fact_id: Some(samples.len() / 2),
        });
    }
    let enc: Vec<Encoded> = samples.iter().map(|s| encode_sft(tok, s, m.seq)).collect();
    let dl = DataLoader::new(enc, m.batch, m.seq, 0);
    let spans = score_spans(eng, params, &dl, None)?;
    let (mut hc, mut hn, mut tc, mut tn) = (0usize, 0usize, 0usize, 0usize);
    for (s, e) in spans.iter().zip(dl.examples()) {
        let fi = e.fact_id.unwrap_or(usize::MAX);
        if fi < 8 {
            hn += 1;
            hc += s.all_correct as usize;
        } else {
            tn += 1;
            tc += s.all_correct as usize;
        }
    }
    Ok((
        if hn > 0 { hc as f64 / hn as f64 } else { 0.0 },
        if tn > 0 { tc as f64 / tn as f64 } else { 0.0 },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        let t = HostTensor::from_vec(&[1, 2, 3], vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0]);
        assert_eq!(argmax_tokens(&t), vec![1, 0]);
    }
}
